//! End-to-end validation driver (the EXPERIMENTS.md "headline" run).
//!
//! Proves all three layers compose on a real small workload:
//!   1. loads the AOT HLO artifacts (L1 Pallas kernels lowered through
//!      the L2 jax graph) into the PJRT runtime;
//!   2. runs OneBatchPAM end-to-end on an MNIST-like 6k x 784 workload
//!      with the XLA backend on the hot path (pairwise + NNIW argmin),
//!      and again with the native backend;
//!   3. runs the paper's key comparison (FasterPAM / FasterCLARA-5 /
//!      k-means++ / Random) and reports the headline metrics: ΔRO vs the
//!      best method and the dissimilarity-computation reduction.
//!
//! Run: `make artifacts && cargo run --release --example paper_e2e`

use obpam::backend::{NativeBackend, XlaBackend};
use obpam::baselines;
use obpam::coordinator::{one_batch_pam, OneBatchConfig, SamplerKind};
use obpam::data::synth;
use obpam::dissim::{DissimCounter, Metric};
use obpam::eval;
use obpam::runtime::Runtime;
use std::rc::Rc;

struct Row {
    name: String,
    objective: f64,
    seconds: f64,
    dissim: u64,
}

fn main() -> anyhow::Result<()> {
    let data = synth::generate("mnist", 0.1, 99);
    let (n, p, k) = (data.n(), data.p(), 10);
    println!("== paper_e2e: MNIST-like workload n={n} p={p} k={k}, l1 ==\n");
    let eval_d = DissimCounter::new(Metric::L1);
    let mut rows: Vec<Row> = Vec::new();

    // --- OneBatchPAM on the XLA (Pallas artifact) hot path ---------------
    match Runtime::load_default() {
        Ok(rt) => {
            let backend = XlaBackend::new(Rc::new(rt), Metric::L1, false);
            let cfg = OneBatchConfig { k, sampler: SamplerKind::Nniw, seed: 5, ..Default::default() };
            let r = one_batch_pam(&data.x, &cfg, &backend)?;
            rows.push(Row {
                name: "OneBatchPAM (xla/pallas)".into(),
                objective: eval::objective(&data.x, &r.medoids, &eval_d),
                seconds: r.stats.seconds,
                dissim: r.stats.dissim_count,
            });
        }
        Err(e) => println!("[warn] XLA path skipped ({e}); run `make artifacts`\n"),
    }

    // --- OneBatchPAM native ------------------------------------------------
    let backend = NativeBackend::new(Metric::L1);
    let cfg = OneBatchConfig { k, sampler: SamplerKind::Nniw, seed: 5, ..Default::default() };
    let r = one_batch_pam(&data.x, &cfg, &backend)?;
    rows.push(Row {
        name: "OneBatchPAM (native)".into(),
        objective: eval::objective(&data.x, &r.medoids, &eval_d),
        seconds: r.stats.seconds,
        dissim: r.stats.dissim_count,
    });

    // --- baselines ----------------------------------------------------------
    {
        let b = NativeBackend::new(Metric::L1);
        let r = baselines::faster_pam(&data.x, k, 50, 5, &b)?;
        rows.push(Row {
            name: "FasterPAM".into(),
            objective: eval::objective(&data.x, &r.medoids, &eval_d),
            seconds: r.stats.seconds,
            dissim: r.stats.dissim_count,
        });
    }
    {
        let b = NativeBackend::new(Metric::L1);
        let r = baselines::faster_clara(&data.x, &baselines::ClaraConfig::new(k, 5, 5), &b)?;
        rows.push(Row {
            name: "FasterCLARA-5".into(),
            objective: eval::objective(&data.x, &r.medoids, &eval_d),
            seconds: r.stats.seconds,
            dissim: r.stats.dissim_count,
        });
    }
    {
        let d = DissimCounter::new(Metric::L1);
        let r = baselines::kmeanspp(&data.x, k, 5, &d);
        rows.push(Row {
            name: "k-means++".into(),
            objective: eval::objective(&data.x, &r.medoids, &eval_d),
            seconds: r.stats.seconds,
            dissim: r.stats.dissim_count,
        });
    }
    {
        let r = baselines::random_select(&data.x, k, 5);
        rows.push(Row {
            name: "Random".into(),
            objective: eval::objective(&data.x, &r.medoids, &eval_d),
            seconds: r.stats.seconds,
            dissim: r.stats.dissim_count,
        });
    }

    // --- report --------------------------------------------------------------
    let best = rows.iter().map(|r| r.objective).fold(f64::INFINITY, f64::min);
    println!(
        "{:<26} {:>10} {:>8} {:>9} {:>12}",
        "method", "objective", "dRO %", "time", "dissim"
    );
    for r in &rows {
        println!(
            "{:<26} {:>10.4} {:>8.2} {:>8.3}s {:>12}",
            r.name,
            r.objective,
            (r.objective / best - 1.0) * 100.0,
            r.seconds,
            r.dissim
        );
    }
    let ob = rows.iter().find(|r| r.name.starts_with("OneBatchPAM (native")).unwrap();
    let fp = rows.iter().find(|r| r.name == "FasterPAM").unwrap();
    println!(
        "\nheadline: OneBatchPAM dRO vs FasterPAM = {:+.2}% | dissim reduction {:.1}x | speedup {:.1}x",
        (ob.objective / fp.objective - 1.0) * 100.0,
        fp.dissim as f64 / ob.dissim as f64,
        fp.seconds / ob.seconds
    );
    println!("paper claim: <2% objective penalty at ~7-12x less work (small scale).");
    Ok(())
}
