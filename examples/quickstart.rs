//! Quickstart: one entry point, every method.  Runs OneBatchPAM and
//! three baselines through the unified [`obpam::solver`] API — each
//! method is just a paper row label — and compares the three things the
//! paper is about: objective quality, wall-clock time, and the number of
//! dissimilarity computations.
//!
//! Run: `cargo run --release --example quickstart`

use obpam::backend::NativeBackend;
use obpam::data::synth;
use obpam::dissim::{DissimCounter, Metric};
use obpam::eval;
use obpam::solver::{self, MethodSpec, SolveSpec};

fn main() -> anyhow::Result<()> {
    // 5 well-separated Gaussian clusters, 4000 points, 8 features.
    let data = synth::try_generate("blobs_4000_8_5", 1.0, 42)?;
    let (n, p, k) = (data.n(), data.p(), 5);
    println!("dataset: n={n} p={p}, k={k}, metric=l1\n");

    let eval_d = DissimCounter::new(Metric::L1);
    println!("{:<14} {:>10} {:>10} {:>20}", "method", "objective", "time", "dissim-computations");

    // any paper row label runs through the same solve() call — swap in
    // "BanditPAM++-2", "FasterCLARA-50", "OneBatch-unif-steepest", ...
    let mut runs = Vec::new();
    for label in ["OneBatch-nniw", "FasterPAM", "k-means++", "Random"] {
        let method = MethodSpec::parse(label).expect("paper row label");
        let backend = NativeBackend::new(Metric::L1);
        let r = solver::solve(&data.x, &SolveSpec::new(method, k, 7), &backend)?;
        let obj = eval::objective(&data.x, &r.medoids, &eval_d);
        println!(
            "{label:<14} {obj:>10.5} {:>9.3}s {:>20}",
            r.stats.seconds, r.stats.dissim_count
        );
        runs.push(r);
    }

    let (ob, fp) = (&runs[0], &runs[1]);
    println!(
        "\nOneBatchPAM medoids: {:?}\n\
         expected: objective within ~2% of FasterPAM using ~{}x fewer dissimilarities",
        ob.medoids,
        (fp.stats.dissim_count.max(1) / ob.stats.dissim_count.max(1)).max(1)
    );
    Ok(())
}
