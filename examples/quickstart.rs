//! Quickstart: one entry point, every method, every data source.  Runs
//! OneBatchPAM and three baselines through the unified [`obpam::solver`]
//! API — each method is just a paper row label — and compares the three
//! things the paper is about: objective quality, wall-clock time, and
//! the number of dissimilarity computations.  Then clusters a CSV
//! loaded from disk through the same [`DataSource`] URI pipeline.
//!
//! Run: `cargo run --release --example quickstart`

use obpam::backend::NativeBackend;
use obpam::data::DataSource;
use obpam::dissim::{DissimCounter, Metric};
use obpam::eval;
use obpam::solver::{self, MethodSpec, SolveSpec};

fn main() -> anyhow::Result<()> {
    // 5 well-separated Gaussian clusters, 4000 points, 8 features —
    // synth: URIs (or bare names) address the seeded generators.
    let data = DataSource::parse("synth:blobs_4000_8_5")?.load(1.0, 42)?;
    let (n, p, k) = (data.n(), data.p(), 5);
    println!("dataset: n={n} p={p}, k={k}, metric=l1\n");

    let eval_d = DissimCounter::new(Metric::L1);
    println!("{:<14} {:>10} {:>10} {:>20}", "method", "objective", "time", "dissim-computations");

    // any paper row label runs through the same solve() call — swap in
    // "BanditPAM++-2", "FasterCLARA-50", "OneBatch-unif-steepest", ...
    let mut runs = Vec::new();
    for label in ["OneBatch-nniw", "FasterPAM", "k-means++", "Random"] {
        let method = MethodSpec::parse(label).expect("paper row label");
        let backend = NativeBackend::new(Metric::L1);
        let r = solver::solve(&data.x, &SolveSpec::new(method, k, 7), &backend)?;
        let obj = eval::objective(&data.x, &r.medoids, &eval_d);
        println!(
            "{label:<14} {obj:>10.5} {:>9.3}s {:>20}",
            r.stats.seconds, r.stats.dissim_count
        );
        runs.push(r);
    }

    let (ob, fp) = (&runs[0], &runs[1]);
    println!(
        "\nOneBatchPAM medoids: {:?}\n\
         expected: objective within ~2% of FasterPAM using ~{}x fewer dissimilarities",
        ob.medoids,
        (fp.stats.dissim_count.max(1) / ob.stats.dissim_count.max(1)).max(1)
    );

    // --- loaded data: the same pipeline, addressed by file: URI -------
    // Export a slice of the synthetic data as a plain CSV, then cluster
    // it from disk exactly like a real dataset (no synth:-specific code).
    let csv_path = std::env::temp_dir().join("obpam_quickstart.csv");
    let mut csv = String::from("f0,f1,f2,f3,f4,f5,f6,f7\n");
    for i in 0..500 {
        let row: Vec<String> = data.x.row(i).iter().map(|v| format!("{v}")).collect();
        csv.push_str(&row.join(","));
        csv.push('\n');
    }
    std::fs::write(&csv_path, csv)?;

    let source = DataSource::parse(&format!("file:{}", csv_path.display()))?;
    let loaded = source.load(1.0, 0)?;
    // file runs often want a different metric than the paper's L1: put
    // it on the spec and build the backend from it.
    let spec = SolveSpec {
        metric: Metric::L2,
        ..SolveSpec::new(MethodSpec::parse("OneBatch-nniw").unwrap(), k, 7)
    };
    let backend = NativeBackend::new(spec.metric);
    let r = solver::solve(&loaded.x, &spec, &backend)?;
    println!(
        "\nloaded {} (n={} p={}) via {}:\n  l2 medoids: {:?}",
        loaded.name,
        loaded.n(),
        loaded.p(),
        source.canon(),
        r.medoids
    );
    std::fs::remove_file(&csv_path).ok();
    Ok(())
}
