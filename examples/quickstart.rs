//! Quickstart: cluster a synthetic blob dataset with OneBatchPAM and
//! compare the three things the paper is about — objective quality,
//! wall-clock time, and the number of dissimilarity computations —
//! against FasterPAM and a random selection.
//!
//! Run: `cargo run --release --example quickstart`

use obpam::backend::NativeBackend;
use obpam::baselines;
use obpam::coordinator::{one_batch_pam, OneBatchConfig, SamplerKind};
use obpam::data::synth;
use obpam::dissim::{DissimCounter, Metric};
use obpam::eval;

fn main() -> anyhow::Result<()> {
    // 5 well-separated Gaussian clusters, 4000 points, 8 features.
    let data = synth::generate("blobs_4000_8_5", 1.0, 42);
    let (n, p, k) = (data.n(), data.p(), 5);
    println!("dataset: n={n} p={p}, k={k}, metric=l1\n");

    let eval_d = DissimCounter::new(Metric::L1);

    // --- OneBatchPAM (the paper's method, NNIW variant) ------------------
    let backend = NativeBackend::new(Metric::L1);
    let cfg = OneBatchConfig { k, sampler: SamplerKind::Nniw, seed: 7, ..Default::default() };
    let ob = one_batch_pam(&data.x, &cfg, &backend)?;
    let ob_obj = eval::objective(&data.x, &ob.medoids, &eval_d);

    // --- FasterPAM (exact local search, O(n^2)) ---------------------------
    let backend_fp = NativeBackend::new(Metric::L1);
    let fp = baselines::faster_pam(&data.x, k, 50, 7, &backend_fp)?;
    let fp_obj = eval::objective(&data.x, &fp.medoids, &eval_d);

    // --- Random -----------------------------------------------------------
    let rnd = baselines::random_select(&data.x, k, 7);
    let rnd_obj = eval::objective(&data.x, &rnd.medoids, &eval_d);

    println!("{:<14} {:>10} {:>10} {:>14}", "method", "objective", "time", "dissim-computations");
    for (name, obj, r) in [
        ("OneBatchPAM", ob_obj, &ob),
        ("FasterPAM", fp_obj, &fp),
        ("Random", rnd_obj, &rnd),
    ] {
        println!(
            "{name:<14} {obj:>10.5} {:>9.3}s {:>14}",
            r.stats.seconds, r.stats.dissim_count
        );
    }
    println!(
        "\nOneBatchPAM medoids: {:?}\n\
         expected: objective within ~2% of FasterPAM using ~{}x fewer dissimilarities",
        ob.medoids,
        (fp.stats.dissim_count.max(1) / ob.stats.dissim_count.max(1)).max(1)
    );
    Ok(())
}
