//! Clustering service demo: start the TCP job server, fire a burst of
//! concurrent clustering requests at it, and report latency /
//! throughput / backpressure behaviour.
//!
//! Run: `cargo run --release --example server`

use obpam::server::{request, serve, ServerConfig};
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let handle = serve(ServerConfig { addr: "127.0.0.1:0".into(), workers: 2, queue_cap: 8 })?;
    println!("server on {}", handle.addr);
    assert_eq!(request(handle.addr, "ping")?.split_whitespace().next(), Some("pong"));

    // a burst of mixed jobs
    let jobs: Vec<String> = (0..6)
        .map(|i| {
            format!(
                "cluster dataset=blobs_{}_8_4 k=4 sampler={} seed={i}",
                1_000 + 500 * i,
                if i % 2 == 0 { "nniw" } else { "unif" }
            )
        })
        .collect();

    let t0 = Instant::now();
    let mut handles = Vec::new();
    for job in jobs.clone() {
        let addr = handle.addr;
        handles.push(std::thread::spawn(move || {
            let t = Instant::now();
            let reply = request(addr, &job).unwrap_or_else(|e| format!("err {e}"));
            (job, reply, t.elapsed().as_secs_f64())
        }));
    }
    let mut ok = 0;
    let mut latencies = Vec::new();
    for h in handles {
        let (job, reply, lat) = h.join().unwrap();
        let status = reply.split_whitespace().next().unwrap_or("?").to_string();
        println!("[{lat:7.3}s] {status:<4} <- {job}");
        if status == "ok" {
            ok += 1;
            latencies.push(lat);
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    println!(
        "\n{ok}/{} ok | wall {wall:.2}s | throughput {:.2} jobs/s | p50 latency {:.3}s | p max {:.3}s",
        jobs.len(),
        ok as f64 / wall,
        latencies.get(latencies.len() / 2).copied().unwrap_or(f64::NAN),
        latencies.last().copied().unwrap_or(f64::NAN),
    );

    handle.shutdown();
    println!("server stopped");
    Ok(())
}
