//! Clustering service demo (protocol v6): start the TCP job server,
//! fire a burst of *mixed-method* clustering requests at it (any paper
//! row label is addressable with `method=`), then repeat the burst to
//! show the sharded dataset cache at work — the warm round reports
//! `cache=hit` on every job.  A middle section demos the asynchronous
//! job-handle API: `submit` returns `job=j<id>` immediately, `poll`
//! probes without blocking, and `wait` collects each result — the
//! submitting loop finishes before any solve does, which is the whole
//! point.  Next, model serving: `promote` captures a finished job's
//! fitted medoids into the model registry, and `assign` labels fresh
//! points against them — no dataset resident, just the `k x p` medoid
//! rows.  A final round clusters a CSV written to disk through the
//! same cache (`dataset=file:... metric=l2`), and the closing `jobs` /
//! `stats` lines show the registry gauges, per-method aggregates and
//! per-model serving counters.
//!
//! Run: `cargo run --release --example server`

use obpam::server::{request, serve, ServerConfig};
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    // workers/queue_cap/budget accept 0 = auto; the default admission
    // budget admits this whole mixed burst (each job's `cost=` work
    // units are visible in its reply)
    let handle = serve(ServerConfig {
        addr: "127.0.0.1:0".into(),
        workers: 2,
        queue_cap: 8,
        cache_cap: 32,
        ..Default::default()
    })?;
    println!("server on {}", handle.addr);
    assert_eq!(request(handle.addr, "ping")?.split_whitespace().next(), Some("pong"));

    // a burst of mixed-method jobs over three distinct datasets
    let methods =
        ["OneBatch-nniw", "FasterPAM", "k-means++", "FasterCLARA-5", "OneBatch-lwcs", "kmc2-20"];
    let jobs: Vec<String> = methods
        .iter()
        .enumerate()
        .map(|(i, m)| {
            format!("cluster dataset=blobs_{}_8_4 k=4 method={m} seed={i}", 1_000 + 500 * (i % 3))
        })
        .collect();

    for round in ["cold", "warm"] {
        let t0 = Instant::now();
        let mut handles = Vec::new();
        for job in jobs.clone() {
            let addr = handle.addr;
            handles.push(std::thread::spawn(move || {
                let t = Instant::now();
                let reply = request(addr, &job).unwrap_or_else(|e| format!("err {e}"));
                (job, reply, t.elapsed().as_secs_f64())
            }));
        }
        let mut ok = 0;
        let mut latencies = Vec::new();
        for h in handles {
            let (job, reply, lat) = h.join().unwrap();
            let status = reply.split_whitespace().next().unwrap_or("?").to_string();
            let cache = reply
                .split_whitespace()
                .find(|t| t.starts_with("cache="))
                .unwrap_or("cache=?")
                .to_string();
            println!("[{lat:7.3}s] {status:<4} {cache:<10} <- {job}");
            if status == "ok" {
                ok += 1;
                latencies.push(lat);
            }
        }
        let wall = t0.elapsed().as_secs_f64();
        latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
        println!(
            "{round} round: {ok}/{} ok | wall {wall:.2}s | throughput {:.2} jobs/s | \
             p50 latency {:.3}s | p max {:.3}s\n",
            jobs.len(),
            ok as f64 / wall,
            latencies.get(latencies.len() / 2).copied().unwrap_or(f64::NAN),
            latencies.last().copied().unwrap_or(f64::NAN),
        );
    }

    // --- async job handles: submit now, collect whenever -------------
    // One-shot `cluster` lines above block their connection for the
    // whole solve; `submit` returns a handle immediately, so all six
    // jobs are in flight before the first one finishes.
    let t0 = Instant::now();
    let mut ids = Vec::new();
    for (i, m) in methods.iter().enumerate() {
        let line =
            format!("submit dataset=blobs_2500_8_4 k=4 method={m} seed={i} deadline_ms=60000");
        let reply = request(handle.addr, &line)?;
        let id = reply
            .split_whitespace()
            .find_map(|t| t.strip_prefix("job="))
            .map(str::to_string);
        println!("submit {m:<14} -> {reply}");
        match id {
            Some(id) => ids.push(id),
            None => println!("  (not admitted; skipping)"),
        }
    }
    println!(
        "all {} submits returned in {:.3}s (solves still running)",
        ids.len(),
        t0.elapsed().as_secs_f64()
    );
    if let Some(first) = ids.first() {
        println!("poll   {first:<14} -> {}", request(handle.addr, &format!("poll job={first}"))?);
    }
    for id in &ids {
        let reply = request(handle.addr, &format!("wait job={id} timeout_ms=120000"))?;
        let brief: String = reply.split_whitespace().take(3).collect::<Vec<_>>().join(" ");
        println!("wait   {id:<14} -> {brief} ...");
    }
    println!("{}\n", request(handle.addr, "jobs")?);

    // --- model serving: promote a finished job, assign new points ----
    // The solve already captured the fitted medoids; `promote` moves
    // them into the model registry and `assign` serves nearest-medoid
    // lookups from them alone — the training dataset is not needed.
    if let Some(first) = ids.first() {
        let promoted = request(handle.addr, &format!("promote job={first} name=demo"))?;
        println!("promote {first:<13} -> {promoted}");
        if promoted.starts_with("ok ") {
            let assign =
                "assign model=demo point=0,0,0,0,0,0,0,0 point=9,9,9,9,9,9,9,9 top2=1";
            println!("assign  demo          -> {}", request(handle.addr, assign)?);
            println!("{}\n", request(handle.addr, "models")?);
        }
    }

    // --- loaded data over the same wire: dataset=file:... ------------
    let csv_path = std::env::temp_dir().join("obpam_server_demo.csv");
    let mut csv = String::from("x,y,z\n");
    for i in 0..300 {
        let c = (i % 3) as f64 * 20.0;
        csv.push_str(&format!(
            "{},{},{}\n",
            c + (i % 7) as f64 * 0.3,
            c - (i % 5) as f64 * 0.2,
            c + (i % 4) as f64 * 0.1
        ));
    }
    std::fs::write(&csv_path, csv)?;
    let file_job =
        format!("cluster dataset=file:{} metric=l2 k=3 seed=1", csv_path.display());
    for round in ["cold", "warm"] {
        let reply = request(handle.addr, &file_job)?;
        let cache = reply
            .split_whitespace()
            .find(|t| t.starts_with("cache="))
            .unwrap_or("cache=?")
            .to_string();
        println!("file round {round:<4}: {cache:<10} <- {file_job}");
    }

    // cache_misses equals the number of distinct (source, scale, seed)
    // keys; the warm rounds reloaded nothing, and the jobs.* lifecycle
    // counters + per-method aggregates close out the demo.
    println!("{}", request(handle.addr, "stats")?);

    handle.shutdown();
    std::fs::remove_file(&csv_path).ok();
    println!("server stopped");
    Ok(())
}
