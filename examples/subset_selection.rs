//! Subset selection / active learning — the use case the paper's intro
//! motivates (Kaushal et al. 2019; de Mathelin et al. 2021): pick k
//! representative exemplars from a large unlabeled pool of embeddings,
//! then measure coverage (mean distance from every pool point to its
//! nearest exemplar) and per-cluster balance.
//!
//! Run: `cargo run --release --example subset_selection`

use obpam::backend::NativeBackend;
use obpam::coordinator::{one_batch_pam, OneBatchConfig, SamplerKind};
use obpam::data::synth;
use obpam::dissim::{DissimCounter, Metric};
use obpam::eval;
use obpam::baselines;

fn main() -> anyhow::Result<()> {
    // an "embedding pool": mnist-like sparse vectors, 6k x 784
    let pool = synth::generate("mnist", 0.1, 11);
    let budget = 25; // labeling budget
    println!(
        "pool: n={} p={} | selecting {budget} exemplars (l1 metric)\n",
        pool.n(),
        pool.p()
    );

    let eval_d = DissimCounter::new(Metric::L1);

    // OneBatchPAM selection
    let backend = NativeBackend::new(Metric::L1);
    let cfg = OneBatchConfig { k: budget, sampler: SamplerKind::Nniw, seed: 3, ..Default::default() };
    let sel = one_batch_pam(&pool.x, &cfg, &backend)?;
    let coverage = eval::objective(&pool.x, &sel.medoids, &eval_d);

    // naive alternatives a practitioner would try first
    let rand = baselines::random_select(&pool.x, budget, 3);
    let rand_cov = eval::objective(&pool.x, &rand.medoids, &eval_d);
    let kpp_d = DissimCounter::new(Metric::L1);
    let kpp = baselines::kmeanspp(&pool.x, budget, 3, &kpp_d);
    let kpp_cov = eval::objective(&pool.x, &kpp.medoids, &eval_d);

    println!("{:<14} {:>10} {:>10}", "selector", "coverage", "time");
    println!("{:<14} {coverage:>10.4} {:>9.3}s", "OneBatchPAM", sel.stats.seconds);
    println!("{:<14} {kpp_cov:>10.4} {:>9.3}s", "k-means++", kpp.stats.seconds);
    println!("{:<14} {rand_cov:>10.4} {:>9.3}s", "random", rand.stats.seconds);

    // balance: how many pool points each exemplar represents
    let mut counts = vec![0usize; budget];
    for i in 0..pool.n() {
        let mut best = (0usize, f32::INFINITY);
        for (j, &m) in sel.medoids.iter().enumerate() {
            let v = Metric::L1.eval(pool.x.row(i), pool.x.row(m));
            if v < best.1 {
                best = (j, v);
            }
        }
        counts[best.0] += 1;
    }
    counts.sort_unstable();
    println!(
        "\nexemplar cluster sizes: min={} median={} max={} (of {} points)",
        counts[0],
        counts[budget / 2],
        counts[budget - 1],
        pool.n()
    );
    println!("selected exemplar rows: {:?}", sel.medoids);
    Ok(())
}
