"""AOT lowering: L2 model functions -> HLO text artifacts + manifest.

Emits HLO *text* (never ``.serialize()``): the image's xla_extension 0.5.1
rejects jax>=0.5 protos with 64-bit instruction ids; the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

The manifest is a whitespace table (one artifact per line) because the Rust
side has no serde offline:

    name kind metric n p m k file

Unused dims are 0 and unused metric is "-".  Usage:

    cd python && python -m compile.aot --out ../artifacts [--quick]
"""

from __future__ import annotations

import argparse
import os
import sys

import jax
from jax._src.lib import xla_client as xc

from . import model

# Shape-bucket grid (see DESIGN.md §L2).  The Rust runtime pads up to the
# nearest bucket.  N_TILE is the fixed row-tile the coordinator streams.
N_TILE = 2048
P_BUCKETS = [16, 64, 128, 784, 3072]
M_BUCKETS = [256, 512, 1024, 1536, 2048]
K_BUCKETS = [10, 50, 100]

# --quick: minimal grid for fast iteration (covers tests + quickstart).
P_QUICK = [16, 64]
M_QUICK = [256]
K_QUICK = [10]


def to_hlo_text(fn, args) -> str:
    """Lower a jax function to HLO text via stablehlo -> XlaComputation."""
    lowered = jax.jit(fn).lower(*args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def build_configs(quick: bool):
    """Yield (name, kind, metric, n, p, m, k) artifact configs."""
    ps = P_QUICK if quick else P_BUCKETS
    ms = M_QUICK if quick else M_BUCKETS
    ks = K_QUICK if quick else K_BUCKETS
    cfgs = []
    for kind in ("pairwise", "pairwise_dense"):
        for metric in ("l1", "sqeuclidean"):
            for p in ps:
                for m in ms:
                    name = f"{kind}_{metric}_n{N_TILE}_p{p}_m{m}"
                    cfgs.append((name, kind, metric, N_TILE, p, m, 0))
    for m in ms:
        for k in ks:
            cfgs.append((f"gains_n{N_TILE}_m{m}_k{k}", "gains", "-", N_TILE, 0, m, k))
    for k in ks:
        cfgs.append((f"top2_n{N_TILE}_k{k}", "top2", "-", N_TILE, 0, 0, k))
    for m in ms:
        cfgs.append((f"argmin_n{N_TILE}_m{m}", "argmin", "-", N_TILE, 0, m, 0))
        cfgs.append((f"objective_m{m}", "objective", "-", 0, 0, m, 0))
    return cfgs


def make_fn(kind, metric, n, p, m, k):
    if kind in ("pairwise", "pairwise_dense"):
        return model.FACTORIES[kind](metric, n, p, m)
    if kind == "gains":
        return model.make_gains(n, m, k)
    if kind == "top2":
        return model.make_top2(n, k)
    if kind == "argmin":
        return model.make_argmin(n, m)
    if kind == "objective":
        return model.make_objective(m)
    raise ValueError(kind)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact directory")
    ap.add_argument("--quick", action="store_true", help="minimal bucket grid")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    cfgs = build_configs(args.quick)
    manifest_lines = []
    for i, (name, kind, metric, n, p, m, k) in enumerate(cfgs):
        fn, specs = make_fn(kind, metric, n, p, m, k)
        text = to_hlo_text(fn, specs)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(args.out, fname), "w") as f:
            f.write(text)
        manifest_lines.append(f"{name} {kind} {metric} {n} {p} {m} {k} {fname}")
        print(f"[{i + 1}/{len(cfgs)}] {name} ({len(text)} chars)", file=sys.stderr)

    with open(os.path.join(args.out, "manifest.txt"), "w") as f:
        f.write("# name kind metric n p m k file\n")
        f.write("\n".join(manifest_lines) + "\n")
    print(f"wrote {len(cfgs)} artifacts + manifest to {args.out}", file=sys.stderr)


if __name__ == "__main__":
    main()
