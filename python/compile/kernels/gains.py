"""L1 Pallas kernel: FasterPAM swap-gain evaluation over the batch.

Computes, for a tile of candidate rows i, the two gain components of the
FasterPAM decomposition (see kernels/ref.py:swap_gains for the math and the
note on the paper's Algorithm-2 line-14 typo):

    shared[i]       = sum_j w_j max(0, dnear_j - d[i, j])
    permedoid[i, l] = sum_j corr[i, j] * onehot[j, l]

TPU mapping: the per-medoid scatter ``G^i_{near(j)}`` is branch-heavy on
CPU; here it is a dense (bn, m) @ (m, k) matmul against the one-hot matrix
of nearest-medoid assignments — MXU work instead of a gather/scatter.  The
grid tiles candidates only; dnear/dsec/onehot/w (O(m k)) stay VMEM-resident.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import pairwise as _pw


def _gains_kernel(d_ref, dnear_ref, dsec_ref, onehot_ref, w_ref, sh_ref, pm_ref):
    d = d_ref[...]          # (bn, m)
    dn = dnear_ref[...]     # (m,)
    ds = dsec_ref[...]      # (m,)
    w = w_ref[...]          # (m,)
    sh_ref[...] = (w[None, :] * jnp.maximum(dn[None, :] - d, 0.0)).sum(axis=1)
    corr = w[None, :] * jnp.where(
        d < dn[None, :],
        (ds - dn)[None, :] * jnp.ones_like(d),
        jnp.where(d < ds[None, :], ds[None, :] - d, 0.0),
    )
    pm_ref[...] = jax.lax.dot_general(
        corr, onehot_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


@functools.partial(jax.jit, static_argnames=("bn",))
def swap_gains(d, dnear, dsec, onehot, w, *, bn: int = 256):
    """Swap-gain components for all n candidates.

    Args:
      d:      (n, m) candidate-to-batch distances.
      dnear:  (m,) nearest-medoid distance per batch point.
      dsec:   (m,) second-nearest-medoid distance per batch point.
      onehot: (m, k) one-hot nearest-medoid assignment.
      w:      (m,) batch weights.
    Returns:
      (shared (n,), permedoid (n, k)) float32.
    """
    n, m = d.shape
    k = onehot.shape[1]
    bn = _pw.largest_divisor_at_most(n, bn)
    grid = (n // bn,)
    return pl.pallas_call(
        _gains_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, m), lambda i: (i, 0)),
            pl.BlockSpec((m,), lambda i: (0,)),
            pl.BlockSpec((m,), lambda i: (0,)),
            pl.BlockSpec((m, k), lambda i: (0, 0)),
            pl.BlockSpec((m,), lambda i: (0,)),
        ],
        out_specs=(
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((bn, k), lambda i: (i, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((n, k), jnp.float32),
        ),
        interpret=True,
    )(
        d.astype(jnp.float32),
        dnear.astype(jnp.float32),
        dsec.astype(jnp.float32),
        onehot.astype(jnp.float32),
        w.astype(jnp.float32),
    )
