"""L1 Pallas kernel: tiled pairwise dissimilarity matrix.

This is the paper's one-time ``O(n m p)`` hot spot: the distance matrix
between the full dataset (tiled to ``n`` rows at AOT time) and the single
batch of ``m`` points.

TPU mapping (see DESIGN.md §Hardware-Adaptation):
  * grid = (n/bn, m/bm, p/bp); the (bn, bp) data tile and (bm, bp) batch
    tile stream HBM -> VMEM via BlockSpec, the (bn, bm) output tile stays
    VMEM-resident across the p-axis of the grid (accumulator pattern).
  * L1 has no matmul form, so it runs on the VPU (broadcast |x - b| then
    reduce over the feature chunk).
  * squared-L2 uses the MXU form ``|x|^2 + |b|^2 - 2 x.b^T`` per chunk.

Kernels are lowered with ``interpret=True``: the CPU PJRT client cannot run
Mosaic custom-calls, and interpret-mode lowers to plain HLO the Rust runtime
executes (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

#: Supported metrics (shared with the Rust side's artifact manifest).
METRICS = ("l1", "sqeuclidean")


def _l1_kernel(x_ref, b_ref, o_ref):
    """One (bn, bm) output tile, accumulating over the p-chunk grid axis."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]  # (bn, bp)
    b = b_ref[...]  # (bm, bp)
    o_ref[...] += jnp.abs(x[:, None, :] - b[None, :, :]).sum(axis=-1)


def _sqeuclidean_kernel(x_ref, b_ref, o_ref):
    """MXU-friendly chunk: |x|^2 + |b|^2 - 2 x.b^T, accumulated over p."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]
    b = b_ref[...]
    xx = (x * x).sum(axis=-1)[:, None]
    bb = (b * b).sum(axis=-1)[None, :]
    xb = jax.lax.dot_general(
        x, b, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    o_ref[...] += xx + bb - 2.0 * xb


_KERNELS = {"l1": _l1_kernel, "sqeuclidean": _sqeuclidean_kernel}


def largest_divisor_at_most(dim: int, target: int) -> int:
    """Largest divisor of ``dim`` that is <= ``target`` (block-size picker)."""
    t = min(dim, max(1, target))
    while dim % t != 0:
        t -= 1
    return t


@functools.partial(jax.jit, static_argnames=("metric", "bn", "bm", "bp"))
def pairwise(x, b, *, metric: str = "l1", bn: int = 128, bm: int = 128, bp: int = 128):
    """Tiled pairwise distance matrix via a Pallas kernel.

    Args:
      x: (n, p) data tile.  n must be divisible by the row block.
      b: (m, p) batch.      m must be divisible by the column block.
      metric: "l1" or "sqeuclidean".
      bn, bm, bp: target block sizes (clamped to divisors of n, m, p).
    Returns:
      (n, m) float32 distance matrix.
    """
    n, p = x.shape
    m, pb = b.shape
    assert p == pb, f"feature dims differ: {p} vs {pb}"
    bn = largest_divisor_at_most(n, bn)
    bm = largest_divisor_at_most(m, bm)
    bp = largest_divisor_at_most(p, bp)
    grid = (n // bn, m // bm, p // bp)
    return pl.pallas_call(
        _KERNELS[metric],
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, bp), lambda i, j, q: (i, q)),
            pl.BlockSpec((bm, bp), lambda i, j, q: (j, q)),
        ],
        out_specs=pl.BlockSpec((bn, bm), lambda i, j, q: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, m), jnp.float32),
        interpret=True,
    )(x.astype(jnp.float32), b.astype(jnp.float32))
