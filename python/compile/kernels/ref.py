"""Pure-jnp reference oracles for the Pallas kernels.

These are the single source of truth for kernel correctness: every Pallas
kernel in this package is checked against the function of the same name here
(pytest + hypothesis, see python/tests/).

Conventions shared with the Rust side (rust/src/backend/):
  * ``BIG = 1e30`` is the finite "infinity" sentinel used for the debias
    variant (d[sigma(j), j] = BIG) and for k-padding.  Finite so that
    differences like ``dsec - dnear`` stay 0.0 instead of NaN when both are
    sentinel.
  * batch-column weights ``w`` implement both NNIW importance weighting and
    column padding (w = 0 for padded columns).
  * ties in top2/argmin break toward the LOWER index (stable argmin).
"""

from __future__ import annotations

import jax.numpy as jnp

#: Finite infinity sentinel (see module docstring).
BIG = 1e30


def pairwise_l1(x, b):
    """L1 (Manhattan) distance matrix.

    Args:
      x: (n, p) data tile.
      b: (m, p) batch tile.
    Returns:
      (n, m) matrix with D[i, j] = sum_d |x[i, d] - b[j, d]|.
    """
    return jnp.abs(x[:, None, :] - b[None, :, :]).sum(axis=-1)


def pairwise_sqeuclidean(x, b):
    """Squared Euclidean distance matrix, (n, p) x (m, p) -> (n, m)."""
    return ((x[:, None, :] - b[None, :, :]) ** 2).sum(axis=-1)


def top2(d):
    """Row-wise smallest and second-smallest entries of ``d`` (n, k).

    Returns (near_idx, near_val, sec_idx, sec_val), each of shape (n,).
    Ties break toward the lower index; requires k >= 2.
    """
    ni = jnp.argmin(d, axis=1)
    nd = jnp.take_along_axis(d, ni[:, None], axis=1)[:, 0]
    cols = jnp.arange(d.shape[1])[None, :]
    masked = jnp.where(cols == ni[:, None], BIG * 10.0, d)
    si = jnp.argmin(masked, axis=1)
    sd = jnp.take_along_axis(masked, si[:, None], axis=1)[:, 0]
    return ni.astype(jnp.int32), nd, si.astype(jnp.int32), sd


def argmin_rows(d):
    """Row-wise argmin and min of ``d`` (n, m) -> ((n,) int32, (n,))."""
    idx = jnp.argmin(d, axis=1).astype(jnp.int32)
    val = jnp.min(d, axis=1)
    return idx, val


def swap_gains(d, dnear, dsec, onehot, w):
    """FasterPAM swap-gain decomposition over a batch of m columns.

    For every candidate row i (a prospective new medoid) and every current
    medoid l, the gain of the swap (remove l, add x_i) over the batch is

        gain(i, l) = shared[i] + permedoid[i, l] + removal_loss[l]

    where ``removal_loss[l] = sum_j w_j (dnear_j - dsec_j) onehot[j, l]`` is
    candidate-independent (computed by the caller), and this function
    returns:

      shared[i]       = sum_j w_j * max(0, dnear_j - d[i, j])
      permedoid[i, l] = sum_j corr[i, j] * onehot[j, l]
      corr[i, j]      = w_j * ( (dsec_j - dnear_j)  if d[i,j] <  dnear_j
                                (dsec_j - d[i, j])  elif d[i,j] < dsec_j
                                0                   otherwise )

    Note: the paper's Algorithm 2 line 14 prints ``dsec - dnear`` in the
    second branch; the correct FasterPAM decomposition (and what makes
    predicted gain equal the exact objective delta) is ``dsec - d_ij``.

    Args:
      d:      (n, m) candidate-to-batch distances.
      dnear:  (m,) distance from batch point j to its nearest medoid.
      dsec:   (m,) distance to its second nearest medoid.
      onehot: (m, k) one-hot of the nearest-medoid index per batch point.
      w:      (m,) batch-column weights (NNIW and/or padding).
    Returns:
      (shared (n,), permedoid (n, k)).
    """
    shared = (w[None, :] * jnp.maximum(dnear[None, :] - d, 0.0)).sum(axis=1)
    corr = w[None, :] * jnp.where(
        d < dnear[None, :],
        (dsec - dnear)[None, :] * jnp.ones_like(d),
        jnp.where(d < dsec[None, :], dsec[None, :] - d, 0.0),
    )
    permedoid = corr @ onehot
    return shared, permedoid


def removal_loss(dnear, dsec, onehot, w):
    """Candidate-independent removal term: (k,) = onehot^T @ (w*(dnear-dsec))."""
    return ((w * (dnear - dsec))[:, None] * onehot).sum(axis=0)


def objective(dnear, w):
    """Weighted batch objective estimate: sum_j w_j * dnear_j / sum_j w_j."""
    return (w * dnear).sum() / w.sum()


def nniw_weights(d):
    """Nearest-neighbour importance weights (Loog 2012).

    w_j is proportional to the number of rows i whose nearest batch column
    is j.  Returned unnormalized (counts, float32): the objective estimate
    normalizes by sum(w).

    Args:
      d: (n, m) full-data-to-batch distances.
    Returns:
      (m,) float32 counts.
    """
    idx, _ = argmin_rows(d)
    return jnp.zeros(d.shape[1], jnp.float32).at[idx].add(1.0)
