"""L1 Pallas kernels: row-wise top-2-min (medoid cache) and row argmin (NNIW).

``top2`` maintains the (near, dnear, sec, dsec) cache FasterPAM keeps per
batch point; ``argmin_rows`` backs the nearest-neighbour importance weights.
Both tile rows only — k (resp. m) fits a VMEM line.  Ties break toward the
lower index, matching ref.py and the Rust native backend exactly.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import pairwise as _pw
from .ref import BIG


def _top2_kernel(d_ref, ni_ref, nd_ref, si_ref, sd_ref):
    d = d_ref[...]  # (bn, k)
    k = d.shape[1]
    cols = jax.lax.broadcasted_iota(jnp.int32, d.shape, 1)
    ni = jnp.argmin(d, axis=1).astype(jnp.int32)
    nd = jnp.min(d, axis=1)
    masked = jnp.where(cols == ni[:, None], BIG * 10.0, d)
    si = jnp.argmin(masked, axis=1).astype(jnp.int32)
    sd = jnp.min(masked, axis=1)
    ni_ref[...] = ni
    nd_ref[...] = nd
    si_ref[...] = si
    sd_ref[...] = sd


@functools.partial(jax.jit, static_argnames=("bn",))
def top2(d, *, bn: int = 512):
    """Row-wise two smallest of (n, k): (near, dnear, sec, dsec)."""
    n, k = d.shape
    bn = _pw.largest_divisor_at_most(n, bn)
    vec = lambda i: (i,)
    return pl.pallas_call(
        _top2_kernel,
        grid=(n // bn,),
        in_specs=[pl.BlockSpec((bn, k), lambda i: (i, 0))],
        out_specs=(
            pl.BlockSpec((bn,), vec),
            pl.BlockSpec((bn,), vec),
            pl.BlockSpec((bn,), vec),
            pl.BlockSpec((bn,), vec),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((n,), jnp.int32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.int32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
        ),
        interpret=True,
    )(d.astype(jnp.float32))


def _argmin_kernel(d_ref, idx_ref, val_ref):
    d = d_ref[...]
    idx_ref[...] = jnp.argmin(d, axis=1).astype(jnp.int32)
    val_ref[...] = jnp.min(d, axis=1)


@functools.partial(jax.jit, static_argnames=("bn",))
def argmin_rows(d, *, bn: int = 512):
    """Row-wise (argmin, min) of an (n, m) matrix."""
    n, m = d.shape
    bn = _pw.largest_divisor_at_most(n, bn)
    vec = lambda i: (i,)
    return pl.pallas_call(
        _argmin_kernel,
        grid=(n // bn,),
        in_specs=[pl.BlockSpec((bn, m), lambda i: (i, 0))],
        out_specs=(pl.BlockSpec((bn,), vec), pl.BlockSpec((bn,), vec)),
        out_shape=(
            jax.ShapeDtypeStruct((n,), jnp.int32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
        ),
        interpret=True,
    )(d.astype(jnp.float32))
