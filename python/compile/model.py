"""L2 — the OneBatchPAM compute graph, composed from the Pallas kernels.

Each public ``make_*`` factory returns a jax function with *static* shapes
(XLA requirement) that ``aot.py`` lowers once to HLO text for the Rust
runtime.  The functions call the L1 Pallas kernels so both layers lower
into the same HLO module — Python never runs at request time.

Runtime contract (mirrored by rust/src/runtime/):
  * shapes come from the artifact manifest; the Rust side pads inputs up to
    the bucket (rows: zeros; batch columns: weight 0; medoid columns:
    distance BIG) so results are exact despite padding.
  * all floats are f32, all indices i32.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import gains as _gains
from .kernels import pairwise as _pairwise
from .kernels import top2 as _top2
from .kernels.ref import BIG


def make_pairwise(metric: str, n: int, p: int, m: int):
    """(n,p) x (m,p) -> (n,m) distance-matrix tile (Pallas)."""

    def fn(x, b):
        return (_pairwise.pairwise(x, b, metric=metric),)

    return fn, (
        jax.ShapeDtypeStruct((n, p), jnp.float32),
        jax.ShapeDtypeStruct((m, p), jnp.float32),
    )


def make_pairwise_dense(metric: str, n: int, p: int, m: int):
    """Plain-XLA (non-Pallas) pairwise variant — perf ablation baseline."""

    def fn(x, b):
        if metric == "l1":
            d = jnp.abs(x[:, None, :] - b[None, :, :]).sum(axis=-1)
        else:
            xx = (x * x).sum(axis=1)[:, None]
            bb = (b * b).sum(axis=1)[None, :]
            d = xx + bb - 2.0 * x @ b.T
        return (d,)

    return fn, (
        jax.ShapeDtypeStruct((n, p), jnp.float32),
        jax.ShapeDtypeStruct((m, p), jnp.float32),
    )


def make_gains(n: int, m: int, k: int):
    """Swap-gain tile: (d, dnear, dsec, onehot, w) -> (shared, permedoid)."""

    def fn(d, dnear, dsec, onehot, w):
        return _gains.swap_gains(d, dnear, dsec, onehot, w)

    return fn, (
        jax.ShapeDtypeStruct((n, m), jnp.float32),
        jax.ShapeDtypeStruct((m,), jnp.float32),
        jax.ShapeDtypeStruct((m,), jnp.float32),
        jax.ShapeDtypeStruct((m, k), jnp.float32),
        jax.ShapeDtypeStruct((m,), jnp.float32),
    )


def make_top2(n: int, k: int):
    """(n,k) medoid distances -> (near, dnear, sec, dsec)."""

    def fn(d):
        return _top2.top2(d)

    return fn, (jax.ShapeDtypeStruct((n, k), jnp.float32),)


def make_argmin(n: int, m: int):
    """(n,m) -> (argmin idx, min val) per row (NNIW weight counting)."""

    def fn(d):
        return _top2.argmin_rows(d)

    return fn, (jax.ShapeDtypeStruct((n, m), jnp.float32),)


def make_objective(m: int):
    """Weighted batch objective: (dnear, w) -> scalar."""

    def fn(dnear, w):
        return ((w * dnear).sum() / w.sum(),)

    return fn, (
        jax.ShapeDtypeStruct((m,), jnp.float32),
        jax.ShapeDtypeStruct((m,), jnp.float32),
    )


#: kind-name -> factory; the manifest's first column uses these names.
FACTORIES = {
    "pairwise": make_pairwise,
    "pairwise_dense": make_pairwise_dense,
    "gains": make_gains,
    "top2": make_top2,
    "argmin": make_argmin,
    "objective": make_objective,
}

__all__ = ["FACTORIES", "BIG"] + [f"make_{k}" for k in FACTORIES]
