"""Pallas kernels vs pure-jnp oracles — the core L1 correctness signal.

Hypothesis sweeps shapes, block sizes and adversarial value patterns
(ties, sentinel BIG columns, zero weights) and asserts allclose against
kernels/ref.py.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import gains, pairwise, ref, top2

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


def _rng(seed):
    return np.random.default_rng(seed)


# ---------------------------------------------------------------------------
# pairwise
# ---------------------------------------------------------------------------

@given(
    n=st.integers(1, 48),
    m=st.integers(1, 24),
    p=st.integers(1, 40),
    metric=st.sampled_from(pairwise.METRICS),
    bn=st.sampled_from([1, 4, 16, 128]),
    bp=st.sampled_from([1, 8, 128]),
    seed=st.integers(0, 2**31 - 1),
)
def test_pairwise_matches_ref(n, m, p, metric, bn, bp, seed):
    r = _rng(seed)
    x = r.normal(scale=3.0, size=(n, p)).astype(np.float32)
    b = r.normal(scale=3.0, size=(m, p)).astype(np.float32)
    got = pairwise.pairwise(jnp.array(x), jnp.array(b), metric=metric, bn=bn, bp=bp)
    want = getattr(ref, f"pairwise_{metric}")(jnp.array(x), jnp.array(b))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_pairwise_identity_rows_are_zero():
    x = _rng(0).normal(size=(12, 7)).astype(np.float32)
    d = pairwise.pairwise(jnp.array(x), jnp.array(x), metric="l1")
    np.testing.assert_allclose(np.diag(np.asarray(d)), 0.0, atol=1e-5)


def test_pairwise_l1_known_values():
    x = jnp.array([[0.0, 0.0], [1.0, 2.0]])
    b = jnp.array([[1.0, 1.0]])
    d = pairwise.pairwise(x, b, metric="l1")
    np.testing.assert_allclose(d, [[2.0], [1.0]])


def test_pairwise_sqeuclidean_known_values():
    x = jnp.array([[0.0, 0.0], [3.0, 4.0]])
    b = jnp.array([[0.0, 0.0], [3.0, 0.0]])
    d = pairwise.pairwise(x, b, metric="sqeuclidean")
    np.testing.assert_allclose(d, [[0.0, 9.0], [25.0, 16.0]], atol=1e-4)


def test_pairwise_p_padding_with_zeros_is_noop():
    """Zero-padded feature columns must not change distances (runtime relies on it)."""
    r = _rng(3)
    x = r.normal(size=(8, 5)).astype(np.float32)
    b = r.normal(size=(4, 5)).astype(np.float32)
    xp = np.concatenate([x, np.zeros((8, 3), np.float32)], axis=1)
    bp = np.concatenate([b, np.zeros((4, 3), np.float32)], axis=1)
    for metric in pairwise.METRICS:
        d0 = pairwise.pairwise(jnp.array(x), jnp.array(b), metric=metric)
        d1 = pairwise.pairwise(jnp.array(xp), jnp.array(bp), metric=metric)
        np.testing.assert_allclose(d0, d1, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# top2 / argmin
# ---------------------------------------------------------------------------

@given(
    n=st.integers(1, 64),
    k=st.integers(2, 16),
    ties=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_top2_matches_ref(n, k, ties, seed):
    r = _rng(seed)
    if ties:
        d = r.integers(0, 3, size=(n, k)).astype(np.float32)  # many ties
    else:
        d = r.uniform(size=(n, k)).astype(np.float32)
    got = top2.top2(jnp.array(d))
    want = ref.top2(jnp.array(d))
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


def test_top2_invariants():
    r = _rng(7)
    d = r.uniform(size=(40, 6)).astype(np.float32)
    ni, nd, si, sd = (np.asarray(a) for a in top2.top2(jnp.array(d)))
    assert (nd <= sd).all()
    assert (ni != si).all()
    np.testing.assert_allclose(nd, d.min(axis=1))


def test_top2_padded_k_columns_never_win():
    """BIG-padded medoid columns must never appear in (near, sec)."""
    r = _rng(11)
    d = r.uniform(size=(16, 4)).astype(np.float32)
    dp = np.concatenate([d, np.full((16, 3), ref.BIG, np.float32)], axis=1)
    ni, nd, si, sd = (np.asarray(a) for a in top2.top2(jnp.array(dp)))
    assert (ni < 4).all() and (si < 4).all()


@given(n=st.integers(1, 64), m=st.integers(1, 32), seed=st.integers(0, 2**31 - 1))
def test_argmin_matches_ref(n, m, seed):
    d = _rng(seed).uniform(size=(n, m)).astype(np.float32)
    got = top2.argmin_rows(jnp.array(d))
    want = ref.argmin_rows(jnp.array(d))
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


# ---------------------------------------------------------------------------
# swap gains
# ---------------------------------------------------------------------------

def _gain_case(seed, n, m, k, zero_w=False, sentinel=False):
    r = _rng(seed)
    d = r.uniform(size=(n, m)).astype(np.float32)
    dn = r.uniform(size=m).astype(np.float32)
    ds = dn + r.uniform(size=m).astype(np.float32)
    near = r.integers(0, k, size=m)
    oh = np.eye(k, dtype=np.float32)[near]
    w = r.uniform(0.5, 2.0, size=m).astype(np.float32)
    if zero_w:
        w[:: max(1, m // 3)] = 0.0
    if sentinel:
        j = m // 2
        d[:, j] = ref.BIG
        dn[j] = ref.BIG
        ds[j] = ref.BIG
    return d, dn, ds, oh, w


@given(
    n=st.integers(1, 48),
    m=st.integers(1, 24),
    k=st.integers(1, 8),
    bn=st.sampled_from([1, 8, 256]),
    zero_w=st.booleans(),
    sentinel=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_gains_match_ref(n, m, k, bn, zero_w, sentinel, seed):
    d, dn, ds, oh, w = _gain_case(seed, n, m, k, zero_w, sentinel)
    got_s, got_p = gains.swap_gains(
        jnp.array(d), jnp.array(dn), jnp.array(ds), jnp.array(oh), jnp.array(w), bn=bn
    )
    want_s, want_p = ref.swap_gains(
        jnp.array(d), jnp.array(dn), jnp.array(ds), jnp.array(oh), jnp.array(w)
    )
    np.testing.assert_allclose(got_s, want_s, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(got_p, want_p, rtol=1e-4, atol=1e-4)


def test_gain_equals_true_objective_delta():
    """shared + permedoid + removal_loss == exact recomputed objective delta.

    This is the invariant that pins down the paper's Algorithm-2 line-14
    typo: with the printed ``dsec - dnear`` branch the identity fails.
    """
    r = _rng(42)
    n, m, k, p = 30, 12, 4, 5
    X = r.normal(size=(n, p)).astype(np.float32)
    batch_idx = r.choice(n, size=m, replace=False)
    med = list(r.choice(n, size=k, replace=False))
    D = np.asarray(ref.pairwise_l1(jnp.array(X), jnp.array(X[batch_idx])))
    w = np.ones(m, np.float32)

    def batch_obj(meds):
        return D[meds].min(axis=0).sum()

    dmk = D[med]  # (k, m)
    order = np.argsort(dmk, axis=0, kind="stable")
    ni = order[0]
    nd = dmk[ni, np.arange(m)]
    sd = dmk[order[1], np.arange(m)]
    oh = np.eye(k, dtype=np.float32)[ni]
    sh, pm = (
        np.asarray(a)
        for a in ref.swap_gains(
            jnp.array(D), jnp.array(nd), jnp.array(sd), jnp.array(oh), jnp.array(w)
        )
    )
    rl = np.asarray(ref.removal_loss(jnp.array(nd), jnp.array(sd), jnp.array(oh), jnp.array(w)))
    base = batch_obj(med)
    for i in range(n):
        if i in med:
            continue
        for l in range(k):
            swapped = med.copy()
            swapped[l] = i
            true_gain = base - batch_obj(swapped)
            pred = sh[i] + pm[i, l] + rl[l]
            np.testing.assert_allclose(pred, true_gain, rtol=1e-4, atol=1e-4)


def test_removal_loss_matches_manual():
    _, dn, ds, oh, w = _gain_case(5, 4, 10, 3)
    rl = np.asarray(ref.removal_loss(jnp.array(dn), jnp.array(ds), jnp.array(oh), jnp.array(w)))
    near = oh.argmax(axis=1)
    for l in range(3):
        sel = near == l
        np.testing.assert_allclose(rl[l], (w[sel] * (dn[sel] - ds[sel])).sum(), rtol=1e-5)


def test_nniw_weights_count_to_n():
    d = _rng(9).uniform(size=(50, 8)).astype(np.float32)
    w = np.asarray(ref.nniw_weights(jnp.array(d)))
    assert w.sum() == 50
    assert (w >= 0).all()
