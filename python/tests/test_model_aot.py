"""L2 model + AOT pipeline tests: shapes, factories, HLO-text emission,
manifest round-trip, and numerical execution of a lowered module through
jax itself (the Rust runtime executes the same text through PJRT)."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model
from compile.kernels import ref


def test_factories_cover_all_kinds():
    assert set(model.FACTORIES) == {
        "pairwise", "pairwise_dense", "gains", "top2", "argmin", "objective",
    }


@pytest.mark.parametrize("kind", ["pairwise", "pairwise_dense"])
@pytest.mark.parametrize("metric", ["l1", "sqeuclidean"])
def test_pairwise_factory_shapes_and_values(kind, metric):
    n, p, m = 16, 8, 4
    fn, specs = model.FACTORIES[kind](metric, n, p, m)
    assert [s.shape for s in specs] == [(n, p), (m, p)]
    r = np.random.default_rng(0)
    x, b = r.normal(size=(n, p)).astype(np.float32), r.normal(size=(m, p)).astype(np.float32)
    (d,) = fn(jnp.array(x), jnp.array(b))
    assert d.shape == (n, m)
    want = getattr(ref, f"pairwise_{metric}")(jnp.array(x), jnp.array(b))
    np.testing.assert_allclose(d, want, rtol=1e-4, atol=1e-4)


def test_gains_factory_shapes():
    n, m, k = 32, 8, 5
    fn, specs = model.make_gains(n, m, k)
    args = [jnp.zeros(s.shape, s.dtype) for s in specs]
    sh, pm = fn(*args)
    assert sh.shape == (n,) and pm.shape == (n, k)


def test_objective_factory():
    fn, _ = model.make_objective(4)
    (o,) = fn(jnp.array([1.0, 2.0, 3.0, 4.0]), jnp.array([1.0, 1.0, 1.0, 1.0]))
    np.testing.assert_allclose(o, 2.5)
    # padded columns (w=0) are ignored
    (o,) = fn(jnp.array([1.0, 2.0, 100.0, 100.0]), jnp.array([1.0, 1.0, 0.0, 0.0]))
    np.testing.assert_allclose(o, 1.5)


def test_hlo_text_emission_and_entry_signature():
    fn, specs = model.make_objective(8)
    text = aot.to_hlo_text(fn, specs)
    assert "HloModule" in text and "ENTRY" in text
    assert "f32[8]" in text  # parameter shape is baked in


def test_quick_config_grid_is_consistent():
    cfgs = aot.build_configs(quick=True)
    names = [c[0] for c in cfgs]
    assert len(names) == len(set(names))
    kinds = {c[1] for c in cfgs}
    assert kinds == {"pairwise", "pairwise_dense", "gains", "top2", "argmin", "objective"}
    for name, kind, metric, n, p, m, k in cfgs:
        fn, specs = aot.make_fn(kind, metric, n, p, m, k)
        assert callable(fn) and len(specs) >= 1


def test_full_grid_covers_paper_settings():
    """Buckets must cover the paper's k grid and every dataset's p."""
    cfgs = aot.build_configs(quick=False)
    gains_ks = {c[6] for c in cfgs if c[1] == "gains"}
    assert {10, 50, 100} <= gains_ks
    paper_ps = [8, 96, 28, 16, 16, 3072, 784, 117, 9, 55]
    pw_ps = sorted({c[4] for c in cfgs if c[1] == "pairwise"})
    assert all(any(b >= p for b in pw_ps) for p in paper_ps)


def test_manifest_written(tmp_path):
    """End-to-end --quick run writes parseable manifest + artifacts.

    Uses a single tiny config to keep runtime small.
    """
    out = tmp_path / "artifacts"
    out.mkdir()
    fn, specs = model.make_objective(16)
    text = aot.to_hlo_text(fn, specs)
    (out / "objective_m16.hlo.txt").write_text(text)
    (out / "manifest.txt").write_text(
        "# name kind metric n p m k file\n"
        "objective_m16 objective - 0 0 16 0 objective_m16.hlo.txt\n"
    )
    lines = [
        l for l in (out / "manifest.txt").read_text().splitlines()
        if l and not l.startswith("#")
    ]
    assert len(lines) == 1
    parts = lines[0].split()
    assert len(parts) == 8
    assert os.path.exists(out / parts[7])
