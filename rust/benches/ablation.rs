//! Design-choice ablations (DESIGN.md §4, beyond the paper's tables):
//!
//! 1. batch-size sweep: m = c * 100 log(k n) for c in {0.25, 0.5, 1, 2};
//! 2. sampler variants at each batch size (unif/debias/nniw/lwcs);
//! 3. swap strategy: eager (Algorithm 2) vs steepest (Eq. 3);
//! 4. backend: native vs xla (Pallas) vs xla-dense, when artifacts exist.

use obpam::backend::{ComputeBackend, NativeBackend};
#[cfg(feature = "xla")]
use obpam::backend::XlaBackend;
use obpam::coordinator::{one_batch_pam, onebatch::SwapStrategy, OneBatchConfig, SamplerKind};
use obpam::data::synth;
use obpam::dissim::{DissimCounter, Metric};
use obpam::eval;
use obpam::harness::{bench_util, emit};
#[cfg(feature = "xla")]
use obpam::runtime::Runtime;
use std::path::Path;
#[cfg(feature = "xla")]
use std::rc::Rc;

fn main() {
    let scale = bench_util::env_scale(0.05);
    let data = synth::generate("drybean", scale, 0xAB1);
    let x = &data.x;
    let k = 10;
    let n = x.rows;
    let base_m = (100.0 * ((k * n) as f64).ln()).ceil() as usize;
    println!("ablations on drybean-like data: n={n} p={} k={k} base m={base_m}\n", x.cols);

    // --- 1+2: batch size x sampler --------------------------------------
    let mut rows = Vec::new();
    let mut csv = Vec::new();
    for sampler in SamplerKind::all() {
        let mut cells = Vec::new();
        for c in [0.25f64, 0.5, 1.0, 2.0] {
            let m = ((base_m as f64 * c) as usize).clamp(k + 1, n);
            let backend = NativeBackend::new(Metric::L1);
            let cfg = OneBatchConfig { k, sampler, m: Some(m), seed: 1, ..Default::default() };
            let r = one_batch_pam(x, &cfg, &backend).expect("run");
            let obj = eval::objective(x, &r.medoids, &DissimCounter::new(Metric::L1));
            cells.push(format!("{obj:.4}/{:.2}s", r.stats.seconds));
            csv.push(vec![sampler.name().into(), format!("{c}"), format!("{obj:.6}"), format!("{:.4}", r.stats.seconds)]);
        }
        rows.push((format!("OneBatch-{}", sampler.name()), cells));
    }
    println!(
        "{}",
        emit::render_table(
            "ablation: objective/time vs batch-size multiplier",
            &["c=0.25", "c=0.5", "c=1", "c=2"],
            &rows
        )
    );
    emit::write_csv(Path::new("bench_out/ablation_batch.csv"), "sampler,mult,objective,seconds", &csv).unwrap();

    // --- 3: eager vs steepest -------------------------------------------
    let mut rows = Vec::new();
    for strategy in [SwapStrategy::Eager, SwapStrategy::Steepest] {
        let backend = NativeBackend::new(Metric::L1);
        let cfg = OneBatchConfig {
            k,
            sampler: SamplerKind::Nniw,
            strategy,
            seed: 2,
            ..Default::default()
        };
        let r = one_batch_pam(x, &cfg, &backend).expect("run");
        let obj = eval::objective(x, &r.medoids, &DissimCounter::new(Metric::L1));
        rows.push((
            format!("{strategy:?}"),
            vec![format!("{obj:.4}"), format!("{:.3}s", r.stats.seconds), r.stats.swap_count.to_string()],
        ));
    }
    println!(
        "{}",
        emit::render_table("ablation: swap strategy", &["objective", "time", "swaps"], &rows)
    );

    // --- 4: backends ------------------------------------------------------
    let mut rows = Vec::new();
    {
        let backend = NativeBackend::new(Metric::L1);
        rows.push(backend_row("native", &backend, x, k));
    }
    {
        use obpam::runtime::Pool;
        let backend = NativeBackend::with_pool(Metric::L1, Pool::auto());
        rows.push(backend_row(
            &format!("native t={}", backend.pool().threads()),
            &backend,
            x,
            k,
        ));
    }
    #[cfg(feature = "xla")]
    match Runtime::load_default() {
        Ok(rt) => {
            let rt = Rc::new(rt);
            let pallas = XlaBackend::new(rt.clone(), Metric::L1, false);
            rows.push(backend_row("xla (pallas)", &pallas, x, k));
            let dense = XlaBackend::new(rt, Metric::L1, true);
            rows.push(backend_row("xla-dense", &dense, x, k));
        }
        Err(e) => eprintln!("skipping XLA backends ({e}); run `make artifacts`"),
    }
    #[cfg(not(feature = "xla"))]
    eprintln!("skipping XLA backends (built without the `xla` feature)");
    println!(
        "{}",
        emit::render_table("ablation: compute backend", &["objective", "time"], &rows)
    );
}

fn backend_row(
    name: &str,
    backend: &dyn ComputeBackend,
    x: &obpam::linalg::Matrix,
    k: usize,
) -> (String, Vec<String>) {
    let cfg = OneBatchConfig { k, sampler: SamplerKind::Nniw, seed: 3, ..Default::default() };
    let r = one_batch_pam(x, &cfg, backend).expect("run");
    let obj = eval::objective(x, &r.medoids, &DissimCounter::new(Metric::L1));
    (name.into(), vec![format!("{obj:.4}"), format!("{:.3}s", r.stats.seconds)])
}
