//! Empirical check of paper **Table 1** (theoretical complexity summary):
//! measure dissimilarity-computation counts as n grows and fit the
//! power-law exponent.
//!
//! Expected exponents (in n): FasterPAM ~2, OneBatchPAM ~1 (n log n),
//! BanditPAM++ ~1 (n log n), k-means++ ~1, kmc2 ~0, FasterCLARA ~1
//! (dominated by the n*k evaluation pass).

use obpam::dissim::Metric;
use obpam::harness::{bench_util, emit, methods::MethodSpec, runner};
use obpam::data::synth;
use std::path::Path;

fn main() {
    let ns = bench_util::env_list("OBPAM_COMPLEXITY_NS", &[500, 1_000, 2_000, 4_000]);
    let k = 10;
    let methods = vec![
        MethodSpec::FasterPam,
        MethodSpec::OneBatch {
            sampler: obpam::coordinator::SamplerKind::Unif,
            strategy: obpam::coordinator::onebatch::SwapStrategy::Eager,
        },
        MethodSpec::BanditPam { swaps: 2 },
        MethodSpec::KMeansPp,
        MethodSpec::Kmc2 { chain: 20 },
        MethodSpec::FasterClara { reps: 5 },
    ];

    let mut csv_rows = Vec::new();
    let mut rows = Vec::new();
    for m in &methods {
        let mut points = Vec::new();
        let mut cells = Vec::new();
        for &n in &ns {
            let x = synth::generate(&format!("blobs_{n}_8_5"), 1.0, 0xC0).x;
            let rec = runner::run_method(
                m,
                &x,
                "blobs",
                k,
                0,
                Metric::L1,
                0xC1,
                bench_util::env_threads(1),
            )
            .expect("run");
            points.push((n as f64, rec.dissim as f64));
            cells.push(format!("{}", rec.dissim));
            csv_rows.push(vec![m.label(), n.to_string(), rec.dissim.to_string()]);
        }
        let expo = bench_util::fit_power_law(&points);
        cells.push(format!("{expo:.2}"));
        rows.push((m.label(), cells));
        eprintln!("  {:<16} exponent {expo:.2}", m.label());
    }
    let mut headers: Vec<String> = ns.iter().map(|n| format!("n={n}")).collect();
    headers.push("exponent".into());
    let headers_ref: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    println!(
        "{}",
        emit::render_table("Table 1 check: dissim computations vs n (k=10)", &headers_ref, &rows)
    );
    emit::write_csv(Path::new("bench_out/complexity.csv"), "method,n,dissim", &csv_rows).unwrap();
    println!(
        "paper reference (Table 1): FasterPAM O(n^2) -> exponent ~2; OneBatchPAM\n\
         O(n log n) -> ~1.0-1.2; kmc2 O(L k^2) -> ~0; k-means++ O(k n) -> ~1."
    );
}
