//! Regenerates paper **Figure 1**: running time and objective on the
//! MNIST(-like) dataset, (left) as a function of n at k=10 and (right)
//! as a function of k at fixed n, for KM / FP / FC / BP / OBP.
//!
//! Knobs: OBPAM_FIG1_NS (default "500,1000,2000"), OBPAM_FIG1_KS
//! (default "5,10,20"), OBPAM_FIG1_FIXED_N (default 1000).

use obpam::data::synth;
use obpam::dissim::Metric;
use obpam::harness::{bench_util, emit, methods::MethodSpec, runner};
use std::path::Path;

fn mnist_subset(n: usize, seed: u64) -> obpam::linalg::Matrix {
    // generate an mnist-like dataset with exactly n rows (p = 784)
    synth::generate("mnist", n as f64 / 60_000.0, seed).x
}

fn sweep(
    title: &str,
    xs: &[usize],
    make_x: impl Fn(usize) -> (obpam::linalg::Matrix, usize),
    csv_name: &str,
) {
    let methods = MethodSpec::fig1_grid();
    let mut csv_rows: Vec<Vec<String>> = Vec::new();
    let mut time_series: Vec<(String, Vec<f64>)> =
        methods.iter().map(|m| (m.label(), Vec::new())).collect();
    let mut obj_series = time_series.clone();

    for &v in xs {
        let (x, k) = make_x(v);
        for (mi, m) in methods.iter().enumerate() {
            // FasterPAM / BanditPAM get slow fast: skip above the paper's
            // own feasibility pattern (they are the motivation, after all)
            let skip = matches!(m, MethodSpec::FasterPam | MethodSpec::BanditPam { .. })
                && x.rows > 4_000;
            let (secs, obj) = if skip {
                (f64::NAN, f64::NAN)
            } else {
                let rec = runner::run_method(
                    m,
                    &x,
                    "mnist",
                    k,
                    0,
                    Metric::L1,
                    0xF16 + v as u64,
                    bench_util::env_threads(1),
                )
                .expect("run");
                (rec.seconds, rec.objective)
            };
            eprintln!("  {title} x={v} {:<16} {secs:.3}s obj={obj:.5}", m.label());
            time_series[mi].1.push(secs);
            obj_series[mi].1.push(obj);
            csv_rows.push(vec![
                v.to_string(),
                m.label(),
                format!("{secs:.5}"),
                format!("{obj:.6}"),
            ]);
        }
    }
    emit::write_csv(
        Path::new(&format!("bench_out/{csv_name}.csv")),
        "x,method,seconds,objective",
        &csv_rows,
    )
    .unwrap();

    println!("== Figure 1 ({title}) ==");
    println!("{:<18} {}", "method", xs.iter().map(|v| format!("{v:>10}")).collect::<String>());
    for (label, ts) in &time_series {
        let cells: String = ts.iter().map(|t| format!("{t:>9.3}s")).collect();
        println!("{label:<18} {cells}   (time)");
    }
    for (label, os) in &obj_series {
        let cells: String = os.iter().map(|o| format!("{o:>10.4}")).collect();
        println!("{label:<18} {cells}   (objective)");
    }
    println!();
}

fn main() {
    let ns = bench_util::env_list("OBPAM_FIG1_NS", &[500, 1_000, 2_000]);
    let ks = bench_util::env_list("OBPAM_FIG1_KS", &[5, 10, 20]);
    let fixed_n = bench_util::env_list("OBPAM_FIG1_FIXED_N", &[1_000])[0];

    sweep("time/objective vs n, k=10", &ns, |n| (mnist_subset(n, 0xF1), 10), "fig1_vs_n");
    sweep(
        "time/objective vs k, fixed n",
        &ks,
        |k| (mnist_subset(fixed_n, 0xF2), k),
        "fig1_vs_k",
    );
    println!(
        "paper reference (Fig 1): OBP time curve tracks KM/FC (flat-ish in n),\n\
         FP/BP blow up with n; OBP objective tracks FP closely while KM/FC sit higher."
    );
}
