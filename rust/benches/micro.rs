//! Micro-benchmarks of the hot-path tile ops — the §Perf tool
//! (EXPERIMENTS.md records before/after from this bench).
//!
//! Measures, with warmup + median/MAD:
//!   * native pairwise throughput (Gdissim/s) at 1 thread and at
//!     `available_parallelism` threads (the runtime::pool scaling check);
//!   * fused `pairwise_argmin` vs the unfused pairwise-then-argmin
//!     composition, per metric x compute profile x thread count
//!     (Gpair/s and GB/s swept);
//!   * the Fast (dot-product) vs Exact (diff-accumulate) profile on the
//!     Euclidean metrics;
//!   * the eager candidate scan at 1 thread and at all cores;
//!   * swap-gain evaluation: native inner loop (1 thread vs all cores);
//!   * SwapState::eval_candidate / apply_swap latency;
//!   * end-to-end OneBatchPAM at a fixed workload, serial vs threaded;
//!   * per-region dispatch overhead on a tiny workload: the persistent
//!     pool (wake parked workers) vs the old scoped-spawn-per-region
//!     shape (spawn + join `threads` OS threads every region);
//!   * v6 model-serving `assign` QPS over TCP, one connection and many
//!     concurrent connections (the fitted-model read path);
//!   * v8 evented-core connection scaling: park/resolve rates for
//!     thousands of concurrent idle `wait`ers held at constant server
//!     thread count, and on-loop `assign` QPS with 0 vs N parked
//!     waiters (the `conn` section);
//!   * v9 out-of-core sweep: chunked `StreamSweep::argmin` over a
//!     memory-resident store and over an on-disk `.npy` store vs the
//!     resident fused kernel — the chunking + I/O tax of never
//!     materialising the full matrix (the `stream` section);
//!   * (feature `xla`) XLA pairwise/gains: Pallas kernel vs plain-XLA.
//!
//! Flags (after `--`): `--smoke` shrinks every exercised section to
//! tiny shapes and skips the heavyweight ones (the CI smoke step);
//! `--only <section>` runs just the rows whose `section` field matches
//! (e.g. `--only conn` is the CI connection-scaling smoke step);
//! `--json` additionally writes every reported row to
//! `BENCH_micro.json` (schema documented in README.md).

use obpam::backend::{ComputeBackend, NativeBackend};
use obpam::coordinator::state::SwapState;
use obpam::coordinator::{engine, one_batch_pam, OneBatchConfig, SamplerKind};
use obpam::dissim::{ComputeProfile, Metric};
use obpam::harness::bench_util::time_median;
use obpam::linalg::Matrix;
use obpam::rng::Rng;
use obpam::runtime::Pool;
use obpam::telemetry::Counters;
use std::sync::Mutex;

fn rand_matrix(rng: &mut Rng, r: usize, c: usize) -> Matrix {
    Matrix::from_vec(r, c, (0..r * c).map(|_| rng.f32()).collect())
}

/// One reported row, kept for the optional `--json` dump.
struct Record {
    section: &'static str,
    name: String,
    med_s: f64,
    mad_s: f64,
    rate: Option<(f64, &'static str)>,
}

static RECORDS: Mutex<Vec<Record>> = Mutex::new(Vec::new());

fn report(
    section: &'static str,
    name: &str,
    med: f64,
    mad: f64,
    work: Option<(f64, &'static str)>,
) {
    match work {
        Some((units, unit_name)) => println!(
            "{name:<46} {:>9.3} ms ± {:>6.3}  ({:.2} {unit_name})",
            med * 1e3,
            mad * 1e3,
            units / med.max(1e-12)
        ),
        None => println!("{name:<46} {:>9.3} ms ± {:>6.3}", med * 1e3, mad * 1e3),
    }
    obpam::sync_ext::lock_or_recover(&RECORDS).push(Record {
        section,
        name: name.to_string(),
        med_s: med,
        mad_s: mad,
        rate: work.map(|(units, unit_name)| (units / med.max(1e-12), unit_name)),
    });
}

/// Dump every recorded row as `BENCH_micro.json` (see README.md for the
/// schema).  Names contain no quotes or backslashes, but escape anyway
/// so the writer cannot emit invalid JSON.
fn write_json(path: &str, cores: usize, smoke: bool) {
    fn esc(s: &str) -> String {
        s.replace('\\', "\\\\").replace('"', "\\\"")
    }
    let records = obpam::sync_ext::lock_or_recover(&RECORDS);
    let mut out = String::new();
    out.push_str("{\n");
    out.push_str("  \"schema\": \"obpam-bench-micro/1\",\n");
    out.push_str(&format!("  \"cores\": {cores},\n"));
    out.push_str(&format!("  \"smoke\": {smoke},\n"));
    out.push_str("  \"records\": [\n");
    for (i, r) in records.iter().enumerate() {
        let (rate, unit) = match &r.rate {
            Some((v, u)) => (format!("{v:.3}"), format!("\"{}\"", esc(u))),
            None => ("null".to_string(), "null".to_string()),
        };
        out.push_str(&format!(
            "    {{\"section\": \"{}\", \"name\": \"{}\", \"ms\": {:.6}, \"mad_ms\": {:.6}, \
             \"rate\": {rate}, \"unit\": {unit}}}{}\n",
            esc(r.section),
            esc(&r.name),
            r.med_s * 1e3,
            r.mad_s * 1e3,
            if i + 1 == records.len() { "" } else { "," }
        ));
    }
    out.push_str("  ]\n}\n");
    match std::fs::write(path, out) {
        Ok(()) => println!("\nwrote {} records to {path}", records.len()),
        Err(e) => eprintln!("\nfailed to write {path}: {e}"),
    }
}

/// Live thread count of this process from `/proc/self/status`
/// (`None` off Linux — callers skip the flat-thread-count check).
fn thread_count() -> Option<usize> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    status.lines().find_map(|l| l.strip_prefix("Threads:")).and_then(|v| v.trim().parse().ok())
}

/// Pull a `key<number>` field out of a server reply line (0 if absent).
fn stat_field(reply: &str, key: &str) -> usize {
    reply
        .split(key)
        .nth(1)
        .and_then(|rest| rest.split_whitespace().next())
        .and_then(|v| v.parse().ok())
        .unwrap_or(0)
}

/// Raise the soft `RLIMIT_NOFILE` toward the hard cap and return the
/// resulting soft limit.  The connection-scaling section holds both
/// ends of every parked waiter in this one process (client socket plus
/// the server's accepted end), so N waiters cost roughly 2N fds.
fn raise_fd_limit() -> usize {
    #[repr(C)]
    struct RLimit {
        cur: u64,
        max: u64,
    }
    extern "C" {
        fn getrlimit(resource: i32, rlim: *mut RLimit) -> i32;
        fn setrlimit(resource: i32, rlim: *const RLimit) -> i32;
    }
    const RLIMIT_NOFILE: i32 = 7;
    let mut lim = RLimit { cur: 0, max: 0 };
    // SAFETY: plain POSIX getrlimit writing into a properly sized,
    // initialised #[repr(C)] struct we own.
    if unsafe { getrlimit(RLIMIT_NOFILE, &mut lim) } != 0 {
        return 1024;
    }
    let want = lim.max.min(65_536);
    if lim.cur < want {
        let new = RLimit { cur: want, max: lim.max };
        // SAFETY: raising the soft limit toward the hard cap is always
        // permitted; on failure the old limit simply stays in place.
        if unsafe { setrlimit(RLIMIT_NOFILE, &new) } == 0 {
            lim.cur = want;
        }
    }
    lim.cur as usize
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let smoke = args.iter().any(|a| a == "--smoke");
    let json = args.iter().any(|a| a == "--json");
    let only: Option<String> =
        args.iter().position(|a| a == "--only").and_then(|i| args.get(i + 1)).cloned();
    let run = |s: &str| only.as_deref().map_or(true, |o| o == s);
    let mut rng = Rng::new(0xBEEF);
    let cores = Pool::auto().threads();
    println!(
        "== micro benches (median ± MAD; {cores} cores detected{}) ==\n",
        if smoke { "; --smoke shapes" } else { "" }
    );

    // ---- native pairwise, paper-ish shapes, 1 thread vs all cores ------
    if run("pairwise") {
        let pairwise_shapes: &[(usize, usize, usize)] = if smoke {
            &[(200, 64, 16)]
        } else {
            &[(2_000, 512, 16), (2_000, 512, 128), (1_000, 512, 784)]
        };
        let (pw_warm, pw_iters) = if smoke { (0, 1) } else { (1, 5) };
        for &(n, m, p) in pairwise_shapes {
            let x = rand_matrix(&mut rng, n, p);
            let b = rand_matrix(&mut rng, m, p);
            let gdps = (n * m) as f64 / 1e9;
            for threads in [1, cores] {
                let backend = NativeBackend::with_pool(Metric::L1, Pool::new(threads));
                let (med, mad) = time_median(pw_warm, pw_iters, || {
                    std::hint::black_box(backend.pairwise(&x, &b).unwrap());
                });
                report(
                    "pairwise",
                    &format!("native pairwise l1 n={n} m={m} p={p} t={threads}"),
                    med,
                    mad,
                    Some((gdps, "Gdissim/s")),
                );
                if threads == cores {
                    break; // cores == 1: avoid a duplicate row
                }
            }
        }
    }

    // ---- fused tile ops: pairwise+argmin single sweep vs rewalk ---------
    // The one-sweep kernel reduces each row while its block tile is
    // still cache-hot; the unfused composition materialises the n x m
    // matrix and walks it again.  GB/s counts the streamed inputs plus
    // the written matrix (4 bytes each); Gpair/s counts n*m distances.
    if run("fused") {
        let (n, m, p) = if smoke { (160, 48, 12) } else { (4_000, 512, 48) };
        let x = rand_matrix(&mut rng, n, p);
        let b = rand_matrix(&mut rng, m, p);
        let gpairs = (n * m) as f64 / 1e9;
        let gbytes = ((n * p + m * p + n * m) * 4) as f64 / 1e9;
        let (warm, iters) = if smoke { (0, 1) } else { (1, 5) };
        for metric in [Metric::L1, Metric::SqL2, Metric::L2, Metric::Chebyshev, Metric::Cosine] {
            for profile in [ComputeProfile::Exact, ComputeProfile::Fast] {
                for threads in [1, cores] {
                    let backend = NativeBackend::with_pool(metric, Pool::new(threads))
                        .with_profile(profile);
                    let (t_fused, mad_f) = time_median(warm, iters, || {
                        std::hint::black_box(backend.pairwise_argmin(&x, &b).unwrap());
                    });
                    report(
                        "fused",
                        &format!(
                            "fused argmin {} {} t={threads}",
                            metric.name(),
                            profile.name()
                        ),
                        t_fused,
                        mad_f,
                        Some((gpairs, "Gpair/s")),
                    );
                    let (t_unfused, mad_u) = time_median(warm, iters, || {
                        let d = backend.pairwise(&x, &b).unwrap();
                        std::hint::black_box(backend.argmin_rows(&d).unwrap());
                    });
                    report(
                        "fused",
                        &format!(
                            "unfused argmin {} {} t={threads}",
                            metric.name(),
                            profile.name()
                        ),
                        t_unfused,
                        mad_u,
                        Some((gpairs, "Gpair/s")),
                    );
                    println!(
                        "  -> fused {:.2}x vs rewalk, {:.2} GB/s swept",
                        t_unfused / t_fused.max(1e-12),
                        gbytes / t_fused.max(1e-12)
                    );
                    if threads == cores {
                        break;
                    }
                }
            }
        }
    }

    // ---- Fast (dot-product) vs Exact (diff-accumulate) profiles ---------
    // Only the Euclidean metrics have a distinct Fast kernel; the rest
    // run the identical code under either profile.
    if run("profile") {
        let (n, m, p) = if smoke { (160, 48, 12) } else { (4_000, 512, 128) };
        let x = rand_matrix(&mut rng, n, p);
        let b = rand_matrix(&mut rng, m, p);
        let gpairs = (n * m) as f64 / 1e9;
        let (warm, iters) = if smoke { (0, 1) } else { (1, 5) };
        for metric in [Metric::SqL2, Metric::L2] {
            let mut per_profile = [0.0f64; 2];
            for (slot, profile) in [ComputeProfile::Exact, ComputeProfile::Fast]
                .into_iter()
                .enumerate()
            {
                let backend =
                    NativeBackend::with_pool(metric, Pool::new(cores)).with_profile(profile);
                let (med, mad) = time_median(warm, iters, || {
                    std::hint::black_box(backend.pairwise(&x, &b).unwrap());
                });
                per_profile[slot] = med;
                report(
                    "profile",
                    &format!("pairwise {} {} p={p} t={cores}", metric.name(), profile.name()),
                    med,
                    mad,
                    Some((gpairs, "Gpair/s")),
                );
            }
            println!(
                "  -> fast {:.2}x vs exact on {}",
                per_profile[0] / per_profile[1].max(1e-12),
                metric.name()
            );
        }
    }

    // ---- v9 out-of-core: chunked stream sweep vs resident fused ---------
    // The same n x m argmin three ways: the resident fused kernel (the
    // floor), StreamSweep over a ResidentStore (pure chunking tax — the
    // kernels are identical, only the row delivery differs) and
    // StreamSweep over an on-disk NpyStore (chunking + file I/O, the
    // shape a streamed `npy:` solve actually runs).  Results are
    // bit-identical across all three by construction; this measures
    // only what the indirection costs.
    if run("stream") {
        use obpam::data::store::{NpyStore, ResidentStore};
        use obpam::data::STREAM_CHUNK_ROWS;
        use obpam::dissim::{DissimCounter, StreamSweep};
        let (n, m, p) = if smoke { (2_000, 32, 16) } else { (40_000, 256, 64) };
        let x = rand_matrix(&mut rng, n, p);
        let b = rand_matrix(&mut rng, m, p);
        let gpairs = (n * m) as f64 / 1e9;
        let gbytes = ((n * p + m * p + n * m) * 4) as f64 / 1e9;
        let (warm, iters) = if smoke { (0, 1) } else { (1, 5) };
        let path = std::env::temp_dir().join(format!("obpam_bench_stream_{}.npy", std::process::id()));
        obpam::data::npy::write_npy(&path, &x).unwrap();
        let d = DissimCounter::new(Metric::L1);
        for threads in [1, cores] {
            let pool = Pool::new(threads);
            let backend = NativeBackend::with_pool(Metric::L1, pool.clone());
            let (t_res, mad_r) = time_median(warm, iters, || {
                std::hint::black_box(backend.pairwise_argmin(&x, &b).unwrap());
            });
            report(
                "stream",
                &format!("resident fused argmin n={n} m={m} p={p} t={threads}"),
                t_res,
                mad_r,
                Some((gpairs, "Gpair/s")),
            );
            let mut sweep = StreamSweep::new(STREAM_CHUNK_ROWS);
            let mut store = ResidentStore::new(x.clone());
            let (t_mem, mad_m) = time_median(warm, iters, || {
                let out =
                    sweep.argmin(&d, &mut store, &b, &pool, ComputeProfile::Exact).unwrap();
                std::hint::black_box(out);
            });
            report(
                "stream",
                &format!("stream argmin (memory) n={n} m={m} p={p} t={threads}"),
                t_mem,
                mad_m,
                Some((gpairs, "Gpair/s")),
            );
            let mut npy_store = NpyStore::open(&path).unwrap();
            let (t_npy, mad_n) = time_median(warm, iters, || {
                let out =
                    sweep.argmin(&d, &mut npy_store, &b, &pool, ComputeProfile::Exact).unwrap();
                std::hint::black_box(out);
            });
            report(
                "stream",
                &format!("stream argmin (npy disk) n={n} m={m} p={p} t={threads}"),
                t_npy,
                mad_n,
                Some((gpairs, "Gpair/s")),
            );
            println!(
                "  -> chunking tax {:.2}x, disk tax {:.2}x, {:.2} GB/s swept from npy",
                t_mem / t_res.max(1e-12),
                t_npy / t_res.max(1e-12),
                gbytes / t_npy.max(1e-12)
            );
            if threads == cores {
                break;
            }
        }
        std::fs::remove_file(&path).ok();
    }

    let heavy =
        ["gains", "eager", "state", "e2e", "dispatch", "xla"].iter().any(|s| run(s));
    if !smoke && heavy {
        // ---- swap gains: native loop, 1 thread vs all cores -------------
        let (n, m, k) = (4_000, 1_024, 100);
        let d = rand_matrix(&mut rng, n, m);
        let dn: Vec<f32> = (0..m).map(|_| rng.f32()).collect();
        let ds: Vec<f32> = dn.iter().map(|v| v + 0.3).collect();
        let near: Vec<usize> = (0..m).map(|_| rng.below(k)).collect();
        let w = vec![1.0f32; m];
        if run("gains") {
            for threads in [1, cores] {
                let backend = NativeBackend::with_pool(Metric::L1, Pool::new(threads));
                let (med, mad) = time_median(1, 5, || {
                    std::hint::black_box(backend.gains(&d, &dn, &ds, &near, k, &w).unwrap());
                });
                report(
                    "gains",
                    &format!("native gains n={n} m={m} k={k} t={threads}"),
                    med,
                    mad,
                    Some(((n * m) as f64 / 1e9, "Gcell/s")),
                );
                if threads == cores {
                    break;
                }
            }
        }

        // ---- eager candidate scan: one full pass, 1 thread vs all cores -
        if run("eager") {
            let mut rng2 = Rng::new(1);
            let med: Vec<usize> = rng2.sample_distinct(n, k);
            let st0 = SwapState::init(&d, med, vec![1.0; m], n);
            for threads in [1, cores] {
                let pool = Pool::new(threads);
                let counters = Counters::default();
                let (t_scan, mad) = time_median(1, 5, || {
                    // fresh state + rng per iteration so every pass scans the
                    // same candidate sequence (clone cost is shared by both
                    // thread counts)
                    let mut st = st0.clone();
                    let mut order_rng = Rng::new(42);
                    std::hint::black_box(engine::eager_loop_eps(
                        &d,
                        &mut st,
                        1,
                        0.0,
                        &mut order_rng,
                        &counters,
                        &pool,
                    ));
                });
                report(
                    "eager",
                    &format!("eager scan pass n={n} m={m} k={k} t={threads}"),
                    t_scan,
                    mad,
                    Some(((n * (m + k)) as f64 / 1e9, "Gop/s")),
                );
                if threads == cores {
                    break;
                }
            }
        }

        // ---- SwapState ops ----------------------------------------------
        if run("state") {
            let mut rng2 = Rng::new(1);
            let med: Vec<usize> = rng2.sample_distinct(n, k);
            let mut st = SwapState::init(&d, med, vec![1.0; m], n);
            let (t_eval, mad) = time_median(10, 50, || {
                std::hint::black_box(st.eval_candidate(d.row(17)));
            });
            report("state", &format!("state eval_candidate m={m} k={k}"), t_eval, mad, None);
            let mut cand = 0usize;
            let (t_swap, mad) = time_median(2, 20, || {
                while st.is_medoid(cand % n) {
                    cand += 1;
                }
                let slot = cand % k;
                st.apply_swap(&d, slot, cand % n);
                cand += 1;
            });
            report("state", &format!("state apply_swap m={m} k={k}"), t_swap, mad, None);
        }

        // ---- end-to-end OneBatchPAM, serial vs threaded ------------------
        if run("e2e") {
            let x = rand_matrix(&mut rng, 5_000, 32);
            for threads in [1, cores] {
                let backend = NativeBackend::with_pool(Metric::L1, Pool::new(threads));
                let cfg = OneBatchConfig {
                    k: 20,
                    sampler: SamplerKind::Nniw,
                    seed: 3,
                    threads,
                    ..Default::default()
                };
                let (med, mad) = time_median(1, 3, || {
                    std::hint::black_box(one_batch_pam(&x, &cfg, &backend).unwrap());
                });
                report(
                    "e2e",
                    &format!("one_batch_pam n=5000 p=32 k=20 t={threads}"),
                    med,
                    mad,
                    None,
                );
                if threads == cores {
                    break;
                }
            }
        }

        // ---- per-region dispatch: persistent pool vs scoped spawn --------
        // A deliberately tiny region (the worst case for dispatch overhead):
        // the work per range is microseconds, so the measured time is mostly
        // the cost of getting the region onto the workers and back.
        if run("dispatch") {
            let rows = 16 * 1024;
            let data: Vec<f32> = (0..rows).map(|i| (i % 97) as f32).collect();
            let data = &data;
            let threads = cores.max(2);
            let pool = Pool::new(threads);
            let (t_persist, mad_p) = time_median(50, 200, || {
                let parts = pool.map_ranges(rows, |r| data[r].iter().sum::<f32>());
                std::hint::black_box(parts);
            });
            report(
                "dispatch",
                &format!("region dispatch: persistent pool t={threads}"),
                t_persist,
                mad_p,
                None,
            );
            // the pre-persistent-pool shape: scoped spawn + join per region
            let ranges = pool.ranges(rows);
            let (t_scoped, mad_s) = time_median(50, 200, || {
                let parts: Vec<f32> = std::thread::scope(|s| {
                    let handles: Vec<_> = ranges
                        .iter()
                        .cloned()
                        .map(|r| s.spawn(move || data[r].iter().sum::<f32>()))
                        .collect();
                    handles.into_iter().map(|h| h.join().unwrap()).collect()
                });
                std::hint::black_box(parts);
            });
            report(
                "dispatch",
                &format!("region dispatch: scoped spawn t={threads}"),
                t_scoped,
                mad_s,
                None,
            );
            println!(
                "  -> per-region dispatch {:.1} us (persistent) vs {:.1} us (scoped), {:.2}x",
                t_persist * 1e6,
                t_scoped * 1e6,
                t_scoped / t_persist.max(1e-12)
            );
        }

        // ---- per-job pool build vs server-cached pool dispatch -----------
        // The v5 server hands every job a clone of one persistent pool per
        // width (server::PoolCache) instead of letting each job build its
        // own.  Measure the difference for a small job-sized region: the
        // per-job shape pays `threads - 1` thread spawns + joins, the
        // cached shape pays a map lookup + clone + wakeup.
        if run("dispatch") {
            let rows = 16 * 1024;
            let data: Vec<f32> = (0..rows).map(|i| (i % 89) as f32).collect();
            let data = &data;
            let threads = cores.max(2);
            let (t_build, mad_b) = time_median(20, 100, || {
                // what each served job paid before the cache: build, use, drop
                let pool = Pool::new(threads);
                let parts = pool.map_ranges(rows, |r| data[r].iter().sum::<f32>());
                std::hint::black_box(parts);
            });
            report(
                "dispatch",
                &format!("job dispatch: per-job pool build t={threads}"),
                t_build,
                mad_b,
                None,
            );
            let cache = obpam::server::PoolCache::new();
            let _warm = cache.get(threads); // first job pays the build once
            let (t_cached, mad_c) = time_median(20, 100, || {
                let pool = cache.get(threads);
                let parts = pool.map_ranges(rows, |r| data[r].iter().sum::<f32>());
                std::hint::black_box(parts);
            });
            report(
                "dispatch",
                &format!("job dispatch: cached-pool reuse t={threads}"),
                t_cached,
                mad_c,
                None,
            );
            println!(
                "  -> per-job dispatch {:.1} us (cached) vs {:.1} us (build+drop), {:.2}x",
                t_cached * 1e6,
                t_build * 1e6,
                t_build / t_cached.max(1e-12)
            );
        }

        // ---- XLA artifact paths ------------------------------------------
        if run("xla") {
            #[cfg(feature = "xla")]
            xla_section(&mut rng, &d, &dn, &ds, &near, k, &w);
            #[cfg(not(feature = "xla"))]
            println!("\n(xla paths skipped: built without the `xla` feature)");
        }
    }

    // ---- v7 model serving: assign QPS over TCP ---------------------------
    // The fitted-model read path: one solve is promoted once, then the
    // server answers nearest-medoid lookups from the k x p medoid rows
    // alone, reusing the per-model scratch (no per-request matrix).
    // Each request pays a fresh TCP connect + one-line dispatch, so this
    // measures the serving wire path, not the argmin (which is
    // nanoseconds at k=5).  One client alone is latency-bound; the
    // concurrent shape shows how far connection-per-request scales.
    if run("serving") {
        use obpam::server::{request, serve, ServerConfig};
        let h = serve(ServerConfig { workers: 1, queue_cap: 64, ..Default::default() }).unwrap();
        let dataset = if smoke { "blobs_500_4_3" } else { "blobs_2000_8_5" };
        let point = if smoke { "0.1,0.2,0.3,0.4" } else { "0.1,0.2,0.3,0.4,0.5,0.6,0.7,0.8" };
        let sub = request(h.addr, &format!("submit dataset={dataset} k=5 seed=1")).unwrap();
        let id = sub
            .split_whitespace()
            .find_map(|t| t.strip_prefix("job="))
            .expect("submit must return a handle")
            .to_string();
        let done = request(h.addr, &format!("wait job={id} timeout_ms=600000")).unwrap();
        assert!(done.starts_with("ok "), "{done}");
        let p = request(h.addr, &format!("promote job={id} name=bench")).unwrap();
        assert!(p.starts_with("ok "), "{p}");
        let reqs = if smoke { 20usize } else { 200 };
        let (qps_warm, qps_iters) = if smoke { (0, 1) } else { (1, 3) };
        for profile in ["exact", "fast"] {
            let line = format!("assign model=bench profile={profile} point={point}");
            let (t_one, mad_one) = time_median(qps_warm, qps_iters, || {
                for _ in 0..reqs {
                    let r = request(h.addr, &line).unwrap();
                    debug_assert!(r.starts_with("ok "), "{r}");
                    std::hint::black_box(r);
                }
            });
            report(
                "serving",
                &format!("assign qps: 1 conn, {reqs} reqs, {profile}"),
                t_one,
                mad_one,
                Some((reqs as f64, "req/s")),
            );
        }
        let conns = cores.clamp(2, 8);
        let line = format!("assign model=bench point={point}");
        let line = line.as_str();
        let (t_many, mad_many) = time_median(qps_warm, qps_iters, || {
            std::thread::scope(|s| {
                for _ in 0..conns {
                    s.spawn(|| {
                        for _ in 0..reqs {
                            let r = request(h.addr, line).unwrap();
                            debug_assert!(r.starts_with("ok "), "{r}");
                            std::hint::black_box(r);
                        }
                    });
                }
            });
        });
        report(
            "serving",
            &format!("assign qps: {conns} connections, {reqs} reqs each"),
            t_many,
            mad_many,
            Some(((conns * reqs) as f64, "req/s")),
        );
        h.shutdown();
    }

    // ---- v8 evented core: connection scaling ------------------------------
    // The readiness-driven accept loop holds an idle `wait`er as a
    // registry entry plus a timer-wheel node instead of a blocked OS
    // thread, so N parked connections cost memory, not threads.  Park N
    // waiters on a queued job behind a long CLARA blocker, check the
    // process thread count stayed flat, measure on-loop `assign` QPS
    // with the waiters still parked (the read path must not degrade
    // behind thousands of sleepers), then resolve every waiter at once
    // with a single `cancel`.
    if run("conn") {
        use obpam::server::{request, serve, ServerConfig};
        use std::io::{BufRead, BufReader, Write};
        use std::time::{Duration, Instant};
        let fd_budget = raise_fd_limit();
        let want = if smoke { 1_000usize } else { 10_000 };
        let waiters = want.min(fd_budget.saturating_sub(256) / 2);
        if waiters < want {
            println!("(conn section capped to {waiters} waiters by RLIMIT_NOFILE={fd_budget})");
        }
        let h = serve(ServerConfig {
            workers: 1,
            queue_cap: 8,
            conn_cap: waiters + 64,
            ..Default::default()
        })
        .unwrap();

        // a fitted model for the assign-QPS probes
        let sub = request(h.addr, "submit dataset=blobs_300_4_3 k=3 seed=1").unwrap();
        let fit = sub.split_whitespace().find_map(|t| t.strip_prefix("job=")).unwrap().to_string();
        let done = request(h.addr, &format!("wait job={fit} timeout_ms=600000")).unwrap();
        assert!(done.starts_with("ok "), "{done}");
        let p = request(h.addr, &format!("promote job={fit} name=bench")).unwrap();
        assert!(p.starts_with("ok "), "{p}");
        let assign_line = "assign model=bench point=0.1,0.2,0.3,0.4";
        let reqs = if smoke { 100usize } else { 500 };
        let (warm, iters) = if smoke { (0, 1) } else { (1, 3) };
        let assign_qps = |label: &str| {
            let (med, mad) = time_median(warm, iters, || {
                for _ in 0..reqs {
                    let r = request(h.addr, assign_line).unwrap();
                    debug_assert!(r.starts_with("ok "), "{r}");
                    std::hint::black_box(r);
                }
            });
            report("conn", label, med, mad, Some((reqs as f64, "req/s")));
        };
        assign_qps(&format!("assign qps: 0 parked waiters, {reqs} reqs"));

        // pin the lone worker on a cancellable many-rep CLARA blocker,
        // then queue a cheap job behind it for the waiters to park on
        let sub = request(
            h.addr,
            "submit dataset=blobs_20000_8_5 k=5 seed=3 method=FasterCLARA-30000",
        )
        .unwrap();
        let blocker =
            sub.split_whitespace().find_map(|t| t.strip_prefix("job=")).unwrap().to_string();
        let deadline = Instant::now() + Duration::from_secs(60);
        loop {
            let r = request(h.addr, &format!("poll job={blocker}")).unwrap();
            if r.contains(" state=running ") || r.ends_with("state=running") {
                break;
            }
            assert!(Instant::now() < deadline, "blocker never started running: {r}");
            std::thread::sleep(Duration::from_millis(5));
        }
        let sub = request(h.addr, "submit dataset=blobs_300_4_3 k=3 seed=4").unwrap();
        let parked =
            sub.split_whitespace().find_map(|t| t.strip_prefix("job=")).unwrap().to_string();

        let threads_before = thread_count();
        let t0 = Instant::now();
        let mut conns: Vec<BufReader<std::net::TcpStream>> = Vec::with_capacity(waiters);
        let wait_line = format!("wait job={parked} timeout_ms=600000\n");
        for _ in 0..waiters {
            let mut s = std::net::TcpStream::connect(h.addr).unwrap();
            s.write_all(wait_line.as_bytes()).unwrap();
            conns.push(BufReader::new(s));
        }
        let deadline = Instant::now() + Duration::from_secs(120);
        loop {
            let s = request(h.addr, "stats").unwrap();
            if stat_field(&s, " waiters=") >= waiters {
                break;
            }
            assert!(Instant::now() < deadline, "waiters never all parked: {s}");
            std::thread::sleep(Duration::from_millis(20));
        }
        let t_park = t0.elapsed().as_secs_f64();
        report(
            "conn",
            &format!("park {waiters} idle waiters"),
            t_park,
            0.0,
            Some((waiters as f64, "conn/s")),
        );
        if let (Some(before), Some(after)) = (threads_before, thread_count()) {
            println!("  -> process threads: {before} before, {after} with {waiters} parked");
            assert_eq!(before, after, "parked waiters must not cost OS threads");
        }

        assign_qps(&format!("assign qps: {waiters} parked waiters, {reqs} reqs"));

        // one cancel of the queued job resolves every parked waiter
        let t0 = Instant::now();
        let c = request(h.addr, &format!("cancel job={parked}")).unwrap();
        assert!(c.starts_with("ok "), "{c}");
        let expect = format!("err cancelled job={parked}");
        for conn in &mut conns {
            let mut line = String::new();
            conn.read_line(&mut line).unwrap();
            debug_assert!(line.starts_with(&expect), "{line}");
        }
        let t_resolve = t0.elapsed().as_secs_f64();
        report(
            "conn",
            &format!("resolve {waiters} parked waiters"),
            t_resolve,
            0.0,
            Some((waiters as f64, "conn/s")),
        );
        drop(conns);
        let c = request(h.addr, &format!("cancel job={blocker}")).unwrap();
        assert!(c.starts_with("ok "), "{c}");
        h.shutdown();
    }

    if json {
        write_json("BENCH_micro.json", cores, smoke);
    }
}

#[cfg(feature = "xla")]
#[allow(clippy::too_many_arguments)]
fn xla_section(
    rng: &mut Rng,
    d: &Matrix,
    dn: &[f32],
    ds: &[f32],
    near: &[usize],
    k: usize,
    w: &[f32],
) {
    use obpam::backend::XlaBackend;
    use obpam::runtime::Runtime;
    use std::rc::Rc;

    let (n, m) = (d.rows, d.cols);
    match Runtime::load_default() {
        Err(e) => println!("\n(xla paths skipped: {e})"),
        Ok(rt) => {
            let rt = Rc::new(rt);
            println!();
            for dense in [false, true] {
                let backend = XlaBackend::new(rt.clone(), Metric::L1, dense);
                let (xn, xm, xp) = (2_000, 512, 128);
                let x = rand_matrix(rng, xn, xp);
                let b = rand_matrix(rng, xm, xp);
                let (med, mad) = time_median(1, 3, || {
                    std::hint::black_box(backend.pairwise(&x, &b).unwrap());
                });
                report(
                    "xla",
                    &format!("{} pairwise l1 n={xn} m={xm} p={xp}", backend.name()),
                    med,
                    mad,
                    Some(((xn * xm) as f64 / 1e9, "Gdissim/s")),
                );
            }
            let backend = XlaBackend::new(rt.clone(), Metric::L1, false);
            let (med, mad) = time_median(1, 3, || {
                std::hint::black_box(backend.gains(d, dn, ds, near, k, w).unwrap());
            });
            report(
                "xla",
                &format!("xla gains (pallas matmul) n={n} m={m} k={k}"),
                med,
                mad,
                Some(((n * m) as f64 / 1e9, "Gcell/s")),
            );
        }
    }
}
