//! Micro-benchmarks of the hot-path tile ops — the §Perf tool
//! (EXPERIMENTS.md records before/after from this bench).
//!
//! Measures, with warmup + median/MAD:
//!   * native pairwise throughput (Gdissim/s and effective GB/s);
//!   * XLA pairwise: Pallas kernel vs plain-XLA lowering (artifact path);
//!   * swap-gain evaluation: native inner loop vs XLA matmul kernel;
//!   * SwapState::eval_candidate / apply_swap latency;
//!   * end-to-end OneBatchPAM at a fixed workload.

use obpam::backend::{ComputeBackend, NativeBackend, XlaBackend};
use obpam::coordinator::state::SwapState;
use obpam::coordinator::{one_batch_pam, OneBatchConfig, SamplerKind};
use obpam::dissim::Metric;
use obpam::harness::bench_util::time_median;
use obpam::linalg::Matrix;
use obpam::rng::Rng;
use obpam::runtime::Runtime;
use std::rc::Rc;

fn rand_matrix(rng: &mut Rng, r: usize, c: usize) -> Matrix {
    Matrix::from_vec(r, c, (0..r * c).map(|_| rng.f32()).collect())
}

fn report(name: &str, med: f64, mad: f64, work: Option<(f64, &str)>) {
    match work {
        Some((units, unit_name)) => println!(
            "{name:<46} {:>9.3} ms ± {:>6.3}  ({:.2} {unit_name})",
            med * 1e3,
            mad * 1e3,
            units / med
        ),
        None => println!("{name:<46} {:>9.3} ms ± {:>6.3}", med * 1e3, mad * 1e3),
    }
}

fn main() {
    let mut rng = Rng::new(0xBEEF);
    println!("== micro benches (median ± MAD) ==\n");

    // ---- native pairwise, paper-ish shapes -----------------------------
    for (n, m, p) in [(2_000, 512, 16), (2_000, 512, 128), (1_000, 512, 784)] {
        let x = rand_matrix(&mut rng, n, p);
        let b = rand_matrix(&mut rng, m, p);
        let backend = NativeBackend::new(Metric::L1);
        let (med, mad) = time_median(1, 5, || {
            std::hint::black_box(backend.pairwise(&x, &b).unwrap());
        });
        let gdps = (n * m) as f64 / 1e9;
        report(&format!("native pairwise l1 n={n} m={m} p={p}"), med, mad, Some((gdps, "Gdissim/s")));
    }

    // ---- swap gains: native loop --------------------------------------
    let (n, m, k) = (4_000, 1_024, 100);
    let d = rand_matrix(&mut rng, n, m);
    let dn: Vec<f32> = (0..m).map(|_| rng.f32()).collect();
    let ds: Vec<f32> = dn.iter().map(|v| v + 0.3).collect();
    let near: Vec<usize> = (0..m).map(|_| rng.below(k)).collect();
    let w = vec![1.0f32; m];
    {
        let backend = NativeBackend::new(Metric::L1);
        let (med, mad) = time_median(1, 5, || {
            std::hint::black_box(backend.gains(&d, &dn, &ds, &near, k, &w).unwrap());
        });
        report(
            &format!("native gains n={n} m={m} k={k}"),
            med,
            mad,
            Some(((n * m) as f64 / 1e9, "Gcell/s")),
        );
    }

    // ---- SwapState ops --------------------------------------------------
    {
        let mut rng2 = Rng::new(1);
        let med: Vec<usize> = rng2.sample_distinct(n, k);
        let mut st = SwapState::init(&d, med, vec![1.0; m], n);
        let (t_eval, mad) = time_median(10, 50, || {
            std::hint::black_box(st.eval_candidate(d.row(17)));
        });
        report(&format!("state eval_candidate m={m} k={k}"), t_eval, mad, None);
        let mut cand = 0usize;
        let (t_swap, mad) = time_median(2, 20, || {
            while st.is_medoid(cand % n) {
                cand += 1;
            }
            let slot = cand % k;
            st.apply_swap(&d, slot, cand % n);
            cand += 1;
        });
        report(&format!("state apply_swap m={m} k={k}"), t_swap, mad, None);
    }

    // ---- end-to-end OneBatchPAM ----------------------------------------
    {
        let x = rand_matrix(&mut rng, 5_000, 32);
        let backend = NativeBackend::new(Metric::L1);
        let cfg = OneBatchConfig { k: 20, sampler: SamplerKind::Nniw, seed: 3, ..Default::default() };
        let (med, mad) = time_median(1, 3, || {
            std::hint::black_box(one_batch_pam(&x, &cfg, &backend).unwrap());
        });
        report("one_batch_pam n=5000 p=32 k=20 (native)", med, mad, None);
    }

    // ---- XLA artifact paths ---------------------------------------------
    match Runtime::load_default() {
        Err(e) => println!("\n(xla paths skipped: {e})"),
        Ok(rt) => {
            let rt = Rc::new(rt);
            println!();
            for dense in [false, true] {
                let backend = XlaBackend::new(rt.clone(), Metric::L1, dense);
                let (n, m, p) = (2_000, 512, 128);
                let x = rand_matrix(&mut rng, n, p);
                let b = rand_matrix(&mut rng, m, p);
                let (med, mad) = time_median(1, 3, || {
                    std::hint::black_box(backend.pairwise(&x, &b).unwrap());
                });
                report(
                    &format!("{} pairwise l1 n={n} m={m} p={p}", backend.name()),
                    med,
                    mad,
                    Some(((n * m) as f64 / 1e9, "Gdissim/s")),
                );
            }
            let backend = XlaBackend::new(rt.clone(), Metric::L1, false);
            let (med, mad) = time_median(1, 3, || {
                std::hint::black_box(backend.gains(&d, &dn, &ds, &near, k, &w).unwrap());
            });
            report(
                &format!("xla gains (pallas matmul) n={n} m={m} k={k}"),
                med,
                mad,
                Some(((n * m) as f64 / 1e9, "Gcell/s")),
            );
        }
    }
}
