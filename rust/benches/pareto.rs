//! Regenerates paper **Figures 12-31**: objective-vs-time Pareto fronts
//! per dataset for k = 10 and k = 100.
//!
//! Reuses the records CSVs produced by the table3 bench when present.

use obpam::data::synth;
use obpam::dissim::Metric;
use obpam::eval;
use obpam::harness::{bench_util, emit, methods::MethodSpec, runner};
use std::path::Path;

fn records_for(tag: &str, datasets: &[&str], scale: f64) -> Vec<runner::Record> {
    let csv = format!("bench_out/records_{tag}.csv");
    if let Some(r) = bench_util::load_records_csv(Path::new(&csv)) {
        eprintln!("[pareto] reusing {} records from {csv}", r.len());
        return r;
    }
    let ks = bench_util::env_ks(&[10, 100]);
    let reps = bench_util::env_reps(1);
    let recs = runner::run_grid(
        datasets,
        &ks,
        reps,
        &MethodSpec::table3_grid(),
        scale,
        Metric::L1,
        0xAAA1,
        bench_util::env_threads(1),
        |r| eprintln!("  {} k={} {:<18} {:.3}s", r.dataset, r.k, r.method, r.seconds),
    )
    .expect("grid");
    emit::write_records_csv(Path::new(&csv), &recs).unwrap();
    recs
}

fn main() {
    let scale = bench_util::env_scale(0.25);
    let small = synth::small_scale_names();
    let large = synth::large_scale_names();
    let mut all = records_for("small", &small, scale);
    all.extend(records_for("large", &large, scale * 0.2));

    let mut front_membership: Vec<Vec<String>> = Vec::new();
    for &ds in small.iter().chain(large.iter()) {
        for &k in &[10usize, 100] {
            // average reps per method
            use std::collections::BTreeMap;
            let mut by_method: BTreeMap<String, (f64, f64, usize)> = BTreeMap::new();
            for r in all.iter().filter(|r| r.dataset == ds && r.k == k) {
                let e = by_method.entry(r.method.clone()).or_insert((0.0, 0.0, 0));
                e.0 += r.seconds;
                e.1 += r.objective;
                e.2 += 1;
            }
            if by_method.is_empty() {
                continue;
            }
            let pts: Vec<(f64, f64, String)> = by_method
                .iter()
                .map(|(m, (t, o, c))| (t / *c as f64, o / *c as f64, m.clone()))
                .collect();
            let xy: Vec<(f64, f64)> = pts.iter().map(|p| (p.0, p.1)).collect();
            let front = eval::pareto_front(&xy);
            println!("{}", emit::scatter(&format!("Pareto: {ds} (k={k})"), &pts, &front));
            for &fi in &front {
                front_membership.push(vec![ds.into(), k.to_string(), pts[fi].2.clone()]);
            }
        }
    }
    emit::write_csv(
        Path::new("bench_out/pareto_front_members.csv"),
        "dataset,k,method",
        &front_membership,
    )
    .unwrap();

    // paper's qualitative claim (Appendix D): these methods populate fronts
    let counts = |needle: &str| front_membership.iter().filter(|r| r[2] == needle).count();
    println!(
        "front membership counts: OneBatch-nniw={} FasterCLARA-5={} k-means++={} kmc2-20={} FasterPAM={}",
        counts("OneBatch-nniw"),
        counts("FasterCLARA-5"),
        counts("k-means++"),
        counts("kmc2-20"),
        counts("FasterPAM"),
    );
    println!(
        "paper reference (App. D): small-scale fronts contain k-means++, FasterCLARA-5,\n\
         OneBatch-nniw, FasterPAM; large-scale fronts contain kmc2-20, FasterCLARA-5, OneBatch-nniw."
    );
}
