//! Regenerates paper **Table 3 / Table 4** (aggregated RT and ΔRO over
//! the small-scale and large-scale dataset groups, averaged over
//! k ∈ {10,50,100} and repetitions).
//!
//! Knobs: OBPAM_SCALE (default 0.05), OBPAM_REPS (default 2),
//! OBPAM_KS (default "10,50,100"), OBPAM_FRESH=1 to ignore cached
//! records.  Raw per-run records land in bench_out/records_{small,large}.csv
//! and are reused by the table5_6 / table7_8 / pareto benches.

use obpam::dissim::Metric;
use obpam::data::synth;
use obpam::harness::{bench_util, emit, methods::MethodSpec, runner};
use std::path::Path;

fn run_group(name: &str, datasets: &[&str], scale: f64) -> Vec<runner::Record> {
    let csv = format!("bench_out/records_{name}.csv");
    if let Some(recs) = bench_util::load_records_csv(Path::new(&csv)) {
        eprintln!("[table3] reusing {csv} ({} records); OBPAM_FRESH=1 to rerun", recs.len());
        return recs;
    }
    let ks = bench_util::env_ks(&[10, 50]);
    let reps = bench_util::env_reps(1);
    let methods = MethodSpec::table3_grid();
    eprintln!(
        "[table3] running {name}-scale grid: {:?} x k={ks:?} x {reps} reps x {} methods (scale {scale})",
        datasets,
        methods.len()
    );
    let threads = bench_util::env_threads(1);
    let recs =
        runner::run_grid(datasets, &ks, reps, &methods, scale, Metric::L1, 0xAAA1, threads, |r| {
            eprintln!(
                "  {} k={} rep={} {:<18} {:.3}s obj={:.5} dissim={}",
                r.dataset, r.k, r.rep, r.method, r.seconds, r.objective, r.dissim
            );
        })
        .expect("grid run failed");
    emit::write_records_csv(Path::new(&csv), &recs).expect("write records");
    recs
}

fn print_group(title: &str, recs: &[runner::Record], rt_reference: &str) {
    let agg = runner::aggregate(recs, rt_reference);
    // order rows like the paper
    let order = MethodSpec::table3_grid();
    let mut rows = Vec::new();
    for m in &order {
        if let Some((method, rt_m, rt_s, dro_m, dro_s)) = agg.iter().find(|a| a.0 == m.label()) {
            rows.push((
                method.clone(),
                vec![emit::pct(*rt_m, *rt_s), emit::pct(*dro_m, *dro_s)],
            ));
        } else {
            rows.push((m.label(), vec!["Na".into(), "Na".into()]));
        }
    }
    println!(
        "{}",
        emit::render_table(title, &["RT %", "dRO %"], &rows)
    );
    let csv_rows: Vec<Vec<String>> = rows
        .iter()
        .map(|(m, c)| vec![m.clone(), c[0].clone(), c[1].clone()])
        .collect();
    emit::write_csv(
        Path::new(&format!("bench_out/table3_{}.csv", title.replace(' ', "_"))),
        "method,rt,dro",
        &csv_rows,
    )
    .unwrap();
}

fn main() {
    let scale = bench_util::env_scale(0.25);
    let small: Vec<&str> = synth::small_scale_names();
    let large: Vec<&str> = synth::large_scale_names();

    let recs_small = run_group("small", &small, scale);
    // large-scale datasets are 1-2 orders bigger; scale them down further
    // by default so the bench finishes on one core (paper runs them on a
    // real testbed; shapes, not absolutes, are the target).
    let large_scale = bench_util::env_scale(0.25) * 0.2;
    let recs_large = run_group("large", &large, large_scale);

    // Paper normalisation: FasterPAM = 100% RT on small scale,
    // OneBatch-nniw = 100% on large scale (FasterPAM is Na there).
    print_group("small scale (Table 3 left)", &recs_small, "FasterPAM");
    print_group("large scale (Table 3 right)", &recs_large, "OneBatch-nniw");

    println!(
        "paper reference (Table 3): OneBatch-nniw small RT~15.5 dRO~1.7 | large RT=100 dRO=0.0\n\
         expected shape: OneBatch-* ~an order faster than FasterPAM at small dRO;\n\
         FasterCLARA faster but 8-13% worse; kmc2/k-means++ fastest but 18-33% worse."
    );
}
