//! Regenerates paper **Tables 5 & 6** (per-dataset small-scale RT and
//! ΔRO) and the data behind **Figures 2-6** (per-dataset RT/ΔRO bars).
//!
//! Reuses bench_out/records_small.csv when present (run the table3 bench
//! first, or let this one regenerate the grid).

use obpam::data::synth;
use obpam::dissim::Metric;
use obpam::harness::{bench_util, emit, methods::MethodSpec, runner};
use std::path::Path;

fn per_dataset_tables(recs: &[runner::Record], datasets: &[&str], rt_reference: &str, tag: &str) {
    let order = MethodSpec::table3_grid();
    for want in ["RT", "dRO"] {
        let mut rows = Vec::new();
        for m in &order {
            let mut cells = Vec::new();
            for &ds in datasets {
                let sub: Vec<runner::Record> = recs
                    .iter()
                    .filter(|r| r.dataset == ds)
                    .cloned()
                    .collect();
                let agg = runner::aggregate(&sub, rt_reference);
                let cell = agg
                    .iter()
                    .find(|a| a.0 == m.label())
                    .map(|(_, rt_m, rt_s, dro_m, dro_s)| {
                        if want == "RT" {
                            emit::pct(*rt_m, *rt_s)
                        } else {
                            emit::pct(*dro_m, *dro_s)
                        }
                    })
                    .unwrap_or_else(|| "Na".into());
                cells.push(cell);
            }
            rows.push((m.label(), cells));
        }
        let title = format!("{want} per dataset ({tag})");
        println!("{}", emit::render_table(&title, datasets, &rows));
        let csv_rows: Vec<Vec<String>> = rows
            .iter()
            .map(|(m, c)| {
                let mut row = vec![m.clone()];
                row.extend(c.clone());
                row
            })
            .collect();
        emit::write_csv(
            Path::new(&format!("bench_out/table_{tag}_{want}.csv")),
            &format!("method,{}", datasets.join(",")),
            &csv_rows,
        )
        .unwrap();
    }

    // Figures 2-6: RT & dRO bar charts per dataset
    for &ds in datasets {
        let sub: Vec<runner::Record> = recs.iter().filter(|r| r.dataset == ds).cloned().collect();
        if sub.is_empty() {
            continue;
        }
        let agg = runner::aggregate(&sub, rt_reference);
        let rt_items: Vec<(String, f64)> = agg.iter().map(|a| (a.0.clone(), a.1)).collect();
        let dro_items: Vec<(String, f64)> = agg.iter().map(|a| (a.0.clone(), a.3)).collect();
        println!("{}", emit::bar_chart(&format!("Fig: RT % — {ds}"), &rt_items, 40));
        println!("{}", emit::bar_chart(&format!("Fig: dRO % — {ds}"), &dro_items, 40));
    }
}

fn main() {
    let small: Vec<&str> = synth::small_scale_names();
    let csv = Path::new("bench_out/records_small.csv");
    let recs = match bench_util::load_records_csv(csv) {
        Some(r) => {
            eprintln!("[table5_6] reusing {} records from {}", r.len(), csv.display());
            r
        }
        None => {
            let scale = bench_util::env_scale(0.25);
            let ks = bench_util::env_ks(&[10, 50]);
            let reps = bench_util::env_reps(1);
            let recs = runner::run_grid(
                &small,
                &ks,
                reps,
                &MethodSpec::table3_grid(),
                scale,
                Metric::L1,
                0xAAA1,
                bench_util::env_threads(1),
                |r| eprintln!("  {} k={} {:<18} {:.3}s", r.dataset, r.k, r.method, r.seconds),
            )
            .expect("grid");
            emit::write_records_csv(csv, &recs).unwrap();
            recs
        }
    };
    per_dataset_tables(&recs, &small, "FasterPAM", "small");
    println!(
        "paper reference (Tables 5/6): OneBatch-nniw RT 7-34%, dRO 1.4-2.4%;\n\
         BanditPAM++ RT 700-5400%; FasterCLARA-5 RT ~2-7% with dRO 9-16%."
    );
}
