//! Regenerates paper **Tables 7 & 8** (per-dataset large-scale RT and
//! ΔRO) and the data behind **Figures 7-11**.  RT is normalised by
//! OneBatch-nniw (= 100), as in the paper.

use obpam::data::synth;
use obpam::dissim::Metric;
use obpam::harness::{bench_util, emit, methods::MethodSpec, runner};
use std::path::Path;

fn main() {
    let large: Vec<&str> = synth::large_scale_names();
    let csv = Path::new("bench_out/records_large.csv");
    let recs = match bench_util::load_records_csv(csv) {
        Some(r) => {
            eprintln!("[table7_8] reusing {} records from {}", r.len(), csv.display());
            r
        }
        None => {
            let scale = bench_util::env_scale(0.25) * 0.2;
            let ks = bench_util::env_ks(&[10, 50]);
            let reps = bench_util::env_reps(1);
            let recs = runner::run_grid(
                &large,
                &ks,
                reps,
                &MethodSpec::table3_grid(),
                scale,
                Metric::L1,
                0xAAA1,
                bench_util::env_threads(1),
                |r| eprintln!("  {} k={} {:<18} {:.3}s", r.dataset, r.k, r.method, r.seconds),
            )
            .expect("grid");
            emit::write_records_csv(csv, &recs).unwrap();
            recs
        }
    };

    let order = MethodSpec::table3_grid();
    for want in ["RT", "dRO"] {
        let mut rows = Vec::new();
        for m in &order {
            if !m.feasible_large_scale() {
                continue; // paper omits Na rows in Tables 7/8
            }
            let mut cells = Vec::new();
            for &ds in &large {
                let sub: Vec<runner::Record> =
                    recs.iter().filter(|r| r.dataset == ds).cloned().collect();
                let agg = runner::aggregate(&sub, "OneBatch-nniw");
                let cell = agg
                    .iter()
                    .find(|a| a.0 == m.label())
                    .map(|(_, rt_m, rt_s, dro_m, dro_s)| {
                        if want == "RT" {
                            emit::pct(*rt_m, *rt_s)
                        } else {
                            emit::pct(*dro_m, *dro_s)
                        }
                    })
                    .unwrap_or_else(|| "Na".into());
                cells.push(cell);
            }
            rows.push((m.label(), cells));
        }
        println!("{}", emit::render_table(&format!("{want} per dataset (large)"), &large, &rows));
        let csv_rows: Vec<Vec<String>> = rows
            .iter()
            .map(|(m, c)| {
                let mut row = vec![m.clone()];
                row.extend(c.clone());
                row
            })
            .collect();
        emit::write_csv(
            Path::new(&format!("bench_out/table_large_{want}.csv")),
            &format!("method,{}", large.join(",")),
            &csv_rows,
        )
        .unwrap();
    }

    // Figures 7-11: bars
    for &ds in &large {
        let sub: Vec<runner::Record> = recs.iter().filter(|r| r.dataset == ds).cloned().collect();
        if sub.is_empty() {
            continue;
        }
        let agg = runner::aggregate(&sub, "OneBatch-nniw");
        let rt_items: Vec<(String, f64)> = agg.iter().map(|a| (a.0.clone(), a.1)).collect();
        let dro_items: Vec<(String, f64)> = agg.iter().map(|a| (a.0.clone(), a.3)).collect();
        println!("{}", emit::bar_chart(&format!("Fig: RT % — {ds}"), &rt_items, 40));
        println!("{}", emit::bar_chart(&format!("Fig: dRO % — {ds}"), &dro_items, 40));
    }
    println!(
        "paper reference (Tables 7/8): OneBatch-nniw dRO = 0 on every large dataset;\n\
         FasterCLARA-5 RT ~12-20% with dRO 4-11%; kmc2 RT < 1-11% with dRO 9-26%."
    );
}
