//! Empirical validation of **Theorem 1**: the probability that
//! OneBatchPAM returns the *same medoid set* as FasterPAM, as a function
//! of the batch size m.  The theorem predicts agreement with probability
//! >= 1 - delta once `m >= (4 D^2 / Delta^2) log(2 T n / delta)`, i.e.
//! agreement should rise steeply with m at fixed n and need only
//! logarithmically larger m as n grows.
//!
//! Also reports the objective ratio for the non-identical cases — the
//! paper's observation that even when the swap sequences diverge, the
//! returned objective stays within ~2%.

use obpam::backend::NativeBackend;
use obpam::coordinator::engine;
use obpam::coordinator::state::SwapState;
use obpam::data::synth;
use obpam::dissim::{DissimCounter, Metric};
use obpam::eval;
use obpam::harness::{bench_util, emit};
use obpam::linalg::Matrix;
use obpam::rng::Rng;
use std::path::Path;

/// Run the eager engine on the given batch columns from a SHARED random
/// init, so OneBatch and FasterPAM are compared per Theorem 1's setting.
fn run_engine(x: &Matrix, batch_idx: &[usize], k: usize, seed: u64) -> Vec<usize> {
    let backend = NativeBackend::new(Metric::L1);
    let b = x.select_rows(batch_idx);
    let d = obpam::dissim::cross_matrix(backend.dissim(), x, &b);
    let mut rng = Rng::new(seed);
    let med = rng.sample_distinct(x.rows, k);
    let mut st = SwapState::init(&d, med, vec![1.0; batch_idx.len()], x.rows);
    let counters = obpam::telemetry::Counters::default();
    // deterministic candidate order shared across runs: reseed
    let mut order_rng = Rng::new(seed ^ 0x0DDE);
    engine::eager_loop(&d, &mut st, 50, &mut order_rng, &counters);
    let mut m = st.med.clone();
    m.sort_unstable();
    m
}

fn main() {
    let n = bench_util::env_list("OBPAM_T1_N", &[600])[0];
    let k = 4;
    let trials = bench_util::env_reps(20);
    let x = synth::generate(&format!("blobs_{n}_6_4"), 1.0, 0x7731).x;
    let eval_d = DissimCounter::new(Metric::L1);

    let ms: Vec<usize> = [0.05, 0.1, 0.2, 0.4, 0.7, 1.0]
        .iter()
        .map(|f| ((n as f64 * f) as usize).max(k + 1))
        .collect();

    let mut rows = Vec::new();
    let mut csv = Vec::new();
    for &m in &ms {
        let mut same = 0usize;
        let mut ratio_sum = 0.0f64;
        for t in 0..trials {
            let seed = 0x5111 + t as u64;
            // FasterPAM = engine on ALL columns; OneBatch = engine on m
            let full: Vec<usize> = (0..n).collect();
            let fp = run_engine(&x, &full, k, seed);
            let mut rng = Rng::new(seed ^ 0xBA7C);
            let batch = rng.sample_distinct(n, m);
            let ob = run_engine(&x, &batch, k, seed);
            if fp == ob {
                same += 1;
            }
            let o_fp = eval::objective(&x, &fp, &eval_d);
            let o_ob = eval::objective(&x, &ob, &eval_d);
            ratio_sum += o_ob / o_fp;
        }
        let p = same as f64 / trials as f64;
        let ratio = ratio_sum / trials as f64;
        rows.push((
            format!("m={m} ({}% of n)", m * 100 / n),
            vec![format!("{p:.2}"), format!("{:+.2}%", (ratio - 1.0) * 100.0)],
        ));
        csv.push(vec![m.to_string(), format!("{p:.3}"), format!("{ratio:.5}")]);
        eprintln!("  m={m}: P(same medoids)={p:.2} mean objective ratio={ratio:.4}");
    }
    println!(
        "{}",
        emit::render_table(
            &format!("Theorem 1 check: n={n} k={k}, {trials} trials"),
            &["P(same)", "mean dRO vs FasterPAM"],
            &rows
        )
    );
    emit::write_csv(Path::new("bench_out/theorem1.csv"), "m,p_same,obj_ratio", &csv).unwrap();
    println!(
        "expected: P(same) increases with m toward 1.0 at m=n, and the\n\
         objective penalty stays small (~<2%) even where medoid sets differ\n\
         (paper, Discussion: 'OneBatchPAM provides close objectives...')."
    );
}
