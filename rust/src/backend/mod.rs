//! Compute backends: the same four tile ops (pairwise / top2 / gains /
//! argmin) on either the pure-Rust native path or the AOT-XLA path.
//!
//! Every algorithm in the crate is written against [`ComputeBackend`], so
//! XLA-vs-native is a runtime switch and numeric agreement is testable
//! (rust/tests/xla_native_agreement.rs).

mod native;
#[cfg(feature = "xla")]
mod xla_backend;

pub use native::NativeBackend;
#[cfg(feature = "xla")]
pub use xla_backend::XlaBackend;

use crate::dissim::{ComputeProfile, Metric};
use crate::linalg::Matrix;
use crate::telemetry::Counters;
use anyhow::Result;
use std::sync::Arc;

/// Row-wise nearest/second-nearest cache: (near, dnear, sec, dsec).
pub type Top2 = (Vec<usize>, Vec<f32>, Vec<usize>, Vec<f32>);

/// The tile operations the coordinator needs.
pub trait ComputeBackend {
    /// Backend name for logs/benches ("native", "xla", "xla-dense").
    fn name(&self) -> &'static str;

    /// Metric this backend evaluates.
    fn metric(&self) -> Metric;

    /// Kernel profile this backend computes with ([`ComputeProfile::Exact`]
    /// unless the backend opts into the fast path).
    fn profile(&self) -> ComputeProfile {
        ComputeProfile::Exact
    }

    /// Telemetry counters (dissim computations etc.).
    fn counters(&self) -> Arc<Counters>;

    /// `rows(x) x rows(b)` distance matrix.
    fn pairwise(&self, x: &Matrix, b: &Matrix) -> Result<Matrix>;

    /// Fused `pairwise` + per-row argmin in one sweep: the distance
    /// matrix and `(argmin, min)` per row, reduced while each output
    /// row is cache-hot.  MUST be bit-identical to the default
    /// composition at any thread count (rust/tests/parallel_equivalence.rs).
    fn pairwise_argmin(&self, x: &Matrix, b: &Matrix) -> Result<(Matrix, Vec<usize>, Vec<f32>)> {
        let d = self.pairwise(x, b)?;
        let (idx, val) = self.argmin_rows(&d)?;
        Ok((d, idx, val))
    }

    /// Fused `pairwise` + per-row top-2 in one sweep (`rows(b) >= 2`).
    /// Same bit-identity obligation as [`ComputeBackend::pairwise_argmin`].
    fn pairwise_top2(&self, x: &Matrix, b: &Matrix) -> Result<(Matrix, Top2)> {
        let d = self.pairwise(x, b)?;
        let t = self.top2(&d)?;
        Ok((d, t))
    }

    /// Row-wise two smallest over an `(n, k)` matrix (k >= 2).
    fn top2(&self, d: &Matrix) -> Result<Top2>;

    /// FasterPAM gain components for all candidate rows of `d`:
    /// `(shared (n,), permedoid (n, k))` — see kernels/ref.py:swap_gains.
    fn gains(
        &self,
        d: &Matrix,
        dnear: &[f32],
        dsec: &[f32],
        near: &[usize],
        k: usize,
        w: &[f32],
    ) -> Result<(Vec<f32>, Matrix)>;

    /// Row-wise (argmin, min) over an `(n, m)` matrix.
    fn argmin_rows(&self, d: &Matrix) -> Result<(Vec<usize>, Vec<f32>)>;
}

/// Nearest-medoid assignment: for every row of `points`, the index of
/// the closest row of `medoids` and the distance to it — one fused
/// `pairwise_argmin` sweep, `O(k p)` per point with no dataset
/// resident and no post-hoc rewalk of the `q x k` matrix.  This is the
/// offline form of the server's `assign` wire verb (a model holds only
/// its `k x p` medoid rows); the online form is fully matrix-free
/// (`server::models::AssignScratch`).
pub fn assign(
    backend: &dyn ComputeBackend,
    points: &Matrix,
    medoids: &Matrix,
) -> Result<(Vec<usize>, Vec<f32>)> {
    anyhow::ensure!(
        points.cols == medoids.cols,
        "assign dimension mismatch: points have {} features, medoids {}",
        points.cols,
        medoids.cols
    );
    let (_, idx, val) = backend.pairwise_argmin(points, medoids)?;
    Ok((idx, val))
}

/// [`assign`] with the second-nearest medoid as well (`top2=1` on the
/// wire): `(near, dnear, second, dsecond)` per point, one fused
/// `pairwise_top2` sweep.  Needs `k >= 2` medoid rows — the same bound
/// the `top2` tile op requires.
pub fn assign_top2(backend: &dyn ComputeBackend, points: &Matrix, medoids: &Matrix) -> Result<Top2> {
    anyhow::ensure!(
        points.cols == medoids.cols,
        "assign dimension mismatch: points have {} features, medoids {}",
        points.cols,
        medoids.cols
    );
    anyhow::ensure!(medoids.rows >= 2, "top2 assignment needs >= 2 medoids (got {})", medoids.rows);
    let (_, t) = backend.pairwise_top2(points, medoids)?;
    Ok(t)
}

/// Candidate-independent removal-loss term (gain form):
/// `rloss[l] = sum_j w_j (dnear_j - dsec_j) [near_j == l]`.
///
/// Cheap (`O(m)`), identical for both backends, computed on the Rust side.
pub fn removal_loss(dnear: &[f32], dsec: &[f32], near: &[usize], k: usize, w: &[f32]) -> Vec<f32> {
    let mut rl = vec![0.0f32; k];
    for j in 0..near.len() {
        rl[near[j]] += w[j] * (dnear[j] - dsec[j]);
    }
    rl
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn removal_loss_known() {
        let rl = removal_loss(&[1.0, 2.0], &[3.0, 5.0], &[0, 1], 2, &[1.0, 2.0]);
        assert_eq!(rl, vec![-2.0, -6.0]);
    }

    #[test]
    fn assign_picks_the_nearest_medoid() {
        let backend = NativeBackend::new(Metric::L1);
        let medoids = Matrix::from_vec(2, 2, vec![0.0, 0.0, 10.0, 10.0]);
        let points = Matrix::from_vec(3, 2, vec![1.0, 0.0, 9.0, 9.0, 4.0, 4.0]);
        let (labels, dists) = assign(&backend, &points, &medoids).unwrap();
        assert_eq!(labels, vec![0, 1, 0]);
        assert_eq!(dists, vec![1.0, 2.0, 8.0]);
        // the top2 variant agrees on the nearest and adds the runner-up
        let (near, dnear, sec, dsec) = assign_top2(&backend, &points, &medoids).unwrap();
        assert_eq!(near, labels);
        assert_eq!(dnear, dists);
        assert_eq!(sec, vec![1, 0, 1]);
        assert_eq!(dsec, vec![19.0, 18.0, 12.0]);
    }

    /// Delegates the primitive tile ops to native but keeps the trait's
    /// *default* fused impls — pins that the default composition agrees
    /// with the native fused overrides bit-for-bit.
    struct UnfusedShim(NativeBackend);

    impl ComputeBackend for UnfusedShim {
        fn name(&self) -> &'static str {
            "unfused-shim"
        }
        fn metric(&self) -> Metric {
            self.0.metric()
        }
        fn counters(&self) -> Arc<Counters> {
            self.0.counters()
        }
        fn pairwise(&self, x: &Matrix, b: &Matrix) -> Result<Matrix> {
            self.0.pairwise(x, b)
        }
        fn top2(&self, d: &Matrix) -> Result<Top2> {
            self.0.top2(d)
        }
        fn gains(
            &self,
            d: &Matrix,
            dnear: &[f32],
            dsec: &[f32],
            near: &[usize],
            k: usize,
            w: &[f32],
        ) -> Result<(Vec<f32>, Matrix)> {
            self.0.gains(d, dnear, dsec, near, k, w)
        }
        fn argmin_rows(&self, d: &Matrix) -> Result<(Vec<usize>, Vec<f32>)> {
            self.0.argmin_rows(d)
        }
    }

    #[test]
    fn fused_defaults_agree_with_native_overrides() {
        let mut rng = crate::rng::Rng::new(41);
        let points = Matrix::from_vec(37, 6, (0..37 * 6).map(|_| rng.normal() as f32).collect());
        let medoids = Matrix::from_vec(9, 6, (0..9 * 6).map(|_| rng.normal() as f32).collect());
        for metric in [Metric::L1, Metric::L2, Metric::SqL2, Metric::Chebyshev, Metric::Cosine] {
            let fused = NativeBackend::new(metric);
            let shim = UnfusedShim(NativeBackend::new(metric));
            let (da, ia, va) = fused.pairwise_argmin(&points, &medoids).unwrap();
            let (db, ib, vb) = shim.pairwise_argmin(&points, &medoids).unwrap();
            assert_eq!(da.data, db.data, "{metric:?}");
            assert_eq!((ia, va), (ib, vb), "{metric:?}");
            let (ta, (n1, d1, s1, e1)) = fused.pairwise_top2(&points, &medoids).unwrap();
            let (tb, (n2, d2, s2, e2)) = shim.pairwise_top2(&points, &medoids).unwrap();
            assert_eq!(ta.data, tb.data, "{metric:?}");
            assert_eq!((n1, d1, s1, e1), (n2, d2, s2, e2), "{metric:?}");
        }
    }

    #[test]
    fn assign_rejects_dimension_mismatch() {
        let backend = NativeBackend::new(Metric::L1);
        let medoids = Matrix::from_vec(2, 3, vec![0.0; 6]);
        let points = Matrix::from_vec(1, 2, vec![1.0, 2.0]);
        let err = assign(&backend, &points, &medoids).unwrap_err().to_string();
        assert!(err.contains("dimension mismatch"), "{err}");
        let one_medoid = Matrix::from_vec(1, 2, vec![0.0, 0.0]);
        let err = assign_top2(&backend, &points, &one_medoid).unwrap_err().to_string();
        assert!(err.contains(">= 2 medoids"), "{err}");
    }
}
