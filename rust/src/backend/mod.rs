//! Compute backends: the same four tile ops (pairwise / top2 / gains /
//! argmin) on either the pure-Rust native path or the AOT-XLA path.
//!
//! Every algorithm in the crate is written against [`ComputeBackend`], so
//! XLA-vs-native is a runtime switch and numeric agreement is testable
//! (rust/tests/xla_native_agreement.rs).

mod native;
#[cfg(feature = "xla")]
mod xla_backend;

pub use native::NativeBackend;
#[cfg(feature = "xla")]
pub use xla_backend::XlaBackend;

use crate::dissim::Metric;
use crate::linalg::Matrix;
use crate::telemetry::Counters;
use anyhow::Result;
use std::sync::Arc;

/// Row-wise nearest/second-nearest cache: (near, dnear, sec, dsec).
pub type Top2 = (Vec<usize>, Vec<f32>, Vec<usize>, Vec<f32>);

/// The tile operations the coordinator needs.
pub trait ComputeBackend {
    /// Backend name for logs/benches ("native", "xla", "xla-dense").
    fn name(&self) -> &'static str;

    /// Metric this backend evaluates.
    fn metric(&self) -> Metric;

    /// Telemetry counters (dissim computations etc.).
    fn counters(&self) -> Arc<Counters>;

    /// `rows(x) x rows(b)` distance matrix.
    fn pairwise(&self, x: &Matrix, b: &Matrix) -> Result<Matrix>;

    /// Row-wise two smallest over an `(n, k)` matrix (k >= 2).
    fn top2(&self, d: &Matrix) -> Result<Top2>;

    /// FasterPAM gain components for all candidate rows of `d`:
    /// `(shared (n,), permedoid (n, k))` — see kernels/ref.py:swap_gains.
    fn gains(
        &self,
        d: &Matrix,
        dnear: &[f32],
        dsec: &[f32],
        near: &[usize],
        k: usize,
        w: &[f32],
    ) -> Result<(Vec<f32>, Matrix)>;

    /// Row-wise (argmin, min) over an `(n, m)` matrix.
    fn argmin_rows(&self, d: &Matrix) -> Result<(Vec<usize>, Vec<f32>)>;
}

/// Candidate-independent removal-loss term (gain form):
/// `rloss[l] = sum_j w_j (dnear_j - dsec_j) [near_j == l]`.
///
/// Cheap (`O(m)`), identical for both backends, computed on the Rust side.
pub fn removal_loss(dnear: &[f32], dsec: &[f32], near: &[usize], k: usize, w: &[f32]) -> Vec<f32> {
    let mut rl = vec![0.0f32; k];
    for j in 0..near.len() {
        rl[near[j]] += w[j] * (dnear[j] - dsec[j]);
    }
    rl
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn removal_loss_known() {
        let rl = removal_loss(&[1.0, 2.0], &[3.0, 5.0], &[0, 1], 2, &[1.0, 2.0]);
        assert_eq!(rl, vec![-2.0, -6.0]);
    }
}
