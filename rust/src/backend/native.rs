//! Pure-Rust compute backend.
//!
//! The fast path on this (single-core CPU) testbed and the reference the
//! XLA path is checked against.  Hot loops are branch-light and
//! allocation-free; the pairwise matrix is cache-blocked (see
//! dissim::cross_matrix).

use super::{ComputeBackend, Top2};
use crate::dissim::{cross_matrix, DissimCounter, Metric};
use crate::linalg::{top2_min, Matrix};
use crate::telemetry::Counters;
use anyhow::Result;
use std::sync::Arc;

/// Pure-Rust backend over a counted dissimilarity.
#[derive(Clone)]
pub struct NativeBackend {
    dissim: DissimCounter,
}

impl NativeBackend {
    /// Backend for `metric` with fresh counters.
    pub fn new(metric: Metric) -> Self {
        NativeBackend { dissim: DissimCounter::new(metric) }
    }

    /// Backend sharing existing counters.
    pub fn with_counters(metric: Metric, counters: Arc<Counters>) -> Self {
        NativeBackend { dissim: DissimCounter::with_counters(metric, counters) }
    }

    /// The underlying counted dissimilarity (for point-level algorithms).
    pub fn dissim(&self) -> &DissimCounter {
        &self.dissim
    }
}

impl ComputeBackend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn metric(&self) -> Metric {
        self.dissim.metric
    }

    fn counters(&self) -> Arc<Counters> {
        self.dissim.counters()
    }

    fn pairwise(&self, x: &Matrix, b: &Matrix) -> Result<Matrix> {
        Ok(cross_matrix(&self.dissim, x, b))
    }

    fn top2(&self, d: &Matrix) -> Result<Top2> {
        let n = d.rows;
        let (mut ni, mut nd) = (vec![0usize; n], vec![0f32; n]);
        let (mut si, mut sd) = (vec![0usize; n], vec![0f32; n]);
        for i in 0..n {
            let (a, av, b, bv) = top2_min(d.row(i));
            ni[i] = a;
            nd[i] = av;
            si[i] = b;
            sd[i] = bv;
        }
        Ok((ni, nd, si, sd))
    }

    fn gains(
        &self,
        d: &Matrix,
        dnear: &[f32],
        dsec: &[f32],
        near: &[usize],
        k: usize,
        w: &[f32],
    ) -> Result<(Vec<f32>, Matrix)> {
        let (n, m) = (d.rows, d.cols);
        let mut shared = vec![0.0f32; n];
        let mut permedoid = Matrix::zeros(n, k);
        for i in 0..n {
            let row = d.row(i);
            let pm = permedoid.row_mut(i);
            let mut sh = 0.0f32;
            for j in 0..m {
                let dij = row[j];
                // branchless-ish: both branches touch pm[near[j]]
                if dij < dnear[j] {
                    sh += w[j] * (dnear[j] - dij);
                    pm[near[j]] += w[j] * (dsec[j] - dnear[j]);
                } else if dij < dsec[j] {
                    pm[near[j]] += w[j] * (dsec[j] - dij);
                }
            }
            shared[i] = sh;
        }
        Ok((shared, permedoid))
    }

    fn argmin_rows(&self, d: &Matrix) -> Result<(Vec<usize>, Vec<f32>)> {
        let n = d.rows;
        let (mut idx, mut val) = (vec![0usize; n], vec![0f32; n]);
        for i in 0..n {
            let (j, v) = crate::linalg::argmin(d.row(i));
            idx[i] = j;
            val[i] = v;
        }
        Ok((idx, val))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn rand_matrix(rng: &mut Rng, r: usize, c: usize) -> Matrix {
        Matrix::from_vec(r, c, (0..r * c).map(|_| rng.f32()).collect())
    }

    #[test]
    fn top2_matches_manual() {
        let b = NativeBackend::new(Metric::L1);
        let d = Matrix::from_vec(2, 3, vec![3., 1., 2., 0.5, 0.5, 0.1]);
        let (ni, nd, si, sd) = b.top2(&d).unwrap();
        assert_eq!((ni[0], nd[0], si[0], sd[0]), (1, 1.0, 2, 2.0));
        assert_eq!((ni[1], si[1]), (2, 0)); // tie 0.5 breaks low index for sec
        assert_eq!(sd[1], 0.5);
    }

    #[test]
    fn gains_match_bruteforce_objective_delta() {
        // The decomposition invariant: shared + permedoid + removal_loss
        // equals the exact batch-objective delta of the swap.
        let mut rng = Rng::new(13);
        let backend = NativeBackend::new(Metric::L1);
        let (n, m, k, p) = (20, 9, 3, 4);
        let x = rand_matrix(&mut rng, n, p);
        let bidx: Vec<usize> = rng.sample_distinct(n, m);
        let b = x.select_rows(&bidx);
        let d = backend.pairwise(&x, &b).unwrap();
        let med: Vec<usize> = rng.sample_distinct(n, k);
        let w = vec![1.0f32; m];

        // caches from medoid rows of d
        let mut dmk = Matrix::zeros(m, k);
        for (l, &mi) in med.iter().enumerate() {
            for j in 0..m {
                dmk.set(j, l, d.get(mi, j));
            }
        }
        let (near, dnear, _, dsec) = backend.top2(&dmk).unwrap();
        let (shared, pm) = backend.gains(&d, &dnear, &dsec, &near, k, &w).unwrap();
        let rl = super::super::removal_loss(&dnear, &dsec, &near, k, &w);

        let batch_obj = |meds: &[usize]| -> f32 {
            (0..m)
                .map(|j| meds.iter().map(|&mi| d.get(mi, j)).fold(f32::INFINITY, f32::min))
                .sum()
        };
        let base = batch_obj(&med);
        for i in 0..n {
            if med.contains(&i) {
                continue;
            }
            for l in 0..k {
                let mut sw = med.clone();
                sw[l] = i;
                let true_gain = base - batch_obj(&sw);
                let pred = shared[i] + pm.get(i, l) + rl[l];
                assert!(
                    (true_gain - pred).abs() < 1e-3,
                    "i={i} l={l}: pred {pred} vs true {true_gain}"
                );
            }
        }
    }

    #[test]
    fn argmin_rows_basic() {
        let b = NativeBackend::new(Metric::L1);
        let d = Matrix::from_vec(2, 3, vec![3., 1., 2., 0.1, 0.5, 0.2]);
        let (idx, val) = b.argmin_rows(&d).unwrap();
        assert_eq!(idx, vec![1, 0]);
        assert_eq!(val, vec![1.0, 0.1]);
    }

    #[test]
    fn pairwise_counts_dissims() {
        let b = NativeBackend::new(Metric::L1);
        let mut rng = Rng::new(5);
        let x = rand_matrix(&mut rng, 10, 3);
        let y = rand_matrix(&mut rng, 7, 3);
        b.pairwise(&x, &y).unwrap();
        assert_eq!(b.counters().dissim(), 70);
    }
}
