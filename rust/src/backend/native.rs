//! Pure-Rust compute backend.
//!
//! The CPU fast path and the reference the XLA path is checked against.
//! Hot loops are branch-light and allocation-free; the pairwise matrix
//! is cache-blocked (see dissim::cross_matrix) and every tile op is
//! row-partitioned across the backend's [`Pool`] of persistent workers
//! (one backend runs many tile ops on one reused pool) — results are
//! bit-identical at any thread count because rows are independent and
//! chunk stitching preserves row order.

use super::{ComputeBackend, Top2};
use crate::dissim::{
    cross_argmin_pool, cross_matrix_pool_profiled, cross_top2_pool, ComputeProfile, DissimCounter,
    Metric,
};
use crate::linalg::{top2_min, Matrix};
use crate::runtime::Pool;
use crate::telemetry::Counters;
use anyhow::Result;
use std::sync::Arc;

/// Pure-Rust backend over a counted dissimilarity.
#[derive(Clone)]
pub struct NativeBackend {
    dissim: DissimCounter,
    pool: Pool,
    profile: ComputeProfile,
}

impl NativeBackend {
    /// Serial backend for `metric` with fresh counters (the pre-parallel
    /// default; use [`NativeBackend::with_pool`] to enable threading).
    pub fn new(metric: Metric) -> Self {
        NativeBackend {
            dissim: DissimCounter::new(metric),
            pool: Pool::serial(),
            profile: ComputeProfile::Exact,
        }
    }

    /// Backend for `metric` running its tile ops on `pool`.
    pub fn with_pool(metric: Metric, pool: Pool) -> Self {
        NativeBackend { dissim: DissimCounter::new(metric), pool, profile: ComputeProfile::Exact }
    }

    /// Serial backend sharing existing counters.
    pub fn with_counters(metric: Metric, counters: Arc<Counters>) -> Self {
        NativeBackend {
            dissim: DissimCounter::with_counters(metric, counters),
            pool: Pool::serial(),
            profile: ComputeProfile::Exact,
        }
    }

    /// Backend sharing existing counters and running on `pool`.
    pub fn with_counters_and_pool(metric: Metric, counters: Arc<Counters>, pool: Pool) -> Self {
        NativeBackend {
            dissim: DissimCounter::with_counters(metric, counters),
            pool,
            profile: ComputeProfile::Exact,
        }
    }

    /// Builder: switch this backend to `profile` (kernels stay
    /// bit-identical at any thread count *within* a profile).
    pub fn with_profile(mut self, profile: ComputeProfile) -> Self {
        self.profile = profile;
        self
    }

    /// The underlying counted dissimilarity (for point-level algorithms).
    pub fn dissim(&self) -> &DissimCounter {
        &self.dissim
    }

    /// The thread pool driving this backend's tile ops.
    pub fn pool(&self) -> &Pool {
        &self.pool
    }
}

impl ComputeBackend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn metric(&self) -> Metric {
        self.dissim.metric
    }

    fn profile(&self) -> ComputeProfile {
        self.profile
    }

    fn counters(&self) -> Arc<Counters> {
        self.dissim.counters()
    }

    fn pairwise(&self, x: &Matrix, b: &Matrix) -> Result<Matrix> {
        Ok(cross_matrix_pool_profiled(&self.dissim, x, b, &self.pool, self.profile))
    }

    fn pairwise_argmin(&self, x: &Matrix, b: &Matrix) -> Result<(Matrix, Vec<usize>, Vec<f32>)> {
        Ok(cross_argmin_pool(&self.dissim, x, b, &self.pool, self.profile))
    }

    fn pairwise_top2(&self, x: &Matrix, b: &Matrix) -> Result<(Matrix, Top2)> {
        let (d, near, dnear, sec, dsec) =
            cross_top2_pool(&self.dissim, x, b, &self.pool, self.profile);
        Ok((d, (near, dnear, sec, dsec)))
    }

    fn top2(&self, d: &Matrix) -> Result<Top2> {
        let n = d.rows;
        let mut parts = self.pool.map_ranges(n, |r| {
            let len = r.end - r.start;
            let (mut ni, mut nd) = (Vec::with_capacity(len), Vec::with_capacity(len));
            let (mut si, mut sd) = (Vec::with_capacity(len), Vec::with_capacity(len));
            for i in r {
                let (a, av, b, bv) = top2_min(d.row(i));
                ni.push(a);
                nd.push(av);
                si.push(b);
                sd.push(bv);
            }
            (ni, nd, si, sd)
        });
        if parts.len() == 1 {
            // serial path: the single part is already the full answer
            return Ok(parts.pop().expect("one part"));
        }
        let (mut ni, mut nd) = (Vec::with_capacity(n), Vec::with_capacity(n));
        let (mut si, mut sd) = (Vec::with_capacity(n), Vec::with_capacity(n));
        for (a, b, c, e) in parts {
            ni.extend(a);
            nd.extend(b);
            si.extend(c);
            sd.extend(e);
        }
        Ok((ni, nd, si, sd))
    }

    fn gains(
        &self,
        d: &Matrix,
        dnear: &[f32],
        dsec: &[f32],
        near: &[usize],
        k: usize,
        w: &[f32],
    ) -> Result<(Vec<f32>, Matrix)> {
        let (n, m) = (d.rows, d.cols);
        // Row i touches only shared[i] and permedoid row i, so the scan
        // partitions cleanly; per-row accumulation order is unchanged.
        let mut parts = self.pool.map_ranges(n, |r| {
            let len = r.end - r.start;
            let mut shared = Vec::with_capacity(len);
            let mut permedoid = vec![0.0f32; len * k];
            for (di, i) in r.enumerate() {
                let row = d.row(i);
                let pm = &mut permedoid[di * k..(di + 1) * k];
                let mut sh = 0.0f32;
                for j in 0..m {
                    let dij = row[j];
                    // branchless-ish: both branches touch pm[near[j]]
                    if dij < dnear[j] {
                        sh += w[j] * (dnear[j] - dij);
                        pm[near[j]] += w[j] * (dsec[j] - dnear[j]);
                    } else if dij < dsec[j] {
                        pm[near[j]] += w[j] * (dsec[j] - dij);
                    }
                }
                shared.push(sh);
            }
            (shared, permedoid)
        });
        if parts.len() == 1 {
            let (shared, pm_data) = parts.pop().expect("one part");
            return Ok((shared, Matrix::from_vec(n, k, pm_data)));
        }
        let mut shared = Vec::with_capacity(n);
        let mut pm_data = Vec::with_capacity(n * k);
        for (sh, pm) in parts {
            shared.extend(sh);
            pm_data.extend(pm);
        }
        Ok((shared, Matrix::from_vec(n, k, pm_data)))
    }

    fn argmin_rows(&self, d: &Matrix) -> Result<(Vec<usize>, Vec<f32>)> {
        let n = d.rows;
        let mut parts = self.pool.map_ranges(n, |r| {
            let len = r.end - r.start;
            let (mut idx, mut val) = (Vec::with_capacity(len), Vec::with_capacity(len));
            for i in r {
                let (j, v) = crate::linalg::argmin(d.row(i));
                idx.push(j);
                val.push(v);
            }
            (idx, val)
        });
        if parts.len() == 1 {
            return Ok(parts.pop().expect("one part"));
        }
        let (mut idx, mut val) = (Vec::with_capacity(n), Vec::with_capacity(n));
        for (a, b) in parts {
            idx.extend(a);
            val.extend(b);
        }
        Ok((idx, val))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn rand_matrix(rng: &mut Rng, r: usize, c: usize) -> Matrix {
        Matrix::from_vec(r, c, (0..r * c).map(|_| rng.f32()).collect())
    }

    #[test]
    fn top2_matches_manual() {
        let b = NativeBackend::new(Metric::L1);
        let d = Matrix::from_vec(2, 3, vec![3., 1., 2., 0.5, 0.5, 0.1]);
        let (ni, nd, si, sd) = b.top2(&d).unwrap();
        assert_eq!((ni[0], nd[0], si[0], sd[0]), (1, 1.0, 2, 2.0));
        assert_eq!((ni[1], si[1]), (2, 0)); // tie 0.5 breaks low index for sec
        assert_eq!(sd[1], 0.5);
    }

    #[test]
    fn gains_match_bruteforce_objective_delta() {
        // The decomposition invariant: shared + permedoid + removal_loss
        // equals the exact batch-objective delta of the swap.
        let mut rng = Rng::new(13);
        let backend = NativeBackend::new(Metric::L1);
        let (n, m, k, p) = (20, 9, 3, 4);
        let x = rand_matrix(&mut rng, n, p);
        let bidx: Vec<usize> = rng.sample_distinct(n, m);
        let b = x.select_rows(&bidx);
        let d = backend.pairwise(&x, &b).unwrap();
        let med: Vec<usize> = rng.sample_distinct(n, k);
        let w = vec![1.0f32; m];

        // caches from medoid rows of d
        let mut dmk = Matrix::zeros(m, k);
        for (l, &mi) in med.iter().enumerate() {
            for j in 0..m {
                dmk.set(j, l, d.get(mi, j));
            }
        }
        let (near, dnear, _, dsec) = backend.top2(&dmk).unwrap();
        let (shared, pm) = backend.gains(&d, &dnear, &dsec, &near, k, &w).unwrap();
        let rl = super::super::removal_loss(&dnear, &dsec, &near, k, &w);

        let batch_obj = |meds: &[usize]| -> f32 {
            (0..m)
                .map(|j| meds.iter().map(|&mi| d.get(mi, j)).fold(f32::INFINITY, f32::min))
                .sum()
        };
        let base = batch_obj(&med);
        for i in 0..n {
            if med.contains(&i) {
                continue;
            }
            for l in 0..k {
                let mut sw = med.clone();
                sw[l] = i;
                let true_gain = base - batch_obj(&sw);
                let pred = shared[i] + pm.get(i, l) + rl[l];
                assert!(
                    (true_gain - pred).abs() < 1e-3,
                    "i={i} l={l}: pred {pred} vs true {true_gain}"
                );
            }
        }
    }

    #[test]
    fn argmin_rows_basic() {
        let b = NativeBackend::new(Metric::L1);
        let d = Matrix::from_vec(2, 3, vec![3., 1., 2., 0.1, 0.5, 0.2]);
        let (idx, val) = b.argmin_rows(&d).unwrap();
        assert_eq!(idx, vec![1, 0]);
        assert_eq!(val, vec![1.0, 0.1]);
    }

    #[test]
    fn pairwise_counts_dissims() {
        let b = NativeBackend::new(Metric::L1);
        let mut rng = Rng::new(5);
        let x = rand_matrix(&mut rng, 10, 3);
        let y = rand_matrix(&mut rng, 7, 3);
        b.pairwise(&x, &y).unwrap();
        assert_eq!(b.counters().dissim(), 70);
    }

    #[test]
    fn tile_ops_identical_across_thread_counts() {
        let mut rng = Rng::new(77);
        let (n, m, k) = (137, 33, 7);
        let d = rand_matrix(&mut rng, n, m);
        let dmk = rand_matrix(&mut rng, m, k);
        let dn: Vec<f32> = (0..m).map(|_| rng.f32()).collect();
        let ds: Vec<f32> = dn.iter().map(|v| v + 0.2).collect();
        let near: Vec<usize> = (0..m).map(|_| rng.below(k)).collect();
        let w: Vec<f32> = (0..m).map(|_| 1.0 + rng.f32()).collect();

        let serial = NativeBackend::new(Metric::L1);
        let (ni, nd, si, sd) = serial.top2(&dmk).unwrap();
        let (am, av) = serial.argmin_rows(&d).unwrap();
        let (sh, pm) = serial.gains(&d, &dn, &ds, &near, k, &w).unwrap();
        let batch = rand_matrix(&mut rng, 9, m);
        let (fm, fi, fv) = serial.pairwise_argmin(&d, &batch).unwrap();
        let (tm, (t1, td1, t2, td2)) = serial.pairwise_top2(&d, &batch).unwrap();
        for threads in [2, 3, 4] {
            let par = NativeBackend::with_pool(Metric::L1, Pool::new(threads));
            let (ni2, nd2, si2, sd2) = par.top2(&dmk).unwrap();
            assert_eq!((ni2, nd2, si2, sd2), (ni.clone(), nd.clone(), si.clone(), sd.clone()));
            let (am2, av2) = par.argmin_rows(&d).unwrap();
            assert_eq!((am2, av2), (am.clone(), av.clone()));
            let (sh2, pm2) = par.gains(&d, &dn, &ds, &near, k, &w).unwrap();
            assert_eq!(sh2, sh, "shared gains differ at {threads} threads");
            assert_eq!(pm2.data, pm.data, "permedoid gains differ at {threads} threads");
            let (fm2, fi2, fv2) = par.pairwise_argmin(&d, &batch).unwrap();
            assert_eq!(fm2.data, fm.data, "fused argmin matrix differs at {threads} threads");
            assert_eq!((fi2, fv2), (fi.clone(), fv.clone()));
            let (tm2, (u1, ud1, u2, ud2)) = par.pairwise_top2(&d, &batch).unwrap();
            assert_eq!(tm2.data, tm.data, "fused top2 matrix differs at {threads} threads");
            assert_eq!(
                (u1, ud1, u2, ud2),
                (t1.clone(), td1.clone(), t2.clone(), td2.clone())
            );
        }
    }

    /// Property: fused ops ≡ `pairwise` ∘ `argmin_rows`/`top2` for every
    /// metric, both profiles, degenerate shapes (m<8 fallback, m=1/2,
    /// p=1), and mixed thread counts — the trait contract, randomized.
    #[test]
    fn prop_fused_equals_unfused_composition() {
        let metrics =
            [Metric::L1, Metric::L2, Metric::SqL2, Metric::Chebyshev, Metric::Cosine];
        crate::proptest::run_cases(48, |rng| {
            let metric = metrics[rng.below(metrics.len())];
            let profile =
                if rng.below(2) == 0 { ComputeProfile::Exact } else { ComputeProfile::Fast };
            let threads = [1, 2, 4][rng.below(3)];
            let p = 1 + rng.below(9);
            let n = 1 + rng.below(40);
            // bias toward the degenerate small-batch path half the time
            let m = if rng.below(2) == 0 { 1 + rng.below(6) } else { 8 + rng.below(70) };
            let x = rand_matrix(rng, n, p);
            let b = rand_matrix(rng, m, p);
            let backend =
                NativeBackend::with_pool(metric, Pool::new(threads)).with_profile(profile);

            let want = backend.pairwise(&x, &b).unwrap();
            let (wi, wv) = backend.argmin_rows(&want).unwrap();
            let (got, gi, gv) = backend.pairwise_argmin(&x, &b).unwrap();
            assert_eq!(got.data, want.data, "{metric:?} {profile:?} n={n} m={m} p={p}");
            assert_eq!(gi, wi);
            assert_eq!(gv, wv);

            if m >= 2 {
                let (wn, wdn, ws, wds) = backend.top2(&want).unwrap();
                let (got2, (gn, gdn, gs, gds)) = backend.pairwise_top2(&x, &b).unwrap();
                assert_eq!(got2.data, want.data);
                assert_eq!((gn, gdn, gs, gds), (wn, wdn, ws, wds));
            }
        });
    }

    /// Property: `Fast` agrees with `Exact` within the cancellation-scaled
    /// tolerance on SqL2/L2 and is bit-identical on every other metric.
    #[test]
    fn prop_fast_profile_tolerance() {
        let metrics =
            [Metric::L1, Metric::L2, Metric::SqL2, Metric::Chebyshev, Metric::Cosine];
        crate::proptest::run_cases(32, |rng| {
            let metric = metrics[rng.below(metrics.len())];
            let p = 1 + rng.below(12);
            let n = 1 + rng.below(30);
            let m = 8 + rng.below(80);
            let x = rand_matrix(rng, n, p);
            let b = rand_matrix(rng, m, p);
            let exact = NativeBackend::new(metric).pairwise(&x, &b).unwrap();
            let fast = NativeBackend::new(metric)
                .with_profile(ComputeProfile::Fast)
                .pairwise(&x, &b)
                .unwrap();
            if !matches!(metric, Metric::SqL2 | Metric::L2) {
                assert_eq!(exact.data, fast.data, "{metric:?} must ignore the profile");
                return;
            }
            for i in 0..n {
                let xn: f32 = x.row(i).iter().map(|v| v * v).sum();
                for j in 0..m {
                    let bn: f32 = b.row(j).iter().map(|v| v * v).sum();
                    let scale = 1.0 + xn + bn;
                    let tol = if metric == Metric::L2 { scale.sqrt() } else { scale };
                    assert!(
                        (fast.get(i, j) - exact.get(i, j)).abs() <= 1e-4 * tol,
                        "{metric:?} ({i},{j}): fast={} exact={}",
                        fast.get(i, j),
                        exact.get(i, j)
                    );
                }
            }
        });
    }
}
