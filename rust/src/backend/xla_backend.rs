//! XLA compute backend: delegates the tile ops to AOT artifacts through
//! the PJRT runtime.  The `dense` flag switches between the Pallas
//! kernels and the plain-XLA lowering of the same math (perf ablation).

use super::{ComputeBackend, Top2};
use crate::dissim::Metric;
use crate::linalg::Matrix;
use crate::runtime::Runtime;
use crate::telemetry::Counters;
use anyhow::Result;
use std::rc::Rc;
use std::sync::Arc;

/// Backend executing the AOT HLO artifacts.
#[derive(Clone)]
pub struct XlaBackend {
    runtime: Rc<Runtime>,
    metric: Metric,
    dense: bool,
}

impl XlaBackend {
    /// Wrap a runtime; `dense=false` uses the Pallas kernels.
    pub fn new(runtime: Rc<Runtime>, metric: Metric, dense: bool) -> Self {
        XlaBackend { runtime, metric, dense }
    }

    /// The underlying runtime.
    pub fn runtime(&self) -> &Runtime {
        &self.runtime
    }
}

impl ComputeBackend for XlaBackend {
    fn name(&self) -> &'static str {
        if self.dense {
            "xla-dense"
        } else {
            "xla"
        }
    }

    fn metric(&self) -> Metric {
        self.metric
    }

    fn counters(&self) -> Arc<Counters> {
        self.runtime.counters()
    }

    fn pairwise(&self, x: &Matrix, b: &Matrix) -> Result<Matrix> {
        self.runtime.pairwise(x, b, self.metric, self.dense)
    }

    fn top2(&self, d: &Matrix) -> Result<Top2> {
        self.runtime.top2(d)
    }

    fn gains(
        &self,
        d: &Matrix,
        dnear: &[f32],
        dsec: &[f32],
        near: &[usize],
        k: usize,
        w: &[f32],
    ) -> Result<(Vec<f32>, Matrix)> {
        self.runtime.gains(d, dnear, dsec, near, k, w)
    }

    fn argmin_rows(&self, d: &Matrix) -> Result<(Vec<usize>, Vec<f32>)> {
        self.runtime.argmin_rows(d)
    }
}
