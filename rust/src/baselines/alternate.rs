//! Alternate k-medoids (Park & Jun 2009): k-means-style alternation.
//!
//! Loop until assignments stabilize: (1) assign each point to its nearest
//! medoid, (2) replace each medoid with the member of its cluster that
//! minimizes the within-cluster dissimilarity sum.  Distances are
//! evaluated on demand (no `n x n` storage) but the update step costs
//! `sum_c |c|^2` evaluations per iteration, which is why the paper's
//! Table 3 shows RT > FasterPAM.

use crate::coordinator::KMedoidsResult;
use crate::dissim::DissimCounter;
use crate::linalg::Matrix;
use crate::rng::Rng;
use crate::telemetry::{RunStats, Timer};

/// Run the Alternate algorithm.
pub fn alternate(
    x: &Matrix,
    k: usize,
    max_iter: usize,
    seed: u64,
    d: &DissimCounter,
) -> KMedoidsResult {
    let n = x.rows;
    assert!(k >= 1 && k <= n);
    let timer = Timer::start();
    let count0 = d.count();
    let mut rng = Rng::new(seed);
    let mut med = rng.sample_distinct(n, k);
    let mut assign = vec![0usize; n];
    let mut iterations = 0usize;

    for _ in 0..max_iter {
        iterations += 1;
        // (1) assignment
        let mut changed = false;
        for i in 0..n {
            let mut bl = 0usize;
            let mut bv = f32::INFINITY;
            for (l, &mi) in med.iter().enumerate() {
                let v = d.eval(x.row(i), x.row(mi));
                if v < bv {
                    bv = v;
                    bl = l;
                }
            }
            if assign[i] != bl {
                assign[i] = bl;
                changed = true;
            }
        }
        // (2) medoid update per cluster
        let mut members: Vec<Vec<usize>> = vec![Vec::new(); k];
        for i in 0..n {
            members[assign[i]].push(i);
        }
        let mut moved = false;
        for l in 0..k {
            let mem = &members[l];
            if mem.is_empty() {
                continue; // keep the old medoid for empty clusters
            }
            let mut best = med[l];
            let mut best_cost = f32::INFINITY;
            for &c in mem {
                let cost: f32 = mem.iter().map(|&i| d.eval(x.row(i), x.row(c))).sum();
                if cost < best_cost {
                    best_cost = cost;
                    best = c;
                }
            }
            if best != med[l] {
                med[l] = best;
                moved = true;
            }
        }
        if !changed && !moved {
            break;
        }
    }

    // final objective from the last assignment pass
    let obj: f64 = (0..n)
        .map(|i| d.eval(x.row(i), x.row(med[assign[i]])) as f64)
        .sum::<f64>()
        / n as f64;
    KMedoidsResult {
        medoids: med,
        est_objective: obj,
        stats: RunStats {
            seconds: timer.secs(),
            dissim_count: d.count() - count0,
            swap_count: iterations as u64,
        },
    }
}

/// [`crate::solver::Solver`] adapter for [`alternate`].
pub struct AlternateSolver {
    /// Max alternation iterations (assignment convergence ends earlier).
    pub max_iter: usize,
}

impl Default for AlternateSolver {
    fn default() -> Self {
        AlternateSolver { max_iter: 100 }
    }
}

impl crate::solver::Solver for AlternateSolver {
    fn label(&self) -> String {
        "Alternate".into()
    }

    fn solve(
        &self,
        x: &Matrix,
        spec: &crate::solver::SolveSpec,
        backend: &dyn crate::backend::ComputeBackend,
    ) -> anyhow::Result<KMedoidsResult> {
        let d = DissimCounter::with_counters(backend.metric(), backend.counters());
        Ok(alternate(x, spec.k, self.max_iter, spec.seed, &d))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::dissim::Metric;

    #[test]
    fn converges_and_is_valid() {
        let mut rng = Rng::new(1);
        let x = synth::gen_gaussian_mixture(&mut rng, 120, 3, 3, 0.1, 1.0);
        let d = DissimCounter::new(Metric::L1);
        let r = alternate(&x, 3, 50, 2, &d);
        r.validate(120, 3);
        assert!(r.est_objective.is_finite());
        assert!(r.stats.dissim_count > 0);
    }

    #[test]
    fn medoids_unique_even_with_duplicates_in_data() {
        // all-identical points: degenerate but must not produce dup medoids
        let x = Matrix::zeros(20, 2);
        let d = DissimCounter::new(Metric::L1);
        let r = alternate(&x, 3, 10, 3, &d);
        r.validate(20, 3);
    }

    #[test]
    fn improves_over_random_init() {
        let mut rng = Rng::new(4);
        let x = synth::gen_gaussian_mixture(&mut rng, 200, 4, 5, 0.1, 1.0);
        let d = DissimCounter::new(Metric::L1);
        let r = alternate(&x, 5, 50, 5, &d);
        let mut rng2 = Rng::new(5);
        let rand_med = rng2.sample_distinct(200, 5);
        let obj = |med: &[usize]| -> f64 {
            (0..200)
                .map(|i| {
                    med.iter()
                        .map(|&m| Metric::L1.eval(x.row(i), x.row(m)))
                        .fold(f32::INFINITY, f32::min) as f64
                })
                .sum()
        };
        assert!(obj(&r.medoids) <= obj(&rand_med));
    }
}
