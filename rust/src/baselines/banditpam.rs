//! BanditPAM++-style baseline (Tiwari et al. 2020, 2023).
//!
//! Reimplemented from the papers (the official C++ is unavailable
//! offline; DESIGN.md §3 records the substitution):
//!
//! * **BUILD**: each of the `k` greedy selections is a multi-armed-bandit
//!   race over all candidate points; arm values (the objective after
//!   adding the candidate) are estimated on shared mini-batches of
//!   reference points, and arms whose UCB is worse than the best LCB are
//!   eliminated (successive elimination with Hoeffding-style CIs).
//! * **SWAP**: up to `T` rounds race over all `(slot, candidate)` pairs
//!   using the FastPAM1 decomposition, so one `d(ref, candidate)`
//!   evaluation updates all `k` arms of that candidate.  The `++`
//!   caching idea is kept through an epoch-tagged nearest/second cache of
//!   reference points that survives rounds and is refreshed lazily after
//!   swaps.
//!
//! The defining cost behaviour vs OneBatchPAM: fresh dissimilarities are
//! drawn **every round**, so the measured dissimilarity count grows
//! linearly with the number of swap rounds (`O((T + k) n log n)`, Table
//! 1) — verified in benches/complexity.rs.

use crate::coordinator::KMedoidsResult;
use crate::dissim::DissimCounter;
use crate::linalg::Matrix;
use crate::rng::Rng;
use crate::solver::{CancelToken, CANCELLED};
use crate::telemetry::{RunStats, Timer};
use anyhow::{bail, Result};
use std::collections::HashMap;

/// BanditPAM++ configuration.
#[derive(Clone, Debug)]
pub struct BanditConfig {
    /// Number of medoids.
    pub k: usize,
    /// Max swap rounds `T` (paper sweeps {0, 2, 5}).
    pub max_swaps: usize,
    /// Reference mini-batch size per race round.
    pub batch: usize,
    /// Confidence parameter for the elimination CIs.
    pub delta: f64,
    /// PRNG seed.
    pub seed: u64,
    /// Cooperative cancellation: checked between BUILD selections and
    /// between SWAP rounds; a cancelled run fails with
    /// [`crate::solver::CANCELLED`] and discards its partial work.  The
    /// inert default costs nothing and never fires, so the selection
    /// sequence is bit-identical with or without a live token.
    pub cancel: CancelToken,
}

impl BanditConfig {
    /// Paper-flavoured defaults for `k` with `T` swap rounds.
    pub fn new(k: usize, max_swaps: usize, seed: u64) -> Self {
        BanditConfig { k, max_swaps, batch: 100, delta: 0.01, seed, cancel: CancelToken::none() }
    }
}

/// Epoch-tagged nearest/second-nearest cache for reference points.
struct RefCache {
    map: HashMap<usize, (usize, f32, usize, f32, u64)>,
    epoch: u64,
}

impl RefCache {
    fn new() -> Self {
        RefCache { map: HashMap::new(), epoch: 0 }
    }

    fn invalidate_all(&mut self) {
        self.epoch += 1;
    }

    /// near/sec of point `i` w.r.t. `med` (k evals on miss or stale).
    fn get(
        &mut self,
        i: usize,
        x: &Matrix,
        med: &[usize],
        d: &DissimCounter,
    ) -> (usize, f32, usize, f32) {
        if let Some(&(a, av, b, bv, ep)) = self.map.get(&i) {
            if ep == self.epoch {
                return (a, av, b, bv);
            }
        }
        let (mut a, mut av, mut b, mut bv) = (0usize, f32::INFINITY, 0usize, f32::INFINITY);
        for (l, &m) in med.iter().enumerate() {
            let v = d.eval(x.row(i), x.row(m));
            if v < av {
                b = a;
                bv = av;
                a = l;
                av = v;
            } else if v < bv {
                b = l;
                bv = v;
            }
        }
        self.map.insert(i, (a, av, b, bv, self.epoch));
        (a, av, b, bv)
    }
}

/// Sub-Gaussian CI half-width from an empirical variance estimate (the
/// BanditPAM papers use sigma-based CIs; range-based Hoeffding is far too
/// loose to eliminate arms at the paper's O(n log n) rate).
fn ci_sigma(sum: f64, sumsq: f64, count: usize, delta: f64, horizon: usize) -> f64 {
    if count < 2 {
        return f64::INFINITY;
    }
    let mean = sum / count as f64;
    let var = (sumsq / count as f64 - mean * mean).max(1e-12);
    (2.0 * var * ((2.0 * (horizon as f64).max(2.0) / delta).ln()) / count as f64).sqrt()
}

/// Run BanditPAM++-style k-medoids.
pub fn bandit_pam(x: &Matrix, cfg: &BanditConfig, d: &DissimCounter) -> Result<KMedoidsResult> {
    let n = x.rows;
    let k = cfg.k;
    assert!(k >= 2 && k < n);
    let timer = Timer::start();
    let count0 = d.count();
    let mut rng = Rng::new(cfg.seed);

    // ---------------- BUILD: k bandit races -----------------------------
    let mut med: Vec<usize> = Vec::with_capacity(k);
    let mut dmin = vec![f32::INFINITY; n];
    for _sel in 0..k {
        if cfg.cancel.is_cancelled() {
            bail!(CANCELLED);
        }
        // race over candidates minimising E_i[min(dmin_i, d(i, c))]
        let mut live: Vec<usize> = (0..n).filter(|i| !med.contains(i)).collect();
        let mut sum = vec![0.0f64; n];
        let mut sumsq = vec![0.0f64; n];
        let mut cnt = vec![0usize; n];
        // After O(log n) rounds, surviving arms are statistically tied at
        // the CI resolution -> pick the best mean (BanditPAM's n-sample
        // cap reached the same state far more expensively).
        let max_rounds = ((n as f64).log2().ceil() as usize + 3).max(4);
        let mut round = 0;
        while live.len() > 1 && cnt[live[0]] < n && round < max_rounds {
            round += 1;
            for _ in 0..cfg.batch {
                let r = rng.below(n);
                let base = if med.is_empty() { f32::INFINITY } else { dmin[r] };
                for &c in &live {
                    let v = d.eval(x.row(r), x.row(c)).min(base) as f64;
                    sum[c] += v;
                    sumsq[c] += v * v;
                }
            }
            for &c in &live {
                cnt[c] += cfg.batch;
            }
            // eliminate: LCB of the best vs UCB of others (minimisation)
            let best_ucb = live
                .iter()
                .map(|&c| sum[c] / cnt[c] as f64 + ci_sigma(sum[c], sumsq[c], cnt[c], cfg.delta, n))
                .fold(f64::INFINITY, f64::min);
            live.retain(|&c| {
                sum[c] / cnt[c] as f64 - ci_sigma(sum[c], sumsq[c], cnt[c], cfg.delta, n)
                    <= best_ucb
            });
        }
        let winner = *live
            .iter()
            .min_by(|&&a, &&b| {
                (sum[a] / cnt[a].max(1) as f64)
                    .partial_cmp(&(sum[b] / cnt[b].max(1) as f64))
                    .unwrap()
            })
            .unwrap();
        med.push(winner);
        for i in 0..n {
            let v = d.eval(x.row(i), x.row(winner));
            if v < dmin[i] {
                dmin[i] = v;
            }
        }
    }

    // ---------------- SWAP: T bandit races over (slot, candidate) -------
    let mut cache = RefCache::new();
    let mut swaps = 0u64;
    for _round in 0..cfg.max_swaps {
        if cfg.cancel.is_cancelled() {
            bail!(CANCELLED);
        }
        // per-candidate gain sums for each slot; count shared per candidate
        let cand: Vec<usize> = (0..n).filter(|i| !med.contains(i)).collect();
        let mut live: Vec<(usize, usize)> = Vec::with_capacity(cand.len() * k);
        for &c in &cand {
            for l in 0..k {
                live.push((c, l));
            }
        }
        let mut sum: HashMap<(usize, usize), (f64, f64)> = HashMap::with_capacity(live.len());
        let mut cnt: HashMap<usize, usize> = HashMap::with_capacity(cand.len());
        let max_rounds = ((n as f64).log2().ceil() as usize + 3).max(4);
        let mut rounds = 0usize;
        while live.len() > 1 && rounds < max_rounds {
            rounds += 1;
            let live_cands: std::collections::HashSet<usize> =
                live.iter().map(|&(c, _)| c).collect();
            let refs: Vec<usize> = (0..cfg.batch).map(|_| rng.below(n)).collect();
            // precompute ref caches once (k evals each, amortised by ++ cache)
            let ref_info: Vec<(usize, usize, f32, usize, f32)> = refs
                .iter()
                .map(|&r| {
                    let (a, av, b, bv) = cache.get(r, x, &med, d);
                    (r, a, av, b, bv)
                })
                .collect();
            for &c in &live_cands {
                for &(r, near, dnear, _sec, dsec) in &ref_info {
                    let dic = d.eval(x.row(r), x.row(c));
                    // FastPAM1 gain of swapping slot l -> c, for this ref
                    let shared = (dnear - dic).max(0.0) as f64;
                    for l in 0..k {
                        let g = if l == near {
                            (dnear - dic.min(dsec)) as f64
                        } else {
                            shared
                        };
                        let e = sum.entry((c, l)).or_insert((0.0, 0.0));
                        e.0 += g;
                        e.1 += g * g;
                    }
                }
                *cnt.entry(c).or_insert(0) += refs.len();
            }
            // maximisation race
            let best_lcb = live
                .iter()
                .map(|&(c, l)| {
                    let (s, sq) = sum[&(c, l)];
                    s / cnt[&c] as f64 - ci_sigma(s, sq, cnt[&c], cfg.delta, n)
                })
                .fold(f64::NEG_INFINITY, f64::max);
            live.retain(|&(c, l)| {
                let (s, sq) = sum[&(c, l)];
                s / cnt[&c] as f64 + ci_sigma(s, sq, cnt[&c], cfg.delta, n) >= best_lcb
            });
            if live.iter().all(|&(c, _)| cnt[&c] >= n) {
                break; // estimates as good as exact
            }
        }
        let (&(c, l), _) = match live
            .iter()
            .map(|p| (p, sum[p].0 / cnt[&p.0] as f64))
            .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
        {
            Some((p, v)) => (p, v),
            None => break,
        };
        let mean_gain = sum[&(c, l)].0 / cnt[&c] as f64;
        if mean_gain <= 0.0 {
            break; // local optimum (estimated)
        }
        med[l] = c;
        cache.invalidate_all();
        swaps += 1;
    }

    // final objective (exact, n*k evals) — BanditPAM reports the true
    // objective of its selection.
    let mut obj = 0.0f64;
    for i in 0..n {
        obj += med
            .iter()
            .map(|&m| d.eval(x.row(i), x.row(m)))
            .fold(f32::INFINITY, f32::min) as f64;
    }
    obj /= n as f64;

    Ok(KMedoidsResult {
        medoids: med,
        est_objective: obj,
        stats: RunStats {
            seconds: timer.secs(),
            dissim_count: d.count() - count0,
            swap_count: swaps,
        },
    })
}

/// [`crate::solver::Solver`] adapter for [`bandit_pam`].
pub struct BanditPamSolver {
    /// Max swap rounds `T` (paper sweeps {0, 2, 5}).
    pub swaps: usize,
}

impl crate::solver::Solver for BanditPamSolver {
    fn label(&self) -> String {
        format!("BanditPAM++-{}", self.swaps)
    }

    fn solve(
        &self,
        x: &Matrix,
        spec: &crate::solver::SolveSpec,
        backend: &dyn crate::backend::ComputeBackend,
    ) -> anyhow::Result<KMedoidsResult> {
        let d = DissimCounter::with_counters(backend.metric(), backend.counters());
        let cfg = BanditConfig {
            cancel: spec.cancel.clone(),
            ..BanditConfig::new(spec.k, self.swaps, spec.seed)
        };
        bandit_pam(x, &cfg, &d)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::dissim::Metric;

    fn blob(n: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        synth::gen_gaussian_mixture(&mut rng, n, 4, 3, 0.1, 1.0)
    }

    #[test]
    fn build_only_t0_is_valid_and_decent() {
        let x = blob(150, 1);
        let d = DissimCounter::new(Metric::L1);
        let r = bandit_pam(&x, &BanditConfig::new(3, 0, 2), &d).unwrap();
        r.validate(150, 3);
        // greedy BUILD should beat random by a margin on clustered data
        let mut rng = Rng::new(3);
        let rand = rng.sample_distinct(150, 3);
        let obj = |med: &[usize]| -> f64 {
            (0..150)
                .map(|i| {
                    med.iter()
                        .map(|&m| Metric::L1.eval(x.row(i), x.row(m)))
                        .fold(f32::INFINITY, f32::min) as f64
                })
                .sum()
        };
        assert!(obj(&r.medoids) < obj(&rand));
    }

    #[test]
    fn swap_rounds_never_hurt() {
        let x = blob(120, 4);
        let d0 = DissimCounter::new(Metric::L1);
        let r0 = bandit_pam(&x, &BanditConfig::new(3, 0, 5), &d0).unwrap();
        let d5 = DissimCounter::new(Metric::L1);
        let r5 = bandit_pam(&x, &BanditConfig::new(3, 5, 5), &d5).unwrap();
        r5.validate(120, 3);
        assert!(r5.est_objective <= r0.est_objective * 1.02);
    }

    #[test]
    fn dissim_cost_grows_with_swap_rounds() {
        let x = blob(150, 6);
        let d0 = DissimCounter::new(Metric::L1);
        bandit_pam(&x, &BanditConfig::new(3, 0, 7), &d0).unwrap();
        let d5 = DissimCounter::new(Metric::L1);
        bandit_pam(&x, &BanditConfig::new(3, 5, 7), &d5).unwrap();
        assert!(d5.count() >= d0.count(), "{} vs {}", d5.count(), d0.count());
    }

    #[test]
    fn live_uncancelled_token_is_bit_identical_to_inert() {
        // the cancellation hook must not perturb the selection sequence
        let x = blob(130, 9);
        let inert = bandit_pam(&x, &BanditConfig::new(3, 2, 8), &DissimCounter::new(Metric::L1))
            .unwrap();
        let cfg = BanditConfig { cancel: CancelToken::new(), ..BanditConfig::new(3, 2, 8) };
        let live = bandit_pam(&x, &cfg, &DissimCounter::new(Metric::L1)).unwrap();
        assert_eq!(inert.medoids, live.medoids);
        assert_eq!(inert.est_objective.to_bits(), live.est_objective.to_bits());
        assert_eq!(inert.stats.dissim_count, live.stats.dissim_count);
    }

    #[test]
    fn cancelled_token_aborts_with_the_marker_error() {
        let x = blob(120, 10);
        let token = CancelToken::new();
        token.cancel();
        let cfg = BanditConfig { cancel: token, ..BanditConfig::new(3, 2, 8) };
        let err = bandit_pam(&x, &cfg, &DissimCounter::new(Metric::L1)).unwrap_err().to_string();
        assert_eq!(err, CANCELLED);
    }
}
