//! FasterCLARA (Schubert & Rousseeuw 2021): FasterPAM on `I` random
//! subsamples of size `80 + 4k`, each candidate medoid set evaluated on
//! the full dataset; the best one wins.
//!
//! The defining difference from OneBatchPAM (paper, "From PAM to
//! OneBatchPAM"): CLARA's swap search space is restricted to the
//! subsample (`x' in X_m`), which doubles the theoretical approximation
//! error; OneBatchPAM keeps all of `X_n` as candidates.

use crate::backend::ComputeBackend;
use crate::coordinator::engine;
use crate::coordinator::state::SwapState;
use crate::coordinator::KMedoidsResult;
use crate::linalg::Matrix;
use crate::rng::Rng;
use crate::solver::{CancelToken, CANCELLED};
use crate::telemetry::{RunStats, Timer};
use anyhow::{bail, Result};

/// FasterCLARA configuration.
#[derive(Clone, Debug)]
pub struct ClaraConfig {
    /// Number of medoids.
    pub k: usize,
    /// Number of subsample repetitions (paper: I in {5, 50}).
    pub reps: usize,
    /// Subsample size; `None` -> `80 + 4k` (Schubert & Rousseeuw).
    pub sample_size: Option<usize>,
    /// Max eager passes inside each FasterPAM run.
    pub max_passes: usize,
    /// PRNG seed.
    pub seed: u64,
}

impl ClaraConfig {
    /// Paper-default configuration for `k` with `reps` repetitions.
    pub fn new(k: usize, reps: usize, seed: u64) -> Self {
        ClaraConfig { k, reps, sample_size: None, max_passes: 20, seed }
    }
}

/// Run FasterCLARA.
pub fn faster_clara(
    x: &Matrix,
    cfg: &ClaraConfig,
    backend: &dyn ComputeBackend,
) -> Result<KMedoidsResult> {
    faster_clara_cancellable(x, cfg, backend, &CancelToken::none())
}

/// [`faster_clara`] with a cooperative cancellation token, checked
/// between subsample repetitions (the natural CLARA granularity — one
/// rep is one bounded FasterPAM run plus one full-dataset evaluation):
/// a cancelled run fails with the [`CANCELLED`] marker error and
/// discards its partial work.  An inert token takes the exact same
/// path, so results stay bit-identical to [`faster_clara`].
pub fn faster_clara_cancellable(
    x: &Matrix,
    cfg: &ClaraConfig,
    backend: &dyn ComputeBackend,
    cancel: &CancelToken,
) -> Result<KMedoidsResult> {
    let n = x.rows;
    let k = cfg.k;
    assert!(k >= 2 && k < n);
    let timer = Timer::start();
    let counters = backend.counters();
    let dissim0 = counters.dissim();
    let swaps0 = counters.swaps();
    let mut rng = Rng::new(cfg.seed);
    let s = cfg.sample_size.unwrap_or(80 + 4 * k).min(n);

    let mut best: Option<(Vec<usize>, f64)> = None;
    for _ in 0..cfg.reps.max(1) {
        // cancellation is honoured between reps; each rep is bounded
        // work, so a cancel lands within one subsample's latency
        if cancel.is_cancelled() {
            bail!(CANCELLED);
        }
        // FasterPAM on the subsample (search space restricted to it).
        let sub_idx = rng.sample_distinct(n, s);
        let sub = x.select_rows(&sub_idx);
        let d = backend.pairwise(&sub, &sub)?;
        let med0 = rng.sample_distinct(s, k);
        let mut state = SwapState::init(&d, med0, vec![1.0; s], s);
        engine::eager_loop(&d, &mut state, cfg.max_passes, &mut rng, &counters);
        let med: Vec<usize> = state.med.iter().map(|&j| sub_idx[j]).collect();

        // Evaluate this candidate set on the FULL dataset (n*k distances).
        let med_rows = x.select_rows(&med);
        let dm = backend.pairwise(x, &med_rows)?;
        let mut obj = 0.0f64;
        for i in 0..n {
            obj += dm.row(i).iter().copied().fold(f32::INFINITY, f32::min) as f64;
        }
        obj /= n as f64;
        if best.as_ref().map_or(true, |(_, b)| obj < *b) {
            best = Some((med, obj));
        }
    }

    let (medoids, est_objective) = best.unwrap();
    Ok(KMedoidsResult {
        medoids,
        est_objective,
        stats: RunStats {
            seconds: timer.secs(),
            dissim_count: counters.dissim() - dissim0,
            swap_count: counters.swaps() - swaps0,
        },
    })
}

/// [`crate::solver::Solver`] adapter for [`faster_clara`].
pub struct ClaraSolver {
    /// Subsample repetitions (paper: I in {5, 50}).
    pub reps: usize,
}

impl crate::solver::Solver for ClaraSolver {
    fn label(&self) -> String {
        format!("FasterCLARA-{}", self.reps)
    }

    fn solve(
        &self,
        x: &Matrix,
        spec: &crate::solver::SolveSpec,
        backend: &dyn ComputeBackend,
    ) -> Result<KMedoidsResult> {
        // the spec's token reaches the rep loop, so a served CLARA job
        // cancels between subsamples instead of running every rep
        faster_clara_cancellable(
            x,
            &ClaraConfig::new(spec.k, self.reps, spec.seed),
            backend,
            &spec.cancel,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::NativeBackend;
    use crate::data::synth;
    use crate::dissim::Metric;

    #[test]
    fn valid_result_and_counts() {
        let mut rng = Rng::new(1);
        let x = synth::gen_gaussian_mixture(&mut rng, 300, 4, 4, 0.15, 1.0);
        let backend = NativeBackend::new(Metric::L1);
        let cfg = ClaraConfig::new(4, 3, 2);
        let r = faster_clara(&x, &cfg, &backend).unwrap();
        r.validate(300, 4);
        // I * (s^2 + n*k) dissimilarities
        let s = (80 + 16).min(300);
        assert_eq!(r.stats.dissim_count as usize, 3 * (s * s + 300 * 4));
    }

    #[test]
    fn more_reps_never_worse() {
        let mut rng = Rng::new(3);
        let x = synth::gen_gaussian_mixture(&mut rng, 250, 3, 5, 0.2, 1.5);
        let backend = NativeBackend::new(Metric::L1);
        // same seed: rep sequence of reps=1 is a prefix of reps=4
        let r1 = faster_clara(&x, &ClaraConfig::new(5, 1, 7), &backend).unwrap();
        let r4 = faster_clara(&x, &ClaraConfig::new(5, 4, 7), &backend).unwrap();
        assert!(r4.est_objective <= r1.est_objective + 1e-9);
    }

    #[test]
    fn cancelled_token_aborts_between_reps() {
        let mut rng = Rng::new(6);
        let x = synth::gen_gaussian_mixture(&mut rng, 200, 4, 4, 0.2, 1.0);
        let backend = NativeBackend::new(Metric::L1);
        let cfg = ClaraConfig::new(4, 5, 9);
        let token = CancelToken::new();
        token.cancel();
        let err =
            faster_clara_cancellable(&x, &cfg, &backend, &token).unwrap_err().to_string();
        assert_eq!(err, CANCELLED);
        // the inert token reproduces the plain entry point bit-for-bit
        let a = faster_clara(&x, &cfg, &backend).unwrap();
        let b = faster_clara_cancellable(&x, &cfg, &backend, &CancelToken::none()).unwrap();
        assert_eq!(a.medoids, b.medoids);
        assert_eq!(a.est_objective.to_bits(), b.est_objective.to_bits());
    }

    #[test]
    fn subsample_capped_at_n() {
        let mut rng = Rng::new(4);
        let x = synth::gen_gaussian_mixture(&mut rng, 60, 3, 3, 0.2, 1.0);
        let backend = NativeBackend::new(Metric::L1);
        let cfg = ClaraConfig::new(3, 2, 5); // 80 + 12 > 60 -> capped
        let r = faster_clara(&x, &cfg, &backend).unwrap();
        r.validate(60, 3);
    }
}
