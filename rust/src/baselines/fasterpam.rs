//! FasterPAM (Schubert & Rousseeuw 2021): random init + eager swaps over
//! the full `n x n` dissimilarity matrix.
//!
//! Implemented as the degenerate OneBatch case `m = n`, batch = identity,
//! weights = 1: the swap engine, caches and tolerance are *identical* to
//! OneBatchPAM's, which is exactly the comparison the paper makes (the
//! only difference is which columns the objective is summed over).
//!
//! Memory: `O(n^2)` — the paper marks FasterPAM "Na" on the large-scale
//! datasets for this reason; we do the same in the harness.

use crate::backend::ComputeBackend;
use crate::coordinator::engine;
use crate::coordinator::state::SwapState;
use crate::coordinator::KMedoidsResult;
use crate::linalg::Matrix;
use crate::rng::Rng;
use crate::runtime::Pool;
use crate::solver::{CancelToken, CANCELLED};
use crate::telemetry::{RunStats, Timer};
use anyhow::{bail, Result};

/// Run FasterPAM.  `max_passes` bounds the eager scans (paper: converges
/// in O(k) swaps; a pass without improvement terminates).
pub fn faster_pam(
    x: &Matrix,
    k: usize,
    max_passes: usize,
    seed: u64,
    backend: &dyn ComputeBackend,
) -> Result<KMedoidsResult> {
    faster_pam_cancellable(x, k, max_passes, seed, backend, &CancelToken::none())
}

/// [`faster_pam`] with a cooperative cancellation token, checked once
/// per eager pass (the same cadence as OneBatchPAM's swap loop): a
/// cancelled run fails with the [`CANCELLED`] marker error and discards
/// its partial work.  The pass-at-a-time loop over a persistent
/// candidate order is bit-identical to the historical multi-pass
/// `eager_loop` call — asserted by
/// `engine::tests::external_pass_loop_matches_internal_loop_exactly`.
pub fn faster_pam_cancellable(
    x: &Matrix,
    k: usize,
    max_passes: usize,
    seed: u64,
    backend: &dyn ComputeBackend,
    cancel: &CancelToken,
) -> Result<KMedoidsResult> {
    let n = x.rows;
    assert!(k >= 2 && k < n);
    let timer = Timer::start();
    let counters = backend.counters();
    let dissim0 = counters.dissim();
    let swaps0 = counters.swaps();
    let mut rng = Rng::new(seed);

    // Full pairwise matrix (the O(p n^2) cost the paper attacks).
    let d = backend.pairwise(x, x)?;
    let med = rng.sample_distinct(n, k);
    let mut state = SwapState::init(&d, med, vec![1.0; n], n);
    // One eager pass per loop iteration so the cancellation token is
    // honoured between passes; the order vector persists across passes
    // (pass p scans the p-times-shuffled permutation), exactly like the
    // in-loop behaviour of `eager_loop`.
    let mut order: Vec<usize> = (0..n).collect();
    for _ in 0..max_passes {
        if cancel.is_cancelled() {
            bail!(CANCELLED);
        }
        let swaps =
            engine::eager_pass(&d, &mut state, 0.0, &mut rng, &counters, &Pool::serial(), &mut order);
        if swaps == 0 {
            break; // a full pass without a swap: local optimum
        }
    }

    Ok(KMedoidsResult {
        medoids: state.med.clone(),
        est_objective: state.est_objective(),
        stats: RunStats {
            seconds: timer.secs(),
            dissim_count: counters.dissim() - dissim0,
            swap_count: counters.swaps() - swaps0,
        },
    })
}

/// [`crate::solver::Solver`] adapter for [`faster_pam`].
pub struct FasterPamSolver {
    /// Max eager passes (converges in O(k) swaps long before this).
    pub max_passes: usize,
}

impl Default for FasterPamSolver {
    fn default() -> Self {
        FasterPamSolver { max_passes: 50 }
    }
}

impl crate::solver::Solver for FasterPamSolver {
    fn label(&self) -> String {
        "FasterPAM".into()
    }

    fn solve(
        &self,
        x: &Matrix,
        spec: &crate::solver::SolveSpec,
        backend: &dyn ComputeBackend,
    ) -> Result<KMedoidsResult> {
        // the spec's token reaches the swap loop, so a served FasterPAM
        // job cancels between eager passes instead of running to the end
        faster_pam_cancellable(x, spec.k, self.max_passes, spec.seed, backend, &spec.cancel)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::NativeBackend;
    use crate::data::synth;
    use crate::dissim::Metric;

    #[test]
    fn finds_planted_clusters() {
        let mut rng = Rng::new(1);
        let x = synth::gen_gaussian_mixture(&mut rng, 150, 3, 3, 0.05, 1.0);
        let backend = NativeBackend::new(Metric::L1);
        let r = faster_pam(&x, 3, 50, 2, &backend).unwrap();
        r.validate(150, 3);
        // exact objective equals est_objective for m = n
        let exact: f64 = (0..150)
            .map(|i| {
                r.medoids
                    .iter()
                    .map(|&m| Metric::L1.eval(x.row(i), x.row(m)))
                    .fold(f32::INFINITY, f32::min) as f64
            })
            .sum::<f64>()
            / 150.0;
        assert!((exact - r.est_objective).abs() < 1e-4);
    }

    #[test]
    fn dissim_count_is_n_squared() {
        let mut rng = Rng::new(3);
        let x = synth::gen_gaussian_mixture(&mut rng, 80, 3, 3, 0.2, 1.0);
        let backend = NativeBackend::new(Metric::L1);
        let r = faster_pam(&x, 4, 30, 1, &backend).unwrap();
        assert_eq!(r.stats.dissim_count, 80 * 80);
    }

    #[test]
    fn cancelled_token_aborts_between_passes() {
        let mut rng = Rng::new(4);
        let x = synth::gen_gaussian_mixture(&mut rng, 120, 3, 3, 0.2, 1.0);
        let backend = NativeBackend::new(Metric::L1);
        let token = CancelToken::new();
        token.cancel();
        let err = faster_pam_cancellable(&x, 3, 50, 1, &backend, &token).unwrap_err().to_string();
        assert_eq!(err, CANCELLED);
        // the inert token reproduces the plain entry point bit-for-bit
        let a = faster_pam(&x, 3, 50, 1, &backend).unwrap();
        let b = faster_pam_cancellable(&x, 3, 50, 1, &backend, &CancelToken::none()).unwrap();
        assert_eq!(a.medoids, b.medoids);
        assert_eq!(a.est_objective.to_bits(), b.est_objective.to_bits());
    }

    #[test]
    fn objective_not_worse_than_onebatch_usually() {
        // FasterPAM sees the exact objective; on a fixed seed it should
        // be at least as good as OneBatchPAM's full-data objective.
        use crate::coordinator::{one_batch_pam, OneBatchConfig};
        let mut rng = Rng::new(5);
        let x = synth::gen_gaussian_mixture(&mut rng, 200, 4, 4, 0.15, 1.0);
        let backend = NativeBackend::new(Metric::L1);
        let fp = faster_pam(&x, 4, 50, 7, &backend).unwrap();
        let ob = one_batch_pam(
            &x,
            &OneBatchConfig { k: 4, m: Some(40), seed: 7, ..Default::default() },
            &backend,
        )
        .unwrap();
        let full = |med: &[usize]| -> f64 {
            (0..200)
                .map(|i| {
                    med.iter()
                        .map(|&m| Metric::L1.eval(x.row(i), x.row(m)))
                        .fold(f32::INFINITY, f32::min) as f64
                })
                .sum::<f64>()
        };
        assert!(full(&fp.medoids) <= full(&ob.medoids) * 1.05);
    }
}
