//! The k-means++ family used as k-medoids proxies (paper, Related Works):
//!
//! * [`kmeanspp`] — D^p sampling (Arthur & Vassilvitskii 2007).  For an
//!   l_p dissimilarity the sampling weight is `d(x, C)^p`; the paper uses
//!   L1, i.e. weight = distance itself.
//! * [`kmc2`] — MCMC approximation of k-means++ (Bachem et al. 2016) with
//!   chain length `L`; `O(L k^2)` dissimilarity computations.
//! * [`ls_kmeanspp`] — k-means++ seeding followed by `Z` local-search
//!   swap iterations (Lattanzi & Sohler 2019).

use crate::coordinator::KMedoidsResult;
use crate::dissim::{DissimCounter, Metric};
use crate::linalg::Matrix;
use crate::rng::Rng;
use crate::telemetry::{RunStats, Timer};

/// Sampling power for the metric: D^2 for (squared) Euclidean, D^1 for L1
/// and the other non-Euclidean metrics (paper: "distance raised to the
/// power p ... for any l_p distance").
fn power(metric: Metric) -> i32 {
    match metric {
        Metric::L2 | Metric::SqL2 => 2,
        _ => 1,
    }
}

#[inline]
fn weight(v: f32, pow: i32) -> f64 {
    if pow == 2 {
        (v as f64) * (v as f64)
    } else {
        v as f64
    }
}

/// Classic k-means++ seeding as a k-medoids proxy (`O(k n)` evals).
pub fn kmeanspp(x: &Matrix, k: usize, seed: u64, d: &DissimCounter) -> KMedoidsResult {
    let n = x.rows;
    assert!(k >= 1 && k <= n);
    let timer = Timer::start();
    let count0 = d.count();
    let mut rng = Rng::new(seed);
    let pow = power(d.metric);

    let mut med = Vec::with_capacity(k);
    med.push(rng.below(n));
    // dmin[i] = distance to nearest chosen center so far
    let mut dmin: Vec<f32> = (0..n).map(|i| d.eval(x.row(i), x.row(med[0]))).collect();
    while med.len() < k {
        let weights: Vec<f64> = dmin.iter().map(|&v| weight(v, pow)).collect();
        let mut c = rng.weighted(&weights);
        // avoid duplicate centers (possible when mass is concentrated)
        while med.contains(&c) {
            c = rng.below(n);
        }
        med.push(c);
        for i in 0..n {
            let v = d.eval(x.row(i), x.row(c));
            if v < dmin[i] {
                dmin[i] = v;
            }
        }
    }
    let obj = dmin.iter().map(|&v| v as f64).sum::<f64>() / n as f64;
    KMedoidsResult {
        medoids: med,
        est_objective: obj,
        stats: RunStats {
            seconds: timer.secs(),
            dissim_count: d.count() - count0,
            swap_count: 0,
        },
    }
}

/// kmc2: Markov-chain approximation of D^p sampling.
///
/// Chain of length `l` per center; each proposal evaluates distances to
/// the current centers, giving `O(k^2 l)` total evaluations — sublinear
/// in `n`, which is why it dominates the large-scale RT column.
pub fn kmc2(x: &Matrix, k: usize, l: usize, seed: u64, d: &DissimCounter) -> KMedoidsResult {
    let n = x.rows;
    assert!(k >= 1 && k <= n && l >= 1);
    let timer = Timer::start();
    let count0 = d.count();
    let mut rng = Rng::new(seed);
    let pow = power(d.metric);

    let dist_to = |c: &[usize], i: usize| -> f32 {
        c.iter()
            .map(|&m| d.eval(x.row(i), x.row(m)))
            .fold(f32::INFINITY, f32::min)
    };

    let mut med = vec![rng.below(n)];
    while med.len() < k {
        // uniform-proposal Metropolis chain targeting D^p
        let mut cur = rng.below(n);
        let mut cur_w = weight(dist_to(&med, cur), pow);
        for _ in 1..l {
            let cand = rng.below(n);
            let cand_w = weight(dist_to(&med, cand), pow);
            let accept = if cur_w <= 0.0 { 1.0 } else { (cand_w / cur_w).min(1.0) };
            if rng.f64() < accept {
                cur = cand;
                cur_w = cand_w;
            }
        }
        if med.contains(&cur) {
            cur = rng.below(n); // extremely rare; keep medoids distinct
            while med.contains(&cur) {
                cur = rng.below(n);
            }
        }
        med.push(cur);
    }
    KMedoidsResult {
        medoids: med,
        est_objective: f64::NAN, // kmc2 never touches the full objective
        stats: RunStats {
            seconds: timer.secs(),
            dissim_count: d.count() - count0,
            swap_count: 0,
        },
    }
}

/// LS-k-means++ (Lattanzi & Sohler 2019): k-means++ seeding then `z`
/// local-search iterations.  Each iteration D^p-samples one candidate and
/// applies the best single-center swap if it improves the objective.
pub fn ls_kmeanspp(x: &Matrix, k: usize, z: usize, seed: u64, d: &DissimCounter) -> KMedoidsResult {
    let n = x.rows;
    let timer = Timer::start();
    let count0 = d.count();
    let seeded = kmeanspp(x, k, seed, d);
    let mut med = seeded.medoids;
    let mut rng = Rng::new(seed ^ 0x5eed);
    let pow = power(d.metric);

    // near/sec caches over ALL points (needed for O(n) swap evaluation)
    let mut dmed = Matrix::zeros(n, k);
    for i in 0..n {
        for (l, &m) in med.iter().enumerate() {
            dmed.set(i, l, d.eval(x.row(i), x.row(m)));
        }
    }
    let mut swaps = 0u64;
    for _ in 0..z {
        // caches
        let mut near = vec![0usize; n];
        let mut dnear = vec![0f32; n];
        let mut dsec = vec![0f32; n];
        for i in 0..n {
            let (l1, v1, _, v2) = crate::linalg::top2_min(dmed.row(i));
            near[i] = l1;
            dnear[i] = v1;
            dsec[i] = v2;
        }
        // D^p-sample the candidate
        let weights: Vec<f64> = dnear.iter().map(|&v| weight(v, pow)).collect();
        let c = rng.weighted(&weights);
        if med.contains(&c) {
            continue;
        }
        // cost of swapping center l -> c, for every l, in one pass
        let dc: Vec<f32> = (0..n).map(|i| d.eval(x.row(i), x.row(c))).collect();
        let base: f64 = dnear.iter().map(|&v| v as f64).sum();
        let mut cost = vec![0.0f64; k];
        let mut shared = 0.0f64; // sum over i of min(dc, dnear) - careful split
        for i in 0..n {
            let keep = dc[i].min(dnear[i]) as f64;
            shared += keep;
            // if near[i] is removed, the point falls back to min(dc, dsec)
            cost[near[i]] += dc[i].min(dsec[i]) as f64 - keep;
        }
        let (mut bl, mut bv) = (0usize, f64::INFINITY);
        for l in 0..k {
            let v = shared + cost[l];
            if v < bv {
                bv = v;
                bl = l;
            }
        }
        if bv < base - 1e-9 {
            med[bl] = c;
            for i in 0..n {
                dmed.set(i, bl, dc[i]);
            }
            swaps += 1;
        }
    }
    let mut obj = 0.0f64;
    for i in 0..n {
        obj += dmed.row(i).iter().copied().fold(f32::INFINITY, f32::min) as f64;
    }
    obj /= n as f64;
    KMedoidsResult {
        medoids: med,
        est_objective: obj,
        stats: RunStats {
            seconds: timer.secs(),
            dissim_count: d.count() - count0,
            swap_count: swaps,
        },
    }
}

/// [`crate::solver::Solver`] adapter for [`kmeanspp`].
pub struct KMeansPpSolver;

/// [`crate::solver::Solver`] adapter for [`kmc2`].
pub struct Kmc2Solver {
    /// MCMC chain length `L` (paper sweeps {20, 100, 200}).
    pub chain: usize,
}

/// [`crate::solver::Solver`] adapter for [`ls_kmeanspp`].
pub struct LsKMeansPpSolver {
    /// Local-search steps `Z` (paper sweeps {5, 10}).
    pub steps: usize,
}

/// Counted evaluator wired to the backend's telemetry, so the measured
/// dissimilarity cost is comparable across every method.
fn counted(backend: &dyn crate::backend::ComputeBackend) -> DissimCounter {
    DissimCounter::with_counters(backend.metric(), backend.counters())
}

impl crate::solver::Solver for KMeansPpSolver {
    fn label(&self) -> String {
        "k-means++".into()
    }

    fn solve(
        &self,
        x: &Matrix,
        spec: &crate::solver::SolveSpec,
        backend: &dyn crate::backend::ComputeBackend,
    ) -> anyhow::Result<KMedoidsResult> {
        Ok(kmeanspp(x, spec.k, spec.seed, &counted(backend)))
    }
}

impl crate::solver::Solver for Kmc2Solver {
    fn label(&self) -> String {
        format!("kmc2-{}", self.chain)
    }

    fn solve(
        &self,
        x: &Matrix,
        spec: &crate::solver::SolveSpec,
        backend: &dyn crate::backend::ComputeBackend,
    ) -> anyhow::Result<KMedoidsResult> {
        Ok(kmc2(x, spec.k, self.chain, spec.seed, &counted(backend)))
    }
}

impl crate::solver::Solver for LsKMeansPpSolver {
    fn label(&self) -> String {
        format!("LS-k-means++-{}", self.steps)
    }

    fn solve(
        &self,
        x: &Matrix,
        spec: &crate::solver::SolveSpec,
        backend: &dyn crate::backend::ComputeBackend,
    ) -> anyhow::Result<KMedoidsResult> {
        Ok(ls_kmeanspp(x, spec.k, self.steps, spec.seed, &counted(backend)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;

    fn blob(n: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        synth::gen_gaussian_mixture(&mut rng, n, 4, 4, 0.1, 1.0)
    }

    fn full_obj(x: &Matrix, med: &[usize], metric: Metric) -> f64 {
        (0..x.rows)
            .map(|i| {
                med.iter()
                    .map(|&m| metric.eval(x.row(i), x.row(m)))
                    .fold(f32::INFINITY, f32::min) as f64
            })
            .sum()
    }

    #[test]
    fn kmeanspp_valid_and_linear_cost() {
        let x = blob(200, 1);
        let d = DissimCounter::new(Metric::L1);
        let r = kmeanspp(&x, 4, 2, &d);
        r.validate(200, 4);
        assert_eq!(r.stats.dissim_count, 4 * 200);
    }

    #[test]
    fn kmeanspp_beats_random() {
        let x = blob(300, 2);
        let d = DissimCounter::new(Metric::L1);
        let r = kmeanspp(&x, 4, 3, &d);
        let mut rng = Rng::new(4);
        let rand = rng.sample_distinct(300, 4);
        assert!(full_obj(&x, &r.medoids, Metric::L1) < full_obj(&x, &rand, Metric::L1));
    }

    #[test]
    fn kmc2_valid_and_sublinear_cost() {
        let x = blob(500, 5);
        let d = DissimCounter::new(Metric::L1);
        let r = kmc2(&x, 5, 20, 6, &d);
        r.validate(500, 5);
        // cost independent of n: < L * k^2 evaluations (plus slack)
        assert!(r.stats.dissim_count < (20 * 5 * 5 + 100) as u64, "{}", r.stats.dissim_count);
    }

    #[test]
    fn ls_improves_or_matches_seeding() {
        let x = blob(250, 7);
        let d = DissimCounter::new(Metric::L1);
        let seed = kmeanspp(&x, 4, 8, &d);
        let ls = ls_kmeanspp(&x, 4, 10, 8, &d);
        ls.validate(250, 4);
        let (o_seed, o_ls) = (
            full_obj(&x, &seed.medoids, Metric::L1),
            full_obj(&x, &ls.medoids, Metric::L1),
        );
        assert!(o_ls <= o_seed + 1e-6, "LS {o_ls} vs seed {o_seed}");
    }

    #[test]
    fn ls_swap_eval_is_exact() {
        // After any accepted swap, recomputing the objective from scratch
        // must match est_objective.
        let x = blob(100, 9);
        let d = DissimCounter::new(Metric::L1);
        let r = ls_kmeanspp(&x, 3, 15, 10, &d);
        let exact = full_obj(&x, &r.medoids, Metric::L1) / 100.0;
        assert!((exact - r.est_objective).abs() < 1e-4);
    }
}
