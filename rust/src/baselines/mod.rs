//! Every comparator from the paper's evaluation (Tables 3-8).
//!
//! | paper name        | here                               |
//! |-------------------|------------------------------------|
//! | Random            | [`random::random_select`]          |
//! | FasterPAM         | [`fasterpam::faster_pam`]          |
//! | Alternate         | [`alternate::alternate`]           |
//! | FasterCLARA-I     | [`clara::faster_clara`]            |
//! | k-means++         | [`kmeanspp::kmeanspp`]             |
//! | kmc2-L            | [`kmeanspp::kmc2`]                 |
//! | LS-k-means++-Z    | [`kmeanspp::ls_kmeanspp`]          |
//! | BanditPAM++-T     | [`banditpam::bandit_pam`]          |
//!
//! All functions return [`crate::coordinator::KMedoidsResult`] and count
//! dissimilarity computations through the same telemetry, so Table 1's
//! complexity claims are measurable.

pub mod alternate;
pub mod banditpam;
pub mod clara;
pub mod fasterpam;
pub mod kmeanspp;
pub mod random;

pub use alternate::alternate;
pub use banditpam::{bandit_pam, BanditConfig};
pub use clara::{faster_clara, ClaraConfig};
pub use fasterpam::faster_pam;
pub use kmeanspp::{kmc2, kmeanspp, ls_kmeanspp};
pub use random::random_select;
