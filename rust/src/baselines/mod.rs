//! Every comparator from the paper's evaluation (Tables 3-8).
//!
//! | paper name        | free function                      | [`crate::solver::Solver`] |
//! |-------------------|------------------------------------|---------------------------|
//! | Random            | [`random::random_select`]          | [`RandomSolver`]          |
//! | FasterPAM         | [`fasterpam::faster_pam`]          | [`FasterPamSolver`]       |
//! | Alternate         | [`alternate::alternate`]           | [`AlternateSolver`]       |
//! | FasterCLARA-I     | [`clara::faster_clara`]            | [`ClaraSolver`]           |
//! | k-means++         | [`kmeanspp::kmeanspp`]             | [`KMeansPpSolver`]        |
//! | kmc2-L            | [`kmeanspp::kmc2`]                 | [`Kmc2Solver`]            |
//! | LS-k-means++-Z    | [`kmeanspp::ls_kmeanspp`]          | [`LsKMeansPpSolver`]      |
//! | BanditPAM++-T     | [`banditpam::bandit_pam`]          | [`BanditPamSolver`]       |
//!
//! All functions return [`crate::coordinator::KMedoidsResult`] and count
//! dissimilarity computations through the same telemetry, so Table 1's
//! complexity claims are measurable.  The `*Solver` adapters plug every
//! method into the unified [`crate::solver`] entry point used by the
//! CLI, the bench harness and the job server.

pub mod alternate;
pub mod banditpam;
pub mod clara;
pub mod fasterpam;
pub mod kmeanspp;
pub mod random;

pub use alternate::{alternate, AlternateSolver};
pub use banditpam::{bandit_pam, BanditConfig, BanditPamSolver};
pub use clara::{faster_clara, faster_clara_cancellable, ClaraConfig, ClaraSolver};
pub use fasterpam::{faster_pam, faster_pam_cancellable, FasterPamSolver};
pub use kmeanspp::{kmc2, kmeanspp, ls_kmeanspp, KMeansPpSolver, Kmc2Solver, LsKMeansPpSolver};
pub use random::{random_select, RandomSolver};
