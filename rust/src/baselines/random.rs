//! Random medoid selection — the lower anchor of every comparison.

use crate::backend::ComputeBackend;
use crate::coordinator::KMedoidsResult;
use crate::linalg::Matrix;
use crate::rng::Rng;
use crate::solver::{SolveSpec, Solver};
use crate::telemetry::{RunStats, Timer};

/// Select `k` distinct rows uniformly at random.
pub fn random_select(x: &Matrix, k: usize, seed: u64) -> KMedoidsResult {
    let timer = Timer::start();
    let mut rng = Rng::new(seed);
    let medoids = rng.sample_distinct(x.rows, k);
    KMedoidsResult {
        medoids,
        est_objective: f64::NAN, // never evaluated internally
        stats: RunStats { seconds: timer.secs(), dissim_count: 0, swap_count: 0 },
    }
}

/// [`Solver`] adapter for [`random_select`].
pub struct RandomSolver;

impl Solver for RandomSolver {
    fn label(&self) -> String {
        "Random".into()
    }

    fn solve(
        &self,
        x: &Matrix,
        spec: &SolveSpec,
        _backend: &dyn ComputeBackend,
    ) -> anyhow::Result<KMedoidsResult> {
        Ok(random_select(x, spec.k, spec.seed))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn valid_and_free() {
        let x = Matrix::zeros(50, 3);
        let r = random_select(&x, 5, 1);
        r.validate(50, 5);
        assert_eq!(r.stats.dissim_count, 0);
    }

    #[test]
    fn deterministic() {
        let x = Matrix::zeros(50, 3);
        assert_eq!(random_select(&x, 5, 2).medoids, random_select(&x, 5, 2).medoids);
    }
}
