//! Run configuration: a TOML-subset parser (flat `key = value` pairs with
//! `[section]` headers — no toml crate offline) merged with CLI-style
//! `key=value` overrides.
//!
//! Used by the `obpam` CLI and the bench harness so every experiment is
//! reproducible from a single file + command line.

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// Flat configuration: `section.key -> value` strings.
#[derive(Clone, Debug, Default)]
pub struct Config {
    values: BTreeMap<String, String>,
}

impl Config {
    /// Parse from TOML-subset text.
    pub fn parse(text: &str) -> Result<Self> {
        let mut values = BTreeMap::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[').and_then(|l| l.strip_suffix(']')) {
                section = name.trim().to_string();
                continue;
            }
            let (key, value) = line
                .split_once('=')
                .with_context(|| format!("config line {}: expected key = value", lineno + 1))?;
            let key = key.trim();
            if key.is_empty() {
                bail!("config line {}: empty key", lineno + 1);
            }
            let full = if section.is_empty() { key.to_string() } else { format!("{section}.{key}") };
            values.insert(full, value.trim().trim_matches('"').to_string());
        }
        Ok(Config { values })
    }

    /// Load from a file.
    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text)
    }

    /// Apply `key=value` overrides (e.g. from trailing CLI args).
    pub fn apply_overrides<'a>(&mut self, overrides: impl IntoIterator<Item = &'a str>) -> Result<()> {
        for ov in overrides {
            let (k, v) = ov
                .split_once('=')
                .with_context(|| format!("override '{ov}': expected key=value"))?;
            self.values.insert(k.trim().to_string(), v.trim().to_string());
        }
        Ok(())
    }

    /// Raw string lookup.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }

    /// String with default.
    pub fn get_str(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    /// Parsed numeric/boolean lookup with default.
    pub fn get_parse<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T> {
        match self.get(key) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|_| anyhow::anyhow!("config key '{key}': cannot parse '{s}'")),
        }
    }

    /// All keys (for diagnostics).
    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.values.keys().map(|s| s.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_comments_quotes() {
        let c = Config::parse(
            "top = 1\n[run]\nk = 50   # medoids\nname = \"mnist\"\n\n[run.sub]\nx=2\n",
        )
        .unwrap();
        assert_eq!(c.get("top"), Some("1"));
        assert_eq!(c.get("run.k"), Some("50"));
        assert_eq!(c.get("run.name"), Some("mnist"));
        assert_eq!(c.get("run.sub.x"), Some("2"));
    }

    #[test]
    fn overrides_win() {
        let mut c = Config::parse("[run]\nk = 10\n").unwrap();
        c.apply_overrides(["run.k=99", "extra=hi"]).unwrap();
        assert_eq!(c.get("run.k"), Some("99"));
        assert_eq!(c.get("extra"), Some("hi"));
    }

    #[test]
    fn typed_get_with_default() {
        let c = Config::parse("[a]\nx = 2.5\n").unwrap();
        assert_eq!(c.get_parse("a.x", 0.0f64).unwrap(), 2.5);
        assert_eq!(c.get_parse("a.missing", 7usize).unwrap(), 7);
        assert!(c.get_parse::<usize>("a.x", 0).is_err());
    }

    #[test]
    fn rejects_garbage() {
        assert!(Config::parse("just words\n").is_err());
        assert!(Config::parse("= novalue\n").is_err());
    }
}
