//! Swap-search engines over a precomputed `n x m` matrix.
//!
//! * [`eager_loop`] — the paper's Algorithm 2 (Approximated-FasterPAM):
//!   scan candidates, swap as soon as an improvement is found, stop after
//!   a full pass without a swap or `max_passes` passes.  `O(n (m + k))`
//!   per pass, pure Rust (the per-candidate evaluation is `O(m)` and
//!   data-dependent, which is exactly what XLA is bad at).
//! * [`steepest_loop`] — Eq. (3) literally: evaluate *all* candidates via
//!   the backend's batched gains kernel (XLA/Pallas on the AOT path),
//!   apply the single best swap, repeat.  One `gains` launch per swap.
//!
//! Both stop on the same tolerance and share [`SwapState`], so they are
//! directly comparable (benches/ablation.rs).

use super::state::SwapState;
use crate::backend::{removal_loss, ComputeBackend};
use crate::linalg::Matrix;
use crate::rng::Rng;
use crate::runtime::Pool;
use crate::telemetry::Counters;
use anyhow::Result;

/// Swap-acceptance tolerance: relative to the current objective estimate
/// so f32 rounding can never produce an infinite improvement loop.
pub fn tolerance(est_objective: f64) -> f64 {
    1e-6 * est_objective.abs().max(1e-12)
}

/// Candidates evaluated per worker thread per parallel round.  Large
/// enough to amortise the pool's per-region dispatch cost (each
/// evaluation is `O(m + k)`), small enough that an accepted swap does
/// not discard much speculative work.
const SCAN_CHUNK: usize = 256;

/// Eager (Algorithm 2) swap search, serial.  Returns the number of
/// swaps applied.
pub fn eager_loop(
    d: &Matrix,
    state: &mut SwapState,
    max_passes: usize,
    rng: &mut Rng,
    counters: &Counters,
) -> usize {
    eager_loop_eps(d, state, max_passes, 0.0, rng, counters, &Pool::serial())
}

/// Eager swap search with an epsilon improvement threshold (paper, "How
/// many iterations T are needed?"): a swap is only taken when it improves
/// the objective by more than `eps * current_objective`, which bounds the
/// number of swaps by `O(log(n)/eps)`.  `eps = 0` reproduces plain
/// FasterPAM acceptance (modulo the FP-safety tolerance).
///
/// The candidate scan is partitioned over `pool`: a window of candidates
/// is gain-evaluated in parallel against the *frozen* state, then walked
/// in scan order; the first improving swap is applied sequentially and
/// invalidates the rest of the window, which is re-evaluated against the
/// new state.  Every gain that decides a swap is therefore computed
/// against exactly the state the serial scan would have used, so the
/// accepted swap sequence — and the final medoids — are bit-identical at
/// any thread count (`pool.threads() == 1` runs the plain serial loop).
#[allow(clippy::too_many_arguments)]
pub fn eager_loop_eps(
    d: &Matrix,
    state: &mut SwapState,
    max_passes: usize,
    eps: f64,
    rng: &mut Rng,
    counters: &Counters,
    pool: &Pool,
) -> usize {
    let mut order: Vec<usize> = (0..d.rows).collect();
    let mut swaps = 0usize;
    for _pass in 0..max_passes {
        let pass_swaps = eager_pass(d, state, eps, rng, counters, pool, &mut order);
        swaps += pass_swaps;
        if pass_swaps == 0 {
            break;
        }
    }
    swaps
}

/// One eager pass over a caller-held candidate order: shuffle `order`
/// in place, scan it, return the swaps applied this pass (`0` = local
/// optimum, the loop's stop condition).
///
/// The order slice *persists across passes on the caller's side*: pass
/// `p` scans the `p`-times-shuffled permutation, exactly like the
/// historical in-loop behaviour of [`eager_loop_eps`] — callers that
/// drive passes one at a time (the cancellation-aware loop in
/// `one_batch_pam`) must reuse one order vector across calls, or the
/// swap sequence diverges from the multi-pass call.  The acceptance
/// threshold is a pure function of the current state (recomputing it at
/// pass entry equals carrying it across passes), so pass-at-a-time
/// driving is bit-identical — asserted by
/// `external_pass_loop_matches_internal_loop_exactly` below.
#[allow(clippy::too_many_arguments)]
pub fn eager_pass(
    d: &Matrix,
    state: &mut SwapState,
    eps: f64,
    rng: &mut Rng,
    counters: &Counters,
    pool: &Pool,
    order: &mut [usize],
) -> usize {
    let n = d.rows;
    debug_assert_eq!(order.len(), n, "order must cover every candidate row");
    // The acceptance threshold only changes when the objective changes,
    // i.e. on a swap — recompute it then, not per candidate (the O(m)
    // est_objective per candidate doubled the scan cost; §Perf).
    let threshold_of = |state: &SwapState| {
        let obj = state.est_objective();
        // `gain` is the unnormalised improvement (sum over weighted
        // columns); eps is relative to the normalised objective.
        tolerance(obj).max(eps * obj.abs() * state.weight_sum())
    };
    let mut threshold = threshold_of(state);
    let window = pool.threads() * SCAN_CHUNK;
    let mut swaps = 0usize;
    rng.shuffle(order);
    if pool.is_serial() {
        // exactly the pre-parallel scan: zero overhead at 1 thread
        for &i in order.iter() {
            if state.is_medoid(i) {
                continue;
            }
            let (l, gain) = state.eval_candidate(d.row(i));
            if gain > threshold {
                state.apply_swap(d, l, i);
                counters.add_swap();
                swaps += 1;
                threshold = threshold_of(state);
            }
        }
    } else {
        let mut start = 0usize;
        while start < n {
            let end = (start + window).min(n);
            let idxs = &order[start..end];
            // Parallel speculative evaluation against the current
            // state; candidates that are (currently) medoids get -inf.
            let frozen: &SwapState = state;
            let evals: Vec<(usize, f64)> = pool
                .map_ranges(idxs.len(), |r| {
                    let mut scratch: Vec<f32> = Vec::with_capacity(frozen.k());
                    r.map(|t| {
                        let i = idxs[t];
                        if frozen.is_medoid(i) {
                            (0usize, f64::NEG_INFINITY)
                        } else {
                            frozen.eval_candidate_at(d.row(i), &mut scratch)
                        }
                    })
                    .collect::<Vec<_>>()
                })
                .into_iter()
                .flatten()
                .collect();
            // Sequential application: first improving candidate in
            // scan order wins; everything after it is stale and is
            // re-evaluated on the next round of the window loop.
            match evals.iter().position(|&(_, gain)| gain > threshold) {
                Some(off) => {
                    let (l, _) = evals[off];
                    state.apply_swap(d, l, order[start + off]);
                    counters.add_swap();
                    swaps += 1;
                    threshold = threshold_of(state);
                    start += off + 1;
                }
                None => start = end,
            }
        }
    }
    swaps
}

/// Steepest-descent (Eq. 3) swap search via the backend's gains kernel.
/// Returns the number of swaps applied.
pub fn steepest_loop(
    backend: &dyn ComputeBackend,
    d: &Matrix,
    state: &mut SwapState,
    max_swaps: usize,
    counters: &Counters,
) -> Result<usize> {
    let k = state.k();
    let mut swaps = 0usize;
    for _ in 0..max_swaps {
        let (shared, pm) = backend.gains(d, &state.dnear, &state.dsec, &state.near, k, &state.w)?;
        let rl = removal_loss(&state.dnear, &state.dsec, &state.near, k, &state.w);
        let mut best: Option<(usize, usize, f64)> = None;
        for i in 0..d.rows {
            if state.is_medoid(i) {
                continue;
            }
            let row = pm.row(i);
            let mut bl = 0usize;
            let mut bv = f32::NEG_INFINITY;
            for l in 0..k {
                let v = row[l] + rl[l];
                if v > bv {
                    bv = v;
                    bl = l;
                }
            }
            let total = shared[i] as f64 + bv as f64;
            if best.map_or(true, |(_, _, g)| total > g) {
                best = Some((i, bl, total));
            }
        }
        match best {
            Some((i, l, gain)) if gain > tolerance(state.est_objective()) => {
                state.apply_swap(d, l, i);
                counters.add_swap();
                swaps += 1;
            }
            _ => break,
        }
    }
    Ok(swaps)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::NativeBackend;
    use crate::dissim::Metric;
    use crate::rng::Rng;

    fn instance(n: usize, m: usize, k: usize, seed: u64) -> (Matrix, SwapState, Rng) {
        let mut rng = Rng::new(seed);
        let d = Matrix::from_vec(n, m, (0..n * m).map(|_| rng.f32()).collect());
        let med = rng.sample_distinct(n, k);
        let st = SwapState::init(&d, med, vec![1.0; m], n);
        (d, st, rng)
    }

    #[test]
    fn eager_reaches_local_optimum() {
        let (d, mut st, mut rng) = instance(60, 20, 4, 1);
        let counters = Counters::default();
        let before = st.est_objective();
        let swaps = eager_loop(&d, &mut st, 100, &mut rng, &counters);
        assert!(st.est_objective() <= before);
        assert_eq!(counters.swaps(), swaps as u64);
        // at a local optimum no candidate improves
        let tol = tolerance(st.est_objective());
        for i in 0..60 {
            if st.is_medoid(i) {
                continue;
            }
            let (_, gain) = st.eval_candidate(d.row(i));
            assert!(gain <= tol, "candidate {i} still improves by {gain}");
        }
    }

    #[test]
    fn steepest_matches_eager_quality_roughly() {
        let (d, st0, _) = instance(50, 16, 3, 2);
        let counters = Counters::default();
        let backend = NativeBackend::new(Metric::L1);

        let mut st_e = st0.clone();
        let mut rng = Rng::new(7);
        eager_loop(&d, &mut st_e, 100, &mut rng, &counters);

        let mut st_s = st0.clone();
        steepest_loop(&backend, &d, &mut st_s, 500, &counters).unwrap();

        // both must land at a local optimum; objectives within 10%
        let (a, b) = (st_e.est_objective(), st_s.est_objective());
        assert!((a - b).abs() / a.max(b) < 0.10, "eager {a} vs steepest {b}");
    }

    #[test]
    fn steepest_objective_monotonically_decreases() {
        let (d, mut st, _) = instance(40, 12, 3, 3);
        let counters = Counters::default();
        let backend = NativeBackend::new(Metric::L1);
        let mut prev = st.est_objective();
        loop {
            let n = steepest_loop(&backend, &d, &mut st, 1, &counters).unwrap();
            if n == 0 {
                break;
            }
            let cur = st.est_objective();
            assert!(cur < prev + 1e-9, "objective increased {prev} -> {cur}");
            prev = cur;
        }
    }

    #[test]
    fn parallel_scan_matches_serial_exactly() {
        let (d, st0, _) = instance(90, 24, 4, 9);
        let counters = Counters::default();
        let mut st_serial = st0.clone();
        let mut rng = Rng::new(5);
        let s1 = eager_loop_eps(&d, &mut st_serial, 50, 0.0, &mut rng, &counters, &Pool::serial());
        assert!(s1 > 0, "instance should admit at least one swap");
        for threads in [2, 3, 4] {
            let mut st_par = st0.clone();
            let mut rng = Rng::new(5);
            let s2 =
                eager_loop_eps(&d, &mut st_par, 50, 0.0, &mut rng, &counters, &Pool::new(threads));
            assert_eq!(s1, s2, "swap count differs at {threads} threads");
            assert_eq!(st_serial.med, st_par.med, "medoids differ at {threads} threads");
            assert_eq!(
                st_serial.est_objective().to_bits(),
                st_par.est_objective().to_bits(),
                "objective bits differ at {threads} threads"
            );
        }
    }

    #[test]
    fn external_pass_loop_matches_internal_loop_exactly() {
        // the cancellation-aware caller drives eager_pass one pass at a
        // time over a persistent order vector; that must reproduce the
        // multi-pass eager_loop_eps swap-for-swap (several passes here)
        let (d, st0, _) = instance(80, 20, 4, 12);
        let counters = Counters::default();
        for threads in [1, 3] {
            let pool = Pool::new(threads);
            let mut a = st0.clone();
            let mut rng_a = Rng::new(9);
            let sa = eager_loop_eps(&d, &mut a, 50, 0.0, &mut rng_a, &counters, &pool);
            let mut b = st0.clone();
            let mut rng_b = Rng::new(9);
            let mut order: Vec<usize> = (0..80).collect();
            let mut sb = 0usize;
            for _ in 0..50 {
                let s = eager_pass(&d, &mut b, 0.0, &mut rng_b, &counters, &pool, &mut order);
                sb += s;
                if s == 0 {
                    break;
                }
            }
            // any swap at all forces a second pass (the terminating
            // zero-swap one), which is exactly where a from-identity
            // reshuffle would diverge from the cumulative permutation
            assert!(sa >= 1, "instance should admit at least one swap");
            assert_eq!(sa, sb, "swap counts differ at {threads} threads");
            assert_eq!(a.med, b.med, "medoids differ at {threads} threads");
            assert_eq!(
                a.est_objective().to_bits(),
                b.est_objective().to_bits(),
                "objective bits differ at {threads} threads"
            );
        }
    }

    #[test]
    fn max_passes_zero_is_noop() {
        let (d, mut st, mut rng) = instance(30, 10, 3, 4);
        let counters = Counters::default();
        let med0 = st.med.clone();
        assert_eq!(eager_loop(&d, &mut st, 0, &mut rng, &counters), 0);
        assert_eq!(st.med, med0);
    }
}
