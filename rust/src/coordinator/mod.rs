//! The OneBatchPAM coordinator — the paper's system contribution.
//!
//! * [`sampler`] builds the single batch `X_m` (unif / debias / nniw /
//!   lwcs variants from the paper's Experiments section);
//! * [`state`] maintains the FasterPAM caches (near/sec per batch column,
//!   removal losses, estimated objective) with incremental swap updates;
//! * [`engine`] runs the swap search (eager Algorithm-2 loop or the
//!   steepest-descent Eq.-3 loop that exercises the XLA gains kernel);
//! * [`onebatch`] is the front door: Algorithm 1 end-to-end.

pub mod engine;
pub mod onebatch;
pub mod sampler;
pub mod state;

pub use onebatch::{one_batch_pam, OneBatchConfig, OneBatchSolver, SwapStrategy};
pub use sampler::SamplerKind;

use crate::telemetry::RunStats;

/// Result of a k-medoids run.
#[derive(Clone, Debug)]
pub struct KMedoidsResult {
    /// Selected medoid row indices into the dataset (unique, len k).
    pub medoids: Vec<usize>,
    /// Objective estimate on the batch (OneBatchPAM) or exact objective
    /// over the evaluation set the algorithm used internally.
    pub est_objective: f64,
    /// Resource usage for the run.
    pub stats: RunStats,
}

impl KMedoidsResult {
    /// Sanity invariants every algorithm must satisfy.
    pub fn validate(&self, n: usize, k: usize) {
        assert_eq!(self.medoids.len(), k, "expected {k} medoids");
        let set: std::collections::HashSet<_> = self.medoids.iter().collect();
        assert_eq!(set.len(), k, "medoids must be unique");
        assert!(self.medoids.iter().all(|&m| m < n), "medoid out of range");
    }
}
