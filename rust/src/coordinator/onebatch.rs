//! OneBatchPAM front door (the paper's Algorithm 1).
//!
//! Pipeline: sample batch -> one `n x m` pairwise computation (the only
//! dissimilarity cost, `O(n m p)`) -> optional debias mask / NNIW weights
//! -> random medoid init -> swap search on the cached matrix.

use super::engine;
use super::sampler::{self, Batch, SamplerKind};
use super::state::SwapState;
use super::KMedoidsResult;
use crate::backend::ComputeBackend;
use crate::data::{RowStore, STREAM_CHUNK_ROWS};
use crate::dissim::{ComputeProfile, DissimCounter, StreamSweep};
use crate::linalg::Matrix;
use crate::rng::Rng;
use crate::runtime::Pool;
use crate::solver::{CancelToken, CANCELLED};
use crate::telemetry::{RunStats, Timer};
use anyhow::{bail, Result};

/// Which swap engine drives the local search.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SwapStrategy {
    /// Algorithm 2: eager first-improvement scan (paper's choice).
    Eager,
    /// Eq. (3): batched best-swap via the gains kernel (XLA-friendly).
    Steepest,
}

impl SwapStrategy {
    /// Parse the CLI / wire spelling.
    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "eager" => SwapStrategy::Eager,
            "steepest" => SwapStrategy::Steepest,
            _ => return None,
        })
    }

    /// Canonical name.
    pub fn name(self) -> &'static str {
        match self {
            SwapStrategy::Eager => "eager",
            SwapStrategy::Steepest => "steepest",
        }
    }
}

/// OneBatchPAM configuration.
#[derive(Clone, Debug)]
pub struct OneBatchConfig {
    /// Number of medoids (k >= 2).
    pub k: usize,
    /// Batch variant (paper: nniw recommended).
    pub sampler: SamplerKind,
    /// Batch size; `None` -> paper default `100 * ln(k n)`.
    pub m: Option<usize>,
    /// Max eager passes (resp. max steepest swaps = k * this).
    pub max_passes: usize,
    /// Swap engine.
    pub strategy: SwapStrategy,
    /// Relative improvement threshold for accepting a swap (paper: with
    /// threshold eps the swap count is O(log(n)/eps)).  0 = any
    /// improvement (plain FasterPAM acceptance).
    pub eps: f64,
    /// PRNG seed.
    pub seed: u64,
    /// Worker threads for the eager candidate scan (`1` = serial,
    /// `0` = auto-detect).  Medoids are bit-identical at any value for a
    /// fixed seed; pair with [`crate::backend::NativeBackend::with_pool`]
    /// to also parallelise the pairwise pass.
    pub threads: usize,
    /// Cooperative cancellation: checked between swap passes; a
    /// cancelled run fails with [`crate::solver::CANCELLED`] and
    /// discards its partial work.  Default: the inert token.
    pub cancel: CancelToken,
    /// Pre-built pool for the eager scan (`None` builds a
    /// `threads`-wide pool per run).  Serving surfaces pass their
    /// cached pool so repeated jobs reuse parked workers.
    pub pool: Option<Pool>,
    /// Kernel profile this run expects from its backend (`Exact` keeps
    /// the paper-reproduction grid bit-identical; `Fast` is the
    /// serving/CLI default).  Distances are computed by the backend, so
    /// this must agree with [`crate::backend::ComputeBackend::profile`]
    /// — [`crate::solver::solve`] enforces the agreement.
    pub profile: ComputeProfile,
}

impl Default for OneBatchConfig {
    fn default() -> Self {
        OneBatchConfig {
            k: 10,
            sampler: SamplerKind::Nniw,
            m: None,
            max_passes: 20,
            strategy: SwapStrategy::Eager,
            eps: 0.0,
            seed: 0,
            threads: 1,
            cancel: CancelToken::none(),
            pool: None,
            profile: ComputeProfile::Exact,
        }
    }
}

/// Run OneBatchPAM on dataset `x` with the given backend.
pub fn one_batch_pam(
    x: &Matrix,
    cfg: &OneBatchConfig,
    backend: &dyn ComputeBackend,
) -> Result<KMedoidsResult> {
    let n = x.rows;
    assert!(cfg.k >= 2 && cfg.k < n, "need 2 <= k < n");
    let timer = Timer::start();
    debug_assert_eq!(
        cfg.profile,
        backend.profile(),
        "config profile must match the backend that computes the distances"
    );
    let counters = backend.counters();
    let dissim0 = counters.dissim();
    let swaps0 = counters.swaps();
    let mut rng = Rng::new(cfg.seed);

    // --- Batch construction (Algorithm 1, lines 3-6) -------------------
    // The sampler's own dissimilarities (Prog / Lwcs passes) go through
    // the backend's counters so dissim_count reflects the true cost.
    let counted = DissimCounter::with_counters(backend.metric(), counters.clone());
    let m = cfg.m.unwrap_or_else(|| sampler::default_batch_size(n, cfg.k));
    let batch: Batch = sampler::sample(cfg.sampler, x, m, &counted, &mut rng);
    let b = x.select_rows(&batch.indices);

    // The single O(n m p) distance computation of the method.  When the
    // batch wants NNIW weights and no self-masking, the per-row argmin
    // comes out of the same fused sweep (each output row reduced while
    // cache-hot) instead of a second walk over the n x m matrix; the
    // fused op is bit-identical to pairwise + argmin_rows, so the swap
    // sequence is unchanged.  Self-masking batches (Debias) must mask
    // *before* any argmin, so they keep the unfused path.
    let (d, w) = if batch.want_nniw && !batch.mask_self {
        let (d, idx, _) = backend.pairwise_argmin(x, &b)?;
        // NNIW reuses D: w_j = #rows whose nearest batch column is j.
        let mut counts = vec![0.0f32; d.cols];
        for &j in &idx {
            counts[j] += 1.0;
        }
        (d, counts)
    } else {
        let mut d = backend.pairwise(x, &b)?;
        if batch.mask_self {
            sampler::mask_self_distances(&mut d, &batch);
        }
        let mut w = batch.weights.clone();
        if batch.want_nniw {
            let (idx, _) = backend.argmin_rows(&d)?;
            let mut counts = vec![0.0f32; d.cols];
            for &j in &idx {
                counts[j] += 1.0;
            }
            w = counts;
        }
        (d, w)
    };

    // --- Random init + swap search (Algorithm 1, lines 7-8) ------------
    let med = rng.sample_distinct(n, cfg.k);
    let mut state = SwapState::init(&d, med, w, n);
    // Both engines run one pass per call so the cancellation token is
    // honoured between passes.  The candidate order vector persists
    // across eager passes (pass p scans the p-times-shuffled
    // permutation) and the acceptance threshold is a pure function of
    // the current state, so the swap sequence is bit-identical to the
    // historical multi-pass `eager_loop_eps` call — asserted by
    // engine::tests::external_pass_loop_matches_internal_loop_exactly.
    match cfg.strategy {
        SwapStrategy::Eager => {
            let pool = cfg.pool.clone().unwrap_or_else(|| Pool::new(cfg.threads));
            let mut order: Vec<usize> = (0..n).collect();
            for _ in 0..cfg.max_passes {
                if cfg.cancel.is_cancelled() {
                    bail!(CANCELLED);
                }
                let swaps = engine::eager_pass(
                    &d,
                    &mut state,
                    cfg.eps,
                    &mut rng,
                    &counters,
                    &pool,
                    &mut order,
                );
                if swaps == 0 {
                    break; // a full pass without a swap: local optimum
                }
            }
        }
        SwapStrategy::Steepest => {
            for _ in 0..cfg.max_passes {
                if cfg.cancel.is_cancelled() {
                    bail!(CANCELLED);
                }
                // a chunk of k swaps per "pass"; a short chunk means the
                // engine hit its tolerance -> converged
                if engine::steepest_loop(backend, &d, &mut state, cfg.k, &counters)? < cfg.k {
                    break;
                }
            }
        }
    }

    Ok(KMedoidsResult {
        medoids: state.med.clone(),
        est_objective: state.est_objective(),
        stats: RunStats {
            seconds: timer.secs(),
            dissim_count: counters.dissim() - dissim0,
            swap_count: counters.swaps() - swaps0,
        },
    })
}

/// Run OneBatchPAM over a [`RowStore`] (the out-of-core entry point).
///
/// Resident stores delegate to [`one_batch_pam`] outright.  Streaming
/// stores run the identical algorithm with every full-data pass chunked
/// through a [`StreamSweep`]: the `m` batch rows are gathered once (the
/// only resident feature slice) and the `n x m` matrix D — which *is*
/// resident, OneBatch's working state — is built chunk-at-a-time, after
/// which the swap search runs unchanged on D.  RNG consumption and
/// float-op order match the resident path exactly, so for a fixed seed
/// the medoids are bit-identical to loading the same bytes resident, at
/// any chunk size or thread width.
pub fn one_batch_pam_store(
    store: &mut dyn RowStore,
    cfg: &OneBatchConfig,
    backend: &dyn ComputeBackend,
) -> Result<KMedoidsResult> {
    if let Some(x) = store.as_matrix() {
        return one_batch_pam(x, cfg, backend);
    }
    let (n, p) = store.dims();
    assert!(cfg.k >= 2 && cfg.k < n, "need 2 <= k < n");
    let timer = Timer::start();
    debug_assert_eq!(
        cfg.profile,
        backend.profile(),
        "config profile must match the backend that computes the distances"
    );
    let counters = backend.counters();
    let dissim0 = counters.dissim();
    let swaps0 = counters.swaps();
    let mut rng = Rng::new(cfg.seed);

    // --- Batch construction (streamed) ---------------------------------
    let counted = DissimCounter::with_counters(backend.metric(), counters.clone());
    let m = cfg.m.unwrap_or_else(|| sampler::default_batch_size(n, cfg.k));
    let batch: Batch = sampler::sample_store(cfg.sampler, store, m, &counted, &mut rng)?;
    let mut bdata = vec![0.0f32; batch.indices.len() * p];
    store.gather_rows(&batch.indices, &mut bdata)?;
    let b = Matrix::from_vec(batch.indices.len(), p, bdata);

    // The single O(n m p) distance computation, driven chunk-at-a-time.
    // Same fused / unfused split as the resident path: NNIW-without-mask
    // reduces each output row while cache-hot; Debias masks *before* any
    // argmin on the assembled (resident) D.
    let pool = cfg.pool.clone().unwrap_or_else(|| Pool::new(cfg.threads));
    let mut sweep = StreamSweep::new(STREAM_CHUNK_ROWS);
    let (d, w) = if batch.want_nniw && !batch.mask_self {
        let (d, idx, _) = sweep.argmin(&counted, store, &b, &pool, cfg.profile)?;
        let mut counts = vec![0.0f32; d.cols];
        for &j in &idx {
            counts[j] += 1.0;
        }
        (d, counts)
    } else {
        let mut d = sweep.matrix(&counted, store, &b, &pool, cfg.profile)?;
        if batch.mask_self {
            sampler::mask_self_distances(&mut d, &batch);
        }
        let mut w = batch.weights.clone();
        if batch.want_nniw {
            let (idx, _) = backend.argmin_rows(&d)?;
            let mut counts = vec![0.0f32; d.cols];
            for &j in &idx {
                counts[j] += 1.0;
            }
            w = counts;
        }
        (d, w)
    };

    // --- Random init + swap search: unchanged, D is resident -----------
    let med = rng.sample_distinct(n, cfg.k);
    let mut state = SwapState::init(&d, med, w, n);
    match cfg.strategy {
        SwapStrategy::Eager => {
            let mut order: Vec<usize> = (0..n).collect();
            for _ in 0..cfg.max_passes {
                if cfg.cancel.is_cancelled() {
                    bail!(CANCELLED);
                }
                let swaps = engine::eager_pass(
                    &d,
                    &mut state,
                    cfg.eps,
                    &mut rng,
                    &counters,
                    &pool,
                    &mut order,
                );
                if swaps == 0 {
                    break;
                }
            }
        }
        SwapStrategy::Steepest => {
            for _ in 0..cfg.max_passes {
                if cfg.cancel.is_cancelled() {
                    bail!(CANCELLED);
                }
                if engine::steepest_loop(backend, &d, &mut state, cfg.k, &counters)? < cfg.k {
                    break;
                }
            }
        }
    }

    Ok(KMedoidsResult {
        medoids: state.med.clone(),
        est_objective: state.est_objective(),
        stats: RunStats {
            seconds: timer.secs(),
            dissim_count: counters.dissim() - dissim0,
            swap_count: counters.swaps() - swaps0,
        },
    })
}

/// [`crate::solver::Solver`] adapter for [`one_batch_pam`]: the batch
/// variant and swap engine live here; batch size / eps / pass budget
/// come from the [`crate::solver::SolveSpec`].
pub struct OneBatchSolver {
    /// Batch construction variant.
    pub sampler: SamplerKind,
    /// Swap engine.
    pub strategy: SwapStrategy,
}

impl crate::solver::Solver for OneBatchSolver {
    fn label(&self) -> String {
        match self.strategy {
            SwapStrategy::Eager => format!("OneBatch-{}", self.sampler.name()),
            SwapStrategy::Steepest => format!("OneBatch-{}-steepest", self.sampler.name()),
        }
    }

    fn solve(
        &self,
        x: &Matrix,
        spec: &crate::solver::SolveSpec,
        backend: &dyn ComputeBackend,
    ) -> Result<KMedoidsResult> {
        let cfg = OneBatchConfig {
            k: spec.k,
            sampler: self.sampler,
            m: spec.m,
            max_passes: spec.max_passes,
            strategy: self.strategy,
            eps: spec.eps,
            seed: spec.seed,
            threads: spec.threads,
            cancel: spec.cancel.clone(),
            pool: spec.pool.clone(),
            profile: spec.profile,
        };
        one_batch_pam(x, &cfg, backend)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::NativeBackend;
    use crate::data::synth;
    use crate::dissim::Metric;

    fn blobs(n: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        synth::gen_gaussian_mixture(&mut rng, n, 4, 3, 0.1, 1.0)
    }

    fn run(cfg: &OneBatchConfig, x: &Matrix) -> KMedoidsResult {
        let backend = NativeBackend::new(Metric::L1);
        let r = one_batch_pam(x, cfg, &backend).unwrap();
        r.validate(x.rows, cfg.k);
        r
    }

    #[test]
    fn produces_valid_result_all_samplers() {
        let x = blobs(200, 1);
        for sampler in SamplerKind::all() {
            let cfg = OneBatchConfig { k: 3, sampler, m: Some(40), seed: 2, ..Default::default() };
            let r = run(&cfg, &x);
            assert!(r.est_objective.is_finite());
            assert!(r.stats.dissim_count > 0);
        }
    }

    #[test]
    fn dissim_count_is_n_times_m_for_unif() {
        let x = blobs(150, 3);
        let cfg = OneBatchConfig {
            k: 3,
            sampler: SamplerKind::Unif,
            m: Some(30),
            seed: 1,
            ..Default::default()
        };
        let r = run(&cfg, &x);
        // the whole run computes exactly n*m dissimilarities
        assert_eq!(r.stats.dissim_count, 150 * 30);
    }

    #[test]
    fn beats_random_selection_on_clustered_data() {
        let x = blobs(300, 4);
        let backend = NativeBackend::new(Metric::L1);
        let cfg = OneBatchConfig { k: 3, m: Some(60), seed: 5, ..Default::default() };
        let r = one_batch_pam(&x, &cfg, &backend).unwrap();
        // random baseline objective (exact, on full data)
        let mut rng = Rng::new(6);
        let rand_med = rng.sample_distinct(300, 3);
        let full_obj = |med: &[usize]| -> f64 {
            (0..300)
                .map(|i| {
                    med.iter()
                        .map(|&mm| Metric::L1.eval(x.row(i), x.row(mm)))
                        .fold(f32::INFINITY, f32::min) as f64
                })
                .sum::<f64>()
                / 300.0
        };
        assert!(
            full_obj(&r.medoids) < full_obj(&rand_med),
            "OneBatchPAM should beat a random selection"
        );
    }

    #[test]
    fn steepest_strategy_runs() {
        let x = blobs(120, 7);
        let cfg = OneBatchConfig {
            k: 3,
            m: Some(30),
            strategy: SwapStrategy::Steepest,
            seed: 3,
            ..Default::default()
        };
        let r = run(&cfg, &x);
        assert!(r.est_objective.is_finite());
    }

    #[test]
    fn eps_threshold_reduces_swap_count() {
        let x = blobs(250, 12);
        let backend = NativeBackend::new(Metric::L1);
        let tight = one_batch_pam(
            &x,
            &OneBatchConfig { k: 4, m: Some(60), eps: 0.0, seed: 2, ..Default::default() },
            &backend,
        )
        .unwrap();
        let loose = one_batch_pam(
            &x,
            &OneBatchConfig { k: 4, m: Some(60), eps: 0.05, seed: 2, ..Default::default() },
            &backend,
        )
        .unwrap();
        assert!(
            loose.stats.swap_count <= tight.stats.swap_count,
            "eps=0.05 did {} swaps vs {} at eps=0",
            loose.stats.swap_count,
            tight.stats.swap_count
        );
    }

    #[test]
    fn progressive_sampler_covers_outliers() {
        // a far-away mini-cluster that uniform batches often miss
        let mut rng = Rng::new(21);
        let mut x = synth::gen_gaussian_mixture(&mut rng, 380, 3, 2, 0.1, 1.0);
        for i in 0..20 {
            let row = x.row_mut(i);
            for v in row.iter_mut() {
                *v += 60.0; // 20 distant outliers
            }
        }
        let backend = NativeBackend::new(Metric::L1);
        let cfg = OneBatchConfig {
            k: 3,
            sampler: SamplerKind::Prog,
            m: Some(50),
            seed: 4,
            ..Default::default()
        };
        let r = one_batch_pam(&x, &cfg, &backend).unwrap();
        // with progressive batching the outlier cluster gets a medoid
        assert!(
            r.medoids.iter().any(|&m| m < 20),
            "no medoid in the outlier cluster: {:?}",
            r.medoids
        );
    }

    #[test]
    fn deterministic_given_seed() {
        let x = blobs(100, 8);
        let cfg = OneBatchConfig { k: 4, m: Some(25), seed: 11, ..Default::default() };
        assert_eq!(run(&cfg, &x).medoids, run(&cfg, &x).medoids);
    }

    #[test]
    fn medoids_identical_across_thread_counts() {
        let x = blobs(300, 10);
        let base = OneBatchConfig { k: 4, m: Some(60), seed: 9, ..Default::default() };
        let serial = run(&base, &x);
        for threads in [0, 2, 4] {
            let cfg = OneBatchConfig { threads, ..base.clone() };
            let r = run(&cfg, &x);
            assert_eq!(r.medoids, serial.medoids, "threads={threads}");
        }
    }

    #[test]
    fn cancelled_token_aborts_between_passes() {
        let x = blobs(200, 5);
        let backend = NativeBackend::new(Metric::L1);
        let token = CancelToken::new();
        token.cancel();
        let cfg =
            OneBatchConfig { k: 3, m: Some(40), seed: 2, cancel: token, ..Default::default() };
        let err = one_batch_pam(&x, &cfg, &backend).unwrap_err().to_string();
        assert_eq!(err, CANCELLED);
    }

    #[test]
    fn caller_supplied_pool_selects_identical_medoids_across_reuse() {
        // the serving shape: one cached pool drives repeated solves
        let x = blobs(250, 6);
        let base = OneBatchConfig { k: 4, m: Some(50), seed: 3, ..Default::default() };
        let serial = run(&base, &x);
        let pool = Pool::new(4);
        for round in 0..3 {
            let cfg = OneBatchConfig { threads: 4, pool: Some(pool.clone()), ..base.clone() };
            let r = run(&cfg, &x);
            assert_eq!(r.medoids, serial.medoids, "round {round}");
        }
    }

    fn npy_store_of(x: &Matrix, name: &str) -> crate::data::store::NpyStore {
        let dir = std::env::temp_dir().join(format!("obpam_ob_{}_{}", std::process::id(), name));
        let _ = std::fs::create_dir_all(&dir);
        let path = dir.join(format!("{name}.npy"));
        crate::data::npy::write_npy(&path, x).unwrap();
        crate::data::store::NpyStore::open(&path).unwrap()
    }

    #[test]
    fn streaming_solve_is_bit_identical_to_resident() {
        // every sampler x both strategies: the npy-backed streaming run
        // must reproduce the resident medoids, objective bits, and
        // dissimilarity count exactly
        let x = blobs(220, 31);
        for sampler in SamplerKind::all() {
            for strategy in [SwapStrategy::Eager, SwapStrategy::Steepest] {
                let cfg = OneBatchConfig {
                    k: 4,
                    sampler,
                    strategy,
                    m: Some(40),
                    seed: 7,
                    ..Default::default()
                };
                let backend = NativeBackend::new(Metric::L1);
                let resident = one_batch_pam(&x, &cfg, &backend).unwrap();
                let mut store = npy_store_of(&x, &format!("bit_{}_{}", sampler.name(), strategy.name()));
                let backend2 = NativeBackend::new(Metric::L1);
                let streamed = one_batch_pam_store(&mut store, &cfg, &backend2).unwrap();
                let tag = format!("{}/{}", sampler.name(), strategy.name());
                assert_eq!(resident.medoids, streamed.medoids, "{tag}");
                assert_eq!(
                    resident.est_objective.to_bits(),
                    streamed.est_objective.to_bits(),
                    "{tag}"
                );
                assert_eq!(resident.stats.dissim_count, streamed.stats.dissim_count, "{tag}");
            }
        }
    }

    #[test]
    fn streaming_solve_is_thread_invariant() {
        let x = blobs(260, 33);
        let base = OneBatchConfig { k: 4, m: Some(50), seed: 13, ..Default::default() };
        let backend = NativeBackend::new(Metric::L1);
        let serial = one_batch_pam(&x, &base, &backend).unwrap();
        for threads in [1, 4] {
            let cfg = OneBatchConfig { threads, ..base.clone() };
            let mut store = npy_store_of(&x, &format!("thr{threads}"));
            let backend = NativeBackend::new(Metric::L1);
            let r = one_batch_pam_store(&mut store, &cfg, &backend).unwrap();
            assert_eq!(r.medoids, serial.medoids, "threads={threads}");
        }
    }

    #[test]
    fn store_entry_point_delegates_for_resident_stores() {
        let x = blobs(150, 35);
        let cfg = OneBatchConfig { k: 3, m: Some(30), seed: 5, ..Default::default() };
        let backend = NativeBackend::new(Metric::L1);
        let direct = one_batch_pam(&x, &cfg, &backend).unwrap();
        let mut store = crate::data::store::ResidentStore::new(x);
        let backend2 = NativeBackend::new(Metric::L1);
        let via = one_batch_pam_store(&mut store, &cfg, &backend2).unwrap();
        assert_eq!(direct.medoids, via.medoids);
        assert_eq!(direct.est_objective.to_bits(), via.est_objective.to_bits());
    }

    #[test]
    fn m_defaults_to_paper_formula_and_caps_at_n() {
        let x = blobs(80, 9);
        // paper default would exceed n=80 -> capped, still valid
        let cfg = OneBatchConfig { k: 3, m: None, seed: 1, ..Default::default() };
        let r = run(&cfg, &x);
        assert_eq!(r.stats.dissim_count, 80 * 80);
    }
}
