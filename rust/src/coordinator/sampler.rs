//! Batch construction: the four sampling variants of the paper.
//!
//! * `Unif`   — uniform without replacement (Theorem 1's setting);
//! * `Debias` — uniform + `d(x_sigma(j), x_sigma(j)) = BIG` so batch
//!   points get no free self-distance (prevents medoid bias toward the
//!   batch);
//! * `Nniw`   — uniform + nearest-neighbour importance weighting
//!   (Loog 2012): w_j = #points whose nearest batch column is j.  Uses
//!   the already-computed n x m matrix, so it is essentially free;
//! * `Lwcs`   — lightweight-coreset sampling (Bachem et al. 2018):
//!   q(x) = 1/2n + d(x, mean)^2 / 2 sum d(., mean)^2, weights 1/q;
//! * `Prog`   — progressive batch construction (the paper's "Overfitting
//!   for highly imbalanced datasets" future-work idea): seed half the
//!   batch uniformly, then grow it by D-sampling points that are far from
//!   the current batch, so sparse/distant regions get covered.

use crate::data::{RowStore, STREAM_CHUNK_ROWS};
use crate::dissim::{DissimCounter, BIG};
use crate::linalg::Matrix;
use crate::rng::Rng;
use anyhow::Result;

/// Which batch variant to run (paper Table 3's OneBatchPAM rows).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SamplerKind {
    /// Uniform sampling.
    Unif,
    /// Uniform + self-distance masking.
    Debias,
    /// Uniform + nearest-neighbour importance weighting (paper's best).
    Nniw,
    /// Lightweight coreset sampling.
    Lwcs,
    /// Progressive batch construction (paper's future-work idea).
    Prog,
}

impl SamplerKind {
    /// Parse the CLI spelling.
    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "unif" | "uniform" => SamplerKind::Unif,
            "debias" => SamplerKind::Debias,
            "nniw" => SamplerKind::Nniw,
            "lwcs" => SamplerKind::Lwcs,
            "prog" | "progressive" => SamplerKind::Prog,
            _ => return None,
        })
    }

    /// Canonical name.
    pub fn name(self) -> &'static str {
        match self {
            SamplerKind::Unif => "unif",
            SamplerKind::Debias => "debias",
            SamplerKind::Nniw => "nniw",
            SamplerKind::Lwcs => "lwcs",
            SamplerKind::Prog => "prog",
        }
    }

    /// The paper's four variants (Table 3 rows).
    pub fn paper() -> [SamplerKind; 4] {
        [SamplerKind::Unif, SamplerKind::Debias, SamplerKind::Nniw, SamplerKind::Lwcs]
    }

    /// All variants including this repo's extension (ablation sweeps).
    pub fn all() -> [SamplerKind; 5] {
        [
            SamplerKind::Unif,
            SamplerKind::Debias,
            SamplerKind::Nniw,
            SamplerKind::Lwcs,
            SamplerKind::Prog,
        ]
    }
}

/// A constructed batch: indices into the dataset plus initial weights.
#[derive(Clone, Debug)]
pub struct Batch {
    /// sigma: batch column j -> dataset row sigma(j).
    pub indices: Vec<usize>,
    /// Per-column weights (1 for unif/debias until NNIW updates them).
    pub weights: Vec<f32>,
    /// Whether self-distances must be masked to BIG after the pairwise
    /// computation (debias variant).
    pub mask_self: bool,
    /// Whether NNIW weights should be computed from the distance matrix.
    pub want_nniw: bool,
}

/// Paper default batch size: `m = 100 * log(k * n)` (natural log),
/// clamped to `[k + 1, n]`.
pub fn default_batch_size(n: usize, k: usize) -> usize {
    let m = (100.0 * ((k as f64) * (n as f64)).ln()).ceil() as usize;
    m.clamp((k + 1).min(n), n)
}

/// Draw the batch according to `kind`.
///
/// Every dissimilarity the sampler itself computes goes through the
/// counted evaluator `d`, so `stats.dissim_count` reflects the *true*
/// per-variant cost (Table 1): `Prog` adds one `O(n)` pass per batch
/// point (`n * |batch|` total) and `Lwcs` adds the `O(n)` mean-distance
/// pass for its q-distribution; the uniform variants add nothing.
pub fn sample(kind: SamplerKind, x: &Matrix, m: usize, d: &DissimCounter, rng: &mut Rng) -> Batch {
    let n = x.rows;
    let m = m.min(n);
    match kind {
        SamplerKind::Unif | SamplerKind::Debias | SamplerKind::Nniw => Batch {
            indices: rng.sample_distinct(n, m),
            weights: vec![1.0; m],
            mask_self: kind == SamplerKind::Debias,
            want_nniw: kind == SamplerKind::Nniw,
        },
        SamplerKind::Prog => {
            // seed half uniformly, then D-sample far-from-batch points
            let seed_m = (m / 2).max(1);
            let mut chosen = rng.sample_distinct(n, seed_m);
            let mut in_batch = vec![false; n];
            let mut dmin = vec![f32::INFINITY; n];
            for &j in &chosen {
                in_batch[j] = true;
            }
            // per-seed-point min sweeps: same evaluations as an i-outer
            // double loop (min over a set is order-independent under
            // strict `<`), but each pass streams x once
            for &j in &chosen {
                d.min_into_rows(x, x.row(j), &mut dmin);
            }
            while chosen.len() < m {
                let weights: Vec<f64> = dmin
                    .iter()
                    .enumerate()
                    .map(|(i, &v)| if in_batch[i] { 0.0 } else { v as f64 })
                    .collect();
                let c = rng.weighted(&weights);
                if in_batch[c] {
                    break; // all remaining mass is zero (duplicates)
                }
                in_batch[c] = true;
                chosen.push(c);
                d.min_into_rows(x, x.row(c), &mut dmin);
            }
            let mlen = chosen.len();
            Batch { indices: chosen, weights: vec![1.0; mlen], mask_self: false, want_nniw: true }
        }
        SamplerKind::Lwcs => {
            // mean point
            let p = x.cols;
            let mut mean = vec![0.0f32; p];
            for i in 0..n {
                for (mj, v) in mean.iter_mut().zip(x.row(i)) {
                    *mj += v;
                }
            }
            for v in &mut mean {
                *v /= n as f32;
            }
            // q(x) = 1/(2n) + d(x, mean)^2 / (2 * sum); one batched
            // point-to-rows pass (n evaluations, same count as before)
            let d2: Vec<f64> = d
                .rows_to_point(x, &mean)
                .into_iter()
                .map(|v| {
                    let v = v as f64;
                    v * v
                })
                .collect();
            let total: f64 = d2.iter().sum::<f64>().max(1e-30);
            let q: Vec<f64> = d2
                .iter()
                .map(|&v| 0.5 / n as f64 + 0.5 * v / total)
                .collect();
            // sample WITH replacement per the coreset construction, then
            // dedupe accumulating 1/q weights on repeats.
            let mut weight_of: std::collections::HashMap<usize, f64> = Default::default();
            let mut order: Vec<usize> = Vec::new();
            for _ in 0..m {
                let i = rng.weighted(&q);
                if !weight_of.contains_key(&i) {
                    order.push(i);
                }
                *weight_of.entry(i).or_insert(0.0) += 1.0 / (m as f64 * q[i]);
            }
            let weights: Vec<f32> = order.iter().map(|i| weight_of[i] as f32).collect();
            Batch { indices: order, weights, mask_self: false, want_nniw: false }
        }
    }
}

/// Streaming twin of [`sample`]: the same batch, bit for bit, drawn
/// over a [`RowStore`] instead of a resident matrix.
///
/// RNG consumption and float-op order are identical to [`sample`] for
/// every variant: the uniform family touches no data at all, `Prog`
/// replays each per-point min sweep through
/// [`DissimCounter::min_into_store`] (same strict `<`, same ascending
/// row order), and `Lwcs` accumulates its mean and q-distribution over
/// ascending chunks — so a resident store delegates outright and a
/// streaming store reproduces the resident batch exactly.
pub fn sample_store(
    kind: SamplerKind,
    store: &mut dyn RowStore,
    m: usize,
    d: &DissimCounter,
    rng: &mut Rng,
) -> Result<Batch> {
    if let Some(x) = store.as_matrix() {
        return Ok(sample(kind, x, m, d, rng));
    }
    let (n, p) = store.dims();
    let m = m.min(n);
    Ok(match kind {
        SamplerKind::Unif | SamplerKind::Debias | SamplerKind::Nniw => Batch {
            indices: rng.sample_distinct(n, m),
            weights: vec![1.0; m],
            mask_self: kind == SamplerKind::Debias,
            want_nniw: kind == SamplerKind::Nniw,
        },
        SamplerKind::Prog => {
            let seed_m = (m / 2).max(1);
            let mut chosen = rng.sample_distinct(n, seed_m);
            let mut in_batch = vec![false; n];
            let mut dmin = vec![f32::INFINITY; n];
            for &j in &chosen {
                in_batch[j] = true;
            }
            let mut chunk = vec![0.0f32; STREAM_CHUNK_ROWS.min(n).max(1) * p];
            let mut point = vec![0.0f32; p];
            for idx in 0..chosen.len() {
                store.gather_rows(&chosen[idx..idx + 1], &mut point)?;
                d.min_into_store(store, &point, &mut dmin, &mut chunk)?;
            }
            while chosen.len() < m {
                let weights: Vec<f64> = dmin
                    .iter()
                    .enumerate()
                    .map(|(i, &v)| if in_batch[i] { 0.0 } else { v as f64 })
                    .collect();
                let c = rng.weighted(&weights);
                if in_batch[c] {
                    break; // all remaining mass is zero (duplicates)
                }
                in_batch[c] = true;
                chosen.push(c);
                store.gather_rows(&chosen[chosen.len() - 1..], &mut point)?;
                d.min_into_store(store, &point, &mut dmin, &mut chunk)?;
            }
            let mlen = chosen.len();
            Batch { indices: chosen, weights: vec![1.0; mlen], mask_self: false, want_nniw: true }
        }
        SamplerKind::Lwcs => {
            // mean point, accumulated chunk-by-chunk in the same
            // ascending row order as the resident pass
            let mut mean = vec![0.0f32; p];
            let mut chunk = vec![0.0f32; STREAM_CHUNK_ROWS.min(n).max(1) * p];
            let mut row0 = 0usize;
            while row0 < n {
                let xs = store.read_chunk(row0, &mut chunk)?;
                let rows = xs.len() / p;
                for i in 0..rows {
                    for (mj, v) in mean.iter_mut().zip(&xs[i * p..(i + 1) * p]) {
                        *mj += v;
                    }
                }
                row0 += rows;
            }
            for v in &mut mean {
                *v /= n as f32;
            }
            let d2: Vec<f64> = d
                .store_to_point(store, &mean, &mut chunk)?
                .into_iter()
                .map(|v| {
                    let v = v as f64;
                    v * v
                })
                .collect();
            let total: f64 = d2.iter().sum::<f64>().max(1e-30);
            let q: Vec<f64> = d2
                .iter()
                .map(|&v| 0.5 / n as f64 + 0.5 * v / total)
                .collect();
            let mut weight_of: std::collections::HashMap<usize, f64> = Default::default();
            let mut order: Vec<usize> = Vec::new();
            for _ in 0..m {
                let i = rng.weighted(&q);
                if !weight_of.contains_key(&i) {
                    order.push(i);
                }
                *weight_of.entry(i).or_insert(0.0) += 1.0 / (m as f64 * q[i]);
            }
            let weights: Vec<f32> = order.iter().map(|i| weight_of[i] as f32).collect();
            Batch { indices: order, weights, mask_self: false, want_nniw: false }
        }
    })
}

/// Apply the debias mask in place: `d[sigma(j), j] = BIG`.
pub fn mask_self_distances(d: &mut Matrix, batch: &Batch) {
    for (j, &i) in batch.indices.iter().enumerate() {
        d.set(i, j, BIG);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dissim::Metric;
    use crate::rng::Rng;

    fn blob(n: usize, p: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        Matrix::from_vec(n, p, (0..n * p).map(|_| rng.f32()).collect())
    }

    fn counter(metric: Metric) -> DissimCounter {
        DissimCounter::new(metric)
    }

    #[test]
    fn default_size_grows_logarithmically() {
        let m1 = default_batch_size(1_000, 10);
        let m2 = default_batch_size(100_000, 10);
        assert!(m2 > m1);
        assert!(m2 - m1 < 500, "log growth expected, got {m1} -> {m2}");
        // paper: m = 100 log(k n); n=60000, k=10 -> ~1330
        let m = default_batch_size(60_000, 10);
        assert!((1_300..1_400).contains(&m), "{m}");
    }

    #[test]
    fn default_size_clamped_to_n() {
        assert_eq!(default_batch_size(50, 10), 50);
    }

    #[test]
    fn unif_indices_distinct_weights_one() {
        let x = blob(100, 3, 1);
        let mut rng = Rng::new(2);
        let b = sample(SamplerKind::Unif, &x, 20, &counter(Metric::L1), &mut rng);
        assert_eq!(b.indices.len(), 20);
        let set: std::collections::HashSet<_> = b.indices.iter().collect();
        assert_eq!(set.len(), 20);
        assert!(b.weights.iter().all(|&w| w == 1.0));
        assert!(!b.mask_self && !b.want_nniw);
    }

    #[test]
    fn debias_and_nniw_flags() {
        let x = blob(50, 3, 3);
        let mut rng = Rng::new(4);
        assert!(sample(SamplerKind::Debias, &x, 10, &counter(Metric::L1), &mut rng).mask_self);
        assert!(sample(SamplerKind::Nniw, &x, 10, &counter(Metric::L1), &mut rng).want_nniw);
    }

    #[test]
    fn lwcs_weights_positive_and_mass_near_one() {
        let x = blob(200, 4, 5);
        let mut rng = Rng::new(6);
        let b = sample(SamplerKind::Lwcs, &x, 60, &counter(Metric::L2), &mut rng);
        assert!(!b.indices.is_empty());
        assert!(b.weights.iter().all(|&w| w > 0.0));
        // importance weights sum to ~n in expectation (each term 1/(m q))
        let total: f32 = b.weights.iter().sum();
        assert!(total > 50.0 && total < 800.0, "total weight {total}");
    }

    #[test]
    fn uniform_family_computes_no_dissims() {
        let x = blob(80, 3, 12);
        for kind in [SamplerKind::Unif, SamplerKind::Debias, SamplerKind::Nniw] {
            let d = counter(Metric::L1);
            let mut rng = Rng::new(13);
            sample(kind, &x, 16, &d, &mut rng);
            assert_eq!(d.count(), 0, "{} should be dissimilarity-free", kind.name());
        }
    }

    #[test]
    fn lwcs_counts_exactly_one_mean_pass() {
        // The q-distribution costs exactly n point-to-mean evaluations.
        let n = 150;
        let x = blob(n, 4, 14);
        let d = counter(Metric::L2);
        let mut rng = Rng::new(15);
        sample(SamplerKind::Lwcs, &x, 40, &d, &mut rng);
        assert_eq!(d.count(), n as u64);
    }

    #[test]
    fn prog_counts_exactly_one_pass_per_batch_point() {
        // Seeding evaluates n * seed_m, then each grown point one O(n)
        // pass: n * |batch| total, no more, no less.
        let n = 120;
        let x = blob(n, 3, 16);
        let d = counter(Metric::L1);
        let mut rng = Rng::new(17);
        let b = sample(SamplerKind::Prog, &x, 24, &d, &mut rng);
        assert_eq!(d.count(), (n * b.indices.len()) as u64);
    }

    #[test]
    fn mask_self_sets_big() {
        let x = blob(10, 2, 7);
        let mut rng = Rng::new(8);
        let b = sample(SamplerKind::Debias, &x, 4, &counter(Metric::L1), &mut rng);
        let mut d = Matrix::zeros(10, 4);
        mask_self_distances(&mut d, &b);
        for (j, &i) in b.indices.iter().enumerate() {
            assert_eq!(d.get(i, j), BIG);
        }
    }

    /// Streaming store over a resident matrix that refuses `as_matrix`
    /// and caps every chunk at `max_rows`, forcing arbitrary seams.
    struct Forced {
        x: Matrix,
        max_rows: usize,
    }

    impl RowStore for Forced {
        fn dims(&self) -> (usize, usize) {
            (self.x.rows, self.x.cols)
        }

        fn read_chunk<'a>(&'a mut self, row0: usize, buf: &'a mut [f32]) -> Result<&'a [f32]> {
            let (n, p) = (self.x.rows, self.x.cols);
            let fit = (buf.len() / p).min(self.max_rows).min(n - row0).max(1);
            let src = &self.x.data[row0 * p..(row0 + fit) * p];
            buf[..src.len()].copy_from_slice(src);
            Ok(&buf[..src.len()])
        }

        fn gather_rows(&mut self, ids: &[usize], out: &mut [f32]) -> Result<()> {
            crate::data::store::gather_from_matrix(&self.x, ids, out)
        }
    }

    #[test]
    fn sample_store_matches_resident_sample_at_every_seam() {
        // every variant, several forced chunk seams: identical indices,
        // weights (bit for bit), flags, and counter totals
        let n = 90;
        let x = blob(n, 4, 21);
        for kind in SamplerKind::all() {
            for max_rows in [1, 3, 37, n] {
                let dr = counter(Metric::L2);
                let mut rr = Rng::new(9);
                let resident = sample(kind, &x, 24, &dr, &mut rr);
                let ds = counter(Metric::L2);
                let mut rs = Rng::new(9);
                let mut store = Forced { x: x.clone(), max_rows };
                let streamed = sample_store(kind, &mut store, 24, &ds, &mut rs).unwrap();
                assert_eq!(resident.indices, streamed.indices, "{} @{max_rows}", kind.name());
                assert_eq!(resident.weights, streamed.weights, "{} @{max_rows}", kind.name());
                assert_eq!(resident.mask_self, streamed.mask_self);
                assert_eq!(resident.want_nniw, streamed.want_nniw);
                assert_eq!(dr.count(), ds.count(), "{} @{max_rows}", kind.name());
            }
        }
    }

    #[test]
    fn sample_store_delegates_for_resident_stores() {
        let x = blob(60, 3, 22);
        let d = counter(Metric::L1);
        let mut rng = Rng::new(23);
        let direct = sample(SamplerKind::Prog, &x, 12, &d, &mut rng);
        let mut store = crate::data::store::ResidentStore::new(x);
        let d2 = counter(Metric::L1);
        let mut rng2 = Rng::new(23);
        let via = sample_store(SamplerKind::Prog, &mut store, 12, &d2, &mut rng2).unwrap();
        assert_eq!(direct.indices, via.indices);
        assert_eq!(d.count(), d2.count());
    }

    #[test]
    fn parse_round_trips() {
        for k in SamplerKind::all() {
            assert_eq!(SamplerKind::parse(k.name()), Some(k));
        }
        assert_eq!(SamplerKind::parse("zzz"), None);
    }
}
