//! Swap-loop state: the FasterPAM caches over the batch columns.
//!
//! The central trick of OneBatchPAM: because medoids are dataset rows and
//! the `n x m` matrix `D` holds distances from *every* dataset row to the
//! batch, the medoid-to-batch distances are just rows of `D` — no new
//! dissimilarity computations are ever needed during the swap search.
//!
//! Maintained per batch column `j`:
//!   * `near[j]` / `dnear[j]` — slot + distance of the nearest medoid;
//!   * `sec[j]`  / `dsec[j]`  — slot + distance of the second nearest;
//! and per medoid slot `l`:
//!   * `rloss[l]` — candidate-independent removal gain (negative).
//!
//! `apply_swap` updates the caches incrementally: only columns whose
//! nearest/second medoid is the removed slot need an `O(k)` recompute,
//! which is `O(m)` expected work per swap instead of `O(k m)`.

use crate::linalg::Matrix;

/// FasterPAM cache state over the batch (see module docs).
#[derive(Clone, Debug)]
pub struct SwapState {
    /// Medoid dataset-row index per slot.
    pub med: Vec<usize>,
    /// Is dataset row i currently a medoid?
    is_med: Vec<bool>,
    /// Nearest medoid slot per batch column.
    pub near: Vec<usize>,
    /// Distance to the nearest medoid per batch column.
    pub dnear: Vec<f32>,
    /// Second nearest medoid slot per batch column.
    pub sec: Vec<usize>,
    /// Distance to the second nearest medoid per batch column.
    pub dsec: Vec<f32>,
    /// Batch column weights.
    pub w: Vec<f32>,
    /// Removal gain per slot (negative): sum_j w_j (dnear-dsec) [near==l].
    pub rloss: Vec<f32>,
    /// Scratch per-slot gain accumulator (avoids per-candidate allocation).
    scratch: Vec<f32>,
    wsum: f64,
}

impl SwapState {
    /// Build the caches from the `n x m` matrix, initial medoid rows and
    /// batch weights.  Requires `k >= 2`.
    pub fn init(d: &Matrix, med: Vec<usize>, w: Vec<f32>, n: usize) -> Self {
        let k = med.len();
        assert!(k >= 2, "k >= 2 required (second-nearest cache)");
        let m = d.cols;
        assert_eq!(w.len(), m);
        let mut is_med = vec![false; n];
        for &mi in &med {
            is_med[mi] = true;
        }
        let mut st = SwapState {
            med,
            is_med,
            near: vec![0; m],
            dnear: vec![0.0; m],
            sec: vec![0; m],
            dsec: vec![0.0; m],
            wsum: w.iter().map(|&x| x as f64).sum(),
            w,
            rloss: vec![0.0; k],
            scratch: vec![0.0; k],
        };
        for j in 0..m {
            st.recompute_column(d, j);
        }
        st.rebuild_rloss();
        st
    }

    /// Number of medoids.
    pub fn k(&self) -> usize {
        self.med.len()
    }

    /// Is dataset row `i` currently a medoid?
    #[inline]
    pub fn is_medoid(&self, i: usize) -> bool {
        self.is_med[i]
    }

    /// Total batch weight `sum_j w_j` (normaliser of the objective).
    pub fn weight_sum(&self) -> f64 {
        self.wsum
    }

    /// Weighted batch objective estimate `sum w dnear / sum w`.
    pub fn est_objective(&self) -> f64 {
        let s: f64 = self
            .dnear
            .iter()
            .zip(&self.w)
            .map(|(&d, &w)| d as f64 * w as f64)
            .sum();
        s / self.wsum.max(1e-30)
    }

    /// Full `O(k)` top-2 recompute for one column.
    fn recompute_column(&mut self, d: &Matrix, j: usize) {
        let (mut i1, mut v1, mut i2, mut v2) = (0usize, f32::INFINITY, 0usize, f32::INFINITY);
        for (l, &mi) in self.med.iter().enumerate() {
            let v = d.get(mi, j);
            if v < v1 {
                i2 = i1;
                v2 = v1;
                i1 = l;
                v1 = v;
            } else if v < v2 {
                i2 = l;
                v2 = v;
            }
        }
        self.near[j] = i1;
        self.dnear[j] = v1;
        self.sec[j] = i2;
        self.dsec[j] = v2;
    }

    /// Rebuild per-slot removal gains (O(m)).
    fn rebuild_rloss(&mut self) {
        self.rloss.iter_mut().for_each(|v| *v = 0.0);
        for j in 0..self.near.len() {
            self.rloss[self.near[j]] += self.w[j] * (self.dnear[j] - self.dsec[j]);
        }
    }

    /// Evaluate candidate row `i` (its `D` row) against all slots.
    ///
    /// Returns `(best_slot, total_gain)` where `total_gain > 0` means the
    /// swap (remove `best_slot`, add `i`) improves the batch objective by
    /// exactly that amount.  `O(m + k)`, allocation-free.
    pub fn eval_candidate(&mut self, drow: &[f32]) -> (usize, f64) {
        // Route through the shared-borrow form using the state's own
        // scratch buffer (take/restore keeps this allocation-free).
        let mut scratch = std::mem::take(&mut self.scratch);
        let r = self.eval_candidate_at(drow, &mut scratch);
        self.scratch = scratch;
        r
    }

    /// [`SwapState::eval_candidate`] against an external `O(k)` scratch
    /// buffer, through a shared borrow — the form the parallel candidate
    /// scan uses (one scratch per worker thread, state read-only).  The
    /// buffer is resized to `k` on entry; reuse it across calls to stay
    /// allocation-free.
    pub fn eval_candidate_at(&self, drow: &[f32], scratch: &mut Vec<f32>) -> (usize, f64) {
        let k = self.k();
        scratch.resize(k, 0.0);
        scratch[..k].copy_from_slice(&self.rloss);
        let mut shared = 0.0f64;
        // Single predictable branch per column: every contribution
        // (shared or per-medoid) requires dij < dsec, which is false for
        // most (candidate, column) pairs once the medoids are decent —
        // measured ~1.25x over the two-branch form (EXPERIMENTS.md §Perf).
        for j in 0..drow.len() {
            let dij = drow[j];
            let ds = self.dsec[j];
            if dij < ds {
                let dn = self.dnear[j];
                let w = self.w[j];
                if dij < dn {
                    shared += (w * (dn - dij)) as f64;
                    scratch[self.near[j]] += w * (ds - dn);
                } else {
                    scratch[self.near[j]] += w * (ds - dij);
                }
            }
        }
        let mut best_l = 0;
        let mut best_v = f32::NEG_INFINITY;
        for (l, &v) in scratch[..k].iter().enumerate() {
            if v > best_v {
                best_v = v;
                best_l = l;
            }
        }
        (best_l, shared + best_v as f64)
    }

    /// Apply the swap (slot `l` -> dataset row `i`), updating caches
    /// incrementally.  `drow` must be row `i` of the same `D` used so far.
    pub fn apply_swap(&mut self, d: &Matrix, l: usize, i: usize) {
        debug_assert!(!self.is_med[i], "candidate already a medoid");
        self.is_med[self.med[l]] = false;
        self.is_med[i] = true;
        self.med[l] = i;
        let m = self.near.len();
        for j in 0..m {
            let dij = d.get(i, j);
            if self.near[j] == l {
                if dij <= self.dsec[j] {
                    // new medoid still nearest for this column
                    self.near[j] = l;
                    self.dnear[j] = dij;
                } else {
                    self.recompute_column(d, j);
                }
            } else if self.sec[j] == l {
                if dij < self.dnear[j] {
                    // new medoid becomes nearest, old nearest becomes second
                    self.sec[j] = self.near[j];
                    self.dsec[j] = self.dnear[j];
                    self.near[j] = l;
                    self.dnear[j] = dij;
                } else {
                    self.recompute_column(d, j);
                }
            } else {
                // removed slot was neither nearest nor second: only the
                // new medoid can improve the top-2.
                if dij < self.dnear[j] {
                    self.sec[j] = self.near[j];
                    self.dsec[j] = self.dnear[j];
                    self.near[j] = l;
                    self.dnear[j] = dij;
                } else if dij < self.dsec[j] {
                    self.sec[j] = l;
                    self.dsec[j] = dij;
                }
            }
        }
        self.rebuild_rloss();
    }

    /// Exhaustively verify cache integrity against `D` (test helper).
    #[cfg(test)]
    pub fn assert_consistent(&self, d: &Matrix) {
        for j in 0..self.near.len() {
            let mut vals: Vec<(f32, usize)> = self
                .med
                .iter()
                .enumerate()
                .map(|(l, &mi)| (d.get(mi, j), l))
                .collect();
            vals.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1)));
            assert_eq!(self.dnear[j], vals[0].0, "dnear mismatch at col {j}");
            assert_eq!(self.dsec[j], vals[1].0, "dsec mismatch at col {j}");
            assert_eq!(
                d.get(self.med[self.near[j]], j),
                vals[0].0,
                "near slot wrong at col {j}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn setup(n: usize, m: usize, k: usize, seed: u64) -> (Matrix, SwapState) {
        let mut rng = Rng::new(seed);
        let d = Matrix::from_vec(n, m, (0..n * m).map(|_| rng.f32()).collect());
        let med = rng.sample_distinct(n, k);
        let st = SwapState::init(&d, med, vec![1.0; m], n);
        (d, st)
    }

    #[test]
    fn init_caches_consistent() {
        let (d, st) = setup(30, 12, 4, 1);
        st.assert_consistent(&d);
    }

    #[test]
    fn eval_gain_equals_true_delta() {
        let (d, mut st) = setup(25, 10, 3, 2);
        let batch_obj = |med: &[usize]| -> f64 {
            (0..10)
                .map(|j| {
                    med.iter()
                        .map(|&mi| d.get(mi, j))
                        .fold(f32::INFINITY, f32::min) as f64
                })
                .sum()
        };
        let base = batch_obj(&st.med);
        for i in 0..25 {
            if st.is_medoid(i) {
                continue;
            }
            let (l, gain) = st.eval_candidate(d.row(i));
            let mut sw = st.med.clone();
            sw[l] = i;
            let true_gain = base - batch_obj(&sw);
            assert!((gain - true_gain).abs() < 1e-4, "i={i}: {gain} vs {true_gain}");
            // and the chosen slot is the best one
            for l2 in 0..st.k() {
                let mut sw2 = st.med.clone();
                sw2[l2] = i;
                assert!(base - batch_obj(&sw2) <= gain + 1e-4);
            }
        }
    }

    #[test]
    fn apply_swap_keeps_caches_consistent() {
        let (d, mut st) = setup(40, 15, 5, 3);
        let mut rng = Rng::new(99);
        for _ in 0..30 {
            // random non-medoid candidate, random slot
            let mut i = rng.below(40);
            while st.is_medoid(i) {
                i = rng.below(40);
            }
            let l = rng.below(5);
            st.apply_swap(&d, l, i);
            st.assert_consistent(&d);
        }
    }

    #[test]
    fn positive_gain_swap_decreases_objective_by_gain() {
        let (d, mut st) = setup(50, 20, 4, 4);
        for i in 0..50 {
            if st.is_medoid(i) {
                continue;
            }
            let (l, gain) = st.eval_candidate(d.row(i));
            if gain > 1e-6 {
                let before = st.est_objective() * 20.0; // unnormalized
                st.apply_swap(&d, l, i);
                let after = st.est_objective() * 20.0;
                assert!((before - after - gain).abs() < 1e-3, "{before} {after} {gain}");
                return;
            }
        }
        panic!("no improving candidate found in random instance");
    }

    #[test]
    fn is_medoid_tracks_swaps() {
        let (d, mut st) = setup(20, 8, 3, 5);
        let old = st.med[1];
        let mut i = 0;
        while st.is_medoid(i) {
            i += 1;
        }
        st.apply_swap(&d, 1, i);
        assert!(st.is_medoid(i));
        assert!(!st.is_medoid(old));
    }

    #[test]
    #[should_panic]
    fn k1_rejected() {
        let d = Matrix::zeros(5, 3);
        SwapState::init(&d, vec![0], vec![1.0; 3], 5);
    }

    #[test]
    fn weighted_objective_ignores_zero_weight_columns() {
        let mut rng = Rng::new(6);
        let d = Matrix::from_vec(10, 4, (0..40).map(|_| rng.f32()).collect());
        let med = vec![0, 1];
        let st_full = SwapState::init(&d, med.clone(), vec![1.0, 1.0, 0.0, 0.0], 10);
        // manual: only columns 0, 1 count
        let expect: f64 = (0..2)
            .map(|j| med.iter().map(|&mi| d.get(mi, j)).fold(f32::INFINITY, f32::min) as f64)
            .sum::<f64>()
            / 2.0;
        assert!((st_full.est_objective() - expect).abs() < 1e-6);
    }
}
