//! Tiny CSV loader (numeric-only; no csv crate offline).
//!
//! Accepts comma/semicolon/whitespace separation, ignores blank lines
//! and `#` comments, and allows exactly one non-numeric header: the
//! *first* content line.  Any later non-numeric line is an error with
//! its line number — corrupt rows must surface, not vanish.

use super::Dataset;
use crate::linalg::Matrix;
use anyhow::{bail, Context, Result};
use std::path::Path;

/// Load a numeric CSV file into a [`Dataset`].
pub fn load_csv(path: &Path) -> Result<Dataset> {
    load_csv_hinted(path, None)
}

/// [`load_csv`] with an optional row-count hint (the `?rows=` URI
/// query).  With a hint the file is streamed line-by-line into a
/// buffer pre-sized to `rows * p` after the first numeric row — no
/// whole-file string and no `Vec` growth-by-doubling; without one it
/// falls back to the slurp-and-parse path.  Both paths report the same
/// errors with the same line numbers.
pub fn load_csv_hinted(path: &Path, rows_hint: Option<usize>) -> Result<Dataset> {
    let name = path
        .file_stem()
        .map(|s| s.to_string_lossy().into_owned())
        .unwrap_or_else(|| "csv".into());
    let Some(hint) = rows_hint else {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        return parse_csv(&text, &name);
    };
    let file = std::fs::File::open(path)
        .with_context(|| format!("reading {}", path.display()))?;
    stream_csv(std::io::BufReader::new(file), &name, hint)
}

/// Streaming twin of [`parse_csv`]: same separator / header / comment
/// rules and the same error strings, but rows land directly in one
/// flat buffer pre-sized from the row hint.
fn stream_csv<R: std::io::BufRead>(reader: R, name: &str, rows_hint: usize) -> Result<Dataset> {
    let mut data: Vec<f32> = Vec::new();
    let mut p = 0usize;
    let mut n = 0usize;
    let mut content_lines = 0usize;
    for (lineno, line) in reader.lines().enumerate() {
        let line = line.with_context(|| format!("reading {name}"))?;
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        content_lines += 1;
        let start = data.len();
        let mut bad = None;
        for f in line
            .split(|c: char| c == ',' || c == ';' || c.is_whitespace())
            .filter(|f| !f.is_empty())
        {
            match f.parse::<f32>() {
                Ok(v) => data.push(v),
                Err(e) => {
                    bad = Some(e);
                    break;
                }
            }
        }
        match bad {
            None => {
                let len = data.len() - start;
                if p == 0 {
                    p = len;
                    data.reserve_exact(rows_hint.saturating_mul(p).saturating_sub(data.len()));
                } else if len != p {
                    bail!("line {}: expected {} fields, got {}", lineno + 1, p, len);
                }
                n += 1;
            }
            Some(_) if content_lines == 1 => data.truncate(start), // the one allowed header
            Some(e) => bail!(
                "line {}: {} (only the first line may be a non-numeric header)",
                lineno + 1,
                e
            ),
        }
    }
    if n == 0 {
        bail!("no numeric rows in {name}");
    }
    Ok(Dataset { name: name.into(), x: Matrix::from_vec(n, p, data) })
}

/// Parse CSV text (exposed for tests).
pub fn parse_csv(text: &str, name: &str) -> Result<Dataset> {
    let mut rows: Vec<Vec<f32>> = Vec::new();
    // non-blank, non-comment lines seen so far: only the very first one
    // may be a non-numeric header — later garbage is corruption, not a
    // header, and must error with its line number
    let mut content_lines = 0usize;
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        content_lines += 1;
        let fields: Vec<&str> = line
            .split(|c: char| c == ',' || c == ';' || c.is_whitespace())
            .filter(|f| !f.is_empty())
            .collect();
        let parsed: std::result::Result<Vec<f32>, _> =
            fields.iter().map(|f| f.parse::<f32>()).collect();
        match parsed {
            Ok(v) => {
                if let Some(first) = rows.first() {
                    if v.len() != first.len() {
                        bail!(
                            "line {}: expected {} fields, got {}",
                            lineno + 1,
                            first.len(),
                            v.len()
                        );
                    }
                }
                rows.push(v);
            }
            Err(_) if content_lines == 1 => continue, // the one allowed header
            Err(e) => bail!(
                "line {}: {} (only the first line may be a non-numeric header)",
                lineno + 1,
                e
            ),
        }
    }
    if rows.is_empty() {
        bail!("no numeric rows in {name}");
    }
    let p = rows[0].len();
    let n = rows.len();
    let data: Vec<f32> = rows.into_iter().flatten().collect();
    Ok(Dataset { name: name.into(), x: Matrix::from_vec(n, p, data) })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_with_header_and_comments() {
        let d = parse_csv("a,b\n# c\n1,2\n3,4\n", "t").unwrap();
        assert_eq!((d.n(), d.p()), (2, 2));
        assert_eq!(d.x.row(1), &[3.0, 4.0]);
    }

    #[test]
    fn mixed_separators() {
        let d = parse_csv("1;2 3\n4,5,6\n", "t").unwrap();
        assert_eq!((d.n(), d.p()), (2, 3));
    }

    #[test]
    fn rejects_ragged_rows() {
        assert!(parse_csv("1,2\n3\n", "t").is_err());
    }

    #[test]
    fn rejects_empty() {
        assert!(parse_csv("only,text\n", "t").is_err());
    }

    #[test]
    fn only_the_first_line_may_be_a_header() {
        // regression: a second non-numeric line before any numeric row
        // used to be silently swallowed as "another header"
        let err = parse_csv("a,b\nx,y\n1,2\n", "t").unwrap_err().to_string();
        assert!(err.contains("line 2"), "{err}");
    }

    #[test]
    fn garbage_after_numeric_rows_errors_with_line_number() {
        let err = parse_csv("1,2\n3,4\noops,zap\n", "t").unwrap_err().to_string();
        assert!(err.contains("line 3"), "{err}");
    }

    #[test]
    fn streamed_parse_matches_slurped_parse() {
        // every fixture (good and bad) must behave identically on the
        // hinted streaming path — same data, same errors, same line
        // numbers
        for text in [
            "a,b\n# c\n1,2\n3,4\n",
            "1;2 3\n4,5,6\n",
            "1,2\n3\n",
            "only,text\n",
            "a,b\nx,y\n1,2\n",
            "1,2\n3,4\noops,zap\n",
            "# generated\n\na,b\n1,2\n3,4\n",
        ] {
            let slurped = parse_csv(text, "t");
            let streamed = stream_csv(std::io::Cursor::new(text), "t", 2);
            match (slurped, streamed) {
                (Ok(a), Ok(b)) => {
                    assert_eq!((a.n(), a.p()), (b.n(), b.p()), "{text:?}");
                    assert_eq!(a.x.data, b.x.data, "{text:?}");
                }
                (Err(a), Err(b)) => assert_eq!(a.to_string(), b.to_string(), "{text:?}"),
                (a, b) => panic!("{text:?}: slurped {a:?} vs streamed {b:?}"),
            }
        }
    }

    #[test]
    fn streamed_buffer_is_presized_by_the_hint() {
        let text: String = (0..100).map(|i| format!("{i},{i}\n")).collect();
        let d = stream_csv(std::io::Cursor::new(text), "t", 100).unwrap();
        assert_eq!((d.n(), d.p()), (100, 2));
        // an exact hint pre-sizes the flat buffer after the first row:
        // no growth-by-doubling slack (doubling would land on 256)
        let cap = d.x.data.capacity();
        assert!((200..256).contains(&cap), "capacity {cap} shows doubling growth");
    }

    #[test]
    fn header_detection_skips_blanks_and_comments() {
        // comments / blank lines do not consume the one header slot
        let d = parse_csv("# generated\n\na,b\n1,2\n3,4\n", "t").unwrap();
        assert_eq!((d.n(), d.p()), (2, 2));
        assert_eq!(d.x.row(0), &[1.0, 2.0]);
    }
}
