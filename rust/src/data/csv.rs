//! Tiny CSV loader (numeric-only; no csv crate offline).
//!
//! Accepts comma/semicolon/whitespace separation, ignores blank lines
//! and `#` comments, and allows exactly one non-numeric header: the
//! *first* content line.  Any later non-numeric line is an error with
//! its line number — corrupt rows must surface, not vanish.

use super::Dataset;
use crate::linalg::Matrix;
use anyhow::{bail, Context, Result};
use std::path::Path;

/// Load a numeric CSV file into a [`Dataset`].
pub fn load_csv(path: &Path) -> Result<Dataset> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading {}", path.display()))?;
    let name = path
        .file_stem()
        .map(|s| s.to_string_lossy().into_owned())
        .unwrap_or_else(|| "csv".into());
    parse_csv(&text, &name)
}

/// Parse CSV text (exposed for tests).
pub fn parse_csv(text: &str, name: &str) -> Result<Dataset> {
    let mut rows: Vec<Vec<f32>> = Vec::new();
    // non-blank, non-comment lines seen so far: only the very first one
    // may be a non-numeric header — later garbage is corruption, not a
    // header, and must error with its line number
    let mut content_lines = 0usize;
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        content_lines += 1;
        let fields: Vec<&str> = line
            .split(|c: char| c == ',' || c == ';' || c.is_whitespace())
            .filter(|f| !f.is_empty())
            .collect();
        let parsed: std::result::Result<Vec<f32>, _> =
            fields.iter().map(|f| f.parse::<f32>()).collect();
        match parsed {
            Ok(v) => {
                if let Some(first) = rows.first() {
                    if v.len() != first.len() {
                        bail!(
                            "line {}: expected {} fields, got {}",
                            lineno + 1,
                            first.len(),
                            v.len()
                        );
                    }
                }
                rows.push(v);
            }
            Err(_) if content_lines == 1 => continue, // the one allowed header
            Err(e) => bail!(
                "line {}: {} (only the first line may be a non-numeric header)",
                lineno + 1,
                e
            ),
        }
    }
    if rows.is_empty() {
        bail!("no numeric rows in {name}");
    }
    let p = rows[0].len();
    let n = rows.len();
    let data: Vec<f32> = rows.into_iter().flatten().collect();
    Ok(Dataset { name: name.into(), x: Matrix::from_vec(n, p, data) })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_with_header_and_comments() {
        let d = parse_csv("a,b\n# c\n1,2\n3,4\n", "t").unwrap();
        assert_eq!((d.n(), d.p()), (2, 2));
        assert_eq!(d.x.row(1), &[3.0, 4.0]);
    }

    #[test]
    fn mixed_separators() {
        let d = parse_csv("1;2 3\n4,5,6\n", "t").unwrap();
        assert_eq!((d.n(), d.p()), (2, 3));
    }

    #[test]
    fn rejects_ragged_rows() {
        assert!(parse_csv("1,2\n3\n", "t").is_err());
    }

    #[test]
    fn rejects_empty() {
        assert!(parse_csv("only,text\n", "t").is_err());
    }

    #[test]
    fn only_the_first_line_may_be_a_header() {
        // regression: a second non-numeric line before any numeric row
        // used to be silently swallowed as "another header"
        let err = parse_csv("a,b\nx,y\n1,2\n", "t").unwrap_err().to_string();
        assert!(err.contains("line 2"), "{err}");
    }

    #[test]
    fn garbage_after_numeric_rows_errors_with_line_number() {
        let err = parse_csv("1,2\n3,4\noops,zap\n", "t").unwrap_err().to_string();
        assert!(err.contains("line 3"), "{err}");
    }

    #[test]
    fn header_detection_skips_blanks_and_comments() {
        // comments / blank lines do not consume the one header slot
        let d = parse_csv("# generated\n\na,b\n1,2\n3,4\n", "t").unwrap();
        assert_eq!((d.n(), d.p()), (2, 2));
        assert_eq!(d.x.row(0), &[1.0, 2.0]);
    }
}
