//! `dir:` source — a directory of numbered CSV / `.npy` shards plus a
//! `manifest` row-count line, concatenated in shard order.
//!
//! Layout: `<dir>/manifest` holds the total row count (one numeric
//! line; blank lines and `#` comments allowed), and every `*.csv` /
//! `*.npy` entry is a shard.  Shards are ordered by a natural
//! (numeric-aware) name sort, so `shard2.csv` precedes `shard10.csv`.
//! The manifest row count must equal the summed shard rows — a
//! mismatch (shards added, dropped, or truncated after the manifest
//! was written) is an error at open, never a silent short read.
//!
//! [`DirStore`] streams the concatenation: at most one shard is
//! resident at a time (CSV shards parse whole; `.npy` shards stream
//! through positioned reads), so the `dir:` peak is one shard, not the
//! dataset.

use super::npy::NpyReader;
use super::store::RowStore;
use super::Dataset;
use crate::linalg::Matrix;
use anyhow::{bail, Context, Result};
use std::cmp::Ordering;
use std::path::{Path, PathBuf};

/// Natural order: digit runs compare numerically, everything else
/// byte-wise, so `shard2` < `shard10`.
fn natural_cmp(a: &str, b: &str) -> Ordering {
    let (ab, bb) = (a.as_bytes(), b.as_bytes());
    let (mut i, mut j) = (0usize, 0usize);
    while i < ab.len() && j < bb.len() {
        if ab[i].is_ascii_digit() && bb[j].is_ascii_digit() {
            let (si, sj) = (i, j);
            while i < ab.len() && ab[i].is_ascii_digit() {
                i += 1;
            }
            while j < bb.len() && bb[j].is_ascii_digit() {
                j += 1;
            }
            let ra = a[si..i].trim_start_matches('0');
            let rb = b[sj..j].trim_start_matches('0');
            let ord = ra.len().cmp(&rb.len()).then_with(|| ra.cmp(rb));
            if ord != Ordering::Equal {
                return ord;
            }
        } else {
            match ab[i].cmp(&bb[j]) {
                Ordering::Equal => {
                    i += 1;
                    j += 1;
                }
                ord => return ord,
            }
        }
    }
    (ab.len() - i).cmp(&(bb.len() - j))
}

/// One shard file: CSV text or `.npy` binary.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum ShardKind {
    Csv,
    Npy,
}

#[derive(Debug)]
struct ShardInfo {
    path: PathBuf,
    kind: ShardKind,
    /// First global row this shard holds.
    row0: usize,
    /// Rows in this shard.
    rows: usize,
}

/// The currently-open shard (at most one resident at a time).
#[derive(Debug)]
enum CurShard {
    Csv { idx: usize, x: Matrix },
    Npy { idx: usize, reader: NpyReader },
}

impl CurShard {
    fn idx(&self) -> usize {
        match self {
            CurShard::Csv { idx, .. } | CurShard::Npy { idx, .. } => *idx,
        }
    }
}

/// Read the `manifest` row-count line.
fn read_manifest(dir: &Path) -> Result<usize> {
    let path = dir.join("manifest");
    let text = std::fs::read_to_string(&path)
        .with_context(|| format!("{}: missing manifest (one line: total row count)", dir.display()))?;
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        return line
            .parse::<usize>()
            .with_context(|| format!("{}: manifest line '{line}' is not a row count", path.display()));
    }
    bail!("{}: manifest holds no row count", path.display());
}

/// The shard files of a `dir:` source in natural order (exposed so the
/// source fingerprint can cover every shard's size+mtime).
pub fn shard_paths(dir: &Path) -> Result<Vec<PathBuf>> {
    let mut shards: Vec<PathBuf> = std::fs::read_dir(dir)
        .with_context(|| format!("reading directory {}", dir.display()))?
        .filter_map(|e| e.ok().map(|e| e.path()))
        .filter(|p| {
            matches!(p.extension().and_then(|e| e.to_str()), Some("csv") | Some("npy"))
        })
        .collect();
    shards.sort_by(|a, b| {
        natural_cmp(&a.file_name().unwrap_or_default().to_string_lossy(),
                    &b.file_name().unwrap_or_default().to_string_lossy())
    });
    if shards.is_empty() {
        bail!("{}: no .csv/.npy shards", dir.display());
    }
    Ok(shards)
}

/// First numeric row's field count of a CSV shard (cheap `p` probe;
/// same separator/header/comment rules as [`super::csv::parse_csv`]).
fn csv_peek_cols(path: &Path) -> Result<usize> {
    use std::io::BufRead;
    let file = std::fs::File::open(path).with_context(|| format!("opening {}", path.display()))?;
    let mut content_lines = 0usize;
    for line in std::io::BufReader::new(file).lines() {
        let line = line.with_context(|| format!("reading {}", path.display()))?;
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        content_lines += 1;
        let fields: Vec<&str> = line
            .split(|c: char| c == ',' || c == ';' || c.is_whitespace())
            .filter(|f| !f.is_empty())
            .collect();
        if fields.iter().all(|f| f.parse::<f32>().is_ok()) {
            return Ok(fields.len());
        }
        if content_lines > 1 {
            bail!("{}: no numeric row found near the top", path.display());
        }
    }
    bail!("{}: no numeric rows", path.display());
}

/// Cheap `(n, p)` probe for admission pricing: the manifest row count
/// plus the first shard's width — no shard data is read.  The full
/// row-count reconciliation happens at [`DirStore::open`].
pub fn probe_dims(dir: &Path) -> Result<(usize, usize)> {
    let rows = read_manifest(dir)?;
    let first = &shard_paths(dir)?[0];
    let cols = match first.extension().and_then(|e| e.to_str()) {
        Some("npy") => super::npy::read_header(first)?.cols,
        _ => csv_peek_cols(first)?,
    };
    Ok((rows, cols))
}

/// Streaming store over a shard directory.
#[derive(Debug)]
pub struct DirStore {
    dir: PathBuf,
    shards: Vec<ShardInfo>,
    rows: usize,
    cols: usize,
    cur: Option<CurShard>,
}

impl DirStore {
    /// Scan the directory: order shards, size each one, and reconcile
    /// against the manifest.
    pub fn open(dir: &Path) -> Result<DirStore> {
        let manifest_rows = read_manifest(dir)?;
        let paths = shard_paths(dir)?;
        let mut shards = Vec::with_capacity(paths.len());
        let mut cols = 0usize;
        let mut row0 = 0usize;
        for path in paths {
            let (kind, rows, p) = match path.extension().and_then(|e| e.to_str()) {
                Some("npy") => {
                    let h = super::npy::read_header(&path)?;
                    (ShardKind::Npy, h.rows, h.cols)
                }
                _ => {
                    let d = super::csv::load_csv(&path)?;
                    (ShardKind::Csv, d.n(), d.p())
                }
            };
            if cols == 0 {
                cols = p;
            } else if p != cols {
                bail!(
                    "{}: shard {} is {p}-wide but earlier shards are {cols}-wide",
                    dir.display(),
                    path.display()
                );
            }
            shards.push(ShardInfo { path, kind, row0, rows });
            row0 += rows;
        }
        if row0 != manifest_rows {
            bail!(
                "{}: manifest says {manifest_rows} rows but the {} shards hold {row0}",
                dir.display(),
                shards.len()
            );
        }
        Ok(DirStore { dir: dir.to_path_buf(), shards, rows: row0, cols, cur: None })
    }

    /// Index of the shard holding global `row`.
    fn shard_of(&self, row: usize) -> usize {
        debug_assert!(row < self.rows);
        self.shards.partition_point(|s| s.row0 + s.rows <= row)
    }

    /// Make shard `idx` the open one (dropping any other — one shard
    /// resident at most).
    fn ensure_open(&mut self, idx: usize) -> Result<()> {
        if self.cur.as_ref().is_some_and(|c| c.idx() == idx) {
            return Ok(());
        }
        let info = &self.shards[idx];
        self.cur = Some(match info.kind {
            ShardKind::Csv => {
                let d = super::csv::load_csv(&info.path)?;
                if d.n() != info.rows || d.p() != self.cols {
                    bail!(
                        "{}: shard {} changed shape since scan ({}x{} now, {}x{} at open)",
                        self.dir.display(),
                        info.path.display(),
                        d.n(),
                        d.p(),
                        info.rows,
                        self.cols
                    );
                }
                CurShard::Csv { idx, x: d.x }
            }
            ShardKind::Npy => CurShard::Npy { idx, reader: NpyReader::open(&info.path)? },
        });
        Ok(())
    }
}

impl RowStore for DirStore {
    fn dims(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    fn read_chunk<'a>(&'a mut self, row0: usize, buf: &'a mut [f32]) -> Result<&'a [f32]> {
        let p = self.cols;
        assert!(row0 < self.rows, "row0 {row0} out of range (n={})", self.rows);
        assert!(buf.len() >= p, "chunk buffer smaller than one row");
        let idx = self.shard_of(row0);
        self.ensure_open(idx)?;
        let local = row0 - self.shards[idx].row0;
        // chunks never cross a shard boundary: a short chunk at the
        // seam keeps every shard's bits flowing from exactly one reader
        match self.cur.as_mut().expect("ensure_open filled cur") {
            CurShard::Csv { x, .. } => {
                let rows = (buf.len() / p).min(x.rows - local);
                Ok(&x.data[local * p..(local + rows) * p])
            }
            CurShard::Npy { reader, .. } => {
                let rows = reader.read_rows(local, buf)?;
                Ok(&buf[..rows * p])
            }
        }
    }

    fn gather_rows(&mut self, ids: &[usize], out: &mut [f32]) -> Result<()> {
        let p = self.cols;
        assert_eq!(out.len(), ids.len() * p, "gather buffer must hold ids.len() * p values");
        // group by shard so each shard is opened at most once per
        // gather, while the output keeps the caller's id order
        let mut order: Vec<usize> = (0..ids.len()).collect();
        order.sort_by_key(|&slot| ids[slot]);
        for &slot in &order {
            let id = ids[slot];
            anyhow::ensure!(id < self.rows, "gather row {id} out of range (n={})", self.rows);
            let idx = self.shard_of(id);
            self.ensure_open(idx)?;
            let local = id - self.shards[idx].row0;
            let dst = &mut out[slot * p..(slot + 1) * p];
            match self.cur.as_mut().expect("ensure_open filled cur") {
                CurShard::Csv { x, .. } => dst.copy_from_slice(x.row(local)),
                CurShard::Npy { reader, .. } => reader.read_row(local, dst)?,
            }
        }
        Ok(())
    }
}

/// Load the whole concatenation as a resident [`Dataset`] (full-matrix
/// methods need this; the OneBatch path streams instead).
pub fn load_dir(dir: &Path) -> Result<Dataset> {
    let mut store = DirStore::open(dir)?;
    let (n, p) = store.dims();
    let mut data = vec![0f32; n * p];
    let mut buf = vec![0f32; super::store::STREAM_CHUNK_ROWS.max(1) * p];
    let mut row0 = 0usize;
    while row0 < n {
        let chunk = store.read_chunk(row0, &mut buf)?;
        let rows = chunk.len() / p;
        data[row0 * p..(row0 + rows) * p].copy_from_slice(chunk);
        row0 += rows;
    }
    let name = dir
        .file_name()
        .map(|s| s.to_string_lossy().into_owned())
        .unwrap_or_else(|| "dir".into());
    Ok(Dataset { name, x: Matrix::from_vec(n, p, data) })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scratch(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("obpam_dir_{}_{}", std::process::id(), name));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    /// 7x2 dataset split across a CSV shard and an npy shard, with the
    /// natural-order trap (shard2 vs shard10).
    fn build_mixed(dir: &Path) -> Matrix {
        let all: Vec<f32> = (0..14).map(|v| v as f32).collect();
        std::fs::write(dir.join("shard2.csv"), "0,1\n2,3\n4,5\n").unwrap();
        let tail = Matrix::from_vec(4, 2, all[6..].to_vec());
        super::super::npy::write_npy(&dir.join("shard10.npy"), &tail).unwrap();
        std::fs::write(dir.join("manifest"), "7\n").unwrap();
        Matrix::from_vec(7, 2, all)
    }

    #[test]
    fn natural_order_and_concatenation() {
        assert_eq!(natural_cmp("shard2.csv", "shard10.npy"), Ordering::Less);
        assert_eq!(natural_cmp("a01", "a1"), Ordering::Greater, "ties break on the raw run");
        let dir = scratch("concat");
        let want = build_mixed(&dir);
        assert_eq!(probe_dims(&dir).unwrap(), (7, 2));
        let d = load_dir(&dir).unwrap();
        assert_eq!(d.x.data, want.data);
        // chunked sweep with a 2-row buffer crosses the shard seam
        let mut s = DirStore::open(&dir).unwrap();
        let mut buf = vec![0f32; 2 * 2];
        let mut got = Vec::new();
        let mut row0 = 0;
        while row0 < 7 {
            let c = s.read_chunk(row0, &mut buf).unwrap();
            row0 += c.len() / 2;
            got.extend_from_slice(c);
        }
        assert_eq!(got, want.data);
        // gather across shards preserves id order
        let mut out = vec![0f32; 3 * 2];
        s.gather_rows(&[6, 0, 3], &mut out).unwrap();
        assert_eq!(out, vec![12.0, 13.0, 0.0, 1.0, 6.0, 7.0]);
    }

    #[test]
    fn manifest_mismatch_and_missing_are_rejected() {
        let dir = scratch("mismatch");
        build_mixed(&dir);
        std::fs::write(dir.join("manifest"), "9\n").unwrap();
        let err = DirStore::open(&dir).unwrap_err().to_string();
        assert!(err.contains("manifest says 9 rows"), "{err}");

        std::fs::remove_file(dir.join("manifest")).unwrap();
        let err = DirStore::open(&dir).unwrap_err().to_string();
        assert!(err.contains("manifest"), "{err}");

        let dir = scratch("empty");
        std::fs::write(dir.join("manifest"), "0\n").unwrap();
        let err = DirStore::open(&dir).unwrap_err().to_string();
        assert!(err.contains("no .csv/.npy shards"), "{err}");
    }

    #[test]
    fn ragged_shard_widths_are_rejected() {
        let dir = scratch("ragged");
        std::fs::write(dir.join("shard1.csv"), "1,2\n").unwrap();
        std::fs::write(dir.join("shard2.csv"), "1,2,3\n").unwrap();
        std::fs::write(dir.join("manifest"), "2\n").unwrap();
        let err = DirStore::open(&dir).unwrap_err().to_string();
        assert!(err.contains("-wide"), "{err}");
    }
}
