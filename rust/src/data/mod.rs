//! Dataset abstraction, loaders and synthetic generators.

pub mod csv;
pub mod synth;

use crate::linalg::Matrix;

/// An in-memory dataset: `n` rows of `p` features plus provenance.
#[derive(Clone, Debug)]
pub struct Dataset {
    /// Human name (paper dataset name or file stem).
    pub name: String,
    /// Feature matrix (n x p).
    pub x: Matrix,
}

impl Dataset {
    /// Number of points.
    pub fn n(&self) -> usize {
        self.x.rows
    }

    /// Feature dimension.
    pub fn p(&self) -> usize {
        self.x.cols
    }

    /// Min-max scale every feature to `[0, 1]` (constant features -> 0).
    ///
    /// Matches the usual preprocessing for mixed-scale UCI tables so no
    /// single feature dominates the L1 distance.
    pub fn minmax_scale(&mut self) {
        let (n, p) = (self.n(), self.p());
        for j in 0..p {
            let mut lo = f32::INFINITY;
            let mut hi = f32::NEG_INFINITY;
            for i in 0..n {
                let v = self.x.get(i, j);
                lo = lo.min(v);
                hi = hi.max(v);
            }
            let span = hi - lo;
            for i in 0..n {
                let v = self.x.get(i, j);
                self.x.set(i, j, if span > 0.0 { (v - lo) / span } else { 0.0 });
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn minmax_scales_to_unit_interval() {
        let mut d = Dataset {
            name: "t".into(),
            x: Matrix::from_vec(3, 2, vec![0.0, 5.0, 10.0, 5.0, 20.0, 5.0]),
        };
        d.minmax_scale();
        assert_eq!(d.x.col(0), vec![0.0, 0.5, 1.0]);
        // constant feature collapses to 0
        assert_eq!(d.x.col(1), vec![0.0, 0.0, 0.0]);
    }
}
