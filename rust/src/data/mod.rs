//! Dataset abstraction, loaders and synthetic generators.
//!
//! Data enters the system through [`DataSource`] — a parsed URI
//! (`synth:abalone`, `file:/data/points.csv`, `npy:/data/points.npy`,
//! `dir:/data/shards`, bare names aliasing `synth:`) with one `load()`
//! entry point — so every surface (CLI, bench grid, server) addresses
//! generated and loaded datasets the same way.  [`FeatureScaling`]
//! names the optional preprocessing step applied after loading.
//! Streaming sources (`npy:`, `dir:`) additionally open as a
//! [`RowStore`] ([`DataSource::open_store`]) so the OneBatch path can
//! sweep them chunk-by-chunk without a resident matrix.

pub mod csv;
pub mod dirsrc;
pub mod npy;
pub mod source;
pub mod store;
pub mod synth;

pub use source::DataSource;
pub use store::{RowStore, STREAM_CHUNK_ROWS};

use crate::linalg::Matrix;

/// Feature preprocessing applied after a [`DataSource`] load (the wire
/// key `scale_features=`, the CLI flag `--scale-features`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum FeatureScaling {
    /// Use features as loaded (protocol-v2 behaviour).
    #[default]
    None,
    /// Min-max scale every feature to `[0, 1]` ([`Dataset::minmax_scale`],
    /// the usual preprocessing for mixed-scale UCI tables).
    MinMax,
}

impl FeatureScaling {
    /// Parse the wire / CLI spelling (`minmax` | `none`).
    pub fn parse(s: &str) -> Option<FeatureScaling> {
        match s {
            "none" => Some(FeatureScaling::None),
            "minmax" => Some(FeatureScaling::MinMax),
            _ => None,
        }
    }

    /// Canonical spelling (round-trips through [`FeatureScaling::parse`]).
    pub fn name(self) -> &'static str {
        match self {
            FeatureScaling::None => "none",
            FeatureScaling::MinMax => "minmax",
        }
    }

    /// Apply the scaling in place.
    pub fn apply(self, d: &mut Dataset) {
        if self == FeatureScaling::MinMax {
            d.minmax_scale();
        }
    }
}

/// An in-memory dataset: `n` rows of `p` features plus provenance.
#[derive(Clone, Debug)]
pub struct Dataset {
    /// Human name (paper dataset name or file stem).
    pub name: String,
    /// Feature matrix (n x p).
    pub x: Matrix,
}

impl Dataset {
    /// Number of points.
    pub fn n(&self) -> usize {
        self.x.rows
    }

    /// Feature dimension.
    pub fn p(&self) -> usize {
        self.x.cols
    }

    /// Min-max scale every feature to `[0, 1]` (constant features -> 0).
    ///
    /// Matches the usual preprocessing for mixed-scale UCI tables so no
    /// single feature dominates the L1 distance.
    pub fn minmax_scale(&mut self) {
        let (n, p) = (self.n(), self.p());
        for j in 0..p {
            let mut lo = f32::INFINITY;
            let mut hi = f32::NEG_INFINITY;
            for i in 0..n {
                let v = self.x.get(i, j);
                lo = lo.min(v);
                hi = hi.max(v);
            }
            let span = hi - lo;
            for i in 0..n {
                let v = self.x.get(i, j);
                self.x.set(i, j, if span > 0.0 { (v - lo) / span } else { 0.0 });
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn minmax_scales_to_unit_interval() {
        let mut d = Dataset {
            name: "t".into(),
            x: Matrix::from_vec(3, 2, vec![0.0, 5.0, 10.0, 5.0, 20.0, 5.0]),
        };
        d.minmax_scale();
        assert_eq!(d.x.col(0), vec![0.0, 0.5, 1.0]);
        // constant feature collapses to 0
        assert_eq!(d.x.col(1), vec![0.0, 0.0, 0.0]);
    }

    #[test]
    fn feature_scaling_round_trips_and_applies() {
        for fs in [FeatureScaling::None, FeatureScaling::MinMax] {
            assert_eq!(FeatureScaling::parse(fs.name()), Some(fs));
        }
        assert_eq!(FeatureScaling::parse("bogus"), None);
        let mk = || Dataset {
            name: "t".into(),
            x: Matrix::from_vec(2, 1, vec![0.0, 4.0]),
        };
        let mut scaled = mk();
        FeatureScaling::MinMax.apply(&mut scaled);
        assert_eq!(scaled.x.col(0), vec![0.0, 1.0]);
        let mut raw = mk();
        FeatureScaling::None.apply(&mut raw);
        assert_eq!(raw.x.col(0), vec![0.0, 4.0]);
    }
}
