//! Minimal `.npy` (NumPy binary format) reader and writer.
//!
//! Supports exactly what the out-of-core path needs: v1.0/v2.0 headers,
//! C-order (`fortran_order: False`) 2-D arrays of little-endian `<f4`
//! or `<f8`.  Reads are mmap-free: the header is parsed once, then data
//! rows are fetched with pread-style positioned reads ([`NpyReader::
//! read_rows`]) so a chunk of rows can be pulled through a small
//! reusable buffer without the file ever being resident.  `f64` files
//! are cast element-wise to `f32` on read (the crate-wide feature type).
//!
//! The writer ([`write_npy`]) emits v1.0 `<f4` C-order files with the
//! standard 64-byte-aligned header, so round-tripping through `obpam
//! gen --format npy` is bit-exact.

use super::Dataset;
use crate::linalg::Matrix;
use anyhow::{bail, Context, Result};
use std::fs::File;
use std::io::Write;
use std::os::unix::fs::FileExt;
use std::path::Path;

/// The six magic bytes every `.npy` file starts with.
pub const MAGIC: &[u8; 6] = b"\x93NUMPY";

/// Element type of an `.npy` file we accept.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    /// `<f4` — little-endian float32 (read verbatim).
    F32,
    /// `<f8` — little-endian float64 (cast to `f32` on read).
    F64,
}

impl Dtype {
    /// Bytes per element.
    pub fn item_size(self) -> usize {
        match self {
            Dtype::F32 => 4,
            Dtype::F64 => 8,
        }
    }

    /// The numpy `descr` spelling.
    pub fn descr(self) -> &'static str {
        match self {
            Dtype::F32 => "<f4",
            Dtype::F64 => "<f8",
        }
    }
}

/// Parsed `.npy` header: shape, element type, and where the data starts.
#[derive(Clone, Copy, Debug)]
pub struct NpyHeader {
    /// Number of rows (first shape axis).
    pub rows: usize,
    /// Number of columns (second shape axis).
    pub cols: usize,
    /// Element type.
    pub dtype: Dtype,
    /// Byte offset of the first data element.
    pub data_offset: u64,
}

impl NpyHeader {
    /// Total data bytes the file must hold past [`Self::data_offset`].
    pub fn data_bytes(&self) -> u64 {
        (self.rows as u64) * (self.cols as u64) * (self.dtype.item_size() as u64)
    }
}

/// Extract the value text following `'key':` in the header dict.
fn dict_field<'a>(dict: &'a str, key: &str, path: &Path) -> Result<&'a str> {
    let pat = format!("'{key}'");
    let at = dict
        .find(&pat)
        .with_context(|| format!("{}: npy header has no {key} field", path.display()))?;
    let rest = dict[at + pat.len()..].trim_start();
    let rest = rest
        .strip_prefix(':')
        .with_context(|| format!("{}: malformed npy header near {key}", path.display()))?;
    Ok(rest.trim_start())
}

/// Parse the header of an open `.npy` file.  Rejects bad magic,
/// unsupported versions/dtypes, Fortran order, non-2-D shapes, and
/// files too short to hold the advertised data (truncation).
pub fn parse_header(file: &File, path: &Path) -> Result<NpyHeader> {
    let mut head = [0u8; 12];
    // magic(6) + major(1) + minor(1) + len(2 or 4)
    file.read_exact_at(&mut head[..10])
        .with_context(|| format!("{}: file too short for an npy header", path.display()))?;
    if &head[..6] != MAGIC {
        bail!("{}: bad npy magic (not a .npy file)", path.display());
    }
    let (major, minor) = (head[6], head[7]);
    let (dict_len, dict_at) = match major {
        1 => (u16::from_le_bytes([head[8], head[9]]) as usize, 10u64),
        2 => {
            file.read_exact_at(&mut head[8..12], 8)
                .with_context(|| format!("{}: file too short for a v2 npy header", path.display()))?;
            (u32::from_le_bytes([head[8], head[9], head[10], head[11]]) as usize, 12u64)
        }
        _ => bail!("{}: unsupported npy version {major}.{minor} (need 1.x or 2.x)", path.display()),
    };
    let mut dict_raw = vec![0u8; dict_len];
    file.read_exact_at(&mut dict_raw, dict_at)
        .with_context(|| format!("{}: truncated npy header dict", path.display()))?;
    let dict = String::from_utf8_lossy(&dict_raw);

    let descr = dict_field(&dict, "descr", path)?;
    let descr = descr
        .strip_prefix('\'')
        .and_then(|r| r.split('\'').next())
        .with_context(|| format!("{}: malformed npy descr", path.display()))?;
    let dtype = match descr {
        "<f4" => Dtype::F32,
        "<f8" => Dtype::F64,
        other => bail!("{}: unsupported npy dtype '{other}' (need <f4 or <f8)", path.display()),
    };

    let fortran = dict_field(&dict, "fortran_order", path)?;
    if fortran.starts_with("True") {
        bail!("{}: fortran-order npy arrays are not supported (need C order)", path.display());
    } else if !fortran.starts_with("False") {
        bail!("{}: malformed npy fortran_order field", path.display());
    }

    let shape = dict_field(&dict, "shape", path)?;
    let shape = shape
        .strip_prefix('(')
        .and_then(|r| r.split(')').next())
        .with_context(|| format!("{}: malformed npy shape", path.display()))?;
    let dims: Vec<usize> = shape
        .split(',')
        .map(str::trim)
        .filter(|t| !t.is_empty())
        .map(|t| t.parse::<usize>().with_context(|| format!("{}: bad npy shape axis '{t}'", path.display())))
        .collect::<Result<_>>()?;
    if dims.len() != 2 {
        bail!("{}: npy shape {shape:?} is {}-D (need a 2-D (n, p) array)", path.display(), dims.len());
    }
    let (rows, cols) = (dims[0], dims[1]);
    if rows == 0 || cols == 0 {
        bail!("{}: empty npy array (shape ({rows}, {cols}))", path.display());
    }

    let header = NpyHeader { rows, cols, dtype, data_offset: dict_at + dict_len as u64 };
    let need = header.data_offset + header.data_bytes();
    let have = file
        .metadata()
        .with_context(|| format!("stat {}", path.display()))?
        .len();
    if have < need {
        bail!(
            "{}: truncated npy (shape ({rows}, {cols}) {} needs {need} bytes, file has {have})",
            path.display(),
            dtype.descr(),
        );
    }
    Ok(header)
}

/// Parse just the header of a `.npy` file on disk (cheap: ~a hundred
/// bytes of I/O — the pre-admission dimension probe).
pub fn read_header(path: &Path) -> Result<NpyHeader> {
    let file = File::open(path).with_context(|| format!("opening {}", path.display()))?;
    parse_header(&file, path)
}

/// Chunked row reader over an open `.npy` file.  Holds the file handle
/// plus a reusable raw-byte scratch so steady-state sweeps allocate
/// nothing.
#[derive(Debug)]
pub struct NpyReader {
    file: File,
    /// Parsed header (shape, dtype, data offset).
    pub header: NpyHeader,
    raw: Vec<u8>,
}

impl NpyReader {
    /// Open a `.npy` file and parse its header.
    pub fn open(path: &Path) -> Result<NpyReader> {
        let file = File::open(path).with_context(|| format!("opening {}", path.display()))?;
        let header = parse_header(&file, path)?;
        Ok(NpyReader { file, header, raw: Vec::new() })
    }

    /// Read consecutive rows starting at `row0` into the front of
    /// `out`, decoding to `f32`.  Reads `min(out.len() / cols, rows -
    /// row0)` whole rows via one positioned read; returns the row
    /// count.  `out` must hold at least one row.
    pub fn read_rows(&mut self, row0: usize, out: &mut [f32]) -> Result<usize> {
        let (n, p) = (self.header.rows, self.header.cols);
        assert!(row0 < n, "row0 {row0} out of range (n={n})");
        assert!(out.len() >= p, "chunk buffer smaller than one row");
        let rows = (out.len() / p).min(n - row0);
        let isz = self.header.dtype.item_size();
        let nbytes = rows * p * isz;
        self.raw.resize(nbytes, 0);
        let off = self.header.data_offset + (row0 * p * isz) as u64;
        self.file
            .read_exact_at(&mut self.raw[..nbytes], off)
            .with_context(|| format!("npy read of rows {row0}..{} failed", row0 + rows))?;
        match self.header.dtype {
            Dtype::F32 => {
                for (dst, src) in out[..rows * p].iter_mut().zip(self.raw.chunks_exact(4)) {
                    *dst = f32::from_le_bytes([src[0], src[1], src[2], src[3]]);
                }
            }
            Dtype::F64 => {
                for (dst, src) in out[..rows * p].iter_mut().zip(self.raw.chunks_exact(8)) {
                    *dst = f64::from_le_bytes([
                        src[0], src[1], src[2], src[3], src[4], src[5], src[6], src[7],
                    ]) as f32;
                }
            }
        }
        Ok(rows)
    }

    /// Read one row by index into `out[..cols]`.
    pub fn read_row(&mut self, row: usize, out: &mut [f32]) -> Result<()> {
        let p = self.header.cols;
        let got = self.read_rows(row, &mut out[..p])?;
        debug_assert_eq!(got, 1);
        Ok(())
    }
}

/// Load a whole `.npy` file as a resident [`Dataset`] (the non-
/// streaming path; full-matrix methods need this).
pub fn load_npy(path: &Path) -> Result<Dataset> {
    let mut r = NpyReader::open(path)?;
    let (n, p) = (r.header.rows, r.header.cols);
    let mut data = vec![0f32; n * p];
    let mut row0 = 0usize;
    // read through a bounded window so the raw-byte scratch stays small
    // even for f64 files (the decoded matrix is the only n*p buffer)
    let window = super::store::STREAM_CHUNK_ROWS.max(1) * p;
    while row0 < n {
        let end = (row0 * p + window).min(n * p);
        let got = r.read_rows(row0, &mut data[row0 * p..end])?;
        row0 += got;
    }
    let name = path
        .file_stem()
        .map(|s| s.to_string_lossy().into_owned())
        .unwrap_or_else(|| "npy".into());
    Ok(Dataset { name, x: Matrix::from_vec(n, p, data) })
}

/// Write a matrix as a v1.0 C-order `<f4` `.npy` file with the
/// standard 64-byte-aligned header.
pub fn write_npy(path: &Path, x: &Matrix) -> Result<()> {
    let dict = format!(
        "{{'descr': '<f4', 'fortran_order': False, 'shape': ({}, {}), }}",
        x.rows, x.cols
    );
    // magic(6) + version(2) + len(2) + dict + padding + '\n', total a
    // multiple of 64 bytes
    let base = 10 + dict.len() + 1;
    let total = base.div_ceil(64) * 64;
    let dict_len = total - 10;
    let mut out = Vec::with_capacity(total + x.data.len() * 4);
    out.extend_from_slice(MAGIC);
    out.extend_from_slice(&[1, 0]);
    out.extend_from_slice(&(dict_len as u16).to_le_bytes());
    out.extend_from_slice(dict.as_bytes());
    out.resize(total - 1, b' ');
    out.push(b'\n');
    for v in &x.data {
        out.extend_from_slice(&v.to_le_bytes());
    }
    let mut f = File::create(path).with_context(|| format!("creating {}", path.display()))?;
    f.write_all(&out).with_context(|| format!("writing {}", path.display()))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("obpam_npy_{}_{}", std::process::id(), name));
        let _ = std::fs::create_dir_all(&dir);
        dir.join(format!("{name}.npy"))
    }

    #[test]
    fn write_read_round_trip_is_bit_exact() {
        let x = Matrix::from_vec(3, 2, vec![1.5, -2.0, 0.25, 4.0, 1e-7, 9.0]);
        let path = tmp("roundtrip");
        write_npy(&path, &x).unwrap();
        let h = read_header(&path).unwrap();
        assert_eq!((h.rows, h.cols, h.dtype), (3, 2, Dtype::F32));
        let d = load_npy(&path).unwrap();
        assert_eq!(d.x.data, x.data);
        // chunked reads see the same bits, chunk by chunk
        let mut r = NpyReader::open(&path).unwrap();
        let mut buf = vec![0f32; 2 * 2];
        assert_eq!(r.read_rows(0, &mut buf).unwrap(), 2);
        assert_eq!(&buf, &x.data[..4]);
        assert_eq!(r.read_rows(2, &mut buf).unwrap(), 1);
        assert_eq!(&buf[..2], &x.data[4..]);
    }

    #[test]
    fn f64_files_cast_to_f32() {
        // hand-build a v2.0 <f8 file
        let path = tmp("f64");
        let dict = "{'descr': '<f8', 'fortran_order': False, 'shape': (2, 2), }";
        let mut out = Vec::new();
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&[2, 0]);
        out.extend_from_slice(&(dict.len() as u32 + 1).to_le_bytes());
        out.extend_from_slice(dict.as_bytes());
        out.push(b'\n');
        for v in [1.0f64, 2.5, -3.0, 0.125] {
            out.extend_from_slice(&v.to_le_bytes());
        }
        std::fs::write(&path, &out).unwrap();
        let d = load_npy(&path).unwrap();
        assert_eq!(d.x.data, vec![1.0f32, 2.5, -3.0, 0.125]);
    }

    #[test]
    fn bad_magic_truncation_and_fortran_are_rejected() {
        let path = tmp("badmagic");
        std::fs::write(&path, b"NOTNPY00rest").unwrap();
        let err = read_header(&path).unwrap_err().to_string();
        assert!(err.contains("bad npy magic"), "{err}");

        let x = Matrix::from_vec(4, 3, (0..12).map(|v| v as f32).collect());
        let path = tmp("trunc");
        write_npy(&path, &x).unwrap();
        let full = std::fs::read(&path).unwrap();
        std::fs::write(&path, &full[..full.len() - 5]).unwrap();
        let err = read_header(&path).unwrap_err().to_string();
        assert!(err.contains("truncated npy"), "{err}");

        let path = tmp("fortran");
        let dict = "{'descr': '<f4', 'fortran_order': True, 'shape': (1, 1), }";
        let mut out = Vec::new();
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&[1, 0]);
        out.extend_from_slice(&(dict.len() as u16 + 1).to_le_bytes());
        out.extend_from_slice(dict.as_bytes());
        out.push(b'\n');
        out.extend_from_slice(&1.0f32.to_le_bytes());
        std::fs::write(&path, &out).unwrap();
        let err = read_header(&path).unwrap_err().to_string();
        assert!(err.contains("fortran-order"), "{err}");
    }

    #[test]
    fn non_2d_and_bad_dtype_are_rejected() {
        for (name, dict) in [
            ("oned", "{'descr': '<f4', 'fortran_order': False, 'shape': (4,), }"),
            ("int", "{'descr': '<i8', 'fortran_order': False, 'shape': (2, 2), }"),
        ] {
            let path = tmp(name);
            let mut out = Vec::new();
            out.extend_from_slice(MAGIC);
            out.extend_from_slice(&[1, 0]);
            out.extend_from_slice(&(dict.len() as u16 + 1).to_le_bytes());
            out.extend_from_slice(dict.as_bytes());
            out.push(b'\n');
            out.resize(out.len() + 64, 0);
            std::fs::write(&path, &out).unwrap();
            assert!(read_header(&path).is_err(), "{name} should be rejected");
        }
    }
}
