//! URI-addressed dataset sources: one parse/load pipeline for every
//! surface (CLI, config, bench grid, server wire protocol).
//!
//! A [`DataSource`] is a parsed dataset URI:
//!
//! * `synth:<name>` — a seeded synthetic generator from the catalogue
//!   (`synth:abalone`, `synth:blobs_2000_8_5`);
//! * `file:<path>` — a numeric CSV on disk, optionally carrying a row
//!   hint for admission control (`file:/data/gas.csv?rows=416153`);
//! * `npy:<path>` — a binary `.npy` array on disk; dims come from the
//!   ~100-byte header, so no hint is needed and the source can also be
//!   *streamed* chunk-by-chunk ([`DataSource::open_store`]);
//! * `dir:<path>` — a directory of numbered CSV/`.npy` shards plus a
//!   `manifest` row-count line, concatenated in natural shard order
//!   (also streamable);
//! * a bare name (`abalone`, `blobs_2000_8_5`) — protocol-v2 back-compat
//!   alias for `synth:<name>`.
//!
//! Every source has a canonical string form ([`DataSource::canon`], the
//! scheme-qualified spelling, round-trips through [`DataSource::parse`])
//! and a stable [`DataSource::fingerprint`] used as the dataset-cache
//! key.  For `file:` sources the fingerprint mixes in the file's size
//! and mtime, so editing the file on disk changes the key and stale
//! cache entries self-invalidate (they age out of the LRU instead of
//! being served).
//!
//! [`DataSource::load`] is the single entry point behind the CLI, the
//! grid runner and the server — call sites no longer pick between
//! `synth::try_generate` and `load_csv` by hand.

use super::csv::load_csv_hinted;
use super::store::{NpyStore, ResidentStore, RowStore};
use super::{dirsrc, npy, synth, Dataset};
use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};

/// Where the bytes come from.
#[derive(Clone, Debug, PartialEq, Eq)]
enum SourceKind {
    /// Seeded synthetic generator addressed by catalogue / `blobs_` name.
    Synth(String),
    /// Numeric CSV on disk.
    File(PathBuf),
    /// Binary `.npy` array on disk (streamable).
    Npy(PathBuf),
    /// Directory of numbered CSV/`.npy` shards + manifest (streamable).
    Dir(PathBuf),
}

/// A parsed dataset URI; see the module docs for the accepted forms.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct DataSource {
    kind: SourceKind,
    /// `?rows=N` hint on `file:` sources (admission control for files
    /// whose size is known without reading them).
    rows_hint: Option<usize>,
}

impl DataSource {
    /// Parse a dataset URI (`synth:name`, `file:path[?rows=N]`, or a
    /// bare name aliasing `synth:`).  Any other scheme is an error —
    /// unknown *names* are only detected at [`DataSource::load`] time.
    pub fn parse(s: &str) -> Result<DataSource> {
        let s = s.trim();
        if s.is_empty() {
            bail!("empty dataset source");
        }
        if let Some(rest) = s.strip_prefix("synth:") {
            if rest.is_empty() {
                bail!("synth: needs a dataset name (e.g. synth:abalone)");
            }
            if rest.contains('?') {
                bail!("synth: sources take no query string (got '{s}')");
            }
            return Ok(DataSource { kind: SourceKind::Synth(rest.to_string()), rows_hint: None });
        }
        if let Some(rest) = s.strip_prefix("file:") {
            let (path, query) = match rest.split_once('?') {
                Some((p, q)) => (p, Some(q)),
                None => (rest, None),
            };
            if path.is_empty() {
                bail!("file: needs a path (e.g. file:/data/points.csv)");
            }
            let mut rows_hint = None;
            if let Some(q) = query {
                for pair in q.split('&') {
                    match pair.split_once('=') {
                        Some(("rows", v)) => {
                            let n: usize = v
                                .parse()
                                .with_context(|| format!("bad rows hint '{v}' in '{s}'"))?;
                            if n == 0 {
                                bail!("rows hint must be >= 1 in '{s}'");
                            }
                            rows_hint = Some(n);
                        }
                        _ => bail!("unknown query key in '{s}' (only rows=N is supported)"),
                    }
                }
            }
            return Ok(DataSource { kind: SourceKind::File(PathBuf::from(path)), rows_hint });
        }
        if let Some(rest) = s.strip_prefix("npy:") {
            if rest.is_empty() {
                bail!("npy: needs a path (e.g. npy:/data/points.npy)");
            }
            if rest.contains('?') {
                bail!("npy: sources take no query string (dims come from the header; got '{s}')");
            }
            return Ok(DataSource { kind: SourceKind::Npy(PathBuf::from(rest)), rows_hint: None });
        }
        if let Some(rest) = s.strip_prefix("dir:") {
            if rest.is_empty() {
                bail!("dir: needs a path (e.g. dir:/data/shards)");
            }
            if rest.contains('?') {
                bail!("dir: sources take no query string (dims come from the manifest; got '{s}')");
            }
            return Ok(DataSource { kind: SourceKind::Dir(PathBuf::from(rest)), rows_hint: None });
        }
        // bare names alias synth: (protocol-v2 back-compat); anything
        // with an unrecognised scheme prefix is rejected, not guessed at
        if let Some((scheme, _)) = s.split_once(':') {
            bail!("unknown dataset scheme '{scheme}:' in '{s}' (use synth:, file:, npy:, dir:, or a bare synth name)");
        }
        Ok(DataSource { kind: SourceKind::Synth(s.to_string()), rows_hint: None })
    }

    /// Canonical scheme-qualified form; `parse(canon())` reproduces the
    /// source exactly, and bare names canonicalise to `synth:<name>`.
    pub fn canon(&self) -> String {
        match &self.kind {
            SourceKind::Synth(name) => format!("synth:{name}"),
            SourceKind::File(path) => match self.rows_hint {
                Some(n) => format!("file:{}?rows={n}", path.display()),
                None => format!("file:{}", path.display()),
            },
            SourceKind::Npy(path) => format!("npy:{}", path.display()),
            SourceKind::Dir(path) => format!("dir:{}", path.display()),
        }
    }

    /// The canonical spelling of *what bytes this source yields*:
    /// [`DataSource::canon`] minus admission-only decorations (the
    /// `?rows=` hint does not change the loaded data), with `file:`
    /// paths resolved through `fs::canonicalize` so different spellings
    /// of one file (`./x.csv`, `/data/../data/x.csv`) collapse to one
    /// identity.  Cache layers key on this, so aliased spellings share
    /// one entry.  Falls back to the raw path for files that do not
    /// exist (yet) — by the time a cache admits one, the load has to
    /// resolve it anyway.
    pub fn identity(&self) -> String {
        let canonical = |p: &Path| std::fs::canonicalize(p).unwrap_or_else(|_| p.to_path_buf());
        match &self.kind {
            SourceKind::Synth(name) => format!("synth:{name}"),
            SourceKind::File(path) => format!("file:{}", canonical(path).display()),
            SourceKind::Npy(path) => format!("npy:{}", canonical(path).display()),
            SourceKind::Dir(path) => format!("dir:{}", canonical(path).display()),
        }
    }

    /// Short human name: the synth name or the file stem (used as the
    /// loaded [`Dataset::name`] and in log lines).
    pub fn name(&self) -> String {
        match &self.kind {
            SourceKind::Synth(name) => name.clone(),
            SourceKind::File(path) | SourceKind::Npy(path) => path
                .file_stem()
                .map(|s| s.to_string_lossy().into_owned())
                .unwrap_or_else(|| "csv".into()),
            SourceKind::Dir(path) => path
                .file_name()
                .map(|s| s.to_string_lossy().into_owned())
                .unwrap_or_else(|| "dir".into()),
        }
    }

    /// Is this an on-disk source (`file:` / `npy:` / `dir:`)?  Disk
    /// bytes are independent of the generation knobs, so cache layers
    /// normalise scale/seed away.
    pub fn is_file(&self) -> bool {
        !matches!(self.kind, SourceKind::Synth(_))
    }

    /// Can this source be streamed chunk-by-chunk without a resident
    /// matrix (`npy:` / `dir:`)?  Streamed solves bypass the dataset
    /// cache by design: the whole point is to never hold `n x p`.
    pub fn is_stream(&self) -> bool {
        matches!(self.kind, SourceKind::Npy(_) | SourceKind::Dir(_))
    }

    /// Stable cache fingerprint over the source's [`DataSource::identity`]
    /// (admission hints excluded — they do not change the bytes).  Synth
    /// sources hash the identity alone (generation is pure given
    /// `(name, scale, seed)`); `file:` sources additionally mix the
    /// file's current size and mtime, so an edit that changes either
    /// gets a fresh fingerprint and the stale cache entry becomes
    /// unreachable.  Caveat: a same-size rewrite landing within one
    /// mtime tick (coarse-granularity filesystems, mtime-preserving
    /// tools like `rsync -t` / `touch -r`) is indistinguishable from no
    /// edit without hashing the content on every request — which would
    /// cost a full read per cache probe, defeating the cache.  Errors if
    /// a `file:` path cannot be stat'ed.
    pub fn fingerprint(&self) -> Result<u64> {
        self.fingerprint_of(&self.identity())
    }

    /// [`DataSource::fingerprint`] with the [`DataSource::identity`]
    /// precomputed — callers that also key on the identity (the dataset
    /// cache) avoid resolving the path twice per request.
    pub fn fingerprint_of(&self, identity: &str) -> Result<u64> {
        let mut h = fnv1a(identity.as_bytes());
        match &self.kind {
            SourceKind::Synth(_) => {}
            SourceKind::File(path) | SourceKind::Npy(path) => h = mix_file_meta(h, path)?,
            SourceKind::Dir(path) => {
                // every shard's size+mtime folds in, in shard order, so
                // touching, resizing or renumbering any shard (or the
                // manifest) moves the fingerprint
                h = mix_file_meta(h, &path.join("manifest"))?;
                for shard in dirsrc::shard_paths(path)? {
                    h = mix_file_meta(h, &shard)?;
                }
            }
        }
        Ok(h)
    }

    /// Rows [`DataSource::load`] is expected to produce, without loading
    /// anything: the catalogue / `blobs_` prediction for synth sources,
    /// the `?rows=` hint for files.  `None` when unpredictable (unknown
    /// synth names, hint-less files) — callers fall back to a post-load
    /// check.
    pub fn expected_rows(&self, scale: f64) -> Option<usize> {
        match &self.kind {
            SourceKind::Synth(name) => synth::expected_rows(name, scale),
            SourceKind::File(_) => self.rows_hint,
            SourceKind::Npy(_) | SourceKind::Dir(_) => self.expected_dims().map(|(n, _)| n),
        }
    }

    /// `(n, p)` for sources whose dimensions are knowable without
    /// loading the data: the `.npy` header (~100 bytes) or the `dir:`
    /// manifest plus one shard-width probe.  `None` for synth / `file:`
    /// sources (and for stream sources whose probe fails — the load
    /// will surface the real error).  This is what prices
    /// `resident_bytes` before any bulk I/O.
    pub fn expected_dims(&self) -> Option<(usize, usize)> {
        match &self.kind {
            SourceKind::Npy(path) => npy::read_header(path).ok().map(|h| (h.rows, h.cols)),
            SourceKind::Dir(path) => dirsrc::probe_dims(path).ok(),
            SourceKind::Synth(_) | SourceKind::File(_) => None,
        }
    }

    /// Does the paper's Table 2 flag this source's dataset large-scale?
    /// (`file:` sources are judged by row count instead — see
    /// [`DataSource::expected_rows`].)
    pub fn paper_large_scale(&self) -> bool {
        match &self.kind {
            SourceKind::Synth(name) => synth::large_scale_names().contains(&name.as_str()),
            SourceKind::File(_) | SourceKind::Npy(_) | SourceKind::Dir(_) => false,
        }
    }

    /// Load the dataset.  `scale` and `seed` shape synthetic generation
    /// only; a `file:` source's provenance is the bytes on disk, so both
    /// are ignored there.
    pub fn load(&self, scale: f64, seed: u64) -> Result<Dataset> {
        match &self.kind {
            SourceKind::Synth(name) => synth::try_generate(name, scale, seed),
            SourceKind::File(path) => load_csv_hinted(path, self.rows_hint),
            SourceKind::Npy(path) => npy::load_npy(path),
            SourceKind::Dir(path) => dirsrc::load_dir(path),
        }
    }

    /// Open the source as a [`RowStore`].  Stream sources (`npy:` /
    /// `dir:`) open without materialising anything; synth / `file:`
    /// sources load resident and wrap — so callers can be written
    /// against stores uniformly while only true streams pay chunk I/O.
    pub fn open_store(&self, scale: f64, seed: u64) -> Result<Box<dyn RowStore + Send>> {
        match &self.kind {
            SourceKind::Npy(path) => Ok(Box::new(NpyStore::open(path)?)),
            SourceKind::Dir(path) => Ok(Box::new(dirsrc::DirStore::open(path)?)),
            SourceKind::Synth(_) | SourceKind::File(_) => {
                Ok(Box::new(ResidentStore::new(self.load(scale, seed)?.x)))
            }
        }
    }
}

/// Fold one file's size and mtime into a fingerprint (the `file:`
/// staleness rule, shared by `npy:` and every `dir:` shard).
fn mix_file_meta(h: u64, path: &Path) -> Result<u64> {
    let meta = std::fs::metadata(path).with_context(|| format!("stat {}", path.display()))?;
    let mtime_ns = meta
        .modified()
        .ok()
        .and_then(|t| t.duration_since(std::time::UNIX_EPOCH).ok())
        .map(|d| d.as_nanos() as u64)
        .unwrap_or(0);
    Ok(h.rotate_left(17)
        .wrapping_mul(0x100000001b3)
        .wrapping_add(meta.len())
        .rotate_left(17)
        .wrapping_mul(0x100000001b3)
        .wrapping_add(mtime_ns))
}

impl std::fmt::Display for DataSource {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.canon())
    }
}

impl std::str::FromStr for DataSource {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        DataSource::parse(s)
    }
}

/// FNV-1a over a byte string (no std::hash — the fingerprint must be
/// stable across runs and Rust versions, it is a cache key).
fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    fn temp_csv(tag: &str, rows: usize) -> PathBuf {
        let dir = std::env::temp_dir().join("obpam_source_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(format!("{tag}_{}.csv", std::process::id()));
        let mut s = String::from("a,b\n");
        for i in 0..rows {
            s.push_str(&format!("{}.0,{}.5\n", i % 10, (i * 3) % 7));
        }
        std::fs::write(&path, s).unwrap();
        path
    }

    #[test]
    fn bare_names_alias_synth() {
        let bare = DataSource::parse("abalone").unwrap();
        let schemed = DataSource::parse("synth:abalone").unwrap();
        assert_eq!(bare, schemed);
        assert_eq!(bare.canon(), "synth:abalone");
        assert_eq!(bare.name(), "abalone");
        assert!(!bare.is_file());
    }

    #[test]
    fn canon_round_trips() {
        for uri in
            ["synth:blobs_2000_8_5", "file:/data/points.csv", "file:/data/points.csv?rows=416153"]
        {
            let src = DataSource::parse(uri).unwrap();
            assert_eq!(src.canon(), uri);
            assert_eq!(DataSource::parse(&src.canon()).unwrap(), src);
        }
    }

    #[test]
    fn bad_uris_rejected() {
        for bad in [
            "",
            "   ",
            "synth:",
            "file:",
            "http://example.com/x.csv",
            "s3:bucket/key",
            "file:/x.csv?rows=0",
            "file:/x.csv?rows=abc",
            "file:/x.csv?bogus=1",
            "synth:abalone?rows=5",
            "npy:",
            "npy:/x.npy?rows=5",
            "dir:",
            "dir:/shards?rows=5",
        ] {
            assert!(DataSource::parse(bad).is_err(), "{bad:?} should not parse");
        }
    }

    #[test]
    fn npy_and_dir_sources_parse_and_round_trip() {
        for uri in ["npy:/data/points.npy", "dir:/data/shards"] {
            let src = DataSource::parse(uri).unwrap();
            assert_eq!(src.canon(), uri);
            assert_eq!(DataSource::parse(&src.canon()).unwrap(), src);
            assert!(src.is_file(), "disk sources skip scale/seed normalisation");
            assert!(src.is_stream(), "npy:/dir: are the streamable kinds");
            assert!(!src.paper_large_scale());
        }
        assert_eq!(DataSource::parse("npy:/data/points.npy").unwrap().name(), "points");
        assert_eq!(DataSource::parse("dir:/data/shards").unwrap().name(), "shards");
        assert!(!DataSource::parse("file:/x.csv").unwrap().is_stream());
        assert!(!DataSource::parse("abalone").unwrap().is_stream());
    }

    #[test]
    fn npy_expected_dims_and_fingerprint_track_the_file() {
        let dir = std::env::temp_dir().join("obpam_source_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(format!("dims_{}.npy", std::process::id()));
        let x = crate::linalg::Matrix::from_vec(6, 3, (0..18).map(|v| v as f32).collect());
        npy::write_npy(&path, &x).unwrap();
        let src = DataSource::parse(&format!("npy:{}", path.display())).unwrap();
        assert_eq!(src.expected_dims(), Some((6, 3)));
        assert_eq!(src.expected_rows(0.5), Some(6), "file bytes do not scale");
        let f1 = src.fingerprint().unwrap();
        assert_eq!(src.fingerprint().unwrap(), f1);
        let grown = crate::linalg::Matrix::from_vec(7, 3, (0..21).map(|v| v as f32).collect());
        npy::write_npy(&path, &grown).unwrap();
        assert_ne!(src.fingerprint().unwrap(), f1, "rewritten file -> new fingerprint");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn dir_fingerprint_covers_every_shard() {
        let dir = std::env::temp_dir()
            .join(format!("obpam_source_dirfp_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("shard1.csv"), "1,2\n3,4\n").unwrap();
        std::fs::write(dir.join("shard2.csv"), "5,6\n").unwrap();
        std::fs::write(dir.join("manifest"), "3\n").unwrap();
        let src = DataSource::parse(&format!("dir:{}", dir.display())).unwrap();
        assert_eq!(src.expected_dims(), Some((3, 2)));
        let f1 = src.fingerprint().unwrap();
        // growing the *last* shard must move the fingerprint
        std::fs::write(dir.join("shard2.csv"), "5,6\n7,8\n").unwrap();
        assert_ne!(src.fingerprint().unwrap(), f1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn expected_rows_synth_matches_generator_prediction() {
        let src = DataSource::parse("synth:drybean").unwrap();
        assert_eq!(src.expected_rows(0.01), synth::expected_rows("drybean", 0.01));
        assert_eq!(DataSource::parse("nope_not_real").unwrap().expected_rows(1.0), None);
    }

    #[test]
    fn expected_rows_file_uses_hint() {
        let hinted = DataSource::parse("file:/x.csv?rows=123").unwrap();
        // the hint is scale-independent: file bytes do not scale
        assert_eq!(hinted.expected_rows(1.0), Some(123));
        assert_eq!(hinted.expected_rows(0.1), Some(123));
        assert_eq!(DataSource::parse("file:/x.csv").unwrap().expected_rows(1.0), None);
    }

    #[test]
    fn paper_large_scale_flags_catalogue_only() {
        assert!(DataSource::parse("gas").unwrap().paper_large_scale());
        assert!(!DataSource::parse("abalone").unwrap().paper_large_scale());
        assert!(!DataSource::parse("file:/x.csv?rows=999999").unwrap().paper_large_scale());
    }

    #[test]
    fn load_synth_matches_direct_generation() {
        let src = DataSource::parse("blobs_200_4_3").unwrap();
        let via_source = src.load(1.0, 7).unwrap();
        let direct = synth::try_generate("blobs_200_4_3", 1.0, 7).unwrap();
        assert_eq!(via_source.x.data, direct.x.data);
    }

    #[test]
    fn load_file_reads_csv_and_ignores_scale_seed() {
        let path = temp_csv("load", 12);
        let src = DataSource::parse(&format!("file:{}", path.display())).unwrap();
        let a = src.load(1.0, 0).unwrap();
        let b = src.load(0.25, 99).unwrap();
        assert_eq!((a.n(), a.p()), (12, 2));
        assert_eq!(a.x.data, b.x.data, "scale/seed must not affect file loads");
        assert_eq!(a.name, src.name());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn fingerprint_is_stable_until_the_file_changes() {
        let path = temp_csv("fp", 10);
        let src = DataSource::parse(&format!("file:{}", path.display())).unwrap();
        let f1 = src.fingerprint().unwrap();
        assert_eq!(src.fingerprint().unwrap(), f1, "unchanged file -> stable fingerprint");
        // append a row: the size changes, so the fingerprint must too
        // (mtime granularity alone is not relied on)
        let mut text = std::fs::read_to_string(&path).unwrap();
        text.push_str("9.0,9.5\n");
        std::fs::write(&path, text).unwrap();
        assert_ne!(src.fingerprint().unwrap(), f1, "edited file -> new fingerprint");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn fingerprint_separates_sources() {
        let a = DataSource::parse("synth:abalone").unwrap().fingerprint().unwrap();
        let b = DataSource::parse("synth:drybean").unwrap().fingerprint().unwrap();
        assert_ne!(a, b);
    }

    #[test]
    fn identity_collapses_path_spellings() {
        let path = temp_csv("alias", 6);
        let plain = DataSource::parse(&format!("file:{}", path.display())).unwrap();
        // insert a redundant `.` component: same file, different spelling
        let dotted = DataSource::parse(&format!(
            "file:{}/./{}",
            path.parent().unwrap().display(),
            path.file_name().unwrap().to_string_lossy()
        ))
        .unwrap();
        assert_ne!(plain, dotted, "the parsed sources differ textually");
        assert_eq!(plain.identity(), dotted.identity());
        assert_eq!(plain.fingerprint().unwrap(), dotted.fingerprint().unwrap());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn identity_and_fingerprint_ignore_the_rows_hint() {
        // the hint is admission metadata, not provenance: hinted and
        // hint-less spellings of one file must share identity/fingerprint
        let path = temp_csv("hint", 8);
        let plain = DataSource::parse(&format!("file:{}", path.display())).unwrap();
        let hinted = DataSource::parse(&format!("file:{}?rows=8", path.display())).unwrap();
        assert_eq!(plain.identity(), hinted.identity());
        assert_ne!(plain.canon(), hinted.canon(), "canon still round-trips the hint");
        assert_eq!(plain.fingerprint().unwrap(), hinted.fingerprint().unwrap());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn fingerprint_errors_on_missing_file() {
        let src = DataSource::parse("file:/definitely/not/here.csv").unwrap();
        assert!(src.fingerprint().is_err());
    }

    #[test]
    fn display_and_fromstr_round_trip() {
        let src: DataSource = "file:/d/x.csv?rows=5".parse().unwrap();
        assert_eq!(src.to_string(), "file:/d/x.csv?rows=5");
    }
}
