//! Row stores: the out-of-core data layer.
//!
//! A [`RowStore`] yields feature rows two ways — consecutive chunks
//! ([`RowStore::read_chunk`], the streaming-sweep order) and arbitrary
//! gathers ([`RowStore::gather_rows`], the one O(m·p) batch
//! materialization) — without promising the full n×p matrix ever
//! exists in memory.  Three impls:
//!
//! * [`ResidentStore`] — wraps a loaded [`Matrix`]; `read_chunk`
//!   returns internal slices (zero-copy) and [`RowStore::as_matrix`]
//!   exposes the matrix so resident solves take today's exact code
//!   path, bit for bit.
//! * [`NpyStore`] — chunked positioned reads over an `npy:` file via
//!   [`super::npy::NpyReader`]; only one chunk buffer of rows is ever
//!   decoded.
//! * [`super::dirsrc::DirStore`] — shard-ordered concatenation of a
//!   `dir:` source, one shard resident at a time at most.
//!
//! The contract the streaming OneBatch path relies on (see
//! INVARIANTS.md): `read_chunk(row0, buf)` returns at least one row
//! when `row0 < n`, rows are returned in ascending order with no gaps
//! or repeats across a sweep, and the returned bits for any row are
//! identical on every read — which makes a chunked sweep a pure
//! re-association of the resident sweep and keeps the two bit-identical
//! at every chunk size and thread width.

use crate::linalg::Matrix;
use anyhow::Result;
use std::path::Path;

/// Rows per streaming chunk.  Shared by [`StreamSweep`](crate::dissim)
/// sweeps and admission pricing (`chunk_bytes = STREAM_CHUNK_ROWS * p *
/// 4`), so the bytes a streaming job is billed for are the bytes it
/// actually holds.
pub const STREAM_CHUNK_ROWS: usize = 4096;

/// A source of `n` feature rows of width `p`, readable in consecutive
/// chunks or arbitrary gathers.
pub trait RowStore {
    /// `(n, p)`: row count and feature dimension.
    fn dims(&self) -> (usize, usize);

    /// Yield consecutive rows starting at `row0` as a flat `rows * p`
    /// slice.  Reads `min(buf.len() / p, n - row0)` rows — at least one
    /// when `row0 < n` and `buf` holds a row.  A resident store returns
    /// an internal slice (ignoring `buf`); a streaming store decodes
    /// into `buf` and returns the filled prefix.
    fn read_chunk<'a>(&'a mut self, row0: usize, buf: &'a mut [f32]) -> Result<&'a [f32]>;

    /// Gather arbitrary rows *in the order given* (batch column order
    /// is seed-determined and must be preserved) into `out`, which must
    /// hold exactly `ids.len() * p` values.
    fn gather_rows(&mut self, ids: &[usize], out: &mut [f32]) -> Result<()>;

    /// The resident matrix, when this store is one (`None` for
    /// streaming stores).  Lets the coordinator route resident stores
    /// through the unchanged in-memory path.
    fn as_matrix(&self) -> Option<&Matrix> {
        None
    }
}

/// Gather rows out of a resident matrix in id order (shared by
/// [`ResidentStore`] and tests).
pub fn gather_from_matrix(x: &Matrix, ids: &[usize], out: &mut [f32]) -> Result<()> {
    let p = x.cols;
    assert_eq!(out.len(), ids.len() * p, "gather buffer must hold ids.len() * p values");
    for (slot, &id) in ids.iter().enumerate() {
        anyhow::ensure!(id < x.rows, "gather row {id} out of range (n={})", x.rows);
        out[slot * p..(slot + 1) * p].copy_from_slice(x.row(id));
    }
    Ok(())
}

/// A loaded matrix presented as a [`RowStore`] (zero-copy chunks).
#[derive(Debug)]
pub struct ResidentStore {
    x: Matrix,
}

impl ResidentStore {
    /// Wrap a loaded matrix.
    pub fn new(x: Matrix) -> ResidentStore {
        ResidentStore { x }
    }

    /// Take the matrix back out.
    pub fn into_matrix(self) -> Matrix {
        self.x
    }
}

impl RowStore for ResidentStore {
    fn dims(&self) -> (usize, usize) {
        (self.x.rows, self.x.cols)
    }

    fn read_chunk<'a>(&'a mut self, row0: usize, buf: &'a mut [f32]) -> Result<&'a [f32]> {
        let (n, p) = (self.x.rows, self.x.cols);
        assert!(row0 < n, "row0 {row0} out of range (n={n})");
        assert!(buf.len() >= p, "chunk buffer smaller than one row");
        let rows = (buf.len() / p).min(n - row0);
        Ok(&self.x.data[row0 * p..(row0 + rows) * p])
    }

    fn gather_rows(&mut self, ids: &[usize], out: &mut [f32]) -> Result<()> {
        gather_from_matrix(&self.x, ids, out)
    }

    fn as_matrix(&self) -> Option<&Matrix> {
        Some(&self.x)
    }
}

/// A `.npy` file presented as a [`RowStore`]: chunked positioned reads,
/// nothing resident beyond the caller's chunk buffer.
#[derive(Debug)]
pub struct NpyStore {
    reader: super::npy::NpyReader,
    row: Vec<f32>,
}

impl NpyStore {
    /// Open an `.npy` file for streaming.
    pub fn open(path: &Path) -> Result<NpyStore> {
        let reader = super::npy::NpyReader::open(path)?;
        Ok(NpyStore { reader, row: Vec::new() })
    }
}

impl RowStore for NpyStore {
    fn dims(&self) -> (usize, usize) {
        (self.reader.header.rows, self.reader.header.cols)
    }

    fn read_chunk<'a>(&'a mut self, row0: usize, buf: &'a mut [f32]) -> Result<&'a [f32]> {
        let rows = self.reader.read_rows(row0, buf)?;
        let p = self.reader.header.cols;
        Ok(&buf[..rows * p])
    }

    fn gather_rows(&mut self, ids: &[usize], out: &mut [f32]) -> Result<()> {
        let (n, p) = self.dims();
        assert_eq!(out.len(), ids.len() * p, "gather buffer must hold ids.len() * p values");
        self.row.resize(p, 0.0);
        for (slot, &id) in ids.iter().enumerate() {
            anyhow::ensure!(id < n, "gather row {id} out of range (n={n})");
            self.reader.read_row(id, &mut self.row)?;
            out[slot * p..(slot + 1) * p].copy_from_slice(&self.row);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn demo_matrix() -> Matrix {
        Matrix::from_vec(5, 2, (0..10).map(|v| v as f32).collect())
    }

    #[test]
    fn resident_chunks_are_zero_copy_and_bounded_by_buf() {
        let mut s = ResidentStore::new(demo_matrix());
        assert_eq!(s.dims(), (5, 2));
        let mut buf = vec![0f32; 2 * 2];
        let c = s.read_chunk(0, &mut buf).unwrap();
        assert_eq!(c, &[0.0, 1.0, 2.0, 3.0]);
        let mut buf = vec![0f32; 2 * 2];
        let c = s.read_chunk(4, &mut buf).unwrap();
        assert_eq!(c, &[8.0, 9.0], "tail chunk is the short remainder");
        assert!(s.as_matrix().is_some());
    }

    #[test]
    fn gather_preserves_id_order() {
        let mut s = ResidentStore::new(demo_matrix());
        let mut out = vec![0f32; 3 * 2];
        s.gather_rows(&[4, 0, 2], &mut out).unwrap();
        assert_eq!(out, vec![8.0, 9.0, 0.0, 1.0, 4.0, 5.0]);
        let mut out = vec![0f32; 2];
        assert!(s.gather_rows(&[9], &mut out).is_err(), "out-of-range id");
    }

    #[test]
    fn npy_store_sweep_matches_resident() {
        let x = demo_matrix();
        let dir = std::env::temp_dir().join(format!("obpam_store_{}", std::process::id()));
        let _ = std::fs::create_dir_all(&dir);
        let path = dir.join("sweep.npy");
        super::super::npy::write_npy(&path, &x).unwrap();
        let mut s = NpyStore::open(&path).unwrap();
        assert_eq!(s.dims(), (5, 2));
        // a 2-row chunked sweep reassembles the exact matrix
        let mut got = Vec::new();
        let mut buf = vec![0f32; 2 * 2];
        let mut row0 = 0;
        while row0 < 5 {
            let c = s.read_chunk(row0, &mut buf).unwrap();
            row0 += c.len() / 2;
            got.extend_from_slice(c);
        }
        assert_eq!(got, x.data);
        let mut out = vec![0f32; 2 * 2];
        s.gather_rows(&[3, 1], &mut out).unwrap();
        assert_eq!(out, vec![6.0, 7.0, 2.0, 3.0]);
    }
}
