//! Synthetic stand-ins for the paper's ten datasets (Table 2).
//!
//! This environment has no network access, so the real MNIST/CIFAR/UCI
//! files are replaced by seeded generators that match each dataset's
//! `(n, p)` exactly and mimic its coarse structure (cluster count,
//! imbalance, feature type and scale).  k-medoids cost landscapes are
//! driven by n, p, the metric and cluster geometry — not labels — and all
//! algorithms see identical data, so RT / ΔRO comparisons are preserved
//! (DESIGN.md §3 records this substitution).
//!
//! `OBPAM_SCALE` (or an explicit `scale` argument) multiplies `n` (never
//! `p`) so the benches run at laptop scale by default.

use super::Dataset;
use crate::linalg::Matrix;
use crate::rng::Rng;
use anyhow::{bail, Result};

/// Catalogue entry: paper name, full-size n, p.
pub const CATALOGUE: &[(&str, usize, usize, bool)] = &[
    // (name, n, p, is_large_scale)
    ("abalone", 4_176, 8, false),
    ("bankruptcy", 6_819, 96, false),
    ("mapping", 10_545, 28, false),
    ("drybean", 13_611, 16, false),
    ("letter", 19_999, 16, false),
    ("cifar", 50_000, 3_072, true),
    ("mnist", 60_000, 784, true),
    ("dota2", 92_650, 117, true),
    ("gas", 416_153, 9, true),
    ("covertype", 581_011, 55, true),
];

/// The five "small scale" dataset names (paper Table 2, left).
pub fn small_scale_names() -> Vec<&'static str> {
    CATALOGUE.iter().filter(|c| !c.3).map(|c| c.0).collect()
}

/// The five "large scale" dataset names (paper Table 2, right).
pub fn large_scale_names() -> Vec<&'static str> {
    CATALOGUE.iter().filter(|c| c.3).map(|c| c.0).collect()
}

/// Scale factor from `OBPAM_SCALE` (default 1.0; benches pass their own).
pub fn env_scale() -> f64 {
    std::env::var("OBPAM_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1.0)
}

/// Generate a catalogue dataset by name at `scale * n` rows.
///
/// Unknown names fall back to isotropic blobs with the requested name
/// parsed as `blobs_<n>_<p>_<k>` if possible; anything else is an error
/// listing the catalogue.  This is the fallible entry point behind the
/// server, the CLI and the grid runner; [`generate`] is the panicking
/// wrapper for callers with known-good names.
pub fn try_generate(name: &str, scale: f64, seed: u64) -> Result<Dataset> {
    let mut rng = Rng::new(seed ^ fxhash(name));
    if let Some(&(_, n, p, _)) = CATALOGUE.iter().find(|c| c.0 == name) {
        let n = ((n as f64 * scale).round() as usize).max(64);
        let x = match name {
            "abalone" => gen_abalone(&mut rng, n, p),
            "bankruptcy" => gen_bankruptcy(&mut rng, n, p),
            "mapping" => gen_gaussian_mixture(&mut rng, n, p, 6, 0.45, 1.4),
            "drybean" => gen_gaussian_mixture(&mut rng, n, p, 7, 0.25, 2.2),
            "letter" => gen_letter(&mut rng, n, p),
            "cifar" => gen_images(&mut rng, n, p, 10, 0.35, false),
            "mnist" => gen_images(&mut rng, n, p, 10, 0.25, true),
            "dota2" => gen_dota2(&mut rng, n, p),
            "gas" => gen_gas(&mut rng, n, p),
            "covertype" => gen_covertype(&mut rng, n, p),
            _ => unreachable!(),
        };
        return Ok(Dataset { name: name.into(), x });
    }
    // blobs_<n>_<p>_<k>
    if let Some(rest) = name.strip_prefix("blobs_") {
        let parts: Vec<usize> = rest.split('_').filter_map(|s| s.parse().ok()).collect();
        if parts.len() == 3 {
            let n = ((parts[0] as f64 * scale).round() as usize).max(8);
            return Ok(Dataset {
                name: name.into(),
                x: gen_gaussian_mixture(&mut rng, n, parts[1], parts[2], 0.15, 1.0),
            });
        }
    }
    bail!(
        "unknown dataset '{name}' (catalogue: {:?})",
        CATALOGUE.iter().map(|c| c.0).collect::<Vec<_>>()
    );
}

/// Infallible wrapper over [`try_generate`]: panics on unknown names.
pub fn generate(name: &str, scale: f64, seed: u64) -> Dataset {
    try_generate(name, scale, seed).unwrap_or_else(|e| panic!("{e}"))
}

/// Rows [`try_generate`] would produce for `name` at `scale`, without
/// generating anything (catalogue lookup / `blobs_` parse).  `None` for
/// unknown names.  Lets callers (the job server) reject infeasible
/// requests before paying for generation.
pub fn expected_rows(name: &str, scale: f64) -> Option<usize> {
    if let Some(&(_, n, _, _)) = CATALOGUE.iter().find(|c| c.0 == name) {
        return Some(((n as f64 * scale).round() as usize).max(64));
    }
    if let Some(rest) = name.strip_prefix("blobs_") {
        let parts: Vec<usize> = rest.split('_').filter_map(|s| s.parse().ok()).collect();
        if parts.len() == 3 {
            return Some(((parts[0] as f64 * scale).round() as usize).max(8));
        }
    }
    None
}

/// Simple FNV-style string hash for per-dataset seed separation.
fn fxhash(s: &str) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Isotropic-ish Gaussian mixture with `kc` clusters.
///
/// `spread` controls within-cluster std relative to between-cluster
/// separation; `aniso` > 1 stretches random feature subsets (anisotropy).
pub fn gen_gaussian_mixture(rng: &mut Rng, n: usize, p: usize, kc: usize, spread: f64, aniso: f64) -> Matrix {
    let centers: Vec<Vec<f64>> = (0..kc)
        .map(|_| (0..p).map(|_| rng.normal() * 2.0).collect())
        .collect();
    let scales: Vec<Vec<f64>> = (0..kc)
        .map(|_| {
            (0..p)
                .map(|_| spread * if rng.f64() < 0.3 { aniso } else { 1.0 })
                .collect()
        })
        .collect();
    // Mildly imbalanced cluster weights.
    let weights: Vec<f64> = (0..kc).map(|_| 0.3 + rng.f64()).collect();
    let mut x = Matrix::zeros(n, p);
    for i in 0..n {
        let c = rng.weighted(&weights);
        let row = x.row_mut(i);
        for j in 0..p {
            row[j] = (centers[c][j] + rng.normal() * scales[c][j]) as f32;
        }
    }
    x
}

/// abalone: 3 elongated, highly correlated positive measurement clusters.
fn gen_abalone(rng: &mut Rng, n: usize, p: usize) -> Matrix {
    let mut x = Matrix::zeros(n, p);
    for i in 0..n {
        let grp = rng.below(3) as f64; // infant / female / male size regimes
        let size = 0.3 + 0.25 * grp + rng.normal().abs() * 0.15; // latent body size
        let row = x.row_mut(i);
        for j in 0..p {
            // every feature is a noisy monotone function of `size`
            let gain = 0.5 + 0.35 * (j as f64 / p as f64);
            row[j] = (size * gain + rng.normal() * 0.04).max(0.0) as f32;
        }
    }
    x
}

/// bankruptcy: two very imbalanced classes + heavy-tailed financial ratios.
fn gen_bankruptcy(rng: &mut Rng, n: usize, p: usize) -> Matrix {
    let mut x = Matrix::zeros(n, p);
    for i in 0..n {
        let failed = rng.f64() < 0.03; // ~3% bankrupt
        let shift = if failed { 1.5 } else { 0.0 };
        let row = x.row_mut(i);
        for j in 0..p {
            let heavy = if rng.f64() < 0.05 {
                // occasional extreme ratio (heavy tail)
                rng.normal() * 8.0
            } else {
                rng.normal()
            };
            row[j] = (heavy + shift * if j % 7 == 0 { 1.0 } else { 0.1 }) as f32;
        }
    }
    x
}

/// letter: 26 clusters on an integer grid in [0, 15]^p.
fn gen_letter(rng: &mut Rng, n: usize, p: usize) -> Matrix {
    let centers: Vec<Vec<f64>> = (0..26)
        .map(|_| (0..p).map(|_| 2.0 + rng.f64() * 12.0).collect())
        .collect();
    let mut x = Matrix::zeros(n, p);
    for i in 0..n {
        let c = rng.below(26);
        let row = x.row_mut(i);
        for j in 0..p {
            let v = centers[c][j] + rng.normal() * 1.8;
            row[j] = v.round().clamp(0.0, 15.0) as f32;
        }
    }
    x
}

/// MNIST/CIFAR-like: cluster templates in pixel space `[0, 1]^p`.
///
/// `sparse` (MNIST) zeroes ~78% of template entries (stroke images);
/// CIFAR templates are dense low-frequency blobs.
fn gen_images(rng: &mut Rng, n: usize, p: usize, kc: usize, noise: f64, sparse: bool) -> Matrix {
    let templates: Vec<Vec<f64>> = (0..kc)
        .map(|_| {
            (0..p)
                .map(|_| {
                    if sparse && rng.f64() < 0.78 {
                        0.0
                    } else {
                        0.2 + 0.8 * rng.f64()
                    }
                })
                .collect()
        })
        .collect();
    let mut x = Matrix::zeros(n, p);
    for i in 0..n {
        let c = rng.below(kc);
        let row = x.row_mut(i);
        for j in 0..p {
            let t = templates[c][j];
            let v = if sparse && t == 0.0 {
                // background stays near 0 with rare speckle
                if rng.f64() < 0.02 { rng.f64() * 0.5 } else { 0.0 }
            } else {
                t + rng.normal() * noise
            };
            row[j] = v.clamp(0.0, 1.0) as f32;
        }
    }
    x
}

/// dota2: sparse signed hero-pick vectors with long-tailed popularity.
fn gen_dota2(rng: &mut Rng, n: usize, p: usize) -> Matrix {
    // Zipf-ish pick probability per hero.
    let pop: Vec<f64> = (0..p).map(|j| 1.0 / (1.0 + j as f64).powf(0.8)).collect();
    let mut x = Matrix::zeros(n, p);
    for i in 0..n {
        let row = x.row_mut(i);
        let mut picks = 0;
        while picks < 10 {
            let h = rng.weighted(&pop);
            if row[h] == 0.0 {
                row[h] = if picks % 2 == 0 { 1.0 } else { -1.0 };
                picks += 1;
            }
        }
    }
    x
}

/// gas: drifting sensor regimes, 6 clusters with multiplicative drift.
fn gen_gas(rng: &mut Rng, n: usize, p: usize) -> Matrix {
    let centers: Vec<Vec<f64>> = (0..6)
        .map(|_| (0..p).map(|_| rng.f64() * 4.0).collect())
        .collect();
    let mut x = Matrix::zeros(n, p);
    for i in 0..n {
        let c = rng.below(6);
        let drift = 1.0 + 0.4 * (i as f64 / n as f64); // sensor drift over time
        let row = x.row_mut(i);
        for j in 0..p {
            let heavy = if rng.f64() < 0.02 { 4.0 } else { 1.0 };
            row[j] = (centers[c][j] * drift + rng.normal() * 0.3 * heavy) as f32;
        }
    }
    x
}

/// covertype: 7 terrain clusters, continuous block + one-hot-ish block.
fn gen_covertype(rng: &mut Rng, n: usize, p: usize) -> Matrix {
    let cont = 10.min(p);
    let centers: Vec<Vec<f64>> = (0..7)
        .map(|_| (0..cont).map(|_| rng.normal() * 3.0).collect())
        .collect();
    let mut x = Matrix::zeros(n, p);
    for i in 0..n {
        let c = rng.below(7);
        let row = x.row_mut(i);
        for j in 0..cont {
            row[j] = (centers[c][j] + rng.normal()) as f32;
        }
        // categorical one-hot blocks correlated with the cluster
        if p > cont {
            let cat = (c * 5 + rng.below(4)) % (p - cont);
            row[cont + cat] = 1.0;
        }
    }
    x
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn catalogue_matches_paper_table2() {
        let sizes: Vec<(usize, usize)> = CATALOGUE.iter().map(|c| (c.1, c.2)).collect();
        assert!(sizes.contains(&(60_000, 784))); // mnist
        assert!(sizes.contains(&(50_000, 3_072))); // cifar
        assert_eq!(CATALOGUE.len(), 10);
        assert_eq!(small_scale_names().len(), 5);
        assert_eq!(large_scale_names().len(), 5);
    }

    #[test]
    fn generate_is_deterministic() {
        let a = generate("abalone", 0.02, 1);
        let b = generate("abalone", 0.02, 1);
        assert_eq!(a.x.data, b.x.data);
    }

    #[test]
    fn scale_changes_n_not_p() {
        let d = generate("drybean", 0.01, 0);
        assert_eq!(d.p(), 16);
        assert_eq!(d.n(), (13_611.0f64 * 0.01).round() as usize);
    }

    #[test]
    fn all_catalogue_datasets_generate() {
        for &(name, _, p, _) in CATALOGUE {
            let d = generate(name, 0.002, 3);
            assert_eq!(d.p(), p, "{name}");
            assert!(d.n() >= 64);
            assert!(d.x.data.iter().all(|v| v.is_finite()), "{name} has non-finite values");
        }
    }

    #[test]
    fn mnist_like_is_sparse_cifar_dense() {
        let m = generate("mnist", 0.002, 4);
        let c = generate("cifar", 0.0015, 4);
        let frac_zero = |x: &Matrix| x.data.iter().filter(|v| **v == 0.0).count() as f64 / x.data.len() as f64;
        assert!(frac_zero(&m.x) > 0.5, "mnist-like should be mostly zeros");
        assert!(frac_zero(&c.x) < 0.2, "cifar-like should be dense");
    }

    #[test]
    fn dota2_rows_have_ten_picks() {
        let d = generate("dota2", 0.001, 5);
        for i in 0..d.n().min(20) {
            let nz = d.x.row(i).iter().filter(|v| **v != 0.0).count();
            assert_eq!(nz, 10);
        }
    }

    #[test]
    fn blobs_fallback_parses() {
        let d = generate("blobs_1000_4_3", 0.1, 6);
        assert_eq!((d.n(), d.p()), (100, 4));
    }

    #[test]
    #[should_panic]
    fn unknown_name_panics() {
        generate("nope", 1.0, 0);
    }

    #[test]
    fn expected_rows_matches_generate() {
        for (name, scale) in [("drybean", 0.01), ("abalone", 0.0001), ("blobs_1000_4_3", 0.1)] {
            assert_eq!(
                expected_rows(name, scale).unwrap(),
                generate(name, scale, 0).n(),
                "{name}@{scale}"
            );
        }
        assert_eq!(expected_rows("nope", 1.0), None);
    }

    #[test]
    fn try_generate_reports_unknown_names() {
        let err = try_generate("nope", 1.0, 0).unwrap_err().to_string();
        assert!(err.contains("unknown dataset 'nope'"), "{err}");
        assert!(err.contains("abalone"), "error should list the catalogue: {err}");
        assert!(try_generate("blobs_100_4_2", 1.0, 0).is_ok());
    }

    #[test]
    fn clusters_are_separated_enough_for_kmedoids() {
        // sanity: mixture generator produces lower objective for k=kc
        // than k=1 by a wide margin (cluster structure exists).
        let mut rng = Rng::new(7);
        let x = gen_gaussian_mixture(&mut rng, 300, 5, 4, 0.15, 1.0);
        let d = crate::dissim::DissimCounter::new(crate::dissim::Metric::L1);
        // objective with 1 medoid (point 0) vs best of 4 random medoids
        let one: f32 = (0..300).map(|i| d.eval(x.row(i), x.row(0))).sum();
        let meds = [0, 75, 150, 225];
        let four: f32 = (0..300)
            .map(|i| meds.iter().map(|&m| d.eval(x.row(i), x.row(m))).fold(f32::INFINITY, f32::min))
            .sum();
        assert!(four < one);
    }
}
