//! Dissimilarity functions and the blocked distance-tile kernels.
//!
//! k-medoids works with *generic* dissimilarities (the paper's defining
//! feature vs k-means); the paper's experiments use L1.  `Dissimilarity`
//! is the open extension point — all algorithms in the crate are generic
//! over it through the telemetry-counting `DissimCounter` wrapper.
//!
//! Storage **and** compute are `f32` end to end (`Matrix.data` is
//! `Vec<f32>`, every kernel accumulates in `f32`), matching both
//! reference implementations; the only `f64` in the pipeline are the
//! scalar objective/inertia summaries.
//!
//! Two kernel families serve the `O(n·m)` cross-matrix:
//!
//! * the **exact** blocked kernel (`cross_matrix_pool`): transposed
//!   batch layout, `BJ = 64` column blocks, per-metric diff-accumulate
//!   inner loops — bit-identical at any thread count;
//! * the **fast** dot-product kernel for SqL2/L2
//!   (`d² = ‖x‖² + ‖b‖² − 2·x·b` over the same transposed layout with
//!   precomputed batch norms), selected via [`ComputeProfile::Fast`] —
//!   same asymptotics, ~⅓ the FLOPs per cell, *not* bit-identical to
//!   the diff-square form (agreement is tolerance-tested instead).
//!
//! The fused variants ([`cross_argmin_pool`], [`cross_top2_pool`])
//! additionally reduce each completed output row (argmin / top-2)
//! while the row is still cache-hot, so callers that need both the
//! matrix and a per-row reduction never re-walk `n×m` memory.

use crate::linalg::Matrix;
use crate::runtime::Pool;
use crate::sync_ext;
use crate::telemetry::Counters;
use std::sync::{Arc, Mutex};

/// Finite "infinity" sentinel shared with the Python side (kernels/ref.py).
/// Finite so sentinel-sentinel differences stay 0.0 instead of NaN.
pub const BIG: f32 = 1e30;

/// A dissimilarity measure over feature vectors.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Metric {
    /// Manhattan / city-block (the paper's choice).
    L1,
    /// Euclidean.
    L2,
    /// Squared Euclidean (matmul-friendly form on the XLA path).
    SqL2,
    /// Chebyshev (max coordinate difference).
    Chebyshev,
    /// Cosine distance `1 - cos(x, y)` (0 for zero vectors).
    Cosine,
}

impl Metric {
    /// Parse from the CLI / config spelling.
    pub fn parse(s: &str) -> Option<Metric> {
        Some(match s {
            "l1" | "manhattan" => Metric::L1,
            "l2" | "euclidean" => Metric::L2,
            "sqeuclidean" | "sql2" => Metric::SqL2,
            "chebyshev" | "linf" => Metric::Chebyshev,
            "cosine" => Metric::Cosine,
            _ => return None,
        })
    }

    /// Canonical name (manifest / CLI spelling).
    pub fn name(self) -> &'static str {
        match self {
            Metric::L1 => "l1",
            Metric::L2 => "l2",
            Metric::SqL2 => "sqeuclidean",
            Metric::Chebyshev => "chebyshev",
            Metric::Cosine => "cosine",
        }
    }

    /// Pointwise dissimilarity between two vectors.
    #[inline]
    pub fn eval(self, a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        match self {
            Metric::L1 => self::l1(a, b),
            Metric::L2 => self::sq_l2(a, b).sqrt(),
            Metric::SqL2 => self::sq_l2(a, b),
            Metric::Chebyshev => a
                .iter()
                .zip(b)
                .map(|(x, y)| (x - y).abs())
                .fold(0.0f32, f32::max),
            Metric::Cosine => {
                let (mut xy, mut xx, mut yy) = (0.0f32, 0.0f32, 0.0f32);
                for (x, y) in a.iter().zip(b) {
                    xy += x * y;
                    xx += x * x;
                    yy += y * y;
                }
                if xx == 0.0 || yy == 0.0 {
                    0.0
                } else {
                    1.0 - xy / (xx.sqrt() * yy.sqrt())
                }
            }
        }
    }
}

/// Which kernel family computes bulk distance matrices.
///
/// `Exact` (the [`Default`]) keeps the diff-accumulate loops whose
/// output is bit-identical across thread counts *and* across releases —
/// the paper-reproduction grid runs on it.  `Fast` swaps the SqL2/L2
/// inner loop for the dot-product form `d² = ‖x‖² + ‖b‖² − 2·x·b`
/// (precomputed batch norms over the same transposed layout); results
/// agree with `Exact` within a floating-point tolerance, not bitwise,
/// so serving surfaces (server, CLI) default to it while the library
/// default stays `Exact`.  Metrics without a dot-product form
/// (L1 / Chebyshev / Cosine) compute identically under both profiles,
/// as do batches small enough for the row-fallback path.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum ComputeProfile {
    /// Bit-identical diff-accumulate kernels (paper-reproduction grid).
    #[default]
    Exact,
    /// Dot-product SqL2/L2 kernel (serving default; tolerance-equal).
    Fast,
}

impl ComputeProfile {
    /// Parse from the CLI / config / wire spelling.
    pub fn parse(s: &str) -> Option<ComputeProfile> {
        Some(match s {
            "exact" => ComputeProfile::Exact,
            "fast" => ComputeProfile::Fast,
            _ => return None,
        })
    }

    /// Canonical name (wire / CLI spelling).
    pub fn name(self) -> &'static str {
        match self {
            ComputeProfile::Exact => "exact",
            ComputeProfile::Fast => "fast",
        }
    }
}

// Point-to-point evaluation: the plain iterator form measured fastest
// for single pairs (manual lane-accumulators were tried and *regressed*
// at p <= 128 — see EXPERIMENTS.md §Perf).  Bulk matrices go through
// the transposed kernel in `cross_matrix` instead.

#[inline]
fn l1(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).sum()
}

#[inline]
fn sq_l2(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum()
}

/// Dissimilarity evaluator with telemetry counting.
///
/// Every algorithm in the crate routes point-to-point evaluations through
/// this, so the `O(nm)` / `O(n^2)` / `O((T+k) n log n)` claims of Table 1
/// can be *measured* (see benches/complexity.rs).
#[derive(Clone)]
pub struct DissimCounter {
    /// The metric in use.
    pub metric: Metric,
    counters: Arc<Counters>,
}

impl DissimCounter {
    /// Wrap a metric with a fresh counter set.
    pub fn new(metric: Metric) -> Self {
        DissimCounter { metric, counters: Arc::new(Counters::default()) }
    }

    /// Wrap with shared counters (e.g. one per experiment run).
    pub fn with_counters(metric: Metric, counters: Arc<Counters>) -> Self {
        DissimCounter { metric, counters }
    }

    /// Evaluate `d(a, b)`, counting one dissimilarity computation.
    #[inline]
    pub fn eval(&self, a: &[f32], b: &[f32]) -> f32 {
        self.counters.add_dissim(1);
        self.metric.eval(a, b)
    }

    /// Distances from one point to many rows of `x` (counts `idx.len()`).
    pub fn point_to_rows(&self, x: &Matrix, point: &[f32], idx: &[usize]) -> Vec<f32> {
        self.counters.add_dissim(idx.len() as u64);
        idx.iter().map(|&i| self.metric.eval(x.row(i), point)).collect()
    }

    /// Distances from *every* row of `x` to one point (counts `x.rows`),
    /// the [`DissimCounter::point_to_rows`] shape without an index
    /// vector — one counter bump for the whole sweep.
    pub fn rows_to_point(&self, x: &Matrix, point: &[f32]) -> Vec<f32> {
        self.counters.add_dissim(x.rows as u64);
        (0..x.rows).map(|i| self.metric.eval(x.row(i), point)).collect()
    }

    /// Fused distance + running-min sweep: for every row `i` of `x`,
    /// `dmin[i] = min(dmin[i], d(x[i], point))` in one pass (counts
    /// `x.rows`, one counter bump).  The strict `<` update makes the
    /// result identical to evaluating then min-folding separately —
    /// the progressive sampler's seed/grow passes run through this.
    pub fn min_into_rows(&self, x: &Matrix, point: &[f32], dmin: &mut [f32]) {
        debug_assert_eq!(dmin.len(), x.rows);
        self.counters.add_dissim(x.rows as u64);
        for (i, slot) in dmin.iter_mut().enumerate() {
            let v = self.metric.eval(x.row(i), point);
            if v < *slot {
                *slot = v;
            }
        }
    }

    /// Streaming twin of [`DissimCounter::rows_to_point`]: one chunked
    /// ascending pass over `store` through the caller's chunk buffer.
    /// Rows are visited in the same order with the same per-row
    /// `Metric::eval` call, so the output bits match the resident pass.
    pub fn store_to_point(
        &self,
        store: &mut dyn crate::data::RowStore,
        point: &[f32],
        chunk: &mut [f32],
    ) -> anyhow::Result<Vec<f32>> {
        let (n, p) = store.dims();
        self.counters.add_dissim(n as u64);
        let mut out = Vec::with_capacity(n);
        let mut row0 = 0usize;
        while row0 < n {
            let xs = store.read_chunk(row0, chunk)?;
            let rows = xs.len() / p;
            for i in 0..rows {
                out.push(self.metric.eval(&xs[i * p..(i + 1) * p], point));
            }
            row0 += rows;
        }
        Ok(out)
    }

    /// Streaming twin of [`DissimCounter::min_into_rows`] (same strict
    /// `<` update, same ascending row order, chunked through `chunk`).
    pub fn min_into_store(
        &self,
        store: &mut dyn crate::data::RowStore,
        point: &[f32],
        dmin: &mut [f32],
        chunk: &mut [f32],
    ) -> anyhow::Result<()> {
        let (n, p) = store.dims();
        debug_assert_eq!(dmin.len(), n);
        self.counters.add_dissim(n as u64);
        let mut row0 = 0usize;
        while row0 < n {
            let xs = store.read_chunk(row0, chunk)?;
            let rows = xs.len() / p;
            for (i, slot) in dmin[row0..row0 + rows].iter_mut().enumerate() {
                let v = self.metric.eval(&xs[i * p..(i + 1) * p], point);
                if v < *slot {
                    *slot = v;
                }
            }
            row0 += rows;
        }
        Ok(())
    }

    /// Total dissimilarity computations so far.
    pub fn count(&self) -> u64 {
        self.counters.dissim()
    }

    /// Shared counters handle.
    pub fn counters(&self) -> Arc<Counters> {
        self.counters.clone()
    }
}

/// Blocked `rows(x) x rows(b)` distance matrix (native path, serial).
///
/// Convenience wrapper over [`cross_matrix_pool`] with the serial pool.
pub fn cross_matrix(d: &DissimCounter, x: &Matrix, b: &Matrix) -> Matrix {
    cross_matrix_pool(d, x, b, &Pool::serial())
}

/// Blocked `rows(x) x rows(b)` distance matrix, row-partitioned over
/// `pool` (the method's single `O(nmp)` cost).
///
/// For the accumulable metrics (L1 / L2 / SqL2 / Chebyshev) this uses a
/// **transposed batch layout**: `b` is transposed once to `(p, m)` so the
/// inner loop runs SIMD across a block of batch columns with contiguous
/// loads (measured 2.2x at p=16 up to 5.8x at p=784 over the
/// row-by-row form — EXPERIMENTS.md §Perf).  Cosine falls back to the
/// row path.  Counts `n*m` evaluations either way.
///
/// Rows are independent and each output cell accumulates in the same
/// order regardless of the chunking, so the result is bit-identical at
/// any thread count (rust/tests/parallel_equivalence.rs).
pub fn cross_matrix_pool(d: &DissimCounter, x: &Matrix, b: &Matrix, pool: &Pool) -> Matrix {
    cross_matrix_pool_profiled(d, x, b, pool, ComputeProfile::Exact)
}

/// [`cross_matrix_pool`] with an explicit kernel [`ComputeProfile`].
///
/// `Exact` is byte-identical to the historical kernel; `Fast` takes the
/// dot-product SqL2/L2 path (tolerance-equal, still bit-identical at
/// any thread count for a fixed profile).
pub fn cross_matrix_pool_profiled(
    d: &DissimCounter,
    x: &Matrix,
    b: &Matrix,
    pool: &Pool,
    profile: ComputeProfile,
) -> Matrix {
    assert_eq!(x.cols, b.cols, "feature dims differ");
    d.counters.add_dissim((x.rows * b.rows) as u64);
    let (n, m) = (x.rows, b.rows);
    let mut out = Matrix::zeros(n, m);
    if m == 0 || n == 0 {
        return out;
    }
    let plan = KernelPlan::new(d.metric, profile, b);
    let plan = &plan;
    pool.for_each_row_chunk(&mut out.data, n, m, |row0, chunk| {
        for (di, full_row) in chunk.chunks_mut(m).enumerate() {
            plan.fill_row(x.row(row0 + di), full_row);
        }
    });
    out
}

/// Fused pairwise + per-row argmin: the distance matrix of
/// [`cross_matrix_pool_profiled`] *and* `(argmin_j, min_j)` per row,
/// reduced from each completed output row while it is still cache-hot
/// (never re-walked from memory).  Requires a non-empty batch.
///
/// Reduction semantics are exactly [`crate::linalg::argmin`] applied to
/// the finished row, so the result is bit-identical to the unfused
/// `pairwise` ∘ `argmin_rows` composition at any thread count.
pub fn cross_argmin_pool(
    d: &DissimCounter,
    x: &Matrix,
    b: &Matrix,
    pool: &Pool,
    profile: ComputeProfile,
) -> (Matrix, Vec<usize>, Vec<f32>) {
    assert!(b.rows >= 1, "argmin needs a non-empty batch");
    let (out, reduced) = cross_reduce(d, x, b, pool, profile, crate::linalg::argmin);
    let (idx, val) = reduced.into_iter().unzip();
    (out, idx, val)
}

/// Fused pairwise + per-row top-2: the distance matrix *and*
/// `(near, dnear, second, dsecond)` per row in one sweep (the
/// [`crate::linalg::top2_min`] reduction over each cache-hot row).
/// Requires `b.rows >= 2`; bit-identical to `pairwise` ∘ `top2`.
#[allow(clippy::type_complexity)]
pub fn cross_top2_pool(
    d: &DissimCounter,
    x: &Matrix,
    b: &Matrix,
    pool: &Pool,
    profile: ComputeProfile,
) -> (Matrix, Vec<usize>, Vec<f32>, Vec<usize>, Vec<f32>) {
    assert!(b.rows >= 2, "top2 needs at least 2 batch rows");
    let (out, reduced) = cross_reduce(d, x, b, pool, profile, crate::linalg::top2_min);
    let mut near = Vec::with_capacity(reduced.len());
    let mut dnear = Vec::with_capacity(reduced.len());
    let mut second = Vec::with_capacity(reduced.len());
    let mut dsecond = Vec::with_capacity(reduced.len());
    for (i1, v1, i2, v2) in reduced {
        near.push(i1);
        dnear.push(v1);
        second.push(i2);
        dsecond.push(v2);
    }
    (out, near, dnear, second, dsecond)
}

/// The shared fused engine: fill each output row via the kernel plan,
/// reduce it with `reduce` while hot, and stitch the per-chunk
/// reductions back into row order.  Each chunk's reductions are pushed
/// under one short-lived mutex lock *per chunk* (at most one per pool
/// worker), then sorted by the chunk's first row — the reduction values
/// themselves are computed row-locally, so the result is independent of
/// chunk completion order.
fn cross_reduce<R, G>(
    d: &DissimCounter,
    x: &Matrix,
    b: &Matrix,
    pool: &Pool,
    profile: ComputeProfile,
    reduce: G,
) -> (Matrix, Vec<R>)
where
    R: Send,
    G: Fn(&[f32]) -> R + Sync,
{
    assert_eq!(x.cols, b.cols, "feature dims differ");
    d.counters.add_dissim((x.rows * b.rows) as u64);
    let (n, m) = (x.rows, b.rows);
    let mut out = Matrix::zeros(n, m);
    if n == 0 {
        return (out, Vec::new());
    }
    let plan = KernelPlan::new(d.metric, profile, b);
    let parts: Mutex<Vec<(usize, Vec<R>)>> = Mutex::new(Vec::new());
    {
        let plan = &plan;
        let reduce = &reduce;
        let parts = &parts;
        pool.for_each_row_chunk(&mut out.data, n, m, |row0, chunk| {
            let mut acc = Vec::with_capacity(chunk.len() / m);
            for (di, full_row) in chunk.chunks_mut(m).enumerate() {
                plan.fill_row(x.row(row0 + di), full_row);
                acc.push(reduce(full_row));
            }
            sync_ext::lock_or_recover(parts).push((row0, acc));
        });
    }
    let mut collected = std::mem::take(&mut *sync_ext::lock_or_recover(&parts));
    collected.sort_by_key(|(row0, _)| *row0);
    let reduced = collected.into_iter().flat_map(|(_, acc)| acc).collect();
    (out, reduced)
}

/// Chunked twins of the fused sweeps, driven by a [`RowStore`] instead
/// of a resident `&Matrix`.  One [`KernelPlan`] is prepared from the
/// resident batch (serial transpose + norms — the same bits as the
/// resident path), then feature rows flow through a reusable
/// `chunk_rows x p` buffer: each loaded chunk is filled, swept and
/// reduced while cache-hot, and the full `n x p` matrix never exists.
///
/// Bit-identity argument: [`KernelPlan::fill_row`] is row-local (every
/// output cell's float-op sequence depends only on `(x_row, plan)`),
/// and the per-row reductions are [`crate::linalg::argmin`] /
/// [`crate::linalg::top2_min`] on the finished row — so chunking is a
/// pure re-association of the resident sweep and the output is
/// identical at every chunk size *and* thread width
/// (rust/tests/out_of_core.rs pins this end to end).
pub struct StreamSweep {
    chunk_rows: usize,
    chunk: Vec<f32>,
    tile: Vec<f32>,
}

impl StreamSweep {
    /// A sweep buffer holding `chunk_rows` feature rows at a time
    /// (callers outside tests pass [`crate::data::STREAM_CHUNK_ROWS`]).
    pub fn new(chunk_rows: usize) -> StreamSweep {
        assert!(chunk_rows >= 1, "need at least one row per chunk");
        StreamSweep { chunk_rows, chunk: Vec::new(), tile: Vec::new() }
    }

    /// Chunked twin of [`cross_matrix_pool_profiled`]: the full `n x m`
    /// distance matrix (which *is* resident — OneBatch's O(n·m) state)
    /// from a streamed `x`.
    pub fn matrix(
        &mut self,
        d: &DissimCounter,
        store: &mut dyn crate::data::RowStore,
        b: &Matrix,
        pool: &Pool,
        profile: ComputeProfile,
    ) -> anyhow::Result<Matrix> {
        let (out, _) = self.reduce(d, store, b, pool, profile, |_| ())?;
        Ok(out)
    }

    /// Chunked twin of [`cross_argmin_pool`].
    pub fn argmin(
        &mut self,
        d: &DissimCounter,
        store: &mut dyn crate::data::RowStore,
        b: &Matrix,
        pool: &Pool,
        profile: ComputeProfile,
    ) -> anyhow::Result<(Matrix, Vec<usize>, Vec<f32>)> {
        assert!(b.rows >= 1, "argmin needs a non-empty batch");
        let (out, reduced) = self.reduce(d, store, b, pool, profile, crate::linalg::argmin)?;
        let (idx, val) = reduced.into_iter().unzip();
        Ok((out, idx, val))
    }

    /// Assignment-only sweep: per-row `(argmin, min)` against `b`
    /// without retaining any `n x m` matrix — distances land in a
    /// `chunk_rows x m` tile that is reduced and overwritten chunk by
    /// chunk (the streaming final-fit pass).
    pub fn assign(
        &mut self,
        d: &DissimCounter,
        store: &mut dyn crate::data::RowStore,
        b: &Matrix,
        pool: &Pool,
        profile: ComputeProfile,
    ) -> anyhow::Result<(Vec<usize>, Vec<f32>)> {
        assert!(b.rows >= 1, "assign needs a non-empty batch");
        let (n, p) = store.dims();
        assert_eq!(p, b.cols, "feature dims differ");
        d.counters.add_dissim((n * b.rows) as u64);
        let m = b.rows;
        let plan = KernelPlan::new(d.metric, profile, b);
        self.chunk.resize(self.chunk_rows * p, 0.0);
        self.tile.resize(self.chunk_rows * m, 0.0);
        let mut idx = Vec::with_capacity(n);
        let mut val = Vec::with_capacity(n);
        let mut row0 = 0usize;
        while row0 < n {
            let xs = store.read_chunk(row0, &mut self.chunk)?;
            let rows = xs.len() / p;
            debug_assert!(rows >= 1, "RowStore contract: a chunk holds at least one row");
            let parts: Mutex<Vec<(usize, Vec<(usize, f32)>)>> = Mutex::new(Vec::new());
            {
                let plan = &plan;
                let parts = &parts;
                pool.for_each_row_chunk(&mut self.tile[..rows * m], rows, m, |r0, dchunk| {
                    let mut acc = Vec::with_capacity(dchunk.len() / m);
                    for (di, full_row) in dchunk.chunks_mut(m).enumerate() {
                        plan.fill_row(&xs[(r0 + di) * p..(r0 + di + 1) * p], full_row);
                        acc.push(crate::linalg::argmin(full_row));
                    }
                    sync_ext::lock_or_recover(parts).push((r0, acc));
                });
            }
            let mut collected = std::mem::take(&mut *sync_ext::lock_or_recover(&parts));
            collected.sort_by_key(|(r0, _)| *r0);
            for (_, acc) in collected {
                for (i, v) in acc {
                    idx.push(i);
                    val.push(v);
                }
            }
            row0 += rows;
        }
        Ok((idx, val))
    }

    /// The shared chunked engine (mirror of [`cross_reduce`]): one plan
    /// for the whole sweep, rows filled and reduced chunk by chunk in
    /// ascending row order.
    fn reduce<R, G>(
        &mut self,
        d: &DissimCounter,
        store: &mut dyn crate::data::RowStore,
        b: &Matrix,
        pool: &Pool,
        profile: ComputeProfile,
        reduce: G,
    ) -> anyhow::Result<(Matrix, Vec<R>)>
    where
        R: Send,
        G: Fn(&[f32]) -> R + Sync,
    {
        let (n, p) = store.dims();
        assert_eq!(p, b.cols, "feature dims differ");
        d.counters.add_dissim((n * b.rows) as u64);
        let m = b.rows;
        let mut out = Matrix::zeros(n, m);
        if n == 0 || m == 0 {
            return Ok((out, Vec::new()));
        }
        let plan = KernelPlan::new(d.metric, profile, b);
        self.chunk.resize(self.chunk_rows * p, 0.0);
        let mut reduced: Vec<R> = Vec::with_capacity(n);
        let mut row0 = 0usize;
        while row0 < n {
            let xs = store.read_chunk(row0, &mut self.chunk)?;
            let rows = xs.len() / p;
            debug_assert!(rows >= 1, "RowStore contract: a chunk holds at least one row");
            let parts: Mutex<Vec<(usize, Vec<R>)>> = Mutex::new(Vec::new());
            {
                let plan = &plan;
                let reduce = &reduce;
                let parts = &parts;
                let dchunk = &mut out.data[row0 * m..(row0 + rows) * m];
                pool.for_each_row_chunk(dchunk, rows, m, |r0, chunk| {
                    let mut acc = Vec::with_capacity(chunk.len() / m);
                    for (di, full_row) in chunk.chunks_mut(m).enumerate() {
                        plan.fill_row(&xs[(r0 + di) * p..(r0 + di + 1) * p], full_row);
                        acc.push(reduce(full_row));
                    }
                    sync_ext::lock_or_recover(parts).push((r0, acc));
                });
            }
            let mut collected = std::mem::take(&mut *sync_ext::lock_or_recover(&parts));
            collected.sort_by_key(|(r0, _)| *r0);
            reduced.extend(collected.into_iter().flat_map(|(_, acc)| acc));
            row0 += rows;
        }
        Ok((out, reduced))
    }
}

/// Column-block width of the transposed kernels: small enough that one
/// block of `f32` output plus the batch slice stays in L1, wide enough
/// to keep the SIMD lanes full.
const BJ: usize = 64;

/// One prepared bulk-distance kernel: the metric/profile dispatch and
/// the batch-side precomputation (transpose, norms), decided once per
/// matrix so the per-row fill is branch-free over rows.
enum KernelPlan<'a> {
    /// Row-by-row `Metric::eval` (non-accumulable metric or tiny batch).
    RowEval { metric: Metric, b: &'a Matrix },
    /// Exact diff-accumulate over the `(p, m)` transposed batch.
    Blocked { metric: Metric, bt: Vec<f32>, m: usize },
    /// Dot-product SqL2/L2 over the same transpose with precomputed
    /// batch norms (`ComputeProfile::Fast`).
    FastDot { bt: Vec<f32>, bn: Vec<f32>, m: usize, post_sqrt: bool },
}

impl<'a> KernelPlan<'a> {
    fn new(metric: Metric, profile: ComputeProfile, b: &'a Matrix) -> KernelPlan<'a> {
        let (m, p) = (b.rows, b.cols);
        if matches!(metric, Metric::Cosine) || m < 8 {
            // row-by-row fallback (non-accumulable metric or tiny batch)
            return KernelPlan::RowEval { metric, b };
        }
        // transpose b to (p, m): bt[d * m + j] = b[j, d]
        let mut bt = vec![0.0f32; p * m];
        for j in 0..m {
            let brow = b.row(j);
            for dd in 0..p {
                bt[dd * m + j] = brow[dd];
            }
        }
        if profile == ComputeProfile::Fast && matches!(metric, Metric::SqL2 | Metric::L2) {
            // batch norms, computed serially before any parallel region
            // so every thread count sees the same bits
            let bn = (0..m).map(|j| b.row(j).iter().map(|v| v * v).sum()).collect();
            return KernelPlan::FastDot { bt, bn, m, post_sqrt: metric == Metric::L2 };
        }
        KernelPlan::Blocked { metric, bt, m }
    }

    /// Fill one output row (all `m` distances from `xi` to the batch).
    ///
    /// The `Blocked` arm is the historical kernel verbatim: j-blocked
    /// accumulation, SIMD across the batch columns, features in
    /// ascending order — every cell's float-op sequence is unchanged,
    /// which is what keeps `Exact` output byte-identical to pre-profile
    /// releases.
    fn fill_row(&self, xi: &[f32], full_row: &mut [f32]) {
        match self {
            KernelPlan::RowEval { metric, b } => {
                for (j, slot) in full_row.iter_mut().enumerate() {
                    *slot = metric.eval(xi, b.row(j));
                }
            }
            KernelPlan::Blocked { metric, bt, m } => {
                let m = *m;
                for j0 in (0..m).step_by(BJ) {
                    let jw = BJ.min(m - j0);
                    let orow = &mut full_row[j0..j0 + jw];
                    orow.iter_mut().for_each(|v| *v = 0.0);
                    match metric {
                        Metric::L1 => {
                            for (dd, &xv) in xi.iter().enumerate() {
                                let brow = &bt[dd * m + j0..dd * m + j0 + jw];
                                for l in 0..jw {
                                    orow[l] += (xv - brow[l]).abs();
                                }
                            }
                        }
                        Metric::SqL2 | Metric::L2 => {
                            for (dd, &xv) in xi.iter().enumerate() {
                                let brow = &bt[dd * m + j0..dd * m + j0 + jw];
                                for l in 0..jw {
                                    let diff = xv - brow[l];
                                    orow[l] += diff * diff;
                                }
                            }
                        }
                        Metric::Chebyshev => {
                            for (dd, &xv) in xi.iter().enumerate() {
                                let brow = &bt[dd * m + j0..dd * m + j0 + jw];
                                for l in 0..jw {
                                    orow[l] = orow[l].max((xv - brow[l]).abs());
                                }
                            }
                        }
                        Metric::Cosine => unreachable!(),
                    }
                    if *metric == Metric::L2 {
                        orow.iter_mut().for_each(|v| *v = v.sqrt());
                    }
                }
            }
            KernelPlan::FastDot { bt, bn, m, post_sqrt } => {
                let m = *m;
                // ‖x‖² accumulated in feature order, row-locally: the
                // same bits at any thread count
                let xn: f32 = xi.iter().map(|v| v * v).sum();
                for j0 in (0..m).step_by(BJ) {
                    let jw = BJ.min(m - j0);
                    let orow = &mut full_row[j0..j0 + jw];
                    orow.iter_mut().for_each(|v| *v = 0.0);
                    for (dd, &xv) in xi.iter().enumerate() {
                        let brow = &bt[dd * m + j0..dd * m + j0 + jw];
                        for l in 0..jw {
                            orow[l] += xv * brow[l];
                        }
                    }
                    let bn = &bn[j0..j0 + jw];
                    for l in 0..jw {
                        // clamp: cancellation can drive the algebraic
                        // form a hair below zero, and sqrt(neg) is NaN
                        let v = (xn + bn[l] - 2.0 * orow[l]).max(0.0);
                        orow[l] = if *post_sqrt { v.sqrt() } else { v };
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn m(rows: usize, cols: usize, v: Vec<f32>) -> Matrix {
        Matrix::from_vec(rows, cols, v)
    }

    #[test]
    fn l1_known() {
        assert_eq!(Metric::L1.eval(&[0.0, 0.0], &[1.0, 2.0]), 3.0);
    }

    #[test]
    fn l2_and_sql2_consistent() {
        let (a, b) = ([3.0f32, 0.0], [0.0f32, 4.0]);
        assert!((Metric::L2.eval(&a, &b) - 5.0).abs() < 1e-6);
        assert!((Metric::SqL2.eval(&a, &b) - 25.0).abs() < 1e-4);
    }

    #[test]
    fn chebyshev_known() {
        assert_eq!(Metric::Chebyshev.eval(&[1.0, 5.0], &[4.0, 6.0]), 3.0);
    }

    #[test]
    fn cosine_bounds_and_zero_vec() {
        assert!(Metric::Cosine.eval(&[1.0, 0.0], &[1.0, 0.0]).abs() < 1e-6);
        assert!((Metric::Cosine.eval(&[1.0, 0.0], &[-1.0, 0.0]) - 2.0).abs() < 1e-6);
        assert_eq!(Metric::Cosine.eval(&[0.0, 0.0], &[1.0, 0.0]), 0.0);
    }

    #[test]
    fn metric_axioms_identity_symmetry() {
        let mut rng = crate::rng::Rng::new(2);
        for metric in [Metric::L1, Metric::L2, Metric::SqL2, Metric::Chebyshev] {
            for _ in 0..50 {
                let a: Vec<f32> = (0..6).map(|_| rng.normal() as f32).collect();
                let b: Vec<f32> = (0..6).map(|_| rng.normal() as f32).collect();
                assert!(metric.eval(&a, &a) < 1e-5);
                assert!((metric.eval(&a, &b) - metric.eval(&b, &a)).abs() < 1e-5);
                assert!(metric.eval(&a, &b) >= 0.0);
            }
        }
    }

    #[test]
    fn parse_round_trips() {
        for metric in [Metric::L1, Metric::L2, Metric::SqL2, Metric::Chebyshev, Metric::Cosine] {
            assert_eq!(Metric::parse(metric.name()), Some(metric));
        }
        assert_eq!(Metric::parse("bogus"), None);
    }

    #[test]
    fn cross_matrix_matches_pointwise_and_counts() {
        let x = m(3, 2, vec![0., 0., 1., 1., 2., 0.]);
        let b = m(2, 2, vec![0., 1., 2., 2.]);
        let d = DissimCounter::new(Metric::L1);
        let c = cross_matrix(&d, &x, &b);
        for i in 0..3 {
            for j in 0..2 {
                assert_eq!(c.get(i, j), Metric::L1.eval(x.row(i), b.row(j)));
            }
        }
        assert_eq!(d.count(), 6);
    }

    #[test]
    fn cross_matrix_blocked_equals_unblocked_large() {
        let mut rng = crate::rng::Rng::new(3);
        let x = Matrix::from_vec(70, 5, (0..350).map(|_| rng.f32()).collect());
        let b = Matrix::from_vec(67, 5, (0..335).map(|_| rng.f32()).collect());
        let d = DissimCounter::new(Metric::L1);
        let c = cross_matrix(&d, &x, &b);
        for i in [0, 13, 69] {
            for j in [0, 31, 32, 66] {
                assert!((c.get(i, j) - Metric::L1.eval(x.row(i), b.row(j))).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn profile_parse_round_trips() {
        for p in [ComputeProfile::Exact, ComputeProfile::Fast] {
            assert_eq!(ComputeProfile::parse(p.name()), Some(p));
        }
        assert_eq!(ComputeProfile::parse("bogus"), None);
        assert_eq!(ComputeProfile::default(), ComputeProfile::Exact);
    }

    fn random_pair(seed: u64, n: usize, m: usize, p: usize) -> (Matrix, Matrix) {
        let mut rng = crate::rng::Rng::new(seed);
        let x = Matrix::from_vec(n, p, (0..n * p).map(|_| rng.normal() as f32).collect());
        let b = Matrix::from_vec(m, p, (0..m * p).map(|_| rng.normal() as f32).collect());
        (x, b)
    }

    #[test]
    fn fused_argmin_matches_unfused_all_metrics_and_shapes() {
        let pool = Pool::serial();
        for metric in [Metric::L1, Metric::L2, Metric::SqL2, Metric::Chebyshev, Metric::Cosine] {
            // covers the blocked path (m=67), the m<8 row fallback
            // (m=5), and the m=1 degenerate batch
            for (n, m_rows, p) in [(41, 67, 7), (19, 5, 3), (9, 1, 4)] {
                let (x, b) = random_pair(11, n, m_rows, p);
                let d = DissimCounter::new(metric);
                let want = cross_matrix_pool(&d, &x, &b, &pool);
                for profile in [ComputeProfile::Exact, ComputeProfile::Fast] {
                    let (got, idx, val) = cross_argmin_pool(&d, &x, &b, &pool, profile);
                    let base = cross_matrix_pool_profiled(&d, &x, &b, &pool, profile);
                    assert_eq!(got.data, base.data, "{metric:?} {profile:?} matrix mismatch");
                    if profile == ComputeProfile::Exact {
                        assert_eq!(got.data, want.data, "{metric:?} Exact drifted");
                    }
                    for i in 0..n {
                        let (bi, bv) = crate::linalg::argmin(got.row(i));
                        assert_eq!((idx[i], val[i].to_bits()), (bi, bv.to_bits()));
                    }
                }
            }
        }
    }

    #[test]
    fn fused_top2_matches_unfused() {
        let pool = Pool::serial();
        for metric in [Metric::L1, Metric::L2, Metric::SqL2, Metric::Chebyshev, Metric::Cosine] {
            for (n, m_rows, p) in [(33, 64, 6), (15, 3, 1), (7, 2, 2)] {
                let (x, b) = random_pair(29, n, m_rows, p);
                let d = DissimCounter::new(metric);
                for profile in [ComputeProfile::Exact, ComputeProfile::Fast] {
                    let (got, near, dnear, second, dsecond) =
                        cross_top2_pool(&d, &x, &b, &pool, profile);
                    for i in 0..n {
                        let (i1, v1, i2, v2) = crate::linalg::top2_min(got.row(i));
                        assert_eq!(near[i], i1);
                        assert_eq!(dnear[i].to_bits(), v1.to_bits());
                        assert_eq!(second[i], i2);
                        assert_eq!(dsecond[i].to_bits(), v2.to_bits());
                    }
                }
            }
        }
    }

    #[test]
    fn fast_profile_matches_exact_within_tolerance() {
        let pool = Pool::serial();
        for metric in [Metric::SqL2, Metric::L2] {
            let (x, b) = random_pair(7, 53, 71, 9);
            let d = DissimCounter::new(metric);
            let exact = cross_matrix_pool_profiled(&d, &x, &b, &pool, ComputeProfile::Exact);
            let fast = cross_matrix_pool_profiled(&d, &x, &b, &pool, ComputeProfile::Fast);
            for i in 0..x.rows {
                let xn: f32 = x.row(i).iter().map(|v| v * v).sum();
                for j in 0..b.rows {
                    let bn: f32 = b.row(j).iter().map(|v| v * v).sum();
                    // absolute error of the algebraic form scales with
                    // the norms being cancelled, not with the distance
                    let scale = 1.0 + xn + bn;
                    let tol = if metric == Metric::L2 { scale.sqrt() } else { scale };
                    assert!(
                        (fast.get(i, j) - exact.get(i, j)).abs() <= 1e-4 * tol,
                        "{metric:?} ({i},{j}): fast={} exact={}",
                        fast.get(i, j),
                        exact.get(i, j)
                    );
                }
            }
        }
    }

    #[test]
    fn fast_profile_identical_for_non_euclidean_metrics() {
        let pool = Pool::serial();
        for metric in [Metric::L1, Metric::Chebyshev, Metric::Cosine] {
            let (x, b) = random_pair(17, 23, 31, 5);
            let d = DissimCounter::new(metric);
            let exact = cross_matrix_pool_profiled(&d, &x, &b, &pool, ComputeProfile::Exact);
            let fast = cross_matrix_pool_profiled(&d, &x, &b, &pool, ComputeProfile::Fast);
            assert_eq!(exact.data, fast.data);
        }
    }

    #[test]
    fn stream_sweep_matches_resident_at_every_chunk_size() {
        use crate::data::store::ResidentStore;
        let pools = [Pool::serial(), Pool::new(3)];
        for metric in [Metric::L1, Metric::SqL2, Metric::Cosine] {
            let (x, b) = random_pair(13, 37, 9, 5);
            for profile in [ComputeProfile::Exact, ComputeProfile::Fast] {
                for pool in &pools {
                    let d = DissimCounter::new(metric);
                    let (want, widx, wval) = cross_argmin_pool(&d, &x, &b, pool, profile);
                    // chunk sizes below, at and above n, plus 1-row
                    for chunk_rows in [1, 3, 37, 100] {
                        let mut store = ResidentStore::new(x.clone());
                        let mut sweep = StreamSweep::new(chunk_rows);
                        let (got, idx, val) =
                            sweep.argmin(&d, &mut store, &b, pool, profile).unwrap();
                        assert_eq!(got.data, want.data, "{metric:?} {profile:?} c={chunk_rows}");
                        assert_eq!(idx, widx);
                        let bits =
                            |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
                        assert_eq!(bits(&val), bits(&wval));
                        let (aidx, aval) =
                            sweep.assign(&d, &mut store, &b, pool, profile).unwrap();
                        assert_eq!(aidx, widx, "assign-only sweep drifted");
                        assert_eq!(bits(&aval), bits(&wval));
                        let mat = sweep.matrix(&d, &mut store, &b, pool, profile).unwrap();
                        assert_eq!(mat.data, want.data);
                    }
                }
            }
        }
    }

    #[test]
    fn stream_sweep_counts_like_the_resident_sweep() {
        use crate::data::store::ResidentStore;
        let pool = Pool::serial();
        let (x, b) = random_pair(5, 12, 9, 4);
        let d = DissimCounter::new(Metric::L1);
        let mut store = ResidentStore::new(x);
        let mut sweep = StreamSweep::new(4);
        let _ = sweep.argmin(&d, &mut store, &b, &pool, ComputeProfile::Exact).unwrap();
        assert_eq!(d.count(), 12 * 9);
        let _ = sweep.assign(&d, &mut store, &b, &pool, ComputeProfile::Exact).unwrap();
        assert_eq!(d.count(), 2 * 12 * 9);
    }

    #[test]
    fn fused_counting_matches_pairwise() {
        let pool = Pool::serial();
        let (x, b) = random_pair(5, 12, 9, 4);
        let d = DissimCounter::new(Metric::SqL2);
        let _ = cross_argmin_pool(&d, &x, &b, &pool, ComputeProfile::Exact);
        assert_eq!(d.count(), 12 * 9);
        let _ = cross_top2_pool(&d, &x, &b, &pool, ComputeProfile::Fast);
        assert_eq!(d.count(), 2 * 12 * 9);
    }

    #[test]
    fn rows_to_point_and_min_into_rows_match_eval() {
        let (x, _) = random_pair(3, 10, 1, 4);
        let point = vec![0.5f32, -0.25, 1.0, 0.0];
        let d = DissimCounter::new(Metric::L1);
        let dist = d.rows_to_point(&x, &point);
        assert_eq!(d.count(), 10);
        let mut dmin = vec![0.1f32; 10];
        d.min_into_rows(&x, &point, &mut dmin);
        assert_eq!(d.count(), 20);
        for i in 0..10 {
            let v = Metric::L1.eval(x.row(i), &point);
            assert_eq!(dist[i].to_bits(), v.to_bits());
            assert_eq!(dmin[i].to_bits(), v.min(0.1).to_bits());
        }
    }
}
