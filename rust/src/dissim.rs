//! Dissimilarity functions.
//!
//! k-medoids works with *generic* dissimilarities (the paper's defining
//! feature vs k-means); the paper's experiments use L1.  `Dissimilarity`
//! is the open extension point — all algorithms in the crate are generic
//! over it through the telemetry-counting `DissimCounter` wrapper.

use crate::linalg::Matrix;
use crate::runtime::Pool;
use crate::telemetry::Counters;
use std::sync::Arc;

/// Finite "infinity" sentinel shared with the Python side (kernels/ref.py).
/// Finite so sentinel-sentinel differences stay 0.0 instead of NaN.
pub const BIG: f32 = 1e30;

/// A dissimilarity measure over feature vectors.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Metric {
    /// Manhattan / city-block (the paper's choice).
    L1,
    /// Euclidean.
    L2,
    /// Squared Euclidean (matmul-friendly form on the XLA path).
    SqL2,
    /// Chebyshev (max coordinate difference).
    Chebyshev,
    /// Cosine distance `1 - cos(x, y)` (0 for zero vectors).
    Cosine,
}

impl Metric {
    /// Parse from the CLI / config spelling.
    pub fn parse(s: &str) -> Option<Metric> {
        Some(match s {
            "l1" | "manhattan" => Metric::L1,
            "l2" | "euclidean" => Metric::L2,
            "sqeuclidean" | "sql2" => Metric::SqL2,
            "chebyshev" | "linf" => Metric::Chebyshev,
            "cosine" => Metric::Cosine,
            _ => return None,
        })
    }

    /// Canonical name (manifest / CLI spelling).
    pub fn name(self) -> &'static str {
        match self {
            Metric::L1 => "l1",
            Metric::L2 => "l2",
            Metric::SqL2 => "sqeuclidean",
            Metric::Chebyshev => "chebyshev",
            Metric::Cosine => "cosine",
        }
    }

    /// Pointwise dissimilarity between two vectors.
    #[inline]
    pub fn eval(self, a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        match self {
            Metric::L1 => self::l1(a, b),
            Metric::L2 => self::sq_l2(a, b).sqrt(),
            Metric::SqL2 => self::sq_l2(a, b),
            Metric::Chebyshev => a
                .iter()
                .zip(b)
                .map(|(x, y)| (x - y).abs())
                .fold(0.0f32, f32::max),
            Metric::Cosine => {
                let (mut xy, mut xx, mut yy) = (0.0f32, 0.0f32, 0.0f32);
                for (x, y) in a.iter().zip(b) {
                    xy += x * y;
                    xx += x * x;
                    yy += y * y;
                }
                if xx == 0.0 || yy == 0.0 {
                    0.0
                } else {
                    1.0 - xy / (xx.sqrt() * yy.sqrt())
                }
            }
        }
    }
}

// Point-to-point evaluation: the plain iterator form measured fastest
// for single pairs (manual lane-accumulators were tried and *regressed*
// at p <= 128 — see EXPERIMENTS.md §Perf).  Bulk matrices go through
// the transposed kernel in `cross_matrix` instead.

#[inline]
fn l1(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).sum()
}

#[inline]
fn sq_l2(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum()
}

/// Dissimilarity evaluator with telemetry counting.
///
/// Every algorithm in the crate routes point-to-point evaluations through
/// this, so the `O(nm)` / `O(n^2)` / `O((T+k) n log n)` claims of Table 1
/// can be *measured* (see benches/complexity.rs).
#[derive(Clone)]
pub struct DissimCounter {
    /// The metric in use.
    pub metric: Metric,
    counters: Arc<Counters>,
}

impl DissimCounter {
    /// Wrap a metric with a fresh counter set.
    pub fn new(metric: Metric) -> Self {
        DissimCounter { metric, counters: Arc::new(Counters::default()) }
    }

    /// Wrap with shared counters (e.g. one per experiment run).
    pub fn with_counters(metric: Metric, counters: Arc<Counters>) -> Self {
        DissimCounter { metric, counters }
    }

    /// Evaluate `d(a, b)`, counting one dissimilarity computation.
    #[inline]
    pub fn eval(&self, a: &[f32], b: &[f32]) -> f32 {
        self.counters.add_dissim(1);
        self.metric.eval(a, b)
    }

    /// Distances from one point to many rows of `x` (counts `idx.len()`).
    pub fn point_to_rows(&self, x: &Matrix, point: &[f32], idx: &[usize]) -> Vec<f32> {
        self.counters.add_dissim(idx.len() as u64);
        idx.iter().map(|&i| self.metric.eval(x.row(i), point)).collect()
    }

    /// Total dissimilarity computations so far.
    pub fn count(&self) -> u64 {
        self.counters.dissim()
    }

    /// Shared counters handle.
    pub fn counters(&self) -> Arc<Counters> {
        self.counters.clone()
    }
}

/// Blocked `rows(x) x rows(b)` distance matrix (native path, serial).
///
/// Convenience wrapper over [`cross_matrix_pool`] with the serial pool.
pub fn cross_matrix(d: &DissimCounter, x: &Matrix, b: &Matrix) -> Matrix {
    cross_matrix_pool(d, x, b, &Pool::serial())
}

/// Blocked `rows(x) x rows(b)` distance matrix, row-partitioned over
/// `pool` (the method's single `O(nmp)` cost).
///
/// For the accumulable metrics (L1 / L2 / SqL2 / Chebyshev) this uses a
/// **transposed batch layout**: `b` is transposed once to `(p, m)` so the
/// inner loop runs SIMD across a block of batch columns with contiguous
/// loads (measured 2.2x at p=16 up to 5.8x at p=784 over the
/// row-by-row form — EXPERIMENTS.md §Perf).  Cosine falls back to the
/// row path.  Counts `n*m` evaluations either way.
///
/// Rows are independent and each output cell accumulates in the same
/// order regardless of the chunking, so the result is bit-identical at
/// any thread count (rust/tests/parallel_equivalence.rs).
pub fn cross_matrix_pool(d: &DissimCounter, x: &Matrix, b: &Matrix, pool: &Pool) -> Matrix {
    assert_eq!(x.cols, b.cols, "feature dims differ");
    d.counters.add_dissim((x.rows * b.rows) as u64);
    let (n, m, p) = (x.rows, b.rows, x.cols);
    let mut out = Matrix::zeros(n, m);
    let metric = d.metric;
    if m == 0 || n == 0 {
        return out;
    }

    if matches!(metric, Metric::Cosine) || m < 8 {
        // row-by-row fallback (non-accumulable metric or tiny batch)
        pool.for_each_row_chunk(&mut out.data, n, m, |row0, chunk| {
            for (di, orow) in chunk.chunks_mut(m).enumerate() {
                let xi = x.row(row0 + di);
                for j in 0..m {
                    orow[j] = metric.eval(xi, b.row(j));
                }
            }
        });
        return out;
    }

    // transpose b to (p, m): bt[d * m + j] = b[j, d]
    let mut bt = vec![0.0f32; p * m];
    for j in 0..m {
        let brow = b.row(j);
        for dd in 0..p {
            bt[dd * m + j] = brow[dd];
        }
    }

    // j-blocked accumulation, SIMD across the batch columns; each worker
    // owns a contiguous row chunk and reads the shared transpose.
    const BJ: usize = 64;
    let post_sqrt = metric == Metric::L2;
    let bt = &bt;
    pool.for_each_row_chunk(&mut out.data, n, m, |row0, chunk| {
        for (di, full_row) in chunk.chunks_mut(m).enumerate() {
            let xi = x.row(row0 + di);
            for j0 in (0..m).step_by(BJ) {
                let jw = BJ.min(m - j0);
                let orow = &mut full_row[j0..j0 + jw];
                orow.iter_mut().for_each(|v| *v = 0.0);
                match metric {
                    Metric::L1 => {
                        for (dd, &xv) in xi.iter().enumerate() {
                            let brow = &bt[dd * m + j0..dd * m + j0 + jw];
                            for l in 0..jw {
                                orow[l] += (xv - brow[l]).abs();
                            }
                        }
                    }
                    Metric::SqL2 | Metric::L2 => {
                        for (dd, &xv) in xi.iter().enumerate() {
                            let brow = &bt[dd * m + j0..dd * m + j0 + jw];
                            for l in 0..jw {
                                let diff = xv - brow[l];
                                orow[l] += diff * diff;
                            }
                        }
                    }
                    Metric::Chebyshev => {
                        for (dd, &xv) in xi.iter().enumerate() {
                            let brow = &bt[dd * m + j0..dd * m + j0 + jw];
                            for l in 0..jw {
                                orow[l] = orow[l].max((xv - brow[l]).abs());
                            }
                        }
                    }
                    Metric::Cosine => unreachable!(),
                }
                if post_sqrt {
                    orow.iter_mut().for_each(|v| *v = v.sqrt());
                }
            }
        }
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn m(rows: usize, cols: usize, v: Vec<f32>) -> Matrix {
        Matrix::from_vec(rows, cols, v)
    }

    #[test]
    fn l1_known() {
        assert_eq!(Metric::L1.eval(&[0.0, 0.0], &[1.0, 2.0]), 3.0);
    }

    #[test]
    fn l2_and_sql2_consistent() {
        let (a, b) = ([3.0f32, 0.0], [0.0f32, 4.0]);
        assert!((Metric::L2.eval(&a, &b) - 5.0).abs() < 1e-6);
        assert!((Metric::SqL2.eval(&a, &b) - 25.0).abs() < 1e-4);
    }

    #[test]
    fn chebyshev_known() {
        assert_eq!(Metric::Chebyshev.eval(&[1.0, 5.0], &[4.0, 6.0]), 3.0);
    }

    #[test]
    fn cosine_bounds_and_zero_vec() {
        assert!(Metric::Cosine.eval(&[1.0, 0.0], &[1.0, 0.0]).abs() < 1e-6);
        assert!((Metric::Cosine.eval(&[1.0, 0.0], &[-1.0, 0.0]) - 2.0).abs() < 1e-6);
        assert_eq!(Metric::Cosine.eval(&[0.0, 0.0], &[1.0, 0.0]), 0.0);
    }

    #[test]
    fn metric_axioms_identity_symmetry() {
        let mut rng = crate::rng::Rng::new(2);
        for metric in [Metric::L1, Metric::L2, Metric::SqL2, Metric::Chebyshev] {
            for _ in 0..50 {
                let a: Vec<f32> = (0..6).map(|_| rng.normal() as f32).collect();
                let b: Vec<f32> = (0..6).map(|_| rng.normal() as f32).collect();
                assert!(metric.eval(&a, &a) < 1e-5);
                assert!((metric.eval(&a, &b) - metric.eval(&b, &a)).abs() < 1e-5);
                assert!(metric.eval(&a, &b) >= 0.0);
            }
        }
    }

    #[test]
    fn parse_round_trips() {
        for metric in [Metric::L1, Metric::L2, Metric::SqL2, Metric::Chebyshev, Metric::Cosine] {
            assert_eq!(Metric::parse(metric.name()), Some(metric));
        }
        assert_eq!(Metric::parse("bogus"), None);
    }

    #[test]
    fn cross_matrix_matches_pointwise_and_counts() {
        let x = m(3, 2, vec![0., 0., 1., 1., 2., 0.]);
        let b = m(2, 2, vec![0., 1., 2., 2.]);
        let d = DissimCounter::new(Metric::L1);
        let c = cross_matrix(&d, &x, &b);
        for i in 0..3 {
            for j in 0..2 {
                assert_eq!(c.get(i, j), Metric::L1.eval(x.row(i), b.row(j)));
            }
        }
        assert_eq!(d.count(), 6);
    }

    #[test]
    fn cross_matrix_blocked_equals_unblocked_large() {
        let mut rng = crate::rng::Rng::new(3);
        let x = Matrix::from_vec(70, 5, (0..350).map(|_| rng.f32()).collect());
        let b = Matrix::from_vec(67, 5, (0..335).map(|_| rng.f32()).collect());
        let d = DissimCounter::new(Metric::L1);
        let c = cross_matrix(&d, &x, &b);
        for i in [0, 13, 69] {
            for j in [0, 31, 32, 66] {
                assert!((c.get(i, j) - Metric::L1.eval(x.row(i), b.row(j))).abs() < 1e-5);
            }
        }
    }
}
