//! Evaluation: exact k-medoids objective, the paper's ΔRO / RT metrics
//! (Eq. 6), Pareto-front extraction (Appendix D) and the cluster-quality
//! utilities in [`quality`].

pub mod quality;

use crate::data::{RowStore, STREAM_CHUNK_ROWS};
use crate::dissim::DissimCounter;
use crate::linalg::Matrix;

/// Exact objective `L(M) = (1/n) sum_i d(x_i, M)` (n*k evaluations).
///
/// Evaluation is *not* part of any algorithm's timed section, matching
/// the paper's protocol.
pub fn objective(x: &Matrix, medoids: &[usize], d: &DissimCounter) -> f64 {
    let n = x.rows;
    let mut total = 0.0f64;
    for i in 0..n {
        let xi = x.row(i);
        let mut best = f32::INFINITY;
        for &m in medoids {
            let v = d.eval(xi, x.row(m));
            if v < best {
                best = v;
            }
        }
        total += best as f64;
    }
    total / n as f64
}

/// [`objective`] over a [`RowStore`]: the exact full-data objective
/// accumulated chunk-at-a-time, for solves whose dataset is never
/// resident.  `medoid_rows` is the `k x p` matrix gathered from the
/// store in medoid order (what [`crate::solver::FittedModel`] carries).
/// Rows are visited in ascending order and the per-row minimum runs the
/// same strict-`<` scan over the same operands as the resident loop, so
/// the f64 accumulation is bit-identical to [`objective`] on the
/// materialized matrix.
pub fn objective_store(
    store: &mut dyn RowStore,
    medoid_rows: &Matrix,
    d: &DissimCounter,
) -> anyhow::Result<f64> {
    let (n, p) = store.dims();
    anyhow::ensure!(
        medoid_rows.cols == p,
        "medoid rows are {}-wide but the store serves {}-wide rows",
        medoid_rows.cols,
        p
    );
    let mut chunk = vec![0.0f32; STREAM_CHUNK_ROWS.min(n).max(1) * p];
    let mut total = 0.0f64;
    let mut row0 = 0usize;
    while row0 < n {
        let xs = store.read_chunk(row0, &mut chunk)?;
        let rows = xs.len() / p;
        for i in 0..rows {
            let xi = &xs[i * p..(i + 1) * p];
            let mut best = f32::INFINITY;
            for j in 0..medoid_rows.rows {
                let v = d.eval(xi, medoid_rows.row(j));
                if v < best {
                    best = v;
                }
            }
            total += best as f64;
        }
        row0 += rows;
    }
    Ok(total / n as f64)
}

/// One algorithm's measurement on one workload.
#[derive(Clone, Debug)]
pub struct Measurement {
    /// Algorithm display name (paper row label).
    pub method: String,
    /// Wall-clock seconds of the selection itself.
    pub seconds: f64,
    /// Exact full-data objective of the selected medoids.
    pub objective: f64,
    /// Dissimilarity computations used by the selection.
    pub dissim_count: u64,
}

/// Delta relative objective (paper Eq. 6): `L(M_A)/L(M_A*) - 1`, in %,
/// where `A*` is the best objective in the run set.
pub fn delta_relative_objective(objectives: &[f64]) -> Vec<f64> {
    let best = objectives
        .iter()
        .copied()
        .filter(|v| v.is_finite())
        .fold(f64::INFINITY, f64::min);
    objectives
        .iter()
        .map(|&o| if o.is_finite() { (o / best - 1.0) * 100.0 } else { f64::NAN })
        .collect()
}

/// Relative time (paper Eq. 6): `T_A / T_ref`, in %, against an explicit
/// reference time (the paper normalises by FasterPAM on small scale and
/// by OneBatch-nniw on large scale).
pub fn relative_time(seconds: &[f64], reference: f64) -> Vec<f64> {
    seconds
        .iter()
        .map(|&s| if reference > 0.0 { s / reference * 100.0 } else { f64::NAN })
        .collect()
}

/// A point in (time, objective) space for Pareto analysis.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ParetoPoint {
    /// Run time (seconds).
    pub time: f64,
    /// Objective value.
    pub objective: f64,
    /// Index into the original measurement list.
    pub index: usize,
}

/// Indices of the Pareto-optimal points (minimise both time and
/// objective).  A point is dominated if another has `time <=` AND
/// `objective <=` with at least one strict.  Output sorted by time.
pub fn pareto_front(points: &[(f64, f64)]) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..points.len())
        .filter(|&i| points[i].0.is_finite() && points[i].1.is_finite())
        .collect();
    idx.sort_by(|&a, &b| {
        points[a]
            .0
            .partial_cmp(&points[b].0)
            .unwrap()
            .then(points[a].1.partial_cmp(&points[b].1).unwrap())
    });
    let mut front = Vec::new();
    let mut best_obj = f64::INFINITY;
    for &i in &idx {
        if points[i].1 < best_obj {
            front.push(i);
            best_obj = points[i].1;
        }
    }
    front
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dissim::Metric;
    use crate::rng::Rng;

    #[test]
    fn objective_known_values() {
        // points on a line: 0, 1, 10; medoid {0} -> mean(0,1,10)
        let x = Matrix::from_vec(3, 1, vec![0.0, 1.0, 10.0]);
        let d = DissimCounter::new(Metric::L1);
        assert!((objective(&x, &[0], &d) - 11.0 / 3.0).abs() < 1e-6);
        // medoids {0, 2} -> mean(0, 1, 0)
        assert!((objective(&x, &[0, 2], &d) - 1.0 / 3.0).abs() < 1e-6);
    }

    #[test]
    fn objective_more_medoids_never_worse() {
        let mut rng = Rng::new(1);
        let x = Matrix::from_vec(50, 3, (0..150).map(|_| rng.f32()).collect());
        let d = DissimCounter::new(Metric::L1);
        let o2 = objective(&x, &[0, 1], &d);
        let o3 = objective(&x, &[0, 1, 2], &d);
        assert!(o3 <= o2 + 1e-9);
    }

    #[test]
    fn objective_store_is_bit_identical_to_resident() {
        let mut rng = Rng::new(9);
        let x = Matrix::from_vec(130, 5, (0..650).map(|_| rng.f32()).collect());
        let medoids = [3usize, 41, 97];
        for metric in [Metric::L1, Metric::L2, Metric::SqL2] {
            let d = DissimCounter::new(metric);
            let resident = objective(&x, &medoids, &d);
            let medoid_rows = x.select_rows(&medoids);
            let mut store = crate::data::store::ResidentStore::new(x.clone());
            // drive the chunk loop, not the as_matrix shortcut: the
            // function reads through read_chunk regardless
            let streamed = objective_store(&mut store, &medoid_rows, &d).unwrap();
            assert_eq!(resident.to_bits(), streamed.to_bits(), "{}", metric.name());
        }
    }

    #[test]
    fn dro_best_is_zero() {
        let dro = delta_relative_objective(&[2.0, 1.0, 4.0]);
        assert!((dro[1]).abs() < 1e-12);
        assert!((dro[0] - 100.0).abs() < 1e-9);
        assert!((dro[2] - 300.0).abs() < 1e-9);
    }

    #[test]
    fn dro_ignores_nan_rows() {
        let dro = delta_relative_objective(&[f64::NAN, 1.0]);
        assert!(dro[0].is_nan());
        assert_eq!(dro[1], 0.0);
    }

    #[test]
    fn rt_normalises() {
        let rt = relative_time(&[0.5, 1.0, 2.0], 1.0);
        assert_eq!(rt, vec![50.0, 100.0, 200.0]);
    }

    #[test]
    fn pareto_front_minimal_and_dominating() {
        //       time  obj
        let pts = [(1.0, 5.0), (2.0, 3.0), (3.0, 4.0), (4.0, 1.0), (0.5, 9.0)];
        let front = pareto_front(&pts);
        assert_eq!(front, vec![4, 0, 1, 3]); // sorted by time
        // every non-front point is dominated by some front point
        for i in 0..pts.len() {
            if front.contains(&i) {
                continue;
            }
            assert!(front.iter().any(|&f| pts[f].0 <= pts[i].0 && pts[f].1 <= pts[i].1));
        }
    }

    #[test]
    fn pareto_handles_nan() {
        let pts = [(1.0, f64::NAN), (2.0, 1.0)];
        assert_eq!(pareto_front(&pts), vec![1]);
    }

    #[test]
    fn pareto_random_front_property() {
        let mut rng = Rng::new(9);
        let pts: Vec<(f64, f64)> = (0..60).map(|_| (rng.f64(), rng.f64())).collect();
        let front = pareto_front(&pts);
        assert!(!front.is_empty());
        // along the front (sorted by time), objectives strictly decrease,
        // so no front point dominates another
        for w in front.windows(2) {
            assert!(pts[w[0]].0 <= pts[w[1]].0);
            assert!(pts[w[0]].1 > pts[w[1]].1);
        }
        // and every non-front point is dominated
        for i in 0..pts.len() {
            if !front.contains(&i) {
                assert!(front
                    .iter()
                    .any(|&f| pts[f].0 <= pts[i].0 && pts[f].1 <= pts[i].1));
            }
        }
    }
}
