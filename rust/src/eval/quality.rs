//! Cluster-quality utilities beyond the raw objective: hard assignment,
//! per-cluster statistics and the (medoid-based) simplified silhouette.
//!
//! These are what downstream users of a k-medoids library actually call
//! after clustering; the paper's evaluation only needs `objective`, but a
//! release-grade library needs the rest.

use crate::dissim::DissimCounter;
use crate::linalg::Matrix;

/// Hard assignment of every row to its nearest medoid.
/// Returns (assignment: n -> slot index, distance to that medoid).
pub fn assign(x: &Matrix, medoids: &[usize], d: &DissimCounter) -> (Vec<usize>, Vec<f32>) {
    let n = x.rows;
    let mut a = vec![0usize; n];
    let mut dist = vec![0f32; n];
    for i in 0..n {
        let xi = x.row(i);
        let (mut bl, mut bv) = (0usize, f32::INFINITY);
        for (l, &m) in medoids.iter().enumerate() {
            let v = d.eval(xi, x.row(m));
            if v < bv {
                bv = v;
                bl = l;
            }
        }
        a[i] = bl;
        dist[i] = bv;
    }
    (a, dist)
}

/// Assign *new* points (rows of `q`) to the medoids of a fitted model —
/// the "predict" half of the API.
pub fn assign_new(x: &Matrix, medoids: &[usize], q: &Matrix, d: &DissimCounter) -> Vec<usize> {
    (0..q.rows)
        .map(|i| {
            let qi = q.row(i);
            let (mut bl, mut bv) = (0usize, f32::INFINITY);
            for (l, &m) in medoids.iter().enumerate() {
                let v = d.eval(qi, x.row(m));
                if v < bv {
                    bv = v;
                    bl = l;
                }
            }
            bl
        })
        .collect()
}

/// Simplified (medoid-based) silhouette: for each point,
/// `s = (b - a) / max(a, b)` with `a` = distance to its own medoid and
/// `b` = distance to the nearest *other* medoid.  Returns the mean over
/// all non-medoid points; in [-1, 1], higher is better.
///
/// This is the standard O(nk) approximation (full silhouette is O(n^2),
/// exactly the cost the paper is trying to avoid).
pub fn simplified_silhouette(x: &Matrix, medoids: &[usize], d: &DissimCounter) -> f64 {
    assert!(medoids.len() >= 2);
    let n = x.rows;
    let mut total = 0.0f64;
    let mut count = 0usize;
    for i in 0..n {
        if medoids.contains(&i) {
            continue;
        }
        let xi = x.row(i);
        let (mut a, mut b) = (f32::INFINITY, f32::INFINITY);
        for &m in medoids {
            let v = d.eval(xi, x.row(m));
            if v < a {
                b = a;
                a = v;
            } else if v < b {
                b = v;
            }
        }
        let denom = a.max(b);
        if denom > 0.0 {
            total += ((b - a) / denom) as f64;
        }
        count += 1;
    }
    if count == 0 {
        0.0
    } else {
        total / count as f64
    }
}

/// Per-cluster summary: (size, mean within-cluster distance to medoid).
pub fn cluster_stats(x: &Matrix, medoids: &[usize], d: &DissimCounter) -> Vec<(usize, f64)> {
    let (a, dist) = assign(x, medoids, d);
    let k = medoids.len();
    let mut size = vec![0usize; k];
    let mut sum = vec![0f64; k];
    for i in 0..x.rows {
        size[a[i]] += 1;
        sum[a[i]] += dist[i] as f64;
    }
    (0..k)
        .map(|l| (size[l], if size[l] > 0 { sum[l] / size[l] as f64 } else { 0.0 }))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dissim::Metric;
    use crate::rng::Rng;

    fn two_blobs() -> Matrix {
        // 10 points at ~0, 10 points at ~100
        let mut rng = Rng::new(1);
        let mut data = Vec::new();
        for c in 0..2 {
            for _ in 0..10 {
                data.push(c as f32 * 100.0 + rng.f32());
                data.push(c as f32 * 100.0 + rng.f32());
            }
        }
        Matrix::from_vec(20, 2, data)
    }

    #[test]
    fn assign_respects_geometry() {
        let x = two_blobs();
        let d = DissimCounter::new(Metric::L1);
        let (a, dist) = assign(&x, &[0, 10], &d);
        assert!(a[..10].iter().all(|&l| l == 0));
        assert!(a[10..].iter().all(|&l| l == 1));
        assert!(dist.iter().all(|&v| v < 5.0));
    }

    #[test]
    fn assign_new_predicts() {
        let x = two_blobs();
        let d = DissimCounter::new(Metric::L1);
        let q = Matrix::from_vec(2, 2, vec![1.0, 1.0, 99.0, 99.0]);
        assert_eq!(assign_new(&x, &[0, 10], &q, &d), vec![0, 1]);
    }

    #[test]
    fn silhouette_high_for_separated_low_for_bad() {
        let x = two_blobs();
        let d = DissimCounter::new(Metric::L1);
        let good = simplified_silhouette(&x, &[0, 10], &d);
        assert!(good > 0.9, "{good}");
        // both medoids in the same blob -> poor silhouette
        let bad = simplified_silhouette(&x, &[0, 1], &d);
        assert!(bad < good, "bad {bad} vs good {good}");
    }

    #[test]
    fn cluster_stats_sizes_sum_to_n() {
        let x = two_blobs();
        let d = DissimCounter::new(Metric::L1);
        let stats = cluster_stats(&x, &[0, 10], &d);
        assert_eq!(stats.iter().map(|s| s.0).sum::<usize>(), 20);
        assert_eq!(stats[0].0, 10);
        assert!(stats[0].1 < 2.0);
    }
}
