//! Shared helpers for the custom bench harness (criterion is unavailable
//! offline): env-tunable scale knobs, robust timing (median + MAD over
//! warm iterations) and records-CSV reload so the per-table benches can
//! share one expensive grid run.

use super::runner::Record;
use std::path::Path;
use std::time::Instant;

/// `OBPAM_SCALE` (default `default`): multiplies dataset sizes.
pub fn env_scale(default: f64) -> f64 {
    std::env::var("OBPAM_SCALE").ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}

/// `OBPAM_REPS` (default `default`): experiment repetitions.
pub fn env_reps(default: usize) -> usize {
    std::env::var("OBPAM_REPS").ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}

/// `OBPAM_THREADS` (default `default`): execution-pool width for the
/// benches (`1` = serial, `0` = auto-detect cores).  Selections are
/// identical at any value; only wall-clock changes.
pub fn env_threads(default: usize) -> usize {
    std::env::var("OBPAM_THREADS").ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}

/// `OBPAM_KS` (default `default`, e.g. "10,50,100").
pub fn env_ks(default: &[usize]) -> Vec<usize> {
    match std::env::var("OBPAM_KS") {
        Ok(s) => s.split(',').filter_map(|t| t.trim().parse().ok()).collect(),
        Err(_) => default.to_vec(),
    }
}

/// Generic comma-separated usize env list.
pub fn env_list(name: &str, default: &[usize]) -> Vec<usize> {
    match std::env::var(name) {
        Ok(s) => s.split(',').filter_map(|t| t.trim().parse().ok()).collect(),
        Err(_) => default.to_vec(),
    }
}

/// Median + median-absolute-deviation of `iters` timed runs after
/// `warmup` discarded ones.  Returns (median_secs, mad_secs).
pub fn time_median(warmup: usize, iters: usize, mut f: impl FnMut()) -> (f64, f64) {
    for _ in 0..warmup {
        f();
    }
    let mut times: Vec<f64> = (0..iters.max(1))
        .map(|_| {
            let t = Instant::now();
            f();
            t.elapsed().as_secs_f64()
        })
        .collect();
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let med = times[times.len() / 2];
    let mut devs: Vec<f64> = times.iter().map(|t| (t - med).abs()).collect();
    devs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    (med, devs[devs.len() / 2])
}

/// Reload records written by `emit::write_records_csv` (returns None when
/// the file is absent or `OBPAM_FRESH=1` forces regeneration).
pub fn load_records_csv(path: &Path) -> Option<Vec<Record>> {
    if std::env::var("OBPAM_FRESH").map(|v| v == "1").unwrap_or(false) {
        return None;
    }
    let text = std::fs::read_to_string(path).ok()?;
    let mut out = Vec::new();
    for line in text.lines().skip(1) {
        let f: Vec<&str> = line.split(',').collect();
        if f.len() != 8 {
            return None;
        }
        out.push(Record {
            dataset: f[0].into(),
            k: f[1].parse().ok()?,
            rep: f[2].parse().ok()?,
            method: f[3].into(),
            seconds: f[4].parse().ok()?,
            objective: f[5].parse().ok()?,
            dissim: f[6].parse().ok()?,
            swaps: f[7].parse().ok()?,
        });
    }
    if out.is_empty() {
        None
    } else {
        Some(out)
    }
}

/// Fit the exponent b of `y = a x^b` by least squares on log-log points.
pub fn fit_power_law(points: &[(f64, f64)]) -> f64 {
    let pts: Vec<(f64, f64)> = points
        .iter()
        .filter(|(x, y)| *x > 0.0 && *y > 0.0)
        .map(|&(x, y)| (x.ln(), y.ln()))
        .collect();
    let n = pts.len() as f64;
    if n < 2.0 {
        return f64::NAN;
    }
    let sx: f64 = pts.iter().map(|p| p.0).sum();
    let sy: f64 = pts.iter().map(|p| p.1).sum();
    let sxx: f64 = pts.iter().map(|p| p.0 * p.0).sum();
    let sxy: f64 = pts.iter().map(|p| p.0 * p.1).sum();
    (n * sxy - sx * sy) / (n * sxx - sx * sx)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_median_positive() {
        let (m, _) = time_median(0, 3, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(m >= 0.0);
    }

    #[test]
    fn power_law_recovers_exponent() {
        let pts: Vec<(f64, f64)> = (1..6).map(|i| (i as f64, (i as f64).powi(2) * 3.0)).collect();
        assert!((fit_power_law(&pts) - 2.0).abs() < 1e-9);
    }

    #[test]
    fn records_roundtrip() {
        let dir = std::env::temp_dir().join("obpam_bench_util");
        let p = dir.join("r.csv");
        let recs = vec![Record {
            dataset: "d".into(),
            k: 3,
            rep: 0,
            method: "Random".into(),
            seconds: 0.5,
            objective: 1.25,
            dissim: 10,
            swaps: 2,
        }];
        super::super::emit::write_records_csv(&p, &recs).unwrap();
        let loaded = load_records_csv(&p).unwrap();
        assert_eq!(loaded.len(), 1);
        assert_eq!(loaded[0].method, "Random");
        assert_eq!(loaded[0].dissim, 10);
    }
}
