//! Emitters: paper-style aligned text tables, ASCII bar/line charts and
//! CSV files under `bench_out/`.

use super::runner::Record;
use std::io::Write;
use std::path::Path;

/// Render an aligned text table.  `rows` are (label, cells).
pub fn render_table(title: &str, headers: &[&str], rows: &[(String, Vec<String>)]) -> String {
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    let label_w = rows
        .iter()
        .map(|(l, _)| l.len())
        .chain(std::iter::once(8))
        .max()
        .unwrap();
    for (_, cells) in rows {
        for (i, c) in cells.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(c.len());
            }
        }
    }
    let mut out = String::new();
    out.push_str(&format!("== {title} ==\n"));
    out.push_str(&format!("{:label_w$}", "Method"));
    for (h, w) in headers.iter().zip(&widths) {
        out.push_str(&format!("  {h:>w$}"));
    }
    out.push('\n');
    out.push_str(&"-".repeat(label_w + widths.iter().map(|w| w + 2).sum::<usize>()));
    out.push('\n');
    for (label, cells) in rows {
        out.push_str(&format!("{label:label_w$}"));
        for (c, w) in cells.iter().zip(&widths) {
            out.push_str(&format!("  {c:>w$}"));
        }
        out.push('\n');
    }
    out
}

/// Format `mean (std)` with paper-style one-decimal percentages.
pub fn pct(mean: f64, std: f64) -> String {
    if mean.is_nan() {
        "Na".into()
    } else {
        format!("{mean:.1} ({std:.1})")
    }
}

/// Write raw records as CSV (one row per run).
pub fn write_records_csv(path: &Path, records: &[Record]) -> std::io::Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::fs::File::create(path)?;
    writeln!(f, "dataset,k,rep,method,seconds,objective,dissim,swaps")?;
    for r in records {
        writeln!(
            f,
            "{},{},{},{},{:.6},{:.6},{},{}",
            r.dataset, r.k, r.rep, r.method, r.seconds, r.objective, r.dissim, r.swaps
        )?;
    }
    Ok(())
}

/// Write generic CSV (header + rows of stringified cells).
pub fn write_csv(path: &Path, header: &str, rows: &[Vec<String>]) -> std::io::Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::fs::File::create(path)?;
    writeln!(f, "{header}")?;
    for row in rows {
        writeln!(f, "{}", row.join(","))?;
    }
    Ok(())
}

/// ASCII horizontal bar chart (used for the Figure 2-11 RT/ΔRO bars).
pub fn bar_chart(title: &str, items: &[(String, f64)], width: usize) -> String {
    let max = items
        .iter()
        .map(|(_, v)| v.abs())
        .fold(0.0f64, f64::max)
        .max(1e-12);
    let label_w = items.iter().map(|(l, _)| l.len()).max().unwrap_or(4);
    let mut out = format!("-- {title} --\n");
    for (label, v) in items {
        let bars = ((v.abs() / max) * width as f64).round() as usize;
        out.push_str(&format!("{label:label_w$} | {:<width$} {v:8.2}\n", "#".repeat(bars)));
    }
    out
}

/// ASCII scatter for the Pareto figures: points ('.') and front ('X'),
/// log-scaled time on the x-axis when the spread is wide.
pub fn scatter(title: &str, pts: &[(f64, f64, String)], front: &[usize]) -> String {
    const W: usize = 72;
    const H: usize = 20;
    if pts.is_empty() {
        return format!("-- {title} -- (no points)\n");
    }
    let xs: Vec<f64> = pts.iter().map(|p| p.0.max(1e-9).ln()).collect();
    let ys: Vec<f64> = pts.iter().map(|p| p.1).collect();
    let (x0, x1) = (
        xs.iter().cloned().fold(f64::INFINITY, f64::min),
        xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max),
    );
    let (y0, y1) = (
        ys.iter().cloned().fold(f64::INFINITY, f64::min),
        ys.iter().cloned().fold(f64::NEG_INFINITY, f64::max),
    );
    let mut grid = vec![vec![' '; W]; H];
    for (i, _p) in pts.iter().enumerate() {
        let gx = if x1 > x0 { ((xs[i] - x0) / (x1 - x0) * (W - 1) as f64) as usize } else { 0 };
        let gy = if y1 > y0 { ((ys[i] - y0) / (y1 - y0) * (H - 1) as f64) as usize } else { 0 };
        let ch = if front.contains(&i) { 'X' } else { '.' };
        grid[H - 1 - gy][gx] = ch;
    }
    let mut out = format!("-- {title} -- (x: log time {:.3}s..{:.3}s, y: objective {y0:.4}..{y1:.4}; X = Pareto)\n", pts.iter().map(|p| p.0).fold(f64::INFINITY, f64::min), pts.iter().map(|p| p.0).fold(f64::NEG_INFINITY, f64::max));
    for row in grid {
        out.push('|');
        out.extend(row);
        out.push('\n');
    }
    for (i, p) in pts.iter().enumerate() {
        out.push_str(&format!(
            "  {} {} t={:.4}s obj={:.5}\n",
            if front.contains(&i) { "X" } else { "." },
            p.2,
            p.0,
            p.1
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_aligns() {
        let t = render_table(
            "T",
            &["RT", "dRO"],
            &[
                ("Random".into(), vec!["0.0".into(), "62.9".into()]),
                ("FasterPAM".into(), vec!["100.0".into(), "0.0".into()]),
            ],
        );
        assert!(t.contains("FasterPAM"));
        let lines: Vec<&str> = t.lines().collect();
        assert_eq!(lines[2].len(), lines[3].len().max(lines[2].len()).min(lines[2].len()));
    }

    #[test]
    fn pct_formats_na() {
        assert_eq!(pct(f64::NAN, f64::NAN), "Na");
        assert_eq!(pct(12.34, 0.5), "12.3 (0.5)");
    }

    #[test]
    fn csv_roundtrip(){
        let dir = std::env::temp_dir().join("obpam_emit_test");
        let p = dir.join("x.csv");
        write_csv(&p, "a,b", &[vec!["1".into(), "2".into()]]).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        assert_eq!(text, "a,b\n1,2\n");
    }

    #[test]
    fn bar_chart_renders() {
        let c = bar_chart("t", &[("a".into(), 1.0), ("bb".into(), 2.0)], 10);
        assert!(c.contains("bb"));
        assert!(c.contains("##########"));
    }

    #[test]
    fn scatter_marks_front() {
        let pts = vec![
            (0.1, 5.0, "a".into()),
            (1.0, 1.0, "b".into()),
        ];
        let s = scatter("t", &pts, &[1]);
        assert!(s.contains('X'));
        assert!(s.contains("obj=1"));
    }
}
