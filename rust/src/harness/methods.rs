//! The method grid of the paper's evaluation (Table 3 rows).

use crate::backend::{ComputeBackend, NativeBackend};
use crate::baselines;
use crate::coordinator::{self, onebatch::SwapStrategy, OneBatchConfig, SamplerKind};
use crate::dissim::Metric;
use crate::linalg::Matrix;
use crate::runtime::Pool;
use anyhow::Result;

/// One method variant, named exactly like the paper's result rows.
#[derive(Clone, Debug, PartialEq)]
pub enum MethodSpec {
    /// Random k-subset.
    Random,
    /// FasterPAM (full n x n; small scale only in the paper).
    FasterPam,
    /// Alternate (Park & Jun; small scale only).
    Alternate,
    /// FasterCLARA with I repetitions.
    FasterClara { reps: usize },
    /// kmc2 with chain length L.
    Kmc2 { chain: usize },
    /// k-means++ seeding.
    KMeansPp,
    /// LS-k-means++ with Z local-search steps.
    LsKMeansPp { steps: usize },
    /// BanditPAM++ with T swap rounds.
    BanditPam { swaps: usize },
    /// OneBatchPAM with a sampling variant.
    OneBatch { sampler: SamplerKind, strategy: SwapStrategy },
}

impl MethodSpec {
    /// Paper row label.
    pub fn label(&self) -> String {
        match self {
            MethodSpec::Random => "Random".into(),
            MethodSpec::FasterPam => "FasterPAM".into(),
            MethodSpec::Alternate => "Alternate".into(),
            MethodSpec::FasterClara { reps } => format!("FasterCLARA-{reps}"),
            MethodSpec::Kmc2 { chain } => format!("kmc2-{chain}"),
            MethodSpec::KMeansPp => "k-means++".into(),
            MethodSpec::LsKMeansPp { steps } => format!("LS-k-means++-{steps}"),
            MethodSpec::BanditPam { swaps } => format!("BanditPAM++-{swaps}"),
            MethodSpec::OneBatch { sampler, strategy } => match strategy {
                SwapStrategy::Eager => format!("OneBatch-{}", sampler.name()),
                SwapStrategy::Steepest => format!("OneBatch-{}-steepest", sampler.name()),
            },
        }
    }

    /// Does the paper run this method on large-scale datasets?
    /// (FasterPAM / Alternate / BanditPAM++ are "Na" there.)
    pub fn feasible_large_scale(&self) -> bool {
        !matches!(
            self,
            MethodSpec::FasterPam | MethodSpec::Alternate | MethodSpec::BanditPam { .. }
        )
    }

    /// The full 18-row method grid of Table 3.
    pub fn table3_grid() -> Vec<MethodSpec> {
        use MethodSpec::*;
        let mut v = vec![
            Random,
            FasterPam,
            Alternate,
            FasterClara { reps: 5 },
            FasterClara { reps: 50 },
            Kmc2 { chain: 20 },
            Kmc2 { chain: 100 },
            Kmc2 { chain: 200 },
            KMeansPp,
            LsKMeansPp { steps: 5 },
            LsKMeansPp { steps: 10 },
            BanditPam { swaps: 0 },
            BanditPam { swaps: 2 },
            BanditPam { swaps: 5 },
        ];
        for sampler in [SamplerKind::Lwcs, SamplerKind::Unif, SamplerKind::Debias, SamplerKind::Nniw] {
            v.push(OneBatch { sampler, strategy: SwapStrategy::Eager });
        }
        v
    }

    /// The 5-method subset of Figure 1 (KM, FP, FC, BP, OBP).
    pub fn fig1_grid() -> Vec<MethodSpec> {
        vec![
            MethodSpec::KMeansPp,
            MethodSpec::FasterPam,
            MethodSpec::FasterClara { reps: 5 },
            MethodSpec::BanditPam { swaps: 2 },
            MethodSpec::OneBatch { sampler: SamplerKind::Nniw, strategy: SwapStrategy::Eager },
        ]
    }

    /// Run the method serially; returns the selected medoids.
    pub fn run(&self, x: &Matrix, k: usize, metric: Metric, seed: u64) -> Result<RunOutput> {
        self.run_threaded(x, k, metric, seed, 1)
    }

    /// Run on a native backend with a `threads`-wide execution pool
    /// (`1` = serial, `0` = auto).  Matrix-level methods (OneBatch,
    /// FasterPAM, FasterCLARA) parallelise their pairwise/tile ops and
    /// OneBatch additionally its eager scan; selections are identical
    /// to the serial run for a fixed seed.
    pub fn run_threaded(
        &self,
        x: &Matrix,
        k: usize,
        metric: Metric,
        seed: u64,
        threads: usize,
    ) -> Result<RunOutput> {
        let backend = NativeBackend::with_pool(metric, Pool::new(threads));
        self.run_with_backend(x, k, seed, &backend, threads)
    }

    /// Run against an explicit backend (XLA-vs-native ablations).
    /// Point-level algorithms (Alternate, k-means++ family, BanditPAM)
    /// always use the backend's counted metric directly.  `threads`
    /// sizes the OneBatch eager-scan pool (backend tile ops use the
    /// backend's own pool).
    pub fn run_with_backend(
        &self,
        x: &Matrix,
        k: usize,
        seed: u64,
        backend: &dyn ComputeBackend,
        threads: usize,
    ) -> Result<RunOutput> {
        let metric = backend.metric();
        let counted = crate::dissim::DissimCounter::with_counters(metric, backend.counters());
        let r = match self {
            MethodSpec::Random => baselines::random_select(x, k, seed),
            MethodSpec::FasterPam => baselines::faster_pam(x, k, 50, seed, backend)?,
            MethodSpec::Alternate => baselines::alternate(x, k, 100, seed, &counted),
            MethodSpec::FasterClara { reps } => baselines::faster_clara(
                x,
                &baselines::ClaraConfig::new(k, *reps, seed),
                backend,
            )?,
            MethodSpec::Kmc2 { chain } => baselines::kmc2(x, k, *chain, seed, &counted),
            MethodSpec::KMeansPp => baselines::kmeanspp(x, k, seed, &counted),
            MethodSpec::LsKMeansPp { steps } => baselines::ls_kmeanspp(x, k, *steps, seed, &counted),
            MethodSpec::BanditPam { swaps } => baselines::bandit_pam(
                x,
                &baselines::BanditConfig::new(k, *swaps, seed),
                &counted,
            ),
            MethodSpec::OneBatch { sampler, strategy } => coordinator::one_batch_pam(
                x,
                &OneBatchConfig {
                    k,
                    sampler: *sampler,
                    strategy: *strategy,
                    seed,
                    threads,
                    ..Default::default()
                },
                backend,
            )?,
        };
        r.validate(x.rows, k);
        Ok(RunOutput {
            medoids: r.medoids,
            seconds: r.stats.seconds,
            dissim_count: r.stats.dissim_count,
            swap_count: r.stats.swap_count,
        })
    }
}

/// What the harness records per run before objective evaluation.
#[derive(Clone, Debug)]
pub struct RunOutput {
    /// Selected medoid rows.
    pub medoids: Vec<usize>,
    /// Timed selection seconds.
    pub seconds: f64,
    /// Dissimilarity computations.
    pub dissim_count: u64,
    /// Accepted swaps.
    pub swap_count: u64,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::rng::Rng;

    #[test]
    fn labels_match_paper_rows() {
        let labels: Vec<String> = MethodSpec::table3_grid().iter().map(|m| m.label()).collect();
        for expect in [
            "Random",
            "FasterPAM",
            "Alternate",
            "FasterCLARA-5",
            "FasterCLARA-50",
            "kmc2-20",
            "kmc2-100",
            "kmc2-200",
            "k-means++",
            "LS-k-means++-5",
            "LS-k-means++-10",
            "BanditPAM++-0",
            "BanditPAM++-2",
            "BanditPAM++-5",
            "OneBatch-lwcs",
            "OneBatch-unif",
            "OneBatch-debias",
            "OneBatch-nniw",
        ] {
            assert!(labels.iter().any(|l| l == expect), "missing {expect}");
        }
        assert_eq!(labels.len(), 18);
    }

    #[test]
    fn large_scale_feasibility_matches_paper_na() {
        assert!(!MethodSpec::FasterPam.feasible_large_scale());
        assert!(!MethodSpec::Alternate.feasible_large_scale());
        assert!(!MethodSpec::BanditPam { swaps: 2 }.feasible_large_scale());
        assert!(MethodSpec::FasterClara { reps: 5 }.feasible_large_scale());
        assert!(MethodSpec::KMeansPp.feasible_large_scale());
    }

    #[test]
    fn every_method_runs_on_tiny_data() {
        let mut rng = Rng::new(1);
        let x = synth::gen_gaussian_mixture(&mut rng, 130, 4, 3, 0.15, 1.0);
        for m in MethodSpec::table3_grid() {
            let out = m.run(&x, 3, Metric::L1, 7).unwrap();
            assert_eq!(out.medoids.len(), 3, "{}", m.label());
        }
    }

    #[test]
    fn threaded_run_selects_identical_medoids() {
        let mut rng = Rng::new(2);
        let x = synth::gen_gaussian_mixture(&mut rng, 160, 4, 3, 0.15, 1.0);
        for m in [
            MethodSpec::FasterPam,
            MethodSpec::OneBatch { sampler: SamplerKind::Nniw, strategy: SwapStrategy::Eager },
        ] {
            let serial = m.run(&x, 3, Metric::L1, 11).unwrap();
            let par = m.run_threaded(&x, 3, Metric::L1, 11, 4).unwrap();
            assert_eq!(serial.medoids, par.medoids, "{}", m.label());
            assert_eq!(serial.dissim_count, par.dissim_count, "{}", m.label());
        }
    }
}
