//! Compatibility shim: the method grid was promoted out of the harness
//! into the core [`crate::solver`] API (so the CLI, server and config
//! files can address any method by name, not just the bench harness).
//! `harness::methods` re-exports it to keep existing bench / test
//! imports working.

pub use crate::solver::{MethodSpec, RunOutput};
