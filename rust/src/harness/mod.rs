//! Experiment harness: runs (dataset x k x repetition x method) grids and
//! emits every table and figure of the paper's evaluation section.
//!
//! Methods are addressed through the unified [`crate::solver`] API
//! ([`MethodSpec`] re-exported here for the bench targets); DESIGN.md §4
//! maps each paper table/figure to the bench target that calls into this
//! module.  Output goes to stdout (paper-style aligned tables / ASCII
//! charts) and `bench_out/*.csv`.

pub mod bench_util;
pub mod emit;
pub mod methods;
pub mod runner;

pub use methods::{MethodSpec, RunOutput};
pub use runner::{run_grid, run_method, Record};
