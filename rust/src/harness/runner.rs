//! Grid runner: (dataset x k x repetition x method) -> [`Record`]s.
//!
//! Datasets are addressed by [`DataSource`] URI — `synth:` names, bare
//! catalogue names, or `file:/path.csv`, so the same grid runs on
//! generated and loaded data.  Timing protocol matches the paper: the
//! *selection* is timed; the exact full-data objective is evaluated
//! afterwards, outside the timed section, with an uncounted
//! dissimilarity evaluator.

use crate::backend::NativeBackend;
use crate::data::DataSource;
use crate::dissim::{DissimCounter, Metric};
use crate::eval;
use crate::linalg::Matrix;
use crate::runtime::Pool;
use crate::solver::{self, MethodSpec, SolveSpec, FULL_MATRIX_LIMIT};

/// One measured run.
#[derive(Clone, Debug)]
pub struct Record {
    /// Dataset name.
    pub dataset: String,
    /// Number of medoids.
    pub k: usize,
    /// Repetition index (seed stream).
    pub rep: usize,
    /// Method label (paper row).
    pub method: String,
    /// Selection wall-clock seconds.
    pub seconds: f64,
    /// Exact full-data objective of the selection.
    pub objective: f64,
    /// Dissimilarity computations during selection.
    pub dissim: u64,
    /// Accepted swaps.
    pub swaps: u64,
}

/// Run one method on one dataset instance and evaluate it exactly.
/// `threads` sizes the execution pool (`1` = serial, `0` = auto); the
/// selection is identical at any value for a fixed seed, only the
/// wall-clock changes.
#[allow(clippy::too_many_arguments)]
pub fn run_method(
    method: &MethodSpec,
    x: &Matrix,
    dataset: &str,
    k: usize,
    rep: usize,
    metric: Metric,
    seed: u64,
    threads: usize,
) -> anyhow::Result<Record> {
    let backend = NativeBackend::with_pool(metric, Pool::new(threads));
    let spec = SolveSpec { threads, metric, ..SolveSpec::new(method.clone(), k, seed) };
    let out = solver::solve(x, &spec, &backend)?;
    // evaluation is outside the timed section and uncounted
    let eval_d = DissimCounter::new(metric);
    let objective = eval::objective(x, &out.medoids, &eval_d);
    Ok(Record {
        dataset: dataset.into(),
        k,
        rep,
        method: method.label(),
        seconds: out.stats.seconds,
        objective,
        dissim: out.stats.dissim_count,
        swaps: out.stats.swap_count,
    })
}

/// Run the full grid.  `datasets` are [`DataSource`] URIs (bare synth
/// names, `synth:`, or `file:` paths).  `scale` multiplies synthetic
/// dataset sizes (OBPAM_SCALE convention); methods infeasible at large
/// scale are skipped for datasets the paper's catalogue flags large —
/// mirroring its "Na" cells — and for `file:` sources above
/// [`FULL_MATRIX_LIMIT`] rows (files carry no catalogue flag, so row
/// count is the only signal; synthetic sources keep the explicit
/// catalogue semantics, so a deliberately over-scaled blobs run still
/// executes).  `threads` sizes the per-run execution pool
/// (`OBPAM_THREADS` from the benches; selections are
/// thread-count-invariant).  `progress` receives one line per finished
/// run.
#[allow(clippy::too_many_arguments)]
pub fn run_grid(
    datasets: &[&str],
    ks: &[usize],
    reps: usize,
    methods: &[MethodSpec],
    scale: f64,
    metric: Metric,
    base_seed: u64,
    threads: usize,
    mut progress: impl FnMut(&Record),
) -> anyhow::Result<Vec<Record>> {
    let mut records = Vec::new();
    for &ds in datasets {
        let src = DataSource::parse(ds)?;
        // the data depends only on (src, scale, base_seed): load once per
        // dataset, not once per grid cell — the paper re-draws nothing
        // (per-rep seeds go to the algorithms), and for file: sources a
        // per-cell load would re-read the CSV from disk every time
        let data = src.load(scale, base_seed)?;
        let x = &data.x;
        // Na-cell skip: catalogue "large" flag for synth; row count for
        // files (no catalogue to consult) — an over-scaled synth run is
        // an explicit caller choice and still executes
        let skip_na =
            src.paper_large_scale() || (src.is_file() && x.rows > FULL_MATRIX_LIMIT);
        for (rep, &k) in (0..reps).flat_map(|r| ks.iter().map(move |k| (r, k))) {
            if x.rows <= k + 1 {
                continue;
            }
            for method in methods {
                if skip_na && !method.feasible_large_scale() {
                    continue;
                }
                let seed = base_seed
                    .wrapping_add(rep as u64)
                    .wrapping_mul(0x9E37_79B9)
                    .wrapping_add(k as u64);
                let rec = run_method(method, x, ds, k, rep, metric, seed, threads)?;
                progress(&rec);
                records.push(rec);
            }
        }
    }
    Ok(records)
}

/// Group records by (dataset, k, rep) — the unit within which ΔRO and RT
/// are computed before averaging (paper Eq. 6).
pub fn group_units<'a>(records: &'a [Record]) -> Vec<Vec<&'a Record>> {
    use std::collections::BTreeMap;
    let mut map: BTreeMap<(String, usize, usize), Vec<&Record>> = BTreeMap::new();
    for r in records {
        map.entry((r.dataset.clone(), r.k, r.rep)).or_default().push(r);
    }
    map.into_values().collect()
}

/// Per-method aggregate of ΔRO (%) and RT (%) across units.
///
/// `rt_reference` picks the normalising method per unit (the paper uses
/// FasterPAM on small scale, OneBatch-nniw on large scale).  Units where
/// the reference is missing are skipped for RT but kept for ΔRO.
pub fn aggregate(
    records: &[Record],
    rt_reference: &str,
) -> Vec<(String, f64, f64, f64, f64)> {
    use std::collections::BTreeMap;
    // method -> (rt values, dro values)
    let mut acc: BTreeMap<String, (Vec<f64>, Vec<f64>)> = BTreeMap::new();
    for unit in group_units(records) {
        let objectives: Vec<f64> = unit.iter().map(|r| r.objective).collect();
        let dro = eval::delta_relative_objective(&objectives);
        let ref_time = unit
            .iter()
            .find(|r| r.method == rt_reference)
            .map(|r| r.seconds);
        for (r, dro_v) in unit.iter().zip(dro) {
            let e = acc.entry(r.method.clone()).or_default();
            e.1.push(dro_v);
            if let Some(t) = ref_time {
                if t > 0.0 {
                    e.0.push(r.seconds / t * 100.0);
                }
            }
        }
    }
    let mean_std = |v: &[f64]| -> (f64, f64) {
        if v.is_empty() {
            return (f64::NAN, f64::NAN);
        }
        let m = v.iter().sum::<f64>() / v.len() as f64;
        let var = v.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / v.len() as f64;
        (m, var.sqrt())
    };
    acc.into_iter()
        .map(|(method, (rt, dro))| {
            let (rt_m, rt_s) = mean_std(&rt);
            let (dro_m, dro_s) = mean_std(&dro);
            (method, rt_m, rt_s, dro_m, dro_s)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::onebatch::SwapStrategy;
    use crate::coordinator::SamplerKind;

    fn tiny_methods() -> Vec<MethodSpec> {
        vec![
            MethodSpec::Random,
            MethodSpec::KMeansPp,
            MethodSpec::OneBatch { sampler: SamplerKind::Unif, strategy: SwapStrategy::Eager },
        ]
    }

    #[test]
    fn grid_runs_and_groups() {
        let recs = run_grid(
            &["blobs_400_4_3"],
            &[3],
            2,
            &tiny_methods(),
            1.0,
            Metric::L1,
            42,
            1,
            |_| {},
        )
        .unwrap();
        assert_eq!(recs.len(), 3 * 2);
        let units = group_units(&recs);
        assert_eq!(units.len(), 2);
        assert!(units.iter().all(|u| u.len() == 3));
    }

    #[test]
    fn aggregate_has_zero_dro_for_best_and_100_rt_for_reference() {
        let recs = run_grid(
            &["blobs_400_4_3"],
            &[3],
            1,
            &tiny_methods(),
            1.0,
            Metric::L1,
            7,
            1,
            |_| {},
        )
        .unwrap();
        let agg = aggregate(&recs, "Random");
        let random = agg.iter().find(|a| a.0 == "Random").unwrap();
        assert!((random.1 - 100.0).abs() < 1e-9, "reference RT must be 100%");
        // the best method in the unit has ΔRO == 0
        let min_dro = agg.iter().map(|a| a.3).fold(f64::INFINITY, f64::min);
        assert!(min_dro.abs() < 1e-9);
    }

    #[test]
    fn grid_runs_on_file_sources() {
        // the same grid API drives loaded CSVs: write one, address it by
        // file: URI, and get records back like any synth dataset
        let dir = std::env::temp_dir().join("obpam_runner_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(format!("grid_{}.csv", std::process::id()));
        let mut s = String::from("x,y\n");
        for i in 0..60 {
            let c = (i % 3) as f64 * 10.0;
            s.push_str(&format!("{},{}\n", c + (i % 5) as f64 * 0.1, c - (i % 4) as f64 * 0.1));
        }
        std::fs::write(&path, s).unwrap();
        let uri = format!("file:{}", path.display());
        let recs = run_grid(
            &[uri.as_str()],
            &[3],
            1,
            &tiny_methods(),
            1.0,
            Metric::L2,
            5,
            1,
            |_| {},
        )
        .unwrap();
        assert_eq!(recs.len(), 3);
        assert!(recs.iter().all(|r| r.dataset == uri));
        assert!(recs.iter().all(|r| r.objective.is_finite()));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn large_scale_skips_na_methods() {
        // use a real large-scale catalogue name at minuscule scale
        let recs = run_grid(
            &["gas"],
            &[3],
            1,
            &[MethodSpec::FasterPam, MethodSpec::KMeansPp],
            0.0005,
            Metric::L1,
            1,
            1,
            |_| {},
        )
        .unwrap();
        assert!(recs.iter().all(|r| r.method != "FasterPAM"));
        assert_eq!(recs.len(), 1);
    }
}
