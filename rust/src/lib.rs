//! # obpam — OneBatchPAM: fast and frugal k-medoids (AAAI 2025)
//!
//! Production-grade reproduction of *OneBatchPAM* (de Mathelin et al.,
//! AAAI 2025) as a three-layer Rust + JAX + Pallas stack:
//!
//! * **L1/L2 (build time)** — Pallas kernels + JAX graph in
//!   `python/compile/`, AOT-lowered to HLO text under `artifacts/`;
//! * **L3 (this crate)** — the coordinator: batch sampling, the
//!   FasterPAM swap engine over one `n x m` distance matrix, every
//!   baseline from the paper's evaluation, the experiment harness that
//!   regenerates each table/figure, and a clustering job server.
//!
//! Quick start (see `examples/quickstart.rs`):
//!
//! ```no_run
//! use obpam::backend::NativeBackend;
//! use obpam::coordinator::{one_batch_pam, OneBatchConfig};
//! use obpam::data::synth;
//! use obpam::dissim::Metric;
//!
//! let data = synth::generate("blobs_2000_8_5", 1.0, 42);
//! let cfg = OneBatchConfig { k: 5, ..Default::default() };
//! let backend = NativeBackend::new(Metric::L1);
//! let result = one_batch_pam(&data.x, &cfg, &backend).unwrap();
//! println!("medoids: {:?}", result.medoids);
//! ```

pub mod backend;
pub mod baselines;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod dissim;
pub mod eval;
pub mod harness;
pub mod linalg;
pub mod proptest;
pub mod rng;
pub mod runtime;
pub mod server;
pub mod telemetry;
