//! # obpam — OneBatchPAM: fast and frugal k-medoids (AAAI 2025)
//!
//! Production-grade reproduction of *OneBatchPAM* (de Mathelin et al.,
//! AAAI 2025) as a three-layer Rust + JAX + Pallas stack:
//!
//! * **L1/L2 (build time)** — Pallas kernels + JAX graph in
//!   `python/compile/`, AOT-lowered to HLO text under `artifacts/`
//!   (consumed by the `xla`-feature PJRT runtime);
//! * **L3 (this crate)** — the coordinator: batch sampling, the
//!   FasterPAM swap engine over one `n x m` distance matrix, every
//!   baseline from the paper's evaluation, the experiment harness that
//!   regenerates each table/figure, and a clustering job server.
//!
//! Both dominant costs — the `O(nmp)` pairwise pass and the
//! `O(n(m+k))` eager swap scan — are row-parallel over the
//! [`runtime::Pool`] execution layer.  The thread count is one knob
//! (`OneBatchConfig::threads` / `NativeBackend::with_pool` /
//! `--threads` on the CLI / `threads=` on the server protocol); for a
//! fixed seed the selected medoids are **bit-identical at any thread
//! count**, so parallelism never costs reproducibility.
//!
//! Quick start (see `examples/quickstart.rs`):
//!
//! ```no_run
//! use obpam::backend::NativeBackend;
//! use obpam::coordinator::{one_batch_pam, OneBatchConfig};
//! use obpam::data::synth;
//! use obpam::dissim::Metric;
//! use obpam::runtime::Pool;
//!
//! let data = synth::generate("blobs_2000_8_5", 1.0, 42);
//! // threads: 0 = all cores, 1 = serial; medoids identical either way.
//! let cfg = OneBatchConfig { k: 5, threads: 0, ..Default::default() };
//! let backend = NativeBackend::with_pool(Metric::L1, Pool::auto());
//! let result = one_batch_pam(&data.x, &cfg, &backend).unwrap();
//! println!("medoids: {:?}", result.medoids);
//! ```

pub mod backend;
pub mod baselines;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod dissim;
pub mod eval;
pub mod harness;
pub mod linalg;
pub mod proptest;
pub mod rng;
pub mod runtime;
pub mod server;
pub mod telemetry;
