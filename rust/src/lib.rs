//! # obpam — OneBatchPAM: fast and frugal k-medoids (AAAI 2025)
//!
//! Production-grade reproduction of *OneBatchPAM* (de Mathelin et al.,
//! AAAI 2025) as a three-layer Rust + JAX + Pallas stack:
//!
//! * **L1/L2 (build time)** — Pallas kernels + JAX graph in
//!   `python/compile/`, AOT-lowered to HLO text under `artifacts/`
//!   (consumed by the `xla`-feature PJRT runtime);
//! * **L3 (this crate)** — the coordinator: batch sampling, the
//!   FasterPAM swap engine over one `n x m` distance matrix, every
//!   baseline from the paper's evaluation, the experiment harness that
//!   regenerates each table/figure, and a clustering job server
//!   (protocol v7: any method by name, any dataset by URI, any metric,
//!   any compute profile (`profile=exact|fast`),
//!   with an **asynchronous job-handle API**, **cost-weighted
//!   admission** with queue-wait deadlines, a sharded dataset
//!   cache that loads cold misses outside its locks, and a
//!   **fitted-model serving path** — `promote` a finished job into a
//!   bounded model registry, then `assign` points against its medoids
//!   with no dataset in memory).
//!
//! Both dominant costs — the `O(nmp)` pairwise pass and the
//! `O(n(m+k))` eager swap scan — are row-parallel over the
//! [`runtime::Pool`] execution layer: a **persistent pool** of parked
//! workers, so a parallel region costs a wakeup rather than a thread
//! spawn and one pool serves every region of a job.  The thread count
//! is one knob (`OneBatchConfig::threads` / `NativeBackend::with_pool`
//! / `--threads` on the CLI / `threads=` on the server protocol); for a
//! fixed seed the selected medoids are **bit-identical at any thread
//! count and across pool reuse**, so parallelism never costs
//! reproducibility.
//!
//! Serving leans on the paper's asymmetry: OneBatchPAM prices at
//! `~ n*m` work units while full-matrix baselines price at `~ n^2`
//! ([`solver::MethodSpec::cost`] / [`solver::JobCost`]), so the server
//! admits many cheap OneBatch jobs concurrently against one weighted
//! budget where a single FasterPAM job would consume most of it —
//! replies carry `cost=` and `queue_ms=`, and `stats` exports
//! per-method latency histograms (solve + queue wait).
//!
//! Since protocol v5 the wire API is **asynchronous**: `submit` admits
//! a job and returns a `job=j<id>` handle immediately, `poll` / `wait`
//! / `cancel` drive it from any later connection, `deadline_ms=` sheds
//! jobs whose queue wait exceeds their deadline, and solver workers
//! drain *jobs* rather than connections — a slow client or a
//! long-running full-matrix baseline no longer pins a worker.  The
//! legacy one-shot `cluster` line is served as `submit`+`wait`
//! internally with byte-identical replies; cancellation is cooperative
//! via [`solver::CancelToken`] (checked between OneBatch swap passes),
//! and jobs reuse server-owned persistent execution pools keyed by
//! thread width ([`server::PoolCache`]).
//!
//! The distance layer itself runs **fused tile kernels**: the backend's
//! [`backend::ComputeBackend::pairwise_argmin`] /
//! [`backend::ComputeBackend::pairwise_top2`] produce the `n x m`
//! matrix *and* its per-row reduction in one blocked sweep (the row is
//! reduced while its tile is still cache-hot, never materialised and
//! rewalked), and a [`dissim::ComputeProfile`] knob selects between two
//! kernel families: `Exact` (the default; bit-identical
//! diff-accumulate kernels, what every paper table runs) and `Fast`
//! (the server/CLI default; squared-Euclidean and Euclidean via the
//! dot-product identity `d² = ‖x‖² + ‖b‖² − 2·x·b` with precomputed
//! norms — a GEMM-shaped inner loop at a bounded relative error, while
//! the other metrics stay bit-identical).  Both profiles keep the
//! bit-identical-at-any-thread-count promise.
//!
//! Protocol v6 adds the **read path**: every successful solve also
//! captures a dataset-free [`solver::FittedModel`] (the `k x p` medoid
//! feature rows plus the fit metric), `promote job=j3 name=prod` moves
//! it into the server's LRU-bounded [`server::ModelRegistry`], and
//! `assign model=prod point=v1,v2,...` labels new points — batched,
//! optionally with the runner-up medoid (`top2=1`), and without the
//! training dataset resident in any cache.  `models` / `evict` manage
//! the registry; `stats` reports per-model serving aggregates.  The
//! same model is usable offline via [`solver::fit_model`] /
//! [`solver::FittedModel::assign`].  See [`server`] for the full
//! protocol.
//!
//! Quick start (see `examples/quickstart.rs`): every algorithm —
//! OneBatchPAM and all eight paper baselines — runs through the unified
//! [`solver`] API, and every dataset — synthetic or loaded from disk —
//! through the [`data::DataSource`] URI pipeline.
//! [`solver::MethodSpec`] round-trips through the paper's row labels
//! and a dataset is one URI string, so a full run is addressable from a
//! config file, CLI flags, or one `cluster` line on the server wire
//! protocol:
//!
//! ```no_run
//! use obpam::backend::NativeBackend;
//! use obpam::data::DataSource;
//! use obpam::runtime::Pool;
//! use obpam::solver::{self, MethodSpec, SolveSpec};
//!
//! // "synth:blobs_2000_8_5" generates; "file:/data/points.csv" loads a
//! // numeric CSV; bare names alias synth: for back-compat.
//! let source = DataSource::parse("synth:blobs_2000_8_5").unwrap();
//! let data = source.load(1.0, 42).unwrap();
//! // any paper row label: "FasterPAM", "BanditPAM++-2", "OneBatch-nniw", ...
//! let method = MethodSpec::parse("OneBatch-nniw").unwrap();
//! // threads: 0 = all cores, 1 = serial; medoids identical either way.
//! // spec.metric (default L1) names the dissimilarity; build the
//! // backend from it so the two can never disagree.
//! // spec.profile (default Exact) picks the distance-kernel family;
//! // pair `ComputeProfile::Fast` with `.with_profile(...)` on the
//! // backend for the dot-product Euclidean fast path.
//! let spec = SolveSpec { threads: 0, ..SolveSpec::new(method, 5, 42) };
//! let backend = NativeBackend::with_pool(spec.metric, Pool::auto());
//! let result = solver::solve(&data.x, &spec, &backend).unwrap();
//! println!("medoids: {:?}", result.medoids);
//! ```
//!
//! The low-level entry points ([`coordinator::one_batch_pam`],
//! [`baselines::faster_pam`], ...) remain available when a caller needs
//! algorithm-specific knobs beyond [`solver::SolveSpec`].
//!
//! ## Invariants and in-tree lints
//!
//! The concurrency invariants this crate promises — bit-identical
//! medoids at any thread count, `SAFETY:`-documented unsafe sites,
//! poison-recovering locking through [`sync_ext`], permit balance and
//! terminal-exactly-once job states — are machine-checked by the
//! in-tree static-analysis pass `tools/tidy` (`cargo run -p tidy`) and
//! the deterministic interleaving suite `rust/tests/interleave.rs`.
//! `docs/INVARIANTS.md` catalogues every lint, the invariant it guards,
//! and the allowlist policy.

pub mod backend;
pub mod baselines;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod dissim;
pub mod eval;
pub mod harness;
pub mod linalg;
pub mod proptest;
pub mod rng;
pub mod runtime;
pub mod server;
pub mod solver;
pub mod sync_ext;
pub mod telemetry;
