//! Minimal dense row-major f32 matrix (no ndarray available offline).
//!
//! Only what the coordinator and backends need: contiguous storage, row
//! views, and a handful of blocked helpers tuned for the single-core
//! hot path.

/// Dense row-major matrix of `f32`.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    /// Number of rows.
    pub rows: usize,
    /// Number of columns.
    pub cols: usize,
    /// Row-major contiguous data, `rows * cols` long.
    pub data: Vec<f32>,
}

impl Matrix {
    /// All-zeros matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Matrix filled with `v`.
    pub fn full(rows: usize, cols: usize, v: f32) -> Self {
        Matrix { rows, cols, data: vec![v; rows * cols] }
    }

    /// Build from an existing row-major buffer.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "buffer/shape mismatch");
        Matrix { rows, cols, data }
    }

    /// Immutable view of row `i`.
    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Mutable view of row `i`.
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Element access.
    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.cols + j]
    }

    /// Element write.
    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f32) {
        self.data[i * self.cols + j] = v;
    }

    /// Copy of column `j` (strided gather).
    pub fn col(&self, j: usize) -> Vec<f32> {
        (0..self.rows).map(|i| self.get(i, j)).collect()
    }

    /// New matrix made of the given rows (gather).
    pub fn select_rows(&self, idx: &[usize]) -> Matrix {
        let mut out = Matrix::zeros(idx.len(), self.cols);
        for (r, &i) in idx.iter().enumerate() {
            out.row_mut(r).copy_from_slice(self.row(i));
        }
        out
    }

    /// Zero-pad to `(rows, cols)` (must be >= current shape).
    pub fn pad_to(&self, rows: usize, cols: usize, fill: f32) -> Matrix {
        assert!(rows >= self.rows && cols >= self.cols);
        let mut out = Matrix::full(rows, cols, fill);
        for i in 0..self.rows {
            out.row_mut(i)[..self.cols].copy_from_slice(self.row(i));
        }
        out
    }
}

/// `argmin` over a slice; ties break to the lower index. Returns (idx, val).
#[inline]
pub fn argmin(xs: &[f32]) -> (usize, f32) {
    debug_assert!(!xs.is_empty());
    let mut bi = 0;
    let mut bv = xs[0];
    for (i, &v) in xs.iter().enumerate().skip(1) {
        if v < bv {
            bv = v;
            bi = i;
        }
    }
    (bi, bv)
}

/// Two smallest entries (stable tie-break): `(i1, v1, i2, v2)` with
/// `v1 <= v2` and `i1 != i2`. Requires `len >= 2`.
#[inline]
pub fn top2_min(xs: &[f32]) -> (usize, f32, usize, f32) {
    debug_assert!(xs.len() >= 2);
    let (mut i1, mut v1, mut i2, mut v2) = if xs[0] <= xs[1] {
        (0, xs[0], 1, xs[1])
    } else {
        (1, xs[1], 0, xs[0])
    };
    for (i, &v) in xs.iter().enumerate().skip(2) {
        if v < v1 {
            i2 = i1;
            v2 = v1;
            i1 = i;
            v1 = v;
        } else if v < v2 {
            i2 = i;
            v2 = v;
        }
    }
    (i1, v1, i2, v2)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rows_and_elements() {
        let mut m = Matrix::zeros(2, 3);
        m.set(1, 2, 5.0);
        assert_eq!(m.get(1, 2), 5.0);
        assert_eq!(m.row(1), &[0.0, 0.0, 5.0]);
        assert_eq!(m.col(2), vec![0.0, 5.0]);
    }

    #[test]
    fn select_rows_gathers() {
        let m = Matrix::from_vec(3, 2, vec![1., 2., 3., 4., 5., 6.]);
        let s = m.select_rows(&[2, 0]);
        assert_eq!(s.data, vec![5., 6., 1., 2.]);
    }

    #[test]
    fn pad_preserves_and_fills() {
        let m = Matrix::from_vec(2, 2, vec![1., 2., 3., 4.]);
        let p = m.pad_to(3, 4, 9.0);
        assert_eq!(p.row(0), &[1., 2., 9., 9.]);
        assert_eq!(p.row(2), &[9., 9., 9., 9.]);
    }

    #[test]
    fn argmin_stable_ties() {
        assert_eq!(argmin(&[3.0, 1.0, 1.0, 2.0]), (1, 1.0));
    }

    #[test]
    fn top2_basic_and_ties() {
        let (i1, v1, i2, v2) = top2_min(&[5.0, 1.0, 3.0, 1.0]);
        assert_eq!((i1, v1, i2, v2), (1, 1.0, 3, 1.0));
        let (i1, _, i2, _) = top2_min(&[2.0, 2.0]);
        assert_eq!((i1, i2), (0, 1));
    }

    #[test]
    fn top2_matches_sort_on_random() {
        let mut rng = crate::rng::Rng::new(1);
        for _ in 0..200 {
            let n = 2 + rng.below(20);
            let xs: Vec<f32> = (0..n).map(|_| (rng.below(6)) as f32).collect();
            let (i1, v1, i2, v2) = top2_min(&xs);
            let mut idx: Vec<usize> = (0..n).collect();
            idx.sort_by(|&a, &b| xs[a].partial_cmp(&xs[b]).unwrap().then(a.cmp(&b)));
            assert_eq!((i1, v1), (idx[0], xs[idx[0]]));
            assert_eq!((i2, v2), (idx[1], xs[idx[1]]));
        }
    }
}
