//! `obpam` CLI — the launcher for the OneBatchPAM framework.
//!
//! Subcommands (hand-rolled parser; clap is unavailable offline):
//!
//! ```text
//! obpam cluster  --dataset mnist --k 10 [--sampler nniw] [--metric l1]
//!                [--scale 0.1] [--seed 0] [--backend native|xla|xla-dense]
//!                [--m N] [--strategy eager|steepest] [--threads T]
//!                [--config file.toml]
//! obpam bench    --table 3|5|7 | --fig 1|pareto  (thin wrapper; prefer `cargo bench`)
//! obpam serve    [--addr 127.0.0.1:7878] [--workers 2] [--queue-cap 16]
//! obpam gen      --list | --dataset NAME [--scale S] [--out file.csv]
//! obpam artifacts-check   (requires the `xla` build feature)
//! ```
//!
//! `--threads T` (config key `run.threads`) sizes the execution pool for
//! the pairwise pass and the eager swap scan; `0` auto-detects the core
//! count and `1` (the default) is the serial path.  Medoids are
//! bit-identical at any thread count for a fixed seed.

use anyhow::{bail, Context, Result};
use obpam::backend::NativeBackend;
#[cfg(feature = "xla")]
use obpam::backend::XlaBackend;
use obpam::config::Config;
use obpam::coordinator::{one_batch_pam, onebatch::SwapStrategy, OneBatchConfig, SamplerKind};
use obpam::data::synth;
use obpam::dissim::{DissimCounter, Metric};
use obpam::eval;
use obpam::runtime::Pool;
#[cfg(feature = "xla")]
use obpam::runtime::Runtime;
use std::collections::HashMap;
#[cfg(feature = "xla")]
use std::rc::Rc;

fn parse_flags(args: &[String]) -> (HashMap<String, String>, Vec<String>) {
    let mut flags = HashMap::new();
    let mut rest = Vec::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(name) = args[i].strip_prefix("--") {
            if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                flags.insert(name.to_string(), args[i + 1].clone());
                i += 2;
            } else {
                flags.insert(name.to_string(), "true".to_string());
                i += 1;
            }
        } else {
            rest.push(args[i].clone());
            i += 1;
        }
    }
    (flags, rest)
}

fn usage() -> ! {
    eprintln!(
        "usage: obpam <cluster|serve|gen|artifacts-check> [--flags]\n\
         see `cargo doc` or README.md for details"
    );
    std::process::exit(2)
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = args.first() else { usage() };
    let (flags, rest) = parse_flags(&args[1..]);

    match cmd.as_str() {
        "cluster" => cmd_cluster(&flags, &rest),
        "serve" => cmd_serve(&flags),
        "gen" => cmd_gen(&flags),
        "artifacts-check" => cmd_artifacts_check(),
        _ => usage(),
    }
}

fn cmd_cluster(flags: &HashMap<String, String>, overrides: &[String]) -> Result<()> {
    // config file (optional) + CLI flags + trailing key=value overrides
    let mut cfg = match flags.get("config") {
        Some(path) => Config::load(std::path::Path::new(path))?,
        None => Config::default(),
    };
    cfg.apply_overrides(overrides.iter().map(|s| s.as_str()))?;
    let get = |key: &str, flag: &str, default: &str| -> String {
        flags
            .get(flag)
            .cloned()
            .or_else(|| cfg.get(key).map(str::to_string))
            .unwrap_or_else(|| default.to_string())
    };

    let dataset = get("run.dataset", "dataset", "blobs_2000_8_5");
    let k: usize = get("run.k", "k", "10").parse().context("--k")?;
    let scale: f64 = get("run.scale", "scale", "1.0").parse().context("--scale")?;
    let seed: u64 = get("run.seed", "seed", "0").parse().context("--seed")?;
    let metric = Metric::parse(&get("run.metric", "metric", "l1")).context("bad --metric")?;
    let sampler = SamplerKind::parse(&get("run.sampler", "sampler", "nniw")).context("bad --sampler")?;
    let strategy = match get("run.strategy", "strategy", "eager").as_str() {
        "eager" => SwapStrategy::Eager,
        "steepest" => SwapStrategy::Steepest,
        s => bail!("bad --strategy {s}"),
    };
    let m: Option<usize> = match get("run.m", "m", "auto").as_str() {
        "auto" => None,
        s => Some(s.parse().context("--m")?),
    };
    let threads: usize = get("run.threads", "threads", "1").parse().context("--threads")?;
    let backend_name = get("run.backend", "backend", "native");

    eprintln!("[obpam] generating dataset {dataset} (scale {scale})");
    let data = synth::generate(&dataset, scale, seed);
    eprintln!(
        "[obpam] n={} p={} k={k} sampler={} backend={backend_name} threads={}",
        data.n(),
        data.p(),
        sampler.name(),
        Pool::new(threads).threads()
    );

    let ob_cfg = OneBatchConfig { k, sampler, m, strategy, seed, threads, ..Default::default() };
    let result = match backend_name.as_str() {
        "native" => {
            let backend = NativeBackend::with_pool(metric, Pool::new(threads));
            one_batch_pam(&data.x, &ob_cfg, &backend)?
        }
        #[cfg(feature = "xla")]
        "xla" | "xla-dense" => {
            // the PJRT runtime is single-threaded; `threads` still
            // parallelises the eager scan via ob_cfg
            let rt = Rc::new(Runtime::load_default()?);
            let backend = XlaBackend::new(rt, metric, backend_name == "xla-dense");
            one_batch_pam(&data.x, &ob_cfg, &backend)?
        }
        #[cfg(not(feature = "xla"))]
        "xla" | "xla-dense" => {
            bail!("this build has no `xla` feature; rebuild with --features xla")
        }
        other => bail!("unknown backend {other}"),
    };

    let obj = eval::objective(&data.x, &result.medoids, &DissimCounter::new(metric));
    println!("medoids: {:?}", result.medoids);
    println!("objective (full data): {obj:.6}");
    println!("objective (batch estimate): {:.6}", result.est_objective);
    println!(
        "selection time: {:.3}s   dissim computations: {}   swaps: {}",
        result.stats.seconds, result.stats.dissim_count, result.stats.swap_count
    );
    Ok(())
}

fn cmd_serve(flags: &HashMap<String, String>) -> Result<()> {
    let cfg = obpam::server::ServerConfig {
        addr: flags.get("addr").cloned().unwrap_or_else(|| "127.0.0.1:7878".into()),
        workers: flags.get("workers").and_then(|s| s.parse().ok()).unwrap_or(2),
        queue_cap: flags.get("queue-cap").and_then(|s| s.parse().ok()).unwrap_or(16),
    };
    let handle = obpam::server::serve(cfg)?;
    println!("obpam server listening on {}", handle.addr);
    println!("try: printf 'cluster dataset=blobs_2000_8_5 k=5\\n' | nc {} {}", handle.addr.ip(), handle.addr.port());
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

fn cmd_gen(flags: &HashMap<String, String>) -> Result<()> {
    if flags.contains_key("list") {
        println!("{:<12} {:>8} {:>6}  scale", "dataset", "n", "p");
        for &(name, n, p, large) in synth::CATALOGUE {
            println!("{name:<12} {n:>8} {p:>6}  {}", if large { "large" } else { "small" });
        }
        return Ok(());
    }
    let dataset = flags.get("dataset").context("--dataset or --list required")?;
    let scale: f64 = flags.get("scale").map(|s| s.parse()).transpose()?.unwrap_or(1.0);
    let seed: u64 = flags.get("seed").map(|s| s.parse()).transpose()?.unwrap_or(0);
    let data = synth::generate(dataset, scale, seed);
    match flags.get("out") {
        Some(path) => {
            let mut out = String::new();
            for i in 0..data.n() {
                let row: Vec<String> = data.x.row(i).iter().map(|v| format!("{v}")).collect();
                out.push_str(&row.join(","));
                out.push('\n');
            }
            std::fs::write(path, out)?;
            println!("wrote {} rows x {} cols to {path}", data.n(), data.p());
        }
        None => println!("generated {}: n={} p={}", dataset, data.n(), data.p()),
    }
    Ok(())
}

#[cfg(feature = "xla")]
fn cmd_artifacts_check() -> Result<()> {
    let rt = Runtime::load_default()?;
    println!("manifest: {} artifacts", rt.specs().len());
    let mut by_kind: std::collections::BTreeMap<&str, usize> = Default::default();
    for s in rt.specs() {
        *by_kind.entry(s.kind.as_str()).or_default() += 1;
    }
    for (kind, count) in by_kind {
        println!("  {kind:<16} {count}");
    }
    // compile + execute one tiny pairwise to prove the PJRT path works
    let x = obpam::linalg::Matrix::from_vec(4, 2, vec![0., 0., 1., 0., 0., 1., 1., 1.]);
    let d = rt.pairwise(&x, &x, Metric::L1, false)?;
    anyhow::ensure!((d.get(0, 3) - 2.0).abs() < 1e-5, "pairwise sanity failed");
    println!("PJRT execution check: OK (l1 pairwise via Pallas artifact)");
    Ok(())
}

#[cfg(not(feature = "xla"))]
fn cmd_artifacts_check() -> Result<()> {
    bail!("this build has no `xla` feature; rebuild with --features xla to check artifacts")
}
