//! `obpam` CLI — the launcher for the OneBatchPAM framework.
//!
//! Subcommands (hand-rolled parser; clap is unavailable offline):
//!
//! ```text
//! obpam cluster  --dataset mnist --k 10 [--method FasterPAM] [--metric l1]
//!                [--scale 0.1] [--seed 0] [--backend native|xla|xla-dense]
//!                [--scale-features minmax|none] [--sampler nniw] [--m N]
//!                [--eps E] [--max-passes P] [--strategy eager|steepest]
//!                [--threads T] [--profile exact|fast] [--config file.toml]
//! obpam bench    --table 3|5|7 | --fig 1|pareto  (thin wrapper; prefer `cargo bench`)
//! obpam serve    [--addr 127.0.0.1:7878] [--workers 2] [--queue-cap 16] [--cache-cap 32]
//!                [--budget UNITS] [--byte-budget BYTES] [--strict-budget]
//!                [--retain-cap N] [--model-cap N] [--conn-cap N]
//! obpam submit   [--addr HOST:PORT] key=value...   (async: returns job=j<id>)
//! obpam poll     [--addr HOST:PORT] --job j3
//! obpam wait     [--addr HOST:PORT] --job j3 [--timeout-ms N]
//! obpam cancel   [--addr HOST:PORT] --job j3
//! obpam jobs     [--addr HOST:PORT]
//! obpam promote  [--addr HOST:PORT] --job j3 [--name mymodel]
//! obpam assign   [--addr HOST:PORT] --model mymodel [--top2]
//!                [--profile exact|fast] point=v1,v2,...
//! obpam models   [--addr HOST:PORT]
//! obpam evict    [--addr HOST:PORT] --model mymodel
//! obpam gen      --list | --dataset SOURCE [--scale S] [--out file.csv|file.npy]
//!                [--format csv|npy]
//! obpam inspect  <uri> [--k K] [--method M] [--m N]  (dims/dtype/fingerprint/cost,
//!                header-only — no rows are read)
//! obpam artifacts-check   (requires the `xla` build feature)
//! ```
//!
//! `--dataset` (config key `run.dataset`) is a [`DataSource`] URI:
//! `synth:<name>` generates a catalogue dataset, `file:<path>` loads a
//! numeric CSV, `npy:<path>` / `dir:<path>` read binary `.npy` arrays
//! (single file / sharded directory — the out-of-core sources the
//! server can stream), and a bare name aliases `synth:` — so
//! `obpam cluster --dataset file:/data/points.csv --metric l2` clusters
//! loaded data through exactly the same path as the synthetic
//! reproductions.  `--scale-features minmax` min-max scales features
//! after loading (config key `run.scale_features`).
//!
//! `--method` (config key `run.method`) accepts any paper row label via
//! [`MethodSpec::parse`] — `FasterPAM`, `FasterCLARA-50`, `BanditPAM++-2`,
//! `OneBatch-nniw-steepest`, ... — and routes through the unified
//! [`obpam::solver`] API; without it the CLI runs OneBatchPAM configured
//! by the OneBatch knobs (`--sampler/--m/--eps/--max-passes/--strategy`,
//! which are rejected for non-OneBatch methods).
//!
//! `--threads T` (config key `run.threads`) sizes the execution pool for
//! the pairwise pass and the eager swap scan; `0` auto-detects the core
//! count and `1` (the default) is the serial path.  Medoids are
//! bit-identical at any thread count for a fixed seed.
//!
//! `--profile exact|fast` (config key `run.profile`) selects the
//! distance-kernel [`ComputeProfile`]: `exact` is the bit-identical
//! paper-reproduction kernel, `fast` (the CLI default on the native
//! backend) routes squared-Euclidean / Euclidean through the
//! dot-product identity for a large speedup at a bounded relative
//! error; the other metrics are identical under both.  The XLA backend
//! ships only exact kernels, so `--profile fast` requires
//! `--backend native`.
//!
//! `serve` knobs follow the same `0 = auto` convention: `--workers 0`
//! auto-detects cores, `--queue-cap 0` scales with the workers,
//! `--budget 0` takes the default cost-weighted admission budget,
//! `--retain-cap 0` the default finished-job retention and
//! `--conn-cap 0` the default concurrent-connection bound (8192 — the
//! evented core makes a connection a registry entry, not a thread).
//! `--strict-budget` disables the lone-job idle-admit exception.
//!
//! The `submit` / `poll` / `wait` / `cancel` / `jobs` subcommands are
//! thin wire clients for the server's asynchronous job handles:
//! `submit` takes the same `key=value` tokens as a `cluster` request
//! line (plus `deadline_ms=`), prints the `ok job=j<id> cost=...`
//! reply, and the handle verbs drive that job from any later
//! connection.  Values containing spaces are quoted automatically
//! (`dataset=file:/data/my points.csv` works as one shell argument).
//!
//! The `promote` / `assign` / `models` / `evict` subcommands are the
//! protocol v6 model-serving clients: `promote` captures a done job's
//! fitted model into the server's registry (bounded by `--model-cap`),
//! `assign` labels points against it with no dataset resident (each
//! trailing `point=v1,v2,...` token is one row; `--top2` also reports
//! the runner-up medoid), and `models` / `evict` inspect and drop the
//! registry.  See the `obpam::server` docs for the full protocol.

use anyhow::{bail, Context, Result};
use obpam::backend::NativeBackend;
#[cfg(feature = "xla")]
use obpam::backend::XlaBackend;
use obpam::config::Config;
use obpam::coordinator::{SamplerKind, SwapStrategy};
use obpam::data::{synth, DataSource, FeatureScaling};
use obpam::dissim::{ComputeProfile, DissimCounter, Metric};
use obpam::eval;
use obpam::runtime::Pool;
use obpam::solver::{self, MethodSpec, SolveSpec};
#[cfg(feature = "xla")]
use obpam::runtime::Runtime;
use std::collections::HashMap;
#[cfg(feature = "xla")]
use std::rc::Rc;

fn parse_flags(args: &[String]) -> (HashMap<String, String>, Vec<String>) {
    let mut flags = HashMap::new();
    let mut rest = Vec::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(name) = args[i].strip_prefix("--") {
            if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                flags.insert(name.to_string(), args[i + 1].clone());
                i += 2;
            } else {
                flags.insert(name.to_string(), "true".to_string());
                i += 1;
            }
        } else {
            rest.push(args[i].clone());
            i += 1;
        }
    }
    (flags, rest)
}

fn usage() -> ! {
    eprintln!(
        "usage: obpam <cluster|serve|submit|poll|wait|cancel|jobs|promote|assign|models|evict|gen|inspect|artifacts-check> [--flags]\n\
         see `cargo doc` or README.md for details"
    );
    std::process::exit(2)
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = args.first() else { usage() };
    let (flags, rest) = parse_flags(&args[1..]);

    match cmd.as_str() {
        "cluster" => cmd_cluster(&flags, &rest),
        "serve" => cmd_serve(&flags),
        "submit" | "poll" | "wait" | "cancel" | "jobs" => cmd_client(cmd, &flags, &rest),
        "promote" | "assign" | "models" | "evict" => cmd_client(cmd, &flags, &rest),
        "gen" => cmd_gen(&flags),
        "inspect" => cmd_inspect(&flags, &rest),
        "artifacts-check" => cmd_artifacts_check(),
        _ => usage(),
    }
}

/// Thin wire client for the v5 job-handle verbs: assemble one request
/// line from the flags + trailing `key=value` tokens, send it, print
/// the reply.  Values containing whitespace are double-quoted so
/// `file:` paths with spaces survive the wire tokenizer.
fn cmd_client(verb: &str, flags: &HashMap<String, String>, rest: &[String]) -> Result<()> {
    use std::net::ToSocketAddrs;
    let addr_s = flags.get("addr").cloned().unwrap_or_else(|| "127.0.0.1:7878".into());
    let addr = addr_s
        .to_socket_addrs()
        .with_context(|| format!("bad --addr {addr_s}"))?
        .next()
        .with_context(|| format!("--addr {addr_s} resolved to no address"))?;
    let mut line = verb.to_string();
    if let Some(job) = flags.get("job") {
        line.push_str(&format!(" job={job}"));
    }
    if let Some(t) = flags.get("timeout-ms") {
        line.push_str(&format!(" timeout_ms={t}"));
    }
    if let Some(d) = flags.get("deadline-ms") {
        line.push_str(&format!(" deadline_ms={d}"));
    }
    // v6 model-serving flags (promote / assign / models / evict)
    if let Some(m) = flags.get("model") {
        line.push_str(&format!(" model={m}"));
    }
    if let Some(n) = flags.get("name") {
        line.push_str(&format!(" name={n}"));
    }
    if matches!(flags.get("top2"), Some(v) if v != "false") {
        line.push_str(" top2=1");
    }
    // v7 compute-profile key (submit / assign); validated server-side
    if let Some(p) = flags.get("profile") {
        line.push_str(&format!(" profile={p}"));
    }
    for tok in rest {
        // the wire tokenizer has no escape character, so a value
        // containing a literal quote has no valid wire spelling
        anyhow::ensure!(
            !tok.contains('"'),
            "values containing a literal \" are not addressable on the wire (token {tok:?})"
        );
        line.push(' ');
        line.push_str(&quote_token(tok));
    }
    println!("{}", obpam::server::request(addr, &line)?);
    Ok(())
}

/// Quote a `key=value` token for the wire if its value contains
/// whitespace (the v5 tokenizer strips the quotes back out).
fn quote_token(tok: &str) -> String {
    if !tok.chars().any(char::is_whitespace) {
        return tok.to_string();
    }
    match tok.split_once('=') {
        Some((k, v)) => format!("{k}=\"{v}\""),
        None => format!("\"{tok}\""),
    }
}

fn cmd_cluster(flags: &HashMap<String, String>, overrides: &[String]) -> Result<()> {
    // config file (optional) + CLI flags + trailing key=value overrides
    let mut cfg = match flags.get("config") {
        Some(path) => Config::load(std::path::Path::new(path))?,
        None => Config::default(),
    };
    cfg.apply_overrides(overrides.iter().map(|s| s.as_str()))?;
    let get = |key: &str, flag: &str, default: &str| -> String {
        flags
            .get(flag)
            .cloned()
            .or_else(|| cfg.get(key).map(str::to_string))
            .unwrap_or_else(|| default.to_string())
    };

    let dataset = get("run.dataset", "dataset", "blobs_2000_8_5");
    let source = DataSource::parse(&dataset)?;
    let k: usize = get("run.k", "k", "10").parse().context("--k")?;
    let scale: f64 = get("run.scale", "scale", "1.0").parse().context("--scale")?;
    if source.is_file() && scale != 1.0 {
        bail!("--scale does not apply to file: sources (got --scale {scale})");
    }
    let seed: u64 = get("run.seed", "seed", "0").parse().context("--seed")?;
    let metric = Metric::parse(&get("run.metric", "metric", "l1")).context("bad --metric")?;
    let scaling = FeatureScaling::parse(&get("run.scale_features", "scale-features", "none"))
        .context("bad --scale-features (minmax|none)")?;
    let threads: usize = get("run.threads", "threads", "1").parse().context("--threads")?;
    let backend_name = get("run.backend", "backend", "native");
    // fast is the CLI default on the native backend; the XLA path ships
    // exact kernels only, so it stays exact unless the user insists
    let profile = match flags
        .get("profile")
        .cloned()
        .or_else(|| cfg.get("run.profile").map(str::to_string))
    {
        Some(s) => {
            let p = ComputeProfile::parse(&s)
                .with_context(|| format!("bad --profile {s} (exact|fast)"))?;
            anyhow::ensure!(
                p == ComputeProfile::Exact || backend_name == "native",
                "--profile fast requires the native backend (got --backend {backend_name})"
            );
            p
        }
        None if backend_name == "native" => ComputeProfile::Fast,
        None => ComputeProfile::Exact,
    };

    // OneBatch-only knobs: track explicit presence so a non-OneBatch
    // --method rejects them instead of silently ignoring them
    let explicit = |key: &str, flag: &str| -> Option<String> {
        flags.get(flag).cloned().or_else(|| cfg.get(key).map(str::to_string))
    };
    let sampler_s = explicit("run.sampler", "sampler");
    let strategy_s = explicit("run.strategy", "strategy");
    // "auto" is the documented not-set spelling for the batch size
    let m_s = explicit("run.m", "m").filter(|s| s != "auto");
    let eps_s = explicit("run.eps", "eps");
    let passes_s = explicit("run.max_passes", "max-passes");
    let sampler = match &sampler_s {
        Some(s) => SamplerKind::parse(s).context("bad --sampler")?,
        None => SamplerKind::Nniw,
    };
    let strategy = match &strategy_s {
        Some(s) => SwapStrategy::parse(s).context("bad --strategy")?,
        None => SwapStrategy::Eager,
    };
    let m: Option<usize> = match m_s.as_deref() {
        None => None,
        Some(s) => Some(s.parse().context("--m")?),
    };
    let eps: f64 = match &eps_s {
        Some(s) => s.parse().context("--eps")?,
        None => 0.0,
    };
    let max_passes: usize = match &passes_s {
        Some(s) => s.parse().context("--max-passes")?,
        None => 20,
    };

    let method = match explicit("run.method", "method") {
        None => MethodSpec::OneBatch { sampler, strategy },
        Some(s) => {
            let Some(base) = MethodSpec::parse(&s) else { bail!("unknown --method {s}") };
            match base {
                // CLI flags beat the parsed label; config-file defaults
                // (run.sampler etc.) must not override an explicit method
                MethodSpec::OneBatch { sampler: s0, strategy: t0 } => MethodSpec::OneBatch {
                    sampler: if flags.contains_key("sampler") { sampler } else { s0 },
                    strategy: if flags.contains_key("strategy") { strategy } else { t0 },
                },
                other => {
                    // only reject knobs typed on this invocation: a config
                    // file's OneBatch defaults are simply unused here, and
                    // `--m auto` is the documented not-set spelling
                    let m_cli =
                        flags.get("m").map(String::as_str).is_some_and(|s| s != "auto");
                    if flags.contains_key("sampler")
                        || flags.contains_key("strategy")
                        || m_cli
                        || flags.contains_key("eps")
                        || flags.contains_key("max-passes")
                    {
                        bail!(
                            "--sampler/--strategy/--m/--eps/--max-passes only apply to \
                             OneBatch methods (got --method {})",
                            other.label()
                        );
                    }
                    other
                }
            }
        }
    };

    eprintln!("[obpam] loading {} (scale {scale})", source.canon());
    let mut data = source.load(scale, seed)?;
    scaling.apply(&mut data);
    eprintln!(
        "[obpam] n={} p={} k={k} method={} metric={} backend={backend_name} threads={} profile={}",
        data.n(),
        data.p(),
        method.label(),
        metric.name(),
        Pool::new(threads).threads(),
        profile.name()
    );

    let spec = SolveSpec {
        metric,
        threads,
        m,
        eps,
        max_passes,
        profile,
        ..SolveSpec::new(method, k, seed)
    };
    let result = match backend_name.as_str() {
        "native" => {
            let backend =
                NativeBackend::with_pool(metric, Pool::new(threads)).with_profile(profile);
            solver::solve(&data.x, &spec, &backend)?
        }
        #[cfg(feature = "xla")]
        "xla" | "xla-dense" => {
            // the PJRT runtime is single-threaded; `threads` still
            // parallelises the eager scan via the spec
            let rt = Rc::new(Runtime::load_default()?);
            let backend = XlaBackend::new(rt, metric, backend_name == "xla-dense");
            solver::solve(&data.x, &spec, &backend)?
        }
        #[cfg(not(feature = "xla"))]
        "xla" | "xla-dense" => {
            bail!("this build has no `xla` feature; rebuild with --features xla")
        }
        other => bail!("unknown backend {other}"),
    };

    let obj = eval::objective(&data.x, &result.medoids, &DissimCounter::new(metric));
    println!("method: {}", spec.method.label());
    println!("medoids: {:?}", result.medoids);
    println!("objective (full data): {obj:.6}");
    // some methods (Random, the seeding family) never estimate one
    if result.est_objective.is_finite() {
        println!("objective (internal estimate): {:.6}", result.est_objective);
    }
    println!(
        "selection time: {:.3}s   dissim computations: {}   swaps: {}",
        result.stats.seconds, result.stats.dissim_count, result.stats.swap_count
    );
    Ok(())
}

fn cmd_serve(flags: &HashMap<String, String>) -> Result<()> {
    // `--workers 0` auto-detects cores and `--queue-cap 0` follows the
    // worker count, matching the `--threads 0` convention; `--budget 0`
    // takes the default weighted-admission budget (4x MAX_JOB_COST),
    // `--byte-budget 0` the default resident-byte ceiling (8 GiB),
    // `--retain-cap 0` the default finished-job retention (64) and
    // `--conn-cap 0` the default connection bound (8192).
    let cfg = obpam::server::ServerConfig {
        addr: flags.get("addr").cloned().unwrap_or_else(|| "127.0.0.1:7878".into()),
        workers: flags.get("workers").and_then(|s| s.parse().ok()).unwrap_or(2),
        queue_cap: flags.get("queue-cap").and_then(|s| s.parse().ok()).unwrap_or(16),
        cache_cap: flags.get("cache-cap").and_then(|s| s.parse().ok()).unwrap_or(32),
        budget: flags.get("budget").and_then(|s| s.parse().ok()).unwrap_or(0),
        byte_budget: flags.get("byte-budget").and_then(|s| s.parse().ok()).unwrap_or(0),
        strict_budget: matches!(flags.get("strict-budget"), Some(v) if v != "false"),
        retain_cap: flags.get("retain-cap").and_then(|s| s.parse().ok()).unwrap_or(0),
        model_cap: flags.get("model-cap").and_then(|s| s.parse().ok()).unwrap_or(0),
        conn_cap: flags.get("conn-cap").and_then(|s| s.parse().ok()).unwrap_or(0),
    };
    let handle = obpam::server::serve(cfg)?;
    println!("obpam server listening on {}", handle.addr);
    println!(
        "try: printf 'cluster dataset=blobs_2000_8_5 k=5 method=FasterPAM\\n' | nc {} {}",
        handle.addr.ip(),
        handle.addr.port()
    );
    println!(
        "or async: obpam submit --addr {} dataset=blobs_2000_8_5 k=5 deadline_ms=5000",
        handle.addr
    );
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

fn cmd_gen(flags: &HashMap<String, String>) -> Result<()> {
    if flags.contains_key("list") {
        println!("{:<12} {:>8} {:>6}  scale", "dataset", "n", "p");
        for &(name, n, p, large) in synth::CATALOGUE {
            println!("{name:<12} {n:>8} {p:>6}  {}", if large { "large" } else { "small" });
        }
        return Ok(());
    }
    let dataset = flags.get("dataset").context("--dataset or --list required")?;
    let scale: f64 = flags.get("scale").map(|s| s.parse()).transpose()?.unwrap_or(1.0);
    let seed: u64 = flags.get("seed").map(|s| s.parse()).transpose()?.unwrap_or(0);
    // any DataSource URI works, so gen doubles as a file:->csv normaliser
    let src = DataSource::parse(dataset)?;
    if src.is_file() && scale != 1.0 {
        // same rule as cluster: file bytes do not scale, and a silently
        // unscaled "subsample" would be a lie
        bail!("--scale does not apply to file: sources (got --scale {scale})");
    }
    let data = src.load(scale, seed)?;
    // --format picks the writer; without it the --out extension decides
    // (.npy -> npy, anything else -> csv).  npy round-trips f32 exactly,
    // so `gen --format npy` + an `npy:` solve is bit-identical to the
    // synth source it came from.
    let format = match flags.get("format").map(String::as_str) {
        Some("csv") => "csv",
        Some("npy") => "npy",
        Some(other) => bail!("unknown --format {other} (csv|npy)"),
        None => match flags.get("out") {
            Some(p) if p.ends_with(".npy") => "npy",
            _ => "csv",
        },
    };
    match flags.get("out") {
        Some(path) if format == "npy" => {
            obpam::data::npy::write_npy(std::path::Path::new(path), &data.x)?;
            println!("wrote {} rows x {} cols to {path} (npy <f4)", data.n(), data.p());
        }
        Some(path) => {
            let mut out = String::new();
            for i in 0..data.n() {
                let row: Vec<String> = data.x.row(i).iter().map(|v| format!("{v}")).collect();
                out.push_str(&row.join(","));
                out.push('\n');
            }
            std::fs::write(path, out)?;
            println!("wrote {} rows x {} cols to {path}", data.n(), data.p());
        }
        None if flags.contains_key("format") => bail!("--format needs --out"),
        None => println!("generated {}: n={} p={}", dataset, data.n(), data.p()),
    }
    Ok(())
}

/// `obpam inspect <uri>` — the pre-flight probe: dims, dtype,
/// fingerprint and the priced admission cost of solving the source,
/// all from headers/metadata only (no row is ever read, so inspecting
/// a 100 GB `npy:` is instant).
fn cmd_inspect(flags: &HashMap<String, String>, rest: &[String]) -> Result<()> {
    let uri = rest
        .first()
        .cloned()
        .or_else(|| flags.get("dataset").cloned())
        .context("usage: obpam inspect <uri> [--k K] [--method M] [--m N]")?;
    let src = DataSource::parse(&uri)?;
    let k: usize = flags.get("k").map(|s| s.parse()).transpose().context("--k")?.unwrap_or(10);
    let m: Option<usize> = match flags.get("m").map(String::as_str) {
        None | Some("auto") => None,
        Some(s) => Some(s.parse().context("--m")?),
    };
    let method = match flags.get("method") {
        None => MethodSpec::default(),
        Some(s) => match MethodSpec::parse(s) {
            Some(spec) => spec,
            None => bail!("unknown --method {s}"),
        },
    };
    let identity = src.identity();
    println!("source: {}", src.canon());
    println!("identity: {identity}");
    println!("fingerprint: {:#018x}", src.fingerprint_of(&identity)?);
    // dtype comes straight off the npy header(s); dir: also counts shards
    let canon = src.canon();
    if let Some(path) = canon.strip_prefix("npy:") {
        let h = obpam::data::npy::read_header(std::path::Path::new(path))?;
        println!("dtype: {}", h.dtype.descr());
    } else if let Some(dirp) = canon.strip_prefix("dir:") {
        let shards = obpam::data::dirsrc::shard_paths(std::path::Path::new(dirp))?;
        // dtype only reads off binary shards; CSV shards are text f32
        match shards.iter().find(|p| p.extension().is_some_and(|e| e == "npy")) {
            Some(first_npy) => {
                let h = obpam::data::npy::read_header(first_npy)?;
                println!("dtype: {} (npy shards)  shards: {}", h.dtype.descr(), shards.len());
            }
            None => println!("dtype: f32 (csv shards)  shards: {}", shards.len()),
        }
    }
    let scale: f64 = flags.get("scale").map(|s| s.parse()).transpose()?.unwrap_or(1.0);
    match src.expected_dims() {
        Some((n, p)) => {
            println!("dims: {n} x {p}");
            println!("resident feature bytes: {}", (n as u64) * (p as u64) * 4);
            let cost = method.cost_with_dims(n, p, k, m);
            println!(
                "cost ({} k={k}): units={} bytes={}{}",
                method.label(),
                cost.units,
                cost.resident_bytes,
                if cost.admissible() { "" } else { "  [over the full-matrix limit]" }
            );
            if let Some(s) = method.streaming_cost(n, p, k, m) {
                println!("cost (streaming): units={} bytes={}", s.units, s.resident_bytes);
            }
        }
        None => match src.expected_rows(scale) {
            Some(n) => {
                let cost = method.cost(n, k, m);
                println!("dims: {n} x ? (width unknown before load)");
                println!("cost ({} k={k}): units={}", method.label(), cost.units);
            }
            None => println!("dims: unknown before load"),
        },
    }
    Ok(())
}

#[cfg(feature = "xla")]
fn cmd_artifacts_check() -> Result<()> {
    let rt = Runtime::load_default()?;
    println!("manifest: {} artifacts", rt.specs().len());
    let mut by_kind: std::collections::BTreeMap<&str, usize> = Default::default();
    for s in rt.specs() {
        *by_kind.entry(s.kind.as_str()).or_default() += 1;
    }
    for (kind, count) in by_kind {
        println!("  {kind:<16} {count}");
    }
    // compile + execute one tiny pairwise to prove the PJRT path works
    let x = obpam::linalg::Matrix::from_vec(4, 2, vec![0., 0., 1., 0., 0., 1., 1., 1.]);
    let d = rt.pairwise(&x, &x, Metric::L1, false)?;
    anyhow::ensure!((d.get(0, 3) - 2.0).abs() < 1e-5, "pairwise sanity failed");
    println!("PJRT execution check: OK (l1 pairwise via Pallas artifact)");
    Ok(())
}

#[cfg(not(feature = "xla"))]
fn cmd_artifacts_check() -> Result<()> {
    bail!("this build has no `xla` feature; rebuild with --features xla to check artifacts")
}
