//! Mini property-testing harness (the proptest crate is unavailable
//! offline).
//!
//! [`run_cases`] drives a closure over `cases` seeded [`Rng`] streams; a
//! panic inside the closure is caught, re-raised with the failing seed so
//! the case can be replayed deterministically:
//!
//! ```
//! obpam::proptest::run_cases(64, |rng| {
//!     let n = 2 + rng.below(30);
//!     assert!(n >= 2);
//! });
//! ```

use crate::rng::Rng;

/// Run `cases` independent property checks.  On failure, panics with the
/// failing case index and seed.
pub fn run_cases(cases: usize, mut prop: impl FnMut(&mut Rng)) {
    run_cases_seeded(0xdead_beef, cases, &mut prop);
}

/// Seeded variant (replay a failure by passing the reported seed with
/// `cases = 1`).
pub fn run_cases_seeded(base_seed: u64, cases: usize, prop: &mut impl FnMut(&mut Rng)) {
    for case in 0..cases {
        let seed = base_seed ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(&mut rng)));
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!("property failed at case {case} (replay: run_cases_seeded({seed:#x}, 1, ..)): {msg}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_when_property_holds() {
        run_cases(32, |rng| {
            let x = rng.f64();
            assert!((0.0..1.0).contains(&x));
        });
    }

    #[test]
    fn reports_failing_seed() {
        let r = std::panic::catch_unwind(|| {
            run_cases(16, |rng| {
                assert!(rng.below(10) < 5, "boom");
            });
        });
        let payload = r.unwrap_err();
        let msg = payload
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_default();
        assert!(msg.contains("replay"), "{msg}");
    }

    #[test]
    fn cases_are_deterministic() {
        let mut a = Vec::new();
        run_cases(8, |rng| a.push(rng.next_u64()));
        let mut b = Vec::new();
        run_cases(8, |rng| b.push(rng.next_u64()));
        assert_eq!(a, b);
    }
}
