//! Deterministic pseudo-random generation (no external `rand` offline).
//!
//! `Xoshiro256**` seeded through SplitMix64 — the standard, well-tested
//! construction.  Every stochastic component in the crate (samplers,
//! dataset generators, baselines) takes an explicit `Rng` so experiments
//! are reproducible from a single `u64` seed.

/// Xoshiro256** generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed (SplitMix64 expansion).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    /// Derive an independent child stream (for per-repetition seeding).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0x9E3779B97F4A7C15))
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in `[0, bound)` (Lemire rejection-free is overkill;
    /// modulo bias is < 2^-32 for our bounds).
    #[inline]
    pub fn below(&mut self, bound: usize) -> usize {
        debug_assert!(bound > 0);
        (self.next_u64() % bound as u64) as usize
    }

    /// Standard normal via Box–Muller (cached second value dropped for
    /// simplicity — generation speed is not a bottleneck).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Sample `k` distinct indices from `[0, n)` (Floyd's algorithm).
    pub fn sample_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "cannot sample {k} distinct from {n}");
        let mut chosen = std::collections::HashSet::with_capacity(k);
        let mut out = Vec::with_capacity(k);
        for j in (n - k)..n {
            let t = self.below(j + 1);
            let v = if chosen.contains(&t) { j } else { t };
            chosen.insert(v);
            out.push(v);
        }
        out
    }

    /// Sample an index proportionally to non-negative `weights`.
    /// Falls back to uniform if the total mass is zero.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        if total <= 0.0 {
            return self.below(weights.len());
        }
        let mut r = self.f64() * total;
        for (i, &w) in weights.iter().enumerate() {
            r -= w;
            if r <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.below(i + 1);
            v.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let (mut a, mut b) = (Rng::new(1), Rng::new(2));
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_respects_bound_and_covers() {
        let mut r = Rng::new(4);
        let mut seen = [false; 10];
        for _ in 0..1_000 {
            seen[r.below(10)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn sample_distinct_is_distinct_and_in_range() {
        let mut r = Rng::new(5);
        for _ in 0..50 {
            let s = r.sample_distinct(30, 10);
            assert_eq!(s.len(), 10);
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), 10);
            assert!(s.iter().all(|&i| i < 30));
        }
    }

    #[test]
    fn sample_distinct_full_range() {
        let mut r = Rng::new(6);
        let mut s = r.sample_distinct(5, 5);
        s.sort_unstable();
        assert_eq!(s, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn weighted_prefers_heavy_arm() {
        let mut r = Rng::new(8);
        let w = [0.05, 0.9, 0.05];
        let mut counts = [0usize; 3];
        for _ in 0..2_000 {
            counts[r.weighted(&w)] += 1;
        }
        assert!(counts[1] > 1_500, "{counts:?}");
    }

    #[test]
    fn weighted_zero_mass_uniform() {
        let mut r = Rng::new(9);
        let w = [0.0, 0.0];
        for _ in 0..10 {
            assert!(r.weighted(&w) < 2);
        }
    }

    #[test]
    fn normal_moments_roughly_standard() {
        let mut r = Rng::new(10);
        let xs: Vec<f64> = (0..20_000).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / xs.len() as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(11);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
