//! Execution-layer runtime: the thread pool used by every parallel hot
//! path, plus the (feature-gated) PJRT bridge to the AOT XLA artifacts.
//!
//! * [`pool`] — the [`Pool`] abstraction: row-chunked parallelism over
//!   a **persistent pool of parked workers** with a configurable thread
//!   count (`1` = the serial path, `0` = auto); dispatching a region is
//!   a wakeup, not a spawn, and results are bit-identical at any width
//!   and across pool reuse.  Used by `dissim::cross_matrix_pool`, the
//!   `NativeBackend` tile ops, the eager swap scan and the job server.
//! * [`pjrt`] (feature `xla`) — load AOT artifacts (HLO text produced
//!   by `python/compile/aot.py`) and execute them through a PJRT CPU
//!   client.  Python never runs at request time.
//!
//! The artifact *manifest* format is parsed here unconditionally so
//! tooling (and tests) can inspect artifact tables without linking the
//! PJRT runtime.

pub mod pool;

pub use pool::Pool;

#[cfg(feature = "xla")]
mod pjrt;
#[cfg(feature = "xla")]
pub use pjrt::Runtime;

use crate::linalg::Matrix;
use anyhow::{bail, Context, Result};

/// Fixed row-tile used by every `n`-tiled artifact (matches aot.py).
pub const N_TILE: usize = 2048;

/// One artifact from the manifest.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    /// Unique artifact name.
    pub name: String,
    /// Kind: pairwise | pairwise_dense | gains | top2 | argmin | objective.
    pub kind: String,
    /// Metric name or "-" when not applicable.
    pub metric: String,
    /// Row-tile size (0 when the kind has no n axis).
    pub n: usize,
    /// Feature bucket (0 when unused).
    pub p: usize,
    /// Batch bucket (0 when unused).
    pub m: usize,
    /// Medoid bucket (0 when unused).
    pub k: usize,
    /// File name relative to the artifact dir.
    pub file: String,
}

/// Parse the whitespace manifest (see aot.py for the format).
pub fn parse_manifest(text: &str) -> Result<Vec<ArtifactSpec>> {
    let mut specs = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let f: Vec<&str> = line.split_whitespace().collect();
        if f.len() != 8 {
            bail!("manifest line {}: expected 8 fields, got {}", lineno + 1, f.len());
        }
        let num = |s: &str| -> Result<usize> {
            s.parse().with_context(|| format!("manifest line {}: bad number {s}", lineno + 1))
        };
        specs.push(ArtifactSpec {
            name: f[0].into(),
            kind: f[1].into(),
            metric: f[2].into(),
            n: num(f[3])?,
            p: num(f[4])?,
            m: num(f[5])?,
            k: num(f[6])?,
            file: f[7].into(),
        });
    }
    if specs.is_empty() {
        bail!("empty manifest");
    }
    Ok(specs)
}

/// Rows `[i0, i1)` of `src`, fill-padded to a `(rows, cols)` tile
/// (the padding scheme shared by every n-tiled artifact).
pub fn slice_rows_padded(
    src: &Matrix,
    i0: usize,
    i1: usize,
    rows: usize,
    cols: usize,
    fill: f32,
) -> Matrix {
    let mut out = Matrix::full(rows, cols, fill);
    for i in i0..i1 {
        out.row_mut(i - i0)[..src.cols].copy_from_slice(src.row(i));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parses_and_rejects() {
        let specs = parse_manifest(
            "# header\npairwise_l1 pairwise l1 2048 16 256 0 f.hlo.txt\n",
        )
        .unwrap();
        assert_eq!(specs.len(), 1);
        assert_eq!(specs[0].p, 16);
        assert!(parse_manifest("bad line\n").is_err());
        assert!(parse_manifest("\n").is_err());
    }

    #[test]
    fn slice_rows_padded_fills() {
        let src = Matrix::from_vec(2, 2, vec![1., 2., 3., 4.]);
        let t = slice_rows_padded(&src, 1, 2, 3, 4, 9.0);
        assert_eq!(t.row(0), &[3., 4., 9., 9.]);
        assert_eq!(t.row(2), &[9., 9., 9., 9.]);
    }
}
