//! PJRT runtime (feature `xla`): load AOT artifacts (HLO text) and
//! execute them.
//!
//! `make artifacts` runs `python/compile/aot.py` once at build time; this
//! module is the only bridge between the Rust hot path and those
//! artifacts.
//!
//! Responsibilities:
//!   * pick the smallest shape bucket that fits a request and pad inputs
//!     (rows: zeros, batch columns: weight 0, medoid columns: BIG) so
//!     results are exact despite padding;
//!   * lazily compile HLO text -> PJRT executable, cached per artifact;
//!   * tile the `n` axis in `N_TILE`-row chunks (the artifacts' fixed row
//!     count).

use super::{parse_manifest, slice_rows_padded, ArtifactSpec};
use crate::dissim::{Metric, BIG};
use crate::linalg::Matrix;
use crate::telemetry::Counters;
use anyhow::{anyhow, bail, Context, Result};
use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;

/// Artifact registry + lazy executable cache over one PJRT client.
///
/// Not `Sync`: intended for single-threaded hot paths (the server guards
/// it with a dedicated worker thread).  CPU-side parallelism lives in
/// [`super::Pool`] instead.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    specs: Vec<ArtifactSpec>,
    cache: RefCell<HashMap<String, Arc<xla::PjRtLoadedExecutable>>>,
    counters: Arc<Counters>,
}

impl Runtime {
    /// Load the manifest from `dir` and start a CPU PJRT client.
    pub fn load(dir: &Path) -> Result<Self> {
        let manifest = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&manifest)
            .with_context(|| format!("reading {} (run `make artifacts`)", manifest.display()))?;
        let specs = parse_manifest(&text)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Runtime {
            client,
            dir: dir.to_path_buf(),
            specs,
            cache: RefCell::new(HashMap::new()),
            counters: Arc::new(Counters::default()),
        })
    }

    /// Default artifact location: `$OBPAM_ARTIFACTS` or `./artifacts`.
    pub fn load_default() -> Result<Self> {
        let dir = std::env::var("OBPAM_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
        Self::load(Path::new(&dir))
    }

    /// Shared telemetry counters.
    pub fn counters(&self) -> Arc<Counters> {
        self.counters.clone()
    }

    /// All artifact specs (for introspection / `obpam artifacts-check`).
    pub fn specs(&self) -> &[ArtifactSpec] {
        &self.specs
    }

    /// Smallest bucket of `kind`/`metric` with p >= min_p, m >= min_m,
    /// k >= min_k (0 requirements ignore that axis).
    pub fn find(&self, kind: &str, metric: Option<Metric>, min_p: usize, min_m: usize, min_k: usize) -> Result<&ArtifactSpec> {
        let metric_name = metric.map(|m| m.name());
        self.specs
            .iter()
            .filter(|s| s.kind == kind)
            .filter(|s| metric_name.map_or(true, |mn| s.metric == mn))
            .filter(|s| s.p >= min_p && s.m >= min_m && s.k >= min_k)
            .min_by_key(|s| (s.p, s.m, s.k))
            .ok_or_else(|| {
                anyhow!(
                    "no artifact for kind={kind} metric={:?} p>={min_p} m>={min_m} k>={min_k}; \
                     regenerate with `make artifacts` (full grid)",
                    metric_name
                )
            })
    }

    /// Compile (cached) and return the executable for a spec.
    fn executable(&self, spec: &ArtifactSpec) -> Result<Arc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.borrow().get(&spec.name) {
            return Ok(exe.clone());
        }
        let path = self.dir.join(&spec.file);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("parsing {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Arc::new(
            self.client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {}: {e:?}", spec.name))?,
        );
        self.cache.borrow_mut().insert(spec.name.clone(), exe.clone());
        Ok(exe)
    }

    /// Execute an artifact; returns the decomposed output tuple.
    fn exec(&self, spec: &ArtifactSpec, inputs: &[&xla::Literal]) -> Result<Vec<xla::Literal>> {
        let exe = self.executable(spec)?;
        self.counters.add_xla_exec();
        let bufs = exe
            .execute::<&xla::Literal>(inputs)
            .map_err(|e| anyhow!("executing {}: {e:?}", spec.name))?;
        let lit = bufs[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching result of {}: {e:?}", spec.name))?;
        lit.to_tuple().map_err(|e| anyhow!("untupling {}: {e:?}", spec.name))
    }

    /// n-tiled, p/m-padded pairwise distance matrix via the Pallas
    /// (`dense=false`) or plain-XLA (`dense=true`) artifact.
    pub fn pairwise(&self, x: &Matrix, b: &Matrix, metric: Metric, dense: bool) -> Result<Matrix> {
        assert_eq!(x.cols, b.cols, "feature dims differ");
        let kind = if dense { "pairwise_dense" } else { "pairwise" };
        // The artifact metric is l1 or sqeuclidean; L2 runs sqeuclidean + sqrt.
        let (art_metric, post_sqrt) = match metric {
            Metric::L1 => (Metric::L1, false),
            Metric::SqL2 => (Metric::SqL2, false),
            Metric::L2 => (Metric::SqL2, true),
            other => bail!("metric {} has no XLA artifact; use the native backend", other.name()),
        };
        let spec = self.find(kind, Some(art_metric), x.cols, b.rows, 0)?.clone();
        self.counters.add_dissim((x.rows * b.rows) as u64);

        let bp = b.pad_to(spec.m, spec.p, 0.0);
        let b_lit = matrix_literal(&bp)?;
        let mut out = Matrix::zeros(x.rows, b.rows);
        for i0 in (0..x.rows).step_by(spec.n) {
            let i1 = (i0 + spec.n).min(x.rows);
            let tile = slice_rows_padded(x, i0, i1, spec.n, spec.p, 0.0);
            let x_lit = matrix_literal(&tile)?;
            let outs = self.exec(&spec, &[&x_lit, &b_lit])?;
            let d: Vec<f32> = outs[0]
                .to_vec()
                .map_err(|e| anyhow!("pairwise output: {e:?}"))?;
            for i in i0..i1 {
                let src = (i - i0) * spec.m;
                let dst = out.row_mut(i);
                let row = &d[src..src + b.rows];
                if post_sqrt {
                    for (o, v) in dst.iter_mut().zip(row) {
                        *o = v.max(0.0).sqrt();
                    }
                } else {
                    dst.copy_from_slice(row);
                }
            }
        }
        Ok(out)
    }

    /// Swap-gain tile over all rows of `d` (n x m), padded to buckets.
    /// Returns (shared (n,), permedoid (n x k)).
    pub fn gains(
        &self,
        d: &Matrix,
        dnear: &[f32],
        dsec: &[f32],
        near: &[usize],
        k: usize,
        w: &[f32],
    ) -> Result<(Vec<f32>, Matrix)> {
        let m = d.cols;
        let spec = self.find("gains", None, 0, m, k)?.clone();
        // Pad batch vectors; padded columns get w = 0 so they contribute 0.
        let mut dn = vec![0.0f32; spec.m];
        let mut ds = vec![0.0f32; spec.m];
        let mut wp = vec![0.0f32; spec.m];
        dn[..m].copy_from_slice(dnear);
        ds[..m].copy_from_slice(dsec);
        wp[..m].copy_from_slice(w);
        let mut onehot = Matrix::zeros(spec.m, spec.k);
        for (j, &l) in near.iter().enumerate() {
            onehot.set(j, l, 1.0);
        }
        let dn_lit = vec_literal(&dn);
        let ds_lit = vec_literal(&ds);
        let w_lit = vec_literal(&wp);
        let oh_lit = matrix_literal(&onehot)?;

        let mut shared = vec![0.0f32; d.rows];
        let mut permedoid = Matrix::zeros(d.rows, k);
        for i0 in (0..d.rows).step_by(spec.n) {
            let i1 = (i0 + spec.n).min(d.rows);
            let tile = slice_rows_padded(d, i0, i1, spec.n, spec.m, 0.0);
            let tile_lit = matrix_literal(&tile)?;
            let outs = self.exec(&spec, &[&tile_lit, &dn_lit, &ds_lit, &oh_lit, &w_lit])?;
            let sh: Vec<f32> = outs[0].to_vec().map_err(|e| anyhow!("gains shared: {e:?}"))?;
            let pm: Vec<f32> = outs[1].to_vec().map_err(|e| anyhow!("gains permedoid: {e:?}"))?;
            shared[i0..i1].copy_from_slice(&sh[..i1 - i0]);
            for i in i0..i1 {
                let src = (i - i0) * spec.k;
                permedoid.row_mut(i).copy_from_slice(&pm[src..src + k]);
            }
        }
        Ok((shared, permedoid))
    }

    /// Row-wise top-2 over an (n x k) medoid-distance matrix.
    pub fn top2(&self, d: &Matrix) -> Result<(Vec<usize>, Vec<f32>, Vec<usize>, Vec<f32>)> {
        let k = d.cols;
        let spec = self.find("top2", None, 0, 0, k)?.clone();
        let (mut ni, mut nd) = (vec![0usize; d.rows], vec![0f32; d.rows]);
        let (mut si, mut sd) = (vec![0usize; d.rows], vec![0f32; d.rows]);
        for i0 in (0..d.rows).step_by(spec.n) {
            let i1 = (i0 + spec.n).min(d.rows);
            // pad medoid columns with BIG so they never win top2
            let tile = slice_rows_padded(d, i0, i1, spec.n, spec.k, BIG);
            let tile_lit = matrix_literal(&tile)?;
            let outs = self.exec(&spec, &[&tile_lit])?;
            let a: Vec<i32> = outs[0].to_vec().map_err(|e| anyhow!("top2 ni: {e:?}"))?;
            let b: Vec<f32> = outs[1].to_vec().map_err(|e| anyhow!("top2 nd: {e:?}"))?;
            let c: Vec<i32> = outs[2].to_vec().map_err(|e| anyhow!("top2 si: {e:?}"))?;
            let e: Vec<f32> = outs[3].to_vec().map_err(|e| anyhow!("top2 sd: {e:?}"))?;
            for i in i0..i1 {
                ni[i] = a[i - i0] as usize;
                nd[i] = b[i - i0];
                si[i] = c[i - i0] as usize;
                sd[i] = e[i - i0];
            }
        }
        Ok((ni, nd, si, sd))
    }

    /// Row-wise (argmin, min) over an (n x m) matrix.
    pub fn argmin_rows(&self, d: &Matrix) -> Result<(Vec<usize>, Vec<f32>)> {
        let spec = self.find("argmin", None, 0, d.cols, 0)?.clone();
        let (mut idx, mut val) = (vec![0usize; d.rows], vec![0f32; d.rows]);
        for i0 in (0..d.rows).step_by(spec.n) {
            let i1 = (i0 + spec.n).min(d.rows);
            let tile = slice_rows_padded(d, i0, i1, spec.n, spec.m, BIG);
            let tile_lit = matrix_literal(&tile)?;
            let outs = self.exec(&spec, &[&tile_lit])?;
            let a: Vec<i32> = outs[0].to_vec().map_err(|e| anyhow!("argmin idx: {e:?}"))?;
            let b: Vec<f32> = outs[1].to_vec().map_err(|e| anyhow!("argmin val: {e:?}"))?;
            for i in i0..i1 {
                idx[i] = a[i - i0] as usize;
                val[i] = b[i - i0];
            }
        }
        Ok((idx, val))
    }

    /// Weighted batch objective via the `objective` artifact.
    pub fn objective(&self, dnear: &[f32], w: &[f32]) -> Result<f32> {
        let spec = self.find("objective", None, 0, dnear.len(), 0)?.clone();
        let mut dn = vec![0.0f32; spec.m];
        let mut wp = vec![0.0f32; spec.m];
        dn[..dnear.len()].copy_from_slice(dnear);
        wp[..w.len()].copy_from_slice(w);
        let dn_lit = vec_literal(&dn);
        let wp_lit = vec_literal(&wp);
        let outs = self.exec(&spec, &[&dn_lit, &wp_lit])?;
        outs[0]
            .to_vec::<f32>()
            .map_err(|e| anyhow!("objective: {e:?}"))
            .map(|v| v[0])
    }
}

/// Matrix -> f32 PJRT literal of shape [rows, cols].
fn matrix_literal(m: &Matrix) -> Result<xla::Literal> {
    xla::Literal::vec1(&m.data)
        .reshape(&[m.rows as i64, m.cols as i64])
        .map_err(|e| anyhow!("reshape literal: {e:?}"))
}

/// Slice -> f32 PJRT literal of shape [len].
fn vec_literal(v: &[f32]) -> xla::Literal {
    xla::Literal::vec1(v)
}
