//! Persistent row-chunked worker pool for the embarrassingly parallel
//! hot paths.
//!
//! Every expensive loop in the crate (the `O(nmp)` pairwise pass, the
//! per-row `top2` / `gains` / `argmin` tile ops, the `O(n(m+k))` eager
//! candidate scan) iterates independent rows, so parallelism is plain
//! row partitioning.  [`Pool`] captures one knob — the thread count —
//! and two execution shapes:
//!
//! * [`Pool::map_ranges`] — split `0..n` into at most `threads`
//!   contiguous ranges, run a closure per range on the pool's workers,
//!   and return the results *in range order*;
//! * [`Pool::for_each_row_chunk`] — hand each worker a disjoint
//!   `&mut` window of a row-major buffer (no result stitching).
//!
//! Determinism: ranges are contiguous, each task writes only its own
//! result slot, results are stitched in range order, and every per-row
//! computation in the crate is independent of its chunk boundaries, so
//! all outputs are **bit-identical at any thread count and across any
//! number of regions on one reused pool** (asserted by
//! rust/tests/parallel_equivalence.rs).
//!
//! `threads == 1` never spawns: closures run inline on the caller's
//! thread, which is exactly the pre-parallel serial path.
//!
//! # Implementation
//!
//! A `threads`-wide pool owns `threads - 1` long-lived parked workers
//! (the caller is the remaining executor — it always participates, so
//! no core idles while the region runs).  Publishing a region is one
//! mutex store + `notify_all`; workers then claim task indices from a
//! shared atomic counter and park again when the region drains.  This
//! replaced the original `std::thread::scope`-per-region design: the
//! facade and the bit-identical guarantee are unchanged, but a region
//! dispatch costs a wakeup instead of `threads - 1` thread spawns +
//! joins (benches/micro.rs reports both shapes side by side).  Rayon
//! would provide this off the shelf, but it is not in the offline
//! vendor set — same reason rand/clap/serde are hand-rolled here.
//!
//! Cloning a [`Pool`] shares the same workers (the handle is an `Arc`);
//! the workers shut down and are joined when the last handle drops.
//! One region runs at a time per pool: a nested or concurrent region on
//! the same pool runs inline on its caller instead of deadlocking —
//! results are identical either way, only the parallelism differs.

use crate::sync_ext;
use std::num::NonZeroUsize;
use std::ops::Range;
use std::panic::{catch_unwind, AssertUnwindSafe};
#[cfg(debug_assertions)]
use std::sync::atomic::AtomicU64;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

/// Type-erased descriptor of one parallel region, published to the
/// workers through [`Shared::job`].  All pointers target the region
/// caller's stack frame.
#[derive(Clone, Copy)]
struct JobRef {
    /// Runs task `t` through the erased closure behind `ctx`.
    call: unsafe fn(*const (), usize),
    /// Points at the caller-stack `&(dyn Fn(usize) + Sync)` fat
    /// reference (a thin pointer to it, since fat pointers do not fit
    /// in `*const ()`).
    ctx: *const (),
    /// Next unclaimed task index.
    next: *const AtomicUsize,
    /// Set when any worker task panicked (the caller re-raises).
    panicked: *const AtomicBool,
    /// Task count of the region.
    total: usize,
}

// SAFETY: the pointers target the region caller's stack frame, which
// outlives every worker's use of them — `run_region` cannot return (or
// unwind) past its quiesce guard until `Shared::active == 0`, i.e.
// until no worker is inside the region anymore.
unsafe impl Send for JobRef {}

/// Trampoline from the erased `ctx` back to the region closure.
///
/// # Safety
///
/// `ctx` must be the thin pointer published in the current region's
/// [`JobRef`]: a pointer to a live `&(dyn Fn(usize) + Sync)` fat
/// reference on the region caller's stack.  Callers guarantee that
/// frame is still pinned — the region's quiesce guard has not run.
unsafe fn call_erased(ctx: *const (), t: usize) {
    // SAFETY: per this function's contract, `ctx` points at the region
    // caller's still-live fat reference; it is only reborrowed, never
    // retained past this call.
    let f: &&(dyn Fn(usize) + Sync) = unsafe { &*(ctx as *const &(dyn Fn(usize) + Sync)) };
    f(t)
}

/// Worker-visible pool state, guarded by one mutex (never held while a
/// task runs).
struct Shared {
    /// The region currently open for claiming, if any.
    job: Option<JobRef>,
    /// Bumped once per published region so a parked worker can tell a
    /// fresh job from the one it already drained.
    seq: u64,
    /// Workers currently inside a region's claim loop.
    active: usize,
    /// Set once, by the last pool handle's drop.
    shutdown: bool,
}

struct Inner {
    shared: Mutex<Shared>,
    /// Workers park here waiting for a region (or shutdown).
    work_cv: Condvar,
    /// The region caller parks here waiting for `active == 0`.
    done_cv: Condvar,
    /// Serialises regions; `try_lock` failure = nested/concurrent
    /// region, which runs inline instead.
    region: Mutex<()>,
    /// Debug-build flow counter: regions ever published to the workers.
    #[cfg(debug_assertions)]
    published: AtomicU64,
    /// Debug-build flow counter: regions ever retired by a quiesce
    /// guard.  Equals `published` whenever no region is running.
    #[cfg(debug_assertions)]
    retired: AtomicU64,
}

/// Owns the worker threads; dropping the last [`Pool`] handle drops
/// this, which signals shutdown and joins every worker.
struct PoolCore {
    inner: Arc<Inner>,
    handles: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

impl Drop for PoolCore {
    fn drop(&mut self) {
        {
            let mut s = sync_ext::lock_or_recover(&self.inner.shared);
            s.shutdown = true;
        }
        self.inner.work_cv.notify_all();
        for h in sync_ext::lock_or_recover(&self.handles).drain(..) {
            let _ = h.join();
        }
    }
}

/// Raw-pointer wrapper that lets disjoint-index writers share one base
/// pointer across worker threads (each task touches only its own slot /
/// row window, so the aliasing is by construction disjoint).
struct SyncPtr<T>(*mut T);
// SAFETY: SyncPtr is only constructed over buffers whose tasks write
// disjoint regions — map_ranges task `t` writes exactly slot `t`, and
// for_each_row_chunk hands out disjoint row windows — so concurrent use
// from worker threads never aliases a write; `T: Send` keeps moving the
// pointed-to values between threads sound.  The same argument covers
// both auto traits, so one comment documents the pair of impls.
unsafe impl<T: Send> Send for SyncPtr<T> {}
unsafe impl<T: Send> Sync for SyncPtr<T> {}
impl<T> Clone for SyncPtr<T> {
    fn clone(&self) -> Self {
        SyncPtr(self.0)
    }
}
impl<T> Copy for SyncPtr<T> {}

/// A configurable-width persistent thread pool (see module docs).
#[derive(Clone)]
pub struct Pool {
    threads: usize,
    /// `None` for the serial pool — no threads exist at width 1.
    core: Option<Arc<PoolCore>>,
}

impl std::fmt::Debug for Pool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Pool").field("threads", &self.threads).finish()
    }
}

impl Default for Pool {
    /// Default is the serial path (one thread).
    fn default() -> Self {
        Pool::serial()
    }
}

impl Pool {
    /// Pool with `threads` workers; `0` means auto-detect
    /// (`std::thread::available_parallelism`, falling back to 1).
    ///
    /// A width-`t` pool spawns `t - 1` parked worker threads (the
    /// caller of each region is the remaining executor); they live
    /// until the last clone of this handle drops.
    pub fn new(threads: usize) -> Self {
        let t = Pool::resolve(threads);
        if t == 1 {
            return Pool { threads: 1, core: None };
        }
        let inner = Arc::new(Inner {
            shared: Mutex::new(Shared { job: None, seq: 0, active: 0, shutdown: false }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
            region: Mutex::new(()),
            #[cfg(debug_assertions)]
            published: AtomicU64::new(0),
            #[cfg(debug_assertions)]
            retired: AtomicU64::new(0),
        });
        let mut handles = Vec::with_capacity(t - 1);
        for _ in 0..t - 1 {
            let inner = inner.clone();
            handles.push(std::thread::spawn(move || worker_loop(&inner)));
        }
        Pool { threads: t, core: Some(Arc::new(PoolCore { inner, handles: Mutex::new(handles) })) }
    }

    /// The worker count [`Pool::new`] would resolve `threads` to
    /// (`0` = auto-detect), without building a pool.  Lets pool caches
    /// key on the effective width so `threads=0` and an explicit
    /// `threads=<cores>` share one cached pool.
    pub fn resolve(threads: usize) -> usize {
        if threads == 0 {
            std::thread::available_parallelism().map(NonZeroUsize::get).unwrap_or(1)
        } else {
            threads
        }
        .max(1)
    }

    /// The single-threaded pool: every call runs inline on the caller.
    pub fn serial() -> Self {
        Pool { threads: 1, core: None }
    }

    /// Pool sized to the machine (`available_parallelism`).
    pub fn auto() -> Self {
        Pool::new(0)
    }

    /// Worker count (>= 1).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Does this pool run everything inline on the caller's thread?
    pub fn is_serial(&self) -> bool {
        self.threads == 1
    }

    /// Debug-build flow counters: `(regions published, regions
    /// retired)`.  The two are equal whenever no region is running —
    /// the deterministic interleaving suite asserts this balance after
    /// every schedule step.  Serial pools (no workers) report `(0, 0)`.
    #[cfg(debug_assertions)]
    pub fn debug_region_flow(&self) -> (u64, u64) {
        match &self.core {
            Some(core) => (
                core.inner.published.load(Ordering::SeqCst),
                core.inner.retired.load(Ordering::SeqCst),
            ),
            None => (0, 0),
        }
    }

    /// Split `0..n` into at most `threads` contiguous, non-empty,
    /// ascending ranges covering `0..n` (empty for `n == 0`).
    pub fn ranges(&self, n: usize) -> Vec<Range<usize>> {
        if n == 0 {
            return Vec::new();
        }
        let t = self.threads.min(n);
        let chunk = (n + t - 1) / t;
        let mut out = Vec::with_capacity(t);
        let mut start = 0;
        while start < n {
            let end = (start + chunk).min(n);
            out.push(start..end);
            start = end;
        }
        out
    }

    /// Run `f` over contiguous sub-ranges of `0..n` in parallel and
    /// return one result per range, in range order.
    ///
    /// Serial pools (and `n <= 1`) call `f(0..n)` inline.
    pub fn map_ranges<R, F>(&self, n: usize, f: F) -> Vec<R>
    where
        R: Send,
        F: Fn(Range<usize>) -> R + Sync,
    {
        if self.is_serial() || n <= 1 {
            return vec![f(0..n)];
        }
        let ranges = self.ranges(n);
        if ranges.len() == 1 {
            return vec![f(0..n)];
        }
        let total = ranges.len();
        let mut out: Vec<Option<R>> = (0..total).map(|_| None).collect();
        {
            let slots = SyncPtr(out.as_mut_ptr());
            let ranges = &ranges;
            let f = &f;
            let task = move |t: usize| {
                let r = f(ranges[t].clone());
                // SAFETY: task index t writes exactly slot t; indices are
                // claimed at most once, so no two writers alias.
                unsafe { *slots.0.add(t) = Some(r) };
            };
            self.run_region(total, &task);
        }
        out.into_iter().map(|r| r.expect("pool task completed")).collect()
    }

    /// Partition the row-major buffer `data` (`rows x cols`) into
    /// disjoint row chunks and run `f(first_row, chunk)` on each in
    /// parallel.  Serial pools call `f(0, data)` inline.
    pub fn for_each_row_chunk<F>(&self, data: &mut [f32], rows: usize, cols: usize, f: F)
    where
        F: Fn(usize, &mut [f32]) + Sync,
    {
        debug_assert_eq!(data.len(), rows * cols, "buffer/shape mismatch");
        if self.is_serial() || rows <= 1 || cols == 0 {
            f(0, data);
            return;
        }
        let ranges = self.ranges(rows);
        if ranges.len() == 1 {
            f(0, data);
            return;
        }
        let base = SyncPtr(data.as_mut_ptr());
        let ranges = &ranges;
        let f = &f;
        let task = move |t: usize| {
            let r = &ranges[t];
            // SAFETY: row ranges are disjoint, so the chunks never alias.
            let chunk = unsafe {
                std::slice::from_raw_parts_mut(base.0.add(r.start * cols), (r.end - r.start) * cols)
            };
            f(r.start, chunk);
        };
        self.run_region(ranges.len(), &task);
    }

    /// Execute one parallel region: publish `total` tasks to the parked
    /// workers, claim tasks on the calling thread too, and return only
    /// once every task ran and every worker left the region.
    fn run_region(&self, total: usize, task: &(dyn Fn(usize) + Sync)) {
        if total == 0 {
            return;
        }
        let Some(core) = &self.core else {
            for t in 0..total {
                task(t);
            }
            return;
        };
        if total == 1 {
            task(0);
            return;
        }
        // One region at a time: a nested or concurrent region on the
        // same pool runs inline on its caller instead of deadlocking on
        // workers that are busy with the outer region.  (sync_ext
        // recovers a guard poisoned by a past caller-side task panic —
        // the pool state itself is still consistent.)
        let Some(_region) = sync_ext::try_lock_or_recover(&core.inner.region) else {
            for t in 0..total {
                task(t);
            }
            return;
        };
        let next = AtomicUsize::new(0);
        let panicked = AtomicBool::new(false);
        let task_ref: &(dyn Fn(usize) + Sync) = task;
        let job = JobRef {
            call: call_erased,
            ctx: (&task_ref) as *const &(dyn Fn(usize) + Sync) as *const (),
            next: &next,
            panicked: &panicked,
            total,
        };
        {
            let mut s = sync_ext::lock_or_recover(&core.inner.shared);
            s.job = Some(job);
            s.seq = s.seq.wrapping_add(1);
        }
        #[cfg(debug_assertions)]
        core.inner.published.fetch_add(1, Ordering::SeqCst);
        core.inner.work_cv.notify_all();
        {
            // The guard quiesces on every exit path — including a task
            // panicking on this thread — so no worker can touch the
            // job's stack pointers after this frame starts unwinding.
            let _quiesce = Quiesce { inner: &core.inner };
            loop {
                let t = next.fetch_add(1, Ordering::SeqCst);
                if t >= total {
                    break;
                }
                task(t);
            }
        }
        if panicked.load(Ordering::SeqCst) {
            panic!("pool worker panicked");
        }
    }
}

/// Waits until no worker is inside the current region, then retires the
/// job.  Runs on drop so unwinding callers still quiesce.
struct Quiesce<'a> {
    inner: &'a Inner,
}

impl Drop for Quiesce<'_> {
    fn drop(&mut self) {
        let mut s = sync_ext::lock_or_recover(&self.inner.shared);
        while s.active > 0 {
            s = sync_ext::wait_or_recover(&self.inner.done_cv, s);
        }
        s.job = None;
        #[cfg(debug_assertions)]
        self.inner.retired.fetch_add(1, Ordering::SeqCst);
    }
}

/// A worker: park on `work_cv`, drain any newly published region by
/// claiming task indices, park again.  A panicking task is caught and
/// flagged (the region caller re-raises), so one bad task never shrinks
/// the pool.
fn worker_loop(inner: &Inner) {
    let mut seen = 0u64;
    let mut s = sync_ext::lock_or_recover(&inner.shared);
    loop {
        if s.shutdown {
            return;
        }
        if s.seq != seen {
            seen = s.seq;
            if let Some(job) = s.job {
                s.active += 1;
                drop(s);
                loop {
                    // SAFETY: run_region keeps these pointers alive while
                    // `active > 0` (its quiesce guard waits for us).
                    let t = unsafe { &*job.next }.fetch_add(1, Ordering::SeqCst);
                    if t >= job.total {
                        break;
                    }
                    // SAFETY: same pin as `job.next` above — `call` is
                    // `call_erased` and `ctx` is the thin pointer
                    // run_region published for it, both live until the
                    // quiesce guard sees `active == 0`.
                    if catch_unwind(AssertUnwindSafe(|| unsafe { (job.call)(job.ctx, t) }))
                        .is_err()
                    {
                        // SAFETY: `job.panicked` points at the region
                        // caller's flag, pinned like the pointers above
                        // until this worker decrements `active`.
                        unsafe { &*job.panicked }.store(true, Ordering::SeqCst);
                    }
                }
                s = sync_ext::lock_or_recover(&inner.shared);
                s.active -= 1;
                if s.active == 0 {
                    inner.done_cv.notify_all();
                }
                continue;
            }
        }
        s = sync_ext::wait_or_recover(&inner.work_cv, s);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn new_zero_is_auto_and_nonzero() {
        assert!(Pool::new(0).threads() >= 1);
        assert_eq!(Pool::new(3).threads(), 3);
        // resolve() predicts the width new() builds, without spawning
        assert_eq!(Pool::resolve(0), Pool::new(0).threads());
        assert_eq!(Pool::resolve(5), 5);
        assert_eq!(Pool::resolve(1), 1);
        assert!(Pool::serial().is_serial());
        assert!(!Pool::new(2).is_serial());
    }

    #[test]
    fn ranges_cover_exactly_in_order() {
        for threads in [1, 2, 3, 4, 7] {
            let pool = Pool::new(threads);
            for n in [0usize, 1, 2, 5, 16, 17, 100] {
                let rs = pool.ranges(n);
                assert!(rs.len() <= threads.max(1));
                let mut expect = 0;
                for r in &rs {
                    assert_eq!(r.start, expect, "gap at n={n} t={threads}");
                    assert!(r.end > r.start, "empty range at n={n} t={threads}");
                    expect = r.end;
                }
                assert_eq!(expect, n, "coverage at n={n} t={threads}");
            }
        }
    }

    #[test]
    fn map_ranges_results_in_order() {
        for threads in [1, 2, 4] {
            let pool = Pool::new(threads);
            let parts = pool.map_ranges(25, |r| r.map(|i| i * i).collect::<Vec<_>>());
            let flat: Vec<usize> = parts.into_iter().flatten().collect();
            let expect: Vec<usize> = (0..25).map(|i| i * i).collect();
            assert_eq!(flat, expect, "threads={threads}");
        }
    }

    #[test]
    fn for_each_row_chunk_touches_every_row_once() {
        for threads in [1, 2, 3, 8] {
            let pool = Pool::new(threads);
            let (rows, cols) = (13, 4);
            let mut data = vec![0.0f32; rows * cols];
            pool.for_each_row_chunk(&mut data, rows, cols, |row0, chunk| {
                for (di, row) in chunk.chunks_mut(cols).enumerate() {
                    for v in row.iter_mut() {
                        *v += (row0 + di) as f32 + 1.0;
                    }
                }
            });
            for i in 0..rows {
                for j in 0..cols {
                    assert_eq!(data[i * cols + j], (i + 1) as f32, "threads={threads}");
                }
            }
        }
    }

    #[test]
    fn one_pool_serves_many_regions() {
        // the persistent-pool contract: repeated regions of different
        // shapes reuse the same parked workers and stay correct
        for threads in [2, 3, 8] {
            let pool = Pool::new(threads);
            for round in 0..50 {
                let n = 7 + round % 40;
                let parts = pool.map_ranges(n, |r| r.sum::<usize>());
                let total: usize = parts.into_iter().sum();
                assert_eq!(total, n * (n - 1) / 2, "round {round} t={threads}");
                let mut buf = vec![0.0f32; n * 3];
                pool.for_each_row_chunk(&mut buf, n, 3, |row0, chunk| {
                    for (di, row) in chunk.chunks_mut(3).enumerate() {
                        row.iter_mut().for_each(|v| *v = (row0 + di) as f32);
                    }
                });
                for i in 0..n {
                    assert_eq!(buf[i * 3], i as f32, "round {round} t={threads}");
                }
            }
        }
    }

    #[test]
    fn clones_share_the_same_workers() {
        let pool = Pool::new(4);
        let clone = pool.clone();
        let a = pool.map_ranges(33, |r| r.len());
        let b = clone.map_ranges(33, |r| r.len());
        assert_eq!(a, b);
        drop(pool);
        // workers outlive the original handle while a clone exists
        assert_eq!(clone.map_ranges(10, |r| r.len()).iter().sum::<usize>(), 10);
    }

    #[test]
    fn nested_region_runs_inline_not_deadlocked() {
        let pool = Pool::new(2);
        let outer = pool.map_ranges(4, |r| {
            // a nested region on the same pool must complete (inline)
            let inner: usize = pool.map_ranges(6, |q| q.len()).into_iter().sum();
            (r.len(), inner)
        });
        let total_rows: usize = outer.iter().map(|(len, _)| len).sum();
        assert_eq!(total_rows, 4, "outer ranges must cover 0..4");
        for (_, inner) in outer {
            assert_eq!(inner, 6, "nested region must cover 0..6");
        }
    }

    #[test]
    fn worker_panic_propagates_and_pool_survives() {
        let pool = Pool::new(4);
        let boom = std::panic::catch_unwind(AssertUnwindSafe(|| {
            pool.map_ranges(64, |r| {
                if r.start >= 16 {
                    panic!("task boom");
                }
                r.len()
            })
        }));
        assert!(boom.is_err(), "panic in a task must propagate to the caller");
        // the pool is still usable afterwards (workers caught the panic)
        let parts = pool.map_ranges(20, |r| r.len());
        assert_eq!(parts.into_iter().sum::<usize>(), 20);
    }
}
