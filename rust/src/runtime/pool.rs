//! Row-chunked thread pool for the embarrassingly parallel hot paths.
//!
//! Every expensive loop in the crate (the `O(nmp)` pairwise pass, the
//! per-row `top2` / `gains` / `argmin` tile ops, the `O(n(m+k))` eager
//! candidate scan) iterates independent rows, so parallelism is plain
//! row partitioning.  [`Pool`] captures one knob — the thread count —
//! and two execution shapes:
//!
//! * [`Pool::map_ranges`] — split `0..n` into at most `threads`
//!   contiguous ranges, run a closure per range on scoped threads, and
//!   return the results *in range order*;
//! * [`Pool::for_each_row_chunk`] — hand each thread a disjoint
//!   `&mut` window of a row-major buffer (no result stitching).
//!
//! Determinism: ranges are contiguous and results are stitched in
//! order, and every per-row computation in the crate is independent of
//! its chunk boundaries, so all outputs are **bit-identical at any
//! thread count** (asserted by rust/tests/parallel_equivalence.rs).
//!
//! `threads == 1` never spawns: closures run inline on the caller's
//! thread, which is exactly the pre-parallel serial path.
//!
//! Implementation note: this is `std::thread::scope` per parallel
//! region rather than a persistent rayon-style pool — rayon is not in
//! the offline vendor set (same reason rand/clap/serde are hand-rolled
//! here).  Scoped-spawn overhead is tens of microseconds, amortised by
//! the chunk sizes used at the call sites.

use std::num::NonZeroUsize;
use std::ops::Range;

/// A configurable-width scoped thread pool (see module docs).
#[derive(Clone, Debug)]
pub struct Pool {
    threads: usize,
}

impl Default for Pool {
    /// Default is the serial path (one thread).
    fn default() -> Self {
        Pool::serial()
    }
}

impl Pool {
    /// Pool with `threads` workers; `0` means auto-detect
    /// (`std::thread::available_parallelism`, falling back to 1).
    pub fn new(threads: usize) -> Self {
        let t = if threads == 0 {
            std::thread::available_parallelism().map(NonZeroUsize::get).unwrap_or(1)
        } else {
            threads
        };
        Pool { threads: t.max(1) }
    }

    /// The single-threaded pool: every call runs inline on the caller.
    pub fn serial() -> Self {
        Pool { threads: 1 }
    }

    /// Pool sized to the machine (`available_parallelism`).
    pub fn auto() -> Self {
        Pool::new(0)
    }

    /// Worker count (>= 1).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Does this pool run everything inline on the caller's thread?
    pub fn is_serial(&self) -> bool {
        self.threads == 1
    }

    /// Split `0..n` into at most `threads` contiguous, non-empty,
    /// ascending ranges covering `0..n` (empty for `n == 0`).
    pub fn ranges(&self, n: usize) -> Vec<Range<usize>> {
        if n == 0 {
            return Vec::new();
        }
        let t = self.threads.min(n);
        let chunk = (n + t - 1) / t;
        let mut out = Vec::with_capacity(t);
        let mut start = 0;
        while start < n {
            let end = (start + chunk).min(n);
            out.push(start..end);
            start = end;
        }
        out
    }

    /// Run `f` over contiguous sub-ranges of `0..n` in parallel and
    /// return one result per range, in range order.
    ///
    /// Serial pools (and `n <= 1`) call `f(0..n)` inline.
    pub fn map_ranges<R, F>(&self, n: usize, f: F) -> Vec<R>
    where
        R: Send,
        F: Fn(Range<usize>) -> R + Sync,
    {
        if self.is_serial() || n <= 1 {
            return vec![f(0..n)];
        }
        let ranges = self.ranges(n);
        let f = &f; // share one &F across the spawned closures
        std::thread::scope(|s| {
            let handles: Vec<_> = ranges
                .into_iter()
                .map(|r| s.spawn(move || f(r)))
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("pool worker panicked"))
                .collect()
        })
    }

    /// Partition the row-major buffer `data` (`rows x cols`) into
    /// disjoint row chunks and run `f(first_row, chunk)` on each in
    /// parallel.  Serial pools call `f(0, data)` inline.
    pub fn for_each_row_chunk<F>(&self, data: &mut [f32], rows: usize, cols: usize, f: F)
    where
        F: Fn(usize, &mut [f32]) + Sync,
    {
        debug_assert_eq!(data.len(), rows * cols, "buffer/shape mismatch");
        if self.is_serial() || rows <= 1 || cols == 0 {
            f(0, data);
            return;
        }
        let ranges = self.ranges(rows);
        let f = &f; // share one &F across the spawned closures
        std::thread::scope(|s| {
            let mut rest: &mut [f32] = data;
            for r in ranges {
                let (head, tail) = rest.split_at_mut((r.end - r.start) * cols);
                rest = tail;
                s.spawn(move || f(r.start, head));
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn new_zero_is_auto_and_nonzero() {
        assert!(Pool::new(0).threads() >= 1);
        assert_eq!(Pool::new(3).threads(), 3);
        assert!(Pool::serial().is_serial());
        assert!(!Pool::new(2).is_serial());
    }

    #[test]
    fn ranges_cover_exactly_in_order() {
        for threads in [1, 2, 3, 4, 7] {
            let pool = Pool::new(threads);
            for n in [0usize, 1, 2, 5, 16, 17, 100] {
                let rs = pool.ranges(n);
                assert!(rs.len() <= threads.max(1));
                let mut expect = 0;
                for r in &rs {
                    assert_eq!(r.start, expect, "gap at n={n} t={threads}");
                    assert!(r.end > r.start, "empty range at n={n} t={threads}");
                    expect = r.end;
                }
                assert_eq!(expect, n, "coverage at n={n} t={threads}");
            }
        }
    }

    #[test]
    fn map_ranges_results_in_order() {
        for threads in [1, 2, 4] {
            let pool = Pool::new(threads);
            let parts = pool.map_ranges(25, |r| r.map(|i| i * i).collect::<Vec<_>>());
            let flat: Vec<usize> = parts.into_iter().flatten().collect();
            let expect: Vec<usize> = (0..25).map(|i| i * i).collect();
            assert_eq!(flat, expect, "threads={threads}");
        }
    }

    #[test]
    fn for_each_row_chunk_touches_every_row_once() {
        for threads in [1, 2, 3, 8] {
            let pool = Pool::new(threads);
            let (rows, cols) = (13, 4);
            let mut data = vec![0.0f32; rows * cols];
            pool.for_each_row_chunk(&mut data, rows, cols, |row0, chunk| {
                for (di, row) in chunk.chunks_mut(cols).enumerate() {
                    for v in row.iter_mut() {
                        *v += (row0 + di) as f32 + 1.0;
                    }
                }
            });
            for i in 0..rows {
                for j in 0..cols {
                    assert_eq!(data[i * cols + j], (i + 1) as f32, "threads={threads}");
                }
            }
        }
    }
}
