//! Sharded, LRU-bounded dataset cache (server protocol v3).
//!
//! Keyed by the full provenance of a prepared matrix: the
//! [`DataSource`] identity (its canonical URI minus admission-only
//! decorations like `?rows=`) *and* its fingerprint, the generation
//! knobs (`scale`, `seed` — synthetic sources only) and the
//! [`FeatureScaling`] applied after loading.  Values are `Arc<Matrix>`
//! so concurrent jobs share one copy with zero cloning, spread over
//! [`SHARDS`] independent locks.
//!
//! `file:` sources are admitted like synthetic ones, with two twists:
//!
//! * the fingerprint mixes the file's size + mtime
//!   ([`DataSource::fingerprint`]), so any edit that changes either
//!   makes the stale entry unreachable (it ages out of the LRU; see the
//!   fingerprint docs for the same-size-same-mtime-tick caveat);
//! * `scale`/`seed` do not shape file bytes, so they are normalised out
//!   of the key — a seed sweep over one CSV shares a single resident
//!   copy.
//!
//! A cold miss loads *outside* the shard lock behind a per-key
//! in-flight marker: the first requester of a key marks it loading,
//! releases the lock, and loads; concurrent requesters of the *same*
//! key park on the shard's condvar and are served the finished entry
//! (single-load-per-burst, no thundering herd), while requesters of
//! *other* keys on the same shard sail through — a cold multi-GB
//! `file:` load no longer stalls unrelated datasets that hash to the
//! same shard.  Load failures (unknown synth names, unreadable files)
//! clear the marker, wake the waiters (the next one retries the load),
//! and are never cached.

use crate::data::{DataSource, FeatureScaling};
use crate::linalg::Matrix;
use crate::sync_ext;
use anyhow::Result;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};

/// Number of independently locked shards.
pub const SHARDS: usize = 8;

/// Cache key: the full provenance of a prepared matrix.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
struct DataKey {
    /// Source identity ([`DataSource::identity`] — the `canon()` minus
    /// admission-only decorations like `?rows=`, which do not change
    /// the loaded bytes).
    source: String,
    /// Source fingerprint — re-stat'ed per request for `file:` sources,
    /// so on-disk edits change the key ([`DataSource::fingerprint`]).
    fingerprint: u64,
    /// `f64::to_bits` of the scale (`f64` itself is not `Eq`/`Hash`).
    scale_bits: u64,
    seed: u64,
    /// Post-load feature preprocessing.
    scaling: FeatureScaling,
}

/// One shard: entries kept in most-recently-used-first order (caches are
/// small — `cache_cap` datasets total — so a scan beats a linked map),
/// plus the keys currently being loaded outside the lock.
struct Shard {
    entries: Vec<(DataKey, Arc<Matrix>)>,
    /// Per-key in-flight markers: a key listed here has a loader running
    /// outside the lock; same-key requesters wait on the shard condvar.
    loading: Vec<DataKey>,
}

/// A shard and the condvar its same-key waiters park on.
struct ShardSlot {
    state: Mutex<Shard>,
    loaded_cv: Condvar,
}

/// Sharded dataset cache; see the module docs.
pub struct DatasetCache {
    shards: Vec<ShardSlot>,
    per_shard_cap: usize,
    /// Largest matrix (in feature bytes, `n * p * 4`) the cache will
    /// load and pin; `0` = unmetered.  The server passes its resolved
    /// byte budget, so an oversized `file:`/`npy:` load fails with a
    /// priced `bytes=` error instead of OOM-ing the process — streamed
    /// solves never touch the cache at all (protocol v9).
    byte_limit: u64,
    hits: AtomicU64,
    misses: AtomicU64,
}

/// Hit/miss/occupancy snapshot (served by the `stats` wire command).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Requests served from the cache.
    pub hits: u64,
    /// Requests that had to load (== total loads ever run).
    pub misses: u64,
    /// Datasets currently resident.
    pub entries: usize,
}

impl DatasetCache {
    /// Cache bounded to ~`cap` datasets total: the budget is split
    /// evenly across [`SHARDS`] shards (rounded up, at least one entry
    /// per shard), each evicting least-recently-used first.
    pub fn new(cap: usize) -> Self {
        Self::with_byte_limit(cap, 0)
    }

    /// [`DatasetCache::new`] with a residency ceiling: any single load
    /// whose feature bytes (`n * p * 4`) exceed `byte_limit` fails with
    /// a priced `bytes=` error instead of being cached (`0` =
    /// unmetered).  Sources that publish their shape up front
    /// (`npy:`/`dir:`) are refused before any row is read; others
    /// (synth, `file:` CSV) are measured after the load and refused
    /// before the matrix is pinned.
    pub fn with_byte_limit(cap: usize, byte_limit: u64) -> Self {
        DatasetCache {
            shards: (0..SHARDS)
                .map(|_| ShardSlot {
                    state: Mutex::new(Shard { entries: Vec::new(), loading: Vec::new() }),
                    loaded_cv: Condvar::new(),
                })
                .collect(),
            per_shard_cap: cap.div_ceil(SHARDS).max(1),
            byte_limit,
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    /// Refuses a load whose resident footprint exceeds the byte limit.
    fn check_bytes(&self, identity: &str, bytes: u64) -> Result<()> {
        anyhow::ensure!(
            self.byte_limit == 0 || bytes <= self.byte_limit,
            "dataset {identity} needs bytes={bytes} resident, over the cache byte \
             limit of {} (raise --byte-budget, or stream it via npy:/dir: with \
             method=onebatch)",
            self.byte_limit
        );
        Ok(())
    }

    /// Fetch the prepared matrix for `(src, scale, seed, scaling)`,
    /// loading it on a miss.  Returns the shared matrix and whether it
    /// was a cache hit.
    pub fn get_or_load(
        &self,
        src: &DataSource,
        scale: f64,
        seed: u64,
        scaling: FeatureScaling,
    ) -> Result<(Arc<Matrix>, bool)> {
        // the canonicalize + stat happen here, outside any shard lock; an
        // edited file gets a fresh fingerprint, so a stale entry is
        // unreachable (identity is computed once and shared with the
        // fingerprint — one path resolution per request, even on hits)
        let identity = src.identity();
        let fingerprint = src.fingerprint_of(&identity)?;
        // shape-publishing sources (npy:/dir:) are priced from their
        // headers before a single row is read; the rest are measured
        // after the load, below
        if let Some((n, p)) = src.expected_dims() {
            self.check_bytes(&identity, (n as u64).saturating_mul(p as u64).saturating_mul(4))?;
        }
        // file bytes are independent of the generation knobs: normalise
        // them out so a scale/seed sweep over one CSV is one entry
        let (kscale, kseed) = if src.is_file() { (1.0, 0) } else { (scale, seed) };
        let key = DataKey {
            source: identity,
            fingerprint,
            scale_bits: kscale.to_bits(),
            seed: kseed,
            scaling,
        };
        let slot = &self.shards[shard_of(&key)];
        let mut guard = sync_ext::lock_or_recover(&slot.state);
        loop {
            if let Some(pos) = guard.entries.iter().position(|(k, _)| *k == key) {
                let entry = guard.entries.remove(pos);
                let x = entry.1.clone();
                guard.entries.insert(0, entry);
                self.hits.fetch_add(1, Ordering::Relaxed);
                return Ok((x, true));
            }
            if !guard.loading.contains(&key) {
                break;
            }
            // someone else is loading exactly this key: park until the
            // loader finishes (success -> hit above; failure -> the
            // marker is gone and we become the loader)
            guard = sync_ext::wait_or_recover(&slot.loaded_cv, guard);
        }
        // mark the key in flight and load OUTSIDE the shard lock, so a
        // slow cold load never stalls other keys on this shard; the
        // guard clears the marker and wakes waiters on every exit path
        // (success, load error, even a panicking loader)
        guard.loading.push(key.clone());
        drop(guard);
        let unmark = UnmarkOnDrop { slot, key: &key };
        let loaded = src.load(scale, seed).map(|mut d| {
            scaling.apply(&mut d);
            Arc::new(d.x)
        });
        // finish under one critical section — entry in, marker out — so
        // a woken same-key waiter can never observe "no entry, no
        // marker" after a successful load and reload it
        let mut guard = sync_ext::lock_or_recover(&slot.state);
        std::mem::forget(unmark);
        guard.loading.retain(|k| k != &key);
        slot.loaded_cv.notify_all();
        let x = loaded?;
        // refuse to pin an over-budget matrix: the error escapes before
        // the insert, the Arc drops with it, and nothing is cached
        self.check_bytes(&key.source, (x.data.len() as u64).saturating_mul(4))?;
        // a fingerprint change (edited file) makes old entries for this
        // same provenance unreachable — evict them now instead of letting
        // dead matrices squat in the LRU and inflate `entries`
        guard.entries.retain(|(k, _)| {
            k.source != key.source
                || k.scale_bits != key.scale_bits
                || k.seed != key.seed
                || k.scaling != key.scaling
        });
        guard.entries.insert(0, (key, x.clone()));
        guard.entries.truncate(self.per_shard_cap);
        self.misses.fetch_add(1, Ordering::Relaxed);
        Ok((x, false))
    }

    /// Lifetime counters plus current occupancy.
    pub fn stats(&self) -> CacheStats {
        let entries = self
            .shards
            .iter()
            .map(|s| sync_ext::lock_or_recover(&s.state).entries.len())
            .sum();
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            entries,
        }
    }

    /// Zero the hit/miss counters (the `stats reset` wire command).
    /// Resident entries are untouched — reset re-bases the counters, it
    /// does not cold-start the cache.
    pub fn reset_counters(&self) {
        self.hits.store(0, Ordering::Relaxed);
        self.misses.store(0, Ordering::Relaxed);
    }

    /// Drop every resident entry (counters untouched).  In-flight load
    /// markers are left alone — they are owned by the loaders running
    /// outside the lock, and clearing them would wedge same-key waiters.
    /// Used by tests to prove fitted-model serving needs no dataset
    /// resident, and available to embedders reclaiming memory.
    pub fn clear(&self) {
        for slot in &self.shards {
            sync_ext::lock_or_recover(&slot.state).entries.clear();
        }
    }
}

/// Clears a key's in-flight marker and wakes its waiters if the loader
/// unwinds (a panicking generator must not wedge the key forever); the
/// normal paths disarm it with `mem::forget` and clear the marker under
/// the same critical section that publishes the outcome.
struct UnmarkOnDrop<'a> {
    slot: &'a ShardSlot,
    key: &'a DataKey,
}

impl Drop for UnmarkOnDrop<'_> {
    fn drop(&mut self) {
        let mut s = sync_ext::lock_or_recover(&self.slot.state);
        s.loading.retain(|k| k != self.key);
        self.slot.loaded_cv.notify_all();
    }
}

fn shard_of(key: &DataKey) -> usize {
    use std::hash::{Hash, Hasher};
    let mut h = std::collections::hash_map::DefaultHasher::new();
    // shard on provenance, NOT the fingerprint: every fingerprint of one
    // source must land in the same shard so the miss-path eviction of a
    // stale file entry is guaranteed to find it
    key.source.hash(&mut h);
    key.scale_bits.hash(&mut h);
    key.seed.hash(&mut h);
    key.scaling.hash(&mut h);
    (h.finish() as usize) % SHARDS
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn src(uri: &str) -> DataSource {
        DataSource::parse(uri).unwrap()
    }

    fn get(cache: &DatasetCache, uri: &str, scale: f64, seed: u64) -> Result<(Arc<Matrix>, bool)> {
        cache.get_or_load(&src(uri), scale, seed, FeatureScaling::None)
    }

    fn temp_csv(tag: &str, rows: usize) -> PathBuf {
        let dir = std::env::temp_dir().join("obpam_cache_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(format!("{tag}_{}.csv", std::process::id()));
        let mut s = String::from("a,b\n");
        for i in 0..rows {
            s.push_str(&format!("{}.0,{}.5\n", i % 9, (i * 5) % 11));
        }
        std::fs::write(&path, s).unwrap();
        path
    }

    #[test]
    fn miss_then_hit_shares_one_matrix() {
        let cache = DatasetCache::new(8);
        let (a, hit_a) = get(&cache, "blobs_200_4_3", 1.0, 7).unwrap();
        let (b, hit_b) = get(&cache, "blobs_200_4_3", 1.0, 7).unwrap();
        assert!(!hit_a && hit_b);
        assert!(Arc::ptr_eq(&a, &b), "hit must return the cached allocation");
        assert_eq!(cache.stats(), CacheStats { hits: 1, misses: 1, entries: 1 });
    }

    #[test]
    fn bare_name_and_synth_scheme_share_one_entry() {
        // back-compat aliasing must not double-cache the same dataset
        let cache = DatasetCache::new(8);
        let (a, _) = get(&cache, "blobs_200_4_3", 1.0, 7).unwrap();
        let (b, hit) = get(&cache, "synth:blobs_200_4_3", 1.0, 7).unwrap();
        assert!(hit);
        assert!(Arc::ptr_eq(&a, &b));
    }

    #[test]
    fn key_is_source_scale_seed() {
        let cache = DatasetCache::new(16);
        let base = get(&cache, "blobs_200_4_3", 1.0, 7).unwrap().0;
        for (name, scale, seed) in
            [("blobs_201_4_3", 1.0, 7), ("blobs_200_4_3", 0.5, 7), ("blobs_200_4_3", 1.0, 8)]
        {
            let (x, hit) = get(&cache, name, scale, seed).unwrap();
            assert!(!hit, "{name}/{scale}/{seed} must be a distinct key");
            assert!(!Arc::ptr_eq(&base, &x));
        }
        assert_eq!(cache.stats().misses, 4);
    }

    #[test]
    fn feature_scaling_is_part_of_the_key() {
        let cache = DatasetCache::new(8);
        let source = src("blobs_200_4_3");
        let (raw, _) = cache.get_or_load(&source, 1.0, 7, FeatureScaling::None).unwrap();
        let (scaled, hit) = cache.get_or_load(&source, 1.0, 7, FeatureScaling::MinMax).unwrap();
        assert!(!hit, "minmax must be a distinct entry, not the raw matrix");
        assert!(!Arc::ptr_eq(&raw, &scaled));
        assert!(scaled.data.iter().all(|v| (0.0..=1.0).contains(v)));
    }

    #[test]
    fn file_sources_are_admitted_and_hit() {
        let path = temp_csv("admit", 20);
        let uri = format!("file:{}", path.display());
        let cache = DatasetCache::new(8);
        let (a, hit_a) = get(&cache, &uri, 1.0, 0).unwrap();
        let (b, hit_b) = get(&cache, &uri, 1.0, 0).unwrap();
        assert!(!hit_a && hit_b);
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(a.rows, 20);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn file_key_normalises_scale_and_seed() {
        // different scale/seed do not change file bytes -> one entry
        let path = temp_csv("norm", 16);
        let uri = format!("file:{}", path.display());
        let cache = DatasetCache::new(8);
        get(&cache, &uri, 1.0, 0).unwrap();
        let (_, hit) = get(&cache, &uri, 0.25, 99).unwrap();
        assert!(hit, "file keys must ignore scale/seed");
        assert_eq!(cache.stats().entries, 1);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn file_key_normalises_the_rows_hint() {
        // the ?rows= admission hint does not change the loaded bytes, so
        // hinted and hint-less spellings must share one resident copy
        let path = temp_csv("hintkey", 16);
        let cache = DatasetCache::new(8);
        let (a, _) = get(&cache, &format!("file:{}", path.display()), 1.0, 0).unwrap();
        let (b, hit) = get(&cache, &format!("file:{}?rows=16", path.display()), 1.0, 0).unwrap();
        assert!(hit, "rows hint must not split the cache key");
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(cache.stats().entries, 1);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn file_edit_invalidates_the_entry() {
        let path = temp_csv("edit", 12);
        let uri = format!("file:{}", path.display());
        let cache = DatasetCache::new(8);
        let (before, _) = get(&cache, &uri, 1.0, 0).unwrap();
        // append a row (size change -> fingerprint change regardless of
        // mtime granularity)
        let mut text = std::fs::read_to_string(&path).unwrap();
        text.push_str("8.0,8.5\n");
        std::fs::write(&path, text).unwrap();
        let (after, hit) = get(&cache, &uri, 1.0, 0).unwrap();
        assert!(!hit, "an edited file must be reloaded, not served stale");
        assert_eq!(after.rows, before.rows + 1);
        // the new fingerprint now hits, and the dead pre-edit entry was
        // evicted rather than left squatting in the LRU
        assert!(get(&cache, &uri, 1.0, 0).unwrap().1);
        assert_eq!(cache.stats().entries, 1, "stale entry must be evicted on reload");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn path_spellings_share_one_entry() {
        // file:/dir/x.csv and file:/dir/./x.csv are one provenance
        let path = temp_csv("spell", 10);
        let cache = DatasetCache::new(8);
        let (a, _) = get(&cache, &format!("file:{}", path.display()), 1.0, 0).unwrap();
        let dotted = format!(
            "file:{}/./{}",
            path.parent().unwrap().display(),
            path.file_name().unwrap().to_string_lossy()
        );
        let (b, hit) = get(&cache, &dotted, 1.0, 0).unwrap();
        assert!(hit, "aliased path spellings must not double-cache");
        assert!(Arc::ptr_eq(&a, &b));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn lru_bound_holds() {
        // cap 1 -> one entry per shard -> at most SHARDS resident no
        // matter how many distinct keys stream through
        let cache = DatasetCache::new(1);
        for seed in 0..50 {
            get(&cache, "blobs_100_4_2", 1.0, seed).unwrap();
        }
        assert!(cache.stats().entries <= SHARDS, "entries {}", cache.stats().entries);
        assert_eq!(cache.stats().misses, 50);
    }

    #[test]
    fn eviction_is_least_recently_used() {
        // With per-shard cap 1, two same-shard keys evict each other; a
        // re-request of the first must reload.  Streaming the same key
        // repeatedly must not (it stays most-recent).
        let cache = DatasetCache::new(1);
        for _ in 0..5 {
            get(&cache, "blobs_100_4_2", 1.0, 1).unwrap();
        }
        let s = cache.stats();
        assert_eq!((s.misses, s.hits), (1, 4));
    }

    #[test]
    fn concurrent_cold_burst_loads_exactly_once() {
        // 8 threads race on one cold key: the in-flight marker must
        // collapse the burst to a single load, with every caller handed
        // the same allocation (7 hits, 1 miss)
        let cache = std::sync::Arc::new(DatasetCache::new(8));
        let barrier = std::sync::Arc::new(std::sync::Barrier::new(8));
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let cache = cache.clone();
                let barrier = barrier.clone();
                std::thread::spawn(move || {
                    barrier.wait();
                    let (x, _) = cache
                        .get_or_load(
                            &DataSource::parse("blobs_400_4_3").unwrap(),
                            1.0,
                            3,
                            FeatureScaling::None,
                        )
                        .unwrap();
                    x
                })
            })
            .collect();
        let mats: Vec<Arc<Matrix>> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        for m in &mats[1..] {
            assert!(Arc::ptr_eq(&mats[0], m), "burst must share one allocation");
        }
        let s = cache.stats();
        assert_eq!((s.misses, s.hits, s.entries), (1, 7, 1));
    }

    #[test]
    fn failed_load_unblocks_same_key_waiters() {
        // a failing key must not wedge later requests for it (the
        // marker is cleared and the next caller retries)
        let cache = DatasetCache::new(8);
        for _ in 0..3 {
            assert!(get(&cache, "doesnotexist", 1.0, 0).is_err());
        }
        assert_eq!(cache.stats(), CacheStats::default());
        // a real key on the same cache still works afterwards
        assert!(get(&cache, "blobs_100_4_2", 1.0, 0).is_ok());
    }

    #[test]
    fn reset_counters_keeps_entries() {
        let cache = DatasetCache::new(8);
        get(&cache, "blobs_200_4_3", 1.0, 7).unwrap();
        get(&cache, "blobs_200_4_3", 1.0, 7).unwrap();
        cache.reset_counters();
        let s = cache.stats();
        assert_eq!((s.hits, s.misses), (0, 0));
        assert_eq!(s.entries, 1, "reset re-bases counters, it does not evict");
        // the resident entry still hits
        assert!(get(&cache, "blobs_200_4_3", 1.0, 7).unwrap().1);
    }

    #[test]
    fn clear_drops_entries_but_keeps_counters() {
        let cache = DatasetCache::new(8);
        get(&cache, "blobs_200_4_3", 1.0, 7).unwrap();
        get(&cache, "blobs_200_4_3", 1.0, 7).unwrap();
        cache.clear();
        let s = cache.stats();
        assert_eq!(s.entries, 0, "clear evicts everything");
        assert_eq!((s.hits, s.misses), (1, 1), "clear re-bases nothing");
        // the next request is a cold miss, and the cache still works
        assert!(!get(&cache, "blobs_200_4_3", 1.0, 7).unwrap().1);
    }

    #[test]
    fn failures_are_not_cached() {
        let cache = DatasetCache::new(8);
        assert!(get(&cache, "doesnotexist", 1.0, 0).is_err());
        assert!(get(&cache, "file:/definitely/not/here.csv", 1.0, 0).is_err());
        assert_eq!(cache.stats(), CacheStats { hits: 0, misses: 0, entries: 0 });
    }

    #[test]
    fn byte_limit_refuses_oversized_loads() {
        // blobs_200_4_3 is 200*4*4 = 3200 feature bytes; synth shapes
        // are not knowable pre-load, so this exercises the post-load
        // refusal: the matrix is measured, rejected, and never pinned
        let cache = DatasetCache::with_byte_limit(8, 1000);
        let err = get(&cache, "blobs_200_4_3", 1.0, 7).unwrap_err().to_string();
        assert!(err.contains("bytes=3200"), "{err}");
        assert!(err.contains("cache byte limit"), "{err}");
        assert_eq!(cache.stats(), CacheStats::default(), "refusals cache and count nothing");
        // a dataset under the limit (50*4*4 = 800 bytes) still loads
        let (x, hit) = get(&cache, "blobs_50_4_2", 1.0, 7).unwrap();
        assert!(!hit);
        assert_eq!(x.rows, 50);
        assert_eq!(cache.stats().entries, 1);
    }

    #[test]
    fn npy_over_limit_is_refused_before_any_row_is_read() {
        let dir = std::env::temp_dir().join("obpam_cache_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(format!("bytelimit_{}.npy", std::process::id()));
        let mut rng = crate::rng::Rng::new(5);
        let x = Matrix::from_vec(100, 6, (0..600).map(|_| rng.f32()).collect());
        crate::data::npy::write_npy(&path, &x).unwrap();
        // truncate the payload: the byte-limit refusal must fire on the
        // header's shape alone, before the loader would ever reach its
        // own "truncated npy" error
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 64]).unwrap();
        let cache = DatasetCache::with_byte_limit(8, 1000);
        let err =
            get(&cache, &format!("npy:{}", path.display()), 1.0, 0).unwrap_err().to_string();
        assert!(err.contains("bytes=2400"), "{err}");
        assert_eq!(cache.stats(), CacheStats::default());
        std::fs::remove_file(&path).ok();
    }
}
