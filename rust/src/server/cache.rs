//! Sharded, LRU-bounded dataset cache (server protocol v2).
//!
//! Keyed by `(dataset, scale, seed)` — exactly the inputs that determine
//! a generated matrix — and holding `Arc<Matrix>` values so concurrent
//! jobs share one copy with zero cloning.  [`SHARDS`] independent locks
//! keep requests for different datasets from serializing on one mutex.
//!
//! A shard generates a missing dataset *while holding its lock*: a burst
//! of identical requests costs exactly one generation (no thundering
//! herd), at the price of briefly blocking other keys that hash to the
//! same shard.  Generation failures (unknown dataset names) are returned
//! to the caller and never cached.

use crate::data::synth;
use crate::linalg::Matrix;
use anyhow::Result;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Number of independently locked shards.
pub const SHARDS: usize = 8;

/// Cache key: the full provenance of a generated dataset.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
struct DataKey {
    dataset: String,
    /// `f64::to_bits` of the scale (`f64` itself is not `Eq`/`Hash`).
    scale_bits: u64,
    seed: u64,
}

/// One shard: entries kept in most-recently-used-first order (caches are
/// small — `cache_cap` datasets total — so a scan beats a linked map).
struct Shard {
    entries: Vec<(DataKey, Arc<Matrix>)>,
}

/// Sharded dataset cache; see the module docs.
pub struct DatasetCache {
    shards: Vec<Mutex<Shard>>,
    per_shard_cap: usize,
    hits: AtomicU64,
    misses: AtomicU64,
}

/// Hit/miss/occupancy snapshot (served by the `stats` wire command).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Requests served from the cache.
    pub hits: u64,
    /// Requests that had to generate (== total generations ever run).
    pub misses: u64,
    /// Datasets currently resident.
    pub entries: usize,
}

impl DatasetCache {
    /// Cache bounded to ~`cap` datasets total: the budget is split
    /// evenly across [`SHARDS`] shards (rounded up, at least one entry
    /// per shard), each evicting least-recently-used first.
    pub fn new(cap: usize) -> Self {
        DatasetCache {
            shards: (0..SHARDS).map(|_| Mutex::new(Shard { entries: Vec::new() })).collect(),
            per_shard_cap: cap.div_ceil(SHARDS).max(1),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    /// Fetch the dataset for `(dataset, scale, seed)`, generating it on a
    /// miss.  Returns the shared matrix and whether it was a cache hit.
    pub fn get_or_generate(
        &self,
        dataset: &str,
        scale: f64,
        seed: u64,
    ) -> Result<(Arc<Matrix>, bool)> {
        let key = DataKey { dataset: dataset.to_string(), scale_bits: scale.to_bits(), seed };
        let shard = &self.shards[shard_of(&key)];
        let mut guard = shard.lock().unwrap_or_else(|e| e.into_inner());
        if let Some(pos) = guard.entries.iter().position(|(k, _)| *k == key) {
            let entry = guard.entries.remove(pos);
            let x = entry.1.clone();
            guard.entries.insert(0, entry);
            self.hits.fetch_add(1, Ordering::Relaxed);
            return Ok((x, true));
        }
        let x = Arc::new(synth::try_generate(dataset, scale, seed)?.x);
        guard.entries.insert(0, (key, x.clone()));
        guard.entries.truncate(self.per_shard_cap);
        self.misses.fetch_add(1, Ordering::Relaxed);
        Ok((x, false))
    }

    /// Lifetime counters plus current occupancy.
    pub fn stats(&self) -> CacheStats {
        let entries = self
            .shards
            .iter()
            .map(|s| s.lock().unwrap_or_else(|e| e.into_inner()).entries.len())
            .sum();
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            entries,
        }
    }
}

fn shard_of(key: &DataKey) -> usize {
    use std::hash::{Hash, Hasher};
    let mut h = std::collections::hash_map::DefaultHasher::new();
    key.hash(&mut h);
    (h.finish() as usize) % SHARDS
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn miss_then_hit_shares_one_matrix() {
        let cache = DatasetCache::new(8);
        let (a, hit_a) = cache.get_or_generate("blobs_200_4_3", 1.0, 7).unwrap();
        let (b, hit_b) = cache.get_or_generate("blobs_200_4_3", 1.0, 7).unwrap();
        assert!(!hit_a && hit_b);
        assert!(Arc::ptr_eq(&a, &b), "hit must return the cached allocation");
        assert_eq!(cache.stats(), CacheStats { hits: 1, misses: 1, entries: 1 });
    }

    #[test]
    fn key_is_dataset_scale_seed() {
        let cache = DatasetCache::new(16);
        let base = cache.get_or_generate("blobs_200_4_3", 1.0, 7).unwrap().0;
        for (name, scale, seed) in
            [("blobs_201_4_3", 1.0, 7), ("blobs_200_4_3", 0.5, 7), ("blobs_200_4_3", 1.0, 8)]
        {
            let (x, hit) = cache.get_or_generate(name, scale, seed).unwrap();
            assert!(!hit, "{name}/{scale}/{seed} must be a distinct key");
            assert!(!Arc::ptr_eq(&base, &x));
        }
        assert_eq!(cache.stats().misses, 4);
    }

    #[test]
    fn lru_bound_holds() {
        // cap 1 -> one entry per shard -> at most SHARDS resident no
        // matter how many distinct keys stream through
        let cache = DatasetCache::new(1);
        for seed in 0..50 {
            cache.get_or_generate("blobs_100_4_2", 1.0, seed).unwrap();
        }
        assert!(cache.stats().entries <= SHARDS, "entries {}", cache.stats().entries);
        assert_eq!(cache.stats().misses, 50);
    }

    #[test]
    fn eviction_is_least_recently_used() {
        // With per-shard cap 1, two same-shard keys evict each other; a
        // re-request of the first must regenerate.  Streaming the same
        // key repeatedly must not (it stays most-recent).
        let cache = DatasetCache::new(1);
        for _ in 0..5 {
            cache.get_or_generate("blobs_100_4_2", 1.0, 1).unwrap();
        }
        let s = cache.stats();
        assert_eq!((s.misses, s.hits), (1, 4));
    }

    #[test]
    fn failures_are_not_cached() {
        let cache = DatasetCache::new(8);
        assert!(cache.get_or_generate("doesnotexist", 1.0, 0).is_err());
        assert_eq!(cache.stats(), CacheStats { hits: 0, misses: 0, entries: 0 });
    }
}
