//! The evented accept core (protocol v8): one readiness-driven loop
//! thread multiplexes every client connection over `poll(2)`, so an
//! idle or parked connection costs a registry entry — never an OS
//! thread.  This replaces v5–v7's thread-per-connection accept path.
//!
//! # Design
//!
//! * **Nonblocking discipline** — the listener, every accepted stream
//!   and the self-pipe are nonblocking; the loop's only blocking call
//!   is `poll(2)` itself, via the thin libc shim in [`sys`] (no async
//!   runtime, no external crate).
//! * **Per-connection state machines** — each [`Conn`] owns a read
//!   buffer (bytes in, split on `\n`), a write buffer (reply bytes
//!   out, drained as the socket accepts them) and a FIFO of
//!   [`Pending`] requests.  Replies flush strictly in request order,
//!   so **pipelining** works: a client may write multiple request
//!   lines before reading any reply and receives the replies in
//!   submission order, each with its own `queue_ms=`/`served_ms=`
//!   trailer.  Reply bytes are identical to the v7 one-line-per-
//!   connection shape — a v1 client that sends one line and reads one
//!   line sees nothing new.
//! * **On-loop vs on-worker verbs** — cheap verbs (`ping`, `submit`,
//!   `poll`, `cancel`, `jobs`, `stats`, `promote`, `assign`, `models`,
//!   `evict`) dispatch synchronously on the loop through
//!   [`super::dispatch_line`] (the `assign` path reuses the per-model
//!   [`super::models::AssignScratch`], so serving stays allocation-
//!   free).  `cluster` and `submit`ted solves hand off to the solver-
//!   worker fleet exactly as before; `wait`/`cluster` replies park as
//!   [`PendingState::WaitJob`]/[`PendingState::ClusterJob`] instead of
//!   blocking a thread, and `sleep` parks as a timer entry.
//! * **Timer wheel** — caller timeouts (`wait timeout_ms=`), queued-job
//!   deadlines (`deadline_ms=`, via [`super::jobs::JobRegistry::probe`]'s
//!   shed instant) and `sleep` expiries all live in one ordered map;
//!   the poll timeout is the distance to the nearest entry.  Stale
//!   entries (request already resolved, connection gone) are skipped
//!   when they fire.
//! * **Self-pipe wakeup invariant** — job completion must never leave a
//!   parked connection unresolved: every [`super::jobs::JobRegistry`]
//!   state broadcast also fires the [`WakePipe`] waker installed at
//!   startup, and the loop drains the pipe then resolves the ids from
//!   `take_terminal_events()`.  Parking is race-free because the loop
//!   probes the job *on the loop thread* before parking: a terminal
//!   transition either lands before the probe (request resolves
//!   immediately) or after it (the event is still queued for the next
//!   drain, since only the loop drains events).
//! * **Backpressure** — connections are admitted up to
//!   [`super::ServerConfig::conn_cap`] (beyond it: `err queue full`,
//!   close); `sleep` holds one of `queue_cap` diagnostic slots so the
//!   v4 burst-backpressure contract (`err queue full` rejections under
//!   a sleep burst) is preserved without any connection thread; a
//!   writer that makes no progress for [`WRITE_STALL`] while bytes are
//!   buffered is shed.  Read-closed connections with nothing in flight
//!   are dropped immediately.
//!
//! Shutdown mirrors the old join semantics: once
//! [`super::ServerHandle::shutdown`] sets the stop flag, the loop stops
//! admitting work, keeps running until every pending reply has resolved
//! and flushed (workers drain the job queue, so every parked request
//! terminates), then exits and the handle joins it.

use super::metrics::ConnCounters;
use super::ServerState;
use std::collections::{BTreeMap, HashMap, VecDeque};
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::os::raw::c_int;
use std::os::unix::io::AsRawFd;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Shed a connection whose write buffer made no progress for this long
/// (the evented successor of the old per-thread write timeout).
const WRITE_STALL: Duration = Duration::from_secs(10);

/// Read-buffer bound: a request line may not exceed this (defensive —
/// the old `read_line` path was unbounded; real lines are tiny, large
/// `assign` batches are well under it).
const LINE_CAP: usize = 4 << 20;

/// Write-buffer bound per connection: a reader this far behind its own
/// pipelined replies is shed rather than buffered without limit.
const WBUF_CAP: usize = 16 << 20;

/// Thin libc shim: `poll(2)` and a self-pipe, declared by hand so the
/// event loop needs no external crate and no async runtime.
mod sys {
    use std::os::raw::{c_int, c_ulong};

    /// `struct pollfd` from poll(2) — layout fixed by the C ABI.
    #[repr(C)]
    pub struct PollFd {
        pub fd: c_int,
        pub events: i16,
        pub revents: i16,
    }

    pub const POLLIN: i16 = 0x001;
    pub const POLLOUT: i16 = 0x004;
    pub const POLLERR: i16 = 0x008;
    pub const POLLHUP: i16 = 0x010;

    const F_GETFL: c_int = 3;
    const F_SETFL: c_int = 4;
    const O_NONBLOCK: c_int = 0o4000;

    extern "C" {
        fn poll(fds: *mut PollFd, nfds: c_ulong, timeout: c_int) -> c_int;
        fn pipe(fds: *mut c_int) -> c_int;
        fn read(fd: c_int, buf: *mut u8, count: usize) -> isize;
        fn write(fd: c_int, buf: *const u8, count: usize) -> isize;
        fn close(fd: c_int) -> c_int;
        fn fcntl(fd: c_int, cmd: c_int, arg: c_int) -> c_int;
    }

    /// poll(2) over `fds` for up to `timeout_ms` (-1 = forever).  A
    /// negative return (EINTR and friends) is treated as "no fd ready"
    /// — the loop just re-polls.
    pub fn poll_fds(fds: &mut [PollFd], timeout_ms: c_int) {
        // SAFETY: `fds` is a valid, exclusively borrowed slice of
        // repr(C) pollfd structs for the duration of the call; the
        // kernel reads fd/events and writes revents within its bounds.
        let _ = unsafe { poll(fds.as_mut_ptr(), fds.len() as c_ulong, timeout_ms) };
    }

    /// pipe(2) with both ends switched to `O_NONBLOCK`; returns
    /// `(read_fd, write_fd)`.
    pub fn nonblocking_pipe() -> std::io::Result<(c_int, c_int)> {
        let mut fds = [0 as c_int; 2];
        // SAFETY: `fds` is a valid 2-element int array the kernel
        // fills with the two pipe descriptors on success.
        if unsafe { pipe(fds.as_mut_ptr()) } != 0 {
            return Err(std::io::Error::last_os_error());
        }
        for fd in fds {
            // SAFETY: `fd` was just returned by pipe(2) and is owned
            // by this function; F_GETFL reads the status flags.
            let flags = unsafe { fcntl(fd, F_GETFL, 0) };
            // SAFETY: as above; F_SETFL only toggles status flags.
            if flags < 0 || unsafe { fcntl(fd, F_SETFL, flags | O_NONBLOCK) } < 0 {
                let err = std::io::Error::last_os_error();
                close_fd(fds[0]);
                close_fd(fds[1]);
                return Err(err);
            }
        }
        Ok((fds[0], fds[1]))
    }

    /// Best-effort one-byte write (the self-pipe wake).  A full pipe is
    /// fine: the loop is already due to wake and drain it.
    pub fn write_byte(fd: c_int) {
        let b = [1u8];
        // SAFETY: `b` is a valid 1-byte buffer for the call; `fd` is a
        // live pipe write end owned by the caller's WakePipe.
        let _ = unsafe { write(fd, b.as_ptr(), 1) };
    }

    /// Drain every buffered byte from a nonblocking read end; returns
    /// whether at least one byte was read (a wakeup was consumed).
    pub fn drain_fd(fd: c_int) -> bool {
        let mut buf = [0u8; 64];
        let mut any = false;
        loop {
            // SAFETY: `buf` is a valid buffer of the stated length for
            // the call; `fd` is a live nonblocking pipe read end owned
            // by the caller's WakePipe.
            let n = unsafe { read(fd, buf.as_mut_ptr(), buf.len()) };
            if n <= 0 {
                return any; // 0 = closed, <0 = EAGAIN/EINTR: drained
            }
            any = true;
        }
    }

    /// close(2); callers own the descriptor and close it at most once.
    pub fn close_fd(fd: c_int) {
        // SAFETY: `fd` is an owned, still-open descriptor (WakePipe
        // closes each end exactly once, on drop).
        let _ = unsafe { close(fd) };
    }
}

/// The self-pipe: `wake()` (any thread) makes the loop's `poll(2)`
/// return; the loop `drain()`s it before resolving job events.  Owns
/// both descriptors and closes them on drop.
pub(crate) struct WakePipe {
    rfd: c_int,
    wfd: c_int,
}

impl WakePipe {
    fn new() -> std::io::Result<Self> {
        let (rfd, wfd) = sys::nonblocking_pipe()?;
        Ok(WakePipe { rfd, wfd })
    }

    /// Make the loop's poll return (called from worker threads through
    /// the registry waker; write errors are ignored by design — a full
    /// pipe already guarantees a pending wakeup).
    pub(crate) fn wake(&self) {
        sys::write_byte(self.wfd);
    }

    /// Consume buffered wakeups; `true` when at least one was pending.
    fn drain(&self) -> bool {
        sys::drain_fd(self.rfd)
    }
}

impl Drop for WakePipe {
    fn drop(&mut self) {
        sys::close_fd(self.rfd);
        sys::close_fd(self.wfd);
    }
}

/// Why a parked request is still unresolved (or its finished reply).
enum PendingState {
    /// Reply line fully formatted (trailer appended); waiting for its
    /// turn in the connection's in-order flush.
    Ready(Vec<u8>),
    /// A `wait` parked on a job: resolved by the job's terminal event,
    /// the caller's `timeout_ms=` timer, or the queued-job deadline
    /// timer — whichever fires first.
    WaitJob {
        id: u64,
        timeout_deadline: Option<Instant>,
    },
    /// A `cluster` solve handed to the worker fleet; resolved by the
    /// job's terminal event (or its queued-deadline shed).
    ClusterJob { id: u64 },
    /// A `sleep ms=` diagnostic holding one of `queue_cap` slots until
    /// its timer fires.
    Sleep { ms: u64 },
}

/// One request a connection has submitted and not yet been answered.
struct Pending {
    /// Per-connection submission order; replies flush in `seq` order.
    seq: u64,
    state: PendingState,
    /// Dispatch time — `served_ms=` measures from here to resolution,
    /// so a parked `wait` reports its park time just like the blocking
    /// path did.
    started: Instant,
    /// The request's connection-level dispatch wait, for replies whose
    /// trailer carries it (timeouts, errors, `sleep`).
    queue_ms: f64,
}

/// One multiplexed client connection.
struct Conn {
    stream: TcpStream,
    rbuf: Vec<u8>,
    wbuf: Vec<u8>,
    pending: VecDeque<Pending>,
    next_seq: u64,
    /// Requests parsed on this connection (the second and later ones
    /// count as pipelined).
    reqs: u64,
    /// Peer sent EOF (or a blank line, the v7 close signal): no more
    /// requests, the connection drops once its replies flush.
    closed_read: bool,
    /// Baseline for the next request's `queue_ms=`: accept time, then
    /// reset after each parsed line.
    dispatch_from: Instant,
    /// Last instant the write buffer made progress (or was appended
    /// to); a stall past [`WRITE_STALL`] sheds the connection.
    last_progress: Instant,
}

/// Start the evented accept core; returns the loop's join handle.
/// Installs the registry waker (job completion -> self-pipe -> poll
/// wakeup) before the loop starts, so no terminal transition can
/// predate the wakeup path.
pub(crate) fn spawn(
    listener: TcpListener,
    state: Arc<ServerState>,
    stop: Arc<AtomicBool>,
    conn_cap: usize,
    queue_cap: usize,
) -> std::io::Result<std::thread::JoinHandle<()>> {
    listener.set_nonblocking(true)?;
    let pipe = Arc::new(WakePipe::new()?);
    let waker = pipe.clone();
    state.jobs.set_waker(Arc::new(move || waker.wake()));
    // tidy:allow(thread-spawn) — the evented accept core: the one
    // long-lived loop thread, owned and joined by ServerHandle::shutdown.
    Ok(std::thread::spawn(move || {
        EventLoop {
            listener,
            state,
            stop,
            conn_cap,
            queue_cap,
            pipe,
            registry: HashMap::new(),
            next_conn: 0,
            timers: BTreeMap::new(),
            next_tick: 0,
            waiters: HashMap::new(),
            sleep_active: 0,
        }
        .run();
    }))
}

/// What a fired timer found its pending request doing.
enum Fired {
    Sleep(u64, f64),
    Wait(u64, Option<Instant>, f64),
    Cluster(u64, f64),
}

struct EventLoop {
    listener: TcpListener,
    state: Arc<ServerState>,
    stop: Arc<AtomicBool>,
    conn_cap: usize,
    /// The `sleep` diagnostic's slot bound (the v4 burst-backpressure
    /// contract: at most this many concurrent sleeps, the rest get
    /// `err queue full`).
    queue_cap: usize,
    pipe: Arc<WakePipe>,
    registry: HashMap<usize, Conn>,
    next_conn: usize,
    /// The timer wheel: fire instant (+ a unique tick breaking ties)
    /// -> the parked request to revisit.  Entries are one-shot and may
    /// be stale — firing checks the pending's live state.
    timers: BTreeMap<(Instant, u64), (usize, u64)>,
    next_tick: u64,
    /// Job id -> parked requests to resolve on its terminal event.
    waiters: HashMap<u64, Vec<(usize, u64)>>,
    /// Live `sleep` slots (see `queue_cap`).
    sleep_active: usize,
}

impl EventLoop {
    fn run(mut self) {
        loop {
            self.process_terminal_events();
            if self.stop.load(Ordering::SeqCst) && self.shutdown_drained() {
                break;
            }
            let timeout = self.next_timeout();
            let (accept_ready, pipe_ready, ready) = self.poll_ready(timeout);
            if pipe_ready && self.pipe.drain() {
                self.conns().record_wakeup();
            }
            self.process_terminal_events();
            if accept_ready {
                self.accept_ready();
            }
            for (id, readable, writable) in ready {
                if writable {
                    self.flush_conn(id);
                }
                if readable {
                    self.handle_readable(id);
                }
            }
            self.fire_timers();
            self.shed_stalled();
        }
    }

    fn conns(&self) -> &ConnCounters {
        &self.state.conns
    }

    /// Poll timeout: distance to the nearest timer or write-stall
    /// deadline, rounded up a millisecond; -1 (forever) when neither
    /// exists — accept, readable bytes and the self-pipe wake us.
    fn next_timeout(&self) -> c_int {
        let mut deadline: Option<Instant> = self.timers.keys().next().map(|&(at, _)| at);
        for conn in self.registry.values() {
            if !conn.wbuf.is_empty() {
                let stall = conn.last_progress + WRITE_STALL;
                deadline = Some(deadline.map_or(stall, |d| d.min(stall)));
            }
        }
        match deadline {
            None => -1,
            Some(at) => {
                let ms = at.saturating_duration_since(Instant::now()).as_millis();
                ms.saturating_add(1).min(60_000) as c_int
            }
        }
    }

    /// One poll(2) round: which of (listener, self-pipe, connections)
    /// are ready.  Connections that are read-closed with an empty write
    /// buffer are left out of the set — they are waiting on job events
    /// or timers, not on IO (this also keeps a hung-up peer from
    /// busy-spinning the loop via level-triggered POLLHUP).
    fn poll_ready(&mut self, timeout_ms: c_int) -> (bool, bool, Vec<(usize, bool, bool)>) {
        let mut fds = Vec::with_capacity(2 + self.registry.len());
        fds.push(sys::PollFd { fd: self.listener.as_raw_fd(), events: sys::POLLIN, revents: 0 });
        fds.push(sys::PollFd { fd: self.pipe.rfd, events: sys::POLLIN, revents: 0 });
        let mut ids = Vec::with_capacity(self.registry.len());
        for (&id, conn) in &self.registry {
            let mut events = 0i16;
            if !conn.closed_read {
                events |= sys::POLLIN;
            }
            if !conn.wbuf.is_empty() {
                events |= sys::POLLOUT;
            }
            if events == 0 {
                continue;
            }
            ids.push(id);
            fds.push(sys::PollFd { fd: conn.stream.as_raw_fd(), events, revents: 0 });
        }
        sys::poll_fds(&mut fds, timeout_ms);
        let accept_ready = fds[0].revents != 0;
        let pipe_ready = fds[1].revents != 0;
        let mut ready = Vec::new();
        for (i, &id) in ids.iter().enumerate() {
            let revents = fds[i + 2].revents;
            if revents == 0 {
                continue;
            }
            let readable = revents & (sys::POLLIN | sys::POLLHUP | sys::POLLERR) != 0;
            let writable = revents & sys::POLLOUT != 0;
            ready.push((id, readable, writable));
        }
        (accept_ready, pipe_ready, ready)
    }

    /// Resolve every request parked on a job that reached a terminal
    /// state since the last drain.
    fn process_terminal_events(&mut self) {
        for id in self.state.jobs.take_terminal_events() {
            if let Some(parked) = self.waiters.remove(&id) {
                for (conn_id, seq) in parked {
                    self.resolve_job_waiter(conn_id, seq);
                }
            }
        }
    }

    /// Re-probe one parked request's job and resolve it if terminal.
    /// Stale targets (request already resolved, connection gone) are
    /// skipped.
    fn resolve_job_waiter(&mut self, conn_id: usize, seq: u64) {
        let target = self.registry.get(&conn_id).and_then(|conn| {
            conn.pending.iter().find(|p| p.seq == seq).and_then(|p| match p.state {
                PendingState::WaitJob { id, .. } => Some((id, false, p.queue_ms)),
                PendingState::ClusterJob { id } => Some((id, true, p.queue_ms)),
                _ => None,
            })
        });
        let Some((id, is_cluster, req_queue_ms)) = target else { return };
        match self.state.jobs.probe(id) {
            None => {
                // evicted before this connection read its reply — the
                // same line the blocking paths produced
                let reply = if is_cluster {
                    format!("err job j{id} evicted before its reply was read")
                } else {
                    format!("err unknown job j{id}")
                };
                self.resolve(conn_id, seq, reply, req_queue_ms);
            }
            Some((v, _)) if v.state.is_terminal() => {
                let reply = v.result.unwrap_or_else(|| format!("err job j{id} lost its result"));
                self.resolve(conn_id, seq, reply, v.queue_ms);
            }
            Some(_) => {} // not terminal: spurious event, stay parked
        }
    }

    /// Accept every pending connection: admitted up to `conn_cap`,
    /// rejected with `err queue full` beyond it, dropped unread once
    /// the stop flag is set (the shutdown dummy-connect lands here).
    fn accept_ready(&mut self) {
        loop {
            match self.listener.accept() {
                Ok((mut stream, _)) => {
                    if self.stop.load(Ordering::SeqCst) {
                        continue;
                    }
                    if self.registry.len() >= self.conn_cap {
                        // accepted streams don't inherit the listener's
                        // nonblocking flag, so this small write is safe
                        let _ = writeln!(stream, "err queue full");
                        continue;
                    }
                    if stream.set_nonblocking(true).is_err() {
                        continue;
                    }
                    let id = self.next_conn;
                    self.next_conn += 1;
                    let now = Instant::now();
                    self.registry.insert(
                        id,
                        Conn {
                            stream,
                            rbuf: Vec::new(),
                            wbuf: Vec::new(),
                            pending: VecDeque::new(),
                            next_seq: 0,
                            reqs: 0,
                            closed_read: false,
                            dispatch_from: now,
                            last_progress: now,
                        },
                    );
                    self.conns().conn_opened();
                }
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(_) => break, // WouldBlock (or a transient accept error)
            }
        }
    }

    /// Drain readable bytes into the connection's buffer, then parse
    /// and dispatch every complete line.
    fn handle_readable(&mut self, conn_id: usize) {
        let mut buf = [0u8; 8192];
        let broken = loop {
            let Some(conn) = self.registry.get_mut(&conn_id) else { return };
            if conn.closed_read {
                break false;
            }
            match conn.stream.read(&mut buf) {
                Ok(0) => {
                    conn.closed_read = true;
                    break false;
                }
                Ok(n) => {
                    conn.rbuf.extend_from_slice(&buf[..n]);
                    if conn.rbuf.len() > LINE_CAP {
                        break true; // no line this long is legitimate
                    }
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break false,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(_) => break true,
            }
        };
        if broken {
            self.drop_conn(conn_id);
            return;
        }
        self.parse_requests(conn_id);
        // flush handles the nothing-in-flight EOF drop too
        self.flush_conn(conn_id);
    }

    /// Split the read buffer on newlines and dispatch each request.
    fn parse_requests(&mut self, conn_id: usize) {
        enum Next {
            Line(String),
            Blank,
            Bad,
            Incomplete,
        }
        loop {
            let next = {
                let Some(conn) = self.registry.get_mut(&conn_id) else { return };
                match conn.rbuf.iter().position(|&b| b == b'\n') {
                    None => Next::Incomplete,
                    Some(pos) => {
                        let raw: Vec<u8> = conn.rbuf.drain(..=pos).collect();
                        match std::str::from_utf8(&raw) {
                            Ok(s) if s.trim().is_empty() => Next::Blank,
                            Ok(s) => Next::Line(s.trim().to_string()),
                            Err(_) => Next::Bad,
                        }
                    }
                }
            };
            match next {
                Next::Incomplete => return,
                // non-UTF-8 input: the old read_line path closed the
                // connection without a reply; do the same
                Next::Bad => {
                    self.drop_conn(conn_id);
                    return;
                }
                // a blank line closed the old per-connection path with
                // no reply; treat it as the peer's end-of-requests
                Next::Blank => {
                    if let Some(conn) = self.registry.get_mut(&conn_id) {
                        conn.closed_read = true;
                        conn.rbuf.clear();
                    }
                    return;
                }
                Next::Line(line) => self.dispatch_request(conn_id, &line),
            }
        }
    }

    /// Dispatch one request line: park the verbs that used to block a
    /// connection thread (`wait`, `cluster`, `sleep`), run everything
    /// else synchronously on the loop through [`super::dispatch_line`].
    fn dispatch_request(&mut self, conn_id: usize, line: &str) {
        let (seq, started, queue_ms) = {
            let Some(conn) = self.registry.get_mut(&conn_id) else { return };
            let queue_ms = conn.dispatch_from.elapsed().as_secs_f64() * 1e3;
            conn.dispatch_from = Instant::now();
            conn.reqs += 1;
            if conn.reqs > 1 {
                self.state.conns.record_pipelined();
            }
            let seq = conn.next_seq;
            conn.next_seq += 1;
            (seq, Instant::now(), queue_ms)
        };
        // peek the verb to intercept the parking ones; a tokenize error
        // falls through to dispatch_line, which reproduces the exact
        // `err unterminated ...` reply
        if let Ok(parts) = super::tokenize(line) {
            match parts.first().map(String::as_str) {
                Some("wait") => {
                    self.state.verbs.record("wait");
                    let kv = super::parse_kv(&parts[1..]);
                    self.dispatch_wait(conn_id, seq, started, queue_ms, &kv);
                    return;
                }
                Some("cluster") if self.state.jobs.has_workers() => {
                    self.state.verbs.record("cluster");
                    let kv = super::parse_kv(&parts[1..]);
                    self.dispatch_cluster(conn_id, seq, started, queue_ms, &kv);
                    return;
                }
                Some("sleep") => {
                    self.state.verbs.record("sleep");
                    let kv = super::parse_kv(&parts[1..]);
                    self.dispatch_sleep(conn_id, seq, started, queue_ms, &kv);
                    return;
                }
                _ => {}
            }
        }
        let (reply, trailer_queue_ms) = super::dispatch_line(&self.state, line, queue_ms);
        self.push_ready(conn_id, seq, started, reply, trailer_queue_ms);
    }

    /// The `wait` verb, evented: identical validation and replies to
    /// [`super::handle_wait`], but parking is a registry entry plus
    /// timers instead of a blocked condvar.
    fn dispatch_wait(
        &mut self,
        conn_id: usize,
        seq: u64,
        started: Instant,
        queue_ms: f64,
        kv: &HashMap<String, String>,
    ) {
        let id = match super::parse_job_id(kv) {
            Ok(id) => id,
            Err(e) => {
                self.push_ready(conn_id, seq, started, format!("err {e}"), queue_ms);
                return;
            }
        };
        let timeout: Option<u64> = match super::parse_key(kv, "timeout_ms") {
            Ok(t) => t,
            Err(e) => {
                self.push_ready(conn_id, seq, started, format!("err {e}"), queue_ms);
                return;
            }
        };
        // kept for reply fidelity with handle_wait; unreachable under
        // serve() (a serving state always has workers)
        if timeout.is_none() && !self.state.jobs.has_workers() {
            match self.state.jobs.poll(id) {
                None => {
                    self.push_ready(conn_id, seq, started, format!("err unknown job j{id}"), queue_ms);
                    return;
                }
                Some(v) if !v.state.is_terminal() => {
                    self.push_ready(
                        conn_id,
                        seq,
                        started,
                        "err wait needs timeout_ms= (no workers are draining jobs)".into(),
                        queue_ms,
                    );
                    return;
                }
                Some(_) => {}
            }
        }
        match self.state.jobs.probe(id) {
            None => self.push_ready(conn_id, seq, started, format!("err unknown job j{id}"), queue_ms),
            Some((v, _)) if v.state.is_terminal() => {
                let reply = v.result.unwrap_or_else(|| format!("err job j{id} lost its result"));
                self.push_ready(conn_id, seq, started, reply, v.queue_ms);
            }
            Some((_, shed_at)) => {
                let timeout_deadline = timeout.map(|t| started + Duration::from_millis(t));
                self.park(
                    conn_id,
                    seq,
                    started,
                    queue_ms,
                    PendingState::WaitJob { id, timeout_deadline },
                );
                self.waiters.entry(id).or_default().push((conn_id, seq));
                self.conns().waiter_parked();
                if let Some(at) = timeout_deadline {
                    self.arm_timer(at, conn_id, seq);
                }
                if let Some(at) = shed_at {
                    self.arm_timer(at, conn_id, seq);
                }
            }
        }
    }

    /// The `cluster` verb, evented: submit through the registry as
    /// before ([`super::cluster_via_jobs`]' submit+wait pair), but the
    /// unbounded wait parks on the loop.
    fn dispatch_cluster(
        &mut self,
        conn_id: usize,
        seq: u64,
        started: Instant,
        queue_ms: f64,
        kv: &HashMap<String, String>,
    ) {
        match super::submit_job(&self.state, kv) {
            Err(e) => self.push_ready(conn_id, seq, started, format!("err {e}"), queue_ms),
            Ok((id, _cost)) => match self.state.jobs.probe(id) {
                None => self.push_ready(
                    conn_id,
                    seq,
                    started,
                    format!("err job j{id} evicted before its reply was read"),
                    queue_ms,
                ),
                Some((v, _)) if v.state.is_terminal() => {
                    let reply =
                        v.result.unwrap_or_else(|| format!("err job j{id} lost its result"));
                    self.push_ready(conn_id, seq, started, reply, v.queue_ms);
                }
                Some((_, shed_at)) => {
                    self.park(conn_id, seq, started, queue_ms, PendingState::ClusterJob { id });
                    self.waiters.entry(id).or_default().push((conn_id, seq));
                    self.conns().waiter_parked();
                    if let Some(at) = shed_at {
                        self.arm_timer(at, conn_id, seq);
                    }
                }
            },
        }
    }

    /// The `sleep` diagnostic, evented: a timer entry instead of a held
    /// thread, bounded by `queue_cap` slots so the burst-backpressure
    /// contract (`err queue full` beyond the cap) is preserved.
    fn dispatch_sleep(
        &mut self,
        conn_id: usize,
        seq: u64,
        started: Instant,
        queue_ms: f64,
        kv: &HashMap<String, String>,
    ) {
        let ms: u64 = kv.get("ms").and_then(|s| s.parse().ok()).unwrap_or(0).min(10_000);
        if self.sleep_active >= self.queue_cap {
            self.push_ready(conn_id, seq, started, "err queue full".into(), queue_ms);
            return;
        }
        self.sleep_active += 1;
        self.park(conn_id, seq, started, queue_ms, PendingState::Sleep { ms });
        self.arm_timer(started + Duration::from_millis(ms), conn_id, seq);
    }

    /// Fire every due timer entry; each revisits one parked request.
    fn fire_timers(&mut self) {
        let now = Instant::now();
        loop {
            let Some((&(at, tick), &(conn_id, seq))) = self.timers.iter().next() else { break };
            if at > now {
                break;
            }
            self.timers.remove(&(at, tick));
            self.fire_timer(conn_id, seq, now);
        }
    }

    fn fire_timer(&mut self, conn_id: usize, seq: u64, now: Instant) {
        let fired = self.registry.get(&conn_id).and_then(|conn| {
            conn.pending.iter().find(|p| p.seq == seq).and_then(|p| match &p.state {
                PendingState::Sleep { ms } => Some(Fired::Sleep(*ms, p.queue_ms)),
                PendingState::WaitJob { id, timeout_deadline } => {
                    Some(Fired::Wait(*id, *timeout_deadline, p.queue_ms))
                }
                PendingState::ClusterJob { id } => Some(Fired::Cluster(*id, p.queue_ms)),
                PendingState::Ready(_) => None,
            })
        });
        match fired {
            None => {} // stale: already resolved or connection gone
            Some(Fired::Sleep(ms, q)) => {
                self.sleep_active -= 1;
                self.resolve(conn_id, seq, format!("ok slept_ms={ms}"), q);
            }
            Some(Fired::Wait(id, timeout_deadline, q)) => match self.state.jobs.probe(id) {
                None => self.resolve(conn_id, seq, format!("err unknown job j{id}"), q),
                Some((v, _)) if v.state.is_terminal() => {
                    let reply =
                        v.result.unwrap_or_else(|| format!("err job j{id} lost its result"));
                    self.resolve(conn_id, seq, reply, v.queue_ms);
                }
                Some((v, _)) if timeout_deadline.is_some_and(|t| now >= t) => {
                    let reply = format!("ok job=j{id} state={} timed_out=1", v.state.name());
                    self.resolve(conn_id, seq, reply, q);
                }
                // the deadline timer fired but the job got picked up in
                // time: it is running now, its terminal event resolves us
                Some(_) => {}
            },
            Some(Fired::Cluster(id, q)) => match self.state.jobs.probe(id) {
                None => self.resolve(
                    conn_id,
                    seq,
                    format!("err job j{id} evicted before its reply was read"),
                    q,
                ),
                Some((v, _)) if v.state.is_terminal() => {
                    let reply =
                        v.result.unwrap_or_else(|| format!("err job j{id} lost its result"));
                    self.resolve(conn_id, seq, reply, v.queue_ms);
                }
                Some(_) => {}
            },
        }
    }

    fn arm_timer(&mut self, at: Instant, conn_id: usize, seq: u64) {
        let tick = self.next_tick;
        self.next_tick += 1;
        self.timers.insert((at, tick), (conn_id, seq));
    }

    /// Park a request: it keeps its FIFO slot so later replies cannot
    /// overtake it on the wire.
    fn park(&mut self, conn_id: usize, seq: u64, started: Instant, queue_ms: f64, st: PendingState) {
        if let Some(conn) = self.registry.get_mut(&conn_id) {
            conn.pending.push_back(Pending { seq, state: st, started, queue_ms });
        }
    }

    /// Append an already-answered request (trailer formatted now, so
    /// `served_ms=` reflects the actual dispatch) and try to flush.
    fn push_ready(
        &mut self,
        conn_id: usize,
        seq: u64,
        started: Instant,
        reply: String,
        trailer_queue_ms: f64,
    ) {
        let line = reply_line(&reply, trailer_queue_ms, started);
        let Some(conn) = self.registry.get_mut(&conn_id) else { return };
        conn.pending.push_back(Pending {
            seq,
            state: PendingState::Ready(line),
            started,
            queue_ms: trailer_queue_ms,
        });
        self.flush_conn(conn_id);
    }

    /// Transition a parked request to its finished reply (idempotent —
    /// the first resolution wins) and flush in order.
    fn resolve(&mut self, conn_id: usize, seq: u64, reply: String, trailer_queue_ms: f64) {
        let was_waiter = {
            let Some(conn) = self.registry.get_mut(&conn_id) else { return };
            let Some(p) = conn.pending.iter_mut().find(|p| p.seq == seq) else { return };
            let was_waiter = match p.state {
                PendingState::Ready(_) => return, // already resolved
                PendingState::WaitJob { .. } | PendingState::ClusterJob { .. } => true,
                PendingState::Sleep { .. } => false,
            };
            p.state = PendingState::Ready(reply_line(&reply, trailer_queue_ms, p.started));
            was_waiter
        };
        if was_waiter {
            self.conns().waiter_resolved();
        }
        self.flush_conn(conn_id);
    }

    /// Move front-of-queue finished replies into the write buffer and
    /// write as much as the socket accepts; drop the connection when it
    /// is broken, hopelessly behind, or cleanly drained after EOF.
    fn flush_conn(&mut self, conn_id: usize) {
        let drop_now = {
            let Some(conn) = self.registry.get_mut(&conn_id) else { return };
            while matches!(conn.pending.front().map(|p| &p.state), Some(PendingState::Ready(_))) {
                let p = conn.pending.pop_front().expect("front was just matched");
                if let PendingState::Ready(bytes) = p.state {
                    conn.wbuf.extend_from_slice(&bytes);
                    conn.last_progress = Instant::now();
                }
            }
            let mut broken = false;
            while !conn.wbuf.is_empty() {
                match conn.stream.write(&conn.wbuf) {
                    Ok(0) => {
                        broken = true;
                        break;
                    }
                    Ok(n) => {
                        conn.wbuf.drain(..n);
                        conn.last_progress = Instant::now();
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                    Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                    Err(_) => {
                        broken = true;
                        break;
                    }
                }
            }
            broken
                || conn.wbuf.len() > WBUF_CAP
                || (conn.closed_read && conn.pending.is_empty() && conn.wbuf.is_empty())
        };
        if drop_now {
            self.drop_conn(conn_id);
        }
    }

    /// Remove a connection, returning its parked requests' gauge slots
    /// (waiters, sleep slots).  Stale timer / waiter-index entries are
    /// left behind and skipped when they surface.
    fn drop_conn(&mut self, conn_id: usize) {
        let Some(conn) = self.registry.remove(&conn_id) else { return };
        for p in &conn.pending {
            match p.state {
                PendingState::WaitJob { .. } | PendingState::ClusterJob { .. } => {
                    self.conns().waiter_resolved();
                }
                PendingState::Sleep { .. } => self.sleep_active -= 1,
                PendingState::Ready(_) => {}
            }
        }
        self.conns().conn_closed();
    }

    /// Shed connections whose write buffer has stalled past
    /// [`WRITE_STALL`] — a slow reader costs a registry entry, not a
    /// thread, but not an unbounded buffer either.
    fn shed_stalled(&mut self) {
        let now = Instant::now();
        let stalled: Vec<usize> = self
            .registry
            .iter()
            .filter(|(_, c)| {
                !c.wbuf.is_empty() && now.duration_since(c.last_progress) >= WRITE_STALL
            })
            .map(|(&id, _)| id)
            .collect();
        for id in stalled {
            self.drop_conn(id);
        }
    }

    /// Shutdown drain: drop idle connections immediately, keep the ones
    /// with unresolved or unflushed replies; `true` once none remain.
    fn shutdown_drained(&mut self) -> bool {
        let idle: Vec<usize> = self
            .registry
            .iter()
            .filter(|(_, c)| c.pending.is_empty() && c.wbuf.is_empty())
            .map(|(&id, _)| id)
            .collect();
        for id in idle {
            self.drop_conn(id);
        }
        self.registry.is_empty()
    }
}

/// One finished wire reply: the v7 trailer appended, newline-terminated.
fn reply_line(reply: &str, queue_ms: f64, started: Instant) -> Vec<u8> {
    format!(
        "{reply} queue_ms={queue_ms:.1} served_ms={:.1}\n",
        started.elapsed().as_secs_f64() * 1e3
    )
    .into_bytes()
}
