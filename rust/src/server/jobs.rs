//! The asynchronous job registry behind protocol v5's handle verbs
//! (`submit` / `poll` / `wait` / `cancel` / `jobs`).
//!
//! A [`JobRegistry`] decouples *connection* lifetime from *job*
//! lifetime: `submit` prices and admits a clustering job, enqueues it,
//! and returns a monotonic `job=j<id>` handle immediately; the server's
//! worker threads drain the queue and
//! publish each job's terminal state back through the registry, where
//! any later connection can observe it.  A slow client holds only its
//! own socket — never a solver worker.
//!
//! # Job lifecycle
//!
//! ```text
//!            submit                 pickup                 finish
//! (admitted) ------> Queued ----------------> Running -----------> Done | Failed | Cancelled
//!                      |                         |
//!                      | cancel / deadline       | cancel -> cooperative token,
//!                      v                         v           lands as Cancelled
//!                  Cancelled / Expired        (runs on)
//! ```
//!
//! * a **queued** job holds its [`crate::server::JobPermit`] (admission
//!   budget units); cancelling or deadline-shedding it releases the
//!   permit immediately — the budget gauge returns to baseline without
//!   the job ever running;
//! * a **running** job is cancelled cooperatively: `cancel` flips the
//!   job's [`CancelToken`], which the solver checks between swap
//!   passes; the job then lands as `Cancelled` (or `Done`, if it
//!   finished first — cancellation is a request, not preemption);
//! * a job whose `deadline_ms=` elapses while still queued is **shed**:
//!   state `Expired`, result `err deadline ... queue_ms=...`, permit
//!   released, recorded in [`JobCounters::expired`] (the `shed=` stats
//!   field).  Deadlines bound *queue wait*, not run time — a job that
//!   started in time runs to completion.
//!
//! # Retention
//!
//! Terminal jobs are retained for later `poll`/`wait` calls, bounded by
//! [`JobRegistry::new`]'s `retain_cap` with LRU eviction: each finished
//! job joins the back of the retention queue, a `poll`/`wait` touch
//! moves it back there, and admitting a finished job beyond the cap
//! evicts the coldest one (its handle then reports `err unknown job`).
//! Queued and running jobs are never evicted.
//!
//! All registry state sits behind one mutex; the critical sections are
//! map/queue edits, vastly cheaper than the solves around them.  Two
//! condvars separate the wakeup targets: workers park on `queue_cv`
//! for new jobs, `wait` callers park on `state_cv` for state changes.
//! The evented accept core ([`crate::server::event`]) parks no thread:
//! it installs a waker via [`JobRegistry::set_waker`] that is fired
//! alongside every `state_cv` broadcast, and drains the terminal-
//! transition ids with [`JobRegistry::take_terminal_events`] to resolve
//! its parked connections.

use super::metrics::JobCounters;
use super::models::ModelSeed;
use super::JobWork;
use crate::solver::CancelToken;
use crate::sync_ext;
use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::time::{Duration, Instant};

/// Where a job is in its lifecycle (see the module docs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JobState {
    /// Admitted and waiting for a worker.
    Queued,
    /// A worker is executing the solve.
    Running,
    /// Finished with a result (the stored `cluster` reply).
    Done,
    /// Finished with an error (load / admission-after-load / solver).
    Failed,
    /// Cancelled while queued, or a running job whose cooperative
    /// cancellation landed.
    Cancelled,
    /// Shed because its `deadline_ms=` passed while still queued.
    Expired,
}

impl JobState {
    /// Wire spelling (`state=` field values).
    pub fn name(self) -> &'static str {
        match self {
            JobState::Queued => "queued",
            JobState::Running => "running",
            JobState::Done => "done",
            JobState::Failed => "failed",
            JobState::Cancelled => "cancelled",
            JobState::Expired => "expired",
        }
    }

    /// Has the job reached a final state (result available, permit
    /// released)?
    pub fn is_terminal(self) -> bool {
        !matches!(self, JobState::Queued | JobState::Running)
    }
}

/// A point-in-time snapshot of one job, safe to format outside the
/// registry lock.
#[derive(Clone, Debug)]
pub struct JobView {
    /// The numeric part of the `j<id>` handle.
    pub id: u64,
    /// Lifecycle state at snapshot time.
    pub state: JobState,
    /// Admitted work units.  An unpredictable source submits at `0`;
    /// the worker reports the real price once the post-load pricing
    /// lands, so only the pre-pickup window reads `0`.
    pub cost: u64,
    /// Queue wait in milliseconds: so-far for a queued job, frozen at
    /// pickup / shed time otherwise.
    pub queue_ms: f64,
    /// The stored reply line for terminal jobs (`ok ...` for done,
    /// `err ...` otherwise); `None` while queued / running.
    pub result: Option<String>,
}

/// Point-in-time occupancy of the registry (the `jobs` wire verb).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct JobGauges {
    /// Jobs waiting for a worker.
    pub queued: usize,
    /// Jobs being executed right now.
    pub running: usize,
    /// Terminal jobs retained for `poll`/`wait` (bounded, LRU).
    pub retained: usize,
}

/// What [`JobRegistry::wait`] observed.
pub enum WaitOutcome {
    /// No job with this id (never submitted, or evicted).
    Unknown,
    /// The job reached a terminal state; the view carries its result.
    Terminal(JobView),
    /// `timeout_ms=` elapsed first; the view shows the live state.
    TimedOut(JobView),
}

/// What [`JobRegistry::fitted`] observed (the `promote` wire verb).
pub enum FittedLookup {
    /// No job with this id (never submitted, or evicted).
    Unknown,
    /// The job has not reached a terminal state yet — promote after
    /// `wait` returns done.
    NotDone(JobState),
    /// The job is terminal but holds no model (failed / cancelled /
    /// expired, or a pre-v6 job finished before fitting existed).
    Unavailable(JobState),
    /// The job's dataset-free fitted model, ready to register.
    Ready(ModelSeed),
}

/// One queued-or-running job a worker picked up.
pub(crate) struct PickedJob {
    pub(crate) id: u64,
    pub(crate) work: Box<JobWork>,
    /// Submit-to-pickup wait (milliseconds) — the v5 successor of the
    /// v4 accept-to-pickup measure, fed to the queue-wait histograms.
    pub(crate) queue_ms: f64,
}

struct Job {
    state: JobState,
    /// The solve request + admission permit; `Some` while queued, taken
    /// by the worker at pickup (or dropped on cancel / shed, which
    /// releases the permit).
    work: Option<Box<JobWork>>,
    cancel: CancelToken,
    result: Option<String>,
    submitted: Instant,
    deadline: Option<Duration>,
    cost: u64,
    queue_ms: f64,
    /// Dataset-free fitted model, stashed by the worker on a successful
    /// solve so a later `promote` needs no dataset and no recompute.
    /// Dropped with the job at LRU eviction.
    fitted: Option<ModelSeed>,
}

struct Inner {
    jobs: HashMap<u64, Job>,
    /// Queued job ids in submit order (ids whose job left `Queued` by
    /// cancel / shed are skipped at pickup).
    queue: VecDeque<u64>,
    /// Terminal job ids, coldest first (LRU retention order).
    finished: VecDeque<u64>,
    /// Ids that reached a terminal state since the last
    /// [`JobRegistry::take_terminal_events`] drain (event-loop feed).
    events: Vec<u64>,
    shutdown: bool,
}

/// The registry: owns every job from submit to eviction.
pub struct JobRegistry {
    inner: Mutex<Inner>,
    /// Workers park here for new queue entries (or shutdown).
    queue_cv: Condvar,
    /// `wait` callers park here for job state changes.
    state_cv: Condvar,
    next_id: AtomicU64,
    retain_cap: usize,
    /// Max *queued* jobs before `submit` backpressures — the v5
    /// successor of v4's connection-held queue slots: a `submit` frees
    /// its connection immediately, so without this bound a client loop
    /// could grow the queue (and, for unpriced hint-less `file:`
    /// sources, bypass the admission budget entirely) without limit.
    queue_cap: usize,
    /// Worker threads draining this registry (0 = none running, e.g. a
    /// direct-library [`crate::server::ServerState`] without `serve`).
    workers: AtomicUsize,
    counters: JobCounters,
    /// The event loop's self-pipe wakeup, fired alongside every
    /// `state_cv` broadcast so parked connections resolve without a
    /// blocked thread.  Unset for library states (no loop to wake).
    waker: OnceLock<Arc<dyn Fn() + Send + Sync>>,
}

impl JobRegistry {
    /// Empty registry retaining at most `retain_cap` finished jobs and
    /// accepting at most `queue_cap` queued (not-yet-running) jobs.
    pub fn new(retain_cap: usize, queue_cap: usize) -> Self {
        JobRegistry {
            inner: Mutex::new(Inner {
                jobs: HashMap::new(),
                queue: VecDeque::new(),
                finished: VecDeque::new(),
                events: Vec::new(),
                shutdown: false,
            }),
            queue_cv: Condvar::new(),
            state_cv: Condvar::new(),
            next_id: AtomicU64::new(1),
            retain_cap: retain_cap.max(1),
            queue_cap: queue_cap.max(1),
            workers: AtomicUsize::new(0),
            counters: JobCounters::new(),
            waker: OnceLock::new(),
        }
    }

    /// Install the event loop's waker; fired with every `state_cv`
    /// broadcast.  First caller wins (one loop per registry).
    pub(crate) fn set_waker(&self, waker: Arc<dyn Fn() + Send + Sync>) {
        let _ = self.waker.set(waker);
    }

    /// Broadcast a job state change: wake parked `wait` threads and,
    /// when an event loop is attached, fire its self-pipe waker.  Every
    /// state transition routes through here — a terminal job must never
    /// leave a parked connection unresolved.
    fn notify_state(&self) {
        self.state_cv.notify_all();
        if let Some(wake) = self.waker.get() {
            wake();
        }
    }

    /// Drain the ids that reached a terminal state since the last
    /// drain.  The event loop calls this after each waker fire and
    /// resolves the connections parked on those jobs.
    pub(crate) fn take_terminal_events(&self) -> Vec<u64> {
        std::mem::take(&mut self.lock().events)
    }

    /// Lifetime counters (the `jobs.*` / `shed=` stats fields).
    pub fn counters(&self) -> &JobCounters {
        &self.counters
    }

    /// Declare `n` worker threads are draining this registry.
    pub(crate) fn set_workers(&self, n: usize) {
        self.workers.store(n, Ordering::SeqCst);
    }

    /// Are any worker threads draining this registry?  `cluster` lines
    /// route through the queue exactly when this holds; a direct
    /// library state runs them inline instead.
    pub fn has_workers(&self) -> bool {
        self.workers.load(Ordering::SeqCst) > 0
    }

    /// Enqueue an admitted job; returns its handle id.  Fails once
    /// [`JobRegistry::shutdown`] ran (a job enqueued then could never
    /// be drained), and backpressures with `queue full` once
    /// `queue_cap` jobs are already queued.
    pub(crate) fn submit(
        &self,
        work: Box<JobWork>,
        deadline_ms: Option<u64>,
        cancel: CancelToken,
        cost: u64,
    ) -> Result<u64, String> {
        let mut inner = self.lock();
        if inner.shutdown {
            return Err("server shutting down".into());
        }
        // cancel/expire/pickup keep `queue` exactly in sync with the
        // Queued state (all under this lock), so its length IS the
        // queued-job count — no map scan on the submit path
        let queued = inner.queue.len();
        if queued >= self.queue_cap {
            return Err(format!("queue full ({queued} jobs queued)"));
        }
        let id = self.next_id.fetch_add(1, Ordering::SeqCst);
        inner.jobs.insert(
            id,
            Job {
                state: JobState::Queued,
                work: Some(work),
                cancel,
                result: None,
                submitted: Instant::now(),
                deadline: deadline_ms.map(Duration::from_millis),
                cost,
                queue_ms: 0.0,
                fitted: None,
            },
        );
        inner.queue.push_back(id);
        self.counters.record_submitted();
        drop(inner);
        self.queue_cv.notify_one();
        Ok(id)
    }

    /// Worker loop: block until a runnable job is available and claim
    /// it, shedding any queued job whose deadline already passed.
    /// Returns `None` on shutdown *after* the queue drained, so jobs
    /// accepted before shutdown still complete.
    pub(crate) fn next_job(&self) -> Option<PickedJob> {
        let mut inner = self.lock();
        loop {
            if let Some(picked) = self.pick_runnable(&mut inner) {
                return Some(picked);
            }
            if inner.shutdown {
                return None;
            }
            inner = sync_ext::wait_or_recover(&self.queue_cv, inner);
        }
    }

    /// Non-blocking [`JobRegistry::next_job`]: claim a runnable job if
    /// one is queued right now, else `None` instead of parking.  Drives
    /// [`crate::server::ServerState::drain_one`] — the deterministic
    /// single-step worker used by workerless embedders and the
    /// interleaving suite.
    pub(crate) fn try_next_job(&self) -> Option<PickedJob> {
        let mut inner = self.lock();
        self.pick_runnable(&mut inner)
    }

    /// One pass over the queue under the lock: shed overdue entries and
    /// claim the first still-runnable job, if any.
    fn pick_runnable(&self, inner: &mut Inner) -> Option<PickedJob> {
        while let Some(id) = inner.queue.pop_front() {
            if self.expire_if_due(inner, id) {
                self.notify_state();
                continue;
            }
            let picked = {
                let Some(job) = inner.jobs.get_mut(&id) else { continue };
                if job.state != JobState::Queued {
                    continue; // cancelled while queued
                }
                let waited = job.submitted.elapsed().as_secs_f64() * 1e3;
                job.state = JobState::Running;
                job.queue_ms = waited;
                PickedJob {
                    id,
                    work: job.work.take().expect("queued job carries its work"),
                    queue_ms: waited,
                }
            };
            self.notify_state();
            return Some(picked);
        }
        None
    }

    /// Publish a picked job's outcome.  An error equal to
    /// [`crate::solver::CANCELLED`] records the job as cancelled (the
    /// cooperative token landed); any other error is a failure.
    pub(crate) fn finish(&self, id: u64, outcome: Result<String, String>) {
        let mut inner = self.lock();
        let landed = {
            let Some(job) = inner.jobs.get_mut(&id) else { return };
            debug_assert!(
                job.state == JobState::Running,
                "finish() on a {} job — terminal transitions are exactly-once",
                job.state.name()
            );
            let state = match &outcome {
                Ok(_) => JobState::Done,
                Err(e) if e.as_str() == crate::solver::CANCELLED => JobState::Cancelled,
                Err(_) => JobState::Failed,
            };
            job.state = state;
            job.result = Some(match outcome {
                Ok(reply) => reply,
                Err(_) if state == JobState::Cancelled => format!("err cancelled job=j{id}"),
                Err(e) => format!("err {e}"),
            });
            state
        };
        match landed {
            JobState::Done => self.counters.record_done(),
            JobState::Cancelled => self.counters.record_cancelled(),
            _ => self.counters.record_failed(),
        }
        self.retire(&mut inner, id);
        drop(inner);
        self.notify_state();
    }

    /// Non-blocking snapshot of one job (`None`: unknown / evicted).
    /// Applies lazy deadline expiry and counts as an LRU touch on
    /// terminal jobs.
    pub fn poll(&self, id: u64) -> Option<JobView> {
        let mut inner = self.lock();
        let expired = self.expire_if_due(&mut inner, id);
        let (view, terminal) = {
            let job = inner.jobs.get(&id)?;
            (view_of(id, job), job.state.is_terminal())
        };
        if terminal {
            touch(&mut inner, id);
        }
        if expired {
            drop(inner);
            self.notify_state();
        }
        Some(view)
    }

    /// Block until the job reaches a terminal state, or `timeout`
    /// elapses.  The wait wakes itself at the job's own deadline, so a
    /// queued job sheds on time even with no worker ever picking it up.
    pub fn wait(&self, id: u64, timeout: Option<Duration>) -> WaitOutcome {
        let wait_until = timeout.map(|t| Instant::now() + t);
        let mut inner = self.lock();
        loop {
            let expired = self.expire_if_due(&mut inner, id);
            if expired {
                self.notify_state();
            }
            let Some(job) = inner.jobs.get(&id) else { return WaitOutcome::Unknown };
            let view = view_of(id, job);
            let (state, submitted, deadline) = (job.state, job.submitted, job.deadline);
            if state.is_terminal() {
                touch(&mut inner, id);
                return WaitOutcome::Terminal(view);
            }
            // next wakeup: the job's own deadline (queued only) and/or
            // the caller's timeout — whichever comes first
            let now = Instant::now();
            let mut sleep: Option<Duration> = match (state, deadline) {
                (JobState::Queued, Some(d)) => {
                    Some((submitted + d).saturating_duration_since(now))
                }
                _ => None,
            };
            if let Some(until) = wait_until {
                if now >= until {
                    return WaitOutcome::TimedOut(view);
                }
                let left = until - now;
                sleep = Some(sleep.map_or(left, |s| s.min(left)));
            }
            inner = match sleep {
                Some(d) => sync_ext::wait_timeout_or_recover(&self.state_cv, inner, d).0,
                None => sync_ext::wait_or_recover(&self.state_cv, inner),
            };
        }
    }

    /// Event-loop snapshot of one job: the [`JobView`] plus, for a
    /// queued job with a deadline, the absolute instant it sheds — the
    /// loop arms a timer-wheel entry there instead of parking a thread
    /// in [`JobRegistry::wait`].  Applies lazy deadline expiry and
    /// counts as an LRU touch on terminal jobs, exactly like
    /// [`JobRegistry::poll`].
    pub(crate) fn probe(&self, id: u64) -> Option<(JobView, Option<Instant>)> {
        let mut inner = self.lock();
        let expired = self.expire_if_due(&mut inner, id);
        let (view, terminal, shed_at) = {
            let job = inner.jobs.get(&id)?;
            let shed_at = match (job.state, job.deadline) {
                (JobState::Queued, Some(d)) => Some(job.submitted + d),
                _ => None,
            };
            (view_of(id, job), job.state.is_terminal(), shed_at)
        };
        if terminal {
            touch(&mut inner, id);
        }
        if expired {
            drop(inner);
            self.notify_state();
        }
        Some((view, shed_at))
    }

    /// Cancel a job: a queued one is terminal immediately (permit
    /// released), a running one gets its cooperative token flipped, a
    /// terminal one is left as-is.  Returns the state observed *after*
    /// the call and whether this call changed anything; `None` for an
    /// unknown handle.
    pub fn cancel(&self, id: u64) -> Option<(JobState, bool)> {
        let mut inner = self.lock();
        let _ = self.expire_if_due(&mut inner, id);
        enum Effect {
            CancelledQueued,
            FlaggedRunning,
            Already(JobState),
        }
        let effect = {
            let job = inner.jobs.get_mut(&id)?;
            match job.state {
                JobState::Queued => {
                    job.state = JobState::Cancelled;
                    job.queue_ms = job.submitted.elapsed().as_secs_f64() * 1e3;
                    job.work = None; // drops the JobWork -> permit released
                    job.result = Some(format!("err cancelled job=j{id}"));
                    Effect::CancelledQueued
                    // the stale queue entry is dropped below, once the
                    // job borrow ends
                }
                JobState::Running => {
                    job.cancel.cancel();
                    Effect::FlaggedRunning
                }
                s => Effect::Already(s),
            }
        };
        match effect {
            Effect::CancelledQueued => {
                inner.queue.retain(|&x| x != id);
                self.counters.record_cancelled();
                self.retire(&mut inner, id);
                drop(inner);
                self.notify_state();
                Some((JobState::Cancelled, true))
            }
            Effect::FlaggedRunning => Some((JobState::Running, true)),
            Effect::Already(s) => Some((s, false)),
        }
    }

    /// Record the job's post-load price (unpredictable sources submit
    /// at `cost=0`; the worker reports the real units once the permit
    /// is priced, so `poll` on a running job shows what it holds).
    pub(crate) fn set_cost(&self, id: u64, units: u64) {
        if let Some(job) = self.lock().jobs.get_mut(&id) {
            job.cost = units;
        }
    }

    /// Stash the solve's dataset-free fitted model on the job (worker,
    /// just before publishing the `Done` reply), so `promote` can serve
    /// it without the dataset ever being resident again.
    pub(crate) fn set_fitted(&self, id: u64, seed: ModelSeed) {
        if let Some(job) = self.lock().jobs.get_mut(&id) {
            job.fitted = Some(seed);
        }
    }

    /// Look up the fitted model `promote job=<id>` asks for.  Applies
    /// lazy deadline expiry and counts as an LRU touch on terminal jobs
    /// (promoting a job is as much an access as polling it).
    pub fn fitted(&self, id: u64) -> FittedLookup {
        let mut inner = self.lock();
        let expired = self.expire_if_due(&mut inner, id);
        let looked = {
            match inner.jobs.get(&id) {
                None => FittedLookup::Unknown,
                Some(job) if !job.state.is_terminal() => FittedLookup::NotDone(job.state),
                Some(job) => match &job.fitted {
                    Some(seed) => FittedLookup::Ready(seed.clone()),
                    None => FittedLookup::Unavailable(job.state),
                },
            }
        };
        if !matches!(looked, FittedLookup::Unknown | FittedLookup::NotDone(_)) {
            touch(&mut inner, id);
        }
        if expired {
            drop(inner);
            self.notify_state();
        }
        looked
    }

    /// Shed every queued job whose deadline already passed.  Expiry is
    /// otherwise lazy (applied when a job is observed), so the submit
    /// path and the gauges run this sweep first — a logically dead job
    /// must not hold budget units against a new submit or count as
    /// queued in `jobs`/`stats`.  O(queued), bounded by `queue_cap`.
    pub(crate) fn shed_expired(&self) {
        let mut inner = self.lock();
        let queued: Vec<u64> = inner.queue.iter().copied().collect();
        let mut any = false;
        for id in queued {
            any |= self.expire_if_due(&mut inner, id);
        }
        if any {
            drop(inner);
            self.notify_state();
        }
    }

    /// Registry occupancy (the `jobs` wire verb and `jobs.*` gauges).
    /// Sweeps overdue queued jobs first, so a dead job never reads as
    /// queued.
    pub fn gauges(&self) -> JobGauges {
        self.shed_expired();
        let inner = self.lock();
        let (mut queued, mut running) = (0, 0);
        for job in inner.jobs.values() {
            match job.state {
                JobState::Queued => queued += 1,
                JobState::Running => running += 1,
                _ => {}
            }
        }
        JobGauges { queued, running, retained: inner.finished.len() }
    }

    /// Begin shutdown: reject new submits, wake parked workers (they
    /// drain the remaining queue, then exit) and every `wait` caller.
    pub fn shutdown(&self) {
        self.lock().shutdown = true;
        self.queue_cv.notify_all();
        self.notify_state();
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, Inner> {
        sync_ext::lock_or_recover(&self.inner)
    }

    /// Shed the job if it is queued past its deadline: terminal
    /// `Expired`, permit released, shed counted.  Returns whether it
    /// expired on this call.
    fn expire_if_due(&self, inner: &mut Inner, id: u64) -> bool {
        let due = {
            let Some(job) = inner.jobs.get_mut(&id) else { return false };
            if job.state != JobState::Queued {
                return false;
            }
            let Some(deadline) = job.deadline else { return false };
            let waited = job.submitted.elapsed();
            if waited < deadline {
                return false;
            }
            let queue_ms = waited.as_secs_f64() * 1e3;
            job.state = JobState::Expired;
            job.queue_ms = queue_ms;
            job.work = None; // releases the admission permit
            job.result = Some(format!(
                "err deadline job=j{id} deadline_ms={} queue_ms={queue_ms:.1}",
                deadline.as_millis()
            ));
            true
        };
        if due {
            // drop the stale queue entry (no-op when the caller already
            // popped it, i.e. the shed-at-pickup path)
            inner.queue.retain(|&x| x != id);
            self.counters.record_expired();
            self.retire(inner, id);
        }
        due
    }

    /// Add a terminal job to the retention queue (warm end), evicting
    /// the coldest beyond `retain_cap`.
    fn retire(&self, inner: &mut Inner, id: u64) {
        touch(inner, id);
        if !inner.finished.contains(&id) {
            inner.finished.push_back(id);
            // every terminal transition passes through retire() exactly
            // once, so this feed is complete and duplicate-free
            inner.events.push(id);
        }
        while inner.finished.len() > self.retain_cap {
            if let Some(cold) = inner.finished.pop_front() {
                inner.jobs.remove(&cold);
            }
        }
    }
}

/// LRU touch: move `id` to the warm end of the retention queue (no-op
/// for ids not yet retired).
fn touch(inner: &mut Inner, id: u64) {
    if let Some(pos) = inner.finished.iter().position(|&x| x == id) {
        inner.finished.remove(pos);
        inner.finished.push_back(id);
    }
}

fn view_of(id: u64, job: &Job) -> JobView {
    let queue_ms = if job.state == JobState::Queued {
        job.submitted.elapsed().as_secs_f64() * 1e3
    } else {
        job.queue_ms
    };
    JobView { id, state: job.state, cost: job.cost, queue_ms, result: job.result.clone() }
}
