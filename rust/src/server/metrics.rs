//! Per-method serving metrics (protocol v3 `stats`).
//!
//! Every successful `cluster` reply records its method's solve+eval
//! latency and dissimilarity count here; the `stats` wire command
//! exports count/min/mean/max per [`crate::solver::MethodSpec`] label.
//! One mutex over a small BTreeMap is plenty: the critical section is a
//! map insert, vastly cheaper than the clustering job that precedes it,
//! and the BTreeMap keeps the `stats` line deterministically ordered.

use std::collections::BTreeMap;
use std::sync::Mutex;

/// Aggregate for one method label.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct MethodAgg {
    /// Jobs served with this method.
    pub count: u64,
    /// Fastest solve+eval latency (milliseconds).
    pub ms_min: f64,
    /// Total latency (milliseconds) — mean = `ms_sum / count`.
    pub ms_sum: f64,
    /// Slowest solve+eval latency (milliseconds).
    pub ms_max: f64,
    /// Smallest dissimilarity count of one job.
    pub dissim_min: u64,
    /// Total dissimilarity computations — mean = `dissim_sum / count`.
    pub dissim_sum: u64,
    /// Largest dissimilarity count of one job.
    pub dissim_max: u64,
}

impl MethodAgg {
    fn first(ms: f64, dissim: u64) -> Self {
        MethodAgg {
            count: 1,
            ms_min: ms,
            ms_sum: ms,
            ms_max: ms,
            dissim_min: dissim,
            dissim_sum: dissim,
            dissim_max: dissim,
        }
    }

    fn add(&mut self, ms: f64, dissim: u64) {
        self.count += 1;
        self.ms_min = self.ms_min.min(ms);
        self.ms_sum += ms;
        self.ms_max = self.ms_max.max(ms);
        self.dissim_min = self.dissim_min.min(dissim);
        self.dissim_sum += dissim;
        self.dissim_max = self.dissim_max.max(dissim);
    }

    /// Mean latency in milliseconds.
    pub fn ms_mean(&self) -> f64 {
        self.ms_sum / self.count.max(1) as f64
    }

    /// Mean dissimilarity computations per job.
    pub fn dissim_mean(&self) -> f64 {
        self.dissim_sum as f64 / self.count.max(1) as f64
    }
}

/// Thread-safe per-method aggregates, keyed by method label.
#[derive(Default)]
pub struct MethodMetrics {
    inner: Mutex<BTreeMap<String, MethodAgg>>,
}

impl MethodMetrics {
    /// Empty registry.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one served job for `label`.
    pub fn record(&self, label: &str, ms: f64, dissim: u64) {
        let mut map = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        match map.get_mut(label) {
            Some(agg) => agg.add(ms, dissim),
            None => {
                map.insert(label.to_string(), MethodAgg::first(ms, dissim));
            }
        }
    }

    /// Snapshot of every label's aggregate, sorted by label.
    pub fn snapshot(&self) -> Vec<(String, MethodAgg)> {
        let map = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        map.iter().map(|(k, v)| (k.clone(), *v)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aggregates_count_min_mean_max() {
        let m = MethodMetrics::new();
        m.record("OneBatch-nniw", 2.0, 100);
        m.record("OneBatch-nniw", 6.0, 300);
        m.record("OneBatch-nniw", 4.0, 200);
        let snap = m.snapshot();
        assert_eq!(snap.len(), 1);
        let (label, a) = &snap[0];
        assert_eq!(label, "OneBatch-nniw");
        assert_eq!(a.count, 3);
        assert_eq!((a.ms_min, a.ms_max), (2.0, 6.0));
        assert!((a.ms_mean() - 4.0).abs() < 1e-12);
        assert_eq!((a.dissim_min, a.dissim_max), (100, 300));
        assert!((a.dissim_mean() - 200.0).abs() < 1e-12);
    }

    #[test]
    fn snapshot_is_sorted_by_label() {
        let m = MethodMetrics::new();
        m.record("kmc2-20", 1.0, 1);
        m.record("FasterPAM", 1.0, 1);
        m.record("OneBatch-nniw", 1.0, 1);
        let labels: Vec<String> = m.snapshot().into_iter().map(|(l, _)| l).collect();
        assert_eq!(labels, vec!["FasterPAM", "OneBatch-nniw", "kmc2-20"]);
    }

    #[test]
    fn concurrent_records_all_land() {
        let m = std::sync::Arc::new(MethodMetrics::new());
        let handles: Vec<_> = (0..8)
            .map(|i| {
                let m = m.clone();
                std::thread::spawn(move || {
                    for _ in 0..50 {
                        m.record("Random", i as f64, 10);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let snap = m.snapshot();
        assert_eq!(snap[0].1.count, 400);
        assert_eq!(snap[0].1.dissim_sum, 4000);
    }
}
