//! Per-method serving metrics (protocol v4 `stats`) and job lifecycle
//! counters (protocol v5 `jobs.*` / `shed=` stats fields).
//!
//! Every successful `cluster` reply records its method's solve+eval
//! latency, its queue wait and its dissimilarity count here; the
//! `stats` wire command exports, per [`crate::solver::MethodSpec`]
//! label, count/min/mean/max aggregates *and* fixed-bucket latency
//! histograms for both the solve latency and the queue wait (the
//! aggregates show the centre, the buckets show the tail).  `stats
//! reset` clears everything via [`MethodMetrics::reset`].
//!
//! [`ModelMetrics`] is the protocol v6 serving-side mirror of the
//! method aggregates: every `assign` records its latency under the
//! model's registry name, exported as `model.<name>.assign_count=` /
//! `model.<name>.assign_ms_mean=` stats fields and cleared by the same
//! `stats reset`.
//!
//! [`JobCounters`] tracks the v5 asynchronous job registry
//! ([`crate::server::jobs`]): jobs submitted and how each one ended
//! (done / failed / cancelled / deadline-expired).  The `stats` line
//! exports them as `jobs.<outcome>=` fields plus the `shed=` alias for
//! deadline expiries, and `stats reset` re-bases them alongside the
//! method aggregates.
//!
//! [`ConnCounters`] instruments the protocol v8 evented accept core
//! ([`crate::server::event`]): live gauges for open connections
//! (`conns=`) and parked waiters (`waiters=`), plus counters for
//! pipelined requests (`pipelined=`, requests after the first on one
//! connection) and self-pipe wakeups (`wakeups=`).  The gauges track
//! current occupancy, so `stats reset` zeroes only the two counters —
//! resetting stats must not un-open a connection.
//!
//! One mutex over a small BTreeMap is plenty: the critical section is a
//! map insert, vastly cheaper than the clustering job that precedes it,
//! and the BTreeMap keeps the `stats` line deterministically ordered.

use crate::sync_ext;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Upper bucket edges (milliseconds, `le` semantics) of every latency
/// histogram; one implicit `+inf` overflow bucket follows, so each
/// histogram has [`HIST_BUCKETS`] counts.
pub const HIST_LE_MS: [f64; 11] =
    [1.0, 2.0, 5.0, 10.0, 25.0, 50.0, 100.0, 250.0, 500.0, 1000.0, 2500.0];

/// Bucket count of one latency histogram (the edges plus `+inf`).
pub const HIST_BUCKETS: usize = HIST_LE_MS.len() + 1;

/// The edges as a wire string (`stats` exports it once as
/// `hist_le_ms=...` so clients need not hardcode the layout).
pub fn hist_edges_wire() -> String {
    let mut s = HIST_LE_MS.iter().map(|e| format!("{e}")).collect::<Vec<_>>().join(",");
    s.push_str(",inf");
    s
}

/// Fixed-bucket latency histogram (non-cumulative counts).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LatencyHist {
    counts: [u64; HIST_BUCKETS],
}

impl Default for LatencyHist {
    fn default() -> Self {
        LatencyHist { counts: [0; HIST_BUCKETS] }
    }
}

impl LatencyHist {
    /// Count one observation of `ms` into its bucket.
    pub fn record(&mut self, ms: f64) {
        let b = HIST_LE_MS.iter().position(|&edge| ms <= edge).unwrap_or(HIST_LE_MS.len());
        self.counts[b] += 1;
    }

    /// Per-bucket counts (`HIST_LE_MS` order, then the `+inf` bucket).
    pub fn counts(&self) -> &[u64; HIST_BUCKETS] {
        &self.counts
    }

    /// Total observations.
    pub fn total(&self) -> u64 {
        self.counts.iter().sum()
    }

    /// Wire form: the bucket counts comma-joined (same order as
    /// [`hist_edges_wire`]).
    pub fn wire(&self) -> String {
        self.counts.iter().map(|c| c.to_string()).collect::<Vec<_>>().join(",")
    }
}

/// Aggregate for one method label.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct MethodAgg {
    /// Jobs served with this method.
    pub count: u64,
    /// Fastest solve+eval latency (milliseconds).
    pub ms_min: f64,
    /// Total latency (milliseconds) — mean = `ms_sum / count`.
    pub ms_sum: f64,
    /// Slowest solve+eval latency (milliseconds).
    pub ms_max: f64,
    /// Smallest dissimilarity count of one job.
    pub dissim_min: u64,
    /// Total dissimilarity computations — mean = `dissim_sum / count`.
    pub dissim_sum: u64,
    /// Largest dissimilarity count of one job.
    pub dissim_max: u64,
    /// Solve+eval latency distribution.
    pub solve_hist: LatencyHist,
    /// Queue-wait distribution (time between accept and worker pickup).
    pub queue_hist: LatencyHist,
}

impl MethodAgg {
    fn first(ms: f64, dissim: u64, queue_ms: f64) -> Self {
        let mut agg = MethodAgg {
            count: 1,
            ms_min: ms,
            ms_sum: ms,
            ms_max: ms,
            dissim_min: dissim,
            dissim_sum: dissim,
            dissim_max: dissim,
            solve_hist: LatencyHist::default(),
            queue_hist: LatencyHist::default(),
        };
        agg.solve_hist.record(ms);
        agg.queue_hist.record(queue_ms);
        agg
    }

    fn add(&mut self, ms: f64, dissim: u64, queue_ms: f64) {
        self.count += 1;
        self.ms_min = self.ms_min.min(ms);
        self.ms_sum += ms;
        self.ms_max = self.ms_max.max(ms);
        self.dissim_min = self.dissim_min.min(dissim);
        self.dissim_sum += dissim;
        self.dissim_max = self.dissim_max.max(dissim);
        self.solve_hist.record(ms);
        self.queue_hist.record(queue_ms);
    }

    /// Mean latency in milliseconds.
    pub fn ms_mean(&self) -> f64 {
        self.ms_sum / self.count.max(1) as f64
    }

    /// Mean dissimilarity computations per job.
    pub fn dissim_mean(&self) -> f64 {
        self.dissim_sum as f64 / self.count.max(1) as f64
    }
}

/// Thread-safe per-method aggregates, keyed by method label.
#[derive(Default)]
pub struct MethodMetrics {
    inner: Mutex<BTreeMap<String, MethodAgg>>,
}

impl MethodMetrics {
    /// Empty registry.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one served job for `label`: solve+eval latency `ms`,
    /// dissimilarity count, and the job's queue wait `queue_ms`
    /// (`0.0` when the request never queued, e.g. direct library calls).
    pub fn record(&self, label: &str, ms: f64, dissim: u64, queue_ms: f64) {
        let mut map = sync_ext::lock_or_recover(&self.inner);
        match map.get_mut(label) {
            Some(agg) => agg.add(ms, dissim, queue_ms),
            None => {
                map.insert(label.to_string(), MethodAgg::first(ms, dissim, queue_ms));
            }
        }
    }

    /// Snapshot of every label's aggregate, sorted by label.
    pub fn snapshot(&self) -> Vec<(String, MethodAgg)> {
        let map = sync_ext::lock_or_recover(&self.inner);
        map.iter().map(|(k, v)| (k.clone(), *v)).collect()
    }

    /// Drop every aggregate (the `stats reset` wire command).
    pub fn reset(&self) {
        sync_ext::lock_or_recover(&self.inner).clear();
    }
}

/// Aggregate of one served model's `assign` traffic (protocol v6).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct ModelAgg {
    /// `assign` requests served from this model.
    pub count: u64,
    /// Total request latency (milliseconds) — mean = `ms_sum / count`.
    pub ms_sum: f64,
}

impl ModelAgg {
    /// Mean `assign` latency in milliseconds.
    pub fn ms_mean(&self) -> f64 {
        self.ms_sum / self.count.max(1) as f64
    }
}

/// Thread-safe per-model `assign` aggregates, keyed by registry name —
/// the serving-side analogue of [`MethodMetrics`] (same mutex-over-
/// BTreeMap shape, same `stats reset` lifecycle).  Kept outside the
/// [`crate::server::models::ModelRegistry`] on purpose: evicting or
/// replacing a model does not erase the traffic it already served.
#[derive(Default)]
pub struct ModelMetrics {
    inner: Mutex<BTreeMap<String, ModelAgg>>,
}

impl ModelMetrics {
    /// Empty registry.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one served `assign` for model `name` taking `ms`.
    pub fn record(&self, name: &str, ms: f64) {
        let mut map = sync_ext::lock_or_recover(&self.inner);
        let agg = map.entry(name.to_string()).or_default();
        agg.count += 1;
        agg.ms_sum += ms;
    }

    /// Snapshot of every model's aggregate, sorted by name.
    pub fn snapshot(&self) -> Vec<(String, ModelAgg)> {
        let map = sync_ext::lock_or_recover(&self.inner);
        map.iter().map(|(k, v)| (k.clone(), *v)).collect()
    }

    /// Drop every aggregate (the `stats reset` wire command).
    pub fn reset(&self) {
        sync_ext::lock_or_recover(&self.inner).clear();
    }
}

/// Every verb of the protocol v6 wire surface, in `stats` export order.
///
/// This table is the single source of truth the in-tree tidy lint
/// `verb-coverage` checks [`crate::server`]'s dispatch match against:
/// a verb handled on the wire but missing here (or from the protocol
/// doc block) fails `cargo run -p tidy`, so the counter and the docs
/// can never silently lag the dispatcher.
pub const VERBS: [&str; 13] = [
    "ping", "cluster", "submit", "poll", "wait", "cancel", "jobs", "stats", "sleep", "promote",
    "assign", "models", "evict",
];

/// Per-verb request counters (`verb.<name>=` stats fields): one atomic
/// per [`VERBS`] entry, bumped once per dispatched request line.
#[derive(Default)]
pub struct VerbCounters {
    counts: [AtomicU64; VERBS.len()],
}

impl VerbCounters {
    /// Fresh zeroed counters.
    pub fn new() -> Self {
        Self::default()
    }

    /// Count one dispatched request for `verb`.  Unknown strings are
    /// ignored — the dispatcher's unknown-command arm replies with an
    /// error and there is nothing meaningful to count it under.
    pub fn record(&self, verb: &str) {
        if let Some(i) = VERBS.iter().position(|v| *v == verb) {
            self.counts[i].fetch_add(1, Ordering::SeqCst);
        }
    }

    /// Requests counted for `verb` (0 for a string not in [`VERBS`]).
    pub fn get(&self, verb: &str) -> u64 {
        VERBS
            .iter()
            .position(|v| *v == verb)
            .map_or(0, |i| self.counts[i].load(Ordering::SeqCst))
    }

    /// `(verb, count)` pairs in [`VERBS`] (= wire export) order.
    pub fn snapshot(&self) -> Vec<(&'static str, u64)> {
        VERBS.iter().zip(&self.counts).map(|(v, c)| (*v, c.load(Ordering::SeqCst))).collect()
    }

    /// Zero every counter (the `stats reset` wire command).
    pub fn reset(&self) {
        for c in &self.counts {
            c.store(0, Ordering::SeqCst);
        }
    }
}

/// Lifetime counters of the asynchronous job registry (protocol v5).
///
/// `submitted` counts every accepted `submit` (including the implicit
/// one behind each served `cluster` line); the outcome counters
/// partition the jobs that reached a terminal state.  A deadline
/// expiry is a *shed*: the job was admitted but never ran, so
/// [`JobCounters::shed`] aliases `expired` for the `shed=` stats
/// field.  All counters are atomics — recording is lock-free.
#[derive(Default)]
pub struct JobCounters {
    submitted: AtomicU64,
    done: AtomicU64,
    failed: AtomicU64,
    cancelled: AtomicU64,
    expired: AtomicU64,
}

impl JobCounters {
    /// Fresh zeroed counters.
    pub fn new() -> Self {
        Self::default()
    }

    pub(crate) fn record_submitted(&self) {
        self.submitted.fetch_add(1, Ordering::SeqCst);
    }

    pub(crate) fn record_done(&self) {
        self.done.fetch_add(1, Ordering::SeqCst);
    }

    pub(crate) fn record_failed(&self) {
        self.failed.fetch_add(1, Ordering::SeqCst);
    }

    pub(crate) fn record_cancelled(&self) {
        self.cancelled.fetch_add(1, Ordering::SeqCst);
    }

    pub(crate) fn record_expired(&self) {
        self.expired.fetch_add(1, Ordering::SeqCst);
    }

    /// Jobs accepted by `submit` (and the `cluster` compatibility path).
    pub fn submitted(&self) -> u64 {
        self.submitted.load(Ordering::SeqCst)
    }

    /// Jobs that finished with a result.
    pub fn done(&self) -> u64 {
        self.done.load(Ordering::SeqCst)
    }

    /// Jobs that finished with an error (admission-after-load, solver
    /// failure, worker panic).
    pub fn failed(&self) -> u64 {
        self.failed.load(Ordering::SeqCst)
    }

    /// Jobs cancelled while queued or running.
    pub fn cancelled(&self) -> u64 {
        self.cancelled.load(Ordering::SeqCst)
    }

    /// Jobs shed because their `deadline_ms=` passed while queued.
    pub fn expired(&self) -> u64 {
        self.expired.load(Ordering::SeqCst)
    }

    /// Alias of [`JobCounters::expired`] — the `shed=` stats field.
    pub fn shed(&self) -> u64 {
        self.expired()
    }

    /// Zero every counter (the `stats reset` wire command).
    pub fn reset(&self) {
        for c in [&self.submitted, &self.done, &self.failed, &self.cancelled, &self.expired] {
            c.store(0, Ordering::SeqCst);
        }
    }
}

/// Connection instrumentation of the evented accept core (protocol v8
/// `conns=` / `waiters=` / `pipelined=` / `wakeups=` stats fields).
///
/// `conns` and `waiters` are *live gauges* (current open connections /
/// currently parked `wait`+`cluster` requests): [`ConnCounters::reset`]
/// leaves them alone, since `stats reset` re-bases traffic counters but
/// cannot close a connection.  `pipelined` (requests parsed after the
/// first on one connection) and `wakeups` (self-pipe fires observed by
/// the loop) are lifetime counters and do reset.  All atomics —
/// recording is lock-free on the event loop.
#[derive(Default)]
pub struct ConnCounters {
    conns: AtomicU64,
    waiters: AtomicU64,
    pipelined: AtomicU64,
    wakeups: AtomicU64,
}

impl ConnCounters {
    /// Fresh zeroed counters.
    pub fn new() -> Self {
        Self::default()
    }

    pub(crate) fn conn_opened(&self) {
        self.conns.fetch_add(1, Ordering::SeqCst);
    }

    pub(crate) fn conn_closed(&self) {
        self.conns.fetch_sub(1, Ordering::SeqCst);
    }

    pub(crate) fn waiter_parked(&self) {
        self.waiters.fetch_add(1, Ordering::SeqCst);
    }

    pub(crate) fn waiter_resolved(&self) {
        self.waiters.fetch_sub(1, Ordering::SeqCst);
    }

    pub(crate) fn record_pipelined(&self) {
        self.pipelined.fetch_add(1, Ordering::SeqCst);
    }

    pub(crate) fn record_wakeup(&self) {
        self.wakeups.fetch_add(1, Ordering::SeqCst);
    }

    /// Currently open connections (gauge).
    pub fn conns(&self) -> u64 {
        self.conns.load(Ordering::SeqCst)
    }

    /// Currently parked in-flight requests — blocked `wait`s plus
    /// `cluster` solves awaiting a worker (gauge).
    pub fn waiters(&self) -> u64 {
        self.waiters.load(Ordering::SeqCst)
    }

    /// Requests parsed after the first on one connection (counter).
    pub fn pipelined(&self) -> u64 {
        self.pipelined.load(Ordering::SeqCst)
    }

    /// Self-pipe wakeups the event loop observed (counter).
    pub fn wakeups(&self) -> u64 {
        self.wakeups.load(Ordering::SeqCst)
    }

    /// Zero the traffic counters (the `stats reset` wire command).  The
    /// `conns`/`waiters` gauges track live occupancy and stay put.
    pub fn reset(&self) {
        self.pipelined.store(0, Ordering::SeqCst);
        self.wakeups.store(0, Ordering::SeqCst);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn job_counters_record_and_reset() {
        let c = JobCounters::new();
        c.record_submitted();
        c.record_submitted();
        c.record_done();
        c.record_cancelled();
        c.record_expired();
        assert_eq!(
            (c.submitted(), c.done(), c.failed(), c.cancelled(), c.expired()),
            (2, 1, 0, 1, 1)
        );
        assert_eq!(c.shed(), c.expired(), "shed= aliases deadline expiries");
        c.reset();
        assert_eq!((c.submitted(), c.done(), c.cancelled(), c.shed()), (0, 0, 0, 0));
    }

    #[test]
    fn conn_counters_gauges_survive_reset() {
        let c = ConnCounters::new();
        c.conn_opened();
        c.conn_opened();
        c.conn_closed();
        c.waiter_parked();
        c.record_pipelined();
        c.record_pipelined();
        c.record_wakeup();
        assert_eq!((c.conns(), c.waiters(), c.pipelined(), c.wakeups()), (1, 1, 2, 1));
        c.reset();
        assert_eq!((c.pipelined(), c.wakeups()), (0, 0), "counters re-base");
        assert_eq!((c.conns(), c.waiters()), (1, 1), "live gauges survive reset");
        c.waiter_resolved();
        c.conn_closed();
        assert_eq!((c.conns(), c.waiters()), (0, 0));
    }

    #[test]
    fn verb_counters_record_known_verbs_only() {
        let v = VerbCounters::new();
        v.record("ping");
        v.record("submit");
        v.record("submit");
        v.record("definitely-not-a-verb");
        assert_eq!(v.get("ping"), 1);
        assert_eq!(v.get("submit"), 2);
        assert_eq!(v.get("cancel"), 0);
        assert_eq!(v.get("definitely-not-a-verb"), 0);
        let snap = v.snapshot();
        assert_eq!(snap.len(), VERBS.len());
        assert_eq!(snap.iter().map(|(_, n)| n).sum::<u64>(), 3);
        // snapshot order is the VERBS (wire export) order
        assert!(snap.iter().map(|(v, _)| *v).eq(VERBS));
        v.reset();
        assert_eq!(v.snapshot().iter().map(|(_, n)| n).sum::<u64>(), 0);
    }

    #[test]
    fn aggregates_count_min_mean_max() {
        let m = MethodMetrics::new();
        m.record("OneBatch-nniw", 2.0, 100, 0.0);
        m.record("OneBatch-nniw", 6.0, 300, 0.0);
        m.record("OneBatch-nniw", 4.0, 200, 0.0);
        let snap = m.snapshot();
        assert_eq!(snap.len(), 1);
        let (label, a) = &snap[0];
        assert_eq!(label, "OneBatch-nniw");
        assert_eq!(a.count, 3);
        assert_eq!((a.ms_min, a.ms_max), (2.0, 6.0));
        assert!((a.ms_mean() - 4.0).abs() < 1e-12);
        assert_eq!((a.dissim_min, a.dissim_max), (100, 300));
        assert!((a.dissim_mean() - 200.0).abs() < 1e-12);
    }

    #[test]
    fn snapshot_is_sorted_by_label() {
        let m = MethodMetrics::new();
        m.record("kmc2-20", 1.0, 1, 0.0);
        m.record("FasterPAM", 1.0, 1, 0.0);
        m.record("OneBatch-nniw", 1.0, 1, 0.0);
        let labels: Vec<String> = m.snapshot().into_iter().map(|(l, _)| l).collect();
        assert_eq!(labels, vec!["FasterPAM", "OneBatch-nniw", "kmc2-20"]);
    }

    #[test]
    fn concurrent_records_all_land() {
        let m = std::sync::Arc::new(MethodMetrics::new());
        let handles: Vec<_> = (0..8)
            .map(|i| {
                let m = m.clone();
                std::thread::spawn(move || {
                    for _ in 0..50 {
                        m.record("Random", i as f64, 10, 0.5);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let snap = m.snapshot();
        assert_eq!(snap[0].1.count, 400);
        assert_eq!(snap[0].1.dissim_sum, 4000);
        assert_eq!(snap[0].1.solve_hist.total(), 400);
        assert_eq!(snap[0].1.queue_hist.total(), 400);
    }

    #[test]
    fn histogram_buckets_latencies() {
        let mut h = LatencyHist::default();
        // one per edge-bounded bucket boundary case, plus the overflow
        h.record(0.5); // le 1
        h.record(1.0); // le 1 (le semantics: boundary counts down)
        h.record(1.5); // le 2
        h.record(9.0); // le 10
        h.record(99_999.0); // +inf
        let c = h.counts();
        assert_eq!(c[0], 2);
        assert_eq!(c[1], 1);
        assert_eq!(c[3], 1);
        assert_eq!(c[HIST_BUCKETS - 1], 1);
        assert_eq!(h.total(), 5);
        assert_eq!(h.wire().split(',').count(), HIST_BUCKETS);
        assert_eq!(hist_edges_wire().split(',').count(), HIST_BUCKETS);
        assert!(hist_edges_wire().ends_with(",inf"));
    }

    #[test]
    fn solve_and_queue_histograms_fill_separately() {
        let m = MethodMetrics::new();
        m.record("OneBatch-nniw", 30.0, 10, 0.2); // solve: le 50, queue: le 1
        m.record("OneBatch-nniw", 600.0, 10, 40.0); // solve: le 1000, queue: le 50
        let (_, a) = &m.snapshot()[0];
        assert_eq!(a.solve_hist.counts()[5], 1, "30 ms -> le 50");
        assert_eq!(a.solve_hist.counts()[9], 1, "600 ms -> le 1000");
        assert_eq!(a.queue_hist.counts()[0], 1, "0.2 ms -> le 1");
        assert_eq!(a.queue_hist.counts()[5], 1, "40 ms -> le 50");
    }

    #[test]
    fn model_metrics_aggregate_and_reset() {
        let m = ModelMetrics::new();
        m.record("prod", 2.0);
        m.record("prod", 4.0);
        m.record("m1", 1.0);
        let snap = m.snapshot();
        let names: Vec<&str> = snap.iter().map(|(n, _)| n.as_str()).collect();
        assert_eq!(names, vec!["m1", "prod"], "snapshot is name-sorted");
        let prod = &snap[1].1;
        assert_eq!(prod.count, 2);
        assert!((prod.ms_mean() - 3.0).abs() < 1e-12);
        m.reset();
        assert!(m.snapshot().is_empty());
    }

    #[test]
    fn reset_clears_everything() {
        let m = MethodMetrics::new();
        m.record("Random", 1.0, 1, 0.0);
        assert_eq!(m.snapshot().len(), 1);
        m.reset();
        assert!(m.snapshot().is_empty());
        // and the registry is usable again afterwards
        m.record("Random", 2.0, 2, 0.0);
        assert_eq!(m.snapshot()[0].1.count, 1);
    }
}
