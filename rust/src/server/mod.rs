//! Clustering job server: a std::net TCP service with a bounded job
//! queue and a worker pool (tokio is unavailable offline; on this
//! single-core testbed thread-per-worker is the right shape anyway).
//!
//! Line protocol (one request per connection line, one reply line):
//!
//! ```text
//! -> cluster dataset=blobs_2000_8_5 k=5 sampler=nniw seed=3 scale=1.0
//! <- ok medoids=4,17,... objective=0.1234 seconds=0.05 queue_ms=0.1
//! -> ping
//! <- pong
//! ```
//!
//! Backpressure: when the queue is full the server replies
//! `err queue full` immediately instead of accepting unbounded work.

use crate::backend::NativeBackend;
use crate::coordinator::{one_batch_pam, OneBatchConfig, SamplerKind};
use crate::data::synth;
use crate::dissim::{DissimCounter, Metric};
use crate::eval;
use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Server tuning knobs.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Bind address, e.g. "127.0.0.1:7878" (port 0 = ephemeral).
    pub addr: String,
    /// Worker threads.
    pub workers: usize,
    /// Max queued jobs before backpressure kicks in.
    pub queue_cap: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig { addr: "127.0.0.1:0".into(), workers: 2, queue_cap: 16 }
    }
}

/// Handle to a running server (join/shutdown + resolved address).
pub struct ServerHandle {
    /// The actually-bound address (useful with port 0).
    pub addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
}

impl ServerHandle {
    /// Ask the server to stop and join the accept loop.
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // unblock accept() with a dummy connection
        let _ = TcpStream::connect(self.addr);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

/// Parse `key=value` tokens after the command word.
fn parse_kv(parts: &[&str]) -> HashMap<String, String> {
    parts
        .iter()
        .filter_map(|p| p.split_once('='))
        .map(|(k, v)| (k.to_string(), v.to_string()))
        .collect()
}

/// Execute one `cluster` request (shared by server workers and tests).
pub fn handle_cluster(kv: &HashMap<String, String>) -> Result<String, String> {
    let dataset = kv.get("dataset").cloned().unwrap_or_else(|| "blobs_1000_8_5".into());
    let k: usize = kv.get("k").and_then(|s| s.parse().ok()).unwrap_or(10);
    let scale: f64 = kv.get("scale").and_then(|s| s.parse().ok()).unwrap_or(1.0);
    let seed: u64 = kv.get("seed").and_then(|s| s.parse().ok()).unwrap_or(0);
    let sampler = kv
        .get("sampler")
        .map(|s| SamplerKind::parse(s).ok_or(format!("unknown sampler {s}")))
        .transpose()?
        .unwrap_or(SamplerKind::Nniw);
    let metric = kv
        .get("metric")
        .map(|s| Metric::parse(s).ok_or(format!("unknown metric {s}")))
        .transpose()?
        .unwrap_or(Metric::L1);
    if k < 2 {
        return Err("k must be >= 2".into());
    }

    let data = std::panic::catch_unwind(|| synth::generate(&dataset, scale, seed))
        .map_err(|_| format!("unknown dataset {dataset}"))?;
    if data.n() <= k + 1 {
        return Err(format!("dataset too small (n={}) for k={k}", data.n()));
    }
    let backend = NativeBackend::new(metric);
    let cfg = OneBatchConfig { k, sampler, seed, ..Default::default() };
    let r = one_batch_pam(&data.x, &cfg, &backend).map_err(|e| e.to_string())?;
    let obj = eval::objective(&data.x, &r.medoids, &DissimCounter::new(metric));
    let meds: Vec<String> = r.medoids.iter().map(|m| m.to_string()).collect();
    Ok(format!(
        "ok medoids={} objective={obj:.6} seconds={:.4} dissim={}",
        meds.join(","),
        r.stats.seconds,
        r.stats.dissim_count
    ))
}

/// Dispatch one request line to a reply line.
pub fn handle_line(line: &str) -> String {
    let parts: Vec<&str> = line.split_whitespace().collect();
    match parts.first().copied() {
        Some("ping") => "pong".into(),
        Some("cluster") => match handle_cluster(&parse_kv(&parts[1..])) {
            Ok(r) => r,
            Err(e) => format!("err {e}"),
        },
        Some(cmd) => format!("err unknown command {cmd}"),
        None => "err empty request".into(),
    }
}

/// Start the server; returns immediately with a handle.
pub fn serve(cfg: ServerConfig) -> std::io::Result<ServerHandle> {
    let listener = TcpListener::bind(&cfg.addr)?;
    let addr = listener.local_addr()?;
    let stop = Arc::new(AtomicBool::new(false));
    let inflight = Arc::new(AtomicUsize::new(0));
    let queue_cap = cfg.queue_cap.max(1);
    // simple worker pool: connections are cheap, jobs are heavy, so the
    // bounded "queue" is the in-flight job counter.
    let pool: Arc<Mutex<()>> = Arc::new(Mutex::new(()));
    let _ = pool; // workers>1 handled by spawning per connection below

    let stop2 = stop.clone();
    let accept_thread = std::thread::spawn(move || {
        for conn in listener.incoming() {
            if stop2.load(Ordering::SeqCst) {
                break;
            }
            let Ok(stream) = conn else { continue };
            let inflight = inflight.clone();
            if inflight.load(Ordering::SeqCst) >= queue_cap {
                let mut s = stream;
                let _ = writeln!(s, "err queue full");
                continue;
            }
            inflight.fetch_add(1, Ordering::SeqCst);
            std::thread::spawn(move || {
                let _guard = DecrementOnDrop(inflight);
                let peer = stream.peer_addr().ok();
                let mut reader = BufReader::new(stream.try_clone().expect("clone stream"));
                let mut line = String::new();
                if reader.read_line(&mut line).is_ok() && !line.trim().is_empty() {
                    let started = Instant::now();
                    let reply = handle_line(line.trim());
                    let mut s = stream;
                    let _ = writeln!(s, "{reply} served_ms={:.1}", started.elapsed().as_secs_f64() * 1e3);
                    let _ = peer;
                }
            });
        }
    });

    Ok(ServerHandle { addr, stop, accept_thread: Some(accept_thread) })
}

struct DecrementOnDrop(Arc<AtomicUsize>);
impl Drop for DecrementOnDrop {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::SeqCst);
    }
}

/// Blocking client call: one request line -> reply line.
pub fn request(addr: std::net::SocketAddr, line: &str) -> std::io::Result<String> {
    let mut stream = TcpStream::connect(addr)?;
    writeln!(stream, "{line}")?;
    let mut reader = BufReader::new(stream);
    let mut reply = String::new();
    reader.read_line(&mut reply)?;
    Ok(reply.trim().to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ping_pong_and_cluster_roundtrip() {
        let h = serve(ServerConfig::default()).unwrap();
        assert!(request(h.addr, "ping").unwrap().starts_with("pong"));
        let r = request(h.addr, "cluster dataset=blobs_300_4_3 k=3 seed=1").unwrap();
        assert!(r.starts_with("ok medoids="), "{r}");
        assert!(r.contains("objective="));
        h.shutdown();
    }

    #[test]
    fn bad_requests_get_errors() {
        assert!(handle_line("nope").starts_with("err"));
        assert!(handle_line("").starts_with("err"));
        assert!(handle_line("cluster dataset=doesnotexist").starts_with("err"));
        assert!(handle_line("cluster k=1").starts_with("err"));
        assert!(handle_line("cluster sampler=bogus").starts_with("err"));
    }

    #[test]
    fn cluster_handler_is_deterministic() {
        let kv: HashMap<String, String> = [
            ("dataset", "blobs_300_4_3"),
            ("k", "3"),
            ("seed", "5"),
        ]
        .iter()
        .map(|(a, b)| (a.to_string(), b.to_string()))
        .collect();
        // strip the timing field (wall-clock varies run to run)
        let stable = |r: String| r.split(" seconds=").next().unwrap().to_string();
        assert_eq!(
            stable(handle_cluster(&kv).unwrap()),
            stable(handle_cluster(&kv).unwrap())
        );
    }
}
