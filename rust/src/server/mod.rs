//! Clustering job server: a std::net TCP service with a bounded job
//! queue and a fixed worker pool (tokio is unavailable offline;
//! thread-per-worker over a bounded queue is the right shape for
//! CPU-bound jobs anyway).
//!
//! Line protocol (one request per connection line, one reply line):
//!
//! ```text
//! -> cluster dataset=blobs_2000_8_5 k=5 sampler=nniw seed=3 scale=1.0 threads=4
//! <- ok medoids=4,17,... objective=0.1234 seconds=0.05 dissim=123456 served_ms=50.1
//! -> ping
//! <- pong
//! ```
//!
//! Concurrency model:
//!   * `ServerConfig::workers` long-lived worker threads drain accepted
//!     connections from an mpsc queue — cross-job parallelism;
//!   * each `cluster` job may additionally ask for data parallelism via
//!     the `threads=` key (a [`crate::runtime::Pool`] per job);
//!   * admission is a **single atomic** `fetch_update` on the in-flight
//!     counter (queued + running): a burst of connections can never
//!     push it past `queue_cap`, and rejected connections get an
//!     immediate `err queue full` line instead of unbounded queueing.

use crate::backend::NativeBackend;
use crate::coordinator::{one_batch_pam, OneBatchConfig, SamplerKind};
use crate::data::synth;
use crate::dissim::{DissimCounter, Metric};
use crate::eval;
use crate::runtime::Pool;
use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::Instant;

/// Server tuning knobs.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Bind address, e.g. "127.0.0.1:7878" (port 0 = ephemeral).
    pub addr: String,
    /// Worker threads draining the job queue (>= 1).
    pub workers: usize,
    /// Max in-flight jobs (queued + running) before backpressure.
    pub queue_cap: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig { addr: "127.0.0.1:0".into(), workers: 2, queue_cap: 16 }
    }
}

/// Handle to a running server (join/shutdown + resolved address).
pub struct ServerHandle {
    /// The actually-bound address (useful with port 0).
    pub addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl ServerHandle {
    /// Ask the server to stop, drain the queue and join every thread.
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // unblock accept() with a dummy connection
        let _ = TcpStream::connect(self.addr);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
        // the accept loop dropped the queue sender; workers drain and exit
        for t in self.workers.drain(..) {
            let _ = t.join();
        }
    }
}

/// Parse `key=value` tokens after the command word.
fn parse_kv(parts: &[&str]) -> HashMap<String, String> {
    parts
        .iter()
        .filter_map(|p| p.split_once('='))
        .map(|(k, v)| (k.to_string(), v.to_string()))
        .collect()
}

/// Execute one `cluster` request (shared by server workers and tests).
pub fn handle_cluster(kv: &HashMap<String, String>) -> Result<String, String> {
    let dataset = kv.get("dataset").cloned().unwrap_or_else(|| "blobs_1000_8_5".into());
    let k: usize = kv.get("k").and_then(|s| s.parse().ok()).unwrap_or(10);
    let scale: f64 = kv.get("scale").and_then(|s| s.parse().ok()).unwrap_or(1.0);
    let seed: u64 = kv.get("seed").and_then(|s| s.parse().ok()).unwrap_or(0);
    // capped: a request can use the machine, not fork-bomb it
    let threads: usize =
        kv.get("threads").and_then(|s| s.parse().ok()).unwrap_or(1).min(64);
    let sampler = kv
        .get("sampler")
        .map(|s| SamplerKind::parse(s).ok_or(format!("unknown sampler {s}")))
        .transpose()?
        .unwrap_or(SamplerKind::Nniw);
    let metric = kv
        .get("metric")
        .map(|s| Metric::parse(s).ok_or(format!("unknown metric {s}")))
        .transpose()?
        .unwrap_or(Metric::L1);
    if k < 2 {
        return Err("k must be >= 2".into());
    }

    let data = std::panic::catch_unwind(|| synth::generate(&dataset, scale, seed))
        .map_err(|_| format!("unknown dataset {dataset}"))?;
    if data.n() <= k + 1 {
        return Err(format!("dataset too small (n={}) for k={k}", data.n()));
    }
    let backend = NativeBackend::with_pool(metric, Pool::new(threads));
    let cfg = OneBatchConfig { k, sampler, seed, threads, ..Default::default() };
    let r = one_batch_pam(&data.x, &cfg, &backend).map_err(|e| e.to_string())?;
    let obj = eval::objective(&data.x, &r.medoids, &DissimCounter::new(metric));
    let meds: Vec<String> = r.medoids.iter().map(|m| m.to_string()).collect();
    Ok(format!(
        "ok medoids={} objective={obj:.6} seconds={:.4} dissim={}",
        meds.join(","),
        r.stats.seconds,
        r.stats.dissim_count
    ))
}

/// Dispatch one request line to a reply line.
pub fn handle_line(line: &str) -> String {
    let parts: Vec<&str> = line.split_whitespace().collect();
    match parts.first().copied() {
        Some("ping") => "pong".into(),
        Some("cluster") => match handle_cluster(&parse_kv(&parts[1..])) {
            Ok(r) => r,
            Err(e) => format!("err {e}"),
        },
        // Diagnostic: hold a worker for `ms` (capped) — used by the
        // backpressure tests and for probing queue behaviour under load.
        Some("sleep") => {
            let kv = parse_kv(&parts[1..]);
            let ms: u64 = kv.get("ms").and_then(|s| s.parse().ok()).unwrap_or(0).min(10_000);
            std::thread::sleep(std::time::Duration::from_millis(ms));
            format!("ok slept_ms={ms}")
        }
        Some(cmd) => format!("err unknown command {cmd}"),
        None => "err empty request".into(),
    }
}

/// How long a worker waits for a client to send its request line (or
/// accept the reply) before giving the slot back.  Without this, a
/// handful of idle connections could pin every worker forever.
const IO_TIMEOUT: std::time::Duration = std::time::Duration::from_secs(10);

/// Serve one accepted connection: read a line, dispatch, reply.
fn handle_connection(stream: TcpStream) {
    let _ = stream.set_read_timeout(Some(IO_TIMEOUT));
    let _ = stream.set_write_timeout(Some(IO_TIMEOUT));
    let Ok(clone) = stream.try_clone() else { return };
    let mut reader = BufReader::new(clone);
    let mut line = String::new();
    if reader.read_line(&mut line).is_ok() && !line.trim().is_empty() {
        let started = Instant::now();
        let reply = handle_line(line.trim());
        let mut s = stream;
        let _ = writeln!(s, "{reply} served_ms={:.1}", started.elapsed().as_secs_f64() * 1e3);
    }
}

/// Start the server; returns immediately with a handle.
pub fn serve(cfg: ServerConfig) -> std::io::Result<ServerHandle> {
    let listener = TcpListener::bind(&cfg.addr)?;
    let addr = listener.local_addr()?;
    let stop = Arc::new(AtomicBool::new(false));
    let inflight = Arc::new(AtomicUsize::new(0));
    let queue_cap = cfg.queue_cap.max(1);
    let worker_count = cfg.workers.max(1);

    // Bounded job queue: admission reserves a slot in `inflight` before
    // enqueueing; the worker releases it when the job finishes, so
    // queued + running <= queue_cap always holds.
    let (tx, rx) = mpsc::channel::<TcpStream>();
    let rx = Arc::new(Mutex::new(rx));
    let mut workers = Vec::with_capacity(worker_count);
    for _ in 0..worker_count {
        let rx = rx.clone();
        let inflight = inflight.clone();
        workers.push(std::thread::spawn(move || loop {
            // the guard temporary drops at the end of this statement, so
            // workers do not hold the lock while serving
            let job = rx.lock().expect("queue receiver poisoned").recv();
            let Ok(stream) = job else { break };
            let _slot = DecrementOnDrop(inflight.clone());
            // a panicking job must not shrink the long-lived pool
            let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                handle_connection(stream);
            }));
        }));
    }

    let stop2 = stop.clone();
    let inflight2 = inflight.clone();
    let accept_thread = std::thread::spawn(move || {
        for conn in listener.incoming() {
            if stop2.load(Ordering::SeqCst) {
                break;
            }
            let Ok(stream) = conn else { continue };
            // single-RMW admission: reserve a slot or reject — no
            // check-then-increment window for a burst to slip through
            let admitted = inflight2
                .fetch_update(Ordering::SeqCst, Ordering::SeqCst, |c| {
                    if c < queue_cap {
                        Some(c + 1)
                    } else {
                        None
                    }
                })
                .is_ok();
            if !admitted {
                let mut s = stream;
                let _ = writeln!(s, "err queue full");
                continue;
            }
            if tx.send(stream).is_err() {
                break;
            }
        }
        // dropping `tx` wakes every idle worker with RecvError -> exit
    });

    Ok(ServerHandle { addr, stop, accept_thread: Some(accept_thread), workers })
}

struct DecrementOnDrop(Arc<AtomicUsize>);
impl Drop for DecrementOnDrop {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::SeqCst);
    }
}

/// Blocking client call: one request line -> reply line.
pub fn request(addr: std::net::SocketAddr, line: &str) -> std::io::Result<String> {
    let mut stream = TcpStream::connect(addr)?;
    writeln!(stream, "{line}")?;
    let mut reader = BufReader::new(stream);
    let mut reply = String::new();
    reader.read_line(&mut reply)?;
    Ok(reply.trim().to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ping_pong_and_cluster_roundtrip() {
        let h = serve(ServerConfig::default()).unwrap();
        assert!(request(h.addr, "ping").unwrap().starts_with("pong"));
        let r = request(h.addr, "cluster dataset=blobs_300_4_3 k=3 seed=1").unwrap();
        assert!(r.starts_with("ok medoids="), "{r}");
        assert!(r.contains("objective="));
        h.shutdown();
    }

    #[test]
    fn bad_requests_get_errors() {
        assert!(handle_line("nope").starts_with("err"));
        assert!(handle_line("").starts_with("err"));
        assert!(handle_line("cluster dataset=doesnotexist").starts_with("err"));
        assert!(handle_line("cluster k=1").starts_with("err"));
        assert!(handle_line("cluster sampler=bogus").starts_with("err"));
    }

    #[test]
    fn cluster_handler_is_deterministic() {
        let kv: HashMap<String, String> = [
            ("dataset", "blobs_300_4_3"),
            ("k", "3"),
            ("seed", "5"),
        ]
        .iter()
        .map(|(a, b)| (a.to_string(), b.to_string()))
        .collect();
        // strip the timing field (wall-clock varies run to run)
        let stable = |r: String| r.split(" seconds=").next().unwrap().to_string();
        assert_eq!(
            stable(handle_cluster(&kv).unwrap()),
            stable(handle_cluster(&kv).unwrap())
        );
    }

    #[test]
    fn threaded_cluster_matches_serial_cluster() {
        let mk = |threads: &str| -> String {
            let kv: HashMap<String, String> = [
                ("dataset", "blobs_400_4_3"),
                ("k", "3"),
                ("seed", "6"),
                ("threads", threads),
            ]
            .iter()
            .map(|(a, b)| (a.to_string(), b.to_string()))
            .collect();
            let r = handle_cluster(&kv).unwrap();
            r.split(" seconds=").next().unwrap().to_string()
        };
        assert_eq!(mk("1"), mk("4"));
    }

    #[test]
    fn workers_serve_concurrently() {
        // With 4 workers, 4 concurrent 150 ms sleeps finish in ~1 batch,
        // far below the 600 ms serial floor.
        let h = serve(ServerConfig { addr: "127.0.0.1:0".into(), workers: 4, queue_cap: 8 })
            .unwrap();
        let t0 = Instant::now();
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let addr = h.addr;
                std::thread::spawn(move || request(addr, "sleep ms=150").unwrap())
            })
            .collect();
        for th in handles {
            assert!(th.join().unwrap().starts_with("ok slept_ms=150"));
        }
        let elapsed = t0.elapsed().as_millis();
        assert!(elapsed < 550, "4 workers should overlap sleeps, took {elapsed} ms");
        h.shutdown();
    }

    #[test]
    fn sleep_command_caps_duration() {
        let r = handle_line("sleep ms=1");
        assert!(r.starts_with("ok slept_ms=1"), "{r}");
    }
}
