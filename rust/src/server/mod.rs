//! Clustering job server: a std::net TCP service with a readiness-driven
//! evented connection core ([`event`]), an asynchronous job registry
//! (connection lifetime is decoupled from job lifetime), solver workers
//! that drain *jobs* rather than connections, cost-weighted admission
//! with deadlines, server-owned execution pools, and a sharded dataset
//! cache that loads cold misses outside its locks.
//!
//! # Line protocol v9 (newline-delimited requests, pipelining allowed)
//!
//! ```text
//! -> cluster dataset=blobs_2000_8_5 k=5 method=FasterPAM seed=3 threads=4
//! <- ok method=FasterPAM cache=miss medoids=4,17,... objective=0.1234 seconds=0.05 dissim=123456 swaps=9 source=synth:blobs_2000_8_5 cost=4000000 inertia=0.1234 profile=fast bytes=80016000 queue_ms=0.2 served_ms=50.1
//! -> cluster dataset=npy:/data/features.npy k=5 seed=3
//! <- ok method=OneBatch-nniw cache=stream medoids=... objective=... seconds=... dissim=... swaps=... source=npy:/data/features.npy cost=61200 inertia=... profile=fast bytes=147456 queue_ms=0.0 served_ms=88.2
//! -> submit dataset=blobs_2000_8_5 k=5 seed=3 deadline_ms=5000
//! <- ok job=j7 cost=61200 queue_ms=0.0 served_ms=0.1
//! -> poll job=j7
//! <- ok job=j7 state=running cost=61200 waited_ms=1.4 queue_ms=0.0 served_ms=0.0
//! -> wait job=j7 timeout_ms=30000
//! <- ok method=OneBatch-nniw cache=hit medoids=... objective=... seconds=... dissim=... swaps=... source=... cost=61200 inertia=... profile=fast queue_ms=0.0 served_ms=48.9
//! -> cancel job=j8
//! <- ok job=j8 state=cancelled queue_ms=0.0 served_ms=0.0
//! -> jobs
//! <- ok queued=0 running=1 retained=4 submitted=9 done=6 failed=1 cancelled=1 expired=1 shed=1 queue_ms=0.0 served_ms=0.0
//! -> promote job=j7 name=blobs
//! <- ok model=blobs job=j7 k=5 dim=8 metric=l1 inertia=0.1234 queue_ms=0.0 served_ms=0.1
//! -> assign model=blobs point=0.1,0.2,... point=3.4,3.5,...
//! <- ok model=blobs n=2 labels=0,4 dists=0.123456,0.987654 queue_ms=0.0 served_ms=0.2
//! -> models
//! <- ok count=1 cap=32 promoted=1 evicted=0 model.blobs.job=j7 model.blobs.method=FasterPAM ... queue_ms=0.0 served_ms=0.0
//! -> evict model=blobs
//! <- ok evicted model=blobs queue_ms=0.0 served_ms=0.0
//! -> stats
//! <- ok cache_hits=12 cache_misses=3 cache_entries=3 budget_total=... budget_used=... mem_total=... mem_used=... hist_le_ms=1,2,... jobs.submitted=9 ... shed=1 pools=2 models=1 conns=1 waiters=0 pipelined=3 wakeups=7 method.FasterPAM.count=2 ... model.blobs.assign_count=2 ... queue_ms=0.0 served_ms=0.0
//! -> ping
//! <- pong queue_ms=0.0 served_ms=0.0
//! ```
//!
//! v9 over v8: **out-of-core data sources and byte-aware admission**.
//! Every v8 reply prefix is byte-identical; the only change to existing
//! replies is a trailing `bytes=` field on `cluster`/`wait`
//! done-replies (the peak resident bytes the job's admission permit
//! held) and the `mem_total=`/`mem_used=` gauges on `stats`.  The new
//! surface:
//!
//! * `dataset=npy:<path>` — stream a NumPy `.npy` array (v1.0/v2.0
//!   header, C-order `<f4`/`<f8`) straight from disk, and
//!   `dataset=dir:<path>` — a directory of numbered CSV or `.npy`
//!   shards with a `manifest` row count ([`DataSource`] grammar).
//! * OneBatch methods over `npy:`/`dir:` run **out of core**: the
//!   `m x p` batch slice is gathered once and every fused sweep reads
//!   the source chunk-at-a-time through a [`crate::data::RowStore`]
//!   ([`solver::solve_fitted_store`]) — the full `n x p` matrix is
//!   never resident, the dataset cache is bypassed (`cache=stream` in
//!   the reply), and the medoids/objective bits equal the resident
//!   solve of the same bytes at every thread width.  Non-OneBatch
//!   methods over a stream source load resident through the cache and
//!   must fit the byte budget.
//! * **admission is two-axis**: jobs are priced in work units *and*
//!   peak resident bytes ([`JobCost::resident_bytes`] — full-matrix
//!   methods price `n*p*4 + n*n*4`, a streaming OneBatch only its
//!   batch slice plus one chunk buffer, [`MethodSpec::streaming_cost`]).
//!   Both axes reserve from the [`AdmissionBudget`]
//!   ([`ServerConfig::byte_budget`], `--byte-budget` on the CLI) under
//!   the same RAII permit; a job over either axis gets
//!   `err over budget ...` / `err over byte budget: bytes=...`, and
//!   the lone-job idle exception / `strict_budget` rule applies to
//!   bytes exactly as it does to units.
//! * the dataset cache refuses to *load* a matrix larger than the byte
//!   budget ([`DatasetCache::with_byte_limit`]) — an oversized
//!   `file:`/`npy:` load fails with its priced `bytes=` instead of
//!   OOM-ing the server; streams never enter the cache by design.
//!
//! v8 over v7: **no reply byte changed** — the delta is connection
//! semantics.  A connection is no longer one-request-one-reply: clients
//! may keep it open and *pipeline* — write any number of request lines
//! before reading replies — and replies come back strictly in request
//! order, each carrying its own `queue_ms=`/`served_ms=` trailer (a v1
//! client that writes one line and reads one line observes nothing
//! new).  Underneath, the thread-per-connection accept path is replaced
//! by the readiness-driven event loop in [`event`]: idle and parked
//! connections cost a registry entry instead of an OS thread, `wait`ers
//! park on a timer wheel and are woken by job completion through a
//! self-pipe, and the cheap verbs (`assign`, `poll`, `models`, `stats`,
//! `jobs`, ...) are answered directly on the loop.  New knobs/fields:
//! [`ServerConfig::conn_cap`] bounds concurrent connections (beyond it:
//! `err queue full`), and `stats` reports `conns=` / `waiters=` /
//! `pipelined=` / `wakeups=` connection telemetry
//! ([`metrics::ConnCounters`]; the gauges survive `stats reset`, the
//! counters re-base).  A blank request line still ends the
//! conversation, and `sleep` still occupies one of `queue_cap`
//! diagnostic slots, preserving the v4 burst-backpressure contract.
//!
//! v7 over v6: the distance kernels carry a **compute profile**.
//! `profile=` (`exact` | `fast`, default `fast` on the wire) selects
//! between the bit-identical paper-reproduction kernels and the
//! dot-product SqL2/L2 path ([`crate::dissim::ComputeProfile`]);
//! `cluster`/`wait` done-replies append a trailing `profile=` after the
//! v6 `inertia=` field (every v1–v6 prefix stays byte-identical), an
//! unknown value is an `err`, and `assign` accepts the same key for its
//! serving kernels.  `assign` itself now runs allocation-free on
//! per-model scratch buffers ([`models::AssignScratch`]).
//!
//! v6 over v5: every v5 request line — including the legacy v1–v4
//! forms — still produces a byte-identical reply prefix; the only
//! change to an existing reply is a new *trailing* `inertia=` field on
//! `cluster`/`wait` done-replies (mean distance to the nearest medoid,
//! the quantity `assign` serves).  The new surface is the **fitted-model
//! read path**: solving stashes a dataset-free [`solver::FittedModel`]
//! (medoid feature vectors + metric, no training arrays) on the done
//! job, and the serving verbs route through a bounded [`ModelRegistry`]:
//!
//! * `promote job=j<id> [name=<handle>]` — capture the done job's
//!   fitted model into the registry under `name` (auto `m<id>` when
//!   omitted; user names are `[A-Za-z0-9_.-]{1,64}` and may not shadow
//!   the reserved `m<digits>` shape).  Re-promoting an existing name
//!   replaces it in place.  Replies
//!   `ok model=<name> job=j<id> k=... dim=... metric=... inertia=...`;
//!   a queued/running job gets `err job j<id> is <state> ...`, an
//!   evicted or failed one `err`.  Past
//!   [`ServerConfig::model_cap`] the coldest model is LRU-evicted.
//! * `assign model=<name> point=v1,v2,... [point=...] [metric=] [top2=1] [profile=]`
//!   — label points against a promoted model *without any dataset in
//!   memory*: each `point=` is one comma-joined feature row (repeats
//!   batch, wire order preserved), the reply is
//!   `ok model=<name> n=<N> labels=... dists=...` (plus `second=`/
//!   `dists2=` under `top2=1`, the medoid-swap lower-bound pair).  A
//!   `metric=` that disagrees with the fit, a wrong dimension, or a
//!   non-finite coordinate is an `err`, never garbage labels.
//! * `models` — registry inventory: `count=`/`cap=` occupancy, lifetime
//!   `promoted=`/`evicted=` (LRU only), then one name-sorted
//!   `model.<name>.job/method/k/dim/metric/inertia/source` group per
//!   retained model.
//! * `evict model=<name>` — drop a model explicitly
//!   (`ok evicted model=<name>`); not counted as an LRU eviction.
//! * `stats` additionally reports the `models=` occupancy gauge and
//!   per-model serving aggregates
//!   `model.<name>.assign_count=`/`model.<name>.assign_ms_mean=`
//!   (kept outside the registry, so eviction does not erase traffic
//!   history; `stats reset` re-bases them).
//!
//! The v5 async-job surface, unchanged underneath:
//!
//! * `submit <cluster keys> [deadline_ms=N]` — validate, price and
//!   admit the job (reserving its [`JobCost::units`] from the
//!   [`AdmissionBudget`]), enqueue it, and reply immediately with a
//!   monotonic handle: `ok job=j<id> cost=<units>`.  Sources that
//!   cannot predict their row count (a hint-less `file:`) report
//!   `cost=0` and are priced right after their load, inside the job
//!   (`poll` reflects the settled price once the job runs).  The job
//!   queue itself is bounded by [`ServerConfig::queue_cap`]: once that
//!   many jobs are queued, further submits get `err queue full ...` —
//!   without this, submit-and-disconnect traffic would be unbounded.
//! * `poll job=j<id>` — non-blocking state probe:
//!   `ok job=j<id> state=queued|running cost=... waited_ms=...` while
//!   in flight (`waited_ms` is the queue wait so far — the trailing
//!   `queue_ms=` every wire reply carries stays connection-level),
//!   `state=done <full cluster reply body>` /
//!   `state=failed|expired error=<message>` / `state=cancelled` once
//!   terminal, `err unknown job j<id>` after eviction.
//! * `wait job=j<id> [timeout_ms=N]` — park (a timer-wheel entry on
//!   the event loop, no thread and no polling) until the job is
//!   terminal or the timeout elapses.  A finished job
//!   replies with its stored `cluster` reply verbatim; a failed one
//!   with its stored `err ...`; a timeout with
//!   `ok job=j<id> state=... timed_out=1`.
//! * `cancel job=j<id>` — cooperative cancellation: a queued job is
//!   cancelled on the spot (admission permit released), a running job
//!   has its [`CancelToken`] flipped, which OneBatchPAM checks between
//!   swap passes (`ok job=j<id> state=running cancel=requested`); a
//!   terminal job is left unchanged (idempotent).
//! * `jobs` — registry gauges: queued / running / retained occupancy
//!   plus the lifetime submitted / done / failed / cancelled / expired
//!   counters (`shed=` aliases `expired=`).
//! * `deadline_ms=` — accepted by `submit` *and* `cluster`: the job is
//!   shed if the deadline passes while it is still queued
//!   (`err deadline job=j<id> deadline_ms=... queue_ms=...`), its
//!   permit released and the shed recorded in the `shed=` stats field.
//!   Deadlines bound queue wait, not run time.
//! * request lines are tokenized with double-quote support, so `file:`
//!   paths containing spaces are now wire-addressable:
//!   `dataset="file:/data/my points.csv"` (quotes may wrap any value;
//!   an unterminated quote is a protocol error).  This lifts the
//!   documented v4 limitation.
//! * `stats` additionally exports the `jobs.*` lifecycle fields,
//!   `shed=`, `pools=` (distinct execution-pool widths cached by the
//!   server) and one `verb.<name>=` request counter per wire verb
//!   ([`metrics::VERBS`]); `stats reset` re-bases the job and verb
//!   counters along with the method aggregates and cache counters.
//! * `sleep ms=N` — diagnostic: delay this request's reply by `ms`
//!   milliseconds (capped at 10 s), then answer `ok slept_ms=N`.  Used
//!   by the backpressure tests; it occupies one of `queue_cap`
//!   diagnostic timer slots on the event loop — never a solver worker,
//!   and (since v8) not a thread either.
//!
//! `cluster` keys (unchanged from v4, plus `deadline_ms=`):
//!
//! * `dataset=` — a [`DataSource`] URI: `synth:<name>` generates,
//!   `file:<path>[?rows=N]` loads a numeric CSV from disk, and a bare
//!   name aliases `synth:` (every v2 request line is still valid).
//! * `scale=`, `seed=` — synthetic-generation knobs (`seed=` also seeds
//!   the algorithm; a non-neutral `scale=` with a `file:` source is an
//!   error).  Requests route through a sharded LRU dataset cache
//!   ([`DatasetCache`], bounded by [`ServerConfig::cache_cap`]); every
//!   reply reports `cache=hit|miss`, and `file:` fingerprints mix size
//!   + mtime so edits self-invalidate.
//! * `method=` — any [`MethodSpec`] label (`FasterPAM`,
//!   `FasterCLARA-50`, `BanditPAM++-2`, `OneBatch-nniw-steepest`, ...).
//!   Omitted -> legacy v1 behaviour: OneBatchPAM with `sampler=`
//!   (default `nniw`) and `strategy=` (default `eager`).  Methods the
//!   paper marks "Na" at large scale are rejected above
//!   [`FULL_MATRIX_LIMIT`] rows *before* loading, using the source's
//!   row hint.
//! * `metric=` — any [`Metric`] spelling (`l1` default, `l2`,
//!   `sqeuclidean`, `chebyshev`, `cosine`).
//! * `profile=` — distance-kernel profile: `fast` (default, dot-product
//!   SqL2/L2 path, tolerance-equal) or `exact` (bit-identical
//!   paper-reproduction kernels).  Echoed back as the done-reply's
//!   trailing `profile=` field.
//! * `scale_features=` — `minmax` | `none` (default `none`).
//! * `k=`, `threads=` — shared run parameters.
//! * `m=`, `eps=`, `max_passes=`, `strategy=`, `sampler=` — OneBatch
//!   knobs; sending one alongside a non-OneBatch `method=` is an
//!   error, as is any present-but-unparsable value (`err ...` replies).
//!
//! # Concurrency model
//!
//! * the accept path is a single readiness-driven **event loop**
//!   ([`event`]): nonblocking sockets multiplexed over `poll(2)`, one
//!   per-connection state machine (read buffer, in-order pending
//!   queue, write buffer) per client, admitted up to
//!   [`ServerConfig::conn_cap`] (`err queue full` beyond it).  Cheap
//!   verbs are answered on the loop; `wait`/`cluster` park as
//!   timer-wheel entries and job completion wakes the loop through a
//!   self-pipe.  A slow or long-`wait`ing client therefore costs a
//!   registry entry — never a thread, and never a solver worker;
//! * [`ServerConfig::workers`] long-lived solver workers (`0` = auto)
//!   drain the [`JobRegistry`] queue: pick a job, shed it if its
//!   deadline passed while queued, otherwise run the solve and publish
//!   the terminal state.  Queue wait (submit-to-pickup) feeds the
//!   per-method queue histograms, succeeding v4's accept-to-pickup
//!   measure;
//! * **job admission is weighted by cost**: every job is priced via
//!   [`MethodSpec::cost`] over the source's predicted rows and must
//!   reserve its work units from the [`AdmissionBudget`]
//!   ([`ServerConfig::budget`]) at submit time.  The permit is held
//!   from admission to the job's terminal state — cancelled and
//!   deadline-shed jobs release it without ever running.  An oversized
//!   job may still run when the budget is completely idle, unless
//!   [`ServerConfig::strict_budget`] disables that lone-job exception;
//! * jobs reuse **server-owned execution pools**: a [`PoolCache`] keyed
//!   by resolved thread width hands every job a clone of one persistent
//!   [`Pool`] per width, so repeated `threads=4` jobs wake the same
//!   parked workers instead of spawning fresh ones (results stay
//!   bit-identical across reuse — rust/tests/parallel_equivalence.rs);
//! * the dataset cache is sharded and loads cold misses *outside* the
//!   shard lock behind per-key in-flight markers.

pub mod cache;
pub(crate) mod event;
pub mod jobs;
pub mod metrics;
pub mod models;

pub use cache::{CacheStats, DatasetCache};
pub use jobs::{FittedLookup, JobGauges, JobRegistry, JobState, JobView, WaitOutcome};
pub use metrics::{
    ConnCounters, JobCounters, MethodAgg, MethodMetrics, ModelAgg, ModelMetrics, VerbCounters,
    VERBS,
};
pub use models::{AssignScratch, ModelGauges, ModelRecord, ModelRegistry, ModelSeed};

use crate::backend::NativeBackend;
use crate::coordinator::{SamplerKind, SwapStrategy};
use crate::data::{DataSource, FeatureScaling};
use crate::dissim::{ComputeProfile, DissimCounter, Metric};
use crate::eval;
use crate::runtime::Pool;
use crate::solver::{self, CancelToken, JobCost, MethodSpec, SolveSpec, MAX_JOB_COST};
use crate::sync_ext;
use std::collections::{HashMap, VecDeque};
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::num::NonZeroUsize;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Server tuning knobs.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Bind address, e.g. "127.0.0.1:7878" (port 0 = ephemeral).
    pub addr: String,
    /// Solver worker threads draining the job registry; `0` =
    /// auto-detect (`available_parallelism`), matching `Pool::new(0)` /
    /// `--threads 0`.
    pub workers: usize,
    /// Max queued (not-yet-running) jobs before backpressure, and the
    /// event loop's bound on concurrent `sleep` diagnostic slots;
    /// `0` = 4x the resolved worker count.  Since v8 connections are
    /// bounded separately by [`ServerConfig::conn_cap`] — a parked
    /// `cluster`/`wait` costs a registry entry, not a thread, so it no
    /// longer competes with job admission.
    pub queue_cap: usize,
    /// Dataset-cache budget in datasets (split across shards, LRU).
    pub cache_cap: usize,
    /// Weighted-admission budget in work units (see [`JobCost`]);
    /// `0` = 4x [`MAX_JOB_COST`] (room for one limit-sized full-matrix
    /// job plus plenty of cheap OneBatch traffic).
    pub budget: u64,
    /// Disable the lone-job idle exception of the admission budget:
    /// when `true`, a job whose cost exceeds the budget is rejected
    /// even when nothing else is in flight.  Default `false` preserves
    /// the v4 behaviour (`--strict-budget` on the CLI).  Applies to
    /// both admission axes (work units and resident bytes).
    pub strict_budget: bool,
    /// Byte axis of the admission budget: the total peak resident bytes
    /// ([`JobCost::resident_bytes`]) concurrently-admitted jobs may
    /// pin, and the ceiling the dataset cache refuses loads above;
    /// `0` = 8 GiB (`--byte-budget` on the CLI).
    pub byte_budget: u64,
    /// How many *finished* jobs the registry retains for later
    /// `poll`/`wait` calls (LRU eviction); `0` = 64.
    pub retain_cap: usize,
    /// How many promoted models the [`ModelRegistry`] retains for
    /// `assign` serving (LRU eviction); `0` = 32.
    pub model_cap: usize,
    /// Max concurrent client connections the event loop admits before
    /// rejecting with `err queue full`; `0` = 8192.  Distinct from
    /// `queue_cap`: since v8 a connection is just a registry entry, so
    /// the bound exists to cap memory, not threads.
    pub conn_cap: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            addr: "127.0.0.1:0".into(),
            workers: 2,
            queue_cap: 16,
            cache_cap: 32,
            budget: 0,
            strict_budget: false,
            byte_budget: 0,
            retain_cap: 0,
            model_cap: 0,
            conn_cap: 0,
        }
    }
}

impl ServerConfig {
    /// `workers` with `0` resolved to the detected core count.
    pub fn resolved_workers(&self) -> usize {
        if self.workers == 0 {
            std::thread::available_parallelism().map(NonZeroUsize::get).unwrap_or(1)
        } else {
            self.workers
        }
    }

    /// `queue_cap` with `0` resolved to 4x the resolved worker count.
    pub fn resolved_queue_cap(&self) -> usize {
        if self.queue_cap == 0 {
            self.resolved_workers() * 4
        } else {
            self.queue_cap
        }
    }

    /// `budget` with `0` resolved to the default (4x [`MAX_JOB_COST`]).
    pub fn resolved_budget(&self) -> u64 {
        if self.budget == 0 {
            4 * MAX_JOB_COST
        } else {
            self.budget
        }
    }

    /// `byte_budget` with `0` resolved to the default (8 GiB).
    pub fn resolved_byte_budget(&self) -> u64 {
        if self.byte_budget == 0 {
            8 << 30
        } else {
            self.byte_budget
        }
    }

    /// `retain_cap` with `0` resolved to the default (64 finished jobs).
    pub fn resolved_retain_cap(&self) -> usize {
        if self.retain_cap == 0 {
            64
        } else {
            self.retain_cap
        }
    }

    /// `model_cap` with `0` resolved to the default (32 models).
    pub fn resolved_model_cap(&self) -> usize {
        if self.model_cap == 0 {
            32
        } else {
            self.model_cap
        }
    }

    /// `conn_cap` with `0` resolved to the default (8192 connections).
    pub fn resolved_conn_cap(&self) -> usize {
        if self.conn_cap == 0 {
            8192
        } else {
            self.conn_cap
        }
    }
}

/// The weighted-admission budget: a pool of work units that every
/// in-flight job holds its [`JobCost::units`] from — reserved at
/// submit, released when the job reaches a terminal state (permit
/// drop), whether it ran, failed, was cancelled or was shed.
///
/// A job is admitted when its units fit the remaining budget — or when
/// the budget is completely idle, so one oversized-but-admissible job
/// (e.g. OneBatchPAM over millions of rows) can still run alone instead
/// of being starved forever by a budget smaller than itself.  That
/// lone-job exception can be disabled ([`AdmissionBudget::with_strict`]
/// / [`ServerConfig::strict_budget`]) for deployments that prefer a
/// hard ceiling.
///
/// Since v9 the budget is **two-axis**: alongside work units, every
/// permit may hold peak resident *bytes* ([`JobCost::resident_bytes`])
/// against a separate `byte_total` ceiling
/// ([`AdmissionBudget::with_limits`] / [`ServerConfig::byte_budget`]).
/// The byte axis follows the unit axis's rules exactly — single-RMW
/// reservation, saturating release, the lone-job idle exception, and
/// `strict` disabling it — and a `byte_total` of `0` leaves the axis
/// unmetered (the pre-v9 constructors), so unit-only callers are
/// unchanged.
pub struct AdmissionBudget {
    total: u64,
    byte_total: u64,
    strict: bool,
    used: AtomicU64,
    bytes_used: AtomicU64,
    /// Debug-build flow counter: units ever reserved (admits plus the
    /// `new` side of every reprice).
    #[cfg(debug_assertions)]
    reserved_flow: AtomicU64,
    /// Debug-build flow counter: units ever released (permit drops plus
    /// the `old` side of every reprice).
    #[cfg(debug_assertions)]
    released_flow: AtomicU64,
    /// Debug-build flow counter: bytes ever reserved.
    #[cfg(debug_assertions)]
    reserved_bytes_flow: AtomicU64,
    /// Debug-build flow counter: bytes ever released.
    #[cfg(debug_assertions)]
    released_bytes_flow: AtomicU64,
}

/// Which axis of the two-axis [`AdmissionBudget`] rejected an
/// admission, carrying the *other* holders' load on that axis (what the
/// unit-only API reported as a bare `u64`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AdmitError {
    /// The work-unit axis rejected; the payload is the units in use.
    Units(u64),
    /// The byte axis rejected; the payload is the bytes in use.
    Bytes(u64),
}

impl AdmissionBudget {
    /// Budget of `total` work units with the lone-job idle exception
    /// enabled (the v4 behaviour) and an unmetered byte axis.
    pub fn new(total: u64) -> Self {
        AdmissionBudget::with_limits(total, 0, false)
    }

    /// Budget of `total` work units; `strict` disables the lone-job
    /// idle exception, so an over-budget job is rejected even when the
    /// budget is idle.  The byte axis is unmetered.
    pub fn with_strict(total: u64, strict: bool) -> Self {
        AdmissionBudget::with_limits(total, 0, strict)
    }

    /// Two-axis budget: `total` work units plus `byte_total` peak
    /// resident bytes (`0` = the byte axis is unmetered).  `strict`
    /// applies to both axes.
    pub fn with_limits(total: u64, byte_total: u64, strict: bool) -> Self {
        AdmissionBudget {
            total: total.max(1),
            byte_total,
            strict,
            used: AtomicU64::new(0),
            bytes_used: AtomicU64::new(0),
            #[cfg(debug_assertions)]
            reserved_flow: AtomicU64::new(0),
            #[cfg(debug_assertions)]
            released_flow: AtomicU64::new(0),
            #[cfg(debug_assertions)]
            reserved_bytes_flow: AtomicU64::new(0),
            #[cfg(debug_assertions)]
            released_bytes_flow: AtomicU64::new(0),
        }
    }

    /// Total work units.
    pub fn total(&self) -> u64 {
        self.total
    }

    /// Total byte budget (`0` = the byte axis is unmetered).
    pub fn byte_total(&self) -> u64 {
        self.byte_total
    }

    /// Bytes currently held by in-flight jobs (the `mem_used=` gauge).
    pub fn bytes_used(&self) -> u64 {
        self.bytes_used.load(Ordering::SeqCst)
    }

    /// Is the lone-job idle exception disabled?
    pub fn is_strict(&self) -> bool {
        self.strict
    }

    /// Units currently held by in-flight jobs.
    pub fn used(&self) -> u64 {
        self.used.load(Ordering::SeqCst)
    }

    /// Debug-build flow counters: `(units ever reserved, units ever
    /// released)`.  The two are equal exactly when no permit is
    /// outstanding — the panic-safety and interleaving suites assert
    /// this balance at every quiescent point.
    #[cfg(debug_assertions)]
    pub fn debug_units_flow(&self) -> (u64, u64) {
        (self.reserved_flow.load(Ordering::SeqCst), self.released_flow.load(Ordering::SeqCst))
    }

    /// Debug-build flow counters for the byte axis: `(bytes ever
    /// reserved, bytes ever released)` — balanced exactly when no
    /// permit is outstanding, like [`AdmissionBudget::debug_units_flow`].
    #[cfg(debug_assertions)]
    pub fn debug_bytes_flow(&self) -> (u64, u64) {
        (
            self.reserved_bytes_flow.load(Ordering::SeqCst),
            self.released_bytes_flow.load(Ordering::SeqCst),
        )
    }

    /// Would `units` be admitted alongside `others` already-held units?
    fn fits(&self, others: u64, units: u64) -> bool {
        (others == 0 && !self.strict) || others.saturating_add(units) <= self.total
    }

    /// Would `bytes` be admitted alongside `others` already-held bytes?
    /// An unmetered axis (`byte_total == 0`) admits everything.
    fn fits_bytes(&self, others: u64, bytes: u64) -> bool {
        self.byte_total == 0
            || (others == 0 && !self.strict)
            || others.saturating_add(bytes) <= self.byte_total
    }

    /// Reserve `units` (single-RMW, no check-then-increment window) or
    /// fail with the units currently in use.
    fn reserve(&self, units: u64) -> Result<(), u64> {
        self.used
            .fetch_update(Ordering::SeqCst, Ordering::SeqCst, |used| {
                if self.fits(used, units) {
                    Some(used.saturating_add(units))
                } else {
                    None
                }
            })
            .map(|_| {
                #[cfg(debug_assertions)]
                self.reserved_flow.fetch_add(units, Ordering::SeqCst);
            })
    }

    /// Atomically swap a reservation of `old` units for `new` — one
    /// RMW, so there is no window where the old units read as released
    /// (a release-then-readmit would let a concurrent oversized job in
    /// through the idle exception while this job is still in flight).
    /// On failure the old reservation is kept and the *other* holders'
    /// units are returned.
    fn exchange(&self, old: u64, new: u64) -> Result<(), u64> {
        self.used
            .fetch_update(Ordering::SeqCst, Ordering::SeqCst, |used| {
                let others = used.saturating_sub(old);
                if self.fits(others, new) {
                    Some(others.saturating_add(new))
                } else {
                    None
                }
            })
            .map(|_| {
                #[cfg(debug_assertions)]
                {
                    self.reserved_flow.fetch_add(new, Ordering::SeqCst);
                    self.released_flow.fetch_add(old, Ordering::SeqCst);
                }
            })
            .map_err(|used| used.saturating_sub(old))
    }

    /// Release `units` (saturating: an idle-exception admit may have
    /// pushed `used` past `total`, but it can never underflow).
    fn release(&self, units: u64) {
        let _ = self
            .used
            .fetch_update(Ordering::SeqCst, Ordering::SeqCst, |used| {
                Some(used.saturating_sub(units))
            });
        #[cfg(debug_assertions)]
        self.released_flow.fetch_add(units, Ordering::SeqCst);
    }

    /// [`AdmissionBudget::reserve`] on the byte axis.
    fn reserve_bytes(&self, bytes: u64) -> Result<(), u64> {
        self.bytes_used
            .fetch_update(Ordering::SeqCst, Ordering::SeqCst, |used| {
                if self.fits_bytes(used, bytes) {
                    Some(used.saturating_add(bytes))
                } else {
                    None
                }
            })
            .map(|_| {
                #[cfg(debug_assertions)]
                self.reserved_bytes_flow.fetch_add(bytes, Ordering::SeqCst);
            })
    }

    /// [`AdmissionBudget::exchange`] on the byte axis.
    fn exchange_bytes(&self, old: u64, new: u64) -> Result<(), u64> {
        self.bytes_used
            .fetch_update(Ordering::SeqCst, Ordering::SeqCst, |used| {
                let others = used.saturating_sub(old);
                if self.fits_bytes(others, new) {
                    Some(others.saturating_add(new))
                } else {
                    None
                }
            })
            .map(|_| {
                #[cfg(debug_assertions)]
                {
                    self.reserved_bytes_flow.fetch_add(new, Ordering::SeqCst);
                    self.released_bytes_flow.fetch_add(old, Ordering::SeqCst);
                }
            })
            .map_err(|used| used.saturating_sub(old))
    }

    /// [`AdmissionBudget::release`] on the byte axis.
    fn release_bytes(&self, bytes: u64) {
        let _ = self
            .bytes_used
            .fetch_update(Ordering::SeqCst, Ordering::SeqCst, |used| {
                Some(used.saturating_sub(bytes))
            });
        #[cfg(debug_assertions)]
        self.released_bytes_flow.fetch_add(bytes, Ordering::SeqCst);
    }

    /// Unchecked unit swap used only to *roll back* a hold this caller
    /// already owned (restoring a prior reservation is not subject to
    /// the fit check — it was admitted when first reserved).
    fn force_exchange(&self, old: u64, new: u64) {
        let _ = self
            .used
            .fetch_update(Ordering::SeqCst, Ordering::SeqCst, |used| {
                Some(used.saturating_sub(old).saturating_add(new))
            });
        #[cfg(debug_assertions)]
        {
            self.reserved_flow.fetch_add(new, Ordering::SeqCst);
            self.released_flow.fetch_add(old, Ordering::SeqCst);
        }
    }

    /// Reserve `(units, bytes)` as one admission, or report which axis
    /// rejected.  Two-phase: units first, bytes second, with the unit
    /// hold rolled back if the byte axis refuses — so a failed admit
    /// holds nothing.  (The phases are not one atom: a concurrent
    /// idle-exception admit may be refused during the window where the
    /// units are held and the bytes are not — it fails safe, never
    /// over-admits.)
    fn reserve_costed(&self, units: u64, bytes: u64) -> Result<(), AdmitError> {
        self.reserve(units).map_err(AdmitError::Units)?;
        if let Err(held) = self.reserve_bytes(bytes) {
            self.release(units);
            return Err(AdmitError::Bytes(held));
        }
        Ok(())
    }

    /// Reserve `units` behind a borrowed RAII permit, or fail with the
    /// units currently in use.
    pub fn try_admit(&self, units: u64) -> Result<AdmissionPermit<'_>, u64> {
        self.reserve(units).map(|_| AdmissionPermit { budget: self, units, bytes: 0 })
    }

    /// Reserve `(units, bytes)` behind a borrowed RAII permit, or
    /// report the axis that rejected.
    pub fn try_admit_costed(
        &self,
        units: u64,
        bytes: u64,
    ) -> Result<AdmissionPermit<'_>, AdmitError> {
        self.reserve_costed(units, bytes)
            .map(|_| AdmissionPermit { budget: self, units, bytes })
    }
}

/// Borrowed RAII hold on [`AdmissionBudget`] units; released on drop.
/// Synchronous callers use this; queued jobs hold the owned
/// [`JobPermit`] instead (a job outlives the stack frame that admitted
/// it).
pub struct AdmissionPermit<'a> {
    budget: &'a AdmissionBudget,
    units: u64,
    bytes: u64,
}

impl AdmissionPermit<'_> {
    /// The units this permit reserved (the reply's `cost=` field).
    pub fn units(&self) -> u64 {
        self.units
    }

    /// The bytes this permit reserved (the reply's `bytes=` field).
    pub fn bytes(&self) -> u64 {
        self.bytes
    }

    /// Atomically swap this permit's reservation for `new_units` (see
    /// [`AdmissionBudget::exchange`] for the guarantees); on failure
    /// the old reservation is kept.  The byte hold is unchanged.
    pub fn reprice(&mut self, new_units: u64) -> Result<(), u64> {
        self.budget.exchange(self.units, new_units).map(|_| self.units = new_units)
    }
}

impl Drop for AdmissionPermit<'_> {
    fn drop(&mut self) {
        self.budget.release(self.units);
        self.budget.release_bytes(self.bytes);
    }
}

/// Owned RAII hold on [`AdmissionBudget`] units for asynchronous jobs:
/// the permit travels inside the queued job (registry-owned, not tied
/// to the submitting connection's stack) and releases its units when
/// the job reaches a terminal state — including cancel-while-queued
/// and deadline sheds, where the job never runs.
pub struct JobPermit {
    budget: Arc<AdmissionBudget>,
    units: u64,
    bytes: u64,
}

impl JobPermit {
    /// Reserve `units` from `budget`, or fail with the units in use.
    pub fn admit(budget: &Arc<AdmissionBudget>, units: u64) -> Result<JobPermit, u64> {
        budget.reserve(units).map(|_| JobPermit { budget: budget.clone(), units, bytes: 0 })
    }

    /// Reserve `(units, bytes)` from `budget`, or report the axis that
    /// rejected (the v9 two-axis admission every priced job goes
    /// through).
    pub fn admit_costed(
        budget: &Arc<AdmissionBudget>,
        units: u64,
        bytes: u64,
    ) -> Result<JobPermit, AdmitError> {
        budget
            .reserve_costed(units, bytes)
            .map(|_| JobPermit { budget: budget.clone(), units, bytes })
    }

    /// The units this permit reserved (the reply's `cost=` field).
    pub fn units(&self) -> u64 {
        self.units
    }

    /// The bytes this permit reserved (the reply's `bytes=` field).
    pub fn bytes(&self) -> u64 {
        self.bytes
    }

    /// Atomically swap this permit's reservation for `new_units` (see
    /// [`AdmissionBudget::exchange`]); on failure the old reservation
    /// is kept and the other holders' units are returned.  The byte
    /// hold is unchanged.
    pub fn reprice(&mut self, new_units: u64) -> Result<(), u64> {
        self.budget.exchange(self.units, new_units).map(|_| self.units = new_units)
    }

    /// Reprice both axes: the unit swap lands first, then the byte
    /// swap; if the byte axis refuses, the unit swap is rolled back to
    /// the old hold (restoring a prior reservation bypasses the fit
    /// check — it was admitted when first reserved) and the permit is
    /// unchanged.
    pub fn reprice_costed(&mut self, new_units: u64, new_bytes: u64) -> Result<(), AdmitError> {
        self.budget.exchange(self.units, new_units).map_err(AdmitError::Units)?;
        if let Err(held) = self.budget.exchange_bytes(self.bytes, new_bytes) {
            self.budget.force_exchange(new_units, self.units);
            return Err(AdmitError::Bytes(held));
        }
        self.units = new_units;
        self.bytes = new_bytes;
        Ok(())
    }
}

impl Drop for JobPermit {
    fn drop(&mut self) {
        self.budget.release(self.units);
        self.budget.release_bytes(self.bytes);
    }
}

/// How many distinct pool widths [`PoolCache`] keeps resident.  The
/// `threads=` key is client-supplied (clamped to 64), so without a
/// bound a width sweep would pin ~2000 parked worker threads for the
/// server's lifetime; real traffic uses a handful of widths.
pub const POOL_CACHE_CAP: usize = 8;

/// Server-owned cache of execution pools, keyed by *resolved* thread
/// width (`threads=0` and an explicit `threads=<cores>` share one
/// entry).  Every job asking for `threads=T` gets a clone of the same
/// persistent [`Pool`] — clones share workers — so worker spawn is paid
/// once per width instead of once per job (the PR-4 follow-up;
/// benches/micro.rs compares both shapes).  Pool reuse is
/// deterministic: results are bit-identical across jobs at any width.
///
/// Bounded: at most [`POOL_CACHE_CAP`] widths stay resident, evicting
/// the least recently used.  Evicting a pool only drops the cache's
/// handle — in-flight jobs hold clones, so the parked workers join
/// once the last job of that width finishes, never mid-solve.
#[derive(Default)]
pub struct PoolCache {
    inner: Mutex<PoolCacheInner>,
}

#[derive(Default)]
struct PoolCacheInner {
    pools: HashMap<usize, Pool>,
    /// Widths, coldest first (LRU order).
    order: VecDeque<usize>,
}

impl PoolCache {
    /// Empty cache.
    pub fn new() -> Self {
        Self::default()
    }

    /// The shared pool for `threads` (`0` = auto): built on first use,
    /// cloned for every subsequent job of the same width.
    pub fn get(&self, threads: usize) -> Pool {
        let width = Pool::resolve(threads);
        let mut inner = sync_ext::lock_or_recover(&self.inner);
        if let Some(pos) = inner.order.iter().position(|&w| w == width) {
            inner.order.remove(pos);
        }
        inner.order.push_back(width);
        let pool = inner.pools.entry(width).or_insert_with(|| Pool::new(width)).clone();
        while inner.pools.len() > POOL_CACHE_CAP {
            if let Some(cold) = inner.order.pop_front() {
                inner.pools.remove(&cold);
            }
        }
        pool
    }

    /// Distinct widths currently cached (the `pools=` stats field).
    pub fn widths(&self) -> usize {
        sync_ext::lock_or_recover(&self.inner).pools.len()
    }
}

/// Shared mutable server state, visible to every worker (and exposed on
/// [`ServerHandle::state`] for tests / ops probes).
pub struct ServerState {
    /// Sharded dataset cache for `cluster` requests.
    pub cache: DatasetCache,
    /// Per-method latency / dissim aggregates (the `stats` command).
    pub methods: MethodMetrics,
    /// Weighted admission budget every job reserves from.
    pub admission: Arc<AdmissionBudget>,
    /// The asynchronous job registry (protocol v5 handle verbs).
    pub jobs: JobRegistry,
    /// Server-owned execution pools, keyed by thread width.
    pub pools: PoolCache,
    /// Per-verb request counters (the `verb.<name>=` stats fields).
    pub verbs: VerbCounters,
    /// Promoted fitted models, served by `assign` (protocol v6).
    pub models: ModelRegistry,
    /// Per-model `assign` aggregates (the `model.<name>.*` stats fields).
    pub model_stats: ModelMetrics,
    /// Connection telemetry from the event loop (the `conns=` /
    /// `waiters=` / `pipelined=` / `wakeups=` stats fields).
    pub conns: ConnCounters,
}

impl ServerState {
    /// Fresh state sized from the config.
    pub fn new(cfg: &ServerConfig) -> Self {
        ServerState {
            cache: DatasetCache::with_byte_limit(cfg.cache_cap, cfg.resolved_byte_budget()),
            methods: MethodMetrics::new(),
            admission: Arc::new(AdmissionBudget::with_limits(
                cfg.resolved_budget(),
                cfg.resolved_byte_budget(),
                cfg.strict_budget,
            )),
            jobs: JobRegistry::new(cfg.resolved_retain_cap(), cfg.resolved_queue_cap()),
            pools: PoolCache::new(),
            verbs: VerbCounters::new(),
            models: ModelRegistry::new(cfg.resolved_model_cap()),
            model_stats: ModelMetrics::new(),
            conns: ConnCounters::new(),
        }
    }

    /// Run at most one queued job to its terminal state on the calling
    /// thread; returns whether a job ran.  This is the deterministic
    /// single-step worker: a workerless embedder pumps the registry
    /// with it, and the interleaving suite (rust/tests/interleave.rs)
    /// uses it to place the run-to-terminal transition at an exact
    /// point in an enumerated schedule.  Serving states never need it —
    /// their solver workers drain the registry continuously.
    pub fn drain_one(&self) -> bool {
        match self.jobs.try_next_job() {
            Some(picked) => {
                run_job(self, picked);
                true
            }
            None => false,
        }
    }
}

/// Handle to a running server (join/shutdown + resolved address).
pub struct ServerHandle {
    /// The actually-bound address (useful with port 0).
    pub addr: std::net::SocketAddr,
    /// The server's shared state (cache, registry, budget, pools).
    pub state: Arc<ServerState>,
    stop: Arc<AtomicBool>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl ServerHandle {
    /// Ask the server to stop, drain the job queue and join every
    /// thread.  Jobs already admitted still run to a terminal state;
    /// new submits are rejected with `err server shutting down`.
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // reject new submits, wake the workers (they drain the queue
        // and exit) and every blocked `wait` caller
        self.state.jobs.shutdown();
        // wake the event loop's poll with a dummy connection (dropped
        // unread once the stop flag is observed)
        let _ = TcpStream::connect(self.addr);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join(); // the loop drains in-flight replies first
        }
        for t in self.workers.drain(..) {
            let _ = t.join();
        }
    }
}

/// Split a request line into tokens, honouring double quotes: a `"`
/// opens a span in which whitespace is literal, the closing `"` ends
/// it, and the quotes themselves are stripped — so
/// `dataset="file:/data/my points.csv"` is one `key=value` token.
/// Unquoted lines tokenize exactly like `split_whitespace` (every
/// v1–v4 request is unchanged); an unterminated quote is a protocol
/// error.  There is no escape character — a value containing a literal
/// `"` has no wire spelling (the CLI client rejects such values with a
/// clear error instead of sending garbage).
fn tokenize(line: &str) -> Result<Vec<String>, String> {
    let mut out = Vec::new();
    let mut cur = String::new();
    let mut in_quotes = false;
    let mut has_content = false;
    for c in line.chars() {
        match c {
            '"' => {
                in_quotes = !in_quotes;
                has_content = true; // `""` is a present-but-empty value
            }
            c if c.is_whitespace() && !in_quotes => {
                if has_content {
                    out.push(std::mem::take(&mut cur));
                    has_content = false;
                }
            }
            c => {
                cur.push(c);
                has_content = true;
            }
        }
    }
    if in_quotes {
        return Err(format!("unterminated \" in request line {line:?}"));
    }
    if has_content {
        out.push(cur);
    }
    Ok(out)
}

/// Parse `key=value` tokens after the command word.
fn parse_kv(parts: &[String]) -> HashMap<String, String> {
    parts
        .iter()
        .filter_map(|p| p.split_once('='))
        .map(|(k, v)| (k.to_string(), v.to_string()))
        .collect()
}

/// Optional `key=value` lookup where a present-but-unparsable value is a
/// protocol error (v2 validates instead of silently falling back).
fn parse_key<T: std::str::FromStr>(
    kv: &HashMap<String, String>,
    key: &str,
) -> Result<Option<T>, String> {
    match kv.get(key) {
        None => Ok(None),
        Some(s) => s.parse().map(Some).map_err(|_| format!("bad {key}={s}")),
    }
}

/// Re-export of [`crate::solver::FULL_MATRIX_LIMIT`] (the constant moved
/// next to [`MethodSpec::feasible_large_scale`] so the grid runner can
/// apply the same bound without depending on the server).
pub use crate::solver::FULL_MATRIX_LIMIT;

/// Format the one admission error a priced-but-rejected job receives.
fn over_budget(cost: JobCost, used: u64, budget: &AdmissionBudget) -> String {
    format!(
        "over budget: cost={} exceeds the {} free of {} work units (in use {used})",
        cost.units,
        budget.total().saturating_sub(used),
        budget.total(),
    )
}

/// The byte-axis twin of [`over_budget`]: the priced resident footprint
/// does not fit the byte budget.
fn over_byte_budget(cost: JobCost, used: u64, budget: &AdmissionBudget) -> String {
    format!(
        "over byte budget: bytes={} exceeds the {} free of {} resident bytes (in use {used})",
        cost.resident_bytes,
        budget.byte_total().saturating_sub(used),
        budget.byte_total(),
    )
}

/// Route an [`AdmitError`] to the axis-appropriate error string.
fn admit_rejected(cost: JobCost, err: AdmitError, budget: &AdmissionBudget) -> String {
    match err {
        AdmitError::Units(used) => over_budget(cost, used, budget),
        AdmitError::Bytes(used) => over_byte_budget(cost, used, budget),
    }
}

/// Price one job at `(n, p)` and apply the feasibility ceiling
/// ([`JobCost::admissible`] — the old `FULL_MATRIX_LIMIT` rule).
/// `streaming` selects the out-of-core OneBatch price
/// ([`MethodSpec::streaming_cost`]: batch slice + one chunk buffer)
/// over the resident one; `p = 0` prices the feature matrix at zero
/// width (synth and hint-only `file:` sources, whose column count is
/// unknown before the load).
fn checked_cost(
    method: &MethodSpec,
    n: usize,
    p: usize,
    k: usize,
    m: Option<usize>,
    streaming: bool,
) -> Result<JobCost, String> {
    let cost = if streaming {
        method
            .streaming_cost(n, p, k, m)
            .unwrap_or_else(|| method.cost_with_dims(n, p, k, m))
    } else {
        method.cost_with_dims(n, p, k, m)
    };
    if !cost.admissible() {
        return Err(format!(
            "method {} infeasible at n={n} (limit {FULL_MATRIX_LIMIT}, cost={})",
            method.label(),
            cost.units
        ));
    }
    Ok(cost)
}

/// The admission decision for one job at `(n, p)`: price it, apply the
/// feasibility ceiling, and reserve the units *and bytes* from the
/// budget.  Shared by the predicted (pre-I/O) and post-load paths so
/// the two can never diverge.
fn price_and_admit(
    state: &ServerState,
    method: &MethodSpec,
    n: usize,
    p: usize,
    k: usize,
    m: Option<usize>,
    streaming: bool,
) -> Result<JobPermit, String> {
    let cost = checked_cost(method, n, p, k, m, streaming)?;
    JobPermit::admit_costed(&state.admission, cost.units, cost.resident_bytes)
        .map_err(|e| admit_rejected(cost, e, &state.admission))
}

/// A fully validated clustering request, ready to run: everything a
/// worker needs, detached from the connection that submitted it.
pub(crate) struct JobRequest {
    src: DataSource,
    k: usize,
    scale: f64,
    seed: u64,
    threads: usize,
    metric: Metric,
    profile: ComputeProfile,
    scaling: FeatureScaling,
    method: MethodSpec,
    m: Option<usize>,
    eps: Option<f64>,
    max_passes: Option<usize>,
    deadline_ms: Option<u64>,
    cancel: CancelToken,
}

impl JobRequest {
    /// Does this request run the out-of-core path — a streamable
    /// source (`npy:`/`dir:`) under a OneBatch method?  Everything
    /// else loads resident (non-OneBatch methods need the full
    /// matrix; synth/`file:` sources have no chunked reader).
    fn streams(&self) -> bool {
        self.src.is_stream() && matches!(self.method, MethodSpec::OneBatch { .. })
    }
}

/// What a queued job carries through the registry: the validated
/// request plus its admission permit (released if the job is cancelled
/// or shed before running).
pub(crate) struct JobWork {
    req: JobRequest,
    permit: Option<JobPermit>,
}

/// Validate a `cluster`/`submit` key set into a runnable [`JobRequest`]
/// (no I/O, no admission).  The checks and their error strings are the
/// v4 `cluster` validation verbatim, plus the v5 `deadline_ms=` key.
fn parse_cluster(kv: &HashMap<String, String>) -> Result<JobRequest, String> {
    let dataset = kv.get("dataset").cloned().unwrap_or_else(|| "blobs_1000_8_5".into());
    let src = DataSource::parse(&dataset).map_err(|e| e.to_string())?;
    let k: usize = parse_key(kv, "k")?.unwrap_or(10);
    let scale: f64 = parse_key(kv, "scale")?.unwrap_or(1.0);
    let seed: u64 = parse_key(kv, "seed")?.unwrap_or(0);
    // capped: a request can use the machine, not fork-bomb it
    let threads: usize = parse_key(kv, "threads")?.unwrap_or(1).min(64);
    let metric = kv
        .get("metric")
        .map(|s| Metric::parse(s).ok_or(format!("unknown metric {s}")))
        .transpose()?
        .unwrap_or(Metric::L1);
    // serving default is the fast kernel path; the paper-reproduction
    // grid (library callers, SolveSpec::new) defaults to exact
    let profile = kv
        .get("profile")
        .map(|s| ComputeProfile::parse(s).ok_or(format!("unknown profile {s} (exact|fast)")))
        .transpose()?
        .unwrap_or(ComputeProfile::Fast);
    let scaling = kv
        .get("scale_features")
        .map(|s| FeatureScaling::parse(s).ok_or(format!("unknown scale_features {s} (minmax|none)")))
        .transpose()?
        .unwrap_or_default();
    if k < 2 {
        return Err("k must be >= 2".into());
    }
    // file bytes do not scale: a non-neutral scale= on a file: source is
    // a mis-configured experiment, not a knob to silently drop (the same
    // rule the protocol applies to OneBatch-only keys)
    if src.is_file() && scale != 1.0 {
        return Err(format!("scale= does not apply to file: sources (got scale={scale})"));
    }

    // method resolution: explicit method= wins; legacy lines without it
    // default to OneBatchPAM driven by the v1 sampler=/strategy= keys
    let base = match kv.get("method") {
        Some(s) => MethodSpec::parse(s).ok_or(format!("unknown method {s}"))?,
        None => MethodSpec::default(),
    };
    let sampler = kv
        .get("sampler")
        .map(|s| SamplerKind::parse(s).ok_or(format!("unknown sampler {s}")))
        .transpose()?;
    let strategy = kv
        .get("strategy")
        .map(|s| SwapStrategy::parse(s).ok_or(format!("unknown strategy {s}")))
        .transpose()?;
    let m: Option<usize> = parse_key(kv, "m")?;
    let eps: Option<f64> = parse_key(kv, "eps")?;
    let max_passes: Option<usize> = parse_key(kv, "max_passes")?;
    let method = match base {
        MethodSpec::OneBatch { sampler: s0, strategy: t0 } => MethodSpec::OneBatch {
            sampler: sampler.unwrap_or(s0),
            strategy: strategy.unwrap_or(t0),
        },
        other => {
            for key in ["sampler", "strategy", "m", "eps", "max_passes"] {
                if kv.contains_key(key) {
                    return Err(format!(
                        "{key}= only applies to OneBatch methods (method={})",
                        other.label()
                    ));
                }
            }
            other
        }
    };
    if let Some(m) = m {
        if m < 2 {
            return Err(format!("m must be >= 2, got {m}"));
        }
    }
    if let Some(e) = eps {
        if !e.is_finite() || e < 0.0 {
            return Err(format!("eps must be finite and >= 0, got {e}"));
        }
    }
    if max_passes == Some(0) {
        return Err("max_passes must be >= 1".into());
    }
    // a streamed OneBatch solve never holds the full matrix, so there
    // is nothing for a feature-scaling pass to rewrite — reject rather
    // than silently load resident (the same no-silent-drop rule as
    // scale= on file: sources)
    if src.is_stream()
        && matches!(method, MethodSpec::OneBatch { .. })
        && scaling != FeatureScaling::None
    {
        return Err(
            "scale_features= needs the dataset resident and cannot apply to a streamed \
             npy:/dir: OneBatch solve"
                .into(),
        );
    }
    // v5: an end-to-end queue-wait deadline, validated at submit
    let deadline_ms: Option<u64> = parse_key(kv, "deadline_ms")?;
    if deadline_ms == Some(0) {
        return Err("deadline_ms must be >= 1".into());
    }

    Ok(JobRequest {
        src,
        k,
        scale,
        seed,
        threads,
        metric,
        profile,
        scaling,
        method,
        m,
        eps,
        max_passes,
        deadline_ms,
        cancel: CancelToken::none(),
    })
}

/// Price the request *before* paying for a load or touching the cache —
/// the size is predictable for every catalogue source, for files
/// carrying a `?rows=` hint, and for `npy:`/`dir:` sources (a ~100 byte
/// header / manifest probe), so the per-job feasibility ceiling
/// (the old FULL_MATRIX_LIMIT rule, now a special case of pricing) and
/// the two-axis weighted budget apply with no bulk I/O.  Unpredictable
/// sources return `None` and are priced right after their load, inside
/// [`run_cluster`].  The column width feeds the byte axis where it is
/// knowable (`npy:`/`dir:` headers); synth and hint-only `file:`
/// sources price features at zero width and settle post-load.
fn admit_request(state: &ServerState, req: &JobRequest) -> Result<Option<JobPermit>, String> {
    let (rows, p) = match req.src.expected_dims() {
        Some((n, p)) => (Some(n), p),
        None => (req.src.expected_rows(req.scale), 0),
    };
    match rows {
        Some(n) => {
            price_and_admit(state, &req.method, n, p, req.k, req.m, req.streams()).map(Some)
        }
        None => Ok(None),
    }
}

/// Execute one admitted clustering request (the worker half of a job,
/// also run inline for direct library calls).  `queue_ms` is the
/// submit-to-pickup wait the job experienced (`0.0` for inline calls);
/// it feeds the per-method queue-wait histogram.  `job_id` names the
/// registry entry to report the final post-load price to (`None` for
/// inline calls, which have no registry entry).
fn run_cluster(
    state: &ServerState,
    req: &JobRequest,
    mut permit: Option<JobPermit>,
    queue_ms: f64,
    job_id: Option<u64>,
) -> Result<String, String> {
    if req.streams() {
        // v9: OneBatch over npy:/dir: never materializes n x p — it
        // bypasses the dataset cache and solves through a RowStore
        return run_cluster_streaming(state, req, permit, queue_ms, job_id);
    }
    let expected = req.src.expected_rows(req.scale);
    let (x, hit) = state
        .cache
        .get_or_load(&req.src, req.scale, req.seed, req.scaling)
        .map_err(|e| e.to_string())?;
    if x.rows <= req.k + 1 {
        return Err(format!("dataset too small (n={}) for k={}", x.rows, req.k));
    }
    if expected != Some(x.rows) || permit.as_ref().is_some_and(|p| p.bytes() == 0) {
        // the prediction was absent (hint-less file, unknown synth
        // name) or wrong (a client-supplied ?rows= hint is never
        // validated against the loaded bytes), or the pre-load price
        // could not see the column width (zero byte hold): reprice at
        // the actual shape so a lying hint cannot smuggle a
        // full-matrix job past the feasibility ceiling or hold a
        // too-small reservation on either axis
        match permit.as_mut() {
            // atomic swap — no window where this job's units read as
            // released (which would let an oversized job in through the
            // budget's idle exception while this one is still in flight)
            Some(p) => {
                let cost = checked_cost(&req.method, x.rows, x.cols, req.k, req.m, false)?;
                p.reprice_costed(cost.units, cost.resident_bytes)
                    .map_err(|e| admit_rejected(cost, e, &state.admission))?;
            }
            None => {
                permit = Some(price_and_admit(
                    state, &req.method, x.rows, x.cols, req.k, req.m, false,
                )?);
            }
        }
    }
    // the permit's units are the reply's cost=; held until the job's
    // terminal state (the drop releases them)
    let permit = permit.expect("job priced and admitted");
    if let Some(id) = job_id {
        // unpredictable sources submitted at cost=0 (and lying hints
        // were repriced): report the settled units so poll shows what
        // the running job actually holds against the budget
        state.jobs.set_cost(id, permit.units());
    }

    // server-owned pool: jobs of the same width share one persistent
    // pool (cloned per job), amortising worker spawn across requests
    let pool = state.pools.get(req.threads);
    let mut spec = SolveSpec::new(req.method.clone(), req.k, req.seed);
    spec.metric = req.metric;
    spec.threads = req.threads;
    spec.m = req.m;
    if let Some(e) = req.eps {
        spec.eps = e;
    }
    if let Some(p) = req.max_passes {
        spec.max_passes = p;
    }
    spec.cancel = req.cancel.clone();
    spec.pool = Some(pool.clone());
    spec.profile = req.profile;
    let backend = NativeBackend::with_pool(req.metric, pool).with_profile(req.profile);
    let solve_started = Instant::now();
    let r = solver::solve(&x, &spec, &backend).map_err(|e| e.to_string())?;
    let obj = eval::objective(&x, &r.medoids, &DissimCounter::new(req.metric));
    // v6: a final assignment pass captures the dataset-free fitted
    // model (medoid rows + metric + inertia).  It runs after solve()
    // returned, so the reply's dissim= (counter deltas captured inside
    // the solve) and objective= (the f64 eval above) are byte-identical
    // to v5; inertia= is the pass's f32-accumulated mean.
    let fitted =
        solver::fit_model(&x, &r, req.metric, &backend).map_err(|e| e.to_string())?;
    let inertia = fitted.inertia;
    // per-method aggregates cover solve + eval (time attributable to the
    // method), not the dataset load a cache miss happens to pay; the
    // queue wait is recorded alongside for the tail histograms
    state.methods.record(
        &spec.method.label(),
        solve_started.elapsed().as_secs_f64() * 1e3,
        r.stats.dissim_count,
        queue_ms,
    );
    if let Some(id) = job_id {
        // stash the model (training arrays dropped) so `promote` serves
        // it with no dataset resident and no recompute
        state.jobs.set_fitted(
            id,
            ModelSeed {
                model: Arc::new(fitted.without_training_arrays()),
                method: spec.method.label(),
                source: req.src.canon(),
            },
        );
    }
    let meds: Vec<String> = r.medoids.iter().map(|m| m.to_string()).collect();
    // v7: `profile=` appended after the v6 `inertia=` trailer; v9
    // appends `bytes=` after it — every v1-v8 prefix stays
    // byte-identical (jobs_api.rs / model_serving.rs pin field order)
    Ok(format!(
        "ok method={} cache={} medoids={} objective={obj:.6} seconds={:.4} dissim={} swaps={} source={} cost={} inertia={inertia:.6} profile={} bytes={}",
        spec.method.label(),
        if hit { "hit" } else { "miss" },
        meds.join(","),
        r.stats.seconds,
        r.stats.dissim_count,
        r.stats.swap_count,
        req.src.canon(),
        permit.units(),
        req.profile.name(),
        permit.bytes(),
    ))
}

/// The out-of-core twin of [`run_cluster`]: OneBatch over an
/// `npy:`/`dir:` source solved through a [`crate::data::RowStore`].
/// The dataset cache is bypassed (nothing resident to cache — the
/// reply says `cache=stream`), the admission permit holds the
/// streaming byte price (batch slice + one chunk buffer,
/// [`MethodSpec::streaming_cost`]) instead of the full matrix, and the
/// medoids / objective / inertia bits equal the resident solve of the
/// same bytes (rust/tests/out_of_core.rs pins this end to end).
fn run_cluster_streaming(
    state: &ServerState,
    req: &JobRequest,
    mut permit: Option<JobPermit>,
    queue_ms: f64,
    job_id: Option<u64>,
) -> Result<String, String> {
    let expected = req.src.expected_dims();
    let mut store = req.src.open_store(req.scale, req.seed).map_err(|e| e.to_string())?;
    let (n, p) = store.dims();
    if n <= req.k + 1 {
        return Err(format!("dataset too small (n={n}) for k={}", req.k));
    }
    if expected != Some((n, p)) {
        // the pre-admission header probe failed (permit is None) or
        // raced a rewrite: (re)price at the opened store's true shape
        let cost = checked_cost(&req.method, n, p, req.k, req.m, true)?;
        match permit.as_mut() {
            Some(pmt) => pmt
                .reprice_costed(cost.units, cost.resident_bytes)
                .map_err(|e| admit_rejected(cost, e, &state.admission))?,
            None => {
                permit = Some(
                    JobPermit::admit_costed(&state.admission, cost.units, cost.resident_bytes)
                        .map_err(|e| admit_rejected(cost, e, &state.admission))?,
                );
            }
        }
    }
    let permit = permit.expect("job priced and admitted");
    if let Some(id) = job_id {
        state.jobs.set_cost(id, permit.units());
    }

    let pool = state.pools.get(req.threads);
    let mut spec = SolveSpec::new(req.method.clone(), req.k, req.seed);
    spec.metric = req.metric;
    spec.threads = req.threads;
    spec.m = req.m;
    if let Some(e) = req.eps {
        spec.eps = e;
    }
    if let Some(p) = req.max_passes {
        spec.max_passes = p;
    }
    spec.cancel = req.cancel.clone();
    spec.pool = Some(pool.clone());
    spec.profile = req.profile;
    let backend = NativeBackend::with_pool(req.metric, pool).with_profile(req.profile);
    let solve_started = Instant::now();
    let (r, fitted) =
        solver::solve_fitted_store(store.as_mut(), &spec, &backend).map_err(|e| e.to_string())?;
    // the exact full-data objective, accumulated chunk-at-a-time in the
    // same row order as eval::objective — bit-identical to the resident
    // evaluation of the same bytes
    let obj = eval::objective_store(store.as_mut(), &fitted.medoid_rows, &DissimCounter::new(req.metric))
        .map_err(|e| e.to_string())?;
    let inertia = fitted.inertia;
    state.methods.record(
        &spec.method.label(),
        solve_started.elapsed().as_secs_f64() * 1e3,
        r.stats.dissim_count,
        queue_ms,
    );
    if let Some(id) = job_id {
        state.jobs.set_fitted(
            id,
            ModelSeed {
                model: Arc::new(fitted.without_training_arrays()),
                method: spec.method.label(),
                source: req.src.canon(),
            },
        );
    }
    let meds: Vec<String> = r.medoids.iter().map(|m| m.to_string()).collect();
    Ok(format!(
        "ok method={} cache=stream medoids={} objective={obj:.6} seconds={:.4} dissim={} swaps={} source={} cost={} inertia={inertia:.6} profile={} bytes={}",
        spec.method.label(),
        meds.join(","),
        r.stats.seconds,
        r.stats.dissim_count,
        r.stats.swap_count,
        req.src.canon(),
        permit.units(),
        req.profile.name(),
        permit.bytes(),
    ))
}

/// Execute one `cluster` request synchronously (shared by workerless
/// library states and tests).  Parse, admit and run are the exact
/// stages a `submit`+`wait` pair goes through — `cluster` on a serving
/// wire routes through the registry instead, with byte-identical
/// replies.
pub fn handle_cluster(
    state: &ServerState,
    kv: &HashMap<String, String>,
    queue_ms: f64,
) -> Result<String, String> {
    let req = parse_cluster(kv)?;
    let permit = admit_request(state, &req)?;
    run_cluster(state, &req, permit, queue_ms, None)
}

/// Validate, price, admit and enqueue one job; returns `(id, cost)` for
/// the `ok job=j<id> cost=<units>` reply.
fn submit_job(state: &ServerState, kv: &HashMap<String, String>) -> Result<(u64, u64), String> {
    // shed overdue queued jobs first: a logically dead job must not
    // hold budget units or a queue slot against this admission
    state.jobs.shed_expired();
    let mut req = parse_cluster(kv)?;
    req.cancel = CancelToken::new();
    let cancel = req.cancel.clone();
    let deadline_ms = req.deadline_ms;
    let permit = admit_request(state, &req)?;
    let cost = permit.as_ref().map_or(0, |p| p.units());
    let id = state.jobs.submit(Box::new(JobWork { req, permit }), deadline_ms, cancel, cost)?;
    Ok((id, cost))
}

/// The v4-compatible `cluster` path on a serving wire: `submit` +
/// unbounded `wait`, returning the job's stored reply verbatim plus the
/// job's queue wait for the reply trailer (the v4 `queue_ms=` was the
/// accept-to-pickup wait; its v5 successor is submit-to-pickup).
fn cluster_via_jobs(
    state: &ServerState,
    kv: &HashMap<String, String>,
    conn_queue_ms: f64,
) -> (String, f64) {
    match submit_job(state, kv) {
        Err(e) => (format!("err {e}"), conn_queue_ms),
        Ok((id, _cost)) => match state.jobs.wait(id, None) {
            WaitOutcome::Terminal(v) => (
                v.result.unwrap_or_else(|| format!("err job j{id} lost its result")),
                v.queue_ms,
            ),
            // wait(None) only returns Terminal or Unknown; Unknown here
            // means the finished job was evicted before we read it,
            // which a default retain_cap makes effectively impossible
            _ => (format!("err job j{id} evicted before its reply was read"), conn_queue_ms),
        },
    }
}

/// Parse the `job=j<id>` handle (the bare numeric form is accepted).
fn parse_job_id(kv: &HashMap<String, String>) -> Result<u64, String> {
    let Some(v) = kv.get("job") else {
        return Err("missing job= handle (e.g. job=j3)".into());
    };
    v.strip_prefix('j')
        .unwrap_or(v)
        .parse()
        .map_err(|_| format!("bad job={v} (handles look like j3)"))
}

/// The `poll` verb: non-blocking state probe.
fn handle_poll(state: &ServerState, kv: &HashMap<String, String>) -> String {
    let id = match parse_job_id(kv) {
        Ok(id) => id,
        Err(e) => return format!("err {e}"),
    };
    match state.jobs.poll(id) {
        None => format!("err unknown job j{id}"),
        Some(v) => poll_reply(&v),
    }
}

fn poll_reply(v: &JobView) -> String {
    let id = v.id;
    match v.state {
        // the queue wait is `waited_ms=`, not `queue_ms=`: every wire
        // reply already carries a trailing connection-level `queue_ms=`
        // (v4 shape), and one line must not hold the same key twice
        JobState::Queued | JobState::Running => format!(
            "ok job=j{id} state={} cost={} waited_ms={:.1}",
            v.state.name(),
            v.cost,
            v.queue_ms
        ),
        JobState::Done => {
            let body = v.result.as_deref().unwrap_or("ok");
            format!("ok job=j{id} state=done {}", body.strip_prefix("ok ").unwrap_or(body))
        }
        JobState::Cancelled => format!("ok job=j{id} state=cancelled"),
        JobState::Failed | JobState::Expired => {
            let body = v.result.as_deref().unwrap_or("err");
            format!(
                "ok job=j{id} state={} error={}",
                v.state.name(),
                body.strip_prefix("err ").unwrap_or(body)
            )
        }
    }
}

/// The `wait` verb: block until terminal or `timeout_ms=` elapses.
/// Returns the reply plus the queue wait for the reply trailer (the
/// waited job's own submit-to-pickup wait once terminal).
fn handle_wait(
    state: &ServerState,
    kv: &HashMap<String, String>,
    conn_queue_ms: f64,
) -> (String, f64) {
    let id = match parse_job_id(kv) {
        Ok(id) => id,
        Err(e) => return (format!("err {e}"), conn_queue_ms),
    };
    let timeout: Option<u64> = match parse_key(kv, "timeout_ms") {
        Ok(t) => t,
        Err(e) => return (format!("err {e}"), conn_queue_ms),
    };
    if timeout.is_none() && !state.jobs.has_workers() {
        // a workerless (direct-library) state can only make progress on
        // already-terminal jobs; an unbounded wait would never return
        match state.jobs.poll(id) {
            None => return (format!("err unknown job j{id}"), conn_queue_ms),
            Some(v) if !v.state.is_terminal() => {
                return (
                    "err wait needs timeout_ms= (no workers are draining jobs)".into(),
                    conn_queue_ms,
                )
            }
            Some(_) => {}
        }
    }
    match state.jobs.wait(id, timeout.map(Duration::from_millis)) {
        WaitOutcome::Unknown => (format!("err unknown job j{id}"), conn_queue_ms),
        WaitOutcome::Terminal(v) => (
            v.result.unwrap_or_else(|| format!("err job j{id} lost its result")),
            v.queue_ms,
        ),
        WaitOutcome::TimedOut(v) => {
            (format!("ok job=j{id} state={} timed_out=1", v.state.name()), conn_queue_ms)
        }
    }
}

/// The `cancel` verb: terminal for queued jobs, cooperative for running
/// ones, idempotent on finished ones.
fn handle_cancel(state: &ServerState, kv: &HashMap<String, String>) -> String {
    let id = match parse_job_id(kv) {
        Ok(id) => id,
        Err(e) => return format!("err {e}"),
    };
    match state.jobs.cancel(id) {
        None => format!("err unknown job j{id}"),
        Some((JobState::Running, true)) => format!("ok job=j{id} state=running cancel=requested"),
        Some((now, _)) => format!("ok job=j{id} state={}", now.name()),
    }
}

/// The `jobs` verb: registry occupancy + lifetime counters.
fn jobs_line(state: &ServerState) -> String {
    let g = state.jobs.gauges();
    let c = state.jobs.counters();
    format!(
        "ok queued={} running={} retained={} submitted={} done={} failed={} cancelled={} \
         expired={} shed={}",
        g.queued,
        g.running,
        g.retained,
        c.submitted(),
        c.done(),
        c.failed(),
        c.cancelled(),
        c.expired(),
        c.shed(),
    )
}

/// The `promote` verb: move a finished job's fitted model into the
/// model registry under `name=` (or a fresh auto handle) and report its
/// shape.  Promotion is pure registry work — the model was captured by
/// the worker at solve time, so no dataset and no compute is involved.
fn handle_promote(state: &ServerState, kv: &HashMap<String, String>) -> String {
    let id = match parse_job_id(kv) {
        Ok(id) => id,
        Err(e) => return format!("err {e}"),
    };
    let seed = match state.jobs.fitted(id) {
        FittedLookup::Unknown => return format!("err unknown job j{id}"),
        FittedLookup::NotDone(s) => {
            return format!("err job j{id} is {} (promote needs a done job)", s.name())
        }
        FittedLookup::Unavailable(s) => {
            return format!("err job j{id} holds no model (state={})", s.name())
        }
        FittedLookup::Ready(seed) => seed,
    };
    let model = seed.model.clone();
    match state.models.promote(kv.get("name").map(String::as_str), seed, id) {
        Err(e) => format!("err {e}"),
        Ok(name) => format!(
            "ok model={name} job=j{id} k={} dim={} metric={} inertia={:.6}",
            model.k(),
            model.dim(),
            model.metric.name(),
            model.inertia,
        ),
    }
}

/// Parse one `point=v1,v2,...` value into a feature row.
fn parse_point(raw: &str) -> Result<Vec<f32>, String> {
    let vals: Result<Vec<f32>, _> = raw.split(',').map(str::parse).collect();
    match vals {
        Ok(v) if !v.is_empty() && v.iter().all(|x| x.is_finite()) => Ok(v),
        _ => Err(format!("bad point={raw} (comma-joined finite numbers)")),
    }
}

/// The `assign` verb: nearest-medoid lookup against a promoted model.
/// Batched — every `point=` token in the request line (wire order) is
/// one row — with optional `top2=1` for the runner-up medoid per point.
/// Serves entirely from the model's own medoid rows: no dataset is
/// loaded, touched, or required to be resident.
fn handle_assign(state: &ServerState, parts: &[String]) -> String {
    let started = Instant::now();
    let kv = parse_kv(parts);
    let Some(name) = kv.get("model") else {
        return "err missing model= (e.g. assign model=m1 point=0.5,1.0)".into();
    };
    let top2 = match kv.get("top2").map(String::as_str) {
        None | Some("0") => false,
        Some("1") => true,
        Some(v) => return format!("err bad top2={v} (0|1)"),
    };
    let profile = match kv.get("profile").map(String::as_str) {
        None => ComputeProfile::Fast,
        Some(s) => match ComputeProfile::parse(s) {
            Some(p) => p,
            None => return format!("err unknown profile {s} (exact|fast)"),
        },
    };
    let Some((model, scratch)) = state.models.get_serving(name) else {
        return format!("err unknown model {name}");
    };
    // an explicit metric= must match what the model was fitted under —
    // serving under a different metric would be silently wrong answers
    if let Some(m) = kv.get("metric") {
        match Metric::parse(m) {
            None => return format!("err unknown metric {m}"),
            Some(m) if m != model.metric => {
                return format!(
                    "err model {name} was fitted under metric {} (got metric={})",
                    model.metric.name(),
                    m.name()
                )
            }
            Some(_) => {}
        }
    }
    let dim = model.dim();
    let k = model.k();
    if top2 && k < 2 {
        return format!("err top2 assignment needs >= 2 medoids (got {k})");
    }
    // Allocation-free hot path: every working buffer lives in the
    // model's AssignScratch (allocated at promotion, reused across
    // requests); each point's k distances land in one reused row that
    // is reduced in place, so the q x k matrix is never materialized
    // and a steady-QPS workload does zero per-request matrix
    // allocations.  profile=fast (the default) takes the dot-product
    // SqL2/L2 kernel with medoid norms cached in the scratch; exact and
    // every non-Euclidean metric evaluate point-to-medoid directly,
    // bit-identical to the offline backend::assign path.
    let mut guard = sync_ext::lock_or_recover(&scratch);
    let s = &mut *guard;
    // collect every point= token in wire order (parse_kv collapses
    // duplicate keys, so the batch is read from the raw tokens)
    s.points.clear();
    let mut n = 0usize;
    for part in parts {
        let Some(raw) = part.strip_prefix("point=") else { continue };
        let vals = match parse_point(raw) {
            Ok(v) => v,
            Err(e) => return format!("err {e}"),
        };
        if vals.len() != dim {
            return format!(
                "err model {name} expects {} features per point, got {} (point {})",
                dim,
                vals.len(),
                n + 1
            );
        }
        s.points.extend_from_slice(&vals);
        n += 1;
    }
    if n == 0 {
        return "err missing point= (e.g. assign model=m1 point=0.5,1.0)".into();
    }
    s.labels.clear();
    s.dists.clear();
    s.second.clear();
    s.dists2.clear();
    s.row.clear();
    s.row.resize(k, 0.0);
    let metric = model.metric;
    let fast = profile == ComputeProfile::Fast && matches!(metric, Metric::SqL2 | Metric::L2);
    if fast && s.bnorms.len() != k {
        // first fast assign against this model: cache the medoid norms
        // for its lifetime (medoid rows are immutable after promotion)
        s.bnorms.clear();
        for j in 0..k {
            s.bnorms.push(model.medoid_rows.row(j).iter().map(|v| v * v).sum());
        }
    }
    for i in 0..n {
        let point = &s.points[i * dim..(i + 1) * dim];
        if fast {
            let xn: f32 = point.iter().map(|v| v * v).sum();
            for j in 0..k {
                let mut dot = 0.0f32;
                for (a, b) in point.iter().zip(model.medoid_rows.row(j)) {
                    dot += a * b;
                }
                let v = (xn + s.bnorms[j] - 2.0 * dot).max(0.0);
                s.row[j] = if metric == Metric::L2 { v.sqrt() } else { v };
            }
        } else {
            for j in 0..k {
                s.row[j] = metric.eval(point, model.medoid_rows.row(j));
            }
        }
        if top2 {
            let (a, av, b, bv) = crate::linalg::top2_min(&s.row);
            s.labels.push(a);
            s.dists.push(av);
            s.second.push(b);
            s.dists2.push(bv);
        } else {
            let (a, av) = crate::linalg::argmin(&s.row);
            s.labels.push(a);
            s.dists.push(av);
        }
    }
    s.reuses += 1;
    let join_u = |v: &[usize]| v.iter().map(|x| x.to_string()).collect::<Vec<_>>().join(",");
    let join_f = |v: &[f32]| v.iter().map(|x| format!("{x:.6}")).collect::<Vec<_>>().join(",");
    let reply = if top2 {
        format!(
            "ok model={name} n={n} labels={} dists={} second={} dists2={}",
            join_u(&s.labels),
            join_f(&s.dists),
            join_u(&s.second),
            join_f(&s.dists2),
        )
    } else {
        format!(
            "ok model={name} n={n} labels={} dists={}",
            join_u(&s.labels),
            join_f(&s.dists),
        )
    };
    state.model_stats.record(name, started.elapsed().as_secs_f64() * 1e3);
    reply
}

/// The `models` verb: registry gauges plus one name-sorted row of
/// provenance and shape per resident model.
fn models_line(state: &ServerState) -> String {
    let g = state.models.gauges();
    let mut line = format!(
        "ok count={} cap={} promoted={} evicted={}",
        g.count, g.cap, g.promoted, g.evicted
    );
    for m in state.models.list() {
        line.push_str(&format!(
            " model.{0}.job=j{1} model.{0}.method={2} model.{0}.k={3} model.{0}.dim={4} \
             model.{0}.metric={5} model.{0}.inertia={6:.6} model.{0}.source={7}",
            m.name,
            m.job,
            m.method,
            m.k,
            m.dim,
            m.metric.name(),
            m.inertia,
            m.source,
        ));
    }
    line
}

/// The `evict` verb: drop a promoted model by name.
fn handle_evict(state: &ServerState, kv: &HashMap<String, String>) -> String {
    let Some(name) = kv.get("model") else {
        return "err missing model= (e.g. evict model=m1)".into();
    };
    if state.models.evict(name) {
        format!("ok evicted model={name}")
    } else {
        format!("err unknown model {name}")
    }
}

/// Dispatch one request line to a reply line (no queue: direct library
/// callers and tests; wire connections go through [`handle_line_queued`]
/// so the connection's dispatch wait reaches the reply).
pub fn handle_line(state: &ServerState, line: &str) -> String {
    handle_line_queued(state, line, 0.0)
}

/// Dispatch one request line to a reply line.  `queue_ms` is the wait
/// the *connection* experienced before dispatch (near zero since v5's
/// per-connection threads; kept for the inline `cluster` path, whose
/// jobs never queue).
pub fn handle_line_queued(state: &ServerState, line: &str, queue_ms: f64) -> String {
    dispatch_line(state, line, queue_ms).0
}

/// [`handle_line_queued`] plus the queue wait the reply trailer should
/// carry: the served *job's* submit-to-pickup wait for `cluster`/`wait`
/// replies (the v4 accept-to-pickup successor — a v4 client watching
/// the trailing `queue_ms=` keeps seeing real saturation), and the
/// connection dispatch wait for everything else.
fn dispatch_line(state: &ServerState, line: &str, queue_ms: f64) -> (String, f64) {
    let parts = match tokenize(line) {
        Ok(p) => p,
        Err(e) => return (format!("err {e}"), queue_ms),
    };
    // count the request under its verb (unknown commands are ignored by
    // record); the tidy lint `verb-coverage` keeps this dispatch match,
    // metrics::VERBS and the protocol doc block in sync
    if let Some(cmd) = parts.first() {
        state.verbs.record(cmd);
    }
    let reply = match parts.first().map(String::as_str) {
        Some("ping") => "pong".into(),
        Some("cluster") => {
            let kv = parse_kv(&parts[1..]);
            if state.jobs.has_workers() {
                // v5: cluster = submit + wait through the registry
                return cluster_via_jobs(state, &kv, queue_ms);
            }
            // workerless library state: run the same stages inline
            match handle_cluster(state, &kv, queue_ms) {
                Ok(r) => r,
                Err(e) => format!("err {e}"),
            }
        }
        Some("submit") => match submit_job(state, &parse_kv(&parts[1..])) {
            Ok((id, cost)) => format!("ok job=j{id} cost={cost}"),
            Err(e) => format!("err {e}"),
        },
        Some("poll") => handle_poll(state, &parse_kv(&parts[1..])),
        Some("wait") => return handle_wait(state, &parse_kv(&parts[1..]), queue_ms),
        Some("cancel") => handle_cancel(state, &parse_kv(&parts[1..])),
        Some("jobs") => jobs_line(state),
        // v6: fitted-model serving
        Some("promote") => handle_promote(state, &parse_kv(&parts[1..])),
        // assign reads the raw tokens: repeated point= keys are a batch
        Some("assign") => handle_assign(state, &parts[1..]),
        Some("models") => models_line(state),
        Some("evict") => handle_evict(state, &parse_kv(&parts[1..])),
        // v4: `stats reset` re-bases the method aggregates, cache and
        // job counters (entries and live gauges stay; budget is live)
        Some("stats") if parts.get(1).map(String::as_str) == Some("reset") => {
            state.methods.reset();
            state.cache.reset_counters();
            state.jobs.counters().reset();
            state.verbs.reset();
            state.model_stats.reset();
            state.conns.reset();
            "ok".into()
        }
        Some("stats") => {
            let s = state.cache.stats();
            let g = state.jobs.gauges();
            let c = state.jobs.counters();
            let mut line = format!(
                "ok cache_hits={} cache_misses={} cache_entries={} \
                 budget_total={} budget_used={} mem_total={} mem_used={} hist_le_ms={} \
                 jobs.submitted={} jobs.done={} jobs.failed={} jobs.cancelled={} \
                 jobs.expired={} jobs.queued={} jobs.running={} jobs.retained={} \
                 shed={} pools={} models={} conns={} waiters={} pipelined={} wakeups={}",
                s.hits,
                s.misses,
                s.entries,
                state.admission.total(),
                state.admission.used(),
                state.admission.byte_total(),
                state.admission.bytes_used(),
                metrics::hist_edges_wire(),
                c.submitted(),
                c.done(),
                c.failed(),
                c.cancelled(),
                c.expired(),
                g.queued,
                g.running,
                g.retained,
                c.shed(),
                state.pools.widths(),
                state.models.gauges().count,
                state.conns.conns(),
                state.conns.waiters(),
                state.conns.pipelined(),
                state.conns.wakeups(),
            );
            // per-verb request counters, VERBS (wire) order
            for (verb, n) in state.verbs.snapshot() {
                line.push_str(&format!(" verb.{verb}={n}"));
            }
            // per-method aggregates, label-sorted for determinism
            for (label, a) in state.methods.snapshot() {
                line.push_str(&format!(
                    " method.{label}.count={} \
                     method.{label}.ms_min={:.3} method.{label}.ms_mean={:.3} \
                     method.{label}.ms_max={:.3} method.{label}.dissim_min={} \
                     method.{label}.dissim_mean={:.1} method.{label}.dissim_max={} \
                     method.{label}.ms_hist={} method.{label}.queue_hist={}",
                    a.count,
                    a.ms_min,
                    a.ms_mean(),
                    a.ms_max,
                    a.dissim_min,
                    a.dissim_mean(),
                    a.dissim_max,
                    a.solve_hist.wire(),
                    a.queue_hist.wire(),
                ));
            }
            // per-model assign aggregates, name-sorted for determinism
            for (name, a) in state.model_stats.snapshot() {
                line.push_str(&format!(
                    " model.{name}.assign_count={} model.{name}.assign_ms_mean={:.3}",
                    a.count,
                    a.ms_mean(),
                ));
            }
            line
        }
        // Diagnostic: delay the reply by `ms` (capped) — used by the
        // backpressure tests.  A serving wire intercepts `sleep` on the
        // event loop (a timer entry, no thread held); this inline arm
        // serves only the direct-library `handle_line` path.
        Some("sleep") => {
            let kv = parse_kv(&parts[1..]);
            let ms: u64 = kv.get("ms").and_then(|s| s.parse().ok()).unwrap_or(0).min(10_000);
            std::thread::sleep(Duration::from_millis(ms));
            format!("ok slept_ms={ms}")
        }
        Some(cmd) => format!("err unknown command {cmd}"),
        None => "err empty request".into(),
    };
    (reply, queue_ms)
}

/// One picked job, executed on a solver worker.  Panics are caught so a
/// bad job can never shrink the worker pool; they land as a failed job.
fn run_job(state: &ServerState, picked: jobs::PickedJob) {
    run_job_with(state, picked, run_cluster);
}

/// [`run_job`] with the solve stage injected, so the panic-safety
/// regression tests drive a panicking solve through the exact guard
/// machinery production uses.  Two layers keep a panicking solve from
/// wedging anything: the `catch_unwind` turns the unwind into a failed
/// outcome — releasing the job's permit, which unwinds inside the
/// closure — and the [`FinishGuard`], armed *before* the solve starts,
/// publishes the terminal state on every exit path, so the job can
/// never stay `running`.
fn run_job_with<F>(state: &ServerState, picked: jobs::PickedJob, solve: F)
where
    F: FnOnce(
        &ServerState,
        &JobRequest,
        Option<JobPermit>,
        f64,
        Option<u64>,
    ) -> Result<String, String>,
{
    let jobs::PickedJob { id, work, queue_ms } = picked;
    let JobWork { req, permit } = *work;
    let mut guard = FinishGuard { state, id, outcome: None };
    let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        solve(state, &req, permit, queue_ms, Some(id))
    }))
    .unwrap_or_else(|_| Err("job panicked".into()));
    guard.outcome = Some(outcome);
    // the guard drops here, publishing the outcome exactly once
}

/// Publishes a picked job's terminal state on drop.  Armed before the
/// solve: if anything between pickup and publication unwinds past the
/// `catch_unwind`, the drop still lands the job `failed` instead of
/// leaving it `running` forever with no result.
struct FinishGuard<'a> {
    state: &'a ServerState,
    id: u64,
    outcome: Option<Result<String, String>>,
}

impl Drop for FinishGuard<'_> {
    fn drop(&mut self) {
        let outcome = self.outcome.take().unwrap_or_else(|| Err("job panicked".into()));
        self.state.jobs.finish(self.id, outcome);
    }
}

/// Start the server; returns immediately with a handle.
pub fn serve(cfg: ServerConfig) -> std::io::Result<ServerHandle> {
    let listener = TcpListener::bind(&cfg.addr)?;
    let addr = listener.local_addr()?;
    let stop = Arc::new(AtomicBool::new(false));
    let state = Arc::new(ServerState::new(&cfg));
    // the resolved_* accessors own the >= 1 invariant (0 means auto)
    let queue_cap = cfg.resolved_queue_cap();
    let worker_count = cfg.resolved_workers();

    // Solver workers drain *jobs*, not connections: each picks the next
    // queued job from the registry (shedding expired ones), runs it,
    // and publishes the terminal state.  They exit when the registry
    // shuts down and its queue is drained.
    state.jobs.set_workers(worker_count);
    let mut workers = Vec::with_capacity(worker_count);
    for _ in 0..worker_count {
        let state = state.clone();
        // tidy:allow(thread-spawn) — the solver-worker fleet: long-lived
        // threads owned and joined by ServerHandle::shutdown.
        workers.push(std::thread::spawn(move || {
            while let Some(picked) = state.jobs.next_job() {
                run_job(&state, picked);
            }
        }));
    }

    // The accept path is the evented core: one readiness-driven loop
    // thread multiplexes every connection over poll(2), parks waiters
    // on its timer wheel, and answers cheap verbs inline — so a slow
    // client or a long `wait` costs a registry entry, never a thread.
    let accept_thread =
        event::spawn(listener, state.clone(), stop.clone(), cfg.resolved_conn_cap(), queue_cap)?;

    Ok(ServerHandle { addr, state, stop, accept_thread: Some(accept_thread), workers })
}

/// Blocking client call: one request line -> reply line.
pub fn request(addr: std::net::SocketAddr, line: &str) -> std::io::Result<String> {
    let mut stream = TcpStream::connect(addr)?;
    writeln!(stream, "{line}")?;
    let mut reader = BufReader::new(stream);
    let mut reply = String::new();
    reader.read_line(&mut reply)?;
    Ok(reply.trim().to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fresh_state() -> ServerState {
        ServerState::new(&ServerConfig::default())
    }

    fn kv(pairs: &[(&str, &str)]) -> HashMap<String, String> {
        pairs.iter().map(|(a, b)| (a.to_string(), b.to_string())).collect()
    }

    #[test]
    fn ping_pong_and_cluster_roundtrip() {
        let h = serve(ServerConfig::default()).unwrap();
        assert!(request(h.addr, "ping").unwrap().starts_with("pong"));
        let r = request(h.addr, "cluster dataset=blobs_300_4_3 k=3 seed=1").unwrap();
        // legacy lines without method= still work and default to
        // OneBatch-nniw (protocol v1 compatibility); the v2 reply fields
        // are byte-identical, with v3's source= appended
        assert!(r.starts_with("ok method=OneBatch-nniw cache=miss medoids="), "{r}");
        assert!(r.contains("objective="));
        assert!(r.contains("swaps="));
        assert!(r.contains(" source=synth:blobs_300_4_3"), "{r}");
        h.shutdown();
    }

    #[test]
    fn every_table3_method_is_addressable_on_the_wire() {
        let h = serve(ServerConfig::default()).unwrap();
        for method in MethodSpec::table3_grid() {
            let label = method.label();
            let r = request(h.addr, &format!("cluster dataset=blobs_200_4_3 k=3 seed=1 method={label}"))
                .unwrap();
            assert!(r.starts_with("ok "), "{label}: {r}");
            assert!(r.contains(&format!("method={label} ")), "{label}: {r}");
        }
        h.shutdown();
    }

    #[test]
    fn bad_requests_get_errors() {
        let st = fresh_state();
        for line in [
            "nope",
            "",
            "cluster dataset=doesnotexist",
            "cluster k=1",
            "cluster k=abc",
            "cluster dataset=s3:bucket/key",
            "cluster dataset=file:",
            "cluster dataset=file:/x.csv?rows=0",
            // file bytes do not scale; silent no-ops are not allowed
            "cluster dataset=file:/x.csv scale=0.5",
            "cluster metric=bogus",
            "cluster profile=bogus",
            "cluster scale_features=bogus",
            "cluster sampler=bogus",
            "cluster method=bogus",
            "cluster strategy=bogus",
            "cluster m=1",
            "cluster m=xyz",
            "cluster eps=-0.5",
            "cluster eps=nope",
            "cluster max_passes=0",
            // OneBatch-only knobs must not be silently dropped
            "cluster method=FasterPAM m=50",
            "cluster method=k-means++ strategy=steepest",
            "cluster method=Random sampler=unif",
            // v5 additions
            "cluster deadline_ms=0",
            "cluster deadline_ms=soon",
            "submit deadline_ms=0",
            "cluster dataset=\"unterminated",
            "poll",
            "poll job=x9",
            "wait job=",
            "cancel job=j",
            // v6 additions
            "promote",
            "promote job=j99",
            "assign",
            "assign model=ghost point=1,2",
            "assign point=1,2",
            "evict",
            "evict model=ghost",
        ] {
            assert!(handle_line(&st, line).starts_with("err"), "{line:?} should err");
        }
    }

    #[test]
    fn tokenizer_honours_double_quotes() {
        assert_eq!(
            tokenize("cluster dataset=blobs_300_4_3 k=3").unwrap(),
            vec!["cluster".to_string(), "dataset=blobs_300_4_3".into(), "k=3".into()]
        );
        // a quoted span keeps its whitespace; the quotes are stripped
        assert_eq!(
            tokenize("cluster dataset=\"file:/data/my points.csv\" k=3").unwrap(),
            vec!["cluster".to_string(), "dataset=file:/data/my points.csv".into(), "k=3".into()]
        );
        // quotes may wrap a whole token, and "" is a present-but-empty value
        assert_eq!(
            tokenize("\"a b\"=c d=\"\"").unwrap(),
            vec!["a b=c".to_string(), "d=".into()]
        );
        assert!(tokenize("cluster dataset=\"file:/oops.csv").is_err());
        // byte-compat: unquoted lines split exactly like split_whitespace
        let legacy = "cluster dataset=blobs_300_4_3 k=3  seed=1\tthreads=2";
        let expect: Vec<String> = legacy.split_whitespace().map(str::to_string).collect();
        assert_eq!(tokenize(legacy).unwrap(), expect);
    }

    #[test]
    fn onebatch_knobs_are_accepted_and_validated() {
        let st = fresh_state();
        let r = handle_line(
            &st,
            "cluster dataset=blobs_300_4_3 k=3 seed=2 m=60 eps=0.01 max_passes=5 strategy=steepest sampler=unif",
        );
        assert!(r.starts_with("ok method=OneBatch-unif-steepest "), "{r}");
        // a unif run computes exactly n*m dissimilarities -> m= reached
        // the coordinator (plus the steepest engine's gain evals)
        assert!(r.contains("dissim="), "{r}");
    }

    #[test]
    fn cache_reports_miss_then_hit_with_identical_medoids() {
        let st = fresh_state();
        let line = "cluster dataset=blobs_300_4_3 k=3 seed=5";
        let first = handle_line(&st, line);
        let second = handle_line(&st, line);
        assert!(first.starts_with("ok "), "{first}");
        assert!(first.contains("cache=miss"), "{first}");
        assert!(second.contains("cache=hit"), "{second}");
        let meds = |r: &str| {
            r.split("medoids=").nth(1).unwrap().split_whitespace().next().unwrap().to_string()
        };
        assert_eq!(meds(&first), meds(&second));
        let s = st.cache.stats();
        assert_eq!((s.hits, s.misses), (1, 1));
    }

    #[test]
    fn repeated_requests_never_regenerate_after_warmup() {
        let h = serve(ServerConfig::default()).unwrap();
        let jobs: Vec<String> = (0..3)
            .map(|i| format!("cluster dataset=blobs_300_4_3 k=3 seed={i}"))
            .collect();
        for job in &jobs {
            assert!(request(h.addr, job).unwrap().contains("cache=miss"));
        }
        let warm_misses = h.state.cache.stats().misses;
        for _ in 0..2 {
            for job in &jobs {
                assert!(request(h.addr, job).unwrap().contains("cache=hit"));
            }
        }
        let s = h.state.cache.stats();
        assert_eq!(s.misses, warm_misses, "no regeneration after warmup");
        assert_eq!(s.hits, 6);
        let stats_line = request(h.addr, "stats").unwrap();
        assert!(stats_line.starts_with("ok cache_hits=6 cache_misses=3"), "{stats_line}");
        h.shutdown();
    }

    #[test]
    fn stats_reports_per_method_aggregates() {
        let st = fresh_state();
        for line in [
            "cluster dataset=blobs_300_4_3 k=3 seed=1",
            "cluster dataset=blobs_300_4_3 k=3 seed=2",
            "cluster dataset=blobs_300_4_3 k=3 seed=1 method=FasterPAM",
        ] {
            assert!(handle_line(&st, line).starts_with("ok "), "{line}");
        }
        let stats = handle_line(&st, "stats");
        assert!(stats.contains("method.OneBatch-nniw.count=2"), "{stats}");
        assert!(stats.contains("method.FasterPAM.count=1"), "{stats}");
        for field in
            ["ms_min", "ms_mean", "ms_max", "dissim_min", "dissim_mean", "dissim_max"]
        {
            assert!(stats.contains(&format!("method.FasterPAM.{field}=")), "{field}: {stats}");
        }
        // the snapshot agrees with the wire line
        let snap = st.methods.snapshot();
        assert_eq!(snap.len(), 2);
        let ob = snap.iter().find(|(l, _)| l == "OneBatch-nniw").unwrap();
        assert_eq!(ob.1.count, 2);
        assert!(ob.1.ms_min <= ob.1.ms_mean() && ob.1.ms_mean() <= ob.1.ms_max);
        assert!(ob.1.dissim_min <= ob.1.dissim_max);
    }

    #[test]
    fn metric_and_scaling_are_wire_addressable() {
        let st = fresh_state();
        let base = "cluster dataset=blobs_300_4_3 k=3 seed=5";
        let l1 = handle_line(&st, base);
        let l2 = handle_line(&st, &format!("{base} metric=l2"));
        let mm = handle_line(&st, &format!("{base} metric=l2 scale_features=minmax"));
        for r in [&l1, &l2, &mm] {
            assert!(r.starts_with("ok "), "{r}");
        }
        // the matrix is metric-independent (one cache entry), but the
        // minmax-scaled variant is a distinct entry
        assert!(l2.contains("cache=hit"), "{l2}");
        assert!(mm.contains("cache=miss"), "{mm}");
        assert_eq!(st.cache.stats().entries, 2);
    }

    #[test]
    fn file_rows_hint_gates_infeasible_methods_before_any_io() {
        // the path does not exist: with a large rows hint the request
        // must be rejected on the hint alone, before any stat/load
        let st = fresh_state();
        let r = handle_line(
            &st,
            "cluster dataset=file:/definitely/not/here.csv?rows=50000 k=5 method=FasterPAM",
        );
        assert!(r.starts_with("err"), "{r}");
        assert!(r.contains("infeasible at n=50000"), "{r}");
        assert_eq!(st.cache.stats(), CacheStats::default());
    }

    #[test]
    fn infeasible_large_scale_method_rejected_before_generation() {
        let st = fresh_state();
        let r = handle_line(&st, "cluster dataset=covertype k=5 method=FasterPAM");
        assert!(r.starts_with("err"), "{r}");
        assert!(r.contains("infeasible"), "{r}");
        let s = st.cache.stats();
        assert_eq!((s.misses, s.entries), (0, 0), "must not generate the dataset");
    }

    #[test]
    fn cluster_handler_is_deterministic() {
        let args = kv(&[("dataset", "blobs_300_4_3"), ("k", "3"), ("seed", "5")]);
        // fresh state each side so both runs are cache=miss; strip the
        // timing field (wall-clock varies run to run)
        let stable = |r: String| r.split(" seconds=").next().unwrap().to_string();
        assert_eq!(
            stable(handle_cluster(&fresh_state(), &args, 0.0).unwrap()),
            stable(handle_cluster(&fresh_state(), &args, 0.0).unwrap())
        );
    }

    #[test]
    fn threaded_cluster_matches_serial_cluster() {
        let mk = |threads: &str| -> String {
            let args = kv(&[
                ("dataset", "blobs_400_4_3"),
                ("k", "3"),
                ("seed", "6"),
                ("threads", threads),
            ]);
            let r = handle_cluster(&fresh_state(), &args, 0.0).unwrap();
            r.split(" seconds=").next().unwrap().to_string()
        };
        assert_eq!(mk("1"), mk("4"));
    }

    #[test]
    fn config_resolves_auto_knobs() {
        let auto = ServerConfig { workers: 0, queue_cap: 0, budget: 0, ..Default::default() };
        assert!(auto.resolved_workers() >= 1);
        assert_eq!(auto.resolved_queue_cap(), auto.resolved_workers() * 4);
        assert_eq!(auto.resolved_budget(), 4 * MAX_JOB_COST);
        assert_eq!(auto.resolved_byte_budget(), 8 << 30);
        assert_eq!(auto.resolved_retain_cap(), 64);
        assert_eq!(auto.resolved_model_cap(), 32);
        assert_eq!(auto.resolved_conn_cap(), 8192);
        let fixed = ServerConfig {
            workers: 3,
            queue_cap: 7,
            budget: 99,
            byte_budget: 123,
            retain_cap: 5,
            model_cap: 2,
            conn_cap: 11,
            ..Default::default()
        };
        assert_eq!(fixed.resolved_workers(), 3);
        assert_eq!(fixed.resolved_queue_cap(), 7);
        assert_eq!(fixed.resolved_budget(), 99);
        assert_eq!(fixed.resolved_byte_budget(), 123);
        assert_eq!(fixed.resolved_retain_cap(), 5);
        assert_eq!(fixed.resolved_model_cap(), 2);
        assert_eq!(fixed.resolved_conn_cap(), 11);
        // workers=0 actually serves (auto-detected pool)
        let h = serve(auto).unwrap();
        assert!(request(h.addr, "ping").unwrap().starts_with("pong"));
        h.shutdown();
    }

    #[test]
    fn admission_budget_reserves_and_releases() {
        let b = AdmissionBudget::new(100);
        let p1 = b.try_admit(60).unwrap();
        assert_eq!((p1.units(), b.used()), (60, 60));
        // over the remaining budget -> rejected with the in-use units
        assert_eq!(b.try_admit(50).unwrap_err(), 60);
        let p2 = b.try_admit(40).unwrap();
        drop(p1);
        assert_eq!(b.used(), 40);
        drop(p2);
        assert_eq!(b.used(), 0);
        // idle exception: an oversized job may run alone...
        let big = b.try_admit(1000).unwrap();
        // ...but blocks everything else until it finishes
        assert!(b.try_admit(1).is_err());
        drop(big);
        assert_eq!(b.used(), 0);
    }

    #[test]
    fn strict_budget_disables_the_idle_exception() {
        let b = AdmissionBudget::with_strict(100, true);
        assert!(b.is_strict());
        // idle budget, oversized job: rejected under strict
        assert_eq!(b.try_admit(1000).unwrap_err(), 0);
        // within-budget jobs are unaffected
        let p = b.try_admit(80).unwrap();
        assert_eq!(b.used(), 80);
        // and repricing respects the hard ceiling too
        let mut p = p;
        assert!(p.reprice(100).is_ok());
        assert!(p.reprice(101).is_err());
        drop(p);
        assert_eq!(b.used(), 0);
        // the owned permit enforces the same rule
        let arc = Arc::new(AdmissionBudget::with_strict(100, true));
        assert!(JobPermit::admit(&arc, 1000).is_err());
        let jp = JobPermit::admit(&arc, 50).unwrap();
        assert_eq!((jp.units(), arc.used()), (50, 50));
        drop(jp);
        assert_eq!(arc.used(), 0);
    }

    #[test]
    fn permit_reprice_is_atomic_and_keeps_old_hold_on_failure() {
        let b = AdmissionBudget::new(100);
        let mut p1 = b.try_admit(40).unwrap();
        let p2 = b.try_admit(40).unwrap();
        // over the other holder's headroom -> rejected, old hold kept
        assert_eq!(p1.reprice(70).unwrap_err(), 40, "reports the other holders' units");
        assert_eq!((p1.units(), b.used()), (40, 80));
        // fits alongside the other holder -> swapped in one step
        assert!(p1.reprice(60).is_ok());
        assert_eq!((p1.units(), b.used()), (60, 100));
        drop(p2);
        // lone holder: the lone-job exception applies to repricing too
        assert!(p1.reprice(5_000).is_ok());
        assert_eq!(b.used(), 5_000);
        drop(p1);
        assert_eq!(b.used(), 0, "drop releases the repriced amount, not the original");
    }

    #[test]
    fn cluster_replies_report_cost_and_hold_no_budget_after() {
        let st = fresh_state();
        let r = handle_line(&st, "cluster dataset=blobs_300_4_3 k=3 seed=1");
        assert!(r.starts_with("ok "), "{r}");
        let cost: u64 = r
            .split(" cost=")
            .nth(1)
            .unwrap()
            .split_whitespace()
            .next()
            .unwrap()
            .parse()
            .unwrap();
        // OneBatch prices its n*m pass; blobs_300 caps m at n=300
        assert_eq!(cost, MethodSpec::default().cost(300, 3, None).units, "{r}");
        assert_eq!(st.admission.used(), 0, "permit must release when the job ends");
        // v6: the final assignment pass's mean distance rides along
        assert!(r.contains(" inertia="), "{r}");
    }

    #[test]
    fn stats_reports_budget_and_histograms_and_resets() {
        let st = fresh_state();
        assert!(handle_line(&st, "cluster dataset=blobs_300_4_3 k=3 seed=1").starts_with("ok "));
        let stats = handle_line(&st, "stats");
        assert!(stats.contains(" budget_total="), "{stats}");
        assert!(stats.contains(" budget_used=0 "), "{stats}");
        // v9: the byte axis rides along as mem_total=/mem_used=
        assert!(stats.contains(" mem_total="), "{stats}");
        assert!(stats.contains(" mem_used=0 "), "{stats}");
        assert!(stats.contains(" hist_le_ms=1,2,5,"), "{stats}");
        assert!(stats.contains("method.OneBatch-nniw.ms_hist="), "{stats}");
        assert!(stats.contains("method.OneBatch-nniw.queue_hist="), "{stats}");
        // the solve histogram holds exactly the one served job
        let hist = stats
            .split("method.OneBatch-nniw.ms_hist=")
            .nth(1)
            .unwrap()
            .split_whitespace()
            .next()
            .unwrap();
        let total: u64 = hist.split(',').map(|c| c.parse::<u64>().unwrap()).sum();
        assert_eq!(total, 1, "{stats}");
        // v5: job lifecycle + pool gauges ride along
        assert!(stats.contains(" jobs.submitted=0 "), "inline cluster is not a job: {stats}");
        assert!(stats.contains(" shed=0 "), "{stats}");
        assert!(stats.contains(" pools=1"), "one width cached: {stats}");
        // reset re-bases method aggregates and cache counters
        assert_eq!(handle_line(&st, "stats reset"), "ok");
        let after = handle_line(&st, "stats");
        assert!(after.starts_with("ok cache_hits=0 cache_misses=0 cache_entries=1"), "{after}");
        assert!(!after.contains("method.OneBatch-nniw"), "{after}");
    }

    #[test]
    fn over_budget_requests_err_with_cost() {
        let st = ServerState::new(&ServerConfig { budget: 1_000, ..Default::default() });
        // occupy the budget so the idle exception cannot apply
        let _held = st.admission.try_admit(900).unwrap();
        let r = handle_line(&st, "cluster dataset=blobs_300_4_3 k=3 seed=1");
        assert!(r.starts_with("err over budget"), "{r}");
        assert!(r.contains("cost="), "{r}");
        // nothing was loaded for the rejected job
        assert_eq!(st.cache.stats(), CacheStats::default());
    }

    #[test]
    fn strict_budget_rejects_oversized_lone_cluster_jobs() {
        // v4 default: the idle exception admits an over-budget lone job
        let lax = ServerState::new(&ServerConfig { budget: 1_000, ..Default::default() });
        let r = handle_line(&lax, "cluster dataset=blobs_300_4_3 k=3 seed=1");
        assert!(r.starts_with("ok "), "{r}");
        // strict: the same request is refused even on an idle budget
        let strict = ServerState::new(&ServerConfig {
            budget: 1_000,
            strict_budget: true,
            ..Default::default()
        });
        let r = handle_line(&strict, "cluster dataset=blobs_300_4_3 k=3 seed=1");
        assert!(r.starts_with("err over budget"), "{r}");
        assert_eq!(strict.admission.used(), 0);
        assert_eq!(strict.cache.stats(), CacheStats::default(), "no I/O for a rejected job");
    }

    #[test]
    fn methods_agree_between_wire_and_library() {
        // the medoids a wire request reports must be exactly what the
        // unified API computes for the same (data, method, seed)
        let st = fresh_state();
        let r = handle_line(&st, "cluster dataset=blobs_250_4_3 k=3 seed=4 method=FasterPAM");
        let wire: Vec<usize> = r
            .split("medoids=")
            .nth(1)
            .unwrap()
            .split_whitespace()
            .next()
            .unwrap()
            .split(',')
            .map(|t| t.parse().unwrap())
            .collect();
        let data = crate::data::synth::generate("blobs_250_4_3", 1.0, 4);
        let backend = NativeBackend::new(Metric::L1);
        let lib =
            solver::solve(&data.x, &SolveSpec::new(MethodSpec::FasterPam, 3, 4), &backend).unwrap();
        assert_eq!(wire, lib.medoids);
    }

    #[test]
    fn workers_serve_concurrently() {
        // 4 concurrent 150 ms sleeps finish in ~1 batch, far below the
        // 600 ms serial floor (sleeps hold connection slots, and the
        // accept path hands each to its own thread).
        let h = serve(ServerConfig {
            addr: "127.0.0.1:0".into(),
            workers: 4,
            queue_cap: 8,
            ..Default::default()
        })
        .unwrap();
        let t0 = Instant::now();
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let addr = h.addr;
                std::thread::spawn(move || request(addr, "sleep ms=150").unwrap())
            })
            .collect();
        for th in handles {
            assert!(th.join().unwrap().starts_with("ok slept_ms=150"));
        }
        let elapsed = t0.elapsed().as_millis();
        assert!(elapsed < 550, "concurrent sleeps should overlap, took {elapsed} ms");
        h.shutdown();
    }

    #[test]
    fn sleep_command_caps_duration() {
        let r = handle_line(&fresh_state(), "sleep ms=1");
        assert!(r.starts_with("ok slept_ms=1"), "{r}");
    }

    #[test]
    fn submit_poll_cancel_on_a_workerless_state() {
        // without workers the job sits queued forever — which makes the
        // queued half of the lifecycle fully deterministic
        let st = fresh_state();
        let r = handle_line(&st, "submit dataset=blobs_300_4_3 k=3 seed=1");
        assert!(r.starts_with("ok job=j1 cost="), "{r}");
        let cost: u64 = r.split("cost=").nth(1).unwrap().trim().parse().unwrap();
        assert_eq!(cost, MethodSpec::default().cost(300, 3, None).units);
        assert_eq!(st.admission.used(), cost, "a queued job holds its permit");
        let p = handle_line(&st, "poll job=j1");
        assert!(p.starts_with("ok job=j1 state=queued cost="), "{p}");
        assert!(p.contains(" waited_ms="), "{p}");
        let g = st.jobs.gauges();
        assert_eq!((g.queued, g.running, g.retained), (1, 0, 0));
        // cancel releases the permit without the job ever running
        let c = handle_line(&st, "cancel job=j1");
        assert_eq!(c, "ok job=j1 state=cancelled");
        assert_eq!(st.admission.used(), 0, "cancel must release the admission permit");
        assert!(handle_line(&st, "poll job=j1").starts_with("ok job=j1 state=cancelled"));
        // idempotent: a second cancel reports the terminal state
        assert_eq!(handle_line(&st, "cancel job=j1"), "ok job=j1 state=cancelled");
        // wait on a terminal job returns its stored reply (the error)
        assert_eq!(handle_line(&st, "wait job=j1"), "err cancelled job=j1");
        let jobs = handle_line(&st, "jobs");
        let expect = "ok queued=0 running=0 retained=1 submitted=1 done=0 failed=0 \
                      cancelled=1 expired=0 shed=0";
        assert_eq!(jobs, expect);
        // handles j2, j3, ... are monotonic
        assert!(handle_line(&st, "submit dataset=blobs_300_4_3 k=3").starts_with("ok job=j2 "));
        // unknown handles are errors
        assert!(handle_line(&st, "poll job=j99").starts_with("err unknown job j99"));
        assert!(handle_line(&st, "cancel job=j99").starts_with("err unknown job j99"));
    }

    #[test]
    fn wait_without_workers_requires_timeout() {
        let st = fresh_state();
        assert!(handle_line(&st, "submit dataset=blobs_300_4_3 k=3").starts_with("ok job=j1"));
        let r = handle_line(&st, "wait job=j1");
        assert!(r.starts_with("err wait needs timeout_ms="), "{r}");
        // a bounded wait returns a timed_out probe instead of blocking
        let r = handle_line(&st, "wait job=j1 timeout_ms=10");
        assert_eq!(r, "ok job=j1 state=queued timed_out=1");
    }

    #[test]
    fn submit_rejects_over_budget_like_cluster() {
        let st = ServerState::new(&ServerConfig { budget: 1_000, ..Default::default() });
        let _held = st.admission.try_admit(900).unwrap();
        let r = handle_line(&st, "submit dataset=blobs_300_4_3 k=3 seed=1");
        assert!(r.starts_with("err over budget"), "{r}");
        assert!(r.contains("cost="), "{r}");
        let g = st.jobs.gauges();
        assert_eq!(g.queued, 0, "a rejected submit enqueues nothing");
    }

    #[test]
    fn pool_cache_builds_one_pool_per_width() {
        let cache = PoolCache::new();
        assert_eq!(cache.widths(), 0);
        let a = cache.get(2);
        let b = cache.get(2);
        assert_eq!(cache.widths(), 1, "same width reuses the cached pool");
        assert_eq!((a.threads(), b.threads()), (2, 2));
        let _serial = cache.get(1);
        assert_eq!(cache.widths(), 2);
        // 0 resolves to the auto width and shares its explicit twin
        let auto = cache.get(0);
        let explicit = cache.get(auto.threads());
        assert_eq!(auto.threads(), explicit.threads());
        // cached pools still compute correctly after many clones
        let parts = cache.get(2).map_ranges(10, |r| r.len());
        assert_eq!(parts.into_iter().sum::<usize>(), 10);
    }

    #[test]
    fn pool_cache_is_bounded_and_evicts_lru() {
        let cache = PoolCache::new();
        // a client-style width sweep must not pin unbounded threads
        for width in 1..=POOL_CACHE_CAP + 4 {
            let _ = cache.get(width);
        }
        assert_eq!(cache.widths(), POOL_CACHE_CAP);
        // the earliest widths were evicted (LRU), the latest survive;
        // a rebuilt evicted width still computes correctly
        let parts = cache.get(2).map_ranges(12, |r| r.len());
        assert_eq!(parts.into_iter().sum::<usize>(), 12);
        assert_eq!(cache.widths(), POOL_CACHE_CAP, "rebuild evicts another width, cap holds");
        // an evicted pool's clones keep working (workers join only when
        // the last handle drops)
        let held = cache.get(3);
        for width in 4..=POOL_CACHE_CAP + 8 {
            let _ = cache.get(width);
        }
        let parts = held.map_ranges(9, |r| r.len());
        assert_eq!(parts.into_iter().sum::<usize>(), 9);
    }

    #[test]
    fn submit_backpressure_bounds_queued_jobs() {
        // no workers: submitted jobs stay queued, so the queue bound is
        // exactly observable
        let st = ServerState::new(&ServerConfig { queue_cap: 2, ..Default::default() });
        assert!(handle_line(&st, "submit dataset=blobs_300_4_3 k=3").starts_with("ok job=j1 "));
        assert!(handle_line(&st, "submit dataset=blobs_300_4_3 k=3").starts_with("ok job=j2 "));
        let r = handle_line(&st, "submit dataset=blobs_300_4_3 k=3");
        assert!(r.starts_with("err queue full (2 jobs queued)"), "{r}");
        // cancelling a queued job frees its slot for the next submit
        // (the rejected submit consumed no handle, so the next is j3)
        assert_eq!(handle_line(&st, "cancel job=j1"), "ok job=j1 state=cancelled");
        assert!(handle_line(&st, "submit dataset=blobs_300_4_3 k=3").starts_with("ok job=j3 "));
        let g = st.jobs.gauges();
        assert_eq!((g.queued, g.retained), (2, 1));
    }

    #[test]
    fn panicking_solve_releases_budget_and_fails_the_job() {
        // Regression test for the panic-safety audit: a solve() that
        // unwinds must (a) release its admission permit — the budget
        // returns to zero — and (b) land the job `failed`, never stuck
        // `running`.  Both are drop-guard obligations, so we drive a
        // panicking solve through the exact production path
        // (run_job_with is what run_job delegates to).
        let st = fresh_state();
        let r = handle_line(&st, "submit dataset=blobs_300_4_3 k=3 seed=1");
        assert!(r.starts_with("ok job=j1 cost="), "{r}");
        assert!(st.admission.used() > 0, "a queued job holds its permit");

        let picked = st.jobs.next_job().expect("one queued job");
        run_job_with(&st, picked, |_, _, _permit, _, _| panic!("solver exploded"));

        assert_eq!(st.admission.used(), 0, "the panic path must release the permit");
        let p = handle_line(&st, "poll job=j1");
        assert!(p.starts_with("ok job=j1 state=failed error=job panicked"), "{p}");
        let c = st.jobs.counters();
        assert_eq!(c.failed(), 1);
        let g = st.jobs.gauges();
        assert_eq!((g.queued, g.running), (0, 0), "the job must not stay running");
        #[cfg(debug_assertions)]
        {
            let (reserved, released) = st.admission.debug_units_flow();
            assert_eq!(reserved, released, "every reserved unit must be released");
        }
    }

    #[test]
    fn drain_one_runs_exactly_one_queued_job() {
        let st = fresh_state();
        assert!(!st.drain_one(), "an empty registry has nothing to drain");
        assert!(handle_line(&st, "submit dataset=blobs_300_4_3 k=3 seed=1").starts_with("ok "));
        assert!(st.drain_one());
        assert!(handle_line(&st, "poll job=j1").starts_with("ok job=j1 state=done "));
        assert!(!st.drain_one(), "the queue is drained");
    }

    #[test]
    fn stats_reports_per_verb_counters_and_resets() {
        let st = fresh_state();
        assert!(handle_line(&st, "ping").starts_with("pong"));
        assert!(handle_line(&st, "ping").starts_with("pong"));
        assert!(handle_line(&st, "sleep ms=1").starts_with("ok "));
        // malformed arguments still count: the verb was requested
        assert!(handle_line(&st, "poll").starts_with("err"));
        let s = handle_line(&st, "stats");
        assert!(s.contains(" verb.ping=2 "), "{s}");
        assert!(s.contains(" verb.sleep=1"), "{s}");
        assert!(s.contains(" verb.poll=1 "), "{s}");
        assert!(s.contains(" verb.cluster=0 "), "{s}");
        // every wire verb shows up, counted or not — the stats line is
        // how operators discover the verb set
        for verb in VERBS {
            assert!(s.contains(&format!(" verb.{verb}=")), "{verb} missing: {s}");
        }
        assert!(handle_line(&st, "stats reset").starts_with("ok"));
        let s = handle_line(&st, "stats");
        assert!(s.contains(" verb.ping=0 "), "{s}");
        // the reset zeroed its own `stats` tick (record runs before the
        // reset arm), so only this follow-up request is counted
        assert!(s.contains(" verb.stats=1 "), "{s}");
    }

    /// Solve one job to completion on a workerless state and return its
    /// wire handle — the setup every serving-verb test starts from.
    fn solved_job(st: &ServerState) -> String {
        let r = handle_line(st, "submit dataset=blobs_300_4_3 k=3 seed=1");
        assert!(r.starts_with("ok job="), "{r}");
        let id = r.split_whitespace().nth(1).unwrap().strip_prefix("job=").unwrap().to_string();
        assert!(st.drain_one());
        id
    }

    #[test]
    fn promote_assign_models_evict_lifecycle() {
        let st = fresh_state();
        let job = solved_job(&st);

        let p = handle_line(&st, &format!("promote job={job} name=blobs"));
        assert!(p.starts_with("ok model=blobs "), "{p}");
        assert!(p.contains(&format!(" job={job} ")), "{p}");
        assert!(p.contains(" k=3 dim=4 metric=l1 inertia="), "{p}");

        // a second promote of the same job mints a fresh auto handle
        let p2 = handle_line(&st, &format!("promote job={job}"));
        assert!(p2.starts_with("ok model=m"), "{p2}");

        let a = handle_line(&st, "assign model=blobs point=0.0,0.0,0.0,0.0 point=1.0,2.0,3.0,4.0");
        assert!(a.starts_with("ok model=blobs n=2 labels="), "{a}");
        assert!(a.contains(" dists="), "{a}");
        let t = handle_line(&st, "assign model=blobs top2=1 point=0.5,0.5,0.5,0.5");
        assert!(t.starts_with("ok model=blobs n=1 labels="), "{t}");
        assert!(t.contains(" second=") && t.contains(" dists2="), "{t}");

        let m = handle_line(&st, "models");
        assert!(m.starts_with("ok count=2 cap=32 promoted=2 evicted=0"), "{m}");
        assert!(m.contains(" model.blobs.job="), "{m}");
        assert!(m.contains(" model.blobs.method=OneBatch-nniw "), "{m}");
        assert!(m.contains(" model.blobs.source=synth:blobs_300_4_3"), "{m}");

        assert!(handle_line(&st, "evict model=blobs").starts_with("ok evicted model=blobs"));
        assert!(handle_line(&st, "assign model=blobs point=0,0,0,0").starts_with("err unknown model"));
        // explicit eviction is not an LRU eviction
        assert!(handle_line(&st, "models").starts_with("ok count=1 cap=32 promoted=2 evicted=0"));
    }

    #[test]
    fn promote_rejects_jobs_without_a_model() {
        let st = fresh_state();
        // queued (workerless, never drained) -> not done yet
        assert!(handle_line(&st, "submit dataset=blobs_300_4_3 k=3 seed=1").starts_with("ok job=j1"));
        let r = handle_line(&st, "promote job=j1");
        assert!(r.starts_with("err job j1 is queued"), "{r}");
        // cancelled -> terminal, but no fitted model was ever captured
        assert!(handle_line(&st, "cancel job=j1").starts_with("ok "));
        let r = handle_line(&st, "promote job=j1");
        assert!(r.starts_with("err job j1 holds no model (state=cancelled)"), "{r}");
        // reserved auto-handle shape is not user-assignable
        let job = solved_job(&st);
        let r = handle_line(&st, &format!("promote job={job} name=m7"));
        assert!(r.starts_with("err "), "{r}");
    }

    #[test]
    fn assign_validates_points_metric_and_top2() {
        let st = fresh_state();
        let job = solved_job(&st);
        assert!(handle_line(&st, &format!("promote job={job} name=b")).starts_with("ok "));
        for line in [
            "assign model=b",                            // no point=
            "assign model=b point=1,2",                  // wrong dimension
            "assign model=b point=1,2,nan,4",            // non-finite
            "assign model=b point=1,2,,4",               // empty coordinate
            "assign model=b point=0,0,0,0 metric=l2",    // fitted under l1
            "assign model=b point=0,0,0,0 metric=warp",  // unknown metric
            "assign model=b point=0,0,0,0 top2=yes",     // bad flag
            "assign model=b point=0,0,0,0 profile=warp", // unknown profile
        ] {
            assert!(handle_line(&st, line).starts_with("err"), "{line:?} should err");
        }
        // matching explicit metric= is fine
        let r = handle_line(&st, "assign model=b point=0,0,0,0 metric=l1");
        assert!(r.starts_with("ok model=b n=1 "), "{r}");
        // both explicit profiles serve; an L1 model answers identically
        // under either (the fast kernel only applies to SqL2/L2)
        let exact = handle_line(&st, "assign model=b point=0,0,0,0 profile=exact");
        let fast = handle_line(&st, "assign model=b point=0,0,0,0 profile=fast");
        assert_eq!(exact, fast);
        assert_eq!(exact, r, "default profile is fast");
    }

    #[test]
    fn assign_serving_reuses_scratch_with_no_matrix_allocations() {
        let st = fresh_state();
        let job = solved_job(&st);
        assert!(handle_line(&st, &format!("promote job={job} name=b")).starts_with("ok "));
        let (_, scratch) = st.models.get_serving("b").expect("model resident");
        // warm the scratch with the largest batch first...
        let big = "assign model=b top2=1 point=0,0,0,0 point=1,1,1,1 point=2,0,2,0";
        assert!(handle_line(&st, big).starts_with("ok model=b n=3 "), "scratch warmup");
        let caps = {
            let s = sync_ext::lock_or_recover(&scratch);
            assert_eq!(s.reuses, 1);
            assert!(s.row.capacity() >= 1, "k-length row allocated");
            (
                s.points.capacity(),
                s.row.capacity(),
                s.labels.capacity(),
                s.dists.capacity(),
                s.second.capacity(),
                s.dists2.capacity(),
            )
        };
        // ...then every same-or-smaller request reuses those buffers:
        // capacities must not move (zero per-request matrix allocations)
        for _ in 0..5 {
            assert!(handle_line(&st, big).starts_with("ok "));
            assert!(handle_line(&st, "assign model=b point=0.5,0.5,0.5,0.5").starts_with("ok "));
        }
        let s = sync_ext::lock_or_recover(&scratch);
        assert_eq!(s.reuses, 11, "every assign served from the one scratch");
        let caps_after = (
            s.points.capacity(),
            s.row.capacity(),
            s.labels.capacity(),
            s.dists.capacity(),
            s.second.capacity(),
            s.dists2.capacity(),
        );
        assert_eq!(caps, caps_after, "steady-state serving must not reallocate");
    }

    #[test]
    fn stats_reports_model_gauges_and_assign_aggregates() {
        let st = fresh_state();
        let job = solved_job(&st);
        assert!(handle_line(&st, &format!("promote job={job} name=b")).starts_with("ok "));
        assert!(handle_line(&st, "assign model=b point=0,0,0,0").starts_with("ok "));
        assert!(handle_line(&st, "assign model=b point=1,1,1,1").starts_with("ok "));
        let s = handle_line(&st, "stats");
        assert!(s.contains(" models=1 "), "{s}");
        assert!(s.contains(" model.b.assign_count=2 model.b.assign_ms_mean="), "{s}");
        // serving aggregates outlive the model they measured...
        assert!(handle_line(&st, "evict model=b").starts_with("ok "));
        let s = handle_line(&st, "stats");
        assert!(s.contains(" models=0 "), "{s}");
        assert!(s.contains(" model.b.assign_count=2 "), "{s}");
        // ...but reset clears them with everything else
        assert!(handle_line(&st, "stats reset").starts_with("ok"));
        let s = handle_line(&st, "stats");
        assert!(!s.contains(" model.b."), "{s}");
    }

    #[test]
    fn byte_axis_admits_reprices_and_releases() {
        let b = AdmissionBudget::with_limits(100, 1000, false);
        assert_eq!(b.byte_total(), 1000);
        let p1 = b.try_admit_costed(10, 600).unwrap();
        assert_eq!((p1.units(), p1.bytes()), (10, 600));
        assert_eq!((b.used(), b.bytes_used()), (10, 600));
        // byte axis rejects alongside p1's hold; the unit half of the
        // failed admit is rolled back, so nothing leaks
        assert_eq!(b.try_admit_costed(10, 500).unwrap_err(), AdmitError::Bytes(600));
        assert_eq!((b.used(), b.bytes_used()), (10, 600), "failed admit holds nothing");
        // the unit axis rejects first, before bytes are touched
        assert_eq!(b.try_admit_costed(95, 10).unwrap_err(), AdmitError::Units(10));
        drop(p1);
        assert_eq!((b.used(), b.bytes_used()), (0, 0));
        // the lone-job idle exception applies to bytes too...
        let big = b.try_admit_costed(1, 5000).unwrap();
        assert_eq!(b.try_admit_costed(1, 1).unwrap_err(), AdmitError::Bytes(5000));
        drop(big);
        // ...unless strict, which hard-ceilings both axes
        let s = Arc::new(AdmissionBudget::with_limits(100, 1000, true));
        assert_eq!(JobPermit::admit_costed(&s, 1, 5000).unwrap_err(), AdmitError::Bytes(0));
        let mut jp = JobPermit::admit_costed(&s, 50, 900).unwrap();
        // a reprice refused on the byte axis keeps both old holds
        assert_eq!(jp.reprice_costed(60, 1200).unwrap_err(), AdmitError::Bytes(0));
        assert_eq!((jp.units(), jp.bytes()), (50, 900));
        assert_eq!((s.used(), s.bytes_used()), (50, 900));
        assert!(jp.reprice_costed(60, 1000).is_ok());
        assert_eq!((s.used(), s.bytes_used()), (60, 1000));
        drop(jp);
        assert_eq!((s.used(), s.bytes_used()), (0, 0));
        #[cfg(debug_assertions)]
        {
            let (reserved, released) = s.debug_bytes_flow();
            assert_eq!(reserved, released, "every reserved byte must be released");
        }
    }

    #[test]
    fn streaming_cluster_serves_out_of_core_and_matches_resident_bits() {
        let x = crate::data::synth::generate("blobs_320_6_4", 1.0, 11).x;
        let path =
            std::env::temp_dir().join(format!("obpam_srv_stream_{}.npy", std::process::id()));
        crate::data::npy::write_npy(&path, &x).unwrap();
        let st = fresh_state();
        let r = handle_line(&st, &format!("cluster dataset=npy:{} k=4 seed=3", path.display()));
        assert!(r.starts_with("ok method=OneBatch-nniw cache=stream medoids="), "{r}");
        assert!(r.contains(" bytes="), "{r}");
        assert!(r.contains(" inertia="), "{r}");
        // streamed solves bypass the dataset cache entirely
        assert_eq!(st.cache.stats(), CacheStats::default());
        assert_eq!((st.admission.used(), st.admission.bytes_used()), (0, 0));
        // the streamed medoids and objective are the resident solve's
        // bits for the same bytes (the wire default profile is fast)
        let mut spec = SolveSpec::new(MethodSpec::default(), 4, 3);
        spec.profile = ComputeProfile::Fast;
        let backend = NativeBackend::new(Metric::L1).with_profile(ComputeProfile::Fast);
        let lib = solver::solve(&x, &spec, &backend).unwrap();
        let wire: Vec<usize> = r
            .split("medoids=")
            .nth(1)
            .unwrap()
            .split_whitespace()
            .next()
            .unwrap()
            .split(',')
            .map(|t| t.parse().unwrap())
            .collect();
        assert_eq!(wire, lib.medoids);
        let obj = eval::objective(&x, &lib.medoids, &DissimCounter::new(Metric::L1));
        assert!(r.contains(&format!(" objective={obj:.6} ")), "{r}");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn full_matrix_over_byte_budget_is_rejected_with_bytes_price() {
        let x = crate::data::synth::generate("blobs_600_8_5", 1.0, 7).x;
        let path =
            std::env::temp_dir().join(format!("obpam_srv_bytebudget_{}.npy", std::process::id()));
        crate::data::npy::write_npy(&path, &x).unwrap();
        let st = ServerState::new(&ServerConfig {
            byte_budget: 400_000,
            strict_budget: true,
            ..Default::default()
        });
        // a full-matrix method must pin n*p + n*n resident: priced over
        // the byte budget and refused before any bulk I/O
        let r = handle_line(
            &st,
            &format!("cluster dataset=npy:{} k=5 method=FasterPAM", path.display()),
        );
        assert!(r.starts_with("err over byte budget: bytes="), "{r}");
        assert_eq!(st.cache.stats(), CacheStats::default(), "no load for a rejected job");
        // the same dataset still serves out of core under the same
        // budget: the streaming price is the batch slice + one chunk
        let r = handle_line(&st, &format!("cluster dataset=npy:{} k=5", path.display()));
        assert!(r.starts_with("ok method=OneBatch-nniw cache=stream "), "{r}");
        assert_eq!(st.admission.bytes_used(), 0, "permit released at job end");
        let _ = std::fs::remove_file(&path);
    }
}
