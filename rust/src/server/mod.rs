//! Clustering job server: a std::net TCP service with a bounded job
//! queue, a fixed worker pool (tokio is unavailable offline;
//! thread-per-worker over a bounded queue is the right shape for
//! CPU-bound jobs anyway), cost-weighted admission, and a sharded
//! dataset cache that loads cold misses outside its locks.
//!
//! # Line protocol v4 (one request line per connection, one reply line)
//!
//! ```text
//! -> cluster dataset=blobs_2000_8_5 k=5 method=FasterPAM seed=3 threads=4
//! <- ok method=FasterPAM cache=miss medoids=4,17,... objective=0.1234 seconds=0.05 dissim=123456 swaps=9 source=synth:blobs_2000_8_5 cost=4000000 queue_ms=0.2 served_ms=50.1
//! -> cluster dataset=file:/data/points.csv metric=l2 scale_features=minmax k=3
//! <- ok method=OneBatch-nniw cache=hit medoids=... objective=... seconds=... dissim=... swaps=... source=file:/data/points.csv cost=61200 queue_ms=0.1 served_ms=1.9
//! -> stats
//! <- ok cache_hits=12 cache_misses=3 cache_entries=3 budget_total=... budget_used=... hist_le_ms=1,2,... method.FasterPAM.count=2 ... method.FasterPAM.ms_hist=0,1,... method.FasterPAM.queue_hist=2,0,... queue_ms=0.0 served_ms=0.0
//! -> stats reset
//! <- ok queue_ms=0.0 served_ms=0.0
//! -> ping
//! <- pong queue_ms=0.0 served_ms=0.0
//! ```
//!
//! v4 over v3: every v3 reply field is byte-identical and in the same
//! position; `cluster` replies append `cost=` (the work units the job
//! was admitted at, see [`JobCost`]), every connection-served reply
//! appends `queue_ms=` (accept-to-worker-pickup wait) before
//! `served_ms=`, `stats` gains the admission-budget gauges, fixed
//! latency histograms per method (solve + queue wait; bucket edges in
//! `hist_le_ms=`), and a `stats reset` subcommand that re-bases the
//! method aggregates and cache counters.
//!
//! `cluster` keys:
//!
//! * `dataset=` — a [`DataSource`] URI: `synth:<name>` generates,
//!   `file:<path>[?rows=N]` loads a numeric CSV from disk, and a bare
//!   name aliases `synth:` (every v2 request line is still valid; v2
//!   replies gained only the trailing `source=` field).  Request lines
//!   are whitespace-tokenized, so paths containing spaces are not
//!   addressable on the wire — use the CLI or library for those.
//! * `scale=`, `seed=` — synthetic-generation knobs (`seed=` also seeds
//!   the algorithm; a non-neutral `scale=` with a `file:` source is an
//!   error — file bytes do not scale).  Requests route through a sharded
//!   LRU dataset cache
//!   keyed by `(source identity + fingerprint, scale, seed, scale_features)`
//!   ([`DatasetCache`], bounded by [`ServerConfig::cache_cap`]), so
//!   repeated traffic never reloads data; every reply reports
//!   `cache=hit|miss`.  A `file:` fingerprint mixes size + mtime, so an
//!   edit that changes either invalidates its entries automatically.
//! * `method=` — any [`MethodSpec`] label (`FasterPAM`, `FasterCLARA-50`,
//!   `BanditPAM++-2`, `OneBatch-nniw-steepest`, ...; see
//!   [`MethodSpec::parse`]).  Omitted -> legacy v1 behaviour: OneBatchPAM
//!   with `sampler=` (default `nniw`) and `strategy=` (default `eager`).
//!   Methods the paper marks "Na" at large scale (full `n x n` matrix or
//!   per-round resampling) are rejected above [`FULL_MATRIX_LIMIT`] rows,
//!   *before* loading, using the source's row hint (catalogue prediction
//!   or `?rows=N`).
//! * `metric=` — any [`Metric`] spelling (`l1` default, `l2`,
//!   `sqeuclidean`, `chebyshev`, `cosine`); carried on
//!   [`SolveSpec::metric`] so selection, evaluation and the backend all
//!   agree.
//! * `scale_features=` — `minmax` | `none` (default `none`): min-max
//!   feature preprocessing applied once at admission and cached.
//! * `k=`, `threads=` — shared run parameters.
//! * `m=`, `eps=`, `max_passes=`, `strategy=`, `sampler=` — OneBatch
//!   knobs (batch size, swap-acceptance threshold, pass budget, swap
//!   engine, batch variant).  Sending one alongside a non-OneBatch
//!   `method=` is an error, not silently ignored — as is any
//!   present-but-unparsable value (`err ...` replies).
//!
//! `stats` reports the cache counters and admission-budget gauges plus,
//! per served method label, count/min/mean/max aggregates of solve+eval
//! latency (ms) and dissimilarity computations, and fixed-bucket
//! histograms of solve latency and queue wait ([`MethodMetrics`]).
//! `stats reset` zeroes the method aggregates and cache counters.
//!
//! # Concurrency model
//!
//! * [`ServerConfig::workers`] long-lived worker threads (`0` =
//!   auto-detect, like `Pool::new(0)` / `--threads 0`) drain accepted
//!   connections from an mpsc queue — cross-job parallelism;
//! * each `cluster` job may additionally ask for data parallelism via
//!   the `threads=` key (a [`crate::runtime::Pool`] of persistent
//!   workers per job);
//! * connection admission is a **single atomic** `fetch_update` on the
//!   in-flight counter (queued + running): a burst of connections can
//!   never push it past `queue_cap` (`0` = 4x workers), and rejected
//!   connections get an immediate `err queue full` line instead of
//!   unbounded queueing;
//! * **job admission is weighted by cost**: every `cluster` job is
//!   priced via [`MethodSpec::cost`] over the source's predicted rows
//!   ([`crate::data::DataSource::expected_rows`] — catalogue names and
//!   `file:...?rows=N` hints price *before any I/O*; unpredictable
//!   sources price right after the load) and must reserve its work
//!   units from the [`AdmissionBudget`] ([`ServerConfig::budget`]).
//!   Many cheap OneBatch jobs are admitted concurrently; one huge
//!   full-matrix job consumes most of the budget; an over-budget job
//!   gets an immediate `err over budget ... cost=...` reply.  An
//!   oversized job may still run when the budget is completely idle, so
//!   a small budget can never brick a legitimate lone job;
//! * the dataset cache is sharded ([`cache::SHARDS`] locks) and loads
//!   cold misses *outside* the shard lock behind per-key in-flight
//!   markers: a burst for the same new dataset loads it exactly once,
//!   and a slow cold `file:` load no longer stalls unrelated datasets
//!   on the same shard.

pub mod cache;
pub mod metrics;

pub use cache::{CacheStats, DatasetCache};
pub use metrics::{MethodAgg, MethodMetrics};

use crate::backend::NativeBackend;
use crate::coordinator::{SamplerKind, SwapStrategy};
use crate::data::{DataSource, FeatureScaling};
use crate::dissim::{DissimCounter, Metric};
use crate::eval;
use crate::runtime::Pool;
use crate::solver::{self, JobCost, MethodSpec, SolveSpec, MAX_JOB_COST};
use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::num::NonZeroUsize;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::Instant;

/// Server tuning knobs.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Bind address, e.g. "127.0.0.1:7878" (port 0 = ephemeral).
    pub addr: String,
    /// Worker threads draining the job queue; `0` = auto-detect
    /// (`available_parallelism`), matching `Pool::new(0)` / `--threads 0`.
    pub workers: usize,
    /// Max in-flight connections (queued + running) before backpressure;
    /// `0` = 4x the resolved worker count.
    pub queue_cap: usize,
    /// Dataset-cache budget in datasets (split across shards, LRU).
    pub cache_cap: usize,
    /// Weighted-admission budget in work units (see [`JobCost`]);
    /// `0` = 4x [`MAX_JOB_COST`] (room for one limit-sized full-matrix
    /// job plus plenty of cheap OneBatch traffic).
    pub budget: u64,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            addr: "127.0.0.1:0".into(),
            workers: 2,
            queue_cap: 16,
            cache_cap: 32,
            budget: 0,
        }
    }
}

impl ServerConfig {
    /// `workers` with `0` resolved to the detected core count.
    pub fn resolved_workers(&self) -> usize {
        if self.workers == 0 {
            std::thread::available_parallelism().map(NonZeroUsize::get).unwrap_or(1)
        } else {
            self.workers
        }
    }

    /// `queue_cap` with `0` resolved to 4x the resolved worker count.
    pub fn resolved_queue_cap(&self) -> usize {
        if self.queue_cap == 0 {
            self.resolved_workers() * 4
        } else {
            self.queue_cap
        }
    }

    /// `budget` with `0` resolved to the default (4x [`MAX_JOB_COST`]).
    pub fn resolved_budget(&self) -> u64 {
        if self.budget == 0 {
            4 * MAX_JOB_COST
        } else {
            self.budget
        }
    }
}

/// The weighted-admission budget: a pool of work units that every
/// in-flight `cluster` job holds its [`JobCost::units`] from, released
/// when the job's [`AdmissionPermit`] drops.
///
/// A job is admitted when its units fit the remaining budget — or when
/// the budget is completely idle, so one oversized-but-admissible job
/// (e.g. OneBatchPAM over millions of rows) can still run alone instead
/// of being starved forever by a budget smaller than itself.
pub struct AdmissionBudget {
    total: u64,
    used: AtomicU64,
}

impl AdmissionBudget {
    /// Budget of `total` work units.
    pub fn new(total: u64) -> Self {
        AdmissionBudget { total: total.max(1), used: AtomicU64::new(0) }
    }

    /// Total work units.
    pub fn total(&self) -> u64 {
        self.total
    }

    /// Units currently held by in-flight jobs.
    pub fn used(&self) -> u64 {
        self.used.load(Ordering::SeqCst)
    }

    /// Reserve `units` (single-RMW, no check-then-increment window) or
    /// fail with the units currently in use.
    pub fn try_admit(&self, units: u64) -> Result<AdmissionPermit<'_>, u64> {
        self.used
            .fetch_update(Ordering::SeqCst, Ordering::SeqCst, |used| {
                if used == 0 || used.saturating_add(units) <= self.total {
                    Some(used.saturating_add(units))
                } else {
                    None
                }
            })
            .map(|_| AdmissionPermit { budget: self, units })
    }
}

/// RAII hold on [`AdmissionBudget`] units; released on drop (job end).
pub struct AdmissionPermit<'a> {
    budget: &'a AdmissionBudget,
    units: u64,
}

impl AdmissionPermit<'_> {
    /// The units this permit reserved (the reply's `cost=` field).
    pub fn units(&self) -> u64 {
        self.units
    }

    /// Atomically swap this permit's reservation for `new_units` — one
    /// RMW, so there is no window where the old units read as released
    /// (a release-then-readmit would let a concurrent oversized job in
    /// through the idle exception while this job is still in flight).
    /// Succeeds when the new units fit alongside the *other* holders,
    /// or when this permit is the only holder (the same lone-job
    /// exception as [`AdmissionBudget::try_admit`]).  On failure the
    /// old reservation is kept and the other holders' units are
    /// returned.
    pub fn reprice(&mut self, new_units: u64) -> Result<(), u64> {
        let old = self.units;
        let total = self.budget.total;
        self.budget
            .used
            .fetch_update(Ordering::SeqCst, Ordering::SeqCst, |used| {
                let others = used.saturating_sub(old);
                if others == 0 || others.saturating_add(new_units) <= total {
                    Some(others.saturating_add(new_units))
                } else {
                    None
                }
            })
            .map(|_| self.units = new_units)
            .map_err(|used| used.saturating_sub(old))
    }
}

impl Drop for AdmissionPermit<'_> {
    fn drop(&mut self) {
        // saturating: an idle-exception admit may have pushed `used`
        // past `total`, but it can never underflow on release
        let _ = self.budget.used.fetch_update(Ordering::SeqCst, Ordering::SeqCst, |used| {
            Some(used.saturating_sub(self.units))
        });
    }
}

/// Shared mutable server state, visible to every worker (and exposed on
/// [`ServerHandle::state`] for tests / ops probes).
pub struct ServerState {
    /// Sharded dataset cache for `cluster` requests.
    pub cache: DatasetCache,
    /// Per-method latency / dissim aggregates (the `stats` command).
    pub methods: MethodMetrics,
    /// Weighted admission budget every `cluster` job reserves from.
    pub admission: AdmissionBudget,
}

impl ServerState {
    /// Fresh state sized from the config.
    pub fn new(cfg: &ServerConfig) -> Self {
        ServerState {
            cache: DatasetCache::new(cfg.cache_cap),
            methods: MethodMetrics::new(),
            admission: AdmissionBudget::new(cfg.resolved_budget()),
        }
    }
}

/// Handle to a running server (join/shutdown + resolved address).
pub struct ServerHandle {
    /// The actually-bound address (useful with port 0).
    pub addr: std::net::SocketAddr,
    /// The server's shared state (dataset cache and its counters).
    pub state: Arc<ServerState>,
    stop: Arc<AtomicBool>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl ServerHandle {
    /// Ask the server to stop, drain the queue and join every thread.
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // unblock accept() with a dummy connection
        let _ = TcpStream::connect(self.addr);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
        // the accept loop dropped the queue sender; workers drain and exit
        for t in self.workers.drain(..) {
            let _ = t.join();
        }
    }
}

/// Parse `key=value` tokens after the command word.
fn parse_kv(parts: &[&str]) -> HashMap<String, String> {
    parts
        .iter()
        .filter_map(|p| p.split_once('='))
        .map(|(k, v)| (k.to_string(), v.to_string()))
        .collect()
}

/// Optional `key=value` lookup where a present-but-unparsable value is a
/// protocol error (v2 validates instead of silently falling back).
fn parse_key<T: std::str::FromStr>(
    kv: &HashMap<String, String>,
    key: &str,
) -> Result<Option<T>, String> {
    match kv.get(key) {
        None => Ok(None),
        Some(s) => s.parse().map(Some).map_err(|_| format!("bad {key}={s}")),
    }
}

/// Re-export of [`crate::solver::FULL_MATRIX_LIMIT`] (the constant moved
/// next to [`MethodSpec::feasible_large_scale`] so the grid runner can
/// apply the same bound without depending on the server).
pub use crate::solver::FULL_MATRIX_LIMIT;

/// Format the one admission error a priced-but-rejected job receives.
fn over_budget(cost: JobCost, used: u64, budget: &AdmissionBudget) -> String {
    format!(
        "over budget: cost={} exceeds the {} free of {} work units (in use {used})",
        cost.units,
        budget.total().saturating_sub(used),
        budget.total(),
    )
}

/// Price one job at `n` rows and apply the feasibility ceiling
/// ([`JobCost::admissible`] — the old `FULL_MATRIX_LIMIT` rule).
fn checked_cost(
    method: &MethodSpec,
    n: usize,
    k: usize,
    m: Option<usize>,
) -> Result<JobCost, String> {
    let cost = method.cost(n, k, m);
    if !cost.admissible() {
        return Err(format!(
            "method {} infeasible at n={n} (limit {FULL_MATRIX_LIMIT}, cost={})",
            method.label(),
            cost.units
        ));
    }
    Ok(cost)
}

/// The admission decision for one job at `n` rows: price it, apply the
/// feasibility ceiling, and reserve the units from the budget.  Shared
/// by the predicted (pre-I/O) and post-load paths so the two can never
/// diverge.
fn price_and_admit<'a>(
    state: &'a ServerState,
    method: &MethodSpec,
    n: usize,
    k: usize,
    m: Option<usize>,
) -> Result<AdmissionPermit<'a>, String> {
    let cost = checked_cost(method, n, k, m)?;
    state
        .admission
        .try_admit(cost.units)
        .map_err(|used| over_budget(cost, used, &state.admission))
}

/// Execute one `cluster` request (shared by server workers and tests).
/// `queue_ms` is the accept-to-pickup wait the connection experienced
/// (`0.0` for direct library calls); it feeds the per-method queue-wait
/// histogram.
pub fn handle_cluster(
    state: &ServerState,
    kv: &HashMap<String, String>,
    queue_ms: f64,
) -> Result<String, String> {
    let dataset = kv.get("dataset").cloned().unwrap_or_else(|| "blobs_1000_8_5".into());
    let src = DataSource::parse(&dataset).map_err(|e| e.to_string())?;
    let k: usize = parse_key(kv, "k")?.unwrap_or(10);
    let scale: f64 = parse_key(kv, "scale")?.unwrap_or(1.0);
    let seed: u64 = parse_key(kv, "seed")?.unwrap_or(0);
    // capped: a request can use the machine, not fork-bomb it
    let threads: usize = parse_key(kv, "threads")?.unwrap_or(1).min(64);
    let metric = kv
        .get("metric")
        .map(|s| Metric::parse(s).ok_or(format!("unknown metric {s}")))
        .transpose()?
        .unwrap_or(Metric::L1);
    let scaling = kv
        .get("scale_features")
        .map(|s| FeatureScaling::parse(s).ok_or(format!("unknown scale_features {s} (minmax|none)")))
        .transpose()?
        .unwrap_or_default();
    if k < 2 {
        return Err("k must be >= 2".into());
    }
    // file bytes do not scale: a non-neutral scale= on a file: source is
    // a mis-configured experiment, not a knob to silently drop (the same
    // rule the protocol applies to OneBatch-only keys)
    if src.is_file() && scale != 1.0 {
        return Err(format!("scale= does not apply to file: sources (got scale={scale})"));
    }

    // method resolution: explicit method= wins; legacy lines without it
    // default to OneBatchPAM driven by the v1 sampler=/strategy= keys
    let base = match kv.get("method") {
        Some(s) => MethodSpec::parse(s).ok_or(format!("unknown method {s}"))?,
        None => MethodSpec::default(),
    };
    let sampler = kv
        .get("sampler")
        .map(|s| SamplerKind::parse(s).ok_or(format!("unknown sampler {s}")))
        .transpose()?;
    let strategy = kv
        .get("strategy")
        .map(|s| SwapStrategy::parse(s).ok_or(format!("unknown strategy {s}")))
        .transpose()?;
    let m: Option<usize> = parse_key(kv, "m")?;
    let eps: Option<f64> = parse_key(kv, "eps")?;
    let max_passes: Option<usize> = parse_key(kv, "max_passes")?;
    let method = match base {
        MethodSpec::OneBatch { sampler: s0, strategy: t0 } => MethodSpec::OneBatch {
            sampler: sampler.unwrap_or(s0),
            strategy: strategy.unwrap_or(t0),
        },
        other => {
            for key in ["sampler", "strategy", "m", "eps", "max_passes"] {
                if kv.contains_key(key) {
                    return Err(format!(
                        "{key}= only applies to OneBatch methods (method={})",
                        other.label()
                    ));
                }
            }
            other
        }
    };
    if let Some(m) = m {
        if m < 2 {
            return Err(format!("m must be >= 2, got {m}"));
        }
    }
    if let Some(e) = eps {
        if !e.is_finite() || e < 0.0 {
            return Err(format!("eps must be finite and >= 0, got {e}"));
        }
    }
    if max_passes == Some(0) {
        return Err("max_passes must be >= 1".into());
    }

    // price the job *before* paying for a load or touching the cache —
    // the size is predictable for every catalogue source and for files
    // carrying a `?rows=` hint, so both the per-job feasibility ceiling
    // (the old FULL_MATRIX_LIMIT rule, now a special case of pricing)
    // and the weighted budget apply with zero I/O
    let expected = src.expected_rows(scale);
    let mut permit = match expected {
        Some(n) => Some(price_and_admit(state, &method, n, k, m)?),
        None => None,
    };

    let (x, hit) = state.cache.get_or_load(&src, scale, seed, scaling).map_err(|e| e.to_string())?;
    if x.rows <= k + 1 {
        return Err(format!("dataset too small (n={}) for k={k}", x.rows));
    }
    if expected != Some(x.rows) {
        // the prediction was absent (hint-less file, unknown synth name)
        // or wrong (a client-supplied ?rows= hint is never validated
        // against the loaded bytes): reprice at the actual row count so
        // a lying hint cannot smuggle a full-matrix job past the
        // feasibility ceiling or hold a too-small reservation
        match permit.as_mut() {
            // atomic swap — no window where this job's units read as
            // released (which would let an oversized job in through the
            // budget's idle exception while this one is still in flight)
            Some(p) => {
                let cost = checked_cost(&method, x.rows, k, m)?;
                p.reprice(cost.units)
                    .map_err(|used| over_budget(cost, used, &state.admission))?;
            }
            None => permit = Some(price_and_admit(state, &method, x.rows, k, m)?),
        }
    }
    // the permit's units are the reply's cost=; held until the solve
    // finishes (end of this function), when the drop releases them
    let permit = permit.expect("job priced and admitted");

    let mut spec = SolveSpec::new(method, k, seed);
    spec.metric = metric;
    spec.threads = threads;
    spec.m = m;
    if let Some(e) = eps {
        spec.eps = e;
    }
    if let Some(p) = max_passes {
        spec.max_passes = p;
    }
    let backend = NativeBackend::with_pool(metric, Pool::new(threads));
    let solve_started = Instant::now();
    let r = solver::solve(&x, &spec, &backend).map_err(|e| e.to_string())?;
    let obj = eval::objective(&x, &r.medoids, &DissimCounter::new(metric));
    // per-method aggregates cover solve + eval (time attributable to the
    // method), not the dataset load a cache miss happens to pay; the
    // queue wait is recorded alongside for the tail histograms
    state.methods.record(
        &spec.method.label(),
        solve_started.elapsed().as_secs_f64() * 1e3,
        r.stats.dissim_count,
        queue_ms,
    );
    let meds: Vec<String> = r.medoids.iter().map(|m| m.to_string()).collect();
    Ok(format!(
        "ok method={} cache={} medoids={} objective={obj:.6} seconds={:.4} dissim={} swaps={} source={} cost={}",
        spec.method.label(),
        if hit { "hit" } else { "miss" },
        meds.join(","),
        r.stats.seconds,
        r.stats.dissim_count,
        r.stats.swap_count,
        src.canon(),
        permit.units(),
    ))
}

/// Dispatch one request line to a reply line (no queue: direct library
/// callers and tests; wire connections go through [`handle_line_queued`]
/// so the queue wait reaches the histograms).
pub fn handle_line(state: &ServerState, line: &str) -> String {
    handle_line_queued(state, line, 0.0)
}

/// Dispatch one request line to a reply line, carrying the queue wait
/// the connection experienced before a worker picked it up.
pub fn handle_line_queued(state: &ServerState, line: &str, queue_ms: f64) -> String {
    let parts: Vec<&str> = line.split_whitespace().collect();
    match parts.first().copied() {
        Some("ping") => "pong".into(),
        Some("cluster") => match handle_cluster(state, &parse_kv(&parts[1..]), queue_ms) {
            Ok(r) => r,
            Err(e) => format!("err {e}"),
        },
        // v4: `stats reset` re-bases the method aggregates + cache
        // counters (entries stay resident; the budget gauge is live)
        Some("stats") if parts.get(1).copied() == Some("reset") => {
            state.methods.reset();
            state.cache.reset_counters();
            "ok".into()
        }
        Some("stats") => {
            let s = state.cache.stats();
            let mut line = format!(
                "ok cache_hits={} cache_misses={} cache_entries={} \
                 budget_total={} budget_used={} hist_le_ms={}",
                s.hits,
                s.misses,
                s.entries,
                state.admission.total(),
                state.admission.used(),
                metrics::hist_edges_wire(),
            );
            // per-method aggregates, label-sorted for determinism
            for (label, a) in state.methods.snapshot() {
                line.push_str(&format!(
                    " method.{label}.count={} \
                     method.{label}.ms_min={:.3} method.{label}.ms_mean={:.3} \
                     method.{label}.ms_max={:.3} method.{label}.dissim_min={} \
                     method.{label}.dissim_mean={:.1} method.{label}.dissim_max={} \
                     method.{label}.ms_hist={} method.{label}.queue_hist={}",
                    a.count,
                    a.ms_min,
                    a.ms_mean(),
                    a.ms_max,
                    a.dissim_min,
                    a.dissim_mean(),
                    a.dissim_max,
                    a.solve_hist.wire(),
                    a.queue_hist.wire(),
                ));
            }
            line
        }
        // Diagnostic: hold a worker for `ms` (capped) — used by the
        // backpressure tests and for probing queue behaviour under load.
        Some("sleep") => {
            let kv = parse_kv(&parts[1..]);
            let ms: u64 = kv.get("ms").and_then(|s| s.parse().ok()).unwrap_or(0).min(10_000);
            std::thread::sleep(std::time::Duration::from_millis(ms));
            format!("ok slept_ms={ms}")
        }
        Some(cmd) => format!("err unknown command {cmd}"),
        None => "err empty request".into(),
    }
}

/// How long a worker waits for a client to send its request line (or
/// accept the reply) before giving the slot back.  Without this, a
/// handful of idle connections could pin every worker forever.
const IO_TIMEOUT: std::time::Duration = std::time::Duration::from_secs(10);

/// Serve one accepted connection: read a line, dispatch, reply.
/// `queued_at` is when the accept loop enqueued the connection; the
/// difference to now is the job's reported + histogrammed queue wait.
fn handle_connection(state: &ServerState, stream: TcpStream, queued_at: Instant) {
    let queue_ms = queued_at.elapsed().as_secs_f64() * 1e3;
    let _ = stream.set_read_timeout(Some(IO_TIMEOUT));
    let _ = stream.set_write_timeout(Some(IO_TIMEOUT));
    let Ok(clone) = stream.try_clone() else { return };
    let mut reader = BufReader::new(clone);
    let mut line = String::new();
    if reader.read_line(&mut line).is_ok() && !line.trim().is_empty() {
        let started = Instant::now();
        let reply = handle_line_queued(state, line.trim(), queue_ms);
        let mut s = stream;
        let _ = writeln!(
            s,
            "{reply} queue_ms={queue_ms:.1} served_ms={:.1}",
            started.elapsed().as_secs_f64() * 1e3
        );
    }
}

/// Start the server; returns immediately with a handle.
pub fn serve(cfg: ServerConfig) -> std::io::Result<ServerHandle> {
    let listener = TcpListener::bind(&cfg.addr)?;
    let addr = listener.local_addr()?;
    let stop = Arc::new(AtomicBool::new(false));
    let inflight = Arc::new(AtomicUsize::new(0));
    let state = Arc::new(ServerState::new(&cfg));
    // the resolved_* accessors own the >= 1 invariant (0 means auto)
    let queue_cap = cfg.resolved_queue_cap();
    let worker_count = cfg.resolved_workers();

    // Bounded job queue: admission reserves a slot in `inflight` before
    // enqueueing; the worker releases it when the job finishes, so
    // queued + running <= queue_cap always holds.
    let (tx, rx) = mpsc::channel::<(TcpStream, Instant)>();
    let rx = Arc::new(Mutex::new(rx));
    let mut workers = Vec::with_capacity(worker_count);
    for _ in 0..worker_count {
        let rx = rx.clone();
        let inflight = inflight.clone();
        let state = state.clone();
        workers.push(std::thread::spawn(move || loop {
            // the guard temporary drops at the end of this statement, so
            // workers do not hold the lock while serving
            let job = rx.lock().expect("queue receiver poisoned").recv();
            let Ok((stream, queued_at)) = job else { break };
            let _slot = DecrementOnDrop(inflight.clone());
            // a panicking job must not shrink the long-lived pool
            let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                handle_connection(&state, stream, queued_at);
            }));
        }));
    }

    let stop2 = stop.clone();
    let inflight2 = inflight.clone();
    let accept_thread = std::thread::spawn(move || {
        for conn in listener.incoming() {
            if stop2.load(Ordering::SeqCst) {
                break;
            }
            let Ok(stream) = conn else { continue };
            // single-RMW admission: reserve a slot or reject — no
            // check-then-increment window for a burst to slip through
            let admitted = inflight2
                .fetch_update(Ordering::SeqCst, Ordering::SeqCst, |c| {
                    if c < queue_cap {
                        Some(c + 1)
                    } else {
                        None
                    }
                })
                .is_ok();
            if !admitted {
                let mut s = stream;
                let _ = writeln!(s, "err queue full");
                continue;
            }
            if tx.send((stream, Instant::now())).is_err() {
                break;
            }
        }
        // dropping `tx` wakes every idle worker with RecvError -> exit
    });

    Ok(ServerHandle { addr, state, stop, accept_thread: Some(accept_thread), workers })
}

struct DecrementOnDrop(Arc<AtomicUsize>);
impl Drop for DecrementOnDrop {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::SeqCst);
    }
}

/// Blocking client call: one request line -> reply line.
pub fn request(addr: std::net::SocketAddr, line: &str) -> std::io::Result<String> {
    let mut stream = TcpStream::connect(addr)?;
    writeln!(stream, "{line}")?;
    let mut reader = BufReader::new(stream);
    let mut reply = String::new();
    reader.read_line(&mut reply)?;
    Ok(reply.trim().to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fresh_state() -> ServerState {
        ServerState::new(&ServerConfig::default())
    }

    fn kv(pairs: &[(&str, &str)]) -> HashMap<String, String> {
        pairs.iter().map(|(a, b)| (a.to_string(), b.to_string())).collect()
    }

    #[test]
    fn ping_pong_and_cluster_roundtrip() {
        let h = serve(ServerConfig::default()).unwrap();
        assert!(request(h.addr, "ping").unwrap().starts_with("pong"));
        let r = request(h.addr, "cluster dataset=blobs_300_4_3 k=3 seed=1").unwrap();
        // legacy lines without method= still work and default to
        // OneBatch-nniw (protocol v1 compatibility); the v2 reply fields
        // are byte-identical, with v3's source= appended
        assert!(r.starts_with("ok method=OneBatch-nniw cache=miss medoids="), "{r}");
        assert!(r.contains("objective="));
        assert!(r.contains("swaps="));
        assert!(r.contains(" source=synth:blobs_300_4_3"), "{r}");
        h.shutdown();
    }

    #[test]
    fn every_table3_method_is_addressable_on_the_wire() {
        let h = serve(ServerConfig::default()).unwrap();
        for method in MethodSpec::table3_grid() {
            let label = method.label();
            let r = request(h.addr, &format!("cluster dataset=blobs_200_4_3 k=3 seed=1 method={label}"))
                .unwrap();
            assert!(r.starts_with("ok "), "{label}: {r}");
            assert!(r.contains(&format!("method={label} ")), "{label}: {r}");
        }
        h.shutdown();
    }

    #[test]
    fn bad_requests_get_errors() {
        let st = fresh_state();
        for line in [
            "nope",
            "",
            "cluster dataset=doesnotexist",
            "cluster k=1",
            "cluster k=abc",
            "cluster dataset=s3:bucket/key",
            "cluster dataset=file:",
            "cluster dataset=file:/x.csv?rows=0",
            // file bytes do not scale; silent no-ops are not allowed
            "cluster dataset=file:/x.csv scale=0.5",
            "cluster metric=bogus",
            "cluster scale_features=bogus",
            "cluster sampler=bogus",
            "cluster method=bogus",
            "cluster strategy=bogus",
            "cluster m=1",
            "cluster m=xyz",
            "cluster eps=-0.5",
            "cluster eps=nope",
            "cluster max_passes=0",
            // OneBatch-only knobs must not be silently dropped
            "cluster method=FasterPAM m=50",
            "cluster method=k-means++ strategy=steepest",
            "cluster method=Random sampler=unif",
        ] {
            assert!(handle_line(&st, line).starts_with("err"), "{line:?} should err");
        }
    }

    #[test]
    fn onebatch_knobs_are_accepted_and_validated() {
        let st = fresh_state();
        let r = handle_line(
            &st,
            "cluster dataset=blobs_300_4_3 k=3 seed=2 m=60 eps=0.01 max_passes=5 strategy=steepest sampler=unif",
        );
        assert!(r.starts_with("ok method=OneBatch-unif-steepest "), "{r}");
        // a unif run computes exactly n*m dissimilarities -> m= reached
        // the coordinator (plus the steepest engine's gain evals)
        assert!(r.contains("dissim="), "{r}");
    }

    #[test]
    fn cache_reports_miss_then_hit_with_identical_medoids() {
        let st = fresh_state();
        let line = "cluster dataset=blobs_300_4_3 k=3 seed=5";
        let first = handle_line(&st, line);
        let second = handle_line(&st, line);
        assert!(first.starts_with("ok "), "{first}");
        assert!(first.contains("cache=miss"), "{first}");
        assert!(second.contains("cache=hit"), "{second}");
        let meds = |r: &str| {
            r.split("medoids=").nth(1).unwrap().split_whitespace().next().unwrap().to_string()
        };
        assert_eq!(meds(&first), meds(&second));
        let s = st.cache.stats();
        assert_eq!((s.hits, s.misses), (1, 1));
    }

    #[test]
    fn repeated_requests_never_regenerate_after_warmup() {
        let h = serve(ServerConfig::default()).unwrap();
        let jobs: Vec<String> = (0..3)
            .map(|i| format!("cluster dataset=blobs_300_4_3 k=3 seed={i}"))
            .collect();
        for job in &jobs {
            assert!(request(h.addr, job).unwrap().contains("cache=miss"));
        }
        let warm_misses = h.state.cache.stats().misses;
        for _ in 0..2 {
            for job in &jobs {
                assert!(request(h.addr, job).unwrap().contains("cache=hit"));
            }
        }
        let s = h.state.cache.stats();
        assert_eq!(s.misses, warm_misses, "no regeneration after warmup");
        assert_eq!(s.hits, 6);
        let stats_line = request(h.addr, "stats").unwrap();
        assert!(stats_line.starts_with("ok cache_hits=6 cache_misses=3"), "{stats_line}");
        h.shutdown();
    }

    #[test]
    fn stats_reports_per_method_aggregates() {
        let st = fresh_state();
        for line in [
            "cluster dataset=blobs_300_4_3 k=3 seed=1",
            "cluster dataset=blobs_300_4_3 k=3 seed=2",
            "cluster dataset=blobs_300_4_3 k=3 seed=1 method=FasterPAM",
        ] {
            assert!(handle_line(&st, line).starts_with("ok "), "{line}");
        }
        let stats = handle_line(&st, "stats");
        assert!(stats.contains("method.OneBatch-nniw.count=2"), "{stats}");
        assert!(stats.contains("method.FasterPAM.count=1"), "{stats}");
        for field in
            ["ms_min", "ms_mean", "ms_max", "dissim_min", "dissim_mean", "dissim_max"]
        {
            assert!(stats.contains(&format!("method.FasterPAM.{field}=")), "{field}: {stats}");
        }
        // the snapshot agrees with the wire line
        let snap = st.methods.snapshot();
        assert_eq!(snap.len(), 2);
        let ob = snap.iter().find(|(l, _)| l == "OneBatch-nniw").unwrap();
        assert_eq!(ob.1.count, 2);
        assert!(ob.1.ms_min <= ob.1.ms_mean() && ob.1.ms_mean() <= ob.1.ms_max);
        assert!(ob.1.dissim_min <= ob.1.dissim_max);
    }

    #[test]
    fn metric_and_scaling_are_wire_addressable() {
        let st = fresh_state();
        let base = "cluster dataset=blobs_300_4_3 k=3 seed=5";
        let l1 = handle_line(&st, base);
        let l2 = handle_line(&st, &format!("{base} metric=l2"));
        let mm = handle_line(&st, &format!("{base} metric=l2 scale_features=minmax"));
        for r in [&l1, &l2, &mm] {
            assert!(r.starts_with("ok "), "{r}");
        }
        // the matrix is metric-independent (one cache entry), but the
        // minmax-scaled variant is a distinct entry
        assert!(l2.contains("cache=hit"), "{l2}");
        assert!(mm.contains("cache=miss"), "{mm}");
        assert_eq!(st.cache.stats().entries, 2);
    }

    #[test]
    fn file_rows_hint_gates_infeasible_methods_before_any_io() {
        // the path does not exist: with a large rows hint the request
        // must be rejected on the hint alone, before any stat/load
        let st = fresh_state();
        let r = handle_line(
            &st,
            "cluster dataset=file:/definitely/not/here.csv?rows=50000 k=5 method=FasterPAM",
        );
        assert!(r.starts_with("err"), "{r}");
        assert!(r.contains("infeasible at n=50000"), "{r}");
        assert_eq!(st.cache.stats(), CacheStats::default());
    }

    #[test]
    fn infeasible_large_scale_method_rejected_before_generation() {
        let st = fresh_state();
        let r = handle_line(&st, "cluster dataset=covertype k=5 method=FasterPAM");
        assert!(r.starts_with("err"), "{r}");
        assert!(r.contains("infeasible"), "{r}");
        let s = st.cache.stats();
        assert_eq!((s.misses, s.entries), (0, 0), "must not generate the dataset");
    }

    #[test]
    fn cluster_handler_is_deterministic() {
        let args = kv(&[("dataset", "blobs_300_4_3"), ("k", "3"), ("seed", "5")]);
        // fresh state each side so both runs are cache=miss; strip the
        // timing field (wall-clock varies run to run)
        let stable = |r: String| r.split(" seconds=").next().unwrap().to_string();
        assert_eq!(
            stable(handle_cluster(&fresh_state(), &args, 0.0).unwrap()),
            stable(handle_cluster(&fresh_state(), &args, 0.0).unwrap())
        );
    }

    #[test]
    fn threaded_cluster_matches_serial_cluster() {
        let mk = |threads: &str| -> String {
            let args = kv(&[
                ("dataset", "blobs_400_4_3"),
                ("k", "3"),
                ("seed", "6"),
                ("threads", threads),
            ]);
            let r = handle_cluster(&fresh_state(), &args, 0.0).unwrap();
            r.split(" seconds=").next().unwrap().to_string()
        };
        assert_eq!(mk("1"), mk("4"));
    }

    #[test]
    fn config_resolves_auto_knobs() {
        let auto = ServerConfig { workers: 0, queue_cap: 0, budget: 0, ..Default::default() };
        assert!(auto.resolved_workers() >= 1);
        assert_eq!(auto.resolved_queue_cap(), auto.resolved_workers() * 4);
        assert_eq!(auto.resolved_budget(), 4 * MAX_JOB_COST);
        let fixed = ServerConfig { workers: 3, queue_cap: 7, budget: 99, ..Default::default() };
        assert_eq!(fixed.resolved_workers(), 3);
        assert_eq!(fixed.resolved_queue_cap(), 7);
        assert_eq!(fixed.resolved_budget(), 99);
        // workers=0 actually serves (auto-detected pool)
        let h = serve(auto).unwrap();
        assert!(request(h.addr, "ping").unwrap().starts_with("pong"));
        h.shutdown();
    }

    #[test]
    fn admission_budget_reserves_and_releases() {
        let b = AdmissionBudget::new(100);
        let p1 = b.try_admit(60).unwrap();
        assert_eq!((p1.units(), b.used()), (60, 60));
        // over the remaining budget -> rejected with the in-use units
        assert_eq!(b.try_admit(50).unwrap_err(), 60);
        let p2 = b.try_admit(40).unwrap();
        drop(p1);
        assert_eq!(b.used(), 40);
        drop(p2);
        assert_eq!(b.used(), 0);
        // idle exception: an oversized job may run alone...
        let big = b.try_admit(1000).unwrap();
        // ...but blocks everything else until it finishes
        assert!(b.try_admit(1).is_err());
        drop(big);
        assert_eq!(b.used(), 0);
    }

    #[test]
    fn permit_reprice_is_atomic_and_keeps_old_hold_on_failure() {
        let b = AdmissionBudget::new(100);
        let mut p1 = b.try_admit(40).unwrap();
        let p2 = b.try_admit(40).unwrap();
        // over the other holder's headroom -> rejected, old hold kept
        assert_eq!(p1.reprice(70).unwrap_err(), 40, "reports the other holders' units");
        assert_eq!((p1.units(), b.used()), (40, 80));
        // fits alongside the other holder -> swapped in one step
        assert!(p1.reprice(60).is_ok());
        assert_eq!((p1.units(), b.used()), (60, 100));
        drop(p2);
        // lone holder: the lone-job exception applies to repricing too
        assert!(p1.reprice(5_000).is_ok());
        assert_eq!(b.used(), 5_000);
        drop(p1);
        assert_eq!(b.used(), 0, "drop releases the repriced amount, not the original");
    }

    #[test]
    fn cluster_replies_report_cost_and_hold_no_budget_after() {
        let st = fresh_state();
        let r = handle_line(&st, "cluster dataset=blobs_300_4_3 k=3 seed=1");
        assert!(r.starts_with("ok "), "{r}");
        let cost: u64 = r.split(" cost=").nth(1).unwrap().trim().parse().unwrap();
        // OneBatch prices its n*m pass; blobs_300 caps m at n=300
        assert_eq!(cost, MethodSpec::default().cost(300, 3, None).units, "{r}");
        assert_eq!(st.admission.used(), 0, "permit must release when the job ends");
    }

    #[test]
    fn stats_reports_budget_and_histograms_and_resets() {
        let st = fresh_state();
        assert!(handle_line(&st, "cluster dataset=blobs_300_4_3 k=3 seed=1").starts_with("ok "));
        let stats = handle_line(&st, "stats");
        assert!(stats.contains(" budget_total="), "{stats}");
        assert!(stats.contains(" budget_used=0 "), "{stats}");
        assert!(stats.contains(" hist_le_ms=1,2,5,"), "{stats}");
        assert!(stats.contains("method.OneBatch-nniw.ms_hist="), "{stats}");
        assert!(stats.contains("method.OneBatch-nniw.queue_hist="), "{stats}");
        // the solve histogram holds exactly the one served job
        let hist = stats
            .split("method.OneBatch-nniw.ms_hist=")
            .nth(1)
            .unwrap()
            .split_whitespace()
            .next()
            .unwrap();
        let total: u64 = hist.split(',').map(|c| c.parse::<u64>().unwrap()).sum();
        assert_eq!(total, 1, "{stats}");
        // reset re-bases method aggregates and cache counters
        assert_eq!(handle_line(&st, "stats reset"), "ok");
        let after = handle_line(&st, "stats");
        assert!(after.starts_with("ok cache_hits=0 cache_misses=0 cache_entries=1"), "{after}");
        assert!(!after.contains("method.OneBatch-nniw"), "{after}");
    }

    #[test]
    fn over_budget_requests_err_with_cost() {
        let st = ServerState::new(&ServerConfig { budget: 1_000, ..Default::default() });
        // occupy the budget so the idle exception cannot apply
        let _held = st.admission.try_admit(900).unwrap();
        let r = handle_line(&st, "cluster dataset=blobs_300_4_3 k=3 seed=1");
        assert!(r.starts_with("err over budget"), "{r}");
        assert!(r.contains("cost="), "{r}");
        // nothing was loaded for the rejected job
        assert_eq!(st.cache.stats(), CacheStats::default());
    }

    #[test]
    fn methods_agree_between_wire_and_library() {
        // the medoids a wire request reports must be exactly what the
        // unified API computes for the same (data, method, seed)
        let st = fresh_state();
        let r = handle_line(&st, "cluster dataset=blobs_250_4_3 k=3 seed=4 method=FasterPAM");
        let wire: Vec<usize> = r
            .split("medoids=")
            .nth(1)
            .unwrap()
            .split_whitespace()
            .next()
            .unwrap()
            .split(',')
            .map(|t| t.parse().unwrap())
            .collect();
        let data = crate::data::synth::generate("blobs_250_4_3", 1.0, 4);
        let backend = NativeBackend::new(Metric::L1);
        let lib =
            solver::solve(&data.x, &SolveSpec::new(MethodSpec::FasterPam, 3, 4), &backend).unwrap();
        assert_eq!(wire, lib.medoids);
    }

    #[test]
    fn workers_serve_concurrently() {
        // With 4 workers, 4 concurrent 150 ms sleeps finish in ~1 batch,
        // far below the 600 ms serial floor.
        let h = serve(ServerConfig {
            addr: "127.0.0.1:0".into(),
            workers: 4,
            queue_cap: 8,
            ..Default::default()
        })
        .unwrap();
        let t0 = Instant::now();
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let addr = h.addr;
                std::thread::spawn(move || request(addr, "sleep ms=150").unwrap())
            })
            .collect();
        for th in handles {
            assert!(th.join().unwrap().starts_with("ok slept_ms=150"));
        }
        let elapsed = t0.elapsed().as_millis();
        assert!(elapsed < 550, "4 workers should overlap sleeps, took {elapsed} ms");
        h.shutdown();
    }

    #[test]
    fn sleep_command_caps_duration() {
        let r = handle_line(&fresh_state(), "sleep ms=1");
        assert!(r.starts_with("ok slept_ms=1"), "{r}");
    }
}
