//! The fitted-model registry behind protocol v6's serving verbs
//! (`promote` / `assign` / `models` / `evict`).
//!
//! A [`ModelRegistry`] mirrors the job registry's shape for the *read*
//! path: `promote` moves a finished job's [`FittedModel`] — the `k x p`
//! medoid feature vectors, the metric and the training inertia, with
//! **no reference to the dataset** — into the registry under a named
//! handle (`m<id>` auto-assigned, or a caller-supplied name), and every
//! later `assign` serves nearest-medoid lookups from that copy alone.
//! The dataset cache can evict the training matrix, the server can be
//! restarted cold on its data, and assignments keep answering: the
//! model owns everything it needs from promotion time on.
//!
//! Retention is bounded LRU, like the job registry and the pool cache:
//! at most `cap` models stay resident ([`crate::server::ServerConfig::
//! model_cap`]), a `get` (one `assign`) touches its model warm, and
//! promoting past the cap evicts the coldest.  Re-promoting an existing
//! name replaces that model in place (the overnight-refit workflow:
//! `promote job=<new> name=prod` swaps what `assign model=prod` serves).
//!
//! All registry state sits behind one mutex (poison-safe via
//! [`sync_ext`]); critical sections are map edits, vastly cheaper than
//! the `O(k p)` assignment around them.

use crate::dissim::Metric;
use crate::solver::FittedModel;
use crate::sync_ext;
use std::collections::{HashMap, VecDeque};
use std::sync::{Arc, Mutex};

/// What `promote` moves from a finished job into the registry: the
/// dataset-free model plus the provenance strings the `models` listing
/// reports.  Stashed on the job by the worker at solve time, so
/// promotion itself does no compute and no I/O.
#[derive(Clone)]
pub struct ModelSeed {
    /// The dataset-free fitted model (medoid rows + metric + inertia).
    pub model: Arc<FittedModel>,
    /// Method label the fit ran under ([`crate::solver::MethodSpec`]).
    pub method: String,
    /// Canonical [`crate::data::DataSource`] URI the fit loaded.
    pub source: String,
}

/// One registered model's listing row (the `models` wire verb).
#[derive(Clone, Debug)]
pub struct ModelRecord {
    /// Registry handle (`m<id>` or the caller-supplied name).
    pub name: String,
    /// Job the model was promoted from.
    pub job: u64,
    /// Method label of the fit.
    pub method: String,
    /// Dataset URI of the fit.
    pub source: String,
    /// Number of medoids.
    pub k: usize,
    /// Feature dimension assignment points must match.
    pub dim: usize,
    /// Metric the model was fitted under.
    pub metric: Metric,
    /// Training inertia (mean nearest-medoid distance).
    pub inertia: f64,
}

/// Point-in-time occupancy of the registry (the `models` wire verb and
/// the `models=` stats gauge).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ModelGauges {
    /// Models currently resident.
    pub count: usize,
    /// Retention bound (LRU eviction beyond it).
    pub cap: usize,
    /// Lifetime promotions (including same-name replacements).
    pub promoted: u64,
    /// Lifetime LRU evictions (explicit `evict` calls not included).
    pub evicted: u64,
}

/// Per-model serving scratch: every buffer the `assign` hot path needs,
/// allocated once at promotion and **reused across requests** so a
/// high-QPS assign workload does zero per-request matrix allocations
/// (the no-alloc obligation — docs/INVARIANTS.md).  Vectors are
/// `clear()`ed, never shrunk, so capacity ratchets up to the largest
/// request seen and stays there.
///
/// One scratch per model, behind its own mutex: concurrent assigns to
/// the *same* model serialize on the buffers (the fill is `O(q k p)`,
/// far above the lock cost), while assigns to different models never
/// contend.
pub struct AssignScratch {
    /// Parsed query points, `q * dim` row-major (reused capacity).
    pub points: Vec<f32>,
    /// One `k`-length distance row — the only per-point working set;
    /// the `q x k` matrix is never materialized.
    pub row: Vec<f32>,
    /// Nearest-medoid index per query point.
    pub labels: Vec<usize>,
    /// Distance to the nearest medoid per query point.
    pub dists: Vec<f32>,
    /// Second-nearest index per query point (`top2=1`).
    pub second: Vec<usize>,
    /// Second-nearest distance per query point (`top2=1`).
    pub dists2: Vec<f32>,
    /// Medoid squared norms for the `Fast` dot-product path, computed
    /// on first use and cached for the model's lifetime (empty until
    /// then; medoid rows are immutable after promotion).
    pub bnorms: Vec<f32>,
    /// Assign calls served from this scratch (the scratch-reuse test
    /// pins that this grows while capacities stop growing).
    pub reuses: u64,
}

impl AssignScratch {
    fn new() -> Self {
        AssignScratch {
            points: Vec::new(),
            row: Vec::new(),
            labels: Vec::new(),
            dists: Vec::new(),
            second: Vec::new(),
            dists2: Vec::new(),
            bnorms: Vec::new(),
            reuses: 0,
        }
    }
}

struct Entry {
    seed: ModelSeed,
    job: u64,
    scratch: Arc<Mutex<AssignScratch>>,
}

struct Inner {
    models: HashMap<String, Entry>,
    /// Names, coldest first (LRU retention order).
    order: VecDeque<String>,
    next_id: u64,
    promoted: u64,
    evicted: u64,
}

/// The registry: owns every promoted model from promotion to eviction.
pub struct ModelRegistry {
    inner: Mutex<Inner>,
    cap: usize,
}

impl ModelRegistry {
    /// Empty registry retaining at most `cap` models (LRU).
    pub fn new(cap: usize) -> Self {
        ModelRegistry {
            inner: Mutex::new(Inner {
                models: HashMap::new(),
                order: VecDeque::new(),
                next_id: 1,
                promoted: 0,
                evicted: 0,
            }),
            cap: cap.max(1),
        }
    }

    /// The retention bound this registry was built with.
    pub fn cap(&self) -> usize {
        self.cap
    }

    /// Register `seed` (promoted from job `job`) under `name`, or under
    /// a fresh auto handle `m<id>` when `name` is `None`.  Returns the
    /// handle.  An existing name is replaced in place (refit workflow);
    /// promoting past the cap evicts the coldest model.
    pub fn promote(
        &self,
        name: Option<&str>,
        seed: ModelSeed,
        job: u64,
    ) -> Result<String, String> {
        let mut inner = self.lock();
        let name = match name {
            Some(n) => {
                validate_name(n)?;
                n.to_string()
            }
            None => {
                let id = inner.next_id;
                inner.next_id += 1;
                format!("m{id}")
            }
        };
        // replacement keeps one order entry per name (warm end below);
        // a fresh scratch is deliberate — the new model may have a
        // different k/dim, and stale cached norms would be wrong
        let entry = Entry { seed, job, scratch: Arc::new(Mutex::new(AssignScratch::new())) };
        if inner.models.insert(name.clone(), entry).is_some() {
            if let Some(pos) = inner.order.iter().position(|n| *n == name) {
                inner.order.remove(pos);
            }
        }
        inner.order.push_back(name.clone());
        inner.promoted += 1;
        while inner.models.len() > self.cap {
            if let Some(cold) = inner.order.pop_front() {
                inner.models.remove(&cold);
                inner.evicted += 1;
            }
        }
        Ok(name)
    }

    /// The model registered under `name`, if any; counts as an LRU
    /// touch (every `assign` keeps its model warm).
    pub fn get(&self, name: &str) -> Option<Arc<FittedModel>> {
        self.get_serving(name).map(|(model, _)| model)
    }

    /// The model *and its serving scratch* — the allocation-free assign
    /// hot path.  Holding the returned `Arc`s keeps both alive even if
    /// the model is evicted or replaced mid-request (an in-flight assign
    /// finishes against the model it resolved).
    pub fn get_serving(&self, name: &str) -> Option<(Arc<FittedModel>, Arc<Mutex<AssignScratch>>)> {
        let mut inner = self.lock();
        let entry = inner.models.get(name)?;
        let out = (entry.seed.model.clone(), entry.scratch.clone());
        if let Some(pos) = inner.order.iter().position(|n| n == name) {
            inner.order.remove(pos);
            inner.order.push_back(name.to_string());
        }
        Some(out)
    }

    /// Drop the model registered under `name`; returns whether one was
    /// resident (explicit drops are not counted as LRU evictions).
    pub fn evict(&self, name: &str) -> bool {
        let mut inner = self.lock();
        let removed = inner.models.remove(name).is_some();
        if removed {
            if let Some(pos) = inner.order.iter().position(|n| n == name) {
                inner.order.remove(pos);
            }
        }
        removed
    }

    /// Listing rows for every resident model, name-sorted for a
    /// deterministic wire line.
    pub fn list(&self) -> Vec<ModelRecord> {
        let inner = self.lock();
        let mut rows: Vec<ModelRecord> = inner
            .models
            .iter()
            .map(|(name, e)| ModelRecord {
                name: name.clone(),
                job: e.job,
                method: e.seed.method.clone(),
                source: e.seed.source.clone(),
                k: e.seed.model.k(),
                dim: e.seed.model.dim(),
                metric: e.seed.model.metric,
                inertia: e.seed.model.inertia,
            })
            .collect();
        rows.sort_by(|a, b| a.name.cmp(&b.name));
        rows
    }

    /// Occupancy and lifetime counters.
    pub fn gauges(&self) -> ModelGauges {
        let inner = self.lock();
        ModelGauges {
            count: inner.models.len(),
            cap: self.cap,
            promoted: inner.promoted,
            evicted: inner.evicted,
        }
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, Inner> {
        sync_ext::lock_or_recover(&self.inner)
    }
}

/// A caller-supplied model name: short, wire-safe (one token, no
/// quoting needed, usable as a `model.<name>.` stats prefix) and
/// outside the auto-handle namespace so `promote name=m3` can never
/// silently shadow a handle a client got from an earlier auto-named
/// promotion.
fn validate_name(name: &str) -> Result<(), String> {
    if name.is_empty() || name.len() > 64 {
        return Err(format!("bad model name {name:?} (1..=64 characters)"));
    }
    if !name.chars().all(|c| c.is_ascii_alphanumeric() || matches!(c, '-' | '_' | '.')) {
        return Err(format!(
            "bad model name {name:?} (allowed: ASCII letters, digits, '-', '_', '.')"
        ));
    }
    let mut chars = name.chars();
    if chars.next() == Some('m') && name.len() > 1 && chars.all(|c| c.is_ascii_digit()) {
        return Err(format!("model name {name} is reserved for auto handles (m<id>)"));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Matrix;

    fn seed(k: usize, dim: usize) -> ModelSeed {
        ModelSeed {
            model: Arc::new(FittedModel {
                medoid_rows: Matrix::zeros(k, dim),
                medoids: (0..k).collect(),
                metric: Metric::L1,
                inertia: 0.5,
                labels: None,
                dist_to_nearest: None,
            }),
            method: "OneBatch-nniw".into(),
            source: "synth:blobs_300_4_3".into(),
        }
    }

    #[test]
    fn auto_handles_are_monotonic_and_named_promotes_stick() {
        let r = ModelRegistry::new(8);
        assert_eq!(r.promote(None, seed(3, 4), 1).unwrap(), "m1");
        assert_eq!(r.promote(None, seed(3, 4), 2).unwrap(), "m2");
        assert_eq!(r.promote(Some("prod"), seed(2, 4), 3).unwrap(), "prod");
        assert_eq!(r.gauges().count, 3);
        assert_eq!(r.gauges().promoted, 3);
        assert_eq!(r.get("prod").unwrap().k(), 2);
        assert!(r.get("m3").is_none());
        let names: Vec<String> = r.list().into_iter().map(|m| m.name).collect();
        assert_eq!(names, vec!["m1", "m2", "prod"], "listing is name-sorted");
    }

    #[test]
    fn replacement_swaps_in_place_without_eviction() {
        let r = ModelRegistry::new(2);
        r.promote(Some("prod"), seed(2, 4), 1).unwrap();
        r.promote(None, seed(3, 4), 2).unwrap();
        // same name: replaced, still 2 resident, nothing evicted
        r.promote(Some("prod"), seed(5, 4), 3).unwrap();
        let g = r.gauges();
        assert_eq!((g.count, g.evicted, g.promoted), (2, 0, 3));
        assert_eq!(r.get("prod").unwrap().k(), 5);
        assert_eq!(r.list().iter().find(|m| m.name == "prod").unwrap().job, 3);
    }

    #[test]
    fn lru_evicts_the_coldest_and_get_touches_warm() {
        let r = ModelRegistry::new(2);
        r.promote(Some("a"), seed(2, 4), 1).unwrap();
        r.promote(Some("b"), seed(2, 4), 2).unwrap();
        // touch `a` warm, so the next promotion evicts `b`
        assert!(r.get("a").is_some());
        r.promote(Some("c"), seed(2, 4), 3).unwrap();
        assert!(r.get("b").is_none(), "coldest model is evicted");
        assert!(r.get("a").is_some() && r.get("c").is_some());
        assert_eq!(r.gauges().evicted, 1);
    }

    #[test]
    fn explicit_evict_is_not_an_lru_eviction() {
        let r = ModelRegistry::new(4);
        r.promote(Some("a"), seed(2, 4), 1).unwrap();
        assert!(r.evict("a"));
        assert!(!r.evict("a"), "second evict reports unknown");
        let g = r.gauges();
        assert_eq!((g.count, g.evicted), (0, 0));
    }

    #[test]
    fn serving_scratch_is_per_model_and_fresh_on_replacement() {
        let r = ModelRegistry::new(4);
        r.promote(Some("prod"), seed(2, 4), 1).unwrap();
        let (_, s1) = r.get_serving("prod").unwrap();
        let (_, s1b) = r.get_serving("prod").unwrap();
        assert!(Arc::ptr_eq(&s1, &s1b), "one scratch per model across calls");
        sync_ext::lock_or_recover(&s1).bnorms.push(1.0);
        // replacement must not inherit cached norms (k/dim may change)
        r.promote(Some("prod"), seed(3, 4), 2).unwrap();
        let (_, s2) = r.get_serving("prod").unwrap();
        assert!(!Arc::ptr_eq(&s1, &s2), "replacement gets a fresh scratch");
        assert!(sync_ext::lock_or_recover(&s2).bnorms.is_empty());
    }

    #[test]
    fn name_validation_rejects_wire_hostile_and_reserved_names() {
        let r = ModelRegistry::new(4);
        for bad in ["", "has space", "newline\n", "a=b", "m42", "m1", &"x".repeat(65)] {
            assert!(r.promote(Some(bad), seed(2, 4), 1).is_err(), "{bad:?} should be rejected");
        }
        // `m` alone and mixed names are fine (not the m<digits> shape)
        for ok in ["m", "m4x", "web-prod_v2.1", "A9"] {
            assert!(r.promote(Some(ok), seed(2, 4), 1).is_ok(), "{ok:?} should be accepted");
        }
    }
}
