//! The unified solver API: every k-medoids algorithm in the crate —
//! OneBatchPAM and all eight paper baselines — behind one entry point.
//!
//! [`MethodSpec`] names a method exactly like the paper's result rows and
//! round-trips through strings ([`MethodSpec::parse`] /
//! [`MethodSpec::label`]), so any method is addressable from config
//! files, CLI flags (`--method`) and the server wire protocol
//! (`cluster method=...`).  [`SolveSpec`] carries the method plus the
//! shared run parameters, and [`solve`] dispatches through the
//! [`Solver`] trait that each algorithm implements as a thin adapter
//! over its existing free function (`baselines::faster_pam`,
//! `coordinator::one_batch_pam`, ...).
//!
//! Adding a new algorithm is: implement [`Solver`] next to the
//! algorithm, add a [`MethodSpec`] variant, and every surface — CLI,
//! bench harness, job server, examples — can run it by name.
//!
//! ```no_run
//! use obpam::backend::NativeBackend;
//! use obpam::data::DataSource;
//! use obpam::dissim::Metric;
//! use obpam::solver::{self, MethodSpec, SolveSpec};
//!
//! // URI-addressed sources: synth:, file:, or a bare synth name.
//! let data = DataSource::parse("synth:blobs_2000_8_5").unwrap().load(1.0, 42).unwrap();
//! // any paper row label works: "FasterPAM", "BanditPAM++-2", ...
//! let method = MethodSpec::parse("OneBatch-nniw").unwrap();
//! // the spec carries the metric; the backend is built from it so the
//! // two can never silently disagree
//! let spec = SolveSpec { metric: Metric::L2, ..SolveSpec::new(method, 5, 42) };
//! let backend = NativeBackend::new(spec.metric);
//! let result = solver::solve(&data.x, &spec, &backend).unwrap();
//! println!("medoids: {:?}", result.medoids);
//! ```

use crate::backend::{ComputeBackend, NativeBackend};
use crate::baselines::{
    AlternateSolver, BanditPamSolver, ClaraSolver, FasterPamSolver, KMeansPpSolver, Kmc2Solver,
    LsKMeansPpSolver, RandomSolver,
};
use crate::coordinator::onebatch::{
    one_batch_pam_store, OneBatchConfig, OneBatchSolver, SwapStrategy,
};
use crate::coordinator::{KMedoidsResult, SamplerKind};
use crate::data::{RowStore, STREAM_CHUNK_ROWS};
use crate::dissim::{ComputeProfile, DissimCounter, Metric, StreamSweep};
use crate::linalg::Matrix;
use crate::runtime::Pool;
use anyhow::Result;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

/// One k-medoids algorithm behind the unified entry point.
///
/// Implementations are thin adapters over the crate's existing free
/// functions; they read the shared run parameters from the [`SolveSpec`]
/// and carry their method-specific hyperparameters (repetitions, chain
/// length, ...) in the struct itself.
pub trait Solver {
    /// Paper row label of the configured method (round-trips through
    /// [`MethodSpec::parse`]).
    fn label(&self) -> String;

    /// Select `spec.k` medoids of `x` on `backend`.
    fn solve(
        &self,
        x: &Matrix,
        spec: &SolveSpec,
        backend: &dyn ComputeBackend,
    ) -> Result<KMedoidsResult>;
}

/// The error message every cancelled solve fails with ([`CancelToken`]):
/// callers that distinguish "cancelled" from "failed" (the job server's
/// registry) match the error string against this constant.
pub const CANCELLED: &str = "cancelled";

/// Cooperative cancellation hook carried on [`SolveSpec::cancel`].
///
/// A token is a shared flag: the owner keeps a clone, hands another to
/// the solve, and [`CancelToken::cancel`] asks the solve to stop at its
/// next check point.  Checks are *cooperative*: [`solve`] checks once
/// before dispatch, and the pass-structured swap loops — OneBatchPAM
/// and FasterPAM — additionally between eager passes; a cancelled solve
/// fails with the [`CANCELLED`] error and discards its partial work.
/// The remaining point-level baselines only honour the pre-dispatch
/// check (they run their existing free functions unchanged), so
/// cancelling one mid-run lets it finish.
///
/// [`CancelToken::none`] (the [`Default`]) is the never-cancelled
/// token: checks are free and `cancel()` is a no-op, so non-serving
/// callers pay nothing.
#[derive(Clone, Debug, Default)]
pub struct CancelToken(Option<Arc<AtomicBool>>);

impl CancelToken {
    /// A live token (initially not cancelled); clones share the flag.
    pub fn new() -> Self {
        CancelToken(Some(Arc::new(AtomicBool::new(false))))
    }

    /// The inert token: never reports cancelled, `cancel()` is a no-op.
    pub const fn none() -> Self {
        CancelToken(None)
    }

    /// Request cancellation (visible to every clone of this token).
    pub fn cancel(&self) {
        if let Some(flag) = &self.0 {
            flag.store(true, Ordering::SeqCst);
        }
    }

    /// Has [`CancelToken::cancel`] been called on any clone?
    pub fn is_cancelled(&self) -> bool {
        self.0.as_deref().is_some_and(|flag| flag.load(Ordering::SeqCst))
    }
}

/// Method-independent run parameters for [`solve`].
///
/// The OneBatch-only knobs (`m`, `eps`, `max_passes`) have no meaning
/// for the point-level baselines and are ignored by them; user surfaces
/// that expose these knobs (CLI flags, server keys) reject them for
/// non-OneBatch methods instead of silently dropping them.
#[derive(Clone, Debug)]
pub struct SolveSpec {
    /// Which algorithm to run.
    pub method: MethodSpec,
    /// Number of medoids (k >= 2).
    pub k: usize,
    /// PRNG seed (every method's selection is deterministic given it).
    pub seed: u64,
    /// Dissimilarity the run is defined over.  Surfaces (CLI, server,
    /// grid runner) construct the compute backend from this field, and
    /// [`solve`] rejects a backend whose metric disagrees — a silent
    /// mismatch would corrupt every downstream number.
    pub metric: Metric,
    /// Execution-pool width for OneBatch's eager scan (`0` = auto,
    /// `1` = serial).  Matrix tile ops use the backend's own pool;
    /// medoids are bit-identical at any value for a fixed seed.
    pub threads: usize,
    /// OneBatch batch size; `None` -> paper default `100 ln(kn)`.
    pub m: Option<usize>,
    /// OneBatch swap acceptance threshold (0 = any improvement).
    pub eps: f64,
    /// OneBatch max eager passes (steepest: `k *` this many swaps).
    pub max_passes: usize,
    /// Cooperative cancellation hook: [`solve`] checks it before
    /// dispatch and OneBatchPAM between swap passes; a cancelled run
    /// fails with the [`CANCELLED`] error.  Defaults to the inert
    /// [`CancelToken::none`].
    pub cancel: CancelToken,
    /// Pre-built execution pool for OneBatch's eager scan.  `None`
    /// (the default) builds a `threads`-wide pool per solve; serving
    /// surfaces pass their cached pool so repeated jobs reuse parked
    /// workers instead of respawning them.  Results are bit-identical
    /// either way (rust/tests/parallel_equivalence.rs).
    pub pool: Option<Pool>,
    /// Distance-kernel profile: `Exact` (default) keeps the historical
    /// diff-accumulate kernels byte-identical for the paper-reproduction
    /// grid; `Fast` takes the dot-product SqL2/L2 path (server/CLI
    /// default, tolerance-equal).  Like `metric`, the backend is built
    /// from this field and [`solve`] rejects a disagreeing backend.
    pub profile: ComputeProfile,
}

impl SolveSpec {
    /// Spec for `method` with the default OneBatch knobs and a serial
    /// pool; override fields with struct-update syntax.
    pub fn new(method: MethodSpec, k: usize, seed: u64) -> Self {
        SolveSpec {
            method,
            k,
            seed,
            metric: Metric::L1,
            threads: 1,
            m: None,
            eps: 0.0,
            max_passes: 20,
            cancel: CancelToken::none(),
            pool: None,
            profile: ComputeProfile::Exact,
        }
    }
}

impl Default for SolveSpec {
    fn default() -> Self {
        SolveSpec::new(MethodSpec::default(), 10, 0)
    }
}

/// Methods the paper marks "Na" at large scale hold a full `n x n`
/// matrix (FasterPAM / Alternate) or resample every round (BanditPAM++);
/// above this many rows the serving surfaces reject them instead of
/// stalling a worker (see [`MethodSpec::feasible_large_scale`]).
pub const FULL_MATRIX_LIMIT: usize = 20_000;

/// The largest single-job price a serving surface accepts: the price of
/// a full-matrix method at exactly [`FULL_MATRIX_LIMIT`] rows.  The old
/// one-off "reject full-matrix methods above `FULL_MATRIX_LIMIT` rows"
/// rule is exactly [`JobCost::admissible`] under this cap — pricing
/// subsumes it (asserted in rust/tests/admission.rs).
pub const MAX_JOB_COST: u64 = (FULL_MATRIX_LIMIT as u64).pow(2);

/// Admission price of one solve, in abstract work units (one unit ~ one
/// dissimilarity evaluation / distance-matrix cell).
///
/// Produced by [`MethodSpec::cost`]; consumed by the job server's
/// weighted admission budget (`crate::server`), which replaced the flat
/// one-slot-per-job accounting: a burst of cheap OneBatch jobs
/// (`~ n*m` units each) fits the budget many times over, while one
/// full-matrix job (`~ n^2` units) consumes most of it.  Prices are
/// order-of-magnitude estimates for *admission weighting*, not exact
/// dissimilarity predictions.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct JobCost {
    /// Estimated work units the solve will consume.
    pub units: u64,
    /// Does the price grow quadratically in `n`?  True exactly for the
    /// methods the paper marks "Na" at large scale
    /// (`!feasible_large_scale()`).
    pub quadratic: bool,
    /// Peak resident bytes the solve pins while it runs: the feature
    /// matrix plus the method's working state (full-matrix methods add
    /// `n*n*4`; resident OneBatch adds its `n*m` matrix; a *streaming*
    /// OneBatch run prices only the gathered `m x p` batch slice plus
    /// one chunk buffer — see [`MethodSpec::streaming_cost`]).  Priced
    /// by [`MethodSpec::cost_with_dims`]; the dimension-less
    /// [`MethodSpec::cost`] prices the feature matrix at zero width.
    pub resident_bytes: u64,
}

impl JobCost {
    /// Is this job small enough to serve at all?  Linear-cost methods
    /// always are (OneBatchPAM's point: `O(mn)` stays cheap at any
    /// paper scale); quadratic ones only below [`MAX_JOB_COST`] — which
    /// is precisely the historical `n <= FULL_MATRIX_LIMIT` rule.
    pub fn admissible(&self) -> bool {
        !self.quadratic || self.units <= MAX_JOB_COST
    }
}

/// A fitted k-medoids model: everything the `assign` read path needs,
/// with **no reference to the training dataset** — the medoid feature
/// vectors (a `k x p` matrix copied out of `x` at fit time), the metric
/// the fit was defined over, and the training inertia.  The optional
/// per-training-row arrays (`labels`, `dist_to_nearest` — the exemplars'
/// `labels_` / `dist_to_nearest_medoid_`) are `O(n)` and are dropped by
/// [`FittedModel::without_training_arrays`] before a serving surface
/// retains the model.
#[derive(Clone, Debug)]
pub struct FittedModel {
    /// Medoid feature vectors, one row per medoid (`k x p`) — copied,
    /// not referenced, so assignment needs no dataset in memory.
    pub medoid_rows: Matrix,
    /// Training-set row indices of the medoids (provenance; assignment
    /// never reads them).
    pub medoids: Vec<usize>,
    /// Dissimilarity the model was fitted under; [`FittedModel::assign`]
    /// rejects a backend with any other metric.
    pub metric: Metric,
    /// Mean nearest-medoid distance over the training set — the final
    /// assignment pass's objective (the exemplars' `inertia_`).
    pub inertia: f64,
    /// Nearest-medoid label per training row (dropped for serving).
    pub labels: Option<Vec<usize>>,
    /// Distance to the nearest medoid per training row (dropped for
    /// serving).
    pub dist_to_nearest: Option<Vec<f32>>,
}

impl FittedModel {
    /// Number of medoids.
    pub fn k(&self) -> usize {
        self.medoid_rows.rows
    }

    /// Feature dimension assignment points must match.
    pub fn dim(&self) -> usize {
        self.medoid_rows.cols
    }

    /// This model minus the `O(n)` per-training-row arrays: what a
    /// serving surface retains (`O(k p)` memory, dataset-free).
    pub fn without_training_arrays(mut self) -> FittedModel {
        self.labels = None;
        self.dist_to_nearest = None;
        self
    }

    /// Nearest-medoid `(label, distance)` per row of `points` — the
    /// [`crate::backend::assign`] kernel with the model's own dimension
    /// and metric checks applied first.
    pub fn assign(
        &self,
        backend: &dyn ComputeBackend,
        points: &Matrix,
    ) -> Result<(Vec<usize>, Vec<f32>)> {
        self.check_assign(backend, points)?;
        crate::backend::assign(backend, points, &self.medoid_rows)
    }

    /// [`FittedModel::assign`] plus the second-nearest medoid:
    /// `(near, dnear, second, dsecond)` per row of `points`.
    pub fn assign_top2(
        &self,
        backend: &dyn ComputeBackend,
        points: &Matrix,
    ) -> Result<crate::backend::Top2> {
        self.check_assign(backend, points)?;
        crate::backend::assign_top2(backend, points, &self.medoid_rows)
    }

    fn check_assign(&self, backend: &dyn ComputeBackend, points: &Matrix) -> Result<()> {
        anyhow::ensure!(
            backend.metric() == self.metric,
            "model was fitted under metric '{}', backend computes '{}'",
            self.metric.name(),
            backend.metric().name()
        );
        anyhow::ensure!(
            points.cols == self.dim(),
            "model expects {} features per point, got {}",
            self.dim(),
            points.cols
        );
        Ok(())
    }
}

/// Capture the fitted model of a finished solve: copy the medoid rows
/// out of `x` and run one final assignment pass over the training set,
/// whose per-row nearest distances yield the inertia (mean) and the
/// optional `labels` / `dist_to_nearest` arrays.  `O(n k)` work — the
/// same order as the objective evaluation serving surfaces already pay.
pub fn fit_model(
    x: &Matrix,
    r: &KMedoidsResult,
    metric: Metric,
    backend: &dyn ComputeBackend,
) -> Result<FittedModel> {
    anyhow::ensure!(
        backend.metric() == metric,
        "fit metric '{}' does not match backend metric '{}'",
        metric.name(),
        backend.metric().name()
    );
    let medoid_rows = x.select_rows(&r.medoids);
    let (labels, dist) = crate::backend::assign(backend, x, &medoid_rows)?;
    let inertia = dist.iter().map(|&d| d as f64).sum::<f64>() / x.rows.max(1) as f64;
    Ok(FittedModel {
        medoid_rows,
        medoids: r.medoids.clone(),
        metric,
        inertia,
        labels: Some(labels),
        dist_to_nearest: Some(dist),
    })
}

/// [`solve`] plus the fitted-model capture of [`fit_model`]: the entry
/// point for serving surfaces that keep the model around for `assign`
/// instead of discarding everything but the medoid indices.
pub fn solve_fitted(
    x: &Matrix,
    spec: &SolveSpec,
    backend: &dyn ComputeBackend,
) -> Result<(KMedoidsResult, FittedModel)> {
    let r = solve(x, spec, backend)?;
    let model = fit_model(x, &r, spec.metric, backend)?;
    Ok((r, model))
}

/// [`solve`] over a [`RowStore`]: resident stores dispatch through the
/// regular path zero-copy; streaming stores run the OneBatch
/// out-of-core coordinator, bit-identical to the resident solve for a
/// fixed seed.  Non-OneBatch methods need the full matrix and fail on
/// a streaming store — serving surfaces price them for resident
/// admission instead.
pub fn solve_store(
    store: &mut dyn RowStore,
    spec: &SolveSpec,
    backend: &dyn ComputeBackend,
) -> Result<KMedoidsResult> {
    if let Some(x) = store.as_matrix() {
        return solve(x, spec, backend);
    }
    anyhow::ensure!(
        backend.metric() == spec.metric,
        "spec metric '{}' does not match backend metric '{}'",
        spec.metric.name(),
        backend.metric().name()
    );
    anyhow::ensure!(
        backend.profile() == spec.profile,
        "spec profile '{}' does not match backend profile '{}'",
        spec.profile.name(),
        backend.profile().name()
    );
    anyhow::ensure!(!spec.cancel.is_cancelled(), CANCELLED);
    let MethodSpec::OneBatch { sampler, strategy } = &spec.method else {
        anyhow::bail!(
            "method {} needs the dataset resident and cannot run over a streaming source",
            spec.method.label()
        );
    };
    let cfg = OneBatchConfig {
        k: spec.k,
        sampler: *sampler,
        m: spec.m,
        max_passes: spec.max_passes,
        strategy: *strategy,
        eps: spec.eps,
        seed: spec.seed,
        threads: spec.threads,
        cancel: spec.cancel.clone(),
        pool: spec.pool.clone(),
        profile: spec.profile,
    };
    let r = one_batch_pam_store(store, &cfg, backend)?;
    r.validate(store.dims().0, spec.k);
    Ok(r)
}

/// [`fit_model`] over a [`RowStore`]: the medoid rows are gathered from
/// the store and the final assignment pass streams chunk-at-a-time
/// ([`StreamSweep::assign`]), so no `n x p` buffer is ever materialized.
/// Output bits match the resident fit of the same data.
pub fn fit_model_store(
    store: &mut dyn RowStore,
    r: &KMedoidsResult,
    spec: &SolveSpec,
    backend: &dyn ComputeBackend,
) -> Result<FittedModel> {
    if let Some(x) = store.as_matrix() {
        return fit_model(x, r, spec.metric, backend);
    }
    anyhow::ensure!(
        backend.metric() == spec.metric,
        "fit metric '{}' does not match backend metric '{}'",
        spec.metric.name(),
        backend.metric().name()
    );
    let (n, p) = store.dims();
    let mut rows = vec![0.0f32; r.medoids.len() * p];
    store.gather_rows(&r.medoids, &mut rows)?;
    let medoid_rows = Matrix::from_vec(r.medoids.len(), p, rows);
    let counted = DissimCounter::with_counters(spec.metric, backend.counters());
    let pool = spec.pool.clone().unwrap_or_else(|| Pool::new(spec.threads));
    let mut sweep = StreamSweep::new(STREAM_CHUNK_ROWS);
    let (labels, dist) = sweep.assign(&counted, store, &medoid_rows, &pool, spec.profile)?;
    let inertia = dist.iter().map(|&d| d as f64).sum::<f64>() / n.max(1) as f64;
    Ok(FittedModel {
        medoid_rows,
        medoids: r.medoids.clone(),
        metric: spec.metric,
        inertia,
        labels: Some(labels),
        dist_to_nearest: Some(dist),
    })
}

/// [`solve_fitted`] over a [`RowStore`] — the serving entry point for
/// out-of-core jobs.
pub fn solve_fitted_store(
    store: &mut dyn RowStore,
    spec: &SolveSpec,
    backend: &dyn ComputeBackend,
) -> Result<(KMedoidsResult, FittedModel)> {
    let r = solve_store(store, spec, backend)?;
    let model = fit_model_store(store, &r, spec, backend)?;
    Ok((r, model))
}

/// Run `spec.method` on `x` and validate the result invariants
/// (`k` unique in-range medoids).  The backend's metric must agree with
/// `spec.metric` — surfaces build the backend from the spec.
///
/// This is the single entry point behind the CLI, the bench harness,
/// the job server and the examples.
pub fn solve(x: &Matrix, spec: &SolveSpec, backend: &dyn ComputeBackend) -> Result<KMedoidsResult> {
    anyhow::ensure!(
        backend.metric() == spec.metric,
        "spec metric '{}' does not match backend metric '{}'",
        spec.metric.name(),
        backend.metric().name()
    );
    anyhow::ensure!(
        backend.profile() == spec.profile,
        "spec profile '{}' does not match backend profile '{}'",
        spec.profile.name(),
        backend.profile().name()
    );
    // cooperative cancellation: a job cancelled before pickup never
    // starts (OneBatchPAM re-checks the token between swap passes)
    anyhow::ensure!(!spec.cancel.is_cancelled(), CANCELLED);
    let r = spec.method.solver().solve(x, spec, backend)?;
    r.validate(x.rows, spec.k);
    Ok(r)
}

/// One method variant, named exactly like the paper's result rows.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum MethodSpec {
    /// Random k-subset.
    Random,
    /// FasterPAM (full n x n; small scale only in the paper).
    FasterPam,
    /// Alternate (Park & Jun; small scale only).
    Alternate,
    /// FasterCLARA with I repetitions.
    FasterClara {
        /// Subsample repetitions (paper: I in {5, 50}).
        reps: usize,
    },
    /// kmc2 with chain length L.
    Kmc2 {
        /// MCMC chain length.
        chain: usize,
    },
    /// k-means++ seeding.
    KMeansPp,
    /// LS-k-means++ with Z local-search steps.
    LsKMeansPp {
        /// Local-search steps.
        steps: usize,
    },
    /// BanditPAM++ with T swap rounds.
    BanditPam {
        /// Max swap rounds (paper sweeps {0, 2, 5}).
        swaps: usize,
    },
    /// OneBatchPAM with a sampling variant.
    OneBatch {
        /// Batch construction variant.
        sampler: SamplerKind,
        /// Swap engine.
        strategy: SwapStrategy,
    },
}

/// Effective OneBatch batch size for pricing: the explicit override or
/// the paper default, clamped to `n` exactly like the coordinator does.
fn onebatch_m_eff(n: usize, k: usize, m: Option<usize>) -> u64 {
    m.unwrap_or_else(|| crate::coordinator::sampler::default_batch_size(n.max(2), k))
        .min(n.max(1)) as u64
}

impl Default for MethodSpec {
    /// The paper's recommended method: OneBatch-nniw with eager swaps.
    fn default() -> Self {
        MethodSpec::OneBatch { sampler: SamplerKind::Nniw, strategy: SwapStrategy::Eager }
    }
}

impl MethodSpec {
    /// Paper row label (round-trips through [`MethodSpec::parse`]).
    ///
    /// Kept as a direct match — `label()` runs once per record / reply /
    /// error message, so it must not box a solver just to name itself;
    /// agreement with [`Solver::label`] is asserted in the tests.
    pub fn label(&self) -> String {
        match self {
            MethodSpec::Random => "Random".into(),
            MethodSpec::FasterPam => "FasterPAM".into(),
            MethodSpec::Alternate => "Alternate".into(),
            MethodSpec::FasterClara { reps } => format!("FasterCLARA-{reps}"),
            MethodSpec::Kmc2 { chain } => format!("kmc2-{chain}"),
            MethodSpec::KMeansPp => "k-means++".into(),
            MethodSpec::LsKMeansPp { steps } => format!("LS-k-means++-{steps}"),
            MethodSpec::BanditPam { swaps } => format!("BanditPAM++-{swaps}"),
            MethodSpec::OneBatch { sampler, strategy } => match strategy {
                SwapStrategy::Eager => format!("OneBatch-{}", sampler.name()),
                SwapStrategy::Steepest => format!("OneBatch-{}-steepest", sampler.name()),
            },
        }
    }

    /// Parse a method label back into a spec (case-insensitive).
    ///
    /// Accepts every [`MethodSpec::label`] spelling plus a few aliases:
    /// `kmeanspp` / `kmeans++` for `k-means++`, `lskmeanspp-Z` for
    /// `LS-k-means++-Z`, `banditpam-T` for `BanditPAM++-T`, and a bare
    /// `onebatch` for the paper default `OneBatch-nniw`.
    pub fn parse(s: &str) -> Option<MethodSpec> {
        let t = s.trim().to_ascii_lowercase();
        let spec = match t.as_str() {
            "random" => MethodSpec::Random,
            "fasterpam" => MethodSpec::FasterPam,
            "alternate" => MethodSpec::Alternate,
            "k-means++" | "kmeans++" | "kmeanspp" => MethodSpec::KMeansPp,
            "onebatch" | "onebatchpam" => MethodSpec::default(),
            _ => {
                if let Some(rest) = t.strip_prefix("fasterclara-") {
                    MethodSpec::FasterClara { reps: rest.parse().ok()? }
                } else if let Some(rest) = t.strip_prefix("kmc2-") {
                    // chain length 0 would trip kmc2's `l >= 1` assert
                    // deep inside a worker; reject it at the boundary
                    match rest.parse().ok()? {
                        0 => return None,
                        chain => MethodSpec::Kmc2 { chain },
                    }
                } else if let Some(rest) =
                    t.strip_prefix("ls-k-means++-").or_else(|| t.strip_prefix("lskmeanspp-"))
                {
                    MethodSpec::LsKMeansPp { steps: rest.parse().ok()? }
                } else if let Some(rest) =
                    t.strip_prefix("banditpam++-").or_else(|| t.strip_prefix("banditpam-"))
                {
                    MethodSpec::BanditPam { swaps: rest.parse().ok()? }
                } else if let Some(rest) = t.strip_prefix("onebatch-") {
                    let (sampler, strategy) = match rest.strip_suffix("-steepest") {
                        Some(sk) => (sk, SwapStrategy::Steepest),
                        None => (rest, SwapStrategy::Eager),
                    };
                    MethodSpec::OneBatch { sampler: SamplerKind::parse(sampler)?, strategy }
                } else {
                    return None;
                }
            }
        };
        Some(spec)
    }

    /// Construct the [`Solver`] that runs this method.
    pub fn solver(&self) -> Box<dyn Solver> {
        match self {
            MethodSpec::Random => Box::new(RandomSolver),
            MethodSpec::FasterPam => Box::new(FasterPamSolver::default()),
            MethodSpec::Alternate => Box::new(AlternateSolver::default()),
            MethodSpec::FasterClara { reps } => Box::new(ClaraSolver { reps: *reps }),
            MethodSpec::Kmc2 { chain } => Box::new(Kmc2Solver { chain: *chain }),
            MethodSpec::KMeansPp => Box::new(KMeansPpSolver),
            MethodSpec::LsKMeansPp { steps } => Box::new(LsKMeansPpSolver { steps: *steps }),
            MethodSpec::BanditPam { swaps } => Box::new(BanditPamSolver { swaps: *swaps }),
            MethodSpec::OneBatch { sampler, strategy } => {
                Box::new(OneBatchSolver { sampler: *sampler, strategy: *strategy })
            }
        }
    }

    /// Does the paper run this method on large-scale datasets?
    /// (FasterPAM / Alternate / BanditPAM++ are "Na" there.)
    ///
    /// Equivalent to `!self.cost(n, k, m).quadratic` for any arguments —
    /// kept as the semantic spelling for callers that do not price.
    pub fn feasible_large_scale(&self) -> bool {
        !matches!(
            self,
            MethodSpec::FasterPam | MethodSpec::Alternate | MethodSpec::BanditPam { .. }
        )
    }

    /// Price one solve of this method over `n` rows with `k` medoids in
    /// work units (~ dissimilarity evaluations).  `m` is the OneBatch
    /// batch-size override (`None` -> the paper default `100 ln(kn)`),
    /// ignored by every other method.
    ///
    /// The dominant terms per family: full-matrix methods (FasterPAM /
    /// Alternate) and per-round resamplers (BanditPAM++) price `n^2`;
    /// OneBatchPAM prices its single `n x m` pairwise pass; FasterCLARA
    /// prices `reps` subsample matrices; the seeding family prices its
    /// `O(nk)`-ish passes.  See [`JobCost`] for what the price is for.
    pub fn cost(&self, n: usize, k: usize, m: Option<usize>) -> JobCost {
        self.cost_with_dims(n, 0, k, m)
    }

    /// [`MethodSpec::cost`] with the feature width known: the same work
    /// units plus an honest `resident_bytes` price — the `n x p` feature
    /// matrix at 4 bytes a cell plus the method's working state
    /// (full-matrix methods pin `n x n`, a resident OneBatch pins its
    /// `n x m`, FasterCLARA one subsample matrix).
    pub fn cost_with_dims(&self, n: usize, p: usize, k: usize, m: Option<usize>) -> JobCost {
        let n64 = n as u64;
        let k64 = k.max(1) as u64;
        let feat = n64.saturating_mul(p as u64).saturating_mul(4);
        match self {
            MethodSpec::Random => {
                JobCost { units: n64.max(1), quadratic: false, resident_bytes: feat }
            }
            MethodSpec::FasterPam | MethodSpec::Alternate => JobCost {
                units: n64.saturating_mul(n64),
                quadratic: true,
                resident_bytes: feat.saturating_add(n64.saturating_mul(n64).saturating_mul(4)),
            },
            // BanditPAM++ re-samples distances every swap round; its
            // serving cost scales with the full matrix it keeps touching
            MethodSpec::BanditPam { .. } => JobCost {
                units: n64.saturating_mul(n64),
                quadratic: true,
                resident_bytes: feat.saturating_add(n64.saturating_mul(n64).saturating_mul(4)),
            },
            MethodSpec::FasterClara { reps } => {
                // `reps` FasterPAM runs on subsamples of `80 + 4k` rows,
                // plus the final full-data assignment
                let s = (80 + 4 * k).min(n.max(1)) as u64;
                let units = ((*reps).max(1) as u64)
                    .saturating_mul(s.saturating_mul(s))
                    .saturating_add(n64.saturating_mul(k64));
                JobCost {
                    units: units.max(1),
                    quadratic: false,
                    // one subsample matrix at a time, whatever `reps` is
                    resident_bytes: feat.saturating_add(s.saturating_mul(s).saturating_mul(4)),
                }
            }
            MethodSpec::Kmc2 { chain } => {
                // one O(n) proposal distribution + k chains of length L
                let units = n64.saturating_add(k64.saturating_mul(*chain as u64));
                JobCost { units: units.max(1), quadratic: false, resident_bytes: feat }
            }
            MethodSpec::KMeansPp => JobCost {
                units: n64.saturating_mul(k64).max(1),
                quadratic: false,
                resident_bytes: feat,
            },
            MethodSpec::LsKMeansPp { steps } => {
                let units = n64.saturating_mul(k64.saturating_add(*steps as u64));
                JobCost { units: units.max(1), quadratic: false, resident_bytes: feat }
            }
            MethodSpec::OneBatch { .. } => {
                // the single O(n m) pairwise pass dominates (Algorithm 1)
                let m_eff = onebatch_m_eff(n, k, m);
                JobCost {
                    units: n64.saturating_mul(m_eff).max(1),
                    quadratic: false,
                    resident_bytes: feat.saturating_add(n64.saturating_mul(m_eff).saturating_mul(4)),
                }
            }
        }
    }

    /// Price of running this method *streaming* over an out-of-core
    /// [`RowStore`], or `None` for methods that need the dataset
    /// resident.  Only the OneBatch family streams: its peak resident
    /// set is the gathered `m x p` batch slice plus one chunk buffer —
    /// the `n x m` matrix D it assembles is already what the units axis
    /// charges for (one unit per cell), so the byte axis prices only
    /// the feature-side footprint that streaming actually bounds.
    pub fn streaming_cost(&self, n: usize, p: usize, k: usize, m: Option<usize>) -> Option<JobCost> {
        if !matches!(self, MethodSpec::OneBatch { .. }) {
            return None;
        }
        let base = self.cost_with_dims(n, p, k, m);
        let p64 = p as u64;
        let m_eff = onebatch_m_eff(n, k, m);
        let chunk = (STREAM_CHUNK_ROWS as u64).saturating_mul(p64).saturating_mul(4);
        let bytes = m_eff.saturating_mul(p64).saturating_mul(4).saturating_add(chunk);
        Some(JobCost { resident_bytes: bytes, ..base })
    }

    /// The full 18-row method grid of Table 3.
    pub fn table3_grid() -> Vec<MethodSpec> {
        use MethodSpec::*;
        let mut v = vec![
            Random,
            FasterPam,
            Alternate,
            FasterClara { reps: 5 },
            FasterClara { reps: 50 },
            Kmc2 { chain: 20 },
            Kmc2 { chain: 100 },
            Kmc2 { chain: 200 },
            KMeansPp,
            LsKMeansPp { steps: 5 },
            LsKMeansPp { steps: 10 },
            BanditPam { swaps: 0 },
            BanditPam { swaps: 2 },
            BanditPam { swaps: 5 },
        ];
        for sampler in [SamplerKind::Lwcs, SamplerKind::Unif, SamplerKind::Debias, SamplerKind::Nniw] {
            v.push(OneBatch { sampler, strategy: SwapStrategy::Eager });
        }
        v
    }

    /// The 5-method subset of Figure 1 (KM, FP, FC, BP, OBP).
    pub fn fig1_grid() -> Vec<MethodSpec> {
        vec![
            MethodSpec::KMeansPp,
            MethodSpec::FasterPam,
            MethodSpec::FasterClara { reps: 5 },
            MethodSpec::BanditPam { swaps: 2 },
            MethodSpec::OneBatch { sampler: SamplerKind::Nniw, strategy: SwapStrategy::Eager },
        ]
    }

    /// Run the method serially (convenience wrapper over [`solve`]).
    pub fn run(&self, x: &Matrix, k: usize, metric: Metric, seed: u64) -> Result<RunOutput> {
        self.run_threaded(x, k, metric, seed, 1)
    }

    /// Run on a native backend with a `threads`-wide execution pool
    /// (`1` = serial, `0` = auto).  Matrix-level methods (OneBatch,
    /// FasterPAM, FasterCLARA) parallelise their pairwise/tile ops and
    /// OneBatch additionally its eager scan; selections are identical
    /// to the serial run for a fixed seed.
    pub fn run_threaded(
        &self,
        x: &Matrix,
        k: usize,
        metric: Metric,
        seed: u64,
        threads: usize,
    ) -> Result<RunOutput> {
        let backend = NativeBackend::with_pool(metric, Pool::new(threads));
        self.run_with_backend(x, k, seed, &backend, threads)
    }

    /// Run against an explicit backend (XLA-vs-native ablations).
    /// `threads` sizes the OneBatch eager-scan pool (backend tile ops
    /// use the backend's own pool).
    pub fn run_with_backend(
        &self,
        x: &Matrix,
        k: usize,
        seed: u64,
        backend: &dyn ComputeBackend,
        threads: usize,
    ) -> Result<RunOutput> {
        let spec = SolveSpec {
            threads,
            metric: backend.metric(),
            profile: backend.profile(),
            ..SolveSpec::new(self.clone(), k, seed)
        };
        Ok(solve(x, &spec, backend)?.into())
    }
}

/// What the harness records per run before objective evaluation.
#[derive(Clone, Debug)]
pub struct RunOutput {
    /// Selected medoid rows.
    pub medoids: Vec<usize>,
    /// Timed selection seconds.
    pub seconds: f64,
    /// Dissimilarity computations.
    pub dissim_count: u64,
    /// Accepted swaps.
    pub swap_count: u64,
}

impl From<KMedoidsResult> for RunOutput {
    fn from(r: KMedoidsResult) -> Self {
        RunOutput {
            medoids: r.medoids,
            seconds: r.stats.seconds,
            dissim_count: r.stats.dissim_count,
            swap_count: r.stats.swap_count,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::rng::Rng;

    #[test]
    fn labels_match_paper_rows() {
        let labels: Vec<String> = MethodSpec::table3_grid().iter().map(|m| m.label()).collect();
        for expect in [
            "Random",
            "FasterPAM",
            "Alternate",
            "FasterCLARA-5",
            "FasterCLARA-50",
            "kmc2-20",
            "kmc2-100",
            "kmc2-200",
            "k-means++",
            "LS-k-means++-5",
            "LS-k-means++-10",
            "BanditPAM++-0",
            "BanditPAM++-2",
            "BanditPAM++-5",
            "OneBatch-lwcs",
            "OneBatch-unif",
            "OneBatch-debias",
            "OneBatch-nniw",
        ] {
            assert!(labels.iter().any(|l| l == expect), "missing {expect}");
        }
        assert_eq!(labels.len(), 18);
    }

    #[test]
    fn parse_round_trips_every_label() {
        let mut grid = MethodSpec::table3_grid();
        grid.extend(MethodSpec::fig1_grid());
        grid.push(MethodSpec::OneBatch {
            sampler: SamplerKind::Prog,
            strategy: SwapStrategy::Steepest,
        });
        for m in grid {
            let label = m.label();
            assert_eq!(MethodSpec::parse(&label), Some(m), "label {label} does not round-trip");
        }
    }

    #[test]
    fn parse_accepts_aliases_and_case() {
        assert_eq!(MethodSpec::parse("kmeanspp"), Some(MethodSpec::KMeansPp));
        assert_eq!(MethodSpec::parse("KMEANS++"), Some(MethodSpec::KMeansPp));
        assert_eq!(MethodSpec::parse("banditpam-3"), Some(MethodSpec::BanditPam { swaps: 3 }));
        assert_eq!(MethodSpec::parse("lskmeanspp-7"), Some(MethodSpec::LsKMeansPp { steps: 7 }));
        assert_eq!(MethodSpec::parse("onebatch"), Some(MethodSpec::default()));
        assert_eq!(MethodSpec::parse(" fasterpam "), Some(MethodSpec::FasterPam));
        assert_eq!(
            MethodSpec::parse("OneBatch-unif-steepest"),
            Some(MethodSpec::OneBatch {
                sampler: SamplerKind::Unif,
                strategy: SwapStrategy::Steepest
            })
        );
    }

    #[test]
    fn parse_rejects_garbage() {
        for bad in
            ["nope", "", "FasterCLARA-", "FasterCLARA-x", "kmc2-", "kmc2-0", "OneBatch-bogus", "k-means"]
        {
            assert_eq!(MethodSpec::parse(bad), None, "{bad:?} should not parse");
        }
    }

    #[test]
    fn solver_labels_agree_with_spec_labels() {
        for m in MethodSpec::table3_grid() {
            assert_eq!(m.label(), m.solver().label());
        }
    }

    #[test]
    fn cost_prices_families_in_the_right_order() {
        let (n, k) = (100_000, 10);
        let ob = MethodSpec::default().cost(n, k, None);
        let fp = MethodSpec::FasterPam.cost(n, k, None);
        let seed = MethodSpec::KMeansPp.cost(n, k, None);
        assert!(!ob.quadratic && fp.quadratic && !seed.quadratic);
        assert_eq!(fp.units, (n as u64) * (n as u64));
        // OneBatch prices its n*m pass with the paper-default m
        let m = crate::coordinator::sampler::default_batch_size(n, k) as u64;
        assert_eq!(ob.units, n as u64 * m);
        // an explicit m override reprices the job
        assert_eq!(MethodSpec::default().cost(n, k, Some(200)).units, n as u64 * 200);
        // full-matrix at this n is far above OneBatch (n/m ~ 72x here)
        assert!(fp.units > 10 * ob.units);
        assert!(ob.admissible() && seed.admissible() && !fp.admissible());
    }

    #[test]
    fn cost_quadratic_flag_matches_feasibility_and_old_limit_rule() {
        for m in MethodSpec::table3_grid() {
            for n in [FULL_MATRIX_LIMIT - 1, FULL_MATRIX_LIMIT, FULL_MATRIX_LIMIT + 1] {
                let c = m.cost(n, 10, None);
                assert_eq!(c.quadratic, !m.feasible_large_scale(), "{}", m.label());
                // pricing subsumes the historical limit check exactly
                let old_rule = m.feasible_large_scale() || n <= FULL_MATRIX_LIMIT;
                assert_eq!(c.admissible(), old_rule, "{} at n={n}", m.label());
                assert!(c.units > 0, "{} at n={n}", m.label());
            }
        }
    }

    #[test]
    fn cost_with_dims_prices_resident_bytes() {
        let (n, p, k) = (10_000usize, 16usize, 8usize);
        let feat = (n * p * 4) as u64;
        // full-matrix methods pin features + the n x n matrix
        let fp = MethodSpec::FasterPam.cost_with_dims(n, p, k, None);
        assert_eq!(fp.resident_bytes, feat + (n as u64 * n as u64 * 4));
        // seeding family pins only the features
        assert_eq!(MethodSpec::KMeansPp.cost_with_dims(n, p, k, None).resident_bytes, feat);
        // resident OneBatch adds its n x m matrix
        let ob = MethodSpec::default().cost_with_dims(n, p, k, Some(200));
        assert_eq!(ob.resident_bytes, feat + (n as u64 * 200 * 4));
        // the dimension-less spelling prices features at zero width but
        // keeps the units identical
        let blind = MethodSpec::default().cost(n, k, Some(200));
        assert_eq!(blind.units, ob.units);
        assert_eq!(blind.resident_bytes, (n as u64 * 200 * 4));
    }

    #[test]
    fn streaming_cost_prices_batch_plus_chunk_only() {
        let (n, p, k, m) = (1_000_000usize, 32usize, 10usize, 400usize);
        let ob = MethodSpec::default().streaming_cost(n, p, k, Some(m)).unwrap();
        let chunk = (STREAM_CHUNK_ROWS * p * 4) as u64;
        assert_eq!(ob.resident_bytes, (m * p * 4) as u64 + chunk);
        // same units as the resident price, n-independent byte price
        assert_eq!(ob.units, MethodSpec::default().cost(n, k, Some(m)).units);
        assert!(ob.resident_bytes < MethodSpec::default().cost_with_dims(n, p, k, Some(m)).resident_bytes / 100);
        // only the OneBatch family streams
        assert!(MethodSpec::FasterPam.streaming_cost(n, p, k, None).is_none());
        assert!(MethodSpec::KMeansPp.streaming_cost(n, p, k, None).is_none());
    }

    #[test]
    fn streaming_solve_and_fit_match_resident_bits() {
        let mut rng = Rng::new(31);
        let x = synth::gen_gaussian_mixture(&mut rng, 180, 4, 3, 0.15, 1.0);
        let spec = SolveSpec { m: Some(40), ..SolveSpec::new(MethodSpec::default(), 3, 9) };
        let backend = NativeBackend::new(Metric::L1);
        let (rr, rm) = solve_fitted(&x, &spec, &backend).unwrap();

        let dir = std::env::temp_dir().join(format!("obpam_solver_{}", std::process::id()));
        let _ = std::fs::create_dir_all(&dir);
        let path = dir.join("fit.npy");
        crate::data::npy::write_npy(&path, &x).unwrap();
        let mut store = crate::data::store::NpyStore::open(&path).unwrap();
        let backend2 = NativeBackend::new(Metric::L1);
        let (sr, sm) = solve_fitted_store(&mut store, &spec, &backend2).unwrap();

        assert_eq!(rr.medoids, sr.medoids);
        assert_eq!(rm.inertia.to_bits(), sm.inertia.to_bits());
        assert_eq!(rm.labels, sm.labels);
        assert_eq!(rm.dist_to_nearest, sm.dist_to_nearest);
        assert_eq!(rm.medoid_rows.data, sm.medoid_rows.data);
    }

    #[test]
    fn streaming_solve_rejects_full_matrix_methods() {
        let mut rng = Rng::new(32);
        let x = synth::gen_gaussian_mixture(&mut rng, 120, 4, 3, 0.15, 1.0);
        let dir = std::env::temp_dir().join(format!("obpam_solver_fm_{}", std::process::id()));
        let _ = std::fs::create_dir_all(&dir);
        let path = dir.join("fm.npy");
        crate::data::npy::write_npy(&path, &x).unwrap();
        let mut store = crate::data::store::NpyStore::open(&path).unwrap();
        let spec = SolveSpec::new(MethodSpec::FasterPam, 3, 1);
        let err = solve_store(&mut store, &spec, &NativeBackend::new(Metric::L1))
            .unwrap_err()
            .to_string();
        assert!(err.contains("cannot run over a streaming source"), "{err}");
    }

    #[test]
    fn large_scale_feasibility_matches_paper_na() {
        assert!(!MethodSpec::FasterPam.feasible_large_scale());
        assert!(!MethodSpec::Alternate.feasible_large_scale());
        assert!(!MethodSpec::BanditPam { swaps: 2 }.feasible_large_scale());
        assert!(MethodSpec::FasterClara { reps: 5 }.feasible_large_scale());
        assert!(MethodSpec::KMeansPp.feasible_large_scale());
    }

    #[test]
    fn every_method_runs_on_tiny_data() {
        let mut rng = Rng::new(1);
        let x = synth::gen_gaussian_mixture(&mut rng, 130, 4, 3, 0.15, 1.0);
        for m in MethodSpec::table3_grid() {
            let out = m.run(&x, 3, Metric::L1, 7).unwrap();
            assert_eq!(out.medoids.len(), 3, "{}", m.label());
        }
    }

    #[test]
    fn threaded_run_selects_identical_medoids() {
        let mut rng = Rng::new(2);
        let x = synth::gen_gaussian_mixture(&mut rng, 160, 4, 3, 0.15, 1.0);
        for m in [
            MethodSpec::FasterPam,
            MethodSpec::OneBatch { sampler: SamplerKind::Nniw, strategy: SwapStrategy::Eager },
        ] {
            let serial = m.run(&x, 3, Metric::L1, 11).unwrap();
            let par = m.run_threaded(&x, 3, Metric::L1, 11, 4).unwrap();
            assert_eq!(serial.medoids, par.medoids, "{}", m.label());
            assert_eq!(serial.dissim_count, par.dissim_count, "{}", m.label());
        }
    }

    #[test]
    fn solve_rejects_metric_mismatch() {
        let mut rng = Rng::new(4);
        let x = synth::gen_gaussian_mixture(&mut rng, 120, 4, 3, 0.15, 1.0);
        let spec = SolveSpec { metric: Metric::L2, ..SolveSpec::new(MethodSpec::KMeansPp, 3, 1) };
        let err = solve(&x, &spec, &NativeBackend::new(Metric::L1)).unwrap_err().to_string();
        assert!(err.contains("does not match backend metric"), "{err}");
        // agreeing metric runs fine
        assert!(solve(&x, &spec, &NativeBackend::new(Metric::L2)).is_ok());
    }

    #[test]
    fn solve_rejects_profile_mismatch() {
        let mut rng = Rng::new(4);
        let x = synth::gen_gaussian_mixture(&mut rng, 120, 4, 3, 0.15, 1.0);
        let spec = SolveSpec {
            profile: ComputeProfile::Fast,
            ..SolveSpec::new(MethodSpec::KMeansPp, 3, 1)
        };
        let err = solve(&x, &spec, &NativeBackend::new(Metric::L1)).unwrap_err().to_string();
        assert!(err.contains("does not match backend profile"), "{err}");
        // agreeing profile runs fine
        let fast = NativeBackend::new(Metric::L1).with_profile(ComputeProfile::Fast);
        assert!(solve(&x, &spec, &fast).is_ok());
    }

    #[test]
    fn cancelled_token_fails_fast_with_the_marker_error() {
        let mut rng = Rng::new(6);
        let x = synth::gen_gaussian_mixture(&mut rng, 120, 4, 3, 0.15, 1.0);
        let token = CancelToken::new();
        assert!(!token.is_cancelled());
        token.cancel();
        assert!(token.is_cancelled(), "clones share the flag");
        let spec = SolveSpec { cancel: token, ..SolveSpec::new(MethodSpec::KMeansPp, 3, 1) };
        let err = solve(&x, &spec, &NativeBackend::new(Metric::L1)).unwrap_err().to_string();
        assert_eq!(err, CANCELLED);
        // the inert token never cancels and cancel() on it is a no-op
        let inert = CancelToken::none();
        inert.cancel();
        assert!(!inert.is_cancelled());
        // an un-cancelled live token does not disturb a solve
        let spec =
            SolveSpec { cancel: CancelToken::new(), ..SolveSpec::new(MethodSpec::KMeansPp, 3, 1) };
        assert!(solve(&x, &spec, &NativeBackend::new(Metric::L1)).is_ok());
    }

    #[test]
    fn spec_metric_drives_the_computation() {
        // FasterPAM's est_objective is exact, so it must equal a fresh
        // evaluation under spec.metric — for every metric, not just the
        // L1 the surfaces used to hardcode.
        let mut rng = Rng::new(5);
        let x = synth::gen_gaussian_mixture(&mut rng, 150, 5, 3, 0.3, 2.0);
        for metric in [Metric::L1, Metric::L2, Metric::Chebyshev] {
            let spec = SolveSpec { metric, ..SolveSpec::new(MethodSpec::FasterPam, 4, 3) };
            let r = solve(&x, &spec, &NativeBackend::new(metric)).unwrap();
            let exact = crate::eval::objective(
                &x,
                &r.medoids,
                &crate::dissim::DissimCounter::new(metric),
            );
            assert!(
                (exact - r.est_objective).abs() < 1e-3 * exact.max(1.0),
                "{}: est {} != exact {exact}",
                metric.name(),
                r.est_objective
            );
        }
    }

    #[test]
    fn solve_fitted_captures_a_dataset_free_model() {
        let mut rng = Rng::new(8);
        let x = synth::gen_gaussian_mixture(&mut rng, 140, 4, 3, 0.15, 1.0);
        let backend = NativeBackend::new(Metric::L2);
        let spec = SolveSpec { metric: Metric::L2, ..SolveSpec::new(MethodSpec::KMeansPp, 3, 2) };
        let (r, model) = solve_fitted(&x, &spec, &backend).unwrap();
        assert_eq!(model.k(), 3);
        assert_eq!(model.dim(), 4);
        assert_eq!(model.medoids, r.medoids);
        // the medoid rows are copies of the training rows
        for (row, &i) in (0..3).zip(&r.medoids) {
            assert_eq!(model.medoid_rows.row(row), x.row(i));
        }
        // labels/dists cover the training set; inertia is their mean
        let labels = model.labels.as_ref().unwrap();
        let dists = model.dist_to_nearest.as_ref().unwrap();
        assert_eq!((labels.len(), dists.len()), (140, 140));
        let mean = dists.iter().map(|&d| d as f64).sum::<f64>() / 140.0;
        assert!((model.inertia - mean).abs() < 1e-12);
        // assigning the training medoid rows themselves is exact
        let (lab, d0) = model.assign(&backend, &model.medoid_rows.clone()).unwrap();
        assert_eq!(lab, vec![0, 1, 2]);
        assert!(d0.iter().all(|&d| d == 0.0));
        // serving form drops the O(n) arrays but keeps the model
        let served = model.without_training_arrays();
        assert!(served.labels.is_none() && served.dist_to_nearest.is_none());
        assert_eq!(served.k(), 3);
    }

    #[test]
    fn fitted_model_rejects_mismatched_assigns() {
        let mut rng = Rng::new(9);
        let x = synth::gen_gaussian_mixture(&mut rng, 120, 4, 3, 0.15, 1.0);
        let backend = NativeBackend::new(Metric::L1);
        let spec = SolveSpec::new(MethodSpec::KMeansPp, 3, 1);
        let (_, model) = solve_fitted(&x, &spec, &backend).unwrap();
        // wrong point width
        let narrow = Matrix::from_vec(1, 2, vec![0.0, 0.0]);
        let err = model.assign(&backend, &narrow).unwrap_err().to_string();
        assert!(err.contains("expects 4 features"), "{err}");
        // wrong backend metric
        let l2 = NativeBackend::new(Metric::L2);
        let err = model.assign(&l2, &model.medoid_rows.clone()).unwrap_err().to_string();
        assert!(err.contains("fitted under metric 'l1'"), "{err}");
    }

    #[test]
    fn onebatch_knobs_flow_through_spec() {
        let mut rng = Rng::new(3);
        let x = synth::gen_gaussian_mixture(&mut rng, 150, 4, 3, 0.15, 1.0);
        let backend = NativeBackend::new(Metric::L1);
        let spec = SolveSpec {
            m: Some(30),
            ..SolveSpec::new(
                MethodSpec::OneBatch { sampler: SamplerKind::Unif, strategy: SwapStrategy::Eager },
                3,
                5,
            )
        };
        let r = solve(&x, &spec, &backend).unwrap();
        // a unif run computes exactly n*m dissimilarities, so spec.m
        // demonstrably reached the coordinator
        assert_eq!(r.stats.dissim_count, 150 * 30);
    }
}
