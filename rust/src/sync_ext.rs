//! Poison-recovering lock helpers — the **only** sanctioned way to take
//! a `Mutex` or wait on a `Condvar` in this crate.
//!
//! Every shared structure in the server and runtime (the job registry,
//! the dataset cache, the pool region slot, the metrics histograms)
//! protects *restorable* state: a panic while the lock is held can at
//! worst lose one in-flight unit of work, never corrupt the invariants
//! the next holder relies on — terminal job states are published by
//! drop guards, cache in-flight markers are cleared by drop guards, and
//! pool regions are retired by drop guards.  Recovering from a poisoned
//! lock is therefore always correct here, and *not* recovering is a
//! reliability bug: one panicking worker would otherwise wedge every
//! subsequent request on `PoisonError`.
//!
//! The in-tree `tidy` lint `lock-discipline` (see `docs/INVARIANTS.md`)
//! forbids raw `.lock()` / `.try_lock()` / poison `into_inner()` calls
//! anywhere outside this module, so the recovery policy — and the
//! debug-build log line that makes a recovery visible in test output —
//! lives in exactly one place.

use std::sync::{Condvar, Mutex, MutexGuard, TryLockError, WaitTimeoutResult};
use std::time::Duration;

#[cfg(debug_assertions)]
fn note_recovery(what: &str) {
    eprintln!("sync_ext: recovered a poisoned {what} (a previous holder panicked)");
}

#[cfg(not(debug_assertions))]
fn note_recovery(_what: &str) {}

/// Acquires `m`, recovering the guard if a previous holder panicked.
///
/// Debug builds log the recovery to stderr so a poisoned lock is
/// visible in test output even though it no longer fails the caller.
pub fn lock_or_recover<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|poisoned| {
        note_recovery("mutex");
        poisoned.into_inner()
    })
}

/// Non-blocking acquire: `Some(guard)` if the lock was free (recovering
/// from poison like [`lock_or_recover`]), `None` if another thread
/// holds it right now.
pub fn try_lock_or_recover<T>(m: &Mutex<T>) -> Option<MutexGuard<'_, T>> {
    match m.try_lock() {
        Ok(guard) => Some(guard),
        Err(TryLockError::Poisoned(poisoned)) => {
            note_recovery("mutex");
            Some(poisoned.into_inner())
        }
        Err(TryLockError::WouldBlock) => None,
    }
}

/// Blocks on `cv`, re-acquiring the guard through poison recovery.
///
/// Spurious wakeups are still possible — callers keep their usual
/// `while !condition` loop around the wait.
pub fn wait_or_recover<'a, T>(cv: &Condvar, guard: MutexGuard<'a, T>) -> MutexGuard<'a, T> {
    cv.wait(guard).unwrap_or_else(|poisoned| {
        note_recovery("condvar mutex");
        poisoned.into_inner()
    })
}

/// Timed wait on `cv`, re-acquiring the guard through poison recovery.
pub fn wait_timeout_or_recover<'a, T>(
    cv: &Condvar,
    guard: MutexGuard<'a, T>,
    dur: Duration,
) -> (MutexGuard<'a, T>, WaitTimeoutResult) {
    cv.wait_timeout(guard, dur).unwrap_or_else(|poisoned| {
        note_recovery("condvar mutex");
        poisoned.into_inner()
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::{Arc, Condvar, Mutex};
    use std::time::Duration;

    #[test]
    fn lock_or_recover_on_healthy_mutex() {
        let m = Mutex::new(7u32);
        *lock_or_recover(&m) += 1;
        assert_eq!(*lock_or_recover(&m), 8);
    }

    #[test]
    fn lock_or_recover_survives_poison() {
        let m = Arc::new(Mutex::new(vec![1, 2, 3]));
        let m2 = Arc::clone(&m);
        // Poison the mutex: panic while holding the guard.
        let h = std::thread::spawn(move || {
            let _guard = m2.lock().unwrap();
            panic!("poison it");
        });
        assert!(h.join().is_err());
        assert!(m.is_poisoned());
        let guard = lock_or_recover(&m);
        assert_eq!(*guard, vec![1, 2, 3]);
    }

    #[test]
    fn try_lock_distinguishes_held_from_poisoned() {
        let m = Arc::new(Mutex::new(0u32));
        // Held elsewhere -> None.
        let held = m.lock().unwrap();
        assert!(try_lock_or_recover(&m).is_none());
        drop(held);
        // Free -> Some.
        assert!(try_lock_or_recover(&m).is_some());
        // Poisoned but free -> Some (recovered).
        let m2 = Arc::clone(&m);
        let h = std::thread::spawn(move || {
            let _guard = m2.lock().unwrap();
            panic!("poison it");
        });
        assert!(h.join().is_err());
        assert!(try_lock_or_recover(&m).is_some());
    }

    #[test]
    fn waits_round_trip_through_recovery_helpers() {
        let pair = Arc::new((Mutex::new(false), Condvar::new()));
        let pair2 = Arc::clone(&pair);
        let h = std::thread::spawn(move || {
            let (m, cv) = &*pair2;
            *lock_or_recover(m) = true;
            cv.notify_all();
        });
        let (m, cv) = &*pair;
        let mut ready = lock_or_recover(m);
        while !*ready {
            ready = wait_or_recover(cv, ready);
        }
        assert!(*ready);
        h.join().unwrap();
        // Timed wait on a condition that never fires times out cleanly.
        let guard = lock_or_recover(m);
        let (_guard, res) = wait_timeout_or_recover(cv, guard, Duration::from_millis(5));
        assert!(res.timed_out());
    }
}
