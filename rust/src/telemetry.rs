//! Run telemetry: dissimilarity-computation counters, swap counters and
//! wall-clock timers.
//!
//! The dissimilarity counter is the empirical check of the paper's Table 1
//! complexity claims: `O(nm)` for OneBatchPAM, `O(n^2)` for FasterPAM,
//! `O((T+k) n log n)` for BanditPAM++ (see benches/complexity.rs).

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

/// Atomic run counters (shared across backend + coordinator).
#[derive(Default, Debug)]
pub struct Counters {
    dissim: AtomicU64,
    swaps: AtomicU64,
    xla_executions: AtomicU64,
}

impl Counters {
    /// Record `n` pairwise dissimilarity computations.
    #[inline]
    pub fn add_dissim(&self, n: u64) {
        self.dissim.fetch_add(n, Ordering::Relaxed);
    }

    /// Record one accepted swap.
    #[inline]
    pub fn add_swap(&self) {
        self.swaps.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one XLA executable invocation.
    #[inline]
    pub fn add_xla_exec(&self) {
        self.xla_executions.fetch_add(1, Ordering::Relaxed);
    }

    /// Dissimilarity computations so far.
    pub fn dissim(&self) -> u64 {
        self.dissim.load(Ordering::Relaxed)
    }

    /// Accepted swaps so far.
    pub fn swaps(&self) -> u64 {
        self.swaps.load(Ordering::Relaxed)
    }

    /// XLA executions so far.
    pub fn xla_executions(&self) -> u64 {
        self.xla_executions.load(Ordering::Relaxed)
    }

    /// Reset everything to zero.
    pub fn reset(&self) {
        self.dissim.store(0, Ordering::Relaxed);
        self.swaps.store(0, Ordering::Relaxed);
        self.xla_executions.store(0, Ordering::Relaxed);
    }
}

/// Simple scoped wall-clock timer.
pub struct Timer {
    start: Instant,
}

impl Timer {
    /// Start timing now.
    pub fn start() -> Self {
        Timer { start: Instant::now() }
    }

    /// Elapsed seconds.
    pub fn secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }
}

/// Result of one timed run: medoids + objective + resource usage.
#[derive(Clone, Debug)]
pub struct RunStats {
    /// Wall-clock seconds.
    pub seconds: f64,
    /// Pairwise dissimilarity computations.
    pub dissim_count: u64,
    /// Accepted swaps.
    pub swap_count: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate_and_reset() {
        let c = Counters::default();
        c.add_dissim(10);
        c.add_dissim(5);
        c.add_swap();
        c.add_xla_exec();
        assert_eq!(c.dissim(), 15);
        assert_eq!(c.swaps(), 1);
        assert_eq!(c.xla_executions(), 1);
        c.reset();
        assert_eq!(c.dissim() + c.swaps() + c.xla_executions(), 0);
    }

    #[test]
    fn timer_monotonic() {
        let t = Timer::start();
        std::thread::sleep(std::time::Duration::from_millis(2));
        assert!(t.secs() > 0.0);
    }
}
